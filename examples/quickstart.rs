//! Quickstart: load the AOT-compiled `quickstart` artifacts, initialize
//! weights on the PJRT device, take a few SGD steps, and evaluate — the
//! minimal end-to-end tour of the three-layer stack.
//!
//!     make artifacts && cargo run --release --example quickstart

use std::sync::Arc;

use bptcnn::data::Dataset;
use bptcnn::nn::Network;
use bptcnn::runtime::{find_model_dir, XlaService};
use bptcnn::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let Some(dir) = find_model_dir("quickstart") else {
        anyhow::bail!("artifacts missing — run `make artifacts` first");
    };
    println!("loading artifacts from {} …", dir.display());
    let service = XlaService::start(&dir)?;
    let h = service.handle();
    let cfg = h.manifest.config.clone();
    println!(
        "model '{}': {} parameters, batch {} of {}×{}×{} images",
        cfg.name,
        cfg.param_count(),
        cfg.batch_size,
        cfg.input_hw,
        cfg.input_hw,
        cfg.in_channels
    );

    // Synthetic 10-class dataset (the ImageNet stand-in).
    let ds = Arc::new(Dataset::synthetic(&cfg, 512, 0.25, 1));
    let mut weights = h.init_weights(42)?;

    // A few epochs of plain SGD through the compiled train_step.
    println!("\n{:>5} {:>10} {:>10}", "step", "loss", "accuracy");
    let steps = 40;
    for step in 0..steps {
        let (xv, yv, _) = ds.batch(step * cfg.batch_size, cfg.batch_size);
        let x = Tensor::from_vec(&[cfg.batch_size, cfg.input_hw, cfg.input_hw, cfg.in_channels], xv);
        let y = Tensor::from_vec(&[cfg.batch_size, cfg.num_classes], yv);
        let (w, loss, correct) = h.train_step(weights, x, y, 0.3)?;
        weights = w;
        if step % 5 == 0 || step == steps - 1 {
            println!(
                "{step:>5} {loss:>10.4} {:>10.3}",
                correct / cfg.batch_size as f32
            );
        }
    }

    // Cross-backend check: the native Rust network computes the same loss.
    let (xv, yv, _) = ds.batch(0, cfg.batch_size);
    let x = Tensor::from_vec(&[cfg.batch_size, cfg.input_hw, cfg.input_hw, cfg.in_channels], xv.clone());
    let y = Tensor::from_vec(&[cfg.batch_size, cfg.num_classes], yv.clone());
    let (xla_loss, _) = h.eval_step(weights.clone(), x, y)?;
    let native = Network::with_weights(&cfg, weights);
    let (native_loss, _) = native.eval_batch(&xv, &yv, cfg.batch_size);
    println!(
        "\ncross-backend parity: XLA loss {xla_loss:.5} vs native loss {native_loss:.5} (Δ {:.2e})",
        (xla_loss - native_loss).abs()
    );
    anyhow::ensure!((xla_loss - native_loss).abs() < 1e-3, "backends disagree");
    println!("quickstart OK");
    Ok(())
}
