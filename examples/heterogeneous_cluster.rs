//! Heterogeneous-cluster demo (§3.3): IDPA vs UDPA and AGWU vs SGWU on a
//! real in-process cluster with deliberately skewed node speeds, plus the
//! same scenario at paper scale through the discrete-event simulator.
//!
//!     cargo run --release --example heterogeneous_cluster

use bptcnn::config::{
    ClusterConfig, NetworkConfig, PartitionStrategy, TrainConfig, UpdateStrategy,
};
use bptcnn::metrics::Table;
use bptcnn::outer::train_native;
use bptcnn::sim::{simulate, SimConfig};

fn main() {
    // A small but sharply heterogeneous cluster: node speeds 1×, 1.5×, 3×.
    let mut cluster = ClusterConfig::homogeneous(3);
    cluster.nodes[0].freq_ghz = 3.0;
    cluster.nodes[1].freq_ghz = 2.0;
    cluster.nodes[2].freq_ghz = 1.0;

    println!("=== real in-process cluster (3 nodes, speeds 3:2:1) ===");
    let mut table = Table::new(
        "strategy ablation (real training, native backend)",
        &["strategy", "wall[s]", "sync wait[s]", "balance", "final acc", "alloc"],
    );
    for (update, partition) in [
        (UpdateStrategy::Agwu, PartitionStrategy::Idpa),
        (UpdateStrategy::Agwu, PartitionStrategy::Udpa),
        (UpdateStrategy::Sgwu, PartitionStrategy::Idpa),
        (UpdateStrategy::Sgwu, PartitionStrategy::Udpa),
    ] {
        let tc = TrainConfig {
            network: NetworkConfig::quickstart(),
            update,
            partition,
            total_samples: 600,
            iterations: 5,
            idpa_batches: 2,
            learning_rate: 0.25,
            seed: 11,
        };
        let r = train_native(&tc, &cluster);
        table.row(&[
            format!("{}+{}", update.name(), partition.name()),
            format!("{:.2}", r.wall_s),
            format!("{:.2}", r.sync_wait_s),
            format!("{:.3}", r.balance_index),
            format!("{:.3}", r.final_accuracy),
            format!("{:?}", r.allocations),
        ]);
    }
    table.print();

    println!("\n=== same ablation at paper scale (30 nodes, simulated) ===");
    let mut sim_table = Table::new(
        "strategy ablation (600k samples, 100 iterations, DES)",
        &["strategy", "makespan[s]", "sync wait[s]", "balance", "comm[MB]"],
    );
    for (update, partition) in [
        (UpdateStrategy::Agwu, PartitionStrategy::Idpa),
        (UpdateStrategy::Agwu, PartitionStrategy::Udpa),
        (UpdateStrategy::Sgwu, PartitionStrategy::Idpa),
        (UpdateStrategy::Sgwu, PartitionStrategy::Udpa),
    ] {
        let cfg = SimConfig {
            cluster: ClusterConfig::heterogeneous(30, 7),
            update,
            partition,
            samples: 600_000,
            iterations: 100,
            ..SimConfig::paper_default()
        };
        let r = simulate(&cfg);
        sim_table.row(&[
            format!("{}+{}", update.name(), partition.name()),
            format!("{:.1}", r.total_s),
            format!("{:.1}", r.sync_wait_s),
            format!("{:.3}", r.balance_index),
            format!("{:.2}", r.comm_mb),
        ]);
    }
    sim_table.print();
    println!("\nExpected shape (paper Fig. 14): AGWU+IDPA fastest, UDPA pays sync wait on\nheterogeneous nodes, IDPA allocations ∝ node speed. heterogeneous_cluster OK");
}
