//! Inner-layer parallelism demo (§4): decompose a convolutional layer into
//! Algorithm-4.1 tasks, schedule them with the Algorithm-4.2 priority
//! scheduler, and compare against sequential execution; then run a full
//! task-parallel train step and verify it matches the serial step bit-for-
//! bit at the tolerance of f32 reduction order.
//!
//!     cargo run --release --example inner_parallel

use bptcnn::config::NetworkConfig;
use bptcnn::data::Dataset;
use bptcnn::inner::{
    conv2d_parallel, conv_task_dag, parallel_train_step, train_step_dag, TilePolicy,
};
use bptcnn::nn::ops::{self, ConvDims};
use bptcnn::nn::{Network, StepWorkspace};
use bptcnn::util::rng::Xoshiro256;
use bptcnn::util::threadpool::ThreadPool;

fn main() {
    let d = ConvDims { n: 16, h: 32, w: 32, c: 8, k: 3, co: 16 };
    let mut rng = Xoshiro256::new(1);
    let x: Vec<f32> = (0..d.x_len()).map(|_| rng.normal(0.0, 1.0) as f32).collect();
    let f: Vec<f32> = (0..d.f_len()).map(|_| rng.normal(0.0, 1.0) as f32).collect();
    let b: Vec<f32> = (0..d.co).map(|_| 0.0).collect();

    println!("conv layer: {}×{}×{}×{}, K_C = {} (Eq. 13 tasks/image)", d.n, d.h, d.w, d.c, d.kc());

    // Sequential reference.
    let mut out_seq = vec![0.0f32; d.y_len()];
    let t0 = std::time::Instant::now();
    ops::conv2d_same_fwd(&d, &x, &f, &b, &mut out_seq);
    let t_seq = t0.elapsed().as_secs_f64();

    // Task-parallel with various granularities (Alg. 4.1 + Alg. 4.2).
    println!("\n{:>14} {:>8} {:>12} {:>10} {:>9}", "rows/task", "tasks", "makespan", "balance", "max|Δ|");
    for threads in [1, 2, 4] {
        let pool = ThreadPool::new(threads);
        for rows in [1usize, 4, 8] {
            let mut out_par = vec![0.0f32; d.y_len()];
            let stats = conv2d_parallel(&pool, &d, &x, &f, &b, &mut out_par, rows);
            let max_diff = out_par
                .iter()
                .zip(&out_seq)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            println!(
                "{threads}T × {rows:>2} rows  {:>8} {:>10.2}ms {:>10.3} {:>9.1e}",
                stats.tasks,
                stats.makespan_s * 1e3,
                stats.assigned_balance_index(),
                max_diff
            );
            assert!(max_diff < 1e-4);
        }
    }
    println!("(sequential: {:.2} ms)", t_seq * 1e3);

    // Whole-train-step DAG structure (Fig. 9).
    let cfg = NetworkConfig::default();
    let dag = conv_task_dag(&d, 4);
    let step_dag = train_step_dag(&cfg, cfg.batch_size);
    println!(
        "\ntrain-step DAG: {} tasks, critical path {:.0} / total {:.0} cost units (→ {:.1}× max parallelism)",
        step_dag.len(),
        step_dag.critical_path_cost(),
        step_dag.total_cost(),
        step_dag.total_cost() / step_dag.critical_path_cost()
    );
    drop(dag);

    // Full task-parallel train step == serial train step.
    let cfg = NetworkConfig::quickstart();
    let ds = Dataset::synthetic(&cfg, 64, 0.2, 2);
    let (xb, yb, _) = ds.batch(0, cfg.batch_size);
    let mut serial = Network::init(&cfg, 3);
    let mut par = serial.clone();
    let pool = ThreadPool::new(4);
    let (sl, _) = serial.train_batch(&xb, &yb, cfg.batch_size, 0.1);
    let mut ws = StepWorkspace::new();
    let r = parallel_train_step(
        &pool,
        &mut par,
        &xb,
        &yb,
        cfg.batch_size,
        0.1,
        TilePolicy::grid2d(2),
        &mut ws,
    );
    println!(
        "\nparallel train step: loss {:.5} (serial {:.5}), weight max|Δ| {:.1e}, {} tasks",
        r.loss,
        sl,
        serial.weights.max_abs_diff(&par.weights),
        r.stats.tasks
    );
    assert!(serial.weights.max_abs_diff(&par.weights) < 1e-5);
    println!("inner_parallel OK");
}
