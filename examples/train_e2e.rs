//! End-to-end validation run (the session's mandated driver): train the
//! `e2e` CNN (~38 k params) for a few hundred steps on the synthetic
//! 10-class corpus with the FULL stack composed:
//!
//!   L1 Pallas conv/pool/FC kernels → lowered inside → L2 JAX train_step
//!   → AOT HLO text → PJRT runtime → L3 Rust coordinator running
//!   4 heterogeneous workers with AGWU + IDPA.
//!
//! Logs the loss curve and writes `results/train_e2e.json`; the run is
//! recorded in EXPERIMENTS.md.
//!
//!     make artifacts && cargo run --release --example train_e2e

use std::sync::Arc;

use bptcnn::config::{ClusterConfig, PartitionStrategy, TrainConfig, UpdateStrategy};
use bptcnn::data::Dataset;
use bptcnn::metrics::{ascii_chart, log_run, Table};
use bptcnn::outer::worker::LocalTrainer;
use bptcnn::outer::{build_schedule, run_agwu, slowdown_factors};
use bptcnn::runtime::{find_model_dir, XlaService, XlaTrainer};
use bptcnn::tensor::Tensor;
use bptcnn::util::json::Json;

fn main() -> anyhow::Result<()> {
    let Some(dir) = find_model_dir("e2e") else {
        anyhow::bail!("artifacts missing — run `make artifacts` first");
    };
    let service = XlaService::start(&dir)?;
    let network = service.handle().manifest.config.clone();
    let nodes = 4;
    let samples = 2048;
    let iterations = 8; // epochs over each worker's shard (≈ hundreds of SGD steps)

    let cluster = ClusterConfig::heterogeneous(nodes, 0x5EED);
    let tc = TrainConfig {
        network: network.clone(),
        update: UpdateStrategy::Agwu,
        partition: PartitionStrategy::Idpa,
        total_samples: samples,
        iterations,
        idpa_batches: 3,
        learning_rate: 0.15,
        seed: 42,
    };
    println!(
        "e2e: {} params, {} synthetic samples, {} heterogeneous nodes, AGWU+IDPA, K={}",
        network.param_count(),
        samples,
        nodes,
        iterations
    );

    let train_ds = Arc::new(Dataset::synthetic(&network, samples, 0.3, tc.seed));
    let eval_ds = Dataset::synthetic_split(&network, 256, 0.3, tc.seed, tc.seed ^ 0xEEEE);
    let (schedule, allocations, iters) = build_schedule(&tc, &cluster);
    let slow = slowdown_factors(&cluster);
    println!("IDPA allocations (samples/node): {allocations:?} | slowdowns {slow:?}");

    let workers: Vec<Box<dyn LocalTrainer>> = (0..nodes)
        .map(|j| {
            Box::new(
                XlaTrainer::new(service.handle(), Arc::clone(&train_ds), tc.learning_rate)
                    .with_slowdown(slow[j]),
            ) as Box<dyn LocalTrainer>
        })
        .collect();
    let init = service.handle().init_weights(tc.seed as i32)?;

    let eval_handle = service.handle();
    let net2 = network.clone();
    let eval_hook = move |ws: &bptcnn::tensor::WeightSet| -> (f64, f64) {
        let bsz = net2.batch_size;
        let (mut loss, mut correct, mut batches, mut seen) = (0.0f64, 0.0f64, 0usize, 0usize);
        while seen < eval_ds.len() {
            let (xv, yv, _) = eval_ds.batch(seen, bsz);
            let x = Tensor::from_vec(&[bsz, net2.input_hw, net2.input_hw, net2.in_channels], xv);
            let y = Tensor::from_vec(&[bsz, net2.num_classes], yv);
            let (l, c) = eval_handle.eval_step(ws.clone(), x, y).expect("xla eval");
            loss += l as f64;
            correct += c as f64;
            seen += bsz;
            batches += 1;
        }
        (loss / batches as f64, correct / (batches * bsz) as f64)
    };

    let t0 = std::time::Instant::now();
    let report = run_agwu(init, workers, &schedule, iters, Some(&eval_hook));
    let wall = t0.elapsed().as_secs_f64();

    let mut table = Table::new(
        "e2e loss curve (held-out, per global version)",
        &["version", "node", "t[s]", "eval loss", "eval acc"],
    );
    for v in &report.versions {
        if let Some((loss, acc)) = v.eval {
            table.row(&[
                format!("{}", v.version),
                format!("{}", v.node),
                format!("{:.2}", v.at_s),
                format!("{loss:.4}"),
                format!("{acc:.3}"),
            ]);
        }
    }
    table.print();

    let curve: Vec<(f64, f64)> = report
        .versions
        .iter()
        .filter_map(|v| v.eval.map(|(l, _)| (v.version as f64, l)))
        .collect();
    let acc_curve: Vec<(f64, f64)> = report
        .versions
        .iter()
        .filter_map(|v| v.eval.map(|(_, a)| (v.version as f64, a)))
        .collect();
    println!(
        "{}",
        ascii_chart("\ne2e held-out loss vs global version", &[("loss", curve.clone())], 64, 14)
    );

    let first_loss = curve.first().map(|p| p.1).unwrap_or(f64::NAN);
    let last_loss = curve.last().map(|p| p.1).unwrap_or(f64::NAN);
    let final_acc = acc_curve.last().map(|p| p.1).unwrap_or(0.0);
    println!(
        "loss {first_loss:.4} → {last_loss:.4} | final accuracy {final_acc:.3} | comm {:.2} MB | wall {wall:.1}s ({} versions)",
        report.comm.megabytes(),
        report.versions.len()
    );

    log_run(
        "results/train_e2e.json",
        Json::obj(vec![
            ("example", Json::from("train_e2e")),
            ("params", Json::from(network.param_count())),
            ("samples", Json::from(samples)),
            ("nodes", Json::from(nodes)),
            ("iterations", Json::from(iters)),
            ("first_loss", Json::from(first_loss)),
            ("last_loss", Json::from(last_loss)),
            ("final_accuracy", Json::from(final_acc)),
            ("comm_mb", Json::from(report.comm.megabytes())),
            ("wall_s", Json::from(wall)),
            ("loss_curve", Json::Arr(curve.iter().map(|p| Json::arr_f64(&[p.0, p.1])).collect())),
        ]),
    )?;
    println!("(logged to results/train_e2e.json)");

    anyhow::ensure!(last_loss < first_loss, "e2e training did not learn");
    anyhow::ensure!(final_acc > 0.3, "e2e accuracy too low: {final_acc}");
    println!("train_e2e OK");
    Ok(())
}
