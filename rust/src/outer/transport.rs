//! The outer layer's communication substrate: every node ↔ parameter-server
//! exchange (§3.2–3.3) goes through the [`Transport`] trait, with three
//! backends sharing one code path:
//!
//! * [`InProcTransport`] — the original thread/`Arc` cluster: fetch is a
//!   refcount bump, submit applies the Eq. 7/10 update under the shared
//!   server lock. Deterministic, zero-copy — the default for tests/CI.
//! * [`TcpTransport`] — real sockets speaking the length-prefixed protocol
//!   of [`super::wire`] against a standalone [`super::server`] process;
//!   weight sets cross the wire through the bit-exact
//!   [`crate::tensor::wire`] codec.
//! * [`ThrottledTransport`] — a decorator that sleeps the [`TransferModel`]
//!   link cost (latency + bytes/bandwidth) around any inner transport, so
//!   the simulated Eq. 11 communication term and real transfer share the
//!   same call sites instead of living in a model-only struct.
//!
//! All backends keep measured accounting ([`TransportStats`]): operation
//! counts, wall time inside fetch/submit, and — for the socket backend —
//! the bytes actually moved, so `bench_outer` reports measured (not
//! modeled) communication cost.

use std::fmt;
use std::io::{BufReader, BufWriter, Write as _};
use std::net::TcpStream;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::tensor::WeightSet;

use super::fault::FaultStats;
use super::param_server::ParamServer;
use super::wire::{read_msg, write_msg, Msg};

/// Default socket read/write deadline for [`TcpTransport`] and the server's
/// per-connection handlers. A hung peer surfaces as a timeout error (which
/// the retry layer can turn into a reconnect) instead of blocking forever.
pub const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(30);

/// An error the *server* reported through a wire `Error` frame — as opposed
/// to a local I/O failure. Typed so callers can distinguish "the server
/// rejected my request" (protocol violation, decode rejection, bad node id)
/// from "the connection died" via `err.downcast_ref::<ServerError>()`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServerError(pub String);

impl fmt::Display for ServerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "param server error: {}", self.0)
    }
}

impl std::error::Error for ServerError {}

/// Which global weight-update rule a submission requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitMode {
    /// Eq. 10 with γ staleness attenuation + accuracy weighting.
    Agwu,
    /// Downpour-style 1/m increment (ablation baseline).
    Plain,
    /// Eq. 7 round averaging; the server barriers until all m nodes of the
    /// round have submitted.
    Sgwu,
}

/// Submission metadata accompanying the local weight set.
#[derive(Debug, Clone, Copy)]
pub struct SubmitMeta {
    pub mode: SubmitMode,
    /// Global version the node trained from (k in Eq. 9/10).
    pub base: usize,
    /// Local training accuracy Q (Eq. 7 / Eq. 10 weighting).
    pub accuracy: f64,
    /// Local mean training loss (server-side learning curve).
    pub loss: f64,
    /// Ask for a post-update global snapshot in the ack. Only the
    /// in-process backend honors it (atomically with the update, for eval
    /// hooks); remote evaluators re-fetch instead.
    pub want_snapshot: bool,
}

/// Reply to a submission.
#[derive(Debug)]
pub struct SubmitAck {
    /// Server version after processing this submission. For a *buffered*
    /// in-process SGWU part (round not yet complete) this is the still-
    /// current version; the completing submission returns the new one.
    pub version: usize,
    /// Post-update global snapshot when requested and supported.
    pub snapshot: Option<Arc<WeightSet>>,
}

/// Measured per-endpoint communication accounting.
#[derive(Debug, Clone, Copy, Default)]
pub struct TransportStats {
    pub fetches: usize,
    pub submits: usize,
    /// Bytes actually moved on the wire by this endpoint, both directions
    /// (frame prefixes included). 0 for in-process transports — their
    /// "transfer" is an `Arc` refcount bump.
    pub wire_bytes: u64,
    /// Wall seconds spent inside `fetch_global`, including any throttle.
    /// Excludes connection setup — that is `connect_wall_s`.
    pub fetch_wall_s: f64,
    /// Wall seconds spent inside `submit` (for SGWU over TCP this includes
    /// the Eq. 8 barrier wait — the reply is the round release).
    pub submit_wall_s: f64,
    /// Wall seconds establishing the endpoint (TCP connect + registration).
    /// Kept out of the fetch/submit columns so per-operation stall
    /// attribution is honest — one-time setup is not Eq. 11 transfer cost.
    pub connect_wall_s: f64,
    /// Wall seconds the *driver* was blocked waiting on communication.
    /// For a serialized worker loop this is the whole fetch+submit wall;
    /// a pipelined driver only counts the residual waits its double
    /// buffering could not hide.
    pub stall_wall_s: f64,
    /// Wall seconds of communication hidden behind local compute
    /// (comm wall − stall, clamped at 0). 0 for serialized drivers.
    pub overlap_wall_s: f64,
    /// Peak number of comm operations queued or executing on the comm
    /// thread at once. 0 for serialized drivers (no queue exists).
    pub max_inflight: usize,
    /// Fault-recovery counters (retries, reconnects, checkpoints, ...).
    pub fault: FaultStats,
}

impl TransportStats {
    pub fn merge(&mut self, other: &TransportStats) {
        self.fetches += other.fetches;
        self.submits += other.submits;
        self.wire_bytes += other.wire_bytes;
        self.fetch_wall_s += other.fetch_wall_s;
        self.submit_wall_s += other.submit_wall_s;
        self.connect_wall_s += other.connect_wall_s;
        self.stall_wall_s += other.stall_wall_s;
        self.overlap_wall_s += other.overlap_wall_s;
        self.max_inflight = self.max_inflight.max(other.max_inflight);
        self.fault.merge(&other.fault);
    }
}

/// A node's view of the parameter server (Definition 2's global weight set
/// behind fetch/submit). One instance per node; implementations carry the
/// node identity fixed at construction.
pub trait Transport: Send {
    /// Fetch the freshest global weight set and its version.
    fn fetch_global(&mut self) -> Result<(Arc<WeightSet>, usize)>;

    /// Submit a locally-trained weight set (moved — in-process backends
    /// hand it to the server without a copy; socket backends serialize and
    /// drop it).
    fn submit(&mut self, local: WeightSet, meta: &SubmitMeta) -> Result<SubmitAck>;

    /// Measured accounting for this endpoint.
    fn stats(&self) -> TransportStats;

    /// Signal an orderly end of this node's run (remote backends tell the
    /// server; in-process ones need nothing).
    fn finish(&mut self) -> Result<()> {
        Ok(())
    }

    /// Drain sample ranges the server re-allocated onto this node after a
    /// peer died (IDPA re-allocation). Ranges arrive piggybacked on fetch
    /// replies; drivers fold them into the local training schedule. Default:
    /// nothing to drain (in-process and decorator-only backends).
    fn take_reassigned(&mut self) -> Vec<Range<usize>> {
        Vec::new()
    }

    /// Liveness probe renewing this node's lease on the server without
    /// moving weight state. Backends with no lease concept no-op.
    fn heartbeat(&mut self) -> Result<()> {
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// In-process backend
// ---------------------------------------------------------------------------

/// The thread-cluster backend: all nodes share one [`ParamServer`] behind a
/// mutex; fetch hands out `Arc` snapshots and submit applies the update rule
/// directly. Exactly the pre-refactor semantics, now behind the trait.
pub struct InProcTransport {
    ps: Arc<Mutex<ParamServer>>,
    node: usize,
    stats: TransportStats,
}

impl InProcTransport {
    pub fn new(ps: Arc<Mutex<ParamServer>>, node: usize) -> Self {
        Self { ps, node, stats: TransportStats::default() }
    }
}

impl Transport for InProcTransport {
    fn fetch_global(&mut self) -> Result<(Arc<WeightSet>, usize)> {
        let t0 = Instant::now();
        let out = self.ps.lock().unwrap().fetch(self.node);
        self.stats.fetches += 1;
        self.stats.fetch_wall_s += t0.elapsed().as_secs_f64();
        Ok(out)
    }

    fn submit(&mut self, local: WeightSet, meta: &SubmitMeta) -> Result<SubmitAck> {
        let t0 = Instant::now();
        let ack = {
            let mut ps = self.ps.lock().unwrap();
            let version = match meta.mode {
                SubmitMode::Agwu => {
                    ps.update_agwu(self.node, &local, meta.base, meta.accuracy)
                }
                SubmitMode::Plain => ps.update_async_plain(self.node, &local, meta.base),
                SubmitMode::Sgwu => ps
                    .submit_sgwu(self.node, local, meta.accuracy)
                    .unwrap_or_else(|| ps.version()),
            };
            // Snapshot under the same lock as the update: eval hooks see
            // exactly the version this submission produced.
            let snapshot = meta.want_snapshot.then(|| ps.global_arc());
            SubmitAck { version, snapshot }
        };
        self.stats.submits += 1;
        self.stats.submit_wall_s += t0.elapsed().as_secs_f64();
        Ok(ack)
    }

    fn stats(&self) -> TransportStats {
        self.stats
    }
}

// ---------------------------------------------------------------------------
// TCP backend
// ---------------------------------------------------------------------------

/// Socket backend: one connection to the standalone param-server process,
/// speaking the [`super::wire`] protocol. Blocking request/reply — an SGWU
/// submit does not return until the server installed the round (the socket
/// is the Eq. 8 barrier).
pub struct TcpTransport {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    stats: TransportStats,
    /// Sample ranges re-allocated onto this node, piggybacked on fetch
    /// replies and drained by [`Transport::take_reassigned`].
    reassigned: Vec<Range<usize>>,
    /// Shared cluster-epoch cell (worker failover): the `Hello` carried its
    /// value at connect time, and every `Global` reply raises it to the
    /// serving side's epoch, so a reconnect after a standby promotion
    /// registers with — and thereby fences — the right generation.
    epoch_cell: Option<Arc<AtomicU64>>,
}

impl TcpTransport {
    /// Connect to `addr` ("host:port") and register as `node`, with the
    /// default [`DEFAULT_IO_TIMEOUT`] socket deadlines. The setup time
    /// (TCP connect + `Hello` registration write) is recorded in
    /// `connect_wall_s`, separate from the per-operation wall columns.
    pub fn connect(addr: &str, node: usize) -> Result<Self> {
        Self::connect_with_timeout(addr, node, Some(DEFAULT_IO_TIMEOUT))
    }

    /// [`TcpTransport::connect`] with an explicit read/write deadline.
    /// `None` restores the old block-forever behavior. Note the read
    /// deadline also bounds the SGWU barrier wait (the delayed Ack *is*
    /// the Eq. 8 barrier) — size it above the slowest node's epoch.
    pub fn connect_with_timeout(
        addr: &str,
        node: usize,
        io_timeout: Option<Duration>,
    ) -> Result<Self> {
        Self::connect_with_epoch(addr, node, io_timeout, None)
    }

    /// [`TcpTransport::connect_with_timeout`] plus a shared epoch cell for
    /// failover-aware deployments: the `Hello` registers at the cell's
    /// current cluster epoch and later `Global` replies keep it fresh.
    /// `None` registers at epoch 0 (single-server deployments).
    pub fn connect_with_epoch(
        addr: &str,
        node: usize,
        io_timeout: Option<Duration>,
        epoch_cell: Option<Arc<AtomicU64>>,
    ) -> Result<Self> {
        let t0 = Instant::now();
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connect to param server at {addr}"))?;
        stream.set_nodelay(true).ok();
        let io_timeout = io_timeout.filter(|d| !d.is_zero());
        stream.set_read_timeout(io_timeout).context("set read timeout")?;
        stream.set_write_timeout(io_timeout).context("set write timeout")?;
        let reader = BufReader::new(stream.try_clone().context("clone stream")?);
        let epoch = epoch_cell.as_ref().map(|c| c.load(Ordering::SeqCst)).unwrap_or(0);
        let mut t = Self {
            reader,
            writer: BufWriter::new(stream),
            stats: TransportStats::default(),
            reassigned: Vec::new(),
            epoch_cell,
        };
        t.stats.wire_bytes +=
            write_msg(&mut t.writer, &Msg::Hello { node: node as u32, epoch })? as u64;
        t.stats.connect_wall_s = t0.elapsed().as_secs_f64();
        Ok(t)
    }

    fn round_trip(&mut self, msg: &Msg) -> Result<Msg> {
        self.stats.wire_bytes += write_msg(&mut self.writer, msg)? as u64;
        let (reply, n) = read_msg(&mut self.reader)?;
        self.stats.wire_bytes += n as u64;
        if let Msg::Error { msg } = reply {
            return Err(anyhow::Error::new(ServerError(msg)));
        }
        Ok(reply)
    }
}

impl Transport for TcpTransport {
    fn fetch_global(&mut self) -> Result<(Arc<WeightSet>, usize)> {
        let t0 = Instant::now();
        let reply = self.round_trip(&Msg::Fetch)?;
        let out = match reply {
            Msg::Global { version, epoch, reassigned, weights } => {
                if let Some(cell) = &self.epoch_cell {
                    // Only ever raise: a snapshot from the current primary
                    // must not roll the worker's epoch knowledge back.
                    cell.fetch_max(epoch, Ordering::SeqCst);
                }
                self.reassigned.extend(
                    reassigned.into_iter().map(|(s, e)| s as usize..e as usize),
                );
                (Arc::new(weights), version as usize)
            }
            other => bail!("unexpected reply to fetch: {other:?}"),
        };
        self.stats.fetches += 1;
        self.stats.fetch_wall_s += t0.elapsed().as_secs_f64();
        Ok(out)
    }

    fn submit(&mut self, local: WeightSet, meta: &SubmitMeta) -> Result<SubmitAck> {
        let t0 = Instant::now();
        let reply = self.round_trip(&Msg::Submit {
            mode: meta.mode,
            base: meta.base as u64,
            accuracy: meta.accuracy,
            loss: meta.loss,
            weights: local,
        })?;
        let version = match reply {
            Msg::Ack { version } => version as usize,
            other => bail!("unexpected reply to submit: {other:?}"),
        };
        self.stats.submits += 1;
        self.stats.submit_wall_s += t0.elapsed().as_secs_f64();
        Ok(SubmitAck { version, snapshot: None })
    }

    fn stats(&self) -> TransportStats {
        self.stats
    }

    fn finish(&mut self) -> Result<()> {
        self.stats.wire_bytes += write_msg(&mut self.writer, &Msg::Done)? as u64;
        self.writer.flush().ok();
        Ok(())
    }

    fn take_reassigned(&mut self) -> Vec<Range<usize>> {
        std::mem::take(&mut self.reassigned)
    }

    fn heartbeat(&mut self) -> Result<()> {
        match self.round_trip(&Msg::Ping)? {
            Msg::Pong => Ok(()),
            other => bail!("unexpected reply to ping: {other:?}"),
        }
    }
}

// ---------------------------------------------------------------------------
// Link model + throttled decorator
// ---------------------------------------------------------------------------

/// Simple latency + bandwidth link model (§3.3.2(3), Fig. 15a) — the unit
/// cost behind Eq. 11's communication term.
#[derive(Debug, Clone, Copy)]
pub struct TransferModel {
    pub bandwidth_bytes_per_s: f64,
    pub latency_s: f64,
}

impl TransferModel {
    pub fn new(bandwidth_bytes_per_s: f64, latency_s: f64) -> Self {
        assert!(bandwidth_bytes_per_s > 0.0);
        Self { bandwidth_bytes_per_s, latency_s }
    }

    /// Seconds to move `bytes` over the link.
    pub fn transfer_time(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bytes_per_s
    }

    /// Eq. 11 as time: 2·c_w·m·K where c_w is one weight-set transfer.
    pub fn total_update_time(&self, weight_bytes: usize, m: usize, k: usize) -> f64 {
        2.0 * self.transfer_time(weight_bytes) * m as f64 * k as f64
    }
}

/// Decorator imposing a [`TransferModel`]'s link cost on any inner
/// transport: each fetch sleeps the modeled download time of the received
/// set, each submit the modeled upload time of the sent set. Wrapping
/// [`InProcTransport`] reproduces the old simulated-link behavior; wrapping
/// [`TcpTransport`] emulates a slower WAN on top of real sockets.
pub struct ThrottledTransport<T: Transport> {
    inner: T,
    model: TransferModel,
    throttle_fetch_s: f64,
    throttle_submit_s: f64,
}

impl<T: Transport> ThrottledTransport<T> {
    pub fn new(inner: T, model: TransferModel) -> Self {
        Self { inner, model, throttle_fetch_s: 0.0, throttle_submit_s: 0.0 }
    }

    pub fn into_inner(self) -> T {
        self.inner
    }
}

impl<T: Transport> Transport for ThrottledTransport<T> {
    fn fetch_global(&mut self) -> Result<(Arc<WeightSet>, usize)> {
        let (ws, version) = self.inner.fetch_global()?;
        let dt = self.model.transfer_time(ws.byte_size());
        self.throttle_fetch_s += dt;
        std::thread::sleep(std::time::Duration::from_secs_f64(dt));
        Ok((ws, version))
    }

    fn submit(&mut self, local: WeightSet, meta: &SubmitMeta) -> Result<SubmitAck> {
        let dt = self.model.transfer_time(local.byte_size());
        self.throttle_submit_s += dt;
        std::thread::sleep(std::time::Duration::from_secs_f64(dt));
        self.inner.submit(local, meta)
    }

    /// Inner stats with the modeled link time folded into the wall columns —
    /// the simulated and the real cost report through one channel.
    fn stats(&self) -> TransportStats {
        let mut s = self.inner.stats();
        s.fetch_wall_s += self.throttle_fetch_s;
        s.submit_wall_s += self.throttle_submit_s;
        s
    }

    fn finish(&mut self) -> Result<()> {
        self.inner.finish()
    }

    fn take_reassigned(&mut self) -> Vec<Range<usize>> {
        self.inner.take_reassigned()
    }

    fn heartbeat(&mut self) -> Result<()> {
        self.inner.heartbeat()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn ws(vals: &[f32]) -> WeightSet {
        WeightSet::new(vec![Tensor::from_vec(&[vals.len()], vals.to_vec())])
    }

    fn inproc(nodes: usize) -> (Arc<Mutex<ParamServer>>, Vec<InProcTransport>) {
        let ps = Arc::new(Mutex::new(ParamServer::new(ws(&[0.0, 0.0]), nodes)));
        let ts = (0..nodes).map(|j| InProcTransport::new(Arc::clone(&ps), j)).collect();
        (ps, ts)
    }

    #[test]
    fn inproc_fetch_is_shared_snapshot() {
        let (ps, mut ts) = inproc(2);
        let (a, va) = ts[0].fetch_global().unwrap();
        let (b, vb) = ts[1].fetch_global().unwrap();
        assert_eq!((va, vb), (0, 0));
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(ps.lock().unwrap().comm.fetches, 2);
        assert_eq!(ts[0].stats().fetches, 1);
        assert_eq!(ts[0].stats().wire_bytes, 0, "in-proc moves no wire bytes");
    }

    #[test]
    fn inproc_agwu_submit_applies_eq10() {
        let (ps, mut ts) = inproc(1);
        let (g, base) = ts[0].fetch_global().unwrap();
        let mut local = (*g).clone();
        local.tensors_mut()[0].data_mut()[0] = 2.0;
        let meta = SubmitMeta {
            mode: SubmitMode::Agwu,
            base,
            accuracy: 1.0,
            loss: 0.5,
            want_snapshot: true,
        };
        let ack = ts[0].submit(local, &meta).unwrap();
        assert_eq!(ack.version, 1);
        // γ=1 (single node), Q=1: W = 0 + (2−0) = 2.
        assert_eq!(ack.snapshot.unwrap().tensors()[0].data(), &[2.0, 0.0]);
        assert_eq!(ps.lock().unwrap().version(), 1);
    }

    #[test]
    fn inproc_sgwu_buffers_until_round_completes() {
        let (ps, mut ts) = inproc(2);
        let meta = |acc| SubmitMeta {
            mode: SubmitMode::Sgwu,
            base: 0,
            accuracy: acc,
            loss: 1.0,
            want_snapshot: false,
        };
        let a0 = ts[0].submit(ws(&[2.0, 0.0]), &meta(0.5)).unwrap();
        assert_eq!(a0.version, 0, "buffered part reports still-current version");
        let a1 = ts[1].submit(ws(&[0.0, 4.0]), &meta(0.5)).unwrap();
        assert_eq!(a1.version, 1, "completing part installs the round");
        let ps = ps.lock().unwrap();
        assert_eq!(ps.global().tensors()[0].data(), &[1.0, 2.0]);
        assert_eq!(ps.comm.submits, 2);
    }

    #[test]
    fn throttled_sleeps_and_reports_link_time() {
        let (_ps, mut ts) = inproc(1);
        let model = TransferModel::new(1e9, 0.02); // dominated by 20 ms latency
        let mut t = ThrottledTransport::new(ts.remove(0), model);
        let t0 = Instant::now();
        let (g, base) = t.fetch_global().unwrap();
        let _ = t
            .submit(
                (*g).clone(),
                &SubmitMeta {
                    mode: SubmitMode::Plain,
                    base,
                    accuracy: 1.0,
                    loss: 1.0,
                    want_snapshot: false,
                },
            )
            .unwrap();
        assert!(t0.elapsed().as_secs_f64() >= 0.04, "two modeled transfers ≥ 2×20 ms");
        let s = t.stats();
        assert!(s.fetch_wall_s >= 0.02 && s.submit_wall_s >= 0.02);
        assert_eq!((s.fetches, s.submits), (1, 1));
    }

    // TransferModel semantics (moved here with the model from the old
    // `outer::comm` module).

    #[test]
    fn transfer_time_components() {
        let m = TransferModel::new(1e6, 0.001);
        // 1 MB at 1 MB/s + 1 ms latency.
        assert!((m.transfer_time(1_000_000) - 1.001).abs() < 1e-9);
        assert!((m.transfer_time(0) - 0.001).abs() < 1e-12);
    }

    #[test]
    fn eq11_scaling() {
        let m = TransferModel::new(1e9, 0.0);
        let t1 = m.total_update_time(1000, 5, 10);
        let t2 = m.total_update_time(1000, 10, 10);
        let t3 = m.total_update_time(1000, 5, 20);
        assert!((t2 / t1 - 2.0).abs() < 1e-9, "linear in m");
        assert!((t3 / t1 - 2.0).abs() < 1e-9, "linear in K");
    }

    #[test]
    #[should_panic]
    fn zero_bandwidth_rejected() {
        TransferModel::new(0.0, 0.0);
    }
}
