//! Pipelined worker communication: overlap transport time with training.
//!
//! The serialized worker loop (`fetch → train → submit`, PR 6) keeps the
//! Eq. 11 communication term on the critical path: every cycle pays one
//! full fetch and one full submit of wall time, even though the transfers
//! have no data dependency on the epoch running *right now*. This module
//! moves all transport calls onto a dedicated **comm thread** and lets the
//! worker loop:
//!
//! * **prefetch** — the next `fetch_global` is issued while the current
//!   epoch is still training, and the resulting `Arc<WeightSet>` generation
//!   is swapped in at the epoch boundary ([`PipelinedTransport::take_snapshot`]);
//! * **push asynchronously** — `submit` runs on the comm thread against the
//!   sealed local delta of the finished epoch while the next epoch starts
//!   immediately ([`PipelinedTransport::submit_async`]).
//!
//! Consistency is governed by a bounded-[`Staleness`] knob: a snapshot may
//! be trained on only while it is at most `s` versions behind the newest
//! version this worker has seen acked by the server. When an ack overtakes
//! the prefetched snapshot by more than `s`, the snapshot is discarded and
//! re-fetched (the worker blocks — that residual wait is the `stall_wall_s`
//! a pipeline cannot hide). `s = 0` is not expressible here by design:
//! [`super::worker::drive_worker`] dispatches `Staleness(0)` to the
//! literal serialized loop, keeping the PR-6 path bit-identical (pinned by
//! test) — a zero-staleness pipeline would still reorder server-side fetch
//! accounting (`node_base`, hence γ in Eq. 9) even if it blocked on every
//! boundary.
//!
//! The comm thread holds the `&mut dyn Transport` exclusively, so every
//! existing backend — [`super::transport::InProcTransport`],
//! [`super::transport::TcpTransport`], throttled or not — composes
//! unchanged: commands are applied strictly in FIFO order, which preserves
//! the per-connection request ordering the wire protocol (and the SGWU
//! Eq. 8 barrier) relies on. Per cycle the queue is `…, fetch_{i+1},
//! submit_i, …`, so at most one submit is ever in flight and a snapshot
//! for epoch `i+1` reflects everything up to this worker's `submit_{i-1}`.

use std::collections::VecDeque;
use std::ops::Range;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::tensor::WeightSet;

use super::transport::{SubmitAck, SubmitMeta, Transport};

/// Bounded-staleness knob for the pipelined worker loop.
///
/// `Staleness(0)` degrades to the serialized fetch → train → submit loop
/// (bit-identical to the pre-pipeline behavior); `Staleness(s)` with
/// `s ≥ 1` permits training on a snapshot up to `s` versions behind the
/// newest server version this worker has seen acked, blocking only when
/// the bound would be violated.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Staleness(pub usize);

impl Staleness {
    /// The serialized (PR-6) mode: no comm thread, no prefetch.
    pub const SERIALIZED: Staleness = Staleness(0);

    /// Whether this bound enables the comm-thread pipeline.
    pub fn is_pipelined(self) -> bool {
        self.0 > 0
    }
}

/// One acknowledged submission, in ack order (the pipelined equivalent of
/// the serialized loop's per-iteration version bookkeeping).
#[derive(Debug, Clone, Copy)]
pub struct AckRecord {
    /// Server version this submission produced (or, for a buffered SGWU
    /// part, the version current when it was buffered).
    pub version: usize,
    /// Local loss / accuracy of the epoch behind the submission.
    pub loss: f64,
    pub accuracy: f64,
    /// When the ack reached the worker (cluster drivers convert to
    /// run-relative seconds).
    pub at: Instant,
}

enum Cmd {
    Fetch,
    Submit(WeightSet, SubmitMeta),
    Finish,
}

enum Reply {
    /// A fetch result plus any sample ranges the server re-allocated to
    /// this node (drained from the transport right after the fetch, so
    /// they survive even if the snapshot itself is later discarded as
    /// stale).
    Fetched(Result<(Arc<WeightSet>, usize)>, Vec<Range<usize>>),
    Acked(Result<SubmitAck>),
}

/// The transport-owning end of the pipeline. Runs on a dedicated thread and
/// applies queued commands strictly in FIFO order against the wrapped
/// [`Transport`] — ordering, and therefore every backend's protocol
/// assumptions, are exactly those of the serialized loop.
pub struct CommThread {
    cmd_rx: Receiver<Cmd>,
    reply_tx: Sender<Reply>,
}

/// How long the comm thread sits idle (no queued command) before sending a
/// keep-alive [`Transport::heartbeat`] — long local epochs must not let the
/// server's per-connection lease expire.
pub const HEARTBEAT_IDLE: Duration = Duration::from_millis(500);

impl CommThread {
    /// Drain commands until [`Cmd::Finish`] (or channel hangup, e.g. the
    /// worker bailed on an error) and then close the transport. Send
    /// failures on the reply channel are ignored: they only mean the worker
    /// already gave up, and the loop still finishes the transport politely.
    /// While the queue is idle (the trainer is mid-epoch) a heartbeat keeps
    /// the server lease alive; heartbeat errors are swallowed — a real
    /// failure resurfaces on the next fetch or submit.
    pub fn run(self, transport: &mut dyn Transport) -> Result<()> {
        loop {
            match self.cmd_rx.recv_timeout(HEARTBEAT_IDLE) {
                Ok(Cmd::Fetch) => {
                    let fetched = transport.fetch_global();
                    let gained = transport.take_reassigned();
                    let _ = self.reply_tx.send(Reply::Fetched(fetched, gained));
                }
                Ok(Cmd::Submit(local, meta)) => {
                    let _ = self.reply_tx.send(Reply::Acked(transport.submit(local, &meta)));
                }
                Ok(Cmd::Finish) => return transport.finish(),
                Err(RecvTimeoutError::Timeout) => {
                    let _ = transport.heartbeat();
                }
                Err(RecvTimeoutError::Disconnected) => return transport.finish(),
            }
        }
    }
}

/// Pipeline accounting extracted when the run ends (folded into
/// [`super::worker::WorkerRunSummary`] and `TransportStats`).
#[derive(Debug, Clone, Default)]
pub struct PipelineAccounting {
    /// Wall seconds the worker was blocked on the reply channel — the comm
    /// time the pipeline could *not* hide (snapshot waits, staleness
    /// refetch waits, the final ack drain).
    pub stall_s: f64,
    /// Snapshots discarded and re-fetched because an ack had overtaken
    /// them by more than the staleness bound.
    pub refetches: usize,
    /// Largest `last_acked − snapshot_version` gap actually trained on —
    /// the observable the staleness-bound proptest pins (`≤ s` always).
    pub max_staleness: usize,
    /// Peak queued + executing comm operations.
    pub max_inflight: usize,
    /// Acknowledged submissions in ack order.
    pub acks: Vec<AckRecord>,
}

/// The worker-facing end of the pipeline: non-blocking `prefetch` /
/// `submit_async` enqueue work for the [`CommThread`]; `take_snapshot`
/// blocks only for the double-buffer swap (and staleness refetches);
/// `finish` drains outstanding acks and shuts the comm thread down.
///
/// This is deliberately *not* an implementation of [`Transport`]: the whole
/// point is that its calls do not have blocking fetch/submit semantics.
pub struct PipelinedTransport {
    cmd_tx: Sender<Cmd>,
    reply_rx: Receiver<Reply>,
    staleness: usize,
    /// Queued or executing commands (fetch + submit), for queue-depth stats.
    inflight: usize,
    fetches_outstanding: usize,
    submits_outstanding: usize,
    /// (loss, accuracy) for each queued submit, FIFO — acks pair up in
    /// order because the comm thread preserves command order.
    pending_meta: VecDeque<(f64, f64)>,
    /// Newest server version seen in any ack — the staleness reference.
    last_acked: usize,
    /// Sample ranges the server re-allocated to this node (a dead peer's
    /// remaining IDPA batches), accumulated across fetch replies.
    reassigned: Vec<Range<usize>>,
    acct: PipelineAccounting,
}

/// Create a connected ([`PipelinedTransport`], [`CommThread`]) pair. The
/// caller spawns `CommThread::run` on a (scoped) thread with the real
/// transport and drives the worker side from the training loop.
pub fn pipeline(staleness: Staleness) -> (PipelinedTransport, CommThread) {
    assert!(
        staleness.is_pipelined(),
        "Staleness(0) is the serialized loop — it must not construct a pipeline"
    );
    let (cmd_tx, cmd_rx) = channel();
    let (reply_tx, reply_rx) = channel();
    (
        PipelinedTransport {
            cmd_tx,
            reply_rx,
            staleness: staleness.0,
            inflight: 0,
            fetches_outstanding: 0,
            submits_outstanding: 0,
            pending_meta: VecDeque::new(),
            last_acked: 0,
            reassigned: Vec::new(),
            acct: PipelineAccounting::default(),
        },
        CommThread { cmd_rx, reply_tx },
    )
}

impl PipelinedTransport {
    fn enqueue(&mut self, cmd: Cmd) -> Result<()> {
        self.inflight += 1;
        self.acct.max_inflight = self.acct.max_inflight.max(self.inflight);
        self.cmd_tx.send(cmd).map_err(|_| anyhow!("comm thread terminated"))
    }

    /// Issue the next `fetch_global` on the comm thread (non-blocking).
    pub fn prefetch(&mut self) -> Result<()> {
        self.fetches_outstanding += 1;
        self.enqueue(Cmd::Fetch)
    }

    /// Queue the sealed local delta for submission on the comm thread and
    /// return immediately — the next epoch starts while the push runs.
    pub fn submit_async(&mut self, local: WeightSet, meta: SubmitMeta) -> Result<()> {
        self.pending_meta.push_back((meta.loss, meta.accuracy));
        self.submits_outstanding += 1;
        self.enqueue(Cmd::Submit(local, meta))
    }

    /// Absorb one reply; returns the snapshot if it was a fetch reply.
    fn absorb(&mut self, reply: Reply) -> Result<Option<(Arc<WeightSet>, usize)>> {
        self.inflight -= 1;
        match reply {
            Reply::Fetched(r, gained) => {
                self.fetches_outstanding -= 1;
                self.reassigned.extend(gained);
                r.map(Some)
            }
            Reply::Acked(r) => {
                self.submits_outstanding -= 1;
                let ack = r?;
                let (loss, accuracy) = self
                    .pending_meta
                    .pop_front()
                    .expect("an ack implies a queued submit");
                self.last_acked = self.last_acked.max(ack.version);
                self.acct.acks.push(AckRecord {
                    version: ack.version,
                    loss,
                    accuracy,
                    at: Instant::now(),
                });
                Ok(None)
            }
        }
    }

    fn recv(&mut self) -> Result<Option<(Arc<WeightSet>, usize)>> {
        let reply = self
            .reply_rx
            .recv()
            .map_err(|_| anyhow!("comm thread terminated"))?;
        self.absorb(reply)
    }

    /// Absorb any acks (or stray fetch replies, discarded) that already
    /// arrived, without blocking — keeps `last_acked` fresh.
    fn drain_ready(&mut self) -> Result<()> {
        loop {
            match self.reply_rx.try_recv() {
                Ok(reply) => {
                    // A stray snapshot here can only be a refetch the bound
                    // made obsolete; drop it (the Arc is just a refcount).
                    let _ = self.absorb(reply)?;
                }
                Err(TryRecvError::Empty) => return Ok(()),
                Err(TryRecvError::Disconnected) => {
                    return Err(anyhow!("comm thread terminated"))
                }
            }
        }
    }

    /// Swap in the prefetched snapshot generation (double-buffer swap
    /// point). Blocks until a snapshot satisfying the staleness bound is
    /// available: if the prefetched one has fallen more than `s` versions
    /// behind the newest acked version, it is discarded and re-fetched.
    /// Issues the fetch itself if none is outstanding.
    pub fn take_snapshot(&mut self) -> Result<(Arc<WeightSet>, usize)> {
        if self.fetches_outstanding == 0 {
            self.prefetch()?;
        }
        let t0 = Instant::now();
        let out = loop {
            // Block for the snapshot (acks arriving meanwhile are absorbed).
            let (snapshot, version) = loop {
                if let Some(f) = self.recv()? {
                    break f;
                }
            };
            self.drain_ready()?;
            let behind = self.last_acked.saturating_sub(version);
            if behind <= self.staleness {
                self.acct.max_staleness = self.acct.max_staleness.max(behind);
                break (snapshot, version);
            }
            // Bound violated: the refetch is queued *after* whatever submit
            // raised `last_acked`, so it must return a version ≥ it.
            self.acct.refetches += 1;
            self.prefetch()?;
        };
        self.acct.stall_s += t0.elapsed().as_secs_f64();
        Ok(out)
    }

    /// Newest server version seen in any ack so far.
    pub fn last_acked(&self) -> usize {
        self.last_acked
    }

    /// Drain the sample ranges the server re-allocated to this node (a dead
    /// peer's remaining IDPA batches, piggybacked on fetch replies).
    pub fn take_reassigned(&mut self) -> Vec<Range<usize>> {
        std::mem::take(&mut self.reassigned)
    }

    /// Snapshots discarded for violating the staleness bound so far.
    pub fn refetches(&self) -> usize {
        self.acct.refetches
    }

    /// Largest staleness gap actually trained on so far.
    pub fn max_staleness(&self) -> usize {
        self.acct.max_staleness
    }

    /// Block until every queued submit is acked (stray prefetches are
    /// drained and discarded), then stop the comm thread, which closes the
    /// transport. Returns the pipeline's accounting.
    pub fn finish(mut self) -> Result<PipelineAccounting> {
        let t0 = Instant::now();
        while self.submits_outstanding > 0 || self.fetches_outstanding > 0 {
            let _ = self.recv()?;
        }
        self.acct.stall_s += t0.elapsed().as_secs_f64();
        self.cmd_tx
            .send(Cmd::Finish)
            .map_err(|_| anyhow!("comm thread terminated"))?;
        Ok(std::mem::take(&mut self.acct))
    }

    /// Like [`PipelinedTransport::finish`] but without waiting: used on the
    /// error path, where dropping the command channel makes the comm thread
    /// close the transport on its own.
    pub fn abandon(self) -> PipelineAccounting {
        self.acct
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::outer::transport::{SubmitMode, TransportStats};
    use crate::tensor::Tensor;

    fn ws(vals: &[f32]) -> WeightSet {
        WeightSet::new(vec![Tensor::from_vec(&[vals.len()], vals.to_vec())])
    }

    fn meta(base: usize) -> SubmitMeta {
        SubmitMeta {
            mode: SubmitMode::Agwu,
            base,
            accuracy: 0.5,
            loss: 1.0,
            want_snapshot: false,
        }
    }

    /// Scripted backend: every submit advances the version by `1 + jump`,
    /// emulating `jump` concurrent peer updates landing with ours.
    struct JumpTransport {
        version: usize,
        jump: usize,
        stats: TransportStats,
    }

    impl Transport for JumpTransport {
        fn fetch_global(&mut self) -> Result<(Arc<WeightSet>, usize)> {
            self.stats.fetches += 1;
            Ok((Arc::new(ws(&[self.version as f32])), self.version))
        }

        fn submit(&mut self, _local: WeightSet, _meta: &SubmitMeta) -> Result<SubmitAck> {
            self.version += 1 + self.jump;
            self.stats.submits += 1;
            Ok(SubmitAck { version: self.version, snapshot: None })
        }

        fn stats(&self) -> TransportStats {
            self.stats
        }
    }

    #[test]
    fn prefetch_submit_ack_round_trip() {
        let mut t = JumpTransport { version: 0, jump: 0, stats: TransportStats::default() };
        std::thread::scope(|scope| {
            let (mut pipe, comm) = pipeline(Staleness(1));
            let handle = scope.spawn(|| comm.run(&mut t));
            let (snap, v0) = pipe.take_snapshot().unwrap();
            assert_eq!(v0, 0);
            assert_eq!(snap.tensors()[0].data(), &[0.0]);
            pipe.prefetch().unwrap();
            pipe.submit_async(ws(&[1.0]), meta(v0)).unwrap();
            let (_, v1) = pipe.take_snapshot().unwrap();
            // FIFO: the prefetch ran before the submit, so it still sees v0.
            assert_eq!(v1, 0);
            let acct = pipe.finish().unwrap();
            handle.join().unwrap().unwrap();
            assert_eq!(acct.acks.len(), 1);
            assert_eq!(acct.acks[0].version, 1);
            assert!(acct.max_inflight >= 2, "fetch and submit were queued together");
        });
        assert_eq!((t.stats.fetches, t.stats.submits), (2, 1));
    }

    /// When an ack overtakes the prefetched snapshot by more than `s`, the
    /// snapshot is discarded and re-fetched — and the refetch, queued after
    /// the submit that raised `last_acked`, comes back fresh.
    #[test]
    fn staleness_violation_triggers_refetch() {
        let mut t = JumpTransport { version: 0, jump: 9, stats: TransportStats::default() };
        std::thread::scope(|scope| {
            let (mut pipe, comm) = pipeline(Staleness(1));
            let handle = scope.spawn(|| comm.run(&mut t));
            let (_, v0) = pipe.take_snapshot().unwrap();
            assert_eq!(v0, 0);
            pipe.prefetch().unwrap(); // still sees v0 (queued before the submit)
            pipe.submit_async(ws(&[1.0]), meta(v0)).unwrap(); // acks v10
            // Let the comm thread process both so the ack is visible when
            // the stale snapshot is inspected.
            std::thread::sleep(std::time::Duration::from_millis(50));
            let (_, v) = pipe.take_snapshot().unwrap();
            assert_eq!(v, 10, "refetch must return the post-submit version");
            assert_eq!(pipe.refetches(), 1);
            assert_eq!(pipe.last_acked(), 10);
            assert_eq!(pipe.max_staleness(), 0, "the stale snapshot was never returned");
            pipe.finish().unwrap();
            handle.join().unwrap().unwrap();
        });
    }

    #[test]
    fn within_bound_snapshot_is_accepted_and_recorded() {
        let mut t = JumpTransport { version: 0, jump: 1, stats: TransportStats::default() };
        std::thread::scope(|scope| {
            let (mut pipe, comm) = pipeline(Staleness(2));
            let handle = scope.spawn(|| comm.run(&mut t));
            let (_, v0) = pipe.take_snapshot().unwrap();
            pipe.prefetch().unwrap();
            pipe.submit_async(ws(&[1.0]), meta(v0)).unwrap(); // acks v2
            std::thread::sleep(std::time::Duration::from_millis(50));
            let (_, v) = pipe.take_snapshot().unwrap();
            assert_eq!(v, 0, "2 behind is within Staleness(2)");
            assert_eq!(pipe.refetches(), 0);
            assert_eq!(pipe.max_staleness(), 2);
            pipe.finish().unwrap();
            handle.join().unwrap().unwrap();
        });
    }

    #[test]
    fn finish_waits_for_outstanding_acks() {
        struct SlowSubmit(TransportStats);
        impl Transport for SlowSubmit {
            fn fetch_global(&mut self) -> Result<(Arc<WeightSet>, usize)> {
                Ok((Arc::new(ws(&[0.0])), 0))
            }
            fn submit(&mut self, _l: WeightSet, _m: &SubmitMeta) -> Result<SubmitAck> {
                std::thread::sleep(std::time::Duration::from_millis(60));
                Ok(SubmitAck { version: 1, snapshot: None })
            }
            fn stats(&self) -> TransportStats {
                self.0
            }
        }
        let mut t = SlowSubmit(TransportStats::default());
        std::thread::scope(|scope| {
            let (mut pipe, comm) = pipeline(Staleness(1));
            let handle = scope.spawn(|| comm.run(&mut t));
            pipe.submit_async(ws(&[1.0]), meta(0)).unwrap();
            let t0 = Instant::now();
            let acct = pipe.finish().unwrap();
            assert!(t0.elapsed().as_secs_f64() >= 0.05, "finish returned before the ack");
            assert_eq!(acct.acks.len(), 1);
            assert!(acct.stall_s >= 0.05, "the final drain is a stall");
            handle.join().unwrap().unwrap();
        });
    }

    #[test]
    #[should_panic(expected = "serialized")]
    fn zero_staleness_pipeline_rejected() {
        let _ = pipeline(Staleness(0));
    }
}
