//! In-process distributed cluster (§3.2.2): one OS thread per computing
//! node plus the parameter server, with real concurrency semantics —
//! SGWU rounds synchronize at a barrier (and pay the Eq. 8 wait), AGWU
//! workers free-run and race on the server exactly as Fig. 5 describes.
//!
//! Every node ↔ server exchange goes through an
//! [`InProcTransport`](super::transport::InProcTransport) — the same
//! [`Transport`] calls a remote worker makes against the standalone
//! [`super::server`], so the in-process cluster and a real multi-process
//! deployment share one code path (and one accounting scheme).

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::config::UpdateStrategy;
use crate::tensor::WeightSet;

use super::param_server::{CommStats, ParamServer};
use super::transport::{InProcTransport, SubmitMeta, SubmitMode, Transport, TransportStats};
use super::worker::LocalTrainer;

/// One global-version record in the training log.
#[derive(Debug, Clone)]
pub struct VersionRecord {
    pub version: usize,
    /// Node whose submission produced this version (SGWU: usize::MAX = all).
    pub node: usize,
    /// Local training loss / accuracy behind the update.
    pub local_loss: f64,
    pub local_accuracy: f64,
    /// Wall-clock seconds since training start.
    pub at_s: f64,
    /// Held-out (loss, accuracy) of the *global* set at this version, when
    /// an eval hook was supplied (possibly subsampled).
    pub eval: Option<(f64, f64)>,
}

/// Full report of one cluster run.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    pub strategy: UpdateStrategy,
    pub versions: Vec<VersionRecord>,
    pub comm: CommStats,
    /// Eq. 8 synchronization wait (SGWU; 0 for AGWU by construction).
    pub sync_wait_s: f64,
    pub wall_s: f64,
    /// Total busy seconds per node (for the balance index).
    pub node_busy_s: Vec<f64>,
    pub final_weights: WeightSet,
}

impl ClusterReport {
    pub fn balance_index(&self) -> f64 {
        crate::util::stats::balance_index(&self.node_busy_s)
    }
}

/// Per-node IDPA allocation schedule: `schedule[a][j]` = dataset index range
/// node j receives before its (a+1)-th local iteration.
pub type AllocationSchedule = Vec<Vec<std::ops::Range<usize>>>;

/// Held-out evaluation hook: global weight set → (loss, accuracy).
pub type EvalHook<'a> = &'a (dyn Fn(&WeightSet) -> (f64, f64) + Sync);

/// Split the IDPA allocation schedule (rows = allocation batches, columns =
/// nodes) into per-node columns — the shape a single node's driver consumes,
/// whether it runs as an in-process thread or a remote worker process.
pub fn schedule_columns(
    schedule: &AllocationSchedule,
    m: usize,
) -> Vec<Vec<std::ops::Range<usize>>> {
    (0..m)
        .map(|j| schedule.iter().map(|row| row[j].clone()).collect())
        .collect()
}

/// Collect each transport's measured accounting into the unwrapped server's
/// [`CommStats`], then move the final global set out — the shared epilogue
/// of both in-process runners.
fn unwrap_server(
    ps: Arc<Mutex<ParamServer>>,
    tstats: &[TransportStats],
) -> (CommStats, WeightSet) {
    let mut ps = Arc::try_unwrap(ps)
        .expect("all transports dropped")
        .into_inner()
        .unwrap();
    for s in tstats {
        ps.comm.absorb_transport(s);
    }
    (ps.comm.clone(), ps.into_global())
}

/// Run `iterations` rounds with the **SGWU** strategy (Fig. 4).
pub fn run_sgwu(
    init: WeightSet,
    mut workers: Vec<Box<dyn LocalTrainer>>,
    schedule: &AllocationSchedule,
    iterations: usize,
    eval: Option<EvalHook<'_>>,
) -> ClusterReport {
    let m = workers.len();
    assert!(m > 0);
    let ps = Arc::new(Mutex::new(ParamServer::new(init, m)));
    let mut transports: Vec<InProcTransport> =
        (0..m).map(|j| InProcTransport::new(Arc::clone(&ps), j)).collect();
    let mut sync_wait = 0.0f64;
    let mut node_busy = vec![0.0f64; m];
    let mut versions = Vec::new();
    let t0 = Instant::now();

    for iter in 0..iterations {
        // IDPA incremental allocation (batch `iter` of the schedule).
        if iter < schedule.len() {
            for (j, w) in workers.iter_mut().enumerate() {
                w.add_samples(schedule[iter][j].clone());
            }
        }
        // Every node fetches the same global version through its transport
        // (m logical transfers; in-process they share one Arc snapshot).
        let mut globals = Vec::with_capacity(m);
        let mut base = 0usize;
        for t in transports.iter_mut() {
            let (g, v) = t.fetch_global().expect("in-process fetch cannot fail");
            base = v;
            globals.push(g);
        }
        // Parallel local epochs.
        let outcomes: Vec<(super::worker::EpochOutcome, f64)> = std::thread::scope(|scope| {
            let handles: Vec<_> = workers
                .iter_mut()
                .zip(globals)
                .map(|(w, g)| {
                    scope.spawn(move || {
                        let t = Instant::now();
                        let out = w.train_epoch(g);
                        (out, t.elapsed().as_secs_f64())
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // Eq. 8: the round barrier makes every node wait for the slowest.
        let t_max = outcomes.iter().map(|(_, t)| *t).fold(0.0f64, f64::max);
        for (j, (_, t)) in outcomes.iter().enumerate() {
            sync_wait += t_max - t;
            node_busy[j] += t;
        }
        let mean_loss =
            outcomes.iter().map(|(o, _)| o.loss).sum::<f64>() / m as f64;
        let mean_acc =
            outcomes.iter().map(|(o, _)| o.accuracy).sum::<f64>() / m as f64;
        // Eq. 7 update: each node's weights move out of its EpochOutcome
        // through its transport in node order — the server buffers the
        // parts and installs the round on the last one, numerically
        // identical to the one-shot slice update (no per-round clones).
        let mut version = 0usize;
        for (t, (o, _)) in transports.iter_mut().zip(outcomes) {
            let meta = SubmitMeta {
                mode: SubmitMode::Sgwu,
                base,
                accuracy: o.accuracy,
                loss: o.loss,
                want_snapshot: false,
            };
            let ack = t.submit(o.weights, &meta).expect("in-process submit cannot fail");
            version = ack.version;
        }
        versions.push(VersionRecord {
            version,
            node: usize::MAX,
            local_loss: mean_loss,
            local_accuracy: mean_acc,
            at_s: t0.elapsed().as_secs_f64(),
            eval: eval.map(|f| f(ps.lock().unwrap().global())),
        });
    }

    let tstats: Vec<TransportStats> = transports.iter().map(|t| t.stats()).collect();
    drop(transports);
    let wall_s = t0.elapsed().as_secs_f64();
    let (comm, final_weights) = unwrap_server(ps, &tstats);
    ClusterReport {
        strategy: UpdateStrategy::Sgwu,
        versions,
        comm,
        sync_wait_s: sync_wait,
        wall_s,
        node_busy_s: node_busy,
        final_weights,
    }
}

/// Asynchronous update rule selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AsyncMode {
    /// The paper's AGWU: Eq. 10 with γ attenuation + accuracy weighting.
    Agwu,
    /// Downpour-style baseline: plain 1/m increment, no γ, no Q.
    Plain,
}

/// Run `iterations` local iterations per node with the **AGWU** strategy
/// (Fig. 5 / Algorithm 3.2): every worker free-runs fetch → train → submit;
/// the server applies Eq. 10 immediately on each submission.
pub fn run_agwu(
    init: WeightSet,
    workers: Vec<Box<dyn LocalTrainer>>,
    schedule: &AllocationSchedule,
    iterations: usize,
    eval: Option<EvalHook<'_>>,
) -> ClusterReport {
    run_async(init, workers, schedule, iterations, eval, AsyncMode::Agwu)
}

/// Asynchronous run with an explicit update rule (AGWU or the plain
/// Downpour-style baseline).
pub fn run_async(
    init: WeightSet,
    workers: Vec<Box<dyn LocalTrainer>>,
    schedule: &AllocationSchedule,
    iterations: usize,
    eval: Option<EvalHook<'_>>,
    mode: AsyncMode,
) -> ClusterReport {
    let m = workers.len();
    assert!(m > 0);
    let ps = Arc::new(Mutex::new(ParamServer::new(init, m)));
    let versions: Arc<Mutex<Vec<VersionRecord>>> = Arc::new(Mutex::new(Vec::new()));
    let t0 = Instant::now();

    let node_schedules = schedule_columns(schedule, m);
    let submit_mode = match mode {
        AsyncMode::Agwu => SubmitMode::Agwu,
        AsyncMode::Plain => SubmitMode::Plain,
    };

    let results: Vec<(f64, TransportStats)> = std::thread::scope(|scope| {
        let handles: Vec<_> = workers
            .into_iter()
            .zip(node_schedules)
            .enumerate()
            .map(|(j, (mut w, sched))| {
                let mut transport = InProcTransport::new(Arc::clone(&ps), j);
                let versions = Arc::clone(&versions);
                scope.spawn(move || {
                    let mut busy = 0.0f64;
                    for iter in 0..iterations {
                        if iter < sched.len() {
                            w.add_samples(sched[iter].clone());
                        }
                        // Fetch the freshest global version.
                        let (global, base) = transport
                            .fetch_global()
                            .expect("in-process fetch cannot fail");
                        // Local epoch — no locks held while computing.
                        let t = Instant::now();
                        let out = w.train_epoch(global);
                        busy += t.elapsed().as_secs_f64();
                        // Submit immediately (Alg. 3.2): no waiting for
                        // other nodes. The snapshot rides the ack — taken
                        // under the same server lock as the update, as a
                        // refcount bump, so eval sees exactly the version
                        // this submission produced.
                        let meta = SubmitMeta {
                            mode: submit_mode,
                            base,
                            accuracy: out.accuracy,
                            loss: out.loss,
                            want_snapshot: eval.is_some(),
                        };
                        let (local_loss, local_accuracy) = (out.loss, out.accuracy);
                        let ack = transport
                            .submit(out.weights, &meta)
                            .expect("in-process submit cannot fail");
                        // Eval outside the lock so stragglers don't serialize.
                        let eval_point = match (eval, ack.snapshot) {
                            (Some(f), Some(g)) => Some(f(&g)),
                            _ => None,
                        };
                        versions.lock().unwrap().push(VersionRecord {
                            version: ack.version,
                            node: j,
                            local_loss,
                            local_accuracy,
                            at_s: t0.elapsed().as_secs_f64(),
                            eval: eval_point,
                        });
                    }
                    (busy, transport.stats())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let (node_busy, tstats): (Vec<f64>, Vec<TransportStats>) = results.into_iter().unzip();
    let wall_s = t0.elapsed().as_secs_f64();
    let (comm, final_weights) = unwrap_server(ps, &tstats);
    let mut versions = Arc::try_unwrap(versions)
        .expect("threads joined")
        .into_inner()
        .unwrap();
    versions.sort_by_key(|v| v.version);

    ClusterReport {
        strategy: UpdateStrategy::Agwu,
        versions,
        comm,
        sync_wait_s: 0.0, // no synchronization barrier exists in AGWU
        wall_s,
        node_busy_s: node_busy,
        final_weights,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetworkConfig;
    use crate::data::Dataset;
    use crate::nn::Network;
    use crate::outer::worker::NativeTrainer;

    fn setup(m: usize, per_node: usize) -> (NetworkConfig, Arc<Dataset>, AllocationSchedule) {
        let cfg = NetworkConfig::quickstart();
        let ds = Arc::new(Dataset::synthetic(&cfg, m * per_node, 0.2, 31));
        // One-shot allocation (UDPA-like) as a single schedule batch.
        let schedule = vec![(0..m).map(|j| j * per_node..(j + 1) * per_node).collect()];
        (cfg, ds, schedule)
    }

    fn workers(
        cfg: &NetworkConfig,
        ds: &Arc<Dataset>,
        m: usize,
        lr: f32,
    ) -> Vec<Box<dyn LocalTrainer>> {
        (0..m)
            .map(|_| {
                Box::new(NativeTrainer::new(cfg, Arc::clone(ds), lr)) as Box<dyn LocalTrainer>
            })
            .collect()
    }

    #[test]
    fn sgwu_runs_and_accounts_comm() {
        let (cfg, ds, schedule) = setup(3, 16);
        let init = Network::init(&cfg, 1).weights;
        let report = run_sgwu(init, workers(&cfg, &ds, 3, 0.2), &schedule, 4, None);
        assert_eq!(report.versions.len(), 4);
        // Eq. 11: 2·m·K transfers.
        assert_eq!(report.comm.fetches, 3 * 4);
        assert_eq!(report.comm.submits, 3 * 4);
        assert!(report.sync_wait_s >= 0.0);
        assert_eq!(report.node_busy_s.len(), 3);
    }

    #[test]
    fn agwu_runs_all_iterations_without_sync_wait() {
        let (cfg, ds, schedule) = setup(3, 16);
        let init = Network::init(&cfg, 2).weights;
        let report = run_agwu(init, workers(&cfg, &ds, 3, 0.2), &schedule, 4, None);
        // m·K versions, strictly increasing.
        assert_eq!(report.versions.len(), 12);
        for (i, v) in report.versions.iter().enumerate() {
            assert_eq!(v.version, i + 1);
        }
        assert_eq!(report.sync_wait_s, 0.0);
        assert_eq!(report.comm.fetches, 12);
        assert_eq!(report.comm.submits, 12);
    }

    #[test]
    fn sgwu_single_node_equals_plain_sgd() {
        // With m=1 and accuracy weighting over one node, SGWU must reproduce
        // exactly the node's local SGD trajectory.
        let cfg = NetworkConfig::quickstart();
        let ds = Arc::new(Dataset::synthetic(&cfg, 16, 0.2, 33));
        let schedule: AllocationSchedule = vec![vec![0..16]];
        let init = Network::init(&cfg, 5).weights;

        let report = run_sgwu(init.clone(), workers(&cfg, &ds, 1, 0.2), &schedule, 3, None);
        // Reference: same worker run standalone.
        let mut w = NativeTrainer::new(&cfg, Arc::clone(&ds), 0.2);
        w.add_samples(0..16);
        let mut cur = init;
        for _ in 0..3 {
            cur = w.train_epoch(Arc::new(cur)).weights;
        }
        assert!(
            report.final_weights.max_abs_diff(&cur) < 1e-6,
            "diff {}",
            report.final_weights.max_abs_diff(&cur)
        );
    }

    #[test]
    fn both_strategies_learn() {
        let (cfg, ds, schedule) = setup(2, 32);
        let init = Network::init(&cfg, 7).weights;
        for strat in ["sgwu", "agwu"] {
            let report = match strat {
                "sgwu" => run_sgwu(init.clone(), workers(&cfg, &ds, 2, 0.3), &schedule, 6, None),
                _ => run_agwu(init.clone(), workers(&cfg, &ds, 2, 0.3), &schedule, 6, None),
            };
            let first = report.versions.first().unwrap().local_loss;
            let last = report.versions.last().unwrap().local_loss;
            assert!(
                last < first,
                "{strat} did not learn: first={first} last={last}"
            );
        }
    }

    /// The in-process transports report measured accounting into the
    /// report's CommStats: no wire bytes (Arc bumps), but real fetch/submit
    /// handling time, and the final weights move out of the server.
    #[test]
    fn inproc_transport_accounting_in_report() {
        let (cfg, ds, schedule) = setup(2, 16);
        let init = Network::init(&cfg, 11).weights;
        let report = run_agwu(init, workers(&cfg, &ds, 2, 0.2), &schedule, 2, None);
        assert_eq!(report.comm.wire_bytes, 0, "in-process runs move no wire bytes");
        assert!(report.comm.comm_wall_s() >= 0.0);
        assert_eq!(report.comm.fetches, 4);
        assert_eq!(report.versions.len(), 4);
        assert_eq!(
            report.final_weights.param_count(),
            Network::init(&cfg, 11).weights.param_count()
        );
    }

    #[test]
    fn schedule_columns_transposes() {
        let schedule: AllocationSchedule = vec![vec![0..2, 2..4], vec![4..6, 6..8]];
        let cols = schedule_columns(&schedule, 2);
        assert_eq!(cols, vec![vec![0..2, 4..6], vec![2..4, 6..8]]);
    }

    #[test]
    fn agwu_with_straggler_still_progresses() {
        let cfg = NetworkConfig::quickstart();
        let ds = Arc::new(Dataset::synthetic(&cfg, 48, 0.2, 35));
        let schedule: AllocationSchedule = vec![vec![0..16, 16..32, 32..48]];
        let init = Network::init(&cfg, 9).weights;
        let mut ws: Vec<Box<dyn LocalTrainer>> = Vec::new();
        ws.push(Box::new(NativeTrainer::new(&cfg, Arc::clone(&ds), 0.2)));
        ws.push(Box::new(NativeTrainer::new(&cfg, Arc::clone(&ds), 0.2)));
        ws.push(Box::new(
            NativeTrainer::new(&cfg, Arc::clone(&ds), 0.2).with_slowdown(3.0),
        ));
        let report = run_agwu(init, ws, &schedule, 3, None);
        assert_eq!(report.versions.len(), 9);
        // The straggler's updates arrive late (higher at_s) but all arrive.
        let by_node3: Vec<_> = report.versions.iter().filter(|v| v.node == 2).collect();
        assert_eq!(by_node3.len(), 3);
    }
}
