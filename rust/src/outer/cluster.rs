//! In-process distributed cluster (§3.2.2): one OS thread per computing
//! node plus the parameter server, with real concurrency semantics —
//! SGWU rounds synchronize at a barrier (and pay the Eq. 8 wait), AGWU
//! workers free-run and race on the server exactly as Fig. 5 describes.
//!
//! Every node ↔ server exchange goes through an
//! [`InProcTransport`](super::transport::InProcTransport) — the same
//! [`Transport`] calls a remote worker makes against the standalone
//! [`super::server`], so the in-process cluster and a real multi-process
//! deployment share one code path (and one accounting scheme).

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::config::UpdateStrategy;
use crate::tensor::WeightSet;

use super::fault::FaultStats;
use super::param_server::{CommStats, ParamServer};
use super::pipeline::Staleness;
use super::transport::{InProcTransport, SubmitMeta, SubmitMode, Transport, TransportStats};
use super::worker::{drive_worker, LocalTrainer};

/// One global-version record in the training log.
#[derive(Debug, Clone)]
pub struct VersionRecord {
    pub version: usize,
    /// Node whose submission produced this version (SGWU: usize::MAX = all).
    pub node: usize,
    /// Local training loss / accuracy behind the update.
    pub local_loss: f64,
    pub local_accuracy: f64,
    /// Wall-clock seconds since training start.
    pub at_s: f64,
    /// Held-out (loss, accuracy) of the *global* set at this version, when
    /// an eval hook was supplied (possibly subsampled).
    pub eval: Option<(f64, f64)>,
}

/// Full report of one cluster run.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    pub strategy: UpdateStrategy,
    pub versions: Vec<VersionRecord>,
    pub comm: CommStats,
    /// Eq. 8 synchronization wait (SGWU; 0 for AGWU by construction).
    pub sync_wait_s: f64,
    pub wall_s: f64,
    /// Total busy seconds per node (for the balance index).
    pub node_busy_s: Vec<f64>,
    /// Per-node seconds blocked on communication or the SGWU barrier —
    /// comm time on that node's critical path. A pipelined driver only
    /// counts the residual waits its prefetch/async-push could not hide.
    pub node_stall_s: Vec<f64>,
    /// Per-node comm seconds hidden behind local compute by the pipelined
    /// driver (0 everywhere for serialized runs).
    pub node_overlap_s: Vec<f64>,
    /// Fault-recovery accounting (retries, reconnects, re-allocated IDPA
    /// batches, checkpoints, expired leases). All zero for in-process runs
    /// and for healthy multi-process runs.
    pub fault: FaultStats,
    pub final_weights: WeightSet,
}

impl ClusterReport {
    pub fn balance_index(&self) -> f64 {
        crate::util::stats::balance_index(&self.node_busy_s)
    }
}

/// Per-node IDPA allocation schedule: `schedule[a][j]` = dataset index range
/// node j receives before its (a+1)-th local iteration.
pub type AllocationSchedule = Vec<Vec<std::ops::Range<usize>>>;

/// Held-out evaluation hook: global weight set → (loss, accuracy).
pub type EvalHook<'a> = &'a (dyn Fn(&WeightSet) -> (f64, f64) + Sync);

/// Split the IDPA allocation schedule (rows = allocation batches, columns =
/// nodes) into per-node columns — the shape a single node's driver consumes,
/// whether it runs as an in-process thread or a remote worker process.
pub fn schedule_columns(
    schedule: &AllocationSchedule,
    m: usize,
) -> Vec<Vec<std::ops::Range<usize>>> {
    (0..m)
        .map(|j| schedule.iter().map(|row| row[j].clone()).collect())
        .collect()
}

/// Collect each transport's measured accounting into the unwrapped server's
/// [`CommStats`], then move the final global set out — the shared epilogue
/// of both in-process runners.
fn unwrap_server(
    ps: Arc<Mutex<ParamServer>>,
    tstats: &[TransportStats],
) -> (CommStats, WeightSet) {
    let mut ps = Arc::try_unwrap(ps)
        .expect("all transports dropped")
        .into_inner()
        .unwrap();
    for s in tstats {
        ps.comm.absorb_transport(s);
    }
    (ps.comm.clone(), ps.into_global())
}

/// Run `iterations` rounds with the **SGWU** strategy (Fig. 4).
pub fn run_sgwu(
    init: WeightSet,
    mut workers: Vec<Box<dyn LocalTrainer>>,
    schedule: &AllocationSchedule,
    iterations: usize,
    eval: Option<EvalHook<'_>>,
) -> ClusterReport {
    let m = workers.len();
    assert!(m > 0);
    let ps = Arc::new(Mutex::new(ParamServer::new(init, m)));
    let mut transports: Vec<InProcTransport> =
        (0..m).map(|j| InProcTransport::new(Arc::clone(&ps), j)).collect();
    let mut sync_wait = 0.0f64;
    let mut node_busy = vec![0.0f64; m];
    let mut node_stall = vec![0.0f64; m];
    let mut versions = Vec::new();
    let t0 = Instant::now();

    for iter in 0..iterations {
        // IDPA incremental allocation (batch `iter` of the schedule).
        if iter < schedule.len() {
            for (j, w) in workers.iter_mut().enumerate() {
                w.add_samples(schedule[iter][j].clone());
            }
        }
        // Every node fetches the same global version through its transport
        // (m logical transfers; in-process they share one Arc snapshot).
        let mut globals = Vec::with_capacity(m);
        let mut base = 0usize;
        for t in transports.iter_mut() {
            let (g, v) = t.fetch_global().expect("in-process fetch cannot fail");
            base = v;
            globals.push(g);
        }
        // Parallel local epochs.
        let outcomes: Vec<(super::worker::EpochOutcome, f64)> = std::thread::scope(|scope| {
            let handles: Vec<_> = workers
                .iter_mut()
                .zip(globals)
                .map(|(w, g)| {
                    scope.spawn(move || {
                        let t = Instant::now();
                        let out = w.train_epoch(g);
                        (out, t.elapsed().as_secs_f64())
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // Eq. 8: the round barrier makes every node wait for the slowest.
        let t_max = outcomes.iter().map(|(_, t)| *t).fold(0.0f64, f64::max);
        for (j, (_, t)) in outcomes.iter().enumerate() {
            sync_wait += t_max - t;
            node_stall[j] += t_max - t;
            node_busy[j] += t;
        }
        let mean_loss =
            outcomes.iter().map(|(o, _)| o.loss).sum::<f64>() / m as f64;
        let mean_acc =
            outcomes.iter().map(|(o, _)| o.accuracy).sum::<f64>() / m as f64;
        // Eq. 7 update: each node's weights move out of its EpochOutcome
        // through its transport in node order — the server buffers the
        // parts and installs the round on the last one, numerically
        // identical to the one-shot slice update (no per-round clones).
        let mut version = 0usize;
        for (t, (o, _)) in transports.iter_mut().zip(outcomes) {
            let meta = SubmitMeta {
                mode: SubmitMode::Sgwu,
                base,
                accuracy: o.accuracy,
                loss: o.loss,
                want_snapshot: false,
            };
            let ack = t.submit(o.weights, &meta).expect("in-process submit cannot fail");
            version = ack.version;
        }
        versions.push(VersionRecord {
            version,
            node: usize::MAX,
            local_loss: mean_loss,
            local_accuracy: mean_acc,
            at_s: t0.elapsed().as_secs_f64(),
            eval: eval.map(|f| f(ps.lock().unwrap().global())),
        });
    }

    let tstats: Vec<TransportStats> = transports.iter().map(|t| t.stats()).collect();
    drop(transports);
    // Serialized round structure: the barrier wait plus every comm wall
    // second sits on the node's critical path.
    for (j, s) in tstats.iter().enumerate() {
        node_stall[j] += s.fetch_wall_s + s.submit_wall_s;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let (comm, final_weights) = unwrap_server(ps, &tstats);
    ClusterReport {
        strategy: UpdateStrategy::Sgwu,
        versions,
        comm,
        sync_wait_s: sync_wait,
        wall_s,
        node_busy_s: node_busy,
        node_stall_s: node_stall,
        node_overlap_s: vec![0.0; m],
        fault: FaultStats::default(),
        final_weights,
    }
}

/// Asynchronous update rule selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AsyncMode {
    /// The paper's AGWU: Eq. 10 with γ attenuation + accuracy weighting.
    Agwu,
    /// Downpour-style baseline: plain 1/m increment, no γ, no Q.
    Plain,
}

/// Run `iterations` local iterations per node with the **AGWU** strategy
/// (Fig. 5 / Algorithm 3.2): every worker free-runs fetch → train → submit;
/// the server applies Eq. 10 immediately on each submission.
pub fn run_agwu(
    init: WeightSet,
    workers: Vec<Box<dyn LocalTrainer>>,
    schedule: &AllocationSchedule,
    iterations: usize,
    eval: Option<EvalHook<'_>>,
) -> ClusterReport {
    run_async(init, workers, schedule, iterations, eval, AsyncMode::Agwu)
}

/// Asynchronous run with an explicit update rule (AGWU or the plain
/// Downpour-style baseline), serialized per-node loops (`Staleness(0)`).
pub fn run_async(
    init: WeightSet,
    workers: Vec<Box<dyn LocalTrainer>>,
    schedule: &AllocationSchedule,
    iterations: usize,
    eval: Option<EvalHook<'_>>,
    mode: AsyncMode,
) -> ClusterReport {
    run_async_pipelined(init, workers, schedule, iterations, eval, mode, Staleness(0))
}

/// Asynchronous run with an explicit staleness knob. `Staleness(0)` runs
/// each node's literal serialized fetch → train → submit loop (identical to
/// [`run_async`]); `Staleness(s ≥ 1)` drives every node through the
/// pipelined [`drive_worker`], overlapping each node's fetch/submit with
/// its local epochs under the bounded-staleness guarantee.
pub fn run_async_pipelined(
    init: WeightSet,
    workers: Vec<Box<dyn LocalTrainer>>,
    schedule: &AllocationSchedule,
    iterations: usize,
    eval: Option<EvalHook<'_>>,
    mode: AsyncMode,
    staleness: Staleness,
) -> ClusterReport {
    if staleness.is_pipelined() {
        return run_async_drivers(init, workers, schedule, iterations, eval, mode, staleness);
    }
    let m = workers.len();
    assert!(m > 0);
    let ps = Arc::new(Mutex::new(ParamServer::new(init, m)));
    let versions: Arc<Mutex<Vec<VersionRecord>>> = Arc::new(Mutex::new(Vec::new()));
    let t0 = Instant::now();

    let node_schedules = schedule_columns(schedule, m);
    let submit_mode = match mode {
        AsyncMode::Agwu => SubmitMode::Agwu,
        AsyncMode::Plain => SubmitMode::Plain,
    };

    let results: Vec<(f64, TransportStats)> = std::thread::scope(|scope| {
        let handles: Vec<_> = workers
            .into_iter()
            .zip(node_schedules)
            .enumerate()
            .map(|(j, (mut w, sched))| {
                let mut transport = InProcTransport::new(Arc::clone(&ps), j);
                let versions = Arc::clone(&versions);
                scope.spawn(move || {
                    let mut busy = 0.0f64;
                    for iter in 0..iterations {
                        if iter < sched.len() {
                            w.add_samples(sched[iter].clone());
                        }
                        // Fetch the freshest global version.
                        let (global, base) = transport
                            .fetch_global()
                            .expect("in-process fetch cannot fail");
                        // Local epoch — no locks held while computing.
                        let t = Instant::now();
                        let out = w.train_epoch(global);
                        busy += t.elapsed().as_secs_f64();
                        // Submit immediately (Alg. 3.2): no waiting for
                        // other nodes. The snapshot rides the ack — taken
                        // under the same server lock as the update, as a
                        // refcount bump, so eval sees exactly the version
                        // this submission produced.
                        let meta = SubmitMeta {
                            mode: submit_mode,
                            base,
                            accuracy: out.accuracy,
                            loss: out.loss,
                            want_snapshot: eval.is_some(),
                        };
                        let (local_loss, local_accuracy) = (out.loss, out.accuracy);
                        let ack = transport
                            .submit(out.weights, &meta)
                            .expect("in-process submit cannot fail");
                        // Eval outside the lock so stragglers don't serialize.
                        let eval_point = match (eval, ack.snapshot) {
                            (Some(f), Some(g)) => Some(f(&g)),
                            _ => None,
                        };
                        versions.lock().unwrap().push(VersionRecord {
                            version: ack.version,
                            node: j,
                            local_loss,
                            local_accuracy,
                            at_s: t0.elapsed().as_secs_f64(),
                            eval: eval_point,
                        });
                    }
                    (busy, transport.stats())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let (node_busy, tstats): (Vec<f64>, Vec<TransportStats>) = results.into_iter().unzip();
    let wall_s = t0.elapsed().as_secs_f64();
    // Serialized loops: every comm wall second sits on the critical path.
    let node_stall: Vec<f64> =
        tstats.iter().map(|s| s.fetch_wall_s + s.submit_wall_s).collect();
    let (comm, final_weights) = unwrap_server(ps, &tstats);
    let mut versions = Arc::try_unwrap(versions)
        .expect("threads joined")
        .into_inner()
        .unwrap();
    versions.sort_by_key(|v| v.version);

    ClusterReport {
        strategy: UpdateStrategy::Agwu,
        versions,
        comm,
        sync_wait_s: 0.0, // no synchronization barrier exists in AGWU
        wall_s,
        node_busy_s: node_busy,
        node_stall_s: node_stall,
        node_overlap_s: vec![0.0; m],
        fault: FaultStats::default(),
        final_weights,
    }
}

/// The pipelined in-process runner: one [`drive_worker`] per node over an
/// `InProcTransport`, each with its own comm thread and double buffer. The
/// per-version log is reconstructed from the workers' ack logs (acks carry
/// the server-assigned version, so the merged order is exact).
fn run_async_drivers(
    init: WeightSet,
    workers: Vec<Box<dyn LocalTrainer>>,
    schedule: &AllocationSchedule,
    iterations: usize,
    eval: Option<EvalHook<'_>>,
    mode: AsyncMode,
    staleness: Staleness,
) -> ClusterReport {
    let m = workers.len();
    assert!(m > 0);
    let ps = Arc::new(Mutex::new(ParamServer::new(init, m)));
    let t0 = Instant::now();
    let node_schedules = schedule_columns(schedule, m);
    let submit_mode = match mode {
        AsyncMode::Agwu => SubmitMode::Agwu,
        AsyncMode::Plain => SubmitMode::Plain,
    };

    let summaries: Vec<super::worker::WorkerRunSummary> = std::thread::scope(|scope| {
        let handles: Vec<_> = workers
            .into_iter()
            .zip(node_schedules)
            .enumerate()
            .map(|(j, (mut w, sched))| {
                let ps = Arc::clone(&ps);
                scope.spawn(move || {
                    let mut transport = InProcTransport::new(ps, j);
                    drive_worker(
                        &mut transport,
                        w.as_mut(),
                        &sched,
                        iterations,
                        submit_mode,
                        staleness,
                        false,
                    )
                    .expect("in-process pipelined worker failed")
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let wall_s = t0.elapsed().as_secs_f64();
    let tstats: Vec<TransportStats> = summaries.iter().map(|s| s.stats).collect();
    let node_busy: Vec<f64> = summaries.iter().map(|s| s.busy_s).collect();
    let node_stall: Vec<f64> = summaries.iter().map(|s| s.stats.stall_wall_s).collect();
    let node_overlap: Vec<f64> = summaries.iter().map(|s| s.stats.overlap_wall_s).collect();

    let mut versions: Vec<VersionRecord> = summaries
        .iter()
        .enumerate()
        .flat_map(|(j, s)| {
            s.ack_log.iter().map(move |a| VersionRecord {
                version: a.version,
                node: j,
                local_loss: a.loss,
                local_accuracy: a.accuracy,
                at_s: a.at.saturating_duration_since(t0).as_secs_f64(),
                eval: None,
            })
        })
        .collect();
    versions.sort_by_key(|v| v.version);

    let (comm, final_weights) = unwrap_server(ps, &tstats);
    // Async pushes do not carry snapshots, so per-version eval is not
    // available mid-flight; evaluate the final global set once instead.
    if let (Some(f), Some(last)) = (eval, versions.last_mut()) {
        last.eval = Some(f(&final_weights));
    }

    ClusterReport {
        strategy: UpdateStrategy::Agwu,
        versions,
        comm,
        sync_wait_s: 0.0,
        wall_s,
        node_busy_s: node_busy,
        node_stall_s: node_stall,
        node_overlap_s: node_overlap,
        fault: FaultStats::default(),
        final_weights,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetworkConfig;
    use crate::data::Dataset;
    use crate::nn::Network;
    use crate::outer::worker::NativeTrainer;

    fn setup(m: usize, per_node: usize) -> (NetworkConfig, Arc<Dataset>, AllocationSchedule) {
        let cfg = NetworkConfig::quickstart();
        let ds = Arc::new(Dataset::synthetic(&cfg, m * per_node, 0.2, 31));
        // One-shot allocation (UDPA-like) as a single schedule batch.
        let schedule = vec![(0..m).map(|j| j * per_node..(j + 1) * per_node).collect()];
        (cfg, ds, schedule)
    }

    fn workers(
        cfg: &NetworkConfig,
        ds: &Arc<Dataset>,
        m: usize,
        lr: f32,
    ) -> Vec<Box<dyn LocalTrainer>> {
        (0..m)
            .map(|_| {
                Box::new(NativeTrainer::new(cfg, Arc::clone(ds), lr)) as Box<dyn LocalTrainer>
            })
            .collect()
    }

    #[test]
    fn sgwu_runs_and_accounts_comm() {
        let (cfg, ds, schedule) = setup(3, 16);
        let init = Network::init(&cfg, 1).weights;
        let report = run_sgwu(init, workers(&cfg, &ds, 3, 0.2), &schedule, 4, None);
        assert_eq!(report.versions.len(), 4);
        // Eq. 11: 2·m·K transfers.
        assert_eq!(report.comm.fetches, 3 * 4);
        assert_eq!(report.comm.submits, 3 * 4);
        assert!(report.sync_wait_s >= 0.0);
        assert_eq!(report.node_busy_s.len(), 3);
    }

    #[test]
    fn agwu_runs_all_iterations_without_sync_wait() {
        let (cfg, ds, schedule) = setup(3, 16);
        let init = Network::init(&cfg, 2).weights;
        let report = run_agwu(init, workers(&cfg, &ds, 3, 0.2), &schedule, 4, None);
        // m·K versions, strictly increasing.
        assert_eq!(report.versions.len(), 12);
        for (i, v) in report.versions.iter().enumerate() {
            assert_eq!(v.version, i + 1);
        }
        assert_eq!(report.sync_wait_s, 0.0);
        assert_eq!(report.comm.fetches, 12);
        assert_eq!(report.comm.submits, 12);
    }

    #[test]
    fn sgwu_single_node_equals_plain_sgd() {
        // With m=1 and accuracy weighting over one node, SGWU must reproduce
        // exactly the node's local SGD trajectory.
        let cfg = NetworkConfig::quickstart();
        let ds = Arc::new(Dataset::synthetic(&cfg, 16, 0.2, 33));
        let schedule: AllocationSchedule = vec![vec![0..16]];
        let init = Network::init(&cfg, 5).weights;

        let report = run_sgwu(init.clone(), workers(&cfg, &ds, 1, 0.2), &schedule, 3, None);
        // Reference: same worker run standalone.
        let mut w = NativeTrainer::new(&cfg, Arc::clone(&ds), 0.2);
        w.add_samples(0..16);
        let mut cur = init;
        for _ in 0..3 {
            cur = w.train_epoch(Arc::new(cur)).weights;
        }
        assert!(
            report.final_weights.max_abs_diff(&cur) < 1e-6,
            "diff {}",
            report.final_weights.max_abs_diff(&cur)
        );
    }

    #[test]
    fn both_strategies_learn() {
        let (cfg, ds, schedule) = setup(2, 32);
        let init = Network::init(&cfg, 7).weights;
        for strat in ["sgwu", "agwu"] {
            let report = match strat {
                "sgwu" => run_sgwu(init.clone(), workers(&cfg, &ds, 2, 0.3), &schedule, 6, None),
                _ => run_agwu(init.clone(), workers(&cfg, &ds, 2, 0.3), &schedule, 6, None),
            };
            let first = report.versions.first().unwrap().local_loss;
            let last = report.versions.last().unwrap().local_loss;
            assert!(
                last < first,
                "{strat} did not learn: first={first} last={last}"
            );
        }
    }

    /// The in-process transports report measured accounting into the
    /// report's CommStats: no wire bytes (Arc bumps), but real fetch/submit
    /// handling time, and the final weights move out of the server.
    #[test]
    fn inproc_transport_accounting_in_report() {
        let (cfg, ds, schedule) = setup(2, 16);
        let init = Network::init(&cfg, 11).weights;
        let report = run_agwu(init, workers(&cfg, &ds, 2, 0.2), &schedule, 2, None);
        assert_eq!(report.comm.wire_bytes, 0, "in-process runs move no wire bytes");
        assert!(report.comm.comm_wall_s() >= 0.0);
        assert_eq!(report.comm.fetches, 4);
        assert_eq!(report.versions.len(), 4);
        assert_eq!(
            report.final_weights.param_count(),
            Network::init(&cfg, 11).weights.param_count()
        );
    }

    #[test]
    fn schedule_columns_transposes() {
        let schedule: AllocationSchedule = vec![vec![0..2, 2..4], vec![4..6, 6..8]];
        let cols = schedule_columns(&schedule, 2);
        assert_eq!(cols, vec![vec![0..2, 4..6], vec![2..4, 6..8]]);
    }

    /// The pipelined in-process runner produces the same version structure
    /// as the serialized one — m·K acked versions, strictly increasing —
    /// while keeping per-node stall/overlap accounting consistent.
    #[test]
    fn pipelined_agwu_matches_version_structure() {
        let (cfg, ds, schedule) = setup(3, 16);
        let init = Network::init(&cfg, 2).weights;
        let report = run_async_pipelined(
            init,
            workers(&cfg, &ds, 3, 0.2),
            &schedule,
            4,
            None,
            AsyncMode::Agwu,
            Staleness(1),
        );
        assert_eq!(report.versions.len(), 12);
        for (i, v) in report.versions.iter().enumerate() {
            assert_eq!(v.version, i + 1);
        }
        // Each node acked exactly its own K submissions.
        for j in 0..3 {
            assert_eq!(report.versions.iter().filter(|v| v.node == j).count(), 4);
        }
        // Staleness refetches may add fetches, but submits are exact.
        assert_eq!(report.comm.submits, 12);
        assert!(report.comm.fetches >= 12);
        assert_eq!(report.node_stall_s.len(), 3);
        assert_eq!(report.node_overlap_s.len(), 3);
        assert!(report.node_stall_s.iter().all(|s| *s >= 0.0));
        assert!(report.versions.iter().all(|v| v.local_loss.is_finite()));
    }

    #[test]
    fn agwu_with_straggler_still_progresses() {
        let cfg = NetworkConfig::quickstart();
        let ds = Arc::new(Dataset::synthetic(&cfg, 48, 0.2, 35));
        let schedule: AllocationSchedule = vec![vec![0..16, 16..32, 32..48]];
        let init = Network::init(&cfg, 9).weights;
        let mut ws: Vec<Box<dyn LocalTrainer>> = Vec::new();
        ws.push(Box::new(NativeTrainer::new(&cfg, Arc::clone(&ds), 0.2)));
        ws.push(Box::new(NativeTrainer::new(&cfg, Arc::clone(&ds), 0.2)));
        ws.push(Box::new(
            NativeTrainer::new(&cfg, Arc::clone(&ds), 0.2).with_slowdown(3.0),
        ));
        let report = run_agwu(init, ws, &schedule, 3, None);
        assert_eq!(report.versions.len(), 9);
        // The straggler's updates arrive late (higher at_s) but all arrive.
        let by_node3: Vec<_> = report.versions.iter().filter(|v| v.node == 2).collect();
        assert_eq!(by_node3.len(), 3);
    }
}
