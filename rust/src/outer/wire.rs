//! Length-prefixed binary protocol between workers and the parameter
//! server (§3.2's node ↔ server links, made real).
//!
//! Every message is one frame:
//! `u32 LE body length | u8 tag | body | u32 LE CRC32(tag+body)`.
//! Weight sets ride the [`crate::tensor::wire`] codec unchanged, so the
//! protocol layer only adds scalars (LE-encoded) around them. Frames are
//! capped at [`MAX_FRAME`] to keep a corrupt length prefix from driving a
//! multi-gigabyte allocation, and the CRC trailer rejects bit corruption
//! that a length check alone would let through (the server answers a
//! mismatch with a typed `Error` frame, like any other decode rejection).
//!
//! The same framing carries the primary → standby replication channel of
//! the warm-standby parameter server: `Replicate` streams committed global
//! updates (metadata plus periodic full `BPWS` snapshots), `ReplAck`
//! acknowledges them, and `Promote` fences a stale primary after the
//! standby bumped the cluster epoch.

use std::io::{Read, Write};

use anyhow::{bail, ensure, Context, Result};

use crate::tensor::wire::{decode_weight_set, encode_weight_set_into, encoded_len};
use crate::tensor::WeightSet;

use super::transport::SubmitMode;

/// Upper bound on one frame's body (weights for the paper's largest Table-2
/// case are ~hundreds of MB below this).
pub const MAX_FRAME: usize = 1 << 30;

/// Sentinel node id a replication channel registers with in its `Hello`:
/// no worker slot can ever collide with it, so the server can tell a
/// standby's replication link from a computing node by the first frame.
pub const REPL_NODE: u32 = u32::MAX;

const TAG_HELLO: u8 = 1;
const TAG_FETCH: u8 = 2;
const TAG_SUBMIT: u8 = 3;
const TAG_GLOBAL: u8 = 4;
const TAG_ACK: u8 = 5;
const TAG_DONE: u8 = 6;
const TAG_ERROR: u8 = 7;
const TAG_PING: u8 = 8;
const TAG_PONG: u8 = 9;
const TAG_REPLICATE: u8 = 10;
const TAG_REPL_ACK: u8 = 11;
const TAG_PROMOTE: u8 = 12;

const EVENT_UPDATE: u8 = 0;
const EVENT_NODE_DONE: u8 = 1;
const EVENT_NODE_DEAD: u8 = 2;

// ---------------------------------------------------------------------------
// CRC32 (IEEE 802.3 polynomial, table-driven, hand-rolled — no crates)
// ---------------------------------------------------------------------------

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// IEEE CRC32 of `data` (the zlib/`cksum -o 3` polynomial). Used as the
/// per-frame integrity trailer; also handy for fingerprinting weight sets
/// in logs without dumping them.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// One protocol message. Client → server: `Hello`, `Fetch`, `Submit`,
/// `Done`. Server → client: `Global`, `Ack`, `Error`. Primary ↔ standby:
/// `Replicate`/`ReplAck`/`Promote` (plus `Hello` with [`REPL_NODE`]).
#[derive(Debug)]
pub enum Msg {
    /// Registration: which node slot this connection drives ([`REPL_NODE`]
    /// marks a replication channel) and the cluster epoch the sender last
    /// observed (0 for a fresh worker; bumped by standby promotion).
    Hello { node: u32, epoch: u64 },
    /// Request the freshest global weight set.
    Fetch,
    /// Submit a locally-trained weight set. `base` is the global version the
    /// node trained from (AGWU staleness, Eq. 9); `accuracy`/`loss` feed the
    /// Eq. 7/10 weighting and the server-side learning curve.
    Submit { mode: SubmitMode, base: u64, accuracy: f64, loss: f64, weights: WeightSet },
    /// Reply to `Fetch`: the global set at `version`, stamped with the
    /// server's cluster `epoch` so workers track promotions. `reassigned`
    /// carries sample ranges the server moved onto this node after a peer
    /// died (IDPA re-allocation); empty in the healthy path. The ranges ride
    /// *before* the weight payload because the `BPWS` decoder rejects
    /// trailing bytes.
    Global { version: u64, epoch: u64, reassigned: Vec<(u64, u64)>, weights: WeightSet },
    /// Reply to `Submit`: the server's version after processing it (for
    /// SGWU, the reply is delayed until the whole round is installed — the
    /// socket *is* the Eq. 8 barrier).
    Ack { version: u64 },
    /// Worker finished all its iterations; the connection winds down.
    Done,
    /// Server-side failure report (protocol violation, bad node id, ...).
    Error { msg: String },
    /// Liveness probe (client → server). Renews the sender's lease without
    /// touching the weight state. Also the primary's keepalive on an idle
    /// replication channel.
    Ping,
    /// Reply to `Ping`.
    Pong,
    /// Primary → standby: one committed cluster event at `epoch`.
    Replicate { epoch: u64, event: ReplEvent },
    /// Standby → primary: the event stream is durable up to `version` as
    /// seen at `epoch`.
    ReplAck { epoch: u64, version: u64 },
    /// "I am the primary at `epoch`" — sent to fence a connection speaking
    /// an older epoch (a resurrected primary or a mis-wired second server).
    /// The receiver must stand down.
    Promote { epoch: u64 },
}

/// One replicated cluster event streamed primary → standby.
#[derive(Debug, Clone)]
pub enum ReplEvent {
    /// A committed global update. `node == u32::MAX` marks an SGWU round
    /// install (no single contributing node). `weights` is the full global
    /// set at `version` on snapshot frames (every frame under
    /// `--repl-ack standby`; every `--repl-snapshot-every`-th otherwise).
    Update {
        version: u64,
        node: u32,
        loss: f64,
        accuracy: f64,
        at_s: f64,
        weights: Option<WeightSet>,
    },
    /// A node finished all its iterations on the primary.
    NodeDone { node: u32 },
    /// A node was declared dead on the primary.
    NodeDead { node: u32 },
}

fn mode_to_wire(m: SubmitMode) -> u8 {
    match m {
        SubmitMode::Agwu => 0,
        SubmitMode::Plain => 1,
        SubmitMode::Sgwu => 2,
    }
}

fn mode_from_wire(b: u8) -> Result<SubmitMode> {
    Ok(match b {
        0 => SubmitMode::Agwu,
        1 => SubmitMode::Plain,
        2 => SubmitMode::Sgwu,
        other => bail!("unknown submit mode byte {other}"),
    })
}

/// Serialize `msg` as one frame into `w`. Returns the total bytes written
/// (frame prefix included) — the transport's measured wire accounting.
pub fn write_msg(w: &mut impl Write, msg: &Msg) -> Result<usize> {
    let mut body: Vec<u8> = Vec::with_capacity(match msg {
        Msg::Submit { weights, .. } => 1 + 1 + 8 + 8 + 8 + encoded_len(weights),
        Msg::Global { reassigned, weights, .. } => {
            1 + 8 + 8 + 4 + 16 * reassigned.len() + encoded_len(weights)
        }
        Msg::Replicate { event: ReplEvent::Update { weights: Some(ws), .. }, .. } => {
            1 + 8 + 1 + 37 + 1 + encoded_len(ws)
        }
        _ => 64,
    });
    match msg {
        Msg::Hello { node, epoch } => {
            body.push(TAG_HELLO);
            body.extend_from_slice(&node.to_le_bytes());
            body.extend_from_slice(&epoch.to_le_bytes());
        }
        Msg::Fetch => body.push(TAG_FETCH),
        Msg::Submit { mode, base, accuracy, loss, weights } => {
            body.push(TAG_SUBMIT);
            body.push(mode_to_wire(*mode));
            body.extend_from_slice(&base.to_le_bytes());
            body.extend_from_slice(&accuracy.to_le_bytes());
            body.extend_from_slice(&loss.to_le_bytes());
            encode_weight_set_into(weights, &mut body);
        }
        Msg::Global { version, epoch, reassigned, weights } => {
            body.push(TAG_GLOBAL);
            body.extend_from_slice(&version.to_le_bytes());
            body.extend_from_slice(&epoch.to_le_bytes());
            body.extend_from_slice(&(reassigned.len() as u32).to_le_bytes());
            for (start, end) in reassigned {
                body.extend_from_slice(&start.to_le_bytes());
                body.extend_from_slice(&end.to_le_bytes());
            }
            encode_weight_set_into(weights, &mut body);
        }
        Msg::Ack { version } => {
            body.push(TAG_ACK);
            body.extend_from_slice(&version.to_le_bytes());
        }
        Msg::Done => body.push(TAG_DONE),
        Msg::Error { msg } => {
            body.push(TAG_ERROR);
            body.extend_from_slice(msg.as_bytes());
        }
        Msg::Ping => body.push(TAG_PING),
        Msg::Pong => body.push(TAG_PONG),
        Msg::Replicate { epoch, event } => {
            body.push(TAG_REPLICATE);
            body.extend_from_slice(&epoch.to_le_bytes());
            match event {
                ReplEvent::Update { version, node, loss, accuracy, at_s, weights } => {
                    body.push(EVENT_UPDATE);
                    body.extend_from_slice(&version.to_le_bytes());
                    body.extend_from_slice(&node.to_le_bytes());
                    body.extend_from_slice(&loss.to_le_bytes());
                    body.extend_from_slice(&accuracy.to_le_bytes());
                    body.extend_from_slice(&at_s.to_le_bytes());
                    match weights {
                        Some(ws) => {
                            body.push(1);
                            encode_weight_set_into(ws, &mut body);
                        }
                        None => body.push(0),
                    }
                }
                ReplEvent::NodeDone { node } => {
                    body.push(EVENT_NODE_DONE);
                    body.extend_from_slice(&node.to_le_bytes());
                }
                ReplEvent::NodeDead { node } => {
                    body.push(EVENT_NODE_DEAD);
                    body.extend_from_slice(&node.to_le_bytes());
                }
            }
        }
        Msg::ReplAck { epoch, version } => {
            body.push(TAG_REPL_ACK);
            body.extend_from_slice(&epoch.to_le_bytes());
            body.extend_from_slice(&version.to_le_bytes());
        }
        Msg::Promote { epoch } => {
            body.push(TAG_PROMOTE);
            body.extend_from_slice(&epoch.to_le_bytes());
        }
    }
    ensure!(body.len() <= MAX_FRAME, "frame body {} exceeds MAX_FRAME", body.len());
    w.write_all(&(body.len() as u32).to_le_bytes()).context("write frame length")?;
    w.write_all(&body).context("write frame body")?;
    w.write_all(&crc32(&body).to_le_bytes()).context("write frame crc")?;
    w.flush().context("flush frame")?;
    Ok(4 + body.len() + 4)
}

/// Read one frame from `r`. Returns the message plus the total bytes read.
/// A CRC trailer mismatch is a decode error (the stream stays frame-aligned
/// — the whole frame was consumed), so servers answer it with a typed
/// `Error` frame instead of tearing the connection down silently.
pub fn read_msg(r: &mut impl Read) -> Result<(Msg, usize)> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4).context("read frame length")?;
    let len = u32::from_le_bytes(len4) as usize;
    ensure!(len >= 1, "empty frame");
    ensure!(len <= MAX_FRAME, "frame length {len} exceeds MAX_FRAME");
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).context("read frame body")?;
    let mut crc4 = [0u8; 4];
    r.read_exact(&mut crc4).context("read frame crc")?;
    let want = u32::from_le_bytes(crc4);
    let got = crc32(&body);
    ensure!(
        got == want,
        "frame crc mismatch: computed {got:#010x}, trailer {want:#010x} (corrupt frame)"
    );
    let tag = body[0];
    let rest = &body[1..];
    let msg = match tag {
        TAG_HELLO => {
            ensure!(rest.len() == 12, "hello body length {}", rest.len());
            Msg::Hello {
                node: u32::from_le_bytes(rest[..4].try_into().unwrap()),
                epoch: u64::from_le_bytes(rest[4..12].try_into().unwrap()),
            }
        }
        TAG_FETCH => {
            ensure!(rest.is_empty(), "fetch carries no body");
            Msg::Fetch
        }
        TAG_SUBMIT => {
            ensure!(rest.len() >= 1 + 8 + 8 + 8, "submit body too short: {}", rest.len());
            let mode = mode_from_wire(rest[0])?;
            let base = u64::from_le_bytes(rest[1..9].try_into().unwrap());
            let accuracy = f64::from_le_bytes(rest[9..17].try_into().unwrap());
            let loss = f64::from_le_bytes(rest[17..25].try_into().unwrap());
            let weights = decode_weight_set(&rest[25..])?;
            Msg::Submit { mode, base, accuracy, loss, weights }
        }
        TAG_GLOBAL => {
            ensure!(rest.len() >= 20, "global body too short: {}", rest.len());
            let version = u64::from_le_bytes(rest[..8].try_into().unwrap());
            let epoch = u64::from_le_bytes(rest[8..16].try_into().unwrap());
            let n = u32::from_le_bytes(rest[16..20].try_into().unwrap()) as usize;
            let ranges_end = 20 + 16 * n;
            ensure!(
                rest.len() >= ranges_end,
                "global declares {n} reassigned ranges but body is {} bytes",
                rest.len()
            );
            let mut reassigned = Vec::with_capacity(n);
            for i in 0..n {
                let at = 20 + 16 * i;
                let start = u64::from_le_bytes(rest[at..at + 8].try_into().unwrap());
                let end = u64::from_le_bytes(rest[at + 8..at + 16].try_into().unwrap());
                ensure!(start <= end, "reassigned range {start}..{end} is inverted");
                reassigned.push((start, end));
            }
            let weights = decode_weight_set(&rest[ranges_end..])?;
            Msg::Global { version, epoch, reassigned, weights }
        }
        TAG_ACK => {
            ensure!(rest.len() == 8, "ack body length {}", rest.len());
            Msg::Ack { version: u64::from_le_bytes(rest.try_into().unwrap()) }
        }
        TAG_DONE => {
            ensure!(rest.is_empty(), "done carries no body");
            Msg::Done
        }
        TAG_ERROR => Msg::Error { msg: String::from_utf8_lossy(rest).into_owned() },
        TAG_PING => {
            ensure!(rest.is_empty(), "ping carries no body");
            Msg::Ping
        }
        TAG_PONG => {
            ensure!(rest.is_empty(), "pong carries no body");
            Msg::Pong
        }
        TAG_REPLICATE => {
            ensure!(rest.len() >= 9, "replicate body too short: {}", rest.len());
            let epoch = u64::from_le_bytes(rest[..8].try_into().unwrap());
            let kind = rest[8];
            let ev = &rest[9..];
            let event = match kind {
                EVENT_UPDATE => {
                    ensure!(ev.len() >= 37, "replicate update body too short: {}", ev.len());
                    let version = u64::from_le_bytes(ev[..8].try_into().unwrap());
                    let node = u32::from_le_bytes(ev[8..12].try_into().unwrap());
                    let loss = f64::from_le_bytes(ev[12..20].try_into().unwrap());
                    let accuracy = f64::from_le_bytes(ev[20..28].try_into().unwrap());
                    let at_s = f64::from_le_bytes(ev[28..36].try_into().unwrap());
                    let weights = match ev[36] {
                        0 => {
                            ensure!(ev.len() == 37, "metadata-only update carries no payload");
                            None
                        }
                        1 => Some(decode_weight_set(&ev[37..])?),
                        other => bail!("bad snapshot flag {other} in replicate update"),
                    };
                    ReplEvent::Update { version, node, loss, accuracy, at_s, weights }
                }
                EVENT_NODE_DONE | EVENT_NODE_DEAD => {
                    ensure!(ev.len() == 4, "replicate node event body length {}", ev.len());
                    let node = u32::from_le_bytes(ev.try_into().unwrap());
                    if kind == EVENT_NODE_DONE {
                        ReplEvent::NodeDone { node }
                    } else {
                        ReplEvent::NodeDead { node }
                    }
                }
                other => bail!("unknown replicate event kind {other}"),
            };
            Msg::Replicate { epoch, event }
        }
        TAG_REPL_ACK => {
            ensure!(rest.len() == 16, "repl-ack body length {}", rest.len());
            Msg::ReplAck {
                epoch: u64::from_le_bytes(rest[..8].try_into().unwrap()),
                version: u64::from_le_bytes(rest[8..16].try_into().unwrap()),
            }
        }
        TAG_PROMOTE => {
            ensure!(rest.len() == 8, "promote body length {}", rest.len());
            Msg::Promote { epoch: u64::from_le_bytes(rest.try_into().unwrap()) }
        }
        other => bail!("unknown message tag {other}"),
    };
    Ok((msg, 4 + len + 4))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn ws() -> WeightSet {
        WeightSet::new(vec![Tensor::from_vec(&[2, 2], vec![1.0, f32::NAN, -0.0, 3.5])])
    }

    fn round_trip(msg: Msg) -> Msg {
        let mut buf = Vec::new();
        let wrote = write_msg(&mut buf, &msg).unwrap();
        assert_eq!(wrote, buf.len());
        let mut cursor = std::io::Cursor::new(buf.clone());
        let (out, read) = read_msg(&mut cursor).unwrap();
        assert_eq!(read, buf.len());
        out
    }

    #[test]
    fn scalar_messages_round_trip() {
        match round_trip(Msg::Hello { node: 7, epoch: 3 }) {
            Msg::Hello { node, epoch } => assert_eq!((node, epoch), (7, 3)),
            other => panic!("{other:?}"),
        }
        match round_trip(Msg::ReplAck { epoch: 2, version: 99 }) {
            Msg::ReplAck { epoch, version } => assert_eq!((epoch, version), (2, 99)),
            other => panic!("{other:?}"),
        }
        match round_trip(Msg::Promote { epoch: 5 }) {
            Msg::Promote { epoch } => assert_eq!(epoch, 5),
            other => panic!("{other:?}"),
        }
        assert!(matches!(round_trip(Msg::Fetch), Msg::Fetch));
        assert!(matches!(round_trip(Msg::Done), Msg::Done));
        match round_trip(Msg::Ack { version: 123 }) {
            Msg::Ack { version } => assert_eq!(version, 123),
            other => panic!("{other:?}"),
        }
        match round_trip(Msg::Error { msg: "boom".into() }) {
            Msg::Error { msg } => assert_eq!(msg, "boom"),
            other => panic!("{other:?}"),
        }
        assert!(matches!(round_trip(Msg::Ping), Msg::Ping));
        assert!(matches!(round_trip(Msg::Pong), Msg::Pong));
    }

    #[test]
    fn submit_round_trips_with_weights() {
        let msg = Msg::Submit {
            mode: SubmitMode::Agwu,
            base: 42,
            accuracy: 0.75,
            loss: 1.25,
            weights: ws(),
        };
        match round_trip(msg) {
            Msg::Submit { mode, base, accuracy, loss, weights } => {
                assert_eq!(mode, SubmitMode::Agwu);
                assert_eq!(base, 42);
                assert_eq!(accuracy, 0.75);
                assert_eq!(loss, 1.25);
                assert_eq!(weights.tensors()[0].shape(), &[2, 2]);
                let bits: Vec<u32> =
                    weights.tensors()[0].data().iter().map(|v| v.to_bits()).collect();
                let want: Vec<u32> =
                    ws().tensors()[0].data().iter().map(|v| v.to_bits()).collect();
                assert_eq!(bits, want);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn global_round_trips() {
        match round_trip(Msg::Global { version: 9, epoch: 4, reassigned: vec![], weights: ws() })
        {
            Msg::Global { version, epoch, reassigned, weights } => {
                assert_eq!((version, epoch), (9, 4));
                assert!(reassigned.is_empty());
                assert_eq!(weights.param_count(), 4);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn global_round_trips_with_reassigned_ranges() {
        let ranges = vec![(100u64, 250u64), (900, 1000)];
        let msg =
            Msg::Global { version: 3, epoch: 0, reassigned: ranges.clone(), weights: ws() };
        match round_trip(msg) {
            Msg::Global { version, epoch, reassigned, weights } => {
                assert_eq!((version, epoch), (3, 0));
                assert_eq!(reassigned, ranges);
                assert_eq!(weights.param_count(), 4);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn replicate_round_trips_with_and_without_snapshot() {
        let msg = Msg::Replicate {
            epoch: 1,
            event: ReplEvent::Update {
                version: 17,
                node: 2,
                loss: 0.5,
                accuracy: 0.75,
                at_s: 1.25,
                weights: Some(ws()),
            },
        };
        match round_trip(msg) {
            Msg::Replicate { epoch, event: ReplEvent::Update { version, node, weights, .. } } => {
                assert_eq!((epoch, version, node), (1, 17, 2));
                let got = weights.expect("snapshot survives");
                let bits: Vec<u32> = got.tensors()[0].data().iter().map(|v| v.to_bits()).collect();
                let want: Vec<u32> = ws().tensors()[0].data().iter().map(|v| v.to_bits()).collect();
                assert_eq!(bits, want, "replicated snapshot must be bit-identical");
            }
            other => panic!("{other:?}"),
        }
        let meta_only = Msg::Replicate {
            epoch: 2,
            event: ReplEvent::Update {
                version: 18,
                node: u32::MAX,
                loss: 0.4,
                accuracy: 0.8,
                at_s: 2.0,
                weights: None,
            },
        };
        match round_trip(meta_only) {
            Msg::Replicate { event: ReplEvent::Update { version, node, weights, .. }, .. } => {
                assert_eq!((version, node), (18, u32::MAX));
                assert!(weights.is_none());
            }
            other => panic!("{other:?}"),
        }
        match round_trip(Msg::Replicate { epoch: 3, event: ReplEvent::NodeDone { node: 1 } }) {
            Msg::Replicate { epoch: 3, event: ReplEvent::NodeDone { node: 1 } } => {}
            other => panic!("{other:?}"),
        }
        match round_trip(Msg::Replicate { epoch: 3, event: ReplEvent::NodeDead { node: 0 } }) {
            Msg::Replicate { epoch: 3, event: ReplEvent::NodeDead { node: 0 } } => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn inverted_reassigned_range_rejected() {
        let mut buf = Vec::new();
        write_msg(
            &mut buf,
            &Msg::Global { version: 1, epoch: 0, reassigned: vec![(10, 4)], weights: ws() },
        )
        .unwrap();
        // Re-stamp the CRC so the *range* check (not the trailer) rejects it.
        let len = u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
        let crc = crc32(&buf[4..4 + len]);
        let at = 4 + len;
        buf[at..at + 4].copy_from_slice(&crc.to_le_bytes());
        assert!(read_msg(&mut std::io::Cursor::new(buf)).is_err());
    }

    #[test]
    fn corrupt_frames_rejected() {
        let mut buf = Vec::new();
        write_msg(&mut buf, &Msg::Fetch).unwrap();
        // Truncated frame (CRC trailer cut short).
        let mut cur = std::io::Cursor::new(buf[..buf.len() - 1].to_vec());
        assert!(read_msg(&mut cur).is_err());
        // Corrupt tag byte: caught by the CRC trailer before tag dispatch.
        let mut bad = buf.clone();
        bad[4] = 0xEE;
        let err = read_msg(&mut std::io::Cursor::new(bad)).unwrap_err();
        assert!(err.to_string().contains("crc mismatch"), "{err:#}");
        // Oversized declared length.
        let mut bad = buf;
        bad[0..4].copy_from_slice(&(MAX_FRAME as u32 + 1).to_le_bytes());
        assert!(read_msg(&mut std::io::Cursor::new(bad)).is_err());
    }

    #[test]
    fn crc_trailer_rejects_any_single_bit_flip() {
        let mut clean = Vec::new();
        write_msg(&mut clean, &Msg::Ack { version: 7 }).unwrap();
        let len = u32::from_le_bytes(clean[..4].try_into().unwrap()) as usize;
        // Flip every bit of the body and of the trailer, one at a time:
        // each corruption must be rejected with a crc mismatch.
        for byte in 4..4 + len + 4 {
            for bit in 0..8 {
                let mut bad = clean.clone();
                bad[byte] ^= 1 << bit;
                let err = read_msg(&mut std::io::Cursor::new(bad)).unwrap_err();
                assert!(
                    err.to_string().contains("crc mismatch"),
                    "byte {byte} bit {bit}: {err:#}"
                );
            }
        }
        // The clean frame still parses (the loop above cloned it).
        assert!(read_msg(&mut std::io::Cursor::new(clean)).is_ok());
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // IEEE CRC32 check values (zlib's crc32()).
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }
}
