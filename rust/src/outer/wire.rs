//! Length-prefixed binary protocol between workers and the parameter
//! server (§3.2's node ↔ server links, made real).
//!
//! Every message is one frame: `u32 LE body length | u8 tag | body`.
//! Weight sets ride the [`crate::tensor::wire`] codec unchanged, so the
//! protocol layer only adds scalars (LE-encoded) around them. Frames are
//! capped at [`MAX_FRAME`] to keep a corrupt length prefix from driving a
//! multi-gigabyte allocation.

use std::io::{Read, Write};

use anyhow::{bail, ensure, Context, Result};

use crate::tensor::wire::{decode_weight_set, encode_weight_set_into, encoded_len};
use crate::tensor::WeightSet;

use super::transport::SubmitMode;

/// Upper bound on one frame's body (weights for the paper's largest Table-2
/// case are ~hundreds of MB below this).
pub const MAX_FRAME: usize = 1 << 30;

const TAG_HELLO: u8 = 1;
const TAG_FETCH: u8 = 2;
const TAG_SUBMIT: u8 = 3;
const TAG_GLOBAL: u8 = 4;
const TAG_ACK: u8 = 5;
const TAG_DONE: u8 = 6;
const TAG_ERROR: u8 = 7;
const TAG_PING: u8 = 8;
const TAG_PONG: u8 = 9;

/// One protocol message. Client → server: `Hello`, `Fetch`, `Submit`,
/// `Done`. Server → client: `Global`, `Ack`, `Error`.
#[derive(Debug)]
pub enum Msg {
    /// Worker registration: which node slot this connection drives.
    Hello { node: u32 },
    /// Request the freshest global weight set.
    Fetch,
    /// Submit a locally-trained weight set. `base` is the global version the
    /// node trained from (AGWU staleness, Eq. 9); `accuracy`/`loss` feed the
    /// Eq. 7/10 weighting and the server-side learning curve.
    Submit { mode: SubmitMode, base: u64, accuracy: f64, loss: f64, weights: WeightSet },
    /// Reply to `Fetch`: the global set at `version`. `reassigned` carries
    /// sample ranges the server moved onto this node after a peer died
    /// (IDPA re-allocation); empty in the healthy path. The ranges ride
    /// *before* the weight payload because the `BPWS` decoder rejects
    /// trailing bytes.
    Global { version: u64, reassigned: Vec<(u64, u64)>, weights: WeightSet },
    /// Reply to `Submit`: the server's version after processing it (for
    /// SGWU, the reply is delayed until the whole round is installed — the
    /// socket *is* the Eq. 8 barrier).
    Ack { version: u64 },
    /// Worker finished all its iterations; the connection winds down.
    Done,
    /// Server-side failure report (protocol violation, bad node id, ...).
    Error { msg: String },
    /// Liveness probe (client → server). Renews the sender's lease without
    /// touching the weight state.
    Ping,
    /// Reply to `Ping`.
    Pong,
}

fn mode_to_wire(m: SubmitMode) -> u8 {
    match m {
        SubmitMode::Agwu => 0,
        SubmitMode::Plain => 1,
        SubmitMode::Sgwu => 2,
    }
}

fn mode_from_wire(b: u8) -> Result<SubmitMode> {
    Ok(match b {
        0 => SubmitMode::Agwu,
        1 => SubmitMode::Plain,
        2 => SubmitMode::Sgwu,
        other => bail!("unknown submit mode byte {other}"),
    })
}

/// Serialize `msg` as one frame into `w`. Returns the total bytes written
/// (frame prefix included) — the transport's measured wire accounting.
pub fn write_msg(w: &mut impl Write, msg: &Msg) -> Result<usize> {
    let mut body: Vec<u8> = Vec::with_capacity(match msg {
        Msg::Submit { weights, .. } => 1 + 1 + 8 + 8 + 8 + encoded_len(weights),
        Msg::Global { reassigned, weights, .. } => {
            1 + 8 + 4 + 16 * reassigned.len() + encoded_len(weights)
        }
        _ => 64,
    });
    match msg {
        Msg::Hello { node } => {
            body.push(TAG_HELLO);
            body.extend_from_slice(&node.to_le_bytes());
        }
        Msg::Fetch => body.push(TAG_FETCH),
        Msg::Submit { mode, base, accuracy, loss, weights } => {
            body.push(TAG_SUBMIT);
            body.push(mode_to_wire(*mode));
            body.extend_from_slice(&base.to_le_bytes());
            body.extend_from_slice(&accuracy.to_le_bytes());
            body.extend_from_slice(&loss.to_le_bytes());
            encode_weight_set_into(weights, &mut body);
        }
        Msg::Global { version, reassigned, weights } => {
            body.push(TAG_GLOBAL);
            body.extend_from_slice(&version.to_le_bytes());
            body.extend_from_slice(&(reassigned.len() as u32).to_le_bytes());
            for (start, end) in reassigned {
                body.extend_from_slice(&start.to_le_bytes());
                body.extend_from_slice(&end.to_le_bytes());
            }
            encode_weight_set_into(weights, &mut body);
        }
        Msg::Ack { version } => {
            body.push(TAG_ACK);
            body.extend_from_slice(&version.to_le_bytes());
        }
        Msg::Done => body.push(TAG_DONE),
        Msg::Error { msg } => {
            body.push(TAG_ERROR);
            body.extend_from_slice(msg.as_bytes());
        }
        Msg::Ping => body.push(TAG_PING),
        Msg::Pong => body.push(TAG_PONG),
    }
    ensure!(body.len() <= MAX_FRAME, "frame body {} exceeds MAX_FRAME", body.len());
    w.write_all(&(body.len() as u32).to_le_bytes()).context("write frame length")?;
    w.write_all(&body).context("write frame body")?;
    w.flush().context("flush frame")?;
    Ok(4 + body.len())
}

/// Read one frame from `r`. Returns the message plus the total bytes read.
pub fn read_msg(r: &mut impl Read) -> Result<(Msg, usize)> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4).context("read frame length")?;
    let len = u32::from_le_bytes(len4) as usize;
    ensure!(len >= 1, "empty frame");
    ensure!(len <= MAX_FRAME, "frame length {len} exceeds MAX_FRAME");
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).context("read frame body")?;
    let tag = body[0];
    let rest = &body[1..];
    let msg = match tag {
        TAG_HELLO => {
            ensure!(rest.len() == 4, "hello body length {}", rest.len());
            Msg::Hello { node: u32::from_le_bytes([rest[0], rest[1], rest[2], rest[3]]) }
        }
        TAG_FETCH => {
            ensure!(rest.is_empty(), "fetch carries no body");
            Msg::Fetch
        }
        TAG_SUBMIT => {
            ensure!(rest.len() >= 1 + 8 + 8 + 8, "submit body too short: {}", rest.len());
            let mode = mode_from_wire(rest[0])?;
            let base = u64::from_le_bytes(rest[1..9].try_into().unwrap());
            let accuracy = f64::from_le_bytes(rest[9..17].try_into().unwrap());
            let loss = f64::from_le_bytes(rest[17..25].try_into().unwrap());
            let weights = decode_weight_set(&rest[25..])?;
            Msg::Submit { mode, base, accuracy, loss, weights }
        }
        TAG_GLOBAL => {
            ensure!(rest.len() >= 12, "global body too short: {}", rest.len());
            let version = u64::from_le_bytes(rest[..8].try_into().unwrap());
            let n = u32::from_le_bytes(rest[8..12].try_into().unwrap()) as usize;
            let ranges_end = 12 + 16 * n;
            ensure!(
                rest.len() >= ranges_end,
                "global declares {n} reassigned ranges but body is {} bytes",
                rest.len()
            );
            let mut reassigned = Vec::with_capacity(n);
            for i in 0..n {
                let at = 12 + 16 * i;
                let start = u64::from_le_bytes(rest[at..at + 8].try_into().unwrap());
                let end = u64::from_le_bytes(rest[at + 8..at + 16].try_into().unwrap());
                ensure!(start <= end, "reassigned range {start}..{end} is inverted");
                reassigned.push((start, end));
            }
            let weights = decode_weight_set(&rest[ranges_end..])?;
            Msg::Global { version, reassigned, weights }
        }
        TAG_ACK => {
            ensure!(rest.len() == 8, "ack body length {}", rest.len());
            Msg::Ack { version: u64::from_le_bytes(rest.try_into().unwrap()) }
        }
        TAG_DONE => {
            ensure!(rest.is_empty(), "done carries no body");
            Msg::Done
        }
        TAG_ERROR => Msg::Error { msg: String::from_utf8_lossy(rest).into_owned() },
        TAG_PING => {
            ensure!(rest.is_empty(), "ping carries no body");
            Msg::Ping
        }
        TAG_PONG => {
            ensure!(rest.is_empty(), "pong carries no body");
            Msg::Pong
        }
        other => bail!("unknown message tag {other}"),
    };
    Ok((msg, 4 + len))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn ws() -> WeightSet {
        WeightSet::new(vec![Tensor::from_vec(&[2, 2], vec![1.0, f32::NAN, -0.0, 3.5])])
    }

    fn round_trip(msg: Msg) -> Msg {
        let mut buf = Vec::new();
        let wrote = write_msg(&mut buf, &msg).unwrap();
        assert_eq!(wrote, buf.len());
        let mut cursor = std::io::Cursor::new(buf.clone());
        let (out, read) = read_msg(&mut cursor).unwrap();
        assert_eq!(read, buf.len());
        out
    }

    #[test]
    fn scalar_messages_round_trip() {
        match round_trip(Msg::Hello { node: 7 }) {
            Msg::Hello { node } => assert_eq!(node, 7),
            other => panic!("{other:?}"),
        }
        assert!(matches!(round_trip(Msg::Fetch), Msg::Fetch));
        assert!(matches!(round_trip(Msg::Done), Msg::Done));
        match round_trip(Msg::Ack { version: 123 }) {
            Msg::Ack { version } => assert_eq!(version, 123),
            other => panic!("{other:?}"),
        }
        match round_trip(Msg::Error { msg: "boom".into() }) {
            Msg::Error { msg } => assert_eq!(msg, "boom"),
            other => panic!("{other:?}"),
        }
        assert!(matches!(round_trip(Msg::Ping), Msg::Ping));
        assert!(matches!(round_trip(Msg::Pong), Msg::Pong));
    }

    #[test]
    fn submit_round_trips_with_weights() {
        let msg = Msg::Submit {
            mode: SubmitMode::Agwu,
            base: 42,
            accuracy: 0.75,
            loss: 1.25,
            weights: ws(),
        };
        match round_trip(msg) {
            Msg::Submit { mode, base, accuracy, loss, weights } => {
                assert_eq!(mode, SubmitMode::Agwu);
                assert_eq!(base, 42);
                assert_eq!(accuracy, 0.75);
                assert_eq!(loss, 1.25);
                assert_eq!(weights.tensors()[0].shape(), &[2, 2]);
                let bits: Vec<u32> =
                    weights.tensors()[0].data().iter().map(|v| v.to_bits()).collect();
                let want: Vec<u32> =
                    ws().tensors()[0].data().iter().map(|v| v.to_bits()).collect();
                assert_eq!(bits, want);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn global_round_trips() {
        match round_trip(Msg::Global { version: 9, reassigned: vec![], weights: ws() }) {
            Msg::Global { version, reassigned, weights } => {
                assert_eq!(version, 9);
                assert!(reassigned.is_empty());
                assert_eq!(weights.param_count(), 4);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn global_round_trips_with_reassigned_ranges() {
        let ranges = vec![(100u64, 250u64), (900, 1000)];
        match round_trip(Msg::Global { version: 3, reassigned: ranges.clone(), weights: ws() }) {
            Msg::Global { version, reassigned, weights } => {
                assert_eq!(version, 3);
                assert_eq!(reassigned, ranges);
                assert_eq!(weights.param_count(), 4);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn inverted_reassigned_range_rejected() {
        let mut buf = Vec::new();
        write_msg(
            &mut buf,
            &Msg::Global { version: 1, reassigned: vec![(10, 4)], weights: ws() },
        )
        .unwrap();
        assert!(read_msg(&mut std::io::Cursor::new(buf)).is_err());
    }

    #[test]
    fn corrupt_frames_rejected() {
        let mut buf = Vec::new();
        write_msg(&mut buf, &Msg::Fetch).unwrap();
        // Truncated frame.
        let mut cur = std::io::Cursor::new(buf[..buf.len() - 1].to_vec());
        assert!(read_msg(&mut cur).is_err());
        // Unknown tag.
        let mut bad = buf.clone();
        bad[4] = 0xEE;
        assert!(read_msg(&mut std::io::Cursor::new(bad)).is_err());
        // Oversized declared length.
        let mut bad = buf;
        bad[0..4].copy_from_slice(&(MAX_FRAME as u32 + 1).to_le_bytes());
        assert!(read_msg(&mut std::io::Cursor::new(bad)).is_err());
    }
}
