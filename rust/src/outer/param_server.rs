//! The parameter server — global weight updating strategies (§3.3.2).
//!
//! * **SGWU** (Eq. 7): after all m nodes finish an epoch, the global set is
//!   the accuracy-weighted mean of the local sets.
//! * **AGWU** (Algorithm 3.2, Eqs. 9–10): a node's submission immediately
//!   produces a new global version: `W^(i) = W^(i−1) + γ·Q·(W_j^(k) − W^(k))`
//!   where `k` is the global version the node trained from and
//!   `γ_j^(k) = e^(k/(i−1)) / Σ_{j'≠j} e^(k_{j'}/(i−1))` attenuates stale
//!   updates.
//!
//! The server retains the recent version history so `(W_j^(k) − W^(k))` can
//! be formed for any base version still in flight.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::tensor::WeightSet;

/// Communication accounting — Eq. 11: every fetch and every submit moves one
/// weight set between a node and the server (`2·c_w·m·K` total).
#[derive(Debug, Clone, Default)]
pub struct CommStats {
    pub fetches: usize,
    pub submits: usize,
    pub bytes: u64,
    /// Submissions whose AGWU base version had already been evicted from the
    /// retained history (cap `2m+2`) and fell back to the oldest retained
    /// version — extreme stragglers. Nonzero values mean the increment was
    /// computed against an older base than the node actually trained from.
    pub evicted_base_fallbacks: usize,
    /// Bytes *actually moved* between endpoints (protocol frames included):
    /// measured by the transports, 0 for in-process runs where a transfer is
    /// an `Arc` refcount bump. Compare with `bytes`, the logical Eq. 11
    /// volume, to see what the deployment really pays.
    pub wire_bytes: u64,
    /// Measured wall seconds inside `Transport::fetch_global` across nodes.
    pub fetch_wall_s: f64,
    /// Measured wall seconds inside `Transport::submit` across nodes (for
    /// SGWU over TCP this includes the Eq. 8 barrier wait).
    pub submit_wall_s: f64,
    /// Measured wall seconds of endpoint setup (TCP connect + registration)
    /// across nodes — split out of the fetch/submit columns so stall
    /// attribution stays honest. 0 for in-process runs.
    pub connect_wall_s: f64,
}

impl CommStats {
    pub fn megabytes(&self) -> f64 {
        self.bytes as f64 / (1024.0 * 1024.0)
    }

    /// Measured Eq. 11 communication wall time (fetch + submit directions).
    pub fn comm_wall_s(&self) -> f64 {
        self.fetch_wall_s + self.submit_wall_s
    }

    /// Fold one endpoint's measured accounting into the server-side stats.
    /// Only the *measured* columns are absorbed — fetch/submit counts and
    /// logical bytes are already accounted server-side per operation.
    pub fn absorb_transport(&mut self, t: &crate::outer::transport::TransportStats) {
        self.wire_bytes += t.wire_bytes;
        self.fetch_wall_s += t.fetch_wall_s;
        self.submit_wall_s += t.submit_wall_s;
        self.connect_wall_s += t.connect_wall_s;
    }
}

/// The parameter server holding the global weight set (Definition 2).
///
/// Versions are immutable [`Arc`] snapshots: the history stores refcounted
/// handles, `fetch` hands out a refcount bump (workers deep-copy only when
/// they mutate), and each update pays exactly one weight-set copy (the new
/// version) instead of the old clone-per-fetch **and** clone-per-submit.
/// [`CommStats`] keeps accounting logical transfer sizes (Eq. 11), not
/// refcount traffic.
#[derive(Debug)]
pub struct ParamServer {
    global: Arc<WeightSet>,
    /// Current global version `i`.
    version: usize,
    /// Retained past versions for AGWU's `(W_j^(k) − W^(k))`.
    history: VecDeque<(usize, Arc<WeightSet>)>,
    history_cap: usize,
    /// Base version each node last fetched (k_{j'} in Eq. 9's denominator).
    node_base: Vec<usize>,
    /// Per-node SGWU round buffer: submissions arriving one at a time (the
    /// transport path) are held here until all m parts of the round exist.
    sgwu_pending: Vec<Option<(WeightSet, f64)>>,
    /// Nodes declared dead (lease expired / connection lost). Dead nodes
    /// leave Eq. 9's denominator and the Eq. 8 barrier quorum.
    dead: Vec<bool>,
    pub comm: CommStats,
}

impl ParamServer {
    pub fn new(init: WeightSet, nodes: usize) -> Self {
        Self::with_version(init, nodes, 0)
    }

    /// Resume constructor: start from a checkpointed global set at
    /// `version`, so AGWU base-version bookkeeping lines up with what
    /// reconnecting workers last fetched.
    pub fn with_version(init: WeightSet, nodes: usize, version: usize) -> Self {
        let global = Arc::new(init);
        let mut history = VecDeque::new();
        history.push_back((version, Arc::clone(&global)));
        Self {
            global,
            version,
            history,
            history_cap: 2 * nodes.max(1) + 2,
            node_base: vec![version; nodes],
            sgwu_pending: (0..nodes).map(|_| None).collect(),
            dead: vec![false; nodes],
            comm: CommStats::default(),
        }
    }

    pub fn version(&self) -> usize {
        self.version
    }

    pub fn global(&self) -> &WeightSet {
        self.global.as_ref()
    }

    /// The current global version as a shared snapshot (refcount bump, no
    /// copy) — e.g. for evaluation hooks that must not hold the server lock.
    pub fn global_arc(&self) -> Arc<WeightSet> {
        Arc::clone(&self.global)
    }

    pub fn nodes(&self) -> usize {
        self.node_base.len()
    }

    /// Nodes still counted live (Eq. 8 quorum / Eq. 9 denominator size).
    pub fn live_nodes(&self) -> usize {
        self.dead.iter().filter(|&&d| !d).count()
    }

    /// Declare `node` dead: it leaves the SGWU barrier quorum and Eq. 9's
    /// denominator. Returns true the first time (so callers count each
    /// death once); later calls are idempotent.
    pub fn mark_dead(&mut self, node: usize) -> bool {
        let first = !self.dead[node];
        self.dead[node] = true;
        first
    }

    /// Re-admit a previously dead node (reconnect with the same node id).
    pub fn revive(&mut self, node: usize) {
        self.dead[node] = false;
    }

    pub fn is_dead(&self, node: usize) -> bool {
        self.dead[node]
    }

    /// Whether `node` already contributed its part to the current SGWU
    /// round — a reconnect replaying its submission must be rejected, not
    /// double-counted.
    pub fn sgwu_has_part(&self, node: usize) -> bool {
        self.sgwu_pending[node].is_some()
    }

    /// Share the current global set with node `j` (counts communication,
    /// records the node's base version for staleness tracking). The
    /// returned snapshot is a refcount bump; a node that mutates it copies
    /// on write ([`Arc::try_unwrap`] succeeds without a copy once the
    /// server has evicted the version).
    pub fn fetch(&mut self, node: usize) -> (Arc<WeightSet>, usize) {
        self.node_base[node] = self.version;
        self.comm.fetches += 1;
        self.comm.bytes += self.global.byte_size() as u64;
        (Arc::clone(&self.global), self.version)
    }

    /// SGWU — Eq. 7: all m local sets + accuracies arrive together; the new
    /// global version is their accuracy-weighted mean. The server only
    /// reads the submitted sets — the cluster driver builds `locals` by
    /// **moving** each node's `EpochOutcome` weights into the slice's
    /// backing storage, so an SGWU round pays no weight-set clone beyond
    /// the Eq.-11 transfers it models.
    pub fn update_sgwu(&mut self, locals: &[(WeightSet, f64)]) -> usize {
        assert_eq!(locals.len(), self.nodes(), "SGWU needs all nodes");
        for (ws, _) in locals {
            self.comm.submits += 1;
            self.comm.bytes += ws.byte_size() as u64;
        }
        self.apply_sgwu(locals)
    }

    /// Eq. 7 proper, without communication accounting (the callers above and
    /// below count each part as it arrives). A full healthy round carries m
    /// parts; after a node death the surviving quorum's parts are averaged
    /// instead (`--on-failure continue`).
    fn apply_sgwu(&mut self, locals: &[(WeightSet, f64)]) -> usize {
        assert!(!locals.is_empty(), "SGWU round needs at least one part");
        let total_q: f64 = locals.iter().map(|(_, q)| q.max(1e-9)).sum();
        let mut new_global = self.global.zeros_like();
        for (ws, q) in locals {
            new_global.axpy((q.max(1e-9) / total_q) as f32, ws);
        }
        self.install(new_global)
    }

    /// One node's part of an SGWU round, arriving through a [`super::transport::Transport`].
    /// Buffered until all m parts of the round are present, then the round
    /// is installed in node order — numerically identical to a single
    /// [`ParamServer::update_sgwu`] call with the full slice. Returns the new
    /// version when this submission completed the round, `None` while the
    /// round is still filling.
    pub fn submit_sgwu(&mut self, node: usize, local: WeightSet, accuracy: f64) -> Option<usize> {
        self.comm.submits += 1;
        self.comm.bytes += local.byte_size() as u64;
        assert!(
            self.sgwu_pending[node].is_none(),
            "node {node} submitted twice in one SGWU round"
        );
        self.sgwu_pending[node] = Some((local, accuracy));
        self.sgwu_try_install()
    }

    /// Install the current SGWU round if its quorum is satisfied: every
    /// *live* node has contributed. Called by `submit_sgwu` on each part
    /// and by the server after a death shrinks the quorum (a round that was
    /// only waiting on the dead node must not hang forever). A healthy
    /// full round installs in node order — numerically identical to
    /// [`ParamServer::update_sgwu`] with the full slice.
    pub fn sgwu_try_install(&mut self) -> Option<usize> {
        let waiting = self
            .sgwu_pending
            .iter()
            .zip(self.dead.iter())
            .any(|(p, &dead)| p.is_none() && !dead);
        if waiting {
            return None;
        }
        // Parts from nodes that died *after* submitting still count — the
        // work is valid. An all-dead round with no parts installs nothing.
        let locals: Vec<(WeightSet, f64)> =
            self.sgwu_pending.iter_mut().filter_map(|p| p.take()).collect();
        if locals.is_empty() {
            return None;
        }
        Some(self.apply_sgwu(&locals))
    }

    /// Parts of the current SGWU round already buffered (server-side
    /// progress reporting).
    pub fn sgwu_round_fill(&self) -> usize {
        self.sgwu_pending.iter().filter(|p| p.is_some()).count()
    }

    /// Staleness attenuation γ_j^(k) — Eq. 9. `i` is the version the update
    /// will create; the denominator sums the staleness terms of the *other*
    /// nodes' current base versions.
    pub fn gamma(&self, node: usize, base_version: usize) -> f64 {
        let i = self.version + 1;
        let denom_scale = (i.saturating_sub(1)).max(1) as f64;
        let numer = (base_version as f64 / denom_scale).exp();
        let mut denom = 0.0;
        for (j, &k) in self.node_base.iter().enumerate() {
            if j == node || self.dead[j] {
                // Dead peers leave the denominator: their frozen base
                // versions would otherwise attenuate survivors forever.
                continue;
            }
            denom += (k as f64 / denom_scale).exp();
        }
        if denom <= 0.0 {
            1.0 // single-node (or sole-survivor) cluster: no attenuation
        } else {
            numer / denom
        }
    }

    /// Plain asynchronous update (DistBelief/Downpour-style baseline used by
    /// the Fig. 11 / Table 1 ablations): the increment is applied with a
    /// fixed 1/m scale — no staleness attenuation (γ≡1), no accuracy
    /// weighting (Q≡1).
    pub fn update_async_plain(
        &mut self,
        _node: usize,
        local: &WeightSet,
        base_version: usize,
    ) -> usize {
        self.comm.submits += 1;
        self.comm.bytes += local.byte_size() as u64;
        // Increment computed against a borrowed history entry — no copy, one
        // history scan (falls back to the oldest retained version, counted).
        let base = self.base_for(base_version);
        let mut increment = local.sub(base);
        increment.scale(1.0 / self.nodes() as f32);
        // One inherent copy: the new immutable version snapshot.
        let mut next = (*self.global).clone();
        next.axpy(1.0, &increment);
        self.install(next)
    }

    /// AGWU — Algorithm 3.2 / Eq. 10: apply one node's increment
    /// immediately. Returns the new global version.
    pub fn update_agwu(
        &mut self,
        node: usize,
        local: &WeightSet,
        base_version: usize,
        accuracy: f64,
    ) -> usize {
        self.comm.submits += 1;
        self.comm.bytes += local.byte_size() as u64;
        let gamma = self.gamma(node, base_version);
        // ΔW_j^{k→i} = γ_j^(k) · Q_j^(k) · (W_j^(k) − W^(k)), computed
        // against a borrowed history entry (no base copy — §Perf L3-1).
        let base = self.base_for(base_version);
        let mut increment = local.sub(base);
        increment.scale((gamma * accuracy.max(1e-9)) as f32);
        // One inherent copy: the new immutable version snapshot.
        let mut next = (*self.global).clone();
        next.axpy(1.0, &increment);
        self.install(next)
    }

    /// Install `ws` as the next global version. The history entry is a
    /// refcount bump on the same snapshot — versions are immutable, so one
    /// `Arc` serves the global pointer, the history window, and every
    /// outstanding fetch.
    fn install(&mut self, ws: WeightSet) -> usize {
        self.global = Arc::new(ws);
        self.version += 1;
        self.history.push_back((self.version, Arc::clone(&self.global)));
        while self.history.len() > self.history_cap {
            self.history.pop_front();
        }
        self.version
    }

    fn lookup(&self, version: usize) -> Option<&WeightSet> {
        self.history
            .iter()
            .find(|(v, _)| *v == version)
            .map(|(_, w)| w.as_ref())
    }

    /// Resolve an update's base weight set in one history scan. When the
    /// base version has been evicted from the window (cap `2m+2`) — an
    /// extreme straggler — the defined behavior is to fall back to the
    /// oldest retained version, recorded in `CommStats` so runs can audit
    /// how often it happens.
    fn base_for(&mut self, base_version: usize) -> &WeightSet {
        let idx = self.history.iter().position(|(v, _)| *v == base_version);
        match idx {
            Some(i) => self.history[i].1.as_ref(),
            None => {
                self.comm.evicted_base_fallbacks += 1;
                self.oldest_retained()
            }
        }
    }

    fn oldest_retained(&self) -> &WeightSet {
        self.history.front().expect("history never empty").1.as_ref()
    }

    /// Consume the server, moving the final global weight set out. Once the
    /// history window (the only other holder of the final version's `Arc`)
    /// is dropped, the unwrap is copy-free; a still-outstanding fetch
    /// snapshot degrades it to one clone rather than failing.
    pub fn into_global(mut self) -> WeightSet {
        self.history.clear();
        Arc::try_unwrap(self.global).unwrap_or_else(|shared| (*shared).clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn ws(vals: &[f32]) -> WeightSet {
        WeightSet::new(vec![Tensor::from_vec(&[vals.len()], vals.to_vec())])
    }

    fn v0(ps: &ParamServer) -> Vec<f32> {
        ps.global().tensors()[0].data().to_vec()
    }

    #[test]
    fn sgwu_equal_accuracy_is_mean() {
        let mut ps = ParamServer::new(ws(&[0.0, 0.0]), 2);
        let v = ps.update_sgwu(&[(ws(&[2.0, 0.0]), 0.5), (ws(&[0.0, 4.0]), 0.5)]);
        assert_eq!(v, 1);
        assert_eq!(v0(&ps), vec![1.0, 2.0]);
    }

    #[test]
    fn sgwu_weights_by_accuracy_eq7() {
        let mut ps = ParamServer::new(ws(&[0.0]), 2);
        // Q = (0.75, 0.25): W = 0.75·4 + 0.25·0 = 3.
        ps.update_sgwu(&[(ws(&[4.0]), 0.75), (ws(&[0.0]), 0.25)]);
        assert_eq!(v0(&ps), vec![3.0]);
    }

    #[test]
    fn agwu_applies_increment_eq10() {
        let mut ps = ParamServer::new(ws(&[1.0]), 1);
        let (w, k) = ps.fetch(0);
        assert_eq!(k, 0);
        // Node trains 1.0 → 3.0; single node ⇒ γ = 1; Q = 0.5. Mutating a
        // fetched snapshot copies on write (the server retains the Arc).
        let mut local = (*w).clone();
        local.tensors_mut()[0].data_mut()[0] = 3.0;
        let v = ps.update_agwu(0, &local, k, 0.5);
        assert_eq!(v, 1);
        // W = 1 + 1·0.5·(3−1) = 2.
        assert_eq!(v0(&ps), vec![2.0]);
    }

    #[test]
    fn agwu_stale_update_attenuated() {
        let mut ps = ParamServer::new(ws(&[0.0]), 3);
        // All three nodes fetch version 0.
        let (w0, k0) = ps.fetch(0);
        let (_, _) = ps.fetch(1);
        let (_, _) = ps.fetch(2);
        // Nodes 1 and 2 submit and refetch repeatedly → version advances,
        // their bases modernize; node 0 stays on version 0.
        for round in 0..4 {
            for node in [1usize, 2] {
                let (w, k) = ps.fetch(node);
                let mut local = (*w).clone();
                local.tensors_mut()[0].data_mut()[0] += 0.1;
                ps.update_agwu(node, &local, k, 0.8);
                let _ = round;
            }
        }
        let i = ps.version();
        assert!(i >= 8);
        // γ for the stale node (base 0) must be < γ for a fresh node.
        let g_stale = ps.gamma(0, k0);
        let g_fresh = ps.gamma(1, i);
        assert!(
            g_stale < g_fresh,
            "stale γ {g_stale} not attenuated vs fresh γ {g_fresh}"
        );
        // Stale submission still applies, scaled.
        let before = v0(&ps)[0];
        let mut local = (*w0).clone();
        local.tensors_mut()[0].data_mut()[0] = 100.0;
        ps.update_agwu(0, &local, k0, 1.0);
        let after = v0(&ps)[0];
        let delta = after - before;
        assert!(delta > 0.0 && delta < 100.0 * g_stale as f32 * 1.01);
    }

    #[test]
    fn gamma_normalizes_against_peer_staleness() {
        let mut ps = ParamServer::new(ws(&[0.0]), 2);
        // Advance to version 10 via node 1.
        for _ in 0..10 {
            let (w, k) = ps.fetch(1);
            ps.update_agwu(1, &w, k, 1.0);
        }
        // Node 0 fetched long ago (base 0); node 1's base is fresh.
        // For node 0: numer = e^0, denom = e^(k1/(i-1)) ≈ e^1 → γ ≈ 1/e.
        let g = ps.gamma(0, 0);
        assert!((g - (-1.0f64).exp()).abs() < 0.15, "γ={g}");
    }

    #[test]
    fn comm_accounting_eq11() {
        // 2 nodes, K=3 iterations of fetch+submit each ⇒ 2·m·K transfers.
        let mut ps = ParamServer::new(ws(&[0.0; 8]), 2);
        for _ in 0..3 {
            for node in 0..2 {
                let (w, k) = ps.fetch(node);
                ps.update_agwu(node, &w, k, 1.0);
            }
        }
        assert_eq!(ps.comm.fetches, 6);
        assert_eq!(ps.comm.submits, 6);
        // 12 transfers × 32 bytes.
        assert_eq!(ps.comm.bytes, 12 * 32);
    }

    #[test]
    fn history_pruned_but_recent_bases_resolvable() {
        let mut ps = ParamServer::new(ws(&[0.0]), 1);
        for _ in 0..50 {
            let (w, k) = ps.fetch(0);
            ps.update_agwu(0, &w, k, 1.0);
        }
        // History capacity is 2·1+2 = 4; old versions pruned.
        assert!(ps.history.len() <= 4);
        // A very stale base falls back to the oldest retained version
        // rather than panicking.
        let local = ws(&[1.0]);
        let v = ps.update_agwu(0, &local, 1, 1.0);
        assert_eq!(v, 51);
    }

    #[test]
    fn straggler_submitting_against_evicted_base_falls_back_and_is_logged() {
        // 2-node cluster → history cap 2·2+2 = 6. Node 1 fetches v0, then
        // node 0 races far ahead so v0 is evicted; node 1's late submission
        // must fall back to the oldest retained base (not panic) and be
        // counted in CommStats.
        let mut ps = ParamServer::new(ws(&[0.0]), 2);
        let (w_straggler, k_straggler) = ps.fetch(1);
        for _ in 0..20 {
            let (w, k) = ps.fetch(0);
            ps.update_agwu(0, &w, k, 1.0);
        }
        assert!(ps.history.len() <= 6);
        assert!(ps.lookup(k_straggler).is_none(), "base must be evicted for this test");
        assert_eq!(ps.comm.evicted_base_fallbacks, 0);
        let before = v0(&ps)[0];
        let mut local = (*w_straggler).clone();
        local.tensors_mut()[0].data_mut()[0] = before + 1.0;
        let v = ps.update_agwu(1, &local, k_straggler, 1.0);
        assert_eq!(v, 21);
        assert_eq!(ps.comm.evicted_base_fallbacks, 1);
        // A fresh-base submission does not bump the counter.
        let (w, k) = ps.fetch(0);
        ps.update_agwu(0, &w, k, 1.0);
        assert_eq!(ps.comm.evicted_base_fallbacks, 1);
    }

    #[test]
    fn plain_async_evicted_base_also_logged() {
        let mut ps = ParamServer::new(ws(&[0.0]), 1);
        let (w, k) = ps.fetch(0);
        for _ in 0..10 {
            let (wf, kf) = ps.fetch(0);
            ps.update_async_plain(0, &wf, kf);
        }
        assert!(ps.lookup(k).is_none());
        ps.update_async_plain(0, &w, k);
        assert_eq!(ps.comm.evicted_base_fallbacks, 1);
    }

    #[test]
    fn fetch_is_a_refcount_bump_not_a_copy() {
        let mut ps = ParamServer::new(ws(&[1.0, 2.0]), 2);
        let (a, _) = ps.fetch(0);
        let (b, _) = ps.fetch(1);
        assert!(Arc::ptr_eq(&a, &b), "fetches must share one snapshot");
        assert!(Arc::ptr_eq(&a, &ps.global_arc()));
        // An update installs a NEW snapshot; outstanding fetches keep the
        // old immutable version (and its byte accounting stayed logical).
        let bytes_per_transfer = a.byte_size() as u64;
        assert_eq!(ps.comm.bytes, 2 * bytes_per_transfer);
        ps.update_agwu(0, &a, 0, 1.0);
        assert!(!Arc::ptr_eq(&a, &ps.global_arc()));
        assert_eq!(a.tensors()[0].data(), &[1.0, 2.0]);
    }

    /// Part-wise SGWU submission (the transport path) must be numerically
    /// identical to the one-shot slice API, regardless of arrival order.
    #[test]
    fn submit_sgwu_parts_match_one_shot_update() {
        let locals = [(ws(&[2.0, 0.0]), 0.75), (ws(&[0.0, 4.0]), 0.25)];
        let mut one_shot = ParamServer::new(ws(&[0.0, 0.0]), 2);
        one_shot.update_sgwu(&locals);

        let mut parts = ParamServer::new(ws(&[0.0, 0.0]), 2);
        // Reverse arrival order: node 1 first, then node 0 completes.
        assert_eq!(parts.submit_sgwu(1, locals[1].0.clone(), locals[1].1), None);
        assert_eq!(parts.sgwu_round_fill(), 1);
        assert_eq!(parts.submit_sgwu(0, locals[0].0.clone(), locals[0].1), Some(1));
        assert_eq!(parts.sgwu_round_fill(), 0);
        assert_eq!(v0(&parts), v0(&one_shot));
        assert_eq!(parts.comm.submits, one_shot.comm.submits);
        assert_eq!(parts.comm.bytes, one_shot.comm.bytes);
        // The buffer resets — a second round works.
        assert_eq!(parts.submit_sgwu(0, ws(&[1.0, 1.0]), 1.0), None);
        assert_eq!(parts.submit_sgwu(1, ws(&[1.0, 1.0]), 1.0), Some(2));
    }

    #[test]
    #[should_panic(expected = "twice")]
    fn submit_sgwu_duplicate_node_panics() {
        let mut ps = ParamServer::new(ws(&[0.0]), 2);
        ps.submit_sgwu(0, ws(&[1.0]), 1.0);
        ps.submit_sgwu(0, ws(&[2.0]), 1.0);
    }

    /// `into_global` moves the final version out without a copy once history
    /// and fetches are gone, and degrades to a clone when a snapshot is
    /// still outstanding.
    #[test]
    fn into_global_moves_final_version() {
        let mut ps = ParamServer::new(ws(&[1.0, 2.0]), 1);
        let (w, k) = ps.fetch(0);
        ps.update_agwu(0, &w, k, 1.0);
        drop(w);
        let final_vals = v0(&ps);
        let out = ps.into_global();
        assert_eq!(out.tensors()[0].data(), &final_vals[..]);

        // Outstanding fetch: still correct, via a clone.
        let mut ps = ParamServer::new(ws(&[3.0]), 1);
        let (held, _) = ps.fetch(0);
        let out = ps.into_global();
        assert_eq!(out.tensors()[0].data(), &[3.0]);
        assert_eq!(held.tensors()[0].data(), &[3.0]);
    }

    #[test]
    fn sgwu_version_monotone() {
        let mut ps = ParamServer::new(ws(&[0.0]), 1);
        for i in 1..=5 {
            let v = ps.update_sgwu(&[(ws(&[i as f32]), 1.0)]);
            assert_eq!(v, i);
        }
    }

    #[test]
    fn dead_node_shrinks_sgwu_quorum() {
        let mut ps = ParamServer::new(ws(&[0.0, 0.0]), 3);
        assert_eq!(ps.submit_sgwu(0, ws(&[3.0, 0.0]), 0.5), None);
        assert_eq!(ps.submit_sgwu(1, ws(&[0.0, 3.0]), 0.5), None);
        // Node 2 dies; the round must complete with the two live parts.
        assert!(ps.mark_dead(2));
        assert!(!ps.mark_dead(2), "second death report is idempotent");
        assert_eq!(ps.live_nodes(), 2);
        assert_eq!(ps.sgwu_try_install(), Some(1));
        assert_eq!(v0(&ps), vec![1.5, 1.5]);
        // The next round only waits for the survivors.
        assert_eq!(ps.submit_sgwu(0, ws(&[1.0, 1.0]), 1.0), None);
        assert_eq!(ps.submit_sgwu(1, ws(&[1.0, 1.0]), 1.0), Some(2));
    }

    #[test]
    fn dead_node_part_already_submitted_still_counts() {
        let mut ps = ParamServer::new(ws(&[0.0]), 2);
        assert_eq!(ps.submit_sgwu(0, ws(&[4.0]), 0.5), None);
        assert!(ps.sgwu_has_part(0));
        // Node 0 dies after submitting; node 1's part completes the round
        // and node 0's valid work is still averaged in.
        ps.mark_dead(0);
        assert_eq!(ps.submit_sgwu(1, ws(&[2.0]), 0.5), Some(1));
        assert_eq!(v0(&ps), vec![3.0]);
    }

    #[test]
    fn all_dead_round_installs_nothing() {
        let mut ps = ParamServer::new(ws(&[0.0]), 2);
        ps.mark_dead(0);
        ps.mark_dead(1);
        assert_eq!(ps.sgwu_try_install(), None);
        assert_eq!(ps.version(), 0);
    }

    #[test]
    fn gamma_skips_dead_peers() {
        let mut ps = ParamServer::new(ws(&[0.0]), 3);
        // Advance so staleness matters; node 2 stays on base 0.
        for _ in 0..10 {
            let (w, k) = ps.fetch(1);
            ps.update_agwu(1, &w, k, 1.0);
        }
        let g_with_dead_peer = {
            let mut probe = ParamServer::new(ws(&[0.0]), 3);
            for _ in 0..10 {
                let (w, k) = probe.fetch(1);
                probe.update_agwu(1, &w, k, 1.0);
            }
            probe.mark_dead(2);
            probe.gamma(0, 0)
        };
        let g_all_live = ps.gamma(0, 0);
        // Node 2's frozen base-0 term inflated the live denominator; with
        // node 2 dead the attenuation must relax (γ grows).
        assert!(
            g_with_dead_peer > g_all_live,
            "dead peer still attenuates: {g_with_dead_peer} vs {g_all_live}"
        );
        // Sole survivor: no peers left, γ degrades to 1.
        let mut solo = ParamServer::new(ws(&[0.0]), 2);
        solo.mark_dead(1);
        assert_eq!(solo.gamma(0, 0), 1.0);
        // Revival restores the quorum.
        solo.revive(1);
        assert_eq!(solo.live_nodes(), 2);
        assert!(!solo.is_dead(1));
    }

    #[test]
    fn resume_constructor_restores_version() {
        let mut ps = ParamServer::with_version(ws(&[5.0]), 2, 17);
        assert_eq!(ps.version(), 17);
        assert_eq!(v0(&ps), vec![5.0]);
        let (_, k) = ps.fetch(0);
        assert_eq!(k, 17);
        let v = ps.update_agwu(0, &ws(&[6.0]), 17, 1.0);
        assert_eq!(v, 18);
    }
}
