//! Link cost model for weight-set traffic (§3.3.2(3), Fig. 15a).
//!
//! In the in-process cluster the "network" is a channel, so transfer *time*
//! is modelled (latency + bytes/bandwidth) while transfer *volume* is
//! accounted exactly by `ParamServer::comm` (Eq. 11).

/// Simple latency + bandwidth link model.
#[derive(Debug, Clone, Copy)]
pub struct TransferModel {
    pub bandwidth_bytes_per_s: f64,
    pub latency_s: f64,
}

impl TransferModel {
    pub fn new(bandwidth_bytes_per_s: f64, latency_s: f64) -> Self {
        assert!(bandwidth_bytes_per_s > 0.0);
        Self { bandwidth_bytes_per_s, latency_s }
    }

    /// Seconds to move `bytes` over the link.
    pub fn transfer_time(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bytes_per_s
    }

    /// Eq. 11 as time: 2·c_w·m·K where c_w is one weight-set transfer.
    pub fn total_update_time(&self, weight_bytes: usize, m: usize, k: usize) -> f64 {
        2.0 * self.transfer_time(weight_bytes) * m as f64 * k as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_components() {
        let m = TransferModel::new(1e6, 0.001);
        // 1 MB at 1 MB/s + 1 ms latency.
        assert!((m.transfer_time(1_000_000) - 1.001).abs() < 1e-9);
        assert!((m.transfer_time(0) - 0.001).abs() < 1e-12);
    }

    #[test]
    fn eq11_scaling() {
        let m = TransferModel::new(1e9, 0.0);
        let t1 = m.total_update_time(1000, 5, 10);
        let t2 = m.total_update_time(1000, 10, 10);
        let t3 = m.total_update_time(1000, 5, 20);
        assert!((t2 / t1 - 2.0).abs() < 1e-9, "linear in m");
        assert!((t3 / t1 - 2.0).abs() < 1e-9, "linear in K");
    }

    #[test]
    #[should_panic]
    fn zero_bandwidth_rejected() {
        TransferModel::new(0.0, 0.0);
    }
}
