//! Data partitioning & allocation — §3.3.1.
//!
//! * **IDPA** (Algorithm 3.1, Eqs. 2–6): the training set is partitioned in
//!   `A` incremental batches. Batch 1 is split proportionally to nominal
//!   CPU frequency μ_j (Eq. 2); each later batch is split so every node's
//!   *predicted* finish time for the next iteration equalizes (Eqs. 3–5),
//!   using measured per-sample times from the previous iteration.
//! * **UDPA** (§5.3.3 baseline): uniform split, all at once.
//!
//! Invariants (tested): batches 1..A−1 each conserve exactly ⌊N/A⌋ samples,
//! the final batch additionally absorbs the remainder N mod A (allocated by
//! the same predicted-finish-time rule), so Σ totals == N exactly;
//! allocations are non-negative.

/// Per-batch allocation state of the IDPA strategy.
#[derive(Debug, Clone)]
pub struct IdpaPartitioner {
    /// N — total samples to distribute.
    pub total_samples: usize,
    /// A — number of incremental batches.
    pub batches: usize,
    /// μ_j — nominal node frequencies (Eq. 2).
    freqs: Vec<f64>,
    /// n_j^(a) history: allocation[a][j].
    allocations: Vec<Vec<usize>>,
    /// Σ_a n_j^(a) so far.
    totals: Vec<usize>,
}

impl IdpaPartitioner {
    pub fn new(total_samples: usize, batches: usize, freqs: &[f64]) -> Self {
        assert!(batches >= 1, "A must be ≥ 1");
        assert!(!freqs.is_empty(), "need at least one node");
        assert!(freqs.iter().all(|&f| f > 0.0), "frequencies must be positive");
        Self {
            total_samples,
            batches,
            freqs: freqs.to_vec(),
            allocations: Vec::new(),
            totals: vec![0; freqs.len()],
        }
    }

    pub fn nodes(&self) -> usize {
        self.freqs.len()
    }

    /// ⌊N/A⌋ — samples distributed per non-final batch.
    pub fn batch_quota(&self) -> usize {
        self.total_samples / self.batches
    }

    /// Samples distributed in batch `a` (1-indexed): ⌊N/A⌋, plus the
    /// remainder N mod A folded into the final batch so no sample is
    /// silently dropped.
    pub fn quota_for_batch(&self, a: usize) -> usize {
        debug_assert!((1..=self.batches).contains(&a));
        let base = self.batch_quota();
        if a == self.batches {
            base + self.total_samples % self.batches
        } else {
            base
        }
    }

    /// Σ quota over batches 1..=a — the cumulative sample target after
    /// batch `a` (equals N when a == A).
    fn distributed_after(&self, a: usize) -> usize {
        let base = self.batch_quota();
        if a == self.batches {
            self.total_samples
        } else {
            a * base
        }
    }

    pub fn batches_done(&self) -> usize {
        self.allocations.len()
    }

    pub fn totals(&self) -> &[usize] {
        &self.totals
    }

    pub fn allocations(&self) -> &[Vec<usize>] {
        &self.allocations
    }

    /// First batch — Eq. 2: proportional to μ_j, remainder to node m.
    pub fn first_batch(&mut self) -> Vec<usize> {
        assert!(self.allocations.is_empty(), "first_batch called twice");
        let quota = self.quota_for_batch(1);
        let m = self.nodes();
        let total_freq: f64 = self.freqs.iter().sum();
        let mut alloc = vec![0usize; m];
        let mut assigned = 0usize;
        for j in 0..m - 1 {
            let n = ((quota as f64) * self.freqs[j] / total_freq).floor() as usize;
            alloc[j] = n;
            assigned += n;
        }
        alloc[m - 1] = quota - assigned; // Eq. 2's j = m case
        self.commit(alloc.clone());
        alloc
    }

    /// Batch a ≥ 2 — Eqs. 3–5: rebalance from measured per-sample times.
    ///
    /// `measured_times[j]` = T_j, the wall time node j took for its last
    /// iteration over its current `totals()[j]` samples.
    pub fn next_batch(&mut self, measured_times: &[f64]) -> Vec<usize> {
        let a = self.allocations.len() + 1;
        assert!(a >= 2, "call first_batch first");
        assert!(a <= self.batches, "all {} batches already allocated", self.batches);
        assert_eq!(measured_times.len(), self.nodes());
        let quota = self.quota_for_batch(a);
        let m = self.nodes();

        // t̄_j = T_j / n_j (average per-sample time on node j).
        let tbar: Vec<f64> = measured_times
            .iter()
            .zip(self.totals.iter())
            .map(|(&t, &n)| if n > 0 { t / n as f64 } else { t.max(1e-12) })
            .collect();
        // T_a per Eq. 3, but with the *harmonic* mean of t̄_j instead of the
        // paper's arithmetic mean: with the arithmetic mean, Σ_j T_a/t̄_j =
        // (⌊N/A⌋·a/m)·t̄·Σ 1/t̄_j ≥ ⌊N/A⌋·a (AM–HM inequality), so Eq. 5
        // systematically over-allocates nodes 1..m−1 and starves node m.
        // The harmonic mean makes Σ_j n'_j equal the cumulative target
        // exactly, which is the stated objective ("all nodes complete each
        // iteration as close as possible"). Documented in DESIGN.md §2. The
        // cumulative target includes the N mod A remainder on the final
        // batch, so the full schedule distributes exactly N samples.
        let h_mean = m as f64 / tbar.iter().map(|t| 1.0 / t).sum::<f64>();
        let t_a = self.distributed_after(a) as f64 * h_mean / m as f64;

        // n'_j = T_a / t̄_j (Eq. 4) → n_j^(a) = n'_j − Σ n_j^(a') (Eq. 5),
        // clamped at 0 (a node already over its equal-time share receives
        // nothing this batch), remainder to node m.
        let mut alloc = vec![0usize; m];
        let mut assigned = 0usize;
        for j in 0..m - 1 {
            let target = t_a / tbar[j];
            let n = (target - self.totals[j] as f64).floor().max(0.0) as usize;
            let n = n.min(quota - assigned); // cannot exceed this batch's quota
            alloc[j] = n;
            assigned += n;
        }
        alloc[m - 1] = quota - assigned;
        self.commit(alloc.clone());
        alloc
    }

    fn commit(&mut self, alloc: Vec<usize>) {
        for (t, &n) in self.totals.iter_mut().zip(alloc.iter()) {
            *t += n;
        }
        self.allocations.push(alloc);
    }

    /// Run the whole A-batch schedule against a performance oracle
    /// (`per_sample_time(j)` seconds) that stands in for measured T_j.
    /// Returns the final totals. This is what the simulator uses.
    pub fn run_with_oracle<F: Fn(usize) -> f64>(&mut self, per_sample_time: F) -> Vec<usize> {
        self.first_batch();
        for _ in 1..self.batches {
            let times: Vec<f64> = (0..self.nodes())
                .map(|j| per_sample_time(j) * self.totals[j].max(1) as f64)
                .collect();
            self.next_batch(&times);
        }
        self.totals.clone()
    }

    /// ΔK correction — Eq. 6: with incremental allocation the first A
    /// iterations only train N(A+1)/2 sample-visits, so the remaining
    /// iteration count grows: K' = K + A/2 − 1 total.
    pub fn corrected_iterations(&self, k: usize) -> usize {
        // K' = A + ΔK where ΔK = K − A/2 − 1  ⇒  K' = K + A/2 − 1.
        (k + self.batches / 2).saturating_sub(1).max(1)
    }
}

/// UDPA baseline: uniform one-shot split of N over m nodes.
pub fn udpa_partition(total_samples: usize, m: usize) -> Vec<usize> {
    assert!(m >= 1);
    let base = total_samples / m;
    let rem = total_samples % m;
    (0..m).map(|j| base + usize::from(j < rem)).collect()
}

/// Re-allocate a dead node's remaining sample ranges over the survivors,
/// proportionally to their measured throughput — the same
/// capacity-follows-measurement rule IDPA's Eq. 4 applies at batch
/// boundaries, reused as the failure-time scheduling event.
///
/// `ranges` are the dead node's unstarted sample ranges; `throughput[j]` is
/// survivor j's measured rate (samples/s or any proportional score). Every
/// sample is conserved exactly: the output's concatenated lengths sum to
/// the input's. Non-positive or all-zero throughputs degrade to an equal
/// split. Range boundaries are preserved (a range may be *split* across
/// survivors, but never merged), so each re-assigned piece still maps to a
/// contiguous run of the original IDPA batch.
pub fn reallocate(
    ranges: &[std::ops::Range<usize>],
    throughput: &[f64],
) -> Vec<Vec<std::ops::Range<usize>>> {
    let m = throughput.len();
    assert!(m >= 1, "need at least one survivor");
    let total: usize = ranges.iter().map(|r| r.len()).sum();
    let mut out = vec![Vec::new(); m];
    if total == 0 {
        return out;
    }
    let positive_sum: f64 = throughput.iter().filter(|&&t| t > 0.0).sum();
    let shares: Vec<f64> = if positive_sum > 0.0 {
        throughput.iter().map(|&t| t.max(0.0) / positive_sum).collect()
    } else {
        vec![1.0 / m as f64; m]
    };
    // Per-survivor sample quotas: floor of the proportional share, with the
    // remainder going to the largest shares first (exact conservation).
    let mut quotas: Vec<usize> = shares.iter().map(|s| (s * total as f64).floor() as usize).collect();
    let mut assigned: usize = quotas.iter().sum();
    let mut order: Vec<usize> = (0..m).collect();
    order.sort_by(|&a, &b| shares[b].partial_cmp(&shares[a]).unwrap_or(std::cmp::Ordering::Equal));
    let mut i = 0;
    while assigned < total {
        quotas[order[i % m]] += 1;
        assigned += 1;
        i += 1;
    }
    // Walk the ranges, carving each survivor's quota off the front.
    let mut pending = ranges.iter().cloned();
    let mut current: Option<std::ops::Range<usize>> = None;
    for (j, &quota) in quotas.iter().enumerate() {
        let mut need = quota;
        while need > 0 {
            let mut r = match current.take().or_else(|| pending.next()) {
                Some(r) if !r.is_empty() => r,
                Some(_) => continue,
                None => unreachable!("quotas sum to the total sample count"),
            };
            let take = need.min(r.len());
            out[j].push(r.start..r.start + take);
            r.start += take;
            need -= take;
            if !r.is_empty() {
                current = Some(r);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_batch_proportional_to_frequency() {
        let mut p = IdpaPartitioner::new(1000, 2, &[1.0, 1.0, 2.0]);
        let alloc = p.first_batch();
        assert_eq!(alloc.iter().sum::<usize>(), 500);
        // Node 2 has half the total frequency → ~250 of 500.
        assert_eq!(alloc[2], 500 - alloc[0] - alloc[1]);
        assert!((alloc[2] as i64 - 250).abs() <= 2, "{alloc:?}");
        assert!((alloc[0] as i64 - 125).abs() <= 2);
    }

    #[test]
    fn every_batch_conserves_quota() {
        let mut p = IdpaPartitioner::new(10_000, 5, &[2.0, 3.0, 1.5, 2.5]);
        p.first_batch();
        for a in 1..5 {
            let times: Vec<f64> = p
                .totals()
                .iter()
                .enumerate()
                .map(|(j, &n)| n as f64 * (0.5 + j as f64 * 0.3))
                .collect();
            let alloc = p.next_batch(&times);
            assert_eq!(alloc.iter().sum::<usize>(), p.batch_quota(), "batch {a}");
        }
        assert_eq!(p.totals().iter().sum::<usize>(), 5 * (10_000 / 5));
    }

    #[test]
    fn faster_nodes_get_more_samples() {
        // Node 0 is 4× faster (per-sample time 4× smaller).
        let mut p = IdpaPartitioner::new(8_000, 4, &[2.0, 2.0]);
        let totals = p.run_with_oracle(|j| if j == 0 { 0.001 } else { 0.004 });
        assert!(totals[0] > totals[1] * 2, "{totals:?}");
        assert_eq!(totals.iter().sum::<usize>(), 8_000);
    }

    #[test]
    fn equal_speed_converges_to_equal_split() {
        let mut p = IdpaPartitioner::new(9_000, 3, &[1.0, 2.0, 3.0]);
        // Frequencies differ but *measured* speed is equal → later batches
        // must pull allocations back toward uniform.
        let totals = p.run_with_oracle(|_| 0.002);
        let spread = totals.iter().max().unwrap() - totals.iter().min().unwrap();
        assert!(spread < 900, "totals did not rebalance: {totals:?}");
    }

    #[test]
    fn finish_times_equalize_after_rebalancing() {
        // The IDPA objective: all nodes complete each iteration in nearly
        // the same time (§3.3.1).
        let speeds = [0.001, 0.002, 0.003, 0.0015];
        let mut p = IdpaPartitioner::new(40_000, 8, &[2.8, 2.0, 1.6, 2.4]);
        let totals = p.run_with_oracle(|j| speeds[j]);
        let times: Vec<f64> = totals.iter().zip(speeds.iter()).map(|(&n, &s)| n as f64 * s).collect();
        let balance = crate::util::stats::balance_index(&times);
        assert!(balance > 0.9, "finish times unbalanced: {times:?} (balance {balance})");
    }

    #[test]
    fn remainder_folded_into_final_batch_conserves_n() {
        // N = 10_007, A = 5 → base quota 2001, remainder 2 lands in batch 5.
        let mut p = IdpaPartitioner::new(10_007, 5, &[2.0, 3.0, 1.5, 2.5]);
        let totals = p.run_with_oracle(|j| 0.001 * (1.0 + j as f64));
        assert_eq!(totals.iter().sum::<usize>(), 10_007, "Σ totals == N");
        for (a, batch) in p.allocations().iter().enumerate() {
            let expect = if a == 4 { 2001 + 2 } else { 2001 };
            assert_eq!(batch.iter().sum::<usize>(), expect, "batch {}", a + 1);
        }
    }

    #[test]
    fn single_batch_distributes_everything() {
        // A = 1 previously dropped N mod 1 = 0, but A = 3 with N = 100
        // dropped 1 sample; both must now conserve N exactly.
        let mut p = IdpaPartitioner::new(100, 3, &[1.0, 1.0]);
        let totals = p.run_with_oracle(|_| 0.001);
        assert_eq!(totals.iter().sum::<usize>(), 100);
        let mut p1 = IdpaPartitioner::new(77, 1, &[1.0, 2.0, 3.0]);
        let alloc = p1.first_batch();
        assert_eq!(alloc.iter().sum::<usize>(), 77);
    }

    #[test]
    fn corrected_iterations_eq6() {
        let p = IdpaPartitioner::new(100, 6, &[1.0]);
        // K' = K + A/2 − 1 = 20 + 3 − 1 = 22.
        assert_eq!(p.corrected_iterations(20), 22);
    }

    #[test]
    fn udpa_uniform() {
        assert_eq!(udpa_partition(10, 3), vec![4, 3, 3]);
        assert_eq!(udpa_partition(9, 3), vec![3, 3, 3]);
        assert_eq!(udpa_partition(2, 4), vec![1, 1, 0, 0]);
        assert_eq!(udpa_partition(600_000, 30).iter().sum::<usize>(), 600_000);
    }

    #[test]
    #[should_panic(expected = "first_batch called twice")]
    fn first_batch_only_once() {
        let mut p = IdpaPartitioner::new(100, 2, &[1.0, 1.0]);
        p.first_batch();
        p.first_batch();
    }

    #[test]
    #[should_panic(expected = "already allocated")]
    fn cannot_exceed_batch_count() {
        let mut p = IdpaPartitioner::new(100, 2, &[1.0, 1.0]);
        p.first_batch();
        p.next_batch(&[1.0, 1.0]);
        p.next_batch(&[1.0, 1.0]);
    }

    fn total_len(parts: &[Vec<std::ops::Range<usize>>]) -> usize {
        parts.iter().flatten().map(|r| r.len()).sum()
    }

    #[test]
    fn reallocate_conserves_every_sample() {
        let ranges = vec![100..250, 400..401, 900..1000];
        let parts = reallocate(&ranges, &[3.0, 1.0, 2.0]);
        assert_eq!(parts.len(), 3);
        assert_eq!(total_len(&parts), 251);
        // Re-assigned pieces tile the original ranges exactly: sorted by
        // start, they reproduce the input sample set.
        let mut all: Vec<std::ops::Range<usize>> = parts.iter().flatten().cloned().collect();
        all.sort_by_key(|r| r.start);
        let covered: Vec<usize> = all.iter().flat_map(|r| r.clone()).collect();
        let expect: Vec<usize> = ranges.iter().flat_map(|r| r.clone()).collect();
        assert_eq!(covered, expect);
    }

    #[test]
    fn reallocate_follows_measured_throughput() {
        let parts = reallocate(&[0..1000], &[3.0, 1.0]);
        let n0 = total_len(&parts[..1]);
        assert!((740..=760).contains(&n0), "fast survivor got {n0}/1000");
    }

    #[test]
    fn reallocate_zero_throughput_degrades_to_equal_split() {
        let parts = reallocate(&[0..90], &[0.0, 0.0, -1.0]);
        let sizes: Vec<usize> = parts.iter().map(|p| p.iter().map(|r| r.len()).sum()).collect();
        assert_eq!(sizes, vec![30, 30, 30]);
    }

    #[test]
    fn reallocate_empty_input_yields_empty_parts() {
        let parts = reallocate(&[], &[1.0, 2.0]);
        assert!(parts.iter().all(|p| p.is_empty()));
        let parts = reallocate(&[5..5], &[1.0]);
        assert!(parts[0].is_empty());
    }

    #[test]
    fn reallocate_single_survivor_absorbs_everything_intact() {
        // One survivor left: it inherits every range, with the original
        // batch boundaries preserved (no splits are needed).
        let ranges = vec![10..25, 40..41, 100..163];
        let parts = reallocate(&ranges, &[0.37]);
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0], ranges);
        // Even a zero-throughput lone survivor must take the load — there
        // is nobody else.
        let parts = reallocate(&ranges, &[0.0]);
        assert_eq!(total_len(&parts), 15 + 1 + 63);
    }

    #[test]
    fn reallocate_zero_throughput_survivor_among_positive_peers_gets_nothing() {
        // A survivor with no measured progress (never completed an epoch)
        // has share 0 when any peer has positive throughput: all samples
        // go to the nodes demonstrably making progress.
        let parts = reallocate(&[0..100], &[0.0, 2.0, 0.0, 3.0]);
        let sizes: Vec<usize> = parts.iter().map(|p| p.iter().map(|r| r.len()).sum()).collect();
        assert_eq!(sizes[0], 0);
        assert_eq!(sizes[2], 0);
        assert_eq!(sizes[1] + sizes[3], 100);
        assert_eq!(sizes[1], 40, "2:3 throughput split of 100");
    }

    #[test]
    fn reallocate_floor_quotas_send_remainder_to_largest_shares() {
        // shares 0.5/0.25/0.25 of 11 → floors 5/2/2 (Σ=9), the 2-sample
        // remainder lands on the largest shares first: 6/3/2.
        let parts = reallocate(&[0..11], &[2.0, 1.0, 1.0]);
        let sizes: Vec<usize> = parts.iter().map(|p| p.iter().map(|r| r.len()).sum()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 11, "floor+remainder conserves N");
        assert_eq!(sizes[0], 6, "largest share takes the first remainder sample");
        assert!(sizes[1] + sizes[2] == 5 && sizes[1] >= 2 && sizes[2] >= 2, "{sizes:?}");
    }

    #[test]
    fn reallocate_simultaneous_multi_node_death_conserves_disjointly() {
        // Two nodes die at once: the server re-allocates each dead node's
        // remaining ranges in separate calls against the same survivor set
        // (exactly what `declare_dead` does). The union must conserve every
        // sample and assign no sample twice.
        let dead_a = vec![0..37, 80..110];
        let dead_b = vec![200..275, 300..301];
        let throughput = [1.7, 0.9, 2.4];
        let parts_a = reallocate(&dead_a, &throughput);
        let parts_b = reallocate(&dead_b, &throughput);
        let total = total_len(&parts_a) + total_len(&parts_b);
        assert_eq!(total, (37 + 30) + (75 + 1));
        let mut covered: Vec<usize> = parts_a
            .iter()
            .chain(parts_b.iter())
            .flatten()
            .flat_map(|r| r.clone())
            .collect();
        covered.sort_unstable();
        let mut expect: Vec<usize> = dead_a
            .iter()
            .chain(dead_b.iter())
            .flat_map(|r| r.clone())
            .collect();
        expect.sort_unstable();
        assert_eq!(covered, expect, "no sample lost, none duplicated");
    }
}
