//! Computing-node worker (§3.2.2): owns a growing data shard (IDPA batches),
//! trains the local weight set for one epoch at a time, and reports the
//! outcome to the cluster driver.

use std::ops::Range;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::config::NetworkConfig;
use crate::data::Dataset;
use crate::inner::{parallel_train_step, AutoTuner, TilePolicy};
use crate::nn::{Network, StepWorkspace, WeightPacks};
use crate::tensor::WeightSet;
use crate::util::threadpool::ThreadPool;

use super::pipeline::{pipeline, AckRecord, Staleness};
use super::transport::{SubmitMeta, SubmitMode, Transport, TransportStats};

/// Result of one local epoch (one "iteration" in the paper's terms: a full
/// pass over the node's current subset, updating the local weight set after
/// every sample batch — Fig. 4).
#[derive(Debug, Clone)]
pub struct EpochOutcome {
    pub weights: WeightSet,
    /// Mean training loss over the epoch.
    pub loss: f64,
    /// Training accuracy Q_j of Eq. 7 / Eq. 10 (fraction correct).
    pub accuracy: f64,
    pub samples: usize,
    /// Pure compute seconds (excludes communication).
    pub compute_s: f64,
}

/// A node-local trainer: the compute side of a worker. Implementations:
/// [`NativeTrainer`] (pure Rust) and `runtime::XlaTrainer` (PJRT artifacts).
pub trait LocalTrainer: Send {
    /// Train one epoch over the current shard starting from `start` — a
    /// shared parameter-server snapshot ([`crate::outer::ParamServer::fetch`]
    /// is a refcount bump). Implementations copy-on-write: `Arc::try_unwrap`
    /// succeeds copy-free when the server has already evicted the version.
    fn train_epoch(&mut self, start: Arc<WeightSet>) -> EpochOutcome;
    /// IDPA incremental allocation: extend the shard with dataset indices.
    fn add_samples(&mut self, range: Range<usize>);
    fn sample_count(&self) -> usize;
}

/// Pure-Rust local trainer over the native network. Owns a persistent
/// [`StepWorkspace`] plus gather buffers, so every epoch after the first
/// runs its batches allocation-free (the `alloc_regression` integration
/// test pins the per-step property), and the node's generation-keyed
/// [`WeightPacks`] cache — SGWU/AGWU spawn a fresh [`Network`] per epoch,
/// so the cache is moved into each one and recovered afterwards: packs for
/// an unchanged weight generation are never rebuilt, and stale ones repack
/// in place into the carried allocations. The node's [`AutoTuner`] rides
/// the same carry ([`Network::take_tuner`]): when the trainer drives the
/// inner-layer pool ([`NativeTrainer::with_pool`]), pool calibration and
/// per-stage locked tile plans survive across every epoch the node runs
/// instead of re-exploring inside each one.
pub struct NativeTrainer {
    cfg: NetworkConfig,
    data: Arc<Dataset>,
    indices: Vec<usize>,
    lr: f32,
    /// Artificial slowdown factor ≥ 1.0 emulating a slower node (in-process
    /// heterogeneity): the worker sleeps (factor−1)× its compute time.
    pub slowdown: f64,
    /// Reused across every batch of every epoch this worker runs.
    ws: StepWorkspace,
    /// Node-owned pack cache, carried across the per-epoch `Network`s.
    packs: WeightPacks,
    /// Node-owned stage autotuner, carried the same way.
    tuner: AutoTuner,
    /// Inner-layer pool: when set, epochs run [`parallel_train_step`]
    /// under `policy` instead of the serial workspace step.
    pool: Option<Arc<ThreadPool>>,
    policy: TilePolicy,
    xbuf: Vec<f32>,
    ybuf: Vec<f32>,
}

impl NativeTrainer {
    pub fn new(cfg: &NetworkConfig, data: Arc<Dataset>, lr: f32) -> Self {
        Self {
            cfg: cfg.clone(),
            data,
            indices: Vec::new(),
            lr,
            slowdown: 1.0,
            ws: StepWorkspace::new(),
            packs: WeightPacks::default(),
            tuner: AutoTuner::default(),
            pool: None,
            policy: TilePolicy::auto(1),
            xbuf: Vec::new(),
            ybuf: Vec::new(),
        }
    }

    pub fn with_slowdown(mut self, factor: f64) -> Self {
        assert!(factor >= 1.0);
        self.slowdown = factor;
        self
    }

    /// Run this node's epochs through the inner-layer task scheduler on
    /// `pool`, with `TilePolicy::Auto` grids: the pool is calibrated once,
    /// and each stage's tile plan adapts online and stays locked across
    /// epochs (the tuner is node state, like the pack cache).
    pub fn with_pool(self, pool: Arc<ThreadPool>) -> Self {
        let rows = (self.cfg.input_hw / 2).max(1);
        self.with_pool_policy(pool, TilePolicy::auto(rows))
    }

    /// [`NativeTrainer::with_pool`] with an explicit tile policy (benches
    /// compare `RowsOnly` / `Grid2d` / `Auto` epochs).
    pub fn with_pool_policy(mut self, pool: Arc<ThreadPool>, policy: TilePolicy) -> Self {
        self.pool = Some(pool);
        self.policy = policy;
        self
    }

    /// Number of stages the node's autotuner has accumulated plans for.
    pub fn tuned_stages(&self) -> usize {
        self.tuner.len()
    }

    /// The node's per-stage tuning table (debugging / logs).
    pub fn tuning_report(&self) -> String {
        self.tuner.table()
    }

    /// Gather a batch (x, one-hot y) from shard-local positions, wrapping,
    /// into reusable buffers.
    fn gather_into(
        data: &Dataset,
        indices: &[usize],
        offset: usize,
        bsz: usize,
        x: &mut Vec<f32>,
        y: &mut Vec<f32>,
    ) {
        let classes = data.num_classes;
        x.clear();
        y.clear();
        y.resize(bsz * classes, 0.0);
        for i in 0..bsz {
            let idx = indices[(offset + i) % indices.len()];
            x.extend_from_slice(&data.images[idx]);
            y[i * classes + data.labels[idx]] = 1.0;
        }
    }
}

impl LocalTrainer for NativeTrainer {
    fn train_epoch(&mut self, start: Arc<WeightSet>) -> EpochOutcome {
        assert!(!self.indices.is_empty(), "worker has no samples (allocate first)");
        let t0 = Instant::now();
        // Copy-on-write: unwrap the snapshot without a copy when this worker
        // holds the last reference, deep-copy otherwise.
        let start = Arc::try_unwrap(start).unwrap_or_else(|shared| (*shared).clone());
        // Hand the node's pack cache and autotuner to this epoch's network
        // (both recovered below): unchanged weight generations skip
        // repacking entirely, changed ones repack in place into the carried
        // allocations, and tuned tile plans stay locked across epochs.
        let mut net = Network::with_node_state(
            &self.cfg,
            start,
            std::mem::take(&mut self.packs),
            std::mem::take(&mut self.tuner),
        );
        let bsz = self.cfg.batch_size.min(self.indices.len().max(1));
        let mut seen = 0usize;
        let mut loss_sum = 0.0f64;
        let mut correct = 0usize;
        let mut batches = 0usize;
        while seen < self.indices.len() {
            let take = bsz.min(self.indices.len() - seen);
            // Gather a full `bsz` batch (wrapping) so the XLA path's fixed
            // batch shape and the native path behave identically.
            Self::gather_into(
                &self.data,
                &self.indices,
                seen,
                bsz,
                &mut self.xbuf,
                &mut self.ybuf,
            );
            let (l, c) = match &self.pool {
                Some(pool) => {
                    let r = parallel_train_step(
                        pool,
                        &mut net,
                        &self.xbuf,
                        &self.ybuf,
                        bsz,
                        self.lr,
                        self.policy,
                        &mut self.ws,
                    );
                    (r.loss, r.correct)
                }
                None => net.train_batch_ws(&self.xbuf, &self.ybuf, bsz, self.lr, &mut self.ws),
            };
            loss_sum += l as f64;
            correct += c.min(take);
            seen += take;
            batches += 1;
        }
        let compute = t0.elapsed().as_secs_f64();
        if self.slowdown > 1.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(
                compute * (self.slowdown - 1.0),
            ));
        }
        // Recover the pack cache and tuner for the next epoch on this node.
        self.packs = net.take_packs();
        self.tuner = net.take_tuner();
        EpochOutcome {
            weights: net.weights,
            loss: loss_sum / batches.max(1) as f64,
            accuracy: correct as f64 / self.indices.len() as f64,
            samples: self.indices.len(),
            compute_s: t0.elapsed().as_secs_f64(),
        }
    }

    fn add_samples(&mut self, range: Range<usize>) {
        self.indices.extend(range);
    }

    fn sample_count(&self) -> usize {
        self.indices.len()
    }
}

/// Summary of one node's run against a parameter server (local or remote).
#[derive(Debug, Clone)]
pub struct WorkerRunSummary {
    pub iterations: usize,
    /// Server version after this node's last submission.
    pub final_version: usize,
    pub last_loss: f64,
    pub last_accuracy: f64,
    /// Pure local-training wall seconds (excludes fetch/submit).
    pub busy_s: f64,
    /// Largest `last_acked − snapshot_version` gap actually trained on
    /// (0 for the serialized loop — it always trains on the version it
    /// just fetched).
    pub max_staleness: usize,
    /// Prefetched snapshots discarded for violating the staleness bound.
    pub staleness_refetches: usize,
    /// Acknowledged submissions in ack order (version + local loss/acc).
    pub ack_log: Vec<AckRecord>,
    /// This endpoint's measured communication accounting. `stall_wall_s`
    /// is comm time on the worker's critical path; `overlap_wall_s` is
    /// comm time hidden behind training by the pipelined driver.
    pub stats: TransportStats,
}

/// Drive one node's fetch → train → submit loop over any [`Transport`] —
/// the same loop `run_async`'s in-process threads execute, reusable against
/// a remote server through `TcpTransport` (the `bptcnn worker` subcommand).
/// In SGWU mode the Eq. 8 barrier is the transport's blocking submit: the
/// call does not return until the server installed the whole round.
///
/// `Staleness(0)` runs the literal serialized loop — bit-identical to the
/// pre-pipeline behavior (pinned by test). `Staleness(s ≥ 1)` moves all
/// transport calls onto a comm thread ([`super::pipeline`]): the next
/// snapshot prefetches and the sealed delta pushes while training runs,
/// with the worker blocking only when a snapshot would be more than `s`
/// versions behind the newest acked server version.
pub fn drive_worker(
    transport: &mut dyn Transport,
    trainer: &mut dyn LocalTrainer,
    schedule: &[Range<usize>],
    iterations: usize,
    mode: SubmitMode,
    staleness: Staleness,
    verbose: bool,
) -> Result<WorkerRunSummary> {
    if staleness.is_pipelined() {
        drive_worker_pipelined(transport, trainer, schedule, iterations, mode, staleness, verbose)
    } else {
        drive_worker_serialized(transport, trainer, schedule, iterations, mode, verbose)
    }
}

/// The PR-6 serialized loop, unchanged in call sequence: every transport
/// wall second sits on the critical path and is accounted as stall.
fn drive_worker_serialized(
    transport: &mut dyn Transport,
    trainer: &mut dyn LocalTrainer,
    schedule: &[Range<usize>],
    iterations: usize,
    mode: SubmitMode,
    verbose: bool,
) -> Result<WorkerRunSummary> {
    let mut busy = 0.0f64;
    let mut stall = 0.0f64;
    let mut last_loss = f64::NAN;
    let mut last_accuracy = 0.0f64;
    let mut final_version = 0usize;
    let mut ack_log = Vec::with_capacity(iterations);
    for iter in 0..iterations {
        // IDPA incremental allocation (batch `iter` of this node's column).
        if iter < schedule.len() {
            trainer.add_samples(schedule[iter].clone());
        }
        let t = Instant::now();
        let (global, base) = transport.fetch_global()?;
        stall += t.elapsed().as_secs_f64();
        // Absorb any IDPA batches the server re-allocated from a dead peer.
        for r in transport.take_reassigned() {
            trainer.add_samples(r);
        }
        let t = Instant::now();
        let out = trainer.train_epoch(global);
        busy += t.elapsed().as_secs_f64();
        last_loss = out.loss;
        last_accuracy = out.accuracy;
        let meta = SubmitMeta {
            mode,
            base,
            accuracy: out.accuracy,
            loss: out.loss,
            want_snapshot: false,
        };
        let t = Instant::now();
        let ack = transport.submit(out.weights, &meta)?;
        stall += t.elapsed().as_secs_f64();
        final_version = ack.version;
        ack_log.push(AckRecord {
            version: ack.version,
            loss: out.loss,
            accuracy: out.accuracy,
            at: Instant::now(),
        });
        if verbose {
            eprintln!(
                "worker: iter {iter} -> v{final_version} loss {last_loss:.4} acc {last_accuracy:.3}"
            );
        }
    }
    transport.finish()?;
    let mut stats = transport.stats();
    stats.stall_wall_s += stall;
    Ok(WorkerRunSummary {
        iterations,
        final_version,
        last_loss,
        last_accuracy,
        busy_s: busy,
        max_staleness: 0,
        staleness_refetches: 0,
        ack_log,
        stats,
    })
}

/// The pipelined loop: the comm thread owns the transport; the worker
/// thread swaps prefetched `Arc<WeightSet>` generations at epoch
/// boundaries and seals each epoch's delta into an async push.
fn drive_worker_pipelined(
    transport: &mut dyn Transport,
    trainer: &mut dyn LocalTrainer,
    schedule: &[Range<usize>],
    iterations: usize,
    mode: SubmitMode,
    staleness: Staleness,
    verbose: bool,
) -> Result<WorkerRunSummary> {
    std::thread::scope(|scope| {
        let (mut pipe, comm) = pipeline(staleness);
        let comm_handle = scope.spawn(move || {
            let result = comm.run(&mut *transport);
            (result, transport.stats())
        });

        let mut busy = 0.0f64;
        let mut last_loss = f64::NAN;
        let mut last_accuracy = 0.0f64;
        // Drive the loop in a closure so an early error still tears the
        // pipeline down (dropping `pipe` hangs up the command channel and
        // the comm thread closes the transport on its own).
        let mut run = || -> Result<()> {
            // Initial snapshot: nothing to overlap yet, a pure stall.
            let mut current = Some(pipe.take_snapshot()?);
            for iter in 0..iterations {
                if iter < schedule.len() {
                    trainer.add_samples(schedule[iter].clone());
                }
                // Double buffer: the next generation's fetch runs on the
                // comm thread while this epoch trains. Queued before the
                // epoch's submit, so FIFO keeps at most one submit in
                // flight and never reorders the wire protocol.
                let last_iter = iter + 1 == iterations;
                if !last_iter {
                    pipe.prefetch()?;
                }
                let (snapshot, base) = current.take().expect("snapshot swapped in");
                // Absorb any IDPA batches the server re-allocated from a
                // dead peer (piggybacked on the fetch behind this snapshot).
                for r in pipe.take_reassigned() {
                    trainer.add_samples(r);
                }
                let t = Instant::now();
                let out = trainer.train_epoch(snapshot);
                busy += t.elapsed().as_secs_f64();
                last_loss = out.loss;
                last_accuracy = out.accuracy;
                let meta = SubmitMeta {
                    mode,
                    base,
                    accuracy: out.accuracy,
                    loss: out.loss,
                    want_snapshot: false,
                };
                pipe.submit_async(out.weights, meta)?;
                if verbose {
                    eprintln!(
                        "worker: iter {iter} async push from v{base} \
                         loss {last_loss:.4} acc {last_accuracy:.3}"
                    );
                }
                if !last_iter {
                    // Swap generations (blocks only for the residual wait
                    // the prefetch could not hide, or a staleness refetch).
                    current = Some(pipe.take_snapshot()?);
                }
            }
            Ok(())
        };
        let run_result = run();
        let acct = match run_result {
            Ok(()) => pipe.finish()?,
            Err(e) => {
                drop(pipe.abandon());
                // Surface the comm thread's error if it has one — it is
                // usually the root cause of the channel hangup.
                let (comm_result, _) = comm_handle.join().expect("comm thread panicked");
                comm_result?;
                return Err(e);
            }
        };
        let (comm_result, inner_stats) = comm_handle.join().expect("comm thread panicked");
        comm_result?;

        let mut stats = inner_stats;
        stats.stall_wall_s += acct.stall_s;
        stats.overlap_wall_s +=
            (inner_stats.fetch_wall_s + inner_stats.submit_wall_s - acct.stall_s).max(0.0);
        stats.max_inflight = stats.max_inflight.max(acct.max_inflight);
        Ok(WorkerRunSummary {
            iterations,
            final_version: acct.acks.last().map(|a| a.version).unwrap_or(0),
            last_loss,
            last_accuracy,
            busy_s: busy,
            max_staleness: acct.max_staleness,
            staleness_refetches: acct.refetches,
            ack_log: acct.acks,
            stats,
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (NetworkConfig, Arc<Dataset>) {
        let cfg = NetworkConfig::quickstart();
        let ds = Arc::new(Dataset::synthetic(&cfg, 64, 0.2, 21));
        (cfg, ds)
    }

    #[test]
    fn epoch_trains_and_reports() {
        let (cfg, ds) = setup();
        let mut w = NativeTrainer::new(&cfg, ds, 0.2);
        w.add_samples(0..32);
        assert_eq!(w.sample_count(), 32);
        let start = Network::init(&cfg, 1).weights;
        let out = w.train_epoch(Arc::new(start.clone()));
        assert_eq!(out.samples, 32);
        assert!(out.loss > 0.0);
        assert!((0.0..=1.0).contains(&out.accuracy));
        // Weights actually moved.
        assert!(out.weights.max_abs_diff(&start) > 0.0);
    }

    #[test]
    fn repeated_epochs_reduce_loss() {
        let (cfg, ds) = setup();
        let mut w = NativeTrainer::new(&cfg, ds, 0.3);
        w.add_samples(0..32);
        let mut weights = Network::init(&cfg, 2).weights;
        let mut losses = Vec::new();
        for _ in 0..8 {
            let out = w.train_epoch(Arc::new(weights));
            weights = out.weights.clone();
            losses.push(out.loss);
        }
        assert!(
            losses.last().unwrap() < &(0.8 * losses[0]),
            "no improvement: {losses:?}"
        );
    }

    /// The pack cache carried across per-epoch networks is value-derived
    /// (generation-keyed), so a trainer reusing it must produce bit-equal
    /// weights to fresh cold-cache trainers.
    #[test]
    fn pack_cache_carry_does_not_change_results() {
        let (cfg, ds) = setup();
        let start = Network::init(&cfg, 5).weights;
        let mut a = NativeTrainer::new(&cfg, Arc::clone(&ds), 0.2);
        a.add_samples(0..16);
        let mut wa = start.clone();
        for _ in 0..3 {
            wa = a.train_epoch(Arc::new(wa)).weights;
        }
        let mut wb = start;
        for _ in 0..3 {
            let mut b = NativeTrainer::new(&cfg, Arc::clone(&ds), 0.2);
            b.add_samples(0..16);
            wb = b.train_epoch(Arc::new(wb)).weights;
        }
        assert_eq!(wa.max_abs_diff(&wb), 0.0, "carried pack cache changed results");
    }

    /// A pool-backed trainer runs its epochs through the inner-layer
    /// scheduler with `TilePolicy::Auto`, still learns, and the node-owned
    /// tuner (like the pack cache) survives the per-epoch networks: stage
    /// entries accumulate in epoch 1 and are *carried*, not re-created,
    /// afterwards.
    #[test]
    fn pool_backed_epochs_train_and_carry_tuner() {
        let (cfg, ds) = setup();
        let pool = Arc::new(ThreadPool::new(2));
        let mut w = NativeTrainer::new(&cfg, ds, 0.3).with_pool(Arc::clone(&pool));
        w.add_samples(0..32);
        assert_eq!(w.tuned_stages(), 0);
        let mut weights = Network::init(&cfg, 4).weights;
        let mut losses = Vec::new();
        let mut stages_after_first = 0;
        for epoch in 0..6 {
            let out = w.train_epoch(Arc::new(weights));
            weights = out.weights.clone();
            losses.push(out.loss);
            if epoch == 0 {
                stages_after_first = w.tuned_stages();
                assert!(stages_after_first > 0, "first epoch accumulated no tuner state");
            }
        }
        assert!(
            losses.last().unwrap() < &(0.8 * losses[0]),
            "pool-backed epochs did not learn: {losses:?}"
        );
        assert_eq!(
            w.tuned_stages(),
            stages_after_first,
            "tuner state was rebuilt instead of carried across epochs"
        );
        assert!(w.tuning_report().contains("dense_fwd"), "{}", w.tuning_report());
    }

    #[test]
    fn incremental_allocation_grows_shard() {
        let (cfg, ds) = setup();
        let mut w = NativeTrainer::new(&cfg, ds, 0.1);
        w.add_samples(0..10);
        w.add_samples(10..25);
        assert_eq!(w.sample_count(), 25);
    }

    #[test]
    fn slowdown_increases_wall_time() {
        let (cfg, ds) = setup();
        let start = Network::init(&cfg, 3).weights;
        let mut fast = NativeTrainer::new(&cfg, Arc::clone(&ds), 0.1);
        fast.add_samples(0..16);
        let mut slow = NativeTrainer::new(&cfg, ds, 0.1).with_slowdown(3.0);
        slow.add_samples(0..16);
        let t_fast = {
            let t = Instant::now();
            fast.train_epoch(Arc::new(start.clone()));
            t.elapsed().as_secs_f64()
        };
        let t_slow = {
            let t = Instant::now();
            slow.train_epoch(Arc::new(start));
            t.elapsed().as_secs_f64()
        };
        assert!(t_slow > 1.8 * t_fast, "slowdown ineffective: {t_slow} vs {t_fast}");
    }

    #[test]
    #[should_panic(expected = "no samples")]
    fn empty_shard_panics() {
        let (cfg, ds) = setup();
        let mut w = NativeTrainer::new(&cfg, ds, 0.1);
        let start = Network::init(&cfg, 1).weights;
        w.train_epoch(Arc::new(start));
    }

    /// The remote-worker driver runs the same loop as the in-process
    /// cluster threads — check it against an `InProcTransport`.
    #[test]
    fn drive_worker_runs_against_inproc_transport() {
        use crate::outer::param_server::ParamServer;
        use crate::outer::transport::InProcTransport;
        use std::sync::Mutex;

        let (cfg, ds) = setup();
        let init = Network::init(&cfg, 6).weights;
        let ps = Arc::new(Mutex::new(ParamServer::new(init, 1)));
        let mut t = InProcTransport::new(Arc::clone(&ps), 0);
        let mut w = NativeTrainer::new(&cfg, ds, 0.2);
        let sched = vec![0..32];
        let summary =
            drive_worker(&mut t, &mut w, &sched, 3, SubmitMode::Agwu, Staleness(0), false)
                .unwrap();
        assert_eq!(summary.iterations, 3);
        assert_eq!(summary.final_version, 3);
        assert_eq!((summary.stats.fetches, summary.stats.submits), (3, 3));
        assert!(summary.busy_s > 0.0);
        assert!(summary.last_loss.is_finite());
        assert_eq!(summary.ack_log.len(), 3);
        assert_eq!(summary.max_staleness, 0);
        // Serialized driver: every comm second is stall, nothing overlaps.
        assert_eq!(summary.stats.overlap_wall_s, 0.0);
        assert_eq!(summary.stats.max_inflight, 0);
        drop(t);
        let ps = Arc::try_unwrap(ps).unwrap().into_inner().unwrap();
        assert_eq!(ps.version(), 3);
    }

    /// Pin the `Staleness(0)` path to the pre-pipeline call sequence: the
    /// same trainer driven by a hand-rolled fetch → train → submit loop
    /// must leave the server with bitwise-identical global weights.
    #[test]
    fn staleness_zero_is_bit_identical_to_hand_rolled_loop() {
        use crate::outer::param_server::ParamServer;
        use crate::outer::transport::InProcTransport;
        use std::sync::Mutex;

        let (cfg, ds) = setup();
        let init = Network::init(&cfg, 6).weights;
        let sched = vec![0..32, 32..48];
        let iterations = 3usize;

        let run_driver = || {
            let ps = Arc::new(Mutex::new(ParamServer::new(init.clone(), 1)));
            let mut t = InProcTransport::new(Arc::clone(&ps), 0);
            let mut w = NativeTrainer::new(&cfg, Arc::clone(&ds), 0.2);
            drive_worker(&mut t, &mut w, &sched, iterations, SubmitMode::Agwu, Staleness(0), false)
                .unwrap();
            drop(t);
            Arc::try_unwrap(ps).unwrap().into_inner().unwrap().into_global()
        };
        let hand_rolled = || {
            let ps = Arc::new(Mutex::new(ParamServer::new(init.clone(), 1)));
            let mut t = InProcTransport::new(Arc::clone(&ps), 0);
            let mut w = NativeTrainer::new(&cfg, Arc::clone(&ds), 0.2);
            for iter in 0..iterations {
                if iter < sched.len() {
                    w.add_samples(sched[iter].clone());
                }
                let (global, base) = t.fetch_global().unwrap();
                let out = w.train_epoch(global);
                let meta = SubmitMeta {
                    mode: SubmitMode::Agwu,
                    base,
                    accuracy: out.accuracy,
                    loss: out.loss,
                    want_snapshot: false,
                };
                t.submit(out.weights, &meta).unwrap();
            }
            t.finish().unwrap();
            drop(t);
            Arc::try_unwrap(ps).unwrap().into_inner().unwrap().into_global()
        };

        let a = run_driver();
        let b = hand_rolled();
        assert_eq!(a.tensors().len(), b.tensors().len());
        for (ta, tb) in a.tensors().iter().zip(b.tensors().iter()) {
            assert_eq!(ta.data(), tb.data(), "serialized driver diverged from PR-6 loop");
        }
    }

    /// A single pipelined worker over `InProcTransport`: the comm thread
    /// and double buffering must preserve the loop's learning behavior and
    /// respect the staleness bound (trivially 0 for one node).
    #[test]
    fn drive_worker_pipelined_runs_and_respects_bound() {
        use crate::outer::param_server::ParamServer;
        use crate::outer::transport::InProcTransport;
        use std::sync::Mutex;

        let (cfg, ds) = setup();
        let init = Network::init(&cfg, 6).weights;
        let ps = Arc::new(Mutex::new(ParamServer::new(init, 1)));
        let mut t = InProcTransport::new(Arc::clone(&ps), 0);
        let mut w = NativeTrainer::new(&cfg, ds, 0.2);
        let sched = vec![0..32];
        let summary =
            drive_worker(&mut t, &mut w, &sched, 4, SubmitMode::Agwu, Staleness(1), false)
                .unwrap();
        assert_eq!(summary.iterations, 4);
        assert_eq!(summary.final_version, 4);
        assert_eq!((summary.stats.fetches, summary.stats.submits), (4, 4));
        assert_eq!(summary.ack_log.len(), 4);
        // One worker: its own acks are the only version advances, and each
        // prefetch is queued behind the previous submit, so a snapshot is
        // never stale at all.
        assert!(summary.max_staleness <= 1, "bound violated: {}", summary.max_staleness);
        assert!(summary.stats.max_inflight >= 1);
        assert!(summary.last_loss.is_finite());
        drop(t);
        let ps = Arc::try_unwrap(ps).unwrap().into_inner().unwrap();
        assert_eq!(ps.version(), 4);
    }
}
