//! Fault tolerance for the outer layer: deterministic fault injection,
//! bounded retry/reconnect, and atomic weight-set checkpointing.
//!
//! Three pieces ride the [`Transport`] seam established in `transport.rs`:
//!
//! - [`FaultyTransport`] is a decorator (like `ThrottledTransport`) that
//!   injects *seeded, deterministic* faults — dropped operations, delayed
//!   or duplicated frames, truncated payloads, and permanent mid-run peer
//!   death — so chaos tests replay bit-for-bit from a seed.
//! - [`RetryPolicy`] + [`RetryingTransport`] wrap a fallible transport
//!   factory with bounded-attempt exponential backoff. A reconnect simply
//!   re-runs the factory (for `TcpTransport` that re-sends the `Hello`
//!   with the same node id; the server re-admits the session and replays
//!   the current global snapshot), so a dropped connection costs one
//!   retry, not the run.
//! - [`write_checkpoint`] / [`read_checkpoint`] persist the global
//!   `WeightSet` through the `BPWS` codec with write-to-temp +
//!   `fs::rename`, so a crash mid-checkpoint never corrupts `latest.ckpt`.
//!
//! [`FaultStats`] counts every recovery event and is threaded through
//! `TransportStats` into `ClusterReport`.

use std::fs;
use std::io::Write as _;
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use crate::tensor::wire::{decode_weight_set, encode_weight_set_into, encoded_len};
use crate::tensor::WeightSet;

use super::transport::{SubmitAck, SubmitMeta, Transport, TransportStats};
use super::wire::{read_msg, write_msg, Msg};

/// Counters for every fault-recovery event in a run. Merged across nodes
/// into `ClusterReport.fault`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Operations that failed and were retried (same or new connection).
    pub retries: usize,
    /// Successful re-connections after a connection was lost.
    pub reconnects: usize,
    /// IDPA batches moved from a dead node to survivors.
    pub reallocated_batches: usize,
    /// Samples contained in those re-allocated batches.
    pub reallocated_samples: usize,
    /// Checkpoints durably written (post-rename).
    pub checkpoints_written: usize,
    /// Checkpoints loaded at startup (`--resume`).
    pub checkpoints_loaded: usize,
    /// Worker leases that expired (heartbeat/read deadline missed).
    pub leases_expired: usize,
    /// Failovers: worker-side, a dial that moved on to the next address in
    /// the `--servers` list; server-side, a standby promotion to primary.
    pub failovers: usize,
}

impl FaultStats {
    /// Fold another node's counters into this one.
    pub fn merge(&mut self, other: &FaultStats) {
        self.retries += other.retries;
        self.reconnects += other.reconnects;
        self.reallocated_batches += other.reallocated_batches;
        self.reallocated_samples += other.reallocated_samples;
        self.checkpoints_written += other.checkpoints_written;
        self.checkpoints_loaded += other.checkpoints_loaded;
        self.leases_expired += other.leases_expired;
        self.failovers += other.failovers;
    }

    /// True if any recovery event fired.
    pub fn any(&self) -> bool {
        *self != FaultStats::default()
    }
}

// ---------------------------------------------------------------------------
// Deterministic fault injection
// ---------------------------------------------------------------------------

/// Which fault, if any, a given operation draws from the plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Fault {
    None,
    /// The operation fails as if the connection dropped.
    Drop,
    /// The frame is delayed by a deterministic amount before proceeding.
    Delay,
    /// A fetch re-delivers the previous snapshot without touching the peer.
    Duplicate,
    /// The payload arrives short — surfaces as a decode error.
    Truncate,
    /// One bit of the frame flips in flight — the CRC32 trailer must catch
    /// it; surfaces as the wire layer's crc-mismatch decode error.
    BitFlip,
}

/// Transport decorator injecting seeded, deterministic faults.
///
/// All randomness comes from an xorshift64 stream derived from the seed,
/// so a given (seed, op sequence) replays the identical fault schedule.
/// Probabilities are percentages checked in a fixed order per operation:
/// kill, drop, truncate, duplicate (fetch only), delay.
pub struct FaultyTransport<T: Transport> {
    inner: T,
    rng: u64,
    drop_pct: u8,
    delay_pct: u8,
    delay: Duration,
    duplicate_pct: u8,
    truncate_pct: u8,
    bitflip_pct: u8,
    kill_after_ops: Option<usize>,
    ops: usize,
    last_fetch: Option<(Arc<WeightSet>, usize)>,
}

impl<T: Transport> FaultyTransport<T> {
    /// Wrap `inner` with a fault plan seeded by `seed`. All fault rates
    /// start at zero; enable them with the builder methods.
    pub fn new(inner: T, seed: u64) -> Self {
        FaultyTransport {
            inner,
            rng: seed.max(1),
            drop_pct: 0,
            delay_pct: 0,
            delay: Duration::from_micros(200),
            duplicate_pct: 0,
            truncate_pct: 0,
            bitflip_pct: 0,
            kill_after_ops: None,
            ops: 0,
            last_fetch: None,
        }
    }

    /// Percentage of operations that fail as a dropped connection.
    pub fn with_drop_pct(mut self, pct: u8) -> Self {
        self.drop_pct = pct.min(100);
        self
    }

    /// Percentage of operations delayed, and the deterministic delay.
    pub fn with_delay(mut self, pct: u8, delay: Duration) -> Self {
        self.delay_pct = pct.min(100);
        self.delay = delay;
        self
    }

    /// Percentage of fetches that re-deliver the previous snapshot
    /// (a duplicated frame) instead of consulting the peer.
    pub fn with_duplicate_pct(mut self, pct: u8) -> Self {
        self.duplicate_pct = pct.min(100);
        self
    }

    /// Percentage of operations whose payload arrives truncated.
    pub fn with_truncate_pct(mut self, pct: u8) -> Self {
        self.truncate_pct = pct.min(100);
        self
    }

    /// Percentage of operations whose frame arrives with one bit flipped.
    /// Unlike the other faults this one is *end-to-end*: the real wire
    /// frame is serialized, a deterministic bit is flipped inside the
    /// body/CRC region, and the frame is re-decoded — the CRC32 trailer
    /// must reject it, and its decode error is what the caller observes.
    pub fn with_bitflip_pct(mut self, pct: u8) -> Self {
        self.bitflip_pct = pct.min(100);
        self
    }

    /// After `ops` successful operations the peer dies permanently:
    /// every later operation fails.
    pub fn with_kill_after_ops(mut self, ops: usize) -> Self {
        self.kill_after_ops = Some(ops);
        self
    }

    /// Unwrap the decorated transport.
    pub fn into_inner(self) -> T {
        self.inner
    }

    fn next(&mut self) -> u64 {
        let mut x = self.rng;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.rng = x;
        x
    }

    fn pct(&mut self) -> u8 {
        (self.next() % 100) as u8
    }

    /// Draw the fault for the next operation. `fetch` enables Duplicate.
    fn draw(&mut self, fetch: bool) -> Result<Fault> {
        if let Some(kill) = self.kill_after_ops {
            if self.ops >= kill {
                bail!("injected fault: peer died after {kill} ops");
            }
        }
        self.ops += 1;
        if self.pct() < self.drop_pct {
            return Ok(Fault::Drop);
        }
        if self.pct() < self.truncate_pct {
            return Ok(Fault::Truncate);
        }
        if self.pct() < self.bitflip_pct {
            return Ok(Fault::BitFlip);
        }
        if fetch && self.pct() < self.duplicate_pct {
            return Ok(Fault::Duplicate);
        }
        if self.pct() < self.delay_pct {
            return Ok(Fault::Delay);
        }
        Ok(Fault::None)
    }

    /// Serialize `msg` as a real wire frame, flip one seeded bit inside the
    /// body-or-trailer region, and re-decode: the CRC32 check must reject
    /// it. Returns the decode error the corrupted frame produced — this is
    /// the end-to-end path a flipped bit takes through the real protocol.
    fn bit_flip_error(&mut self, msg: &Msg, during: &str) -> anyhow::Error {
        let mut frame = Vec::new();
        if let Err(e) = write_msg(&mut frame, msg) {
            return e.context("injected fault: encode for bit flip");
        }
        // Flip within [4, len): body + CRC trailer, never the length prefix
        // (a corrupt length is a different failure mode — `Truncate`).
        let span = frame.len() - 4;
        let bit = (self.next() as usize) % (span * 8);
        frame[4 + bit / 8] ^= 1 << (bit % 8);
        match read_msg(&mut std::io::Cursor::new(frame)) {
            Err(e) => e.context(format!("injected fault: bit-flipped frame during {during}")),
            Ok(_) => anyhow::anyhow!(
                "injected bit flip survived the CRC32 trailer during {during} — \
                 integrity check is broken"
            ),
        }
    }
}

impl<T: Transport> Transport for FaultyTransport<T> {
    fn fetch_global(&mut self) -> Result<(Arc<WeightSet>, usize)> {
        match self.draw(true)? {
            Fault::Drop => bail!("injected fault: connection dropped during fetch"),
            Fault::Truncate => bail!("injected fault: truncated global frame"),
            Fault::BitFlip => {
                // The reply arrives, but one bit flipped in flight: build
                // the real Global frame it would have ridden in, corrupt
                // it, and surface the CRC rejection.
                let (ws, v) = self.inner.fetch_global()?;
                let msg = Msg::Global {
                    version: v as u64,
                    epoch: 0,
                    reassigned: Vec::new(),
                    weights: (*ws).clone(),
                };
                return Err(self.bit_flip_error(&msg, "fetch"));
            }
            Fault::Duplicate => {
                if let Some((ws, v)) = &self.last_fetch {
                    return Ok((Arc::clone(ws), *v));
                }
            }
            Fault::Delay => std::thread::sleep(self.delay),
            Fault::None => {}
        }
        let got = self.inner.fetch_global()?;
        self.last_fetch = Some((Arc::clone(&got.0), got.1));
        Ok(got)
    }

    fn submit(&mut self, local: WeightSet, meta: &SubmitMeta) -> Result<SubmitAck> {
        match self.draw(false)? {
            Fault::Drop => bail!("injected fault: connection dropped during submit"),
            Fault::Truncate => bail!("injected fault: truncated submit frame"),
            Fault::BitFlip => {
                let msg = Msg::Submit {
                    mode: meta.mode,
                    base: meta.base as u64,
                    accuracy: meta.accuracy,
                    loss: meta.loss,
                    weights: local,
                };
                return Err(self.bit_flip_error(&msg, "submit"));
            }
            Fault::Delay => std::thread::sleep(self.delay),
            Fault::Duplicate | Fault::None => {}
        }
        self.inner.submit(local, meta)
    }

    fn stats(&self) -> TransportStats {
        self.inner.stats()
    }

    fn finish(&mut self) -> Result<()> {
        self.inner.finish()
    }

    fn take_reassigned(&mut self) -> Vec<Range<usize>> {
        self.inner.take_reassigned()
    }

    fn heartbeat(&mut self) -> Result<()> {
        self.inner.heartbeat()
    }
}

// ---------------------------------------------------------------------------
// Retry / reconnect
// ---------------------------------------------------------------------------

/// Bounded-attempt exponential backoff. Fully deterministic: no jitter,
/// no wall-clock randomness — `backoff(k)` is a pure function of `k`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total attempts per operation (first try included). Must be ≥ 1.
    pub max_attempts: usize,
    /// Backoff before the first retry; doubles per subsequent retry.
    pub base_backoff: Duration,
    /// Ceiling on the per-retry backoff.
    pub max_backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 4,
            base_backoff: Duration::from_millis(50),
            max_backoff: Duration::from_secs(2),
        }
    }
}

impl RetryPolicy {
    /// Backoff to sleep before retry number `retry` (0-based):
    /// `min(base · 2^retry, max)`.
    pub fn backoff(&self, retry: u32) -> Duration {
        let scaled = self
            .base_backoff
            .checked_mul(1u32.checked_shl(retry).unwrap_or(u32::MAX))
            .unwrap_or(self.max_backoff);
        scaled.min(self.max_backoff)
    }
}

/// Factory that (re-)establishes a transport session. For TCP this is
/// `TcpTransport::connect(addr, node)` — the node id identifies the
/// session, so the server re-admits the worker and replays the current
/// global snapshot on the first fetch.
pub type ConnectFn = Box<dyn FnMut() -> Result<Box<dyn Transport>> + Send>;

// ---------------------------------------------------------------------------
// Worker-driven failover across an ordered server list
// ---------------------------------------------------------------------------

/// Ordered `--servers` address list shared by a worker's dialers, plus the
/// cluster-epoch cell every session stamps into its `Hello` and raises
/// from `Global` replies. `preferred` starts at 0 (the primary); when a
/// dial fails the factory advances past it, so once the worker has failed
/// over every later reconnect goes straight to the promoted standby.
pub struct ServerList {
    addrs: Vec<String>,
    preferred: AtomicUsize,
    failovers: AtomicUsize,
    epoch: Arc<AtomicU64>,
}

impl ServerList {
    /// Build from an ordered address list (primary first). Panics on an
    /// empty list — a worker with nowhere to dial is a config error.
    pub fn new(addrs: Vec<String>) -> Arc<Self> {
        assert!(!addrs.is_empty(), "server list must not be empty");
        Arc::new(ServerList {
            addrs,
            preferred: AtomicUsize::new(0),
            failovers: AtomicUsize::new(0),
            epoch: Arc::new(AtomicU64::new(0)),
        })
    }

    /// The shared cluster-epoch cell. Hand this to every transport dialed
    /// from the list so a promotion observed on one connection raises the
    /// epoch all future `Hello`s carry.
    pub fn epoch_cell(&self) -> Arc<AtomicU64> {
        Arc::clone(&self.epoch)
    }

    /// Highest cluster epoch observed so far.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::SeqCst)
    }

    /// The addresses, in priority order.
    pub fn addrs(&self) -> &[String] {
        &self.addrs
    }

    /// Index of the address new sessions currently prefer.
    pub fn preferred(&self) -> usize {
        self.preferred.load(Ordering::SeqCst)
    }

    /// How many times a dial moved on to a different address.
    pub fn failovers(&self) -> usize {
        self.failovers.load(Ordering::SeqCst)
    }
}

/// Build a [`ConnectFn`] that tries `list` in order starting from the
/// preferred address, advancing (and counting a failover) when a dial
/// fails. `dial` receives the address and the shared epoch cell.
pub fn failover_connect(
    list: Arc<ServerList>,
    mut dial: impl FnMut(&str, Arc<AtomicU64>) -> Result<Box<dyn Transport>> + Send + 'static,
) -> ConnectFn {
    Box::new(move || {
        let n = list.addrs.len();
        let start = list.preferred.load(Ordering::SeqCst);
        let mut last_err = None;
        for k in 0..n {
            let idx = (start + k) % n;
            match dial(&list.addrs[idx], list.epoch_cell()) {
                Ok(t) => {
                    if idx != start {
                        list.preferred.store(idx, Ordering::SeqCst);
                        list.failovers.fetch_add(1, Ordering::SeqCst);
                    }
                    return Ok(t);
                }
                Err(e) => {
                    last_err =
                        Some(e.context(format!("dial param server {}", list.addrs[idx])))
                }
            }
        }
        Err(last_err.expect("server list non-empty"))
    })
}

/// Transport wrapper that retries failed operations under a
/// [`RetryPolicy`], reconnecting via the factory when the underlying
/// session is lost. Stats of dead sessions are absorbed so nothing is
/// lost across reconnects.
pub struct RetryingTransport {
    connect: ConnectFn,
    policy: RetryPolicy,
    inner: Option<Box<dyn Transport>>,
    ever_connected: bool,
    absorbed: TransportStats,
    fault: FaultStats,
    servers: Option<Arc<ServerList>>,
}

impl RetryingTransport {
    /// Build from a session factory. The first session is established
    /// lazily on the first operation (and does not count as a reconnect).
    pub fn new(connect: ConnectFn, policy: RetryPolicy) -> Self {
        assert!(policy.max_attempts >= 1, "retry policy needs >= 1 attempt");
        RetryingTransport {
            connect,
            policy,
            inner: None,
            ever_connected: false,
            absorbed: TransportStats::default(),
            fault: FaultStats::default(),
            servers: None,
        }
    }

    /// Attach the [`ServerList`] the factory dials through, so its
    /// failover count shows up in this transport's fault stats.
    pub fn with_servers(mut self, servers: Arc<ServerList>) -> Self {
        self.servers = Some(servers);
        self
    }

    /// Recovery counters accumulated so far.
    pub fn fault_stats(&self) -> FaultStats {
        let mut f = self.fault;
        if let Some(list) = &self.servers {
            f.failovers += list.failovers();
        }
        f
    }

    fn ensure_inner(&mut self) -> Result<&mut Box<dyn Transport>> {
        if self.inner.is_none() {
            let session = (self.connect)().context("establish transport session")?;
            if self.ever_connected {
                self.fault.reconnects += 1;
            }
            self.ever_connected = true;
            self.inner = Some(session);
        }
        Ok(self.inner.as_mut().expect("session just established"))
    }

    /// Tear down the current session, folding its stats into `absorbed`.
    fn discard_inner(&mut self) {
        if let Some(dead) = self.inner.take() {
            self.absorbed.merge(&dead.stats());
        }
    }

    fn with_retry<R>(
        &mut self,
        mut op: impl FnMut(&mut dyn Transport) -> Result<R>,
    ) -> Result<R> {
        let mut last_err = None;
        for attempt in 0..self.policy.max_attempts {
            if attempt > 0 {
                self.fault.retries += 1;
                std::thread::sleep(self.policy.backoff(attempt as u32 - 1));
            }
            let session = match self.ensure_inner() {
                Ok(s) => s,
                Err(e) => {
                    last_err = Some(e);
                    continue;
                }
            };
            match op(session.as_mut()) {
                Ok(r) => return Ok(r),
                Err(e) => {
                    // Assume the session is tainted: reconnect next attempt.
                    self.discard_inner();
                    last_err = Some(e);
                }
            }
        }
        Err(last_err.expect("max_attempts >= 1").context(format!(
            "operation failed after {} attempts",
            self.policy.max_attempts
        )))
    }
}

impl Transport for RetryingTransport {
    fn fetch_global(&mut self) -> Result<(Arc<WeightSet>, usize)> {
        self.with_retry(|t| t.fetch_global())
    }

    fn submit(&mut self, local: WeightSet, meta: &SubmitMeta) -> Result<SubmitAck> {
        let meta = *meta;
        self.with_retry(move |t| t.submit(local.clone(), &meta))
    }

    fn stats(&self) -> TransportStats {
        let mut s = self.absorbed;
        if let Some(inner) = &self.inner {
            s.merge(&inner.stats());
        }
        s.fault.merge(&self.fault_stats());
        s
    }

    fn finish(&mut self) -> Result<()> {
        // Finishing a lost session is not worth reconnecting for.
        if let Some(inner) = &mut self.inner {
            inner.finish()?;
        }
        Ok(())
    }

    fn take_reassigned(&mut self) -> Vec<Range<usize>> {
        match &mut self.inner {
            Some(inner) => inner.take_reassigned(),
            None => Vec::new(),
        }
    }

    fn heartbeat(&mut self) -> Result<()> {
        match &mut self.inner {
            Some(inner) => inner.heartbeat(),
            None => Ok(()),
        }
    }
}

// ---------------------------------------------------------------------------
// Checkpointing
// ---------------------------------------------------------------------------

/// Magic prefix of a checkpoint file.
pub const CHECKPOINT_MAGIC: [u8; 4] = *b"BPCK";
/// Checkpoint container format version.
pub const CHECKPOINT_FORMAT: u16 = 1;
/// Name of the newest checkpoint inside `--checkpoint-dir`.
pub const CHECKPOINT_FILE: &str = "latest.ckpt";

/// Path of the live checkpoint in `dir`.
pub fn checkpoint_path(dir: &Path) -> PathBuf {
    dir.join(CHECKPOINT_FILE)
}

/// Durably write `ws` at global `version` into `dir/latest.ckpt`.
///
/// Layout: `"BPCK" | format u16 LE | version u64 LE | BPWS payload`.
/// The bytes land in a temp file first and are `rename`d into place, so
/// a crash at any point leaves either the old or the new checkpoint —
/// never a torn one.
pub fn write_checkpoint(dir: &Path, version: u64, ws: &WeightSet) -> Result<()> {
    fs::create_dir_all(dir)
        .with_context(|| format!("create checkpoint dir {}", dir.display()))?;
    let mut buf = Vec::with_capacity(14 + encoded_len(ws));
    buf.extend_from_slice(&CHECKPOINT_MAGIC);
    buf.extend_from_slice(&CHECKPOINT_FORMAT.to_le_bytes());
    buf.extend_from_slice(&version.to_le_bytes());
    encode_weight_set_into(ws, &mut buf);
    let tmp = dir.join(format!(".ckpt-{version}.tmp"));
    {
        let mut f = fs::File::create(&tmp)
            .with_context(|| format!("create {}", tmp.display()))?;
        f.write_all(&buf)
            .with_context(|| format!("write {}", tmp.display()))?;
        f.sync_all().with_context(|| format!("sync {}", tmp.display()))?;
    }
    fs::rename(&tmp, checkpoint_path(dir))
        .with_context(|| format!("publish checkpoint in {}", dir.display()))?;
    // The rename is only durable once the *directory entry* is on disk:
    // fsyncing the file alone does not persist the name change, so a
    // power loss right here could resurrect the old checkpoint — or
    // leave none at all on filesystems that journal lazily.
    sync_dir(dir)?;
    Ok(())
}

/// Fsync a directory so a just-renamed entry inside it survives power
/// loss. Split out so the open/sync path is testable on its own.
pub fn sync_dir(dir: &Path) -> Result<()> {
    let d = fs::File::open(dir)
        .with_context(|| format!("open checkpoint dir {} for sync", dir.display()))?;
    d.sync_all()
        .with_context(|| format!("sync checkpoint dir {}", dir.display()))?;
    Ok(())
}

/// Load `dir/latest.ckpt`, returning the global version it was written
/// at and the decoded `WeightSet` (bit-identical to what was written).
pub fn read_checkpoint(dir: &Path) -> Result<(u64, WeightSet)> {
    let path = checkpoint_path(dir);
    let bytes =
        fs::read(&path).with_context(|| format!("read checkpoint {}", path.display()))?;
    ensure!(bytes.len() >= 14, "checkpoint too short: {} bytes", bytes.len());
    ensure!(bytes[..4] == CHECKPOINT_MAGIC, "bad checkpoint magic");
    let format = u16::from_le_bytes([bytes[4], bytes[5]]);
    ensure!(
        format == CHECKPOINT_FORMAT,
        "unsupported checkpoint format {format} (expected {CHECKPOINT_FORMAT})"
    );
    let mut v = [0u8; 8];
    v.copy_from_slice(&bytes[6..14]);
    let version = u64::from_le_bytes(v);
    let ws = decode_weight_set(&bytes[14..]).context("decode checkpoint payload")?;
    Ok((version, ws))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::outer::param_server::ParamServer;
    use crate::outer::transport::{InProcTransport, SubmitMode};
    use crate::tensor::Tensor;
    use std::sync::Mutex;

    fn ws(vals: &[f32]) -> WeightSet {
        WeightSet::new(vec![Tensor::from_vec(&[vals.len()], vals.to_vec())])
    }

    fn agwu_meta(base: usize) -> SubmitMeta {
        SubmitMeta {
            mode: SubmitMode::Agwu,
            base,
            accuracy: 0.5,
            loss: 1.0,
            want_snapshot: false,
        }
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let p = RetryPolicy {
            max_attempts: 8,
            base_backoff: Duration::from_millis(10),
            max_backoff: Duration::from_millis(65),
        };
        assert_eq!(p.backoff(0), Duration::from_millis(10));
        assert_eq!(p.backoff(1), Duration::from_millis(20));
        assert_eq!(p.backoff(2), Duration::from_millis(40));
        assert_eq!(p.backoff(3), Duration::from_millis(65));
        assert_eq!(p.backoff(31), Duration::from_millis(65));
        assert_eq!(p.backoff(63), Duration::from_millis(65));
    }

    #[test]
    fn fault_schedule_is_deterministic_in_the_seed() {
        let draw_seq = |seed: u64| {
            let ps = Arc::new(Mutex::new(ParamServer::new(ws(&[0.0]), 1)));
            let mut t = FaultyTransport::new(InProcTransport::new(ps, 0), seed)
                .with_drop_pct(30)
                .with_duplicate_pct(30);
            (0..32)
                .map(|_| match t.fetch_global() {
                    Ok(_) => 0u8,
                    Err(_) => 1u8,
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(draw_seq(7), draw_seq(7));
        assert_ne!(draw_seq(7), draw_seq(8), "different seeds, same schedule");
    }

    #[test]
    fn duplicate_redelivers_previous_snapshot() {
        let ps = Arc::new(Mutex::new(ParamServer::new(ws(&[1.0]), 1)));
        let mut t = FaultyTransport::new(InProcTransport::new(Arc::clone(&ps), 0), 3)
            .with_duplicate_pct(100);
        // First fetch has nothing cached, so it reaches the server.
        let (first, v0) = t.fetch_global().unwrap();
        // Advance the real global behind the decorator's back.
        {
            let mut g = ps.lock().unwrap();
            let _ = g.fetch(0);
            let local = ws(&[9.0]);
            g.update_agwu(0, &local, v0, 0.9);
        }
        // Duplicate frame: we must see the stale cached snapshot again.
        let (second, v1) = t.fetch_global().unwrap();
        assert_eq!(v1, v0);
        assert_eq!(second.max_abs_diff(&first), 0.0);
    }

    #[test]
    fn killed_peer_fails_every_operation() {
        let ps = Arc::new(Mutex::new(ParamServer::new(ws(&[0.0]), 1)));
        let mut t = FaultyTransport::new(InProcTransport::new(ps, 0), 11).with_kill_after_ops(2);
        assert!(t.fetch_global().is_ok());
        assert!(t.fetch_global().is_ok());
        assert!(t.fetch_global().is_err());
        assert!(t.submit(ws(&[0.0]), &agwu_meta(0)).is_err());
    }

    #[test]
    fn retrying_transport_reconnects_through_peer_death() {
        // Each session dies after 3 ops; the retrying wrapper must keep
        // reconnecting and complete 5 full fetch+submit epochs.
        let ps = Arc::new(Mutex::new(ParamServer::new(ws(&[0.0]), 1)));
        let factory_ps = Arc::clone(&ps);
        let connect: ConnectFn = Box::new(move || {
            let inner = InProcTransport::new(Arc::clone(&factory_ps), 0);
            Ok(Box::new(FaultyTransport::new(inner, 5).with_kill_after_ops(3)) as Box<dyn Transport>)
        });
        let policy = RetryPolicy {
            max_attempts: 3,
            base_backoff: Duration::ZERO,
            max_backoff: Duration::ZERO,
        };
        let mut t = RetryingTransport::new(connect, policy);
        for _ in 0..5 {
            let (snap, base) = t.fetch_global().unwrap();
            let mut local = (*snap).clone();
            local.tensors_mut()[0].data_mut()[0] += 1.0;
            t.submit(local, &agwu_meta(base)).unwrap();
        }
        let f = t.fault_stats();
        assert!(f.reconnects >= 2, "expected reconnects, got {f:?}");
        assert!(f.retries >= f.reconnects);
        assert_eq!(ps.lock().unwrap().version(), 5);
        // Absorbed stats survive session churn.
        assert_eq!(t.stats().submits, 5);
        assert_eq!(t.stats().fault.reconnects, f.reconnects);
    }

    #[test]
    fn retrying_transport_gives_up_after_max_attempts() {
        let connect: ConnectFn = Box::new(|| bail!("injected fault: endpoint unreachable"));
        let policy = RetryPolicy {
            max_attempts: 3,
            base_backoff: Duration::ZERO,
            max_backoff: Duration::ZERO,
        };
        let mut t = RetryingTransport::new(connect, policy);
        let err = t.fetch_global().unwrap_err();
        assert!(err.to_string().contains("3 attempts"), "{err:#}");
        assert_eq!(t.fault_stats().retries, 2);
        assert_eq!(t.fault_stats().reconnects, 0);
    }

    #[test]
    fn bit_flip_fault_is_rejected_by_the_crc_trailer() {
        let ps = Arc::new(Mutex::new(ParamServer::new(ws(&[1.0, -2.0]), 1)));
        let mut t = FaultyTransport::new(InProcTransport::new(Arc::clone(&ps), 0), 17)
            .with_bitflip_pct(100);
        for _ in 0..8 {
            let err = t.fetch_global().unwrap_err();
            let chain = format!("{err:#}");
            assert!(chain.contains("bit-flipped frame during fetch"), "{chain}");
            assert!(chain.contains("crc mismatch"), "{chain}");
        }
        for _ in 0..8 {
            let err = t.submit(ws(&[0.5, 0.5]), &agwu_meta(0)).unwrap_err();
            let chain = format!("{err:#}");
            assert!(chain.contains("bit-flipped frame during submit"), "{chain}");
            assert!(chain.contains("crc mismatch"), "{chain}");
        }
        // The corrupted submits never reached the server.
        assert_eq!(ps.lock().unwrap().version(), 0);
    }

    #[test]
    fn failover_connect_advances_to_the_standby_and_sticks() {
        let ps = Arc::new(Mutex::new(ParamServer::new(ws(&[0.0]), 1)));
        let list = ServerList::new(vec!["primary:1".into(), "standby:2".into()]);
        let dial_log = Arc::new(Mutex::new(Vec::<String>::new()));
        let log = Arc::clone(&dial_log);
        let dial_ps = Arc::clone(&ps);
        let connect = failover_connect(Arc::clone(&list), move |addr, _epoch| {
            log.lock().unwrap().push(addr.to_string());
            if addr.starts_with("primary") {
                bail!("injected fault: primary unreachable");
            }
            Ok(Box::new(InProcTransport::new(Arc::clone(&dial_ps), 0)) as Box<dyn Transport>)
        });
        let policy = RetryPolicy {
            max_attempts: 2,
            base_backoff: Duration::ZERO,
            max_backoff: Duration::ZERO,
        };
        let mut t = RetryingTransport::new(connect, policy).with_servers(Arc::clone(&list));
        t.fetch_global().unwrap();
        t.fetch_global().unwrap();
        // First connect walked primary -> standby; after the failover the
        // list prefers the standby, so no second dial of the primary.
        assert_eq!(
            *dial_log.lock().unwrap(),
            vec!["primary:1".to_string(), "standby:2".to_string()]
        );
        assert_eq!(list.preferred(), 1);
        assert_eq!(list.failovers(), 1);
        assert_eq!(t.fault_stats().failovers, 1);
        assert_eq!(t.stats().fault.failovers, 1);
    }

    #[test]
    fn failover_connect_reports_last_error_when_all_addresses_fail() {
        let list = ServerList::new(vec!["a:1".into(), "b:2".into()]);
        let mut connect = failover_connect(Arc::clone(&list), |addr, _| {
            bail!("injected fault: {addr} unreachable")
        });
        let err = connect().unwrap_err();
        let chain = format!("{err:#}");
        assert!(chain.contains("dial param server"), "{chain}");
        assert_eq!(list.failovers(), 0, "failed dials are not failovers");
    }

    #[test]
    fn checkpoint_dir_is_syncable_after_publish() {
        let dir = std::env::temp_dir().join(format!(
            "bptcnn-ckpt-sync-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = fs::remove_dir_all(&dir);
        // write_checkpoint itself runs the open/sync path; exercise it
        // again standalone and assert the failure mode on a missing dir.
        write_checkpoint(&dir, 3, &ws(&[1.0])).unwrap();
        sync_dir(&dir).unwrap();
        fs::remove_dir_all(&dir).unwrap();
        let err = sync_dir(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("open checkpoint dir"), "{err:#}");
    }

    #[test]
    fn checkpoint_round_trips_bit_identically() {
        let dir = std::env::temp_dir().join(format!(
            "bptcnn-ckpt-rt-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = fs::remove_dir_all(&dir);
        let original = ws(&[1.5, -2.25, f32::MIN_POSITIVE, 0.0, 3.0e8]);
        write_checkpoint(&dir, 42, &original).unwrap();
        let (version, restored) = read_checkpoint(&dir).unwrap();
        assert_eq!(version, 42);
        let a: Vec<u32> = original.flatten().iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = restored.flatten().iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b, "checkpoint payload must be bit-identical");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn checkpoint_overwrite_is_atomic_and_leaves_no_temp() {
        let dir = std::env::temp_dir().join(format!(
            "bptcnn-ckpt-atomic-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = fs::remove_dir_all(&dir);
        write_checkpoint(&dir, 1, &ws(&[1.0])).unwrap();
        write_checkpoint(&dir, 2, &ws(&[2.0])).unwrap();
        let (version, restored) = read_checkpoint(&dir).unwrap();
        assert_eq!(version, 2);
        assert_eq!(restored.flatten(), vec![2.0]);
        let leftovers: Vec<_> = fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .filter(|n| n != CHECKPOINT_FILE)
            .collect();
        assert!(leftovers.is_empty(), "stray files: {leftovers:?}");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_checkpoints_are_rejected() {
        let dir = std::env::temp_dir().join(format!(
            "bptcnn-ckpt-corrupt-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = fs::remove_dir_all(&dir);
        write_checkpoint(&dir, 7, &ws(&[1.0, 2.0])).unwrap();
        let path = checkpoint_path(&dir);
        let mut bytes = fs::read(&path).unwrap();
        bytes[0] = b'X';
        fs::write(&path, &bytes).unwrap();
        assert!(read_checkpoint(&dir).is_err());
        // Truncated payload is rejected by the BPWS decoder, not ignored.
        bytes[0] = b'B';
        bytes.truncate(bytes.len() - 1);
        fs::write(&path, &bytes).unwrap();
        assert!(read_checkpoint(&dir).is_err());
        fs::remove_dir_all(&dir).unwrap();
    }
}
