//! Standalone parameter-server service: the §3.2.1 server node as a real
//! process. An accept loop hands each TCP connection to a handler thread
//! serving the shared [`ParamServer`] — the Eq. 7/Eq. 10 update rules run
//! unchanged; only the node ↔ server link is a socket instead of an `Arc`
//! bump.
//!
//! SGWU's Eq. 8 barrier falls out of the protocol: a round part's `Ack` is
//! not written until the last node of the round arrives and the round is
//! installed, so the blocked socket *is* the synchronization wait (accounted
//! in `sync_wait_s` exactly like the in-process runner does).
//!
//! # Failure model
//!
//! Every connection carries a read/write deadline of [`ServeOptions::lease`]
//! — a peer that goes silent longer than its lease is declared dead (a hung
//! socket can no longer wedge the server). Worker death is a *scheduling
//! event*, not an error, when `--on-failure continue`:
//!
//! * **AGWU** — the run continues with the survivors; the dead node's
//!   remaining IDPA batches are re-allocated across survivors proportional
//!   to their measured epoch throughput ([`super::partition::reallocate`])
//!   and delivered piggybacked on their next fetch replies.
//! * **SGWU** — the Eq. 8 barrier quorum shrinks to the live nodes, so a
//!   round waiting only on the dead peer installs immediately.
//!
//! A worker reconnecting with the same node id is re-admitted (its old
//! session is superseded) and replays the current global snapshot with its
//! first fetch. Protocol violations (bad hello, wrong update mode, decode
//! rejections) are never survivable: they get an `Error` frame and abort
//! the run regardless of policy.
//!
//! With `--checkpoint-dir`, every `--checkpoint-every`-th installed version
//! is persisted through [`super::fault::write_checkpoint`] (atomic
//! rename-on-write), and `--resume` restarts from `latest.ckpt`.

use std::io::Read;
use std::net::{TcpListener, TcpStream};
use std::ops::Range;
use std::path::PathBuf;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::config::{OnFailure, UpdateStrategy};
use crate::tensor::WeightSet;

use super::cluster::{AllocationSchedule, ClusterReport, VersionRecord};
use super::fault::{write_checkpoint, FaultStats};
use super::param_server::ParamServer;
use super::partition::reallocate;
use super::transport::{SubmitMode, DEFAULT_IO_TIMEOUT};
use super::wire::{read_msg, write_msg, Msg};

/// Configuration of one serving run.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Number of computing nodes; the run ends when every node slot has
    /// finished (or, under `OnFailure::Continue`, finished or died).
    pub nodes: usize,
    /// Update rule this server enforces: SGWU runs reject AGWU submissions
    /// and vice versa (`Plain` submissions ride under `Agwu`).
    pub update: UpdateStrategy,
    /// Log every installed version to stderr.
    pub verbose: bool,
    /// Policy when a worker's connection dies or its lease expires.
    pub on_failure: OnFailure,
    /// Per-connection read/write deadline; a peer silent for longer is
    /// declared dead. Zero disables the deadline (block forever).
    pub lease: Duration,
    /// Directory receiving periodic `latest.ckpt` weight checkpoints.
    pub checkpoint_dir: Option<PathBuf>,
    /// Checkpoint every this many installed versions (0 = never).
    pub checkpoint_every: usize,
    /// Global version the initial weights correspond to (nonzero when
    /// resuming from a checkpoint).
    pub init_version: usize,
    /// Whether `init` came from a loaded checkpoint (accounted in
    /// [`FaultStats::checkpoints_loaded`]).
    pub resumed: bool,
    /// Per-node IDPA sample schedule (one `Vec<Range>` per node, one range
    /// per iteration). Needed to re-allocate a dead node's remaining
    /// batches; without it, death under AGWU only shrinks the cluster.
    pub schedule: Option<AllocationSchedule>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            nodes: 1,
            update: UpdateStrategy::Agwu,
            verbose: false,
            on_failure: OnFailure::Abort,
            lease: DEFAULT_IO_TIMEOUT,
            checkpoint_dir: None,
            checkpoint_every: 0,
            init_version: 0,
            resumed: false,
            schedule: None,
        }
    }
}

/// Lifecycle of a node slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum NodeStatus {
    /// No connection has claimed this slot yet.
    Unclaimed,
    /// A live connection is serving this slot.
    Active,
    /// The node sent `Done`.
    Done,
    /// The node's connection died / lease expired.
    Dead,
}

/// Lock a poisoned-or-not mutex: a handler that panicked while holding the
/// state must not turn every other handler's next lock into an opaque
/// poison panic — the shared state stays usable and the `aborted` flag
/// (set by the panicking handler's error path or the supervisor) decides
/// whether the run survives.
fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

struct ServerState {
    ps: ParamServer,
    versions: Vec<VersionRecord>,
    /// SGWU: completed-round counter releasing the Eq. 8 barrier.
    round: usize,
    /// SGWU: per-node (loss, accuracy) of the filling round.
    round_meta: Vec<Option<(f64, f64)>>,
    /// Eq. 8 synchronization wait accumulated across nodes (SGWU only).
    sync_wait_s: f64,
    /// Per-node busy proxy: fetch-reply sent → submission received.
    /// Updated per submission so death-time re-allocation sees live values.
    node_busy: Vec<f64>,
    /// Per-node stall as seen from the server: the Eq. 8 barrier wait the
    /// node's submit spent blocked (0 for AGWU). Worker-side comm stall and
    /// overlap are only observable in the worker's own summary.
    node_stall: Vec<f64>,
    /// Submissions per node — the epoch count behind the measured
    /// throughput used for re-allocation.
    node_submits: Vec<usize>,
    status: Vec<NodeStatus>,
    /// Session epoch per slot: bumped when a reconnect supersedes an old
    /// connection, so the stale handler's death report is ignored.
    session: Vec<u64>,
    /// Re-allocated sample ranges awaiting delivery, piggybacked on each
    /// survivor's next fetch reply.
    pending_extras: Vec<Vec<Range<usize>>>,
    /// Fault-recovery accounting for the final report.
    fault: FaultStats,
    /// Highest version already checkpointed (dedups concurrent triggers).
    last_ckpt: u64,
    /// When the most recent node death was declared — starts the reconnect
    /// grace window once every node is dead.
    last_death: Option<Instant>,
    /// Set when the run must fail (protocol violation, all nodes dead, or
    /// any death under `OnFailure::Abort`) so barrier waiters don't hang.
    aborted: bool,
}

struct Shared {
    state: Mutex<ServerState>,
    round_cv: Condvar,
    t0: Instant,
    opts: ServeOptions,
}

/// Serve one training run on an already-bound listener (bind to port 0 and
/// read `listener.local_addr()` for ephemeral deployments). Blocks until
/// every node slot finished — or died, under `OnFailure::Continue` — then
/// returns the run's [`ClusterReport`].
pub fn serve(listener: TcpListener, init: WeightSet, opts: ServeOptions) -> Result<ClusterReport> {
    ensure!(opts.nodes > 0, "param server needs at least one node");
    if let Some(schedule) = &opts.schedule {
        ensure!(
            schedule.len() == opts.nodes,
            "schedule covers {} nodes, server has {}",
            schedule.len(),
            opts.nodes
        );
    }
    let nodes = opts.nodes;
    let shared = Arc::new(Shared {
        state: Mutex::new(ServerState {
            ps: ParamServer::with_version(init, nodes, opts.init_version),
            versions: Vec::new(),
            round: 0,
            round_meta: (0..nodes).map(|_| None).collect(),
            sync_wait_s: 0.0,
            node_busy: vec![0.0; nodes],
            node_stall: vec![0.0; nodes],
            node_submits: vec![0; nodes],
            status: vec![NodeStatus::Unclaimed; nodes],
            session: vec![0; nodes],
            pending_extras: vec![Vec::new(); nodes],
            fault: FaultStats {
                checkpoints_loaded: usize::from(opts.resumed),
                ..FaultStats::default()
            },
            last_ckpt: opts.init_version as u64,
            last_death: None,
            aborted: false,
        }),
        round_cv: Condvar::new(),
        t0: Instant::now(),
        opts,
    });

    // Poll-accept so the listener stays open for reconnecting workers and
    // the loop can notice completion/abort between connections.
    listener.set_nonblocking(true).context("nonblocking listener")?;
    let mut handles = Vec::with_capacity(nodes);
    loop {
        {
            let mut st = lock_recover(&shared.state);
            if st.aborted {
                break;
            }
            let finished = st
                .status
                .iter()
                .all(|s| matches!(s, NodeStatus::Done | NodeStatus::Dead));
            if finished {
                if st.status.iter().any(|s| *s == NodeStatus::Done) {
                    break;
                }
                // Every node is dead: hold the listener open for a
                // reconnect before declaring the run lost.
                let grace = if shared.opts.lease.is_zero() {
                    Duration::from_secs(2)
                } else {
                    shared.opts.lease * 2
                };
                let expired = st.last_death.map(|t| t.elapsed() >= grace).unwrap_or(true);
                if expired {
                    st.aborted = true;
                    break;
                }
            }
        }
        match listener.accept() {
            Ok((stream, peer)) => {
                if shared.opts.verbose {
                    eprintln!("param-server: worker connected from {peer}");
                }
                let sh = Arc::clone(&shared);
                handles.push(std::thread::spawn(move || handle_conn(stream, sh)));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(e).context("accept worker connection"),
        }
    }
    drop(listener);

    let mut failures: Vec<String> = Vec::new();
    for h in handles {
        match h.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => failures.push(format!("{e:#}")),
            Err(_) => failures.push("connection handler panicked".to_string()),
        }
    }
    let shared = Arc::try_unwrap(shared)
        .map_err(|_| anyhow!("handler threads still hold server state"))?;
    let wall_s = shared.t0.elapsed().as_secs_f64();
    ensure!(failures.is_empty(), "worker connections failed: {}", failures.join("; "));

    let mut st = shared.state.into_inner().unwrap_or_else(|e| e.into_inner());
    ensure!(
        !st.aborted,
        "run aborted: every worker died before the run completed"
    );
    // Final checkpoint so a resumed deployment can pick up the end state.
    if let Some(dir) = shared.opts.checkpoint_dir.as_ref() {
        let version = st.ps.version() as u64;
        if shared.opts.checkpoint_every > 0
            && (version > st.last_ckpt || st.fault.checkpoints_written == 0)
        {
            match write_checkpoint(dir, version, st.ps.global()) {
                Ok(()) => st.fault.checkpoints_written += 1,
                Err(e) => eprintln!("param-server: final checkpoint failed: {e:#}"),
            }
        }
    }
    st.versions.sort_by_key(|v| v.version);
    Ok(ClusterReport {
        strategy: shared.opts.update,
        versions: st.versions,
        comm: st.ps.comm.clone(),
        sync_wait_s: st.sync_wait_s,
        wall_s,
        node_busy_s: st.node_busy,
        node_stall_s: st.node_stall,
        node_overlap_s: vec![0.0; nodes],
        fault: st.fault,
        final_weights: st.ps.into_global(),
    })
}

/// Handler-local measured accounting, folded into the shared state exactly
/// once when the connection ends (valid because one connection = one node).
#[derive(Default)]
struct ConnAcct {
    wire_bytes: u64,
    fetch_wall_s: f64,
    submit_wall_s: f64,
    sync_wait_s: f64,
    last_fetch_reply: Option<Instant>,
}

/// Mark the run aborted and release any Eq. 8 barrier waiters so a dead
/// peer can't hang the round.
fn abort_run(shared: &Shared) {
    lock_recover(&shared.state).aborted = true;
    shared.round_cv.notify_all();
}

/// The innermost `std::io::Error` of an error chain, if any — the marker
/// distinguishing "the connection died" from a protocol violation.
fn io_cause(e: &anyhow::Error) -> Option<&std::io::Error> {
    e.chain().find_map(|c| c.downcast_ref::<std::io::Error>())
}

fn is_timeout(io: &std::io::Error) -> bool {
    matches!(io.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// Handle one node's death: shrink the SGWU quorum or re-allocate the
/// node's remaining AGWU batches over the survivors. Idempotent per
/// (node, session): a stale superseded handler reports nothing.
fn declare_dead(shared: &Shared, node: usize, session: u64, lease_expired: bool) {
    let mut st = lock_recover(&shared.state);
    if st.session[node] != session || st.status[node] != NodeStatus::Active {
        return; // superseded by a reconnect, or already resolved
    }
    st.status[node] = NodeStatus::Dead;
    st.last_death = Some(Instant::now());
    if lease_expired {
        st.fault.leases_expired += 1;
    }
    if !st.ps.mark_dead(node) {
        return;
    }
    if shared.opts.verbose {
        let why = if lease_expired { "lease expired" } else { "connection lost" };
        eprintln!("param-server: node {node} dead ({why})");
    }
    let update = shared.opts.update;
    match update {
        UpdateStrategy::Sgwu => {
            // The quorum shrank: a round waiting only on this node must
            // install now, not hang at the Eq. 8 barrier.
            if let Some(v) = st.ps.sgwu_try_install() {
                let at_s = shared.t0.elapsed().as_secs_f64();
                let mut l_sum = 0.0f64;
                let mut q_sum = 0.0f64;
                let mut parts = 0usize;
                for meta in st.round_meta.iter_mut() {
                    if let Some((l, q)) = meta.take() {
                        l_sum += l;
                        q_sum += q;
                        parts += 1;
                    }
                }
                let m = parts.max(1) as f64;
                st.versions.push(VersionRecord {
                    version: v,
                    node: usize::MAX,
                    local_loss: l_sum / m,
                    local_accuracy: q_sum / m,
                    at_s,
                    eval: None,
                });
                st.round += 1;
            }
        }
        UpdateStrategy::Agwu => reallocate_dead_node(shared, &mut st, node),
    }
    drop(st);
    shared.round_cv.notify_all();
}

/// Move a dead node's remaining schedule (plus its undelivered extras) onto
/// the survivors, weighted by measured epoch throughput.
fn reallocate_dead_node(shared: &Shared, st: &mut ServerState, node: usize) {
    let mut remaining: Vec<Range<usize>> = Vec::new();
    if let Some(schedule) = &shared.opts.schedule {
        let done = st.node_submits[node].min(schedule[node].len());
        remaining.extend(schedule[node][done..].iter().cloned());
    }
    remaining.append(&mut st.pending_extras[node]);
    if remaining.is_empty() {
        return;
    }
    let survivors: Vec<usize> = (0..shared.opts.nodes)
        .filter(|&j| {
            j != node && matches!(st.status[j], NodeStatus::Unclaimed | NodeStatus::Active)
        })
        .collect();
    if survivors.is_empty() {
        let lost: usize = remaining.iter().map(|r| r.len()).sum();
        eprintln!(
            "param-server: node {node} died with {lost} samples left and no \
             survivor to absorb them"
        );
        return;
    }
    let throughput: Vec<f64> = survivors
        .iter()
        .map(|&j| {
            if st.node_busy[j] > 0.0 {
                st.node_submits[j] as f64 / st.node_busy[j]
            } else {
                0.0
            }
        })
        .collect();
    let batches = remaining.len();
    let samples: usize = remaining.iter().map(|r| r.len()).sum();
    let parts = reallocate(&remaining, &throughput);
    for (slot, part) in survivors.iter().zip(parts) {
        st.pending_extras[*slot].extend(part);
    }
    st.fault.reallocated_batches += batches;
    st.fault.reallocated_samples += samples;
    if shared.opts.verbose {
        eprintln!(
            "param-server: re-allocated {batches} batches ({samples} samples) \
             from node {node} to {} survivors",
            survivors.len()
        );
    }
}

/// Plan a periodic checkpoint for freshly installed `version`: dedups under
/// the lock, returns the snapshot to persist once the lock is released.
fn plan_checkpoint(
    shared: &Shared,
    st: &mut ServerState,
    version: usize,
) -> Option<(PathBuf, u64, Arc<WeightSet>)> {
    let dir = shared.opts.checkpoint_dir.as_ref()?;
    let every = shared.opts.checkpoint_every;
    if every == 0 || version % every != 0 || version as u64 <= st.last_ckpt {
        return None;
    }
    st.last_ckpt = version as u64;
    Some((dir.clone(), version as u64, st.ps.global_arc()))
}

/// Persist a planned checkpoint (outside the state lock) and account it.
fn run_checkpoint(shared: &Shared, plan: Option<(PathBuf, u64, Arc<WeightSet>)>) {
    let Some((dir, version, snapshot)) = plan else { return };
    match write_checkpoint(&dir, version, &snapshot) {
        Ok(()) => {
            lock_recover(&shared.state).fault.checkpoints_written += 1;
            if shared.opts.verbose {
                eprintln!("param-server: checkpointed v{version}");
            }
        }
        Err(e) => eprintln!("param-server: checkpoint of v{version} failed: {e:#}"),
    }
}

/// Send a registration/protocol rejection: an `Error` frame, a short drain
/// so the peer can collect the frame, then mark the run aborted.
fn reject_conn(
    reader: &mut std::io::BufReader<TcpStream>,
    writer: &mut std::io::BufWriter<TcpStream>,
    shared: &Shared,
    why: String,
) -> anyhow::Error {
    let _ = write_msg(writer, &Msg::Error { msg: why.clone() });
    drain_for_error_delivery(reader);
    abort_run(shared);
    anyhow!(why)
}

/// Read (and discard) until the peer closes or a short deadline passes.
/// Closing immediately after an `Error` frame can reset the connection and
/// discard the frame from the peer's receive buffer; holding the read side
/// open until the peer hangs up makes the typed error reliably observable.
fn drain_for_error_delivery(reader: &mut std::io::BufReader<TcpStream>) {
    let _ = reader.get_ref().set_read_timeout(Some(Duration::from_secs(1)));
    let mut buf = [0u8; 4096];
    loop {
        match reader.read(&mut buf) {
            Ok(n) if n > 0 => {}
            _ => break,
        }
    }
}

/// Serve one node's connection: `Hello`, then fetch/submit rounds until
/// `Done` (or disconnect). Measured accounting is handler-local and folded
/// into the shared [`super::CommStats`] once, at the end.
fn handle_conn(stream: TcpStream, shared: Arc<Shared>) -> Result<()> {
    stream.set_nodelay(true).ok();
    let lease = Some(shared.opts.lease).filter(|d| !d.is_zero());
    stream.set_read_timeout(lease).context("set connection read deadline")?;
    stream.set_write_timeout(lease).context("set connection write deadline")?;
    let mut reader = std::io::BufReader::new(stream.try_clone().context("clone stream")?);
    let mut writer = std::io::BufWriter::new(stream);
    let mut acct = ConnAcct::default();

    // Registration.
    let (hello, hello_bytes) = match read_msg(&mut reader) {
        Ok(v) => v,
        Err(e) if io_cause(&e).is_some() => {
            // The connection died before registering: no slot to clean up
            // under Continue; any failure fails the run under Abort.
            return match shared.opts.on_failure {
                OnFailure::Continue => Ok(()),
                OnFailure::Abort => {
                    abort_run(&shared);
                    Err(e).context("reading hello")
                }
            };
        }
        Err(e) => {
            let why = format!("bad hello: {e:#}");
            return Err(reject_conn(&mut reader, &mut writer, &shared, why));
        }
    };
    acct.wire_bytes += hello_bytes as u64;
    let node = match hello {
        Msg::Hello { node } => node as usize,
        other => {
            let why = format!("expected hello, got {other:?}");
            return Err(reject_conn(&mut reader, &mut writer, &shared, why));
        }
    };
    let session = {
        let mut st = lock_recover(&shared.state);
        let rejection = if node >= shared.opts.nodes {
            Some(format!("node slot {node} out of range"))
        } else {
            match st.status[node] {
                NodeStatus::Unclaimed => None,
                NodeStatus::Dead => {
                    // Re-admission: the node comes back under the same id;
                    // its first fetch replays the current global snapshot.
                    st.ps.revive(node);
                    st.fault.reconnects += 1;
                    if shared.opts.verbose {
                        eprintln!("param-server: node {node} reconnected");
                    }
                    None
                }
                NodeStatus::Active if shared.opts.on_failure == OnFailure::Continue => {
                    // The old connection is still draining its lease;
                    // supersede it so the reconnect needn't wait it out.
                    st.fault.reconnects += 1;
                    if shared.opts.verbose {
                        eprintln!("param-server: node {node} superseded a stale session");
                    }
                    None
                }
                NodeStatus::Active | NodeStatus::Done => {
                    Some(format!("node slot {node} already claimed"))
                }
            }
        };
        match rejection {
            Some(why) => {
                drop(st);
                return Err(reject_conn(&mut reader, &mut writer, &shared, why));
            }
            None => {
                st.status[node] = NodeStatus::Active;
                st.session[node] += 1;
                st.session[node]
            }
        }
    };

    let result = serve_node(&mut reader, &mut writer, &shared, node, &mut acct);

    // Fold this node's measured accounting into the shared stats exactly
    // once per connection.
    {
        let mut st = lock_recover(&shared.state);
        st.ps.comm.wire_bytes += acct.wire_bytes;
        st.ps.comm.fetch_wall_s += acct.fetch_wall_s;
        st.ps.comm.submit_wall_s += acct.submit_wall_s;
        st.sync_wait_s += acct.sync_wait_s;
        st.node_stall[node] += acct.sync_wait_s;
        if result.is_ok() && st.session[node] == session {
            st.status[node] = NodeStatus::Done;
        }
    }

    let Err(err) = result else { return Ok(()) };
    match io_cause(&err) {
        // The connection died (EOF, reset, or lease timeout): a node
        // failure, handled per policy.
        Some(io) => {
            let lease_expired = is_timeout(io);
            match shared.opts.on_failure {
                OnFailure::Continue => {
                    declare_dead(&shared, node, session, lease_expired);
                    Ok(())
                }
                OnFailure::Abort => {
                    abort_run(&shared);
                    Err(err).with_context(|| format!("node {node} connection lost"))
                }
            }
        }
        // Protocol violation: report it to the peer (the socket is still
        // frame-aligned — decode errors happen after the full frame was
        // read) and fail the run regardless of policy.
        None => {
            let _ = write_msg(&mut writer, &Msg::Error { msg: format!("{err:#}") });
            drain_for_error_delivery(&mut reader);
            abort_run(&shared);
            Err(err).with_context(|| format!("serving node {node}"))
        }
    }
}

/// The per-connection request loop (registration already done).
fn serve_node(
    reader: &mut std::io::BufReader<TcpStream>,
    writer: &mut std::io::BufWriter<TcpStream>,
    shared: &Shared,
    node: usize,
    acct: &mut ConnAcct,
) -> Result<()> {
    loop {
        let (msg, nread) = read_msg(reader)?;
        acct.wire_bytes += nread as u64;
        match msg {
            Msg::Fetch => {
                let t_h = Instant::now();
                let (snapshot, version, extras) = {
                    let mut st = lock_recover(&shared.state);
                    let extras: Vec<(u64, u64)> = st.pending_extras[node]
                        .drain(..)
                        .map(|r| (r.start as u64, r.end as u64))
                        .collect();
                    let (snapshot, version) = st.ps.fetch(node);
                    (snapshot, version, extras)
                };
                let reply = Msg::Global {
                    version: version as u64,
                    reassigned: extras,
                    weights: (*snapshot).clone(),
                };
                acct.wire_bytes += write_msg(writer, &reply)? as u64;
                acct.fetch_wall_s += t_h.elapsed().as_secs_f64();
                acct.last_fetch_reply = Some(Instant::now());
            }
            Msg::Ping => {
                // Lease renewal: the read deadline restarted when the ping
                // arrived; the reply keeps the worker's side alive too.
                acct.wire_bytes += write_msg(writer, &Msg::Pong)? as u64;
            }
            Msg::Submit { mode, base, accuracy, loss, weights } => {
                let epoch_busy = acct
                    .last_fetch_reply
                    .take()
                    .map(|t| t.elapsed().as_secs_f64())
                    .unwrap_or(0.0);
                let t_h = Instant::now();
                let mut waited = 0.0f64;
                let mut ckpt = None;
                let version = {
                    let mut st = lock_recover(&shared.state);
                    st.node_busy[node] += epoch_busy;
                    let at_s = shared.t0.elapsed().as_secs_f64();
                    match (shared.opts.update, mode) {
                        (UpdateStrategy::Agwu, SubmitMode::Agwu)
                        | (UpdateStrategy::Agwu, SubmitMode::Plain) => {
                            let v = if mode == SubmitMode::Agwu {
                                st.ps.update_agwu(node, &weights, base as usize, accuracy)
                            } else {
                                st.ps.update_async_plain(node, &weights, base as usize)
                            };
                            st.node_submits[node] += 1;
                            st.versions.push(VersionRecord {
                                version: v,
                                node,
                                local_loss: loss,
                                local_accuracy: accuracy,
                                at_s,
                                eval: None,
                            });
                            if shared.opts.verbose {
                                eprintln!(
                                    "param-server: v{v} node {node} loss {loss:.4} acc {accuracy:.3}"
                                );
                            }
                            ckpt = plan_checkpoint(shared, &mut st, v);
                            v
                        }
                        (UpdateStrategy::Sgwu, SubmitMode::Sgwu) => {
                            if st.ps.sgwu_has_part(node) {
                                drop(st);
                                bail!(
                                    "node {node} already contributed to the current \
                                     SGWU round (duplicate or replayed submit)"
                                );
                            }
                            let my_round = st.round;
                            st.round_meta[node] = Some((loss, accuracy));
                            st.node_submits[node] += 1;
                            match st.ps.submit_sgwu(node, weights, accuracy) {
                                Some(v) => {
                                    let mut l_sum = 0.0f64;
                                    let mut q_sum = 0.0f64;
                                    let mut parts = 0usize;
                                    for meta in st.round_meta.iter_mut() {
                                        if let Some((l, q)) = meta.take() {
                                            l_sum += l;
                                            q_sum += q;
                                            parts += 1;
                                        }
                                    }
                                    let m = parts.max(1) as f64;
                                    st.versions.push(VersionRecord {
                                        version: v,
                                        node: usize::MAX,
                                        local_loss: l_sum / m,
                                        local_accuracy: q_sum / m,
                                        at_s,
                                        eval: None,
                                    });
                                    if shared.opts.verbose {
                                        eprintln!(
                                            "param-server: v{v} (SGWU round) mean loss {:.4}",
                                            l_sum / m
                                        );
                                    }
                                    st.round += 1;
                                    shared.round_cv.notify_all();
                                    ckpt = plan_checkpoint(shared, &mut st, v);
                                    v
                                }
                                None => {
                                    // Eq. 8: wait for the round's last node.
                                    let w0 = Instant::now();
                                    while st.round == my_round && !st.aborted {
                                        st = shared
                                            .round_cv
                                            .wait(st)
                                            .unwrap_or_else(|e| e.into_inner());
                                    }
                                    waited = w0.elapsed().as_secs_f64();
                                    acct.sync_wait_s += waited;
                                    if st.aborted {
                                        bail!("SGWU round aborted: the run failed");
                                    }
                                    st.ps.version()
                                }
                            }
                        }
                        (want, got) => {
                            drop(st);
                            bail!("server runs {want:?} but node submitted {got:?}");
                        }
                    }
                };
                acct.submit_wall_s += t_h.elapsed().as_secs_f64() - waited;
                acct.wire_bytes += write_msg(writer, &Msg::Ack { version: version as u64 })? as u64;
                run_checkpoint(shared, ckpt);
            }
            Msg::Done => return Ok(()),
            other => bail!("unexpected message from node {node}: {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::outer::transport::{ServerError, SubmitMeta, TcpTransport, Transport};
    use crate::tensor::Tensor;

    fn ws(vals: &[f32]) -> WeightSet {
        WeightSet::new(vec![Tensor::from_vec(&[vals.len()], vals.to_vec())])
    }

    fn spawn_server(
        init: WeightSet,
        opts: ServeOptions,
    ) -> (String, std::thread::JoinHandle<Result<ClusterReport>>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let h = std::thread::spawn(move || serve(listener, init, opts));
        (addr, h)
    }

    #[test]
    fn loopback_agwu_round_trip() {
        let opts = ServeOptions {
            nodes: 1,
            update: UpdateStrategy::Agwu,
            ..ServeOptions::default()
        };
        let (addr, server) = spawn_server(ws(&[1.0]), opts);
        let mut t = TcpTransport::connect(&addr, 0).unwrap();
        let (g, base) = t.fetch_global().unwrap();
        assert_eq!(base, 0);
        assert_eq!(g.tensors()[0].data(), &[1.0]);
        let mut local = (*g).clone();
        local.tensors_mut()[0].data_mut()[0] = 3.0;
        let meta = SubmitMeta {
            mode: SubmitMode::Agwu,
            base,
            accuracy: 0.5,
            loss: 0.9,
            want_snapshot: false,
        };
        let ack = t.submit(local, &meta).unwrap();
        assert_eq!(ack.version, 1);
        // W = 1 + 1·0.5·(3−1) = 2, visible in the next fetch.
        let (g2, v2) = t.fetch_global().unwrap();
        assert_eq!(v2, 1);
        assert_eq!(g2.tensors()[0].data(), &[2.0]);
        t.finish().unwrap();
        let report = server.join().unwrap().unwrap();
        assert_eq!(report.versions.len(), 1);
        assert_eq!(report.comm.fetches, 2);
        assert_eq!(report.comm.submits, 1);
        assert!(report.comm.wire_bytes > 0, "sockets must move real bytes");
        assert!(!report.fault.any(), "healthy run reports no fault events");
        assert_eq!(report.final_weights.tensors()[0].data(), &[2.0]);
        assert!(t.stats().wire_bytes > 0);
        // Connection setup is accounted separately from transfer walls.
        assert!(t.stats().connect_wall_s > 0.0);
        assert!(t.stats().fetch_wall_s > 0.0);
    }

    #[test]
    fn loopback_sgwu_barrier_blocks_until_round_completes() {
        let opts = ServeOptions {
            nodes: 2,
            update: UpdateStrategy::Sgwu,
            ..ServeOptions::default()
        };
        let (addr, server) = spawn_server(ws(&[0.0, 0.0]), opts);
        let addr2 = addr.clone();
        // Node 0 submits first and must block in submit() until node 1 arrives.
        let first = std::thread::spawn(move || {
            let mut t = TcpTransport::connect(&addr2, 0).unwrap();
            let meta = SubmitMeta {
                mode: SubmitMode::Sgwu,
                base: 0,
                accuracy: 0.5,
                loss: 1.0,
                want_snapshot: false,
            };
            let t_submit = Instant::now();
            let ack = t.submit(ws(&[2.0, 0.0]), &meta).unwrap();
            t.finish().unwrap();
            (ack.version, t_submit.elapsed().as_secs_f64())
        });
        std::thread::sleep(std::time::Duration::from_millis(150));
        let mut t1 = TcpTransport::connect(&addr, 1).unwrap();
        let meta = SubmitMeta {
            mode: SubmitMode::Sgwu,
            base: 0,
            accuracy: 0.5,
            loss: 1.0,
            want_snapshot: false,
        };
        let ack1 = t1.submit(ws(&[0.0, 4.0]), &meta).unwrap();
        t1.finish().unwrap();
        let (v0, blocked_s) = first.join().unwrap();
        assert_eq!((v0, ack1.version), (1, 1));
        assert!(blocked_s >= 0.1, "first submitter did not wait: {blocked_s}s");
        let report = server.join().unwrap().unwrap();
        assert_eq!(report.versions.len(), 1);
        assert_eq!(report.versions[0].node, usize::MAX);
        assert!(report.sync_wait_s >= 0.1, "Eq. 8 wait not accounted");
        assert_eq!(report.final_weights.tensors()[0].data(), &[1.0, 2.0]);
    }

    #[test]
    fn wrong_mode_rejected() {
        let opts = ServeOptions {
            nodes: 1,
            update: UpdateStrategy::Sgwu,
            ..ServeOptions::default()
        };
        let (addr, server) = spawn_server(ws(&[0.0]), opts);
        let mut t = TcpTransport::connect(&addr, 0).unwrap();
        let meta = SubmitMeta {
            mode: SubmitMode::Agwu,
            base: 0,
            accuracy: 1.0,
            loss: 1.0,
            want_snapshot: false,
        };
        let err = t.submit(ws(&[1.0]), &meta).unwrap_err();
        // The rejection is a *typed* server-side error, not a dead socket.
        assert!(
            err.downcast_ref::<ServerError>().is_some(),
            "want ServerError, got: {err:#}"
        );
        drop(t);
        assert!(server.join().unwrap().is_err());
    }

    #[test]
    fn bad_node_slot_rejected() {
        let opts = ServeOptions {
            nodes: 1,
            update: UpdateStrategy::Agwu,
            ..ServeOptions::default()
        };
        let (addr, server) = spawn_server(ws(&[0.0]), opts);
        let mut t = TcpTransport::connect(&addr, 5).unwrap();
        // The registration error surfaces on the first request.
        let err = t.fetch_global().unwrap_err();
        assert!(
            err.downcast_ref::<ServerError>().is_some(),
            "want ServerError, got: {err:#}"
        );
        drop(t);
        assert!(server.join().unwrap().is_err());
    }

    #[test]
    fn ping_renews_without_touching_state() {
        let opts = ServeOptions { nodes: 1, ..ServeOptions::default() };
        let (addr, server) = spawn_server(ws(&[1.0]), opts);
        let mut t = TcpTransport::connect(&addr, 0).unwrap();
        t.heartbeat().unwrap();
        t.heartbeat().unwrap();
        let (_, v) = t.fetch_global().unwrap();
        assert_eq!(v, 0, "pings must not install versions");
        t.finish().unwrap();
        let report = server.join().unwrap().unwrap();
        assert_eq!(report.comm.fetches, 1);
        assert_eq!(report.versions.len(), 0);
    }

    #[test]
    fn lease_expiry_kills_silent_worker_and_run_continues() {
        let opts = ServeOptions {
            nodes: 2,
            update: UpdateStrategy::Agwu,
            on_failure: OnFailure::Continue,
            lease: Duration::from_millis(200),
            ..ServeOptions::default()
        };
        let (addr, server) = spawn_server(ws(&[0.0]), opts);
        // Node 1 connects and goes silent: its lease must expire.
        let silent = TcpStream::connect(&addr).unwrap();
        let mut w = std::io::BufWriter::new(silent.try_clone().unwrap());
        write_msg(&mut w, &Msg::Hello { node: 1 }).unwrap();
        // Node 0 does real work and finishes.
        let mut t = TcpTransport::connect(&addr, 0).unwrap();
        let (g, base) = t.fetch_global().unwrap();
        let mut local = (*g).clone();
        local.tensors_mut()[0].data_mut()[0] = 1.0;
        let meta = SubmitMeta {
            mode: SubmitMode::Agwu,
            base,
            accuracy: 1.0,
            loss: 1.0,
            want_snapshot: false,
        };
        t.submit(local, &meta).unwrap();
        t.finish().unwrap();
        drop(w);
        drop(silent);
        let report = server.join().unwrap().unwrap();
        assert_eq!(report.versions.len(), 1, "survivor's work landed");
        // The silent node died by lease expiry or by the socket closing —
        // either way the run survived and the death was accounted.
        assert!(report.fault.leases_expired <= 1);
    }

    #[test]
    fn dead_worker_batches_reallocated_to_survivor() {
        let schedule: AllocationSchedule = vec![vec![0..10, 10..20], vec![20..30, 30..40]];
        let opts = ServeOptions {
            nodes: 2,
            update: UpdateStrategy::Agwu,
            on_failure: OnFailure::Continue,
            schedule: Some(schedule),
            ..ServeOptions::default()
        };
        let (addr, server) = spawn_server(ws(&[0.0]), opts);
        // Node 1 fetches once, then dies without a Done (socket drop = EOF).
        {
            let mut t1 = TcpTransport::connect(&addr, 1).unwrap();
            let _ = t1.fetch_global().unwrap();
        }
        // Node 0 runs its two iterations; the dead node's two batches must
        // arrive piggybacked on a later fetch.
        let mut t = TcpTransport::connect(&addr, 0).unwrap();
        let mut gained: Vec<Range<usize>> = Vec::new();
        for _ in 0..2 {
            let (g, base) = t.fetch_global().unwrap();
            gained.extend(t.take_reassigned());
            let mut local = (*g).clone();
            local.tensors_mut()[0].data_mut()[0] += 1.0;
            let meta = SubmitMeta {
                mode: SubmitMode::Agwu,
                base,
                accuracy: 1.0,
                loss: 1.0,
                want_snapshot: false,
            };
            t.submit(local, &meta).unwrap();
            // Give the server time to notice the EOF of node 1.
            std::thread::sleep(Duration::from_millis(50));
        }
        let (_, _) = t.fetch_global().unwrap();
        gained.extend(t.take_reassigned());
        t.finish().unwrap();
        let report = server.join().unwrap().unwrap();
        assert_eq!(report.fault.reallocated_batches, 2);
        assert_eq!(report.fault.reallocated_samples, 20);
        let gained_samples: usize = gained.iter().map(|r| r.len()).sum();
        assert_eq!(gained_samples, 20, "survivor received the dead node's samples");
    }

    #[test]
    fn reconnect_is_readmitted_and_replays_snapshot() {
        let opts = ServeOptions {
            nodes: 1,
            update: UpdateStrategy::Agwu,
            on_failure: OnFailure::Continue,
            // Grace window for all-dead reconnects is 2× the lease: plenty
            // of room for the 300ms gap below.
            lease: Duration::from_millis(500),
            ..ServeOptions::default()
        };
        let (addr, server) = spawn_server(ws(&[1.0]), opts);
        // First session: fetch + submit, then vanish without Done.
        {
            let mut t = TcpTransport::connect(&addr, 0).unwrap();
            let (g, base) = t.fetch_global().unwrap();
            let mut local = (*g).clone();
            local.tensors_mut()[0].data_mut()[0] = 3.0;
            let meta = SubmitMeta {
                mode: SubmitMode::Agwu,
                base,
                accuracy: 0.5,
                loss: 1.0,
                want_snapshot: false,
            };
            t.submit(local, &meta).unwrap();
        }
        // Second session under the same node id: must be re-admitted and
        // see the v1 snapshot the first session installed.
        std::thread::sleep(Duration::from_millis(300));
        let mut t = TcpTransport::connect(&addr, 0).unwrap();
        let (g, v) = t.fetch_global().unwrap();
        assert_eq!(v, 1);
        assert_eq!(g.tensors()[0].data(), &[2.0]);
        t.finish().unwrap();
        let report = server.join().unwrap().unwrap();
        assert_eq!(report.fault.reconnects, 1);
    }

    #[test]
    fn poisoned_state_lock_recovers() {
        // A panicking lock holder must not turn later lock attempts into
        // poison panics — lock_recover takes the data through the poison.
        let m = Arc::new(Mutex::new(7i32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.lock().is_err(), "lock must actually be poisoned");
        assert_eq!(*lock_recover(&m), 7);
        *lock_recover(&m) += 1;
        assert_eq!(*lock_recover(&m), 8);
    }
}
