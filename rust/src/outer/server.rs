//! Standalone parameter-server service: the §3.2.1 server node as a real
//! process. An accept loop takes one TCP connection per computing node,
//! each served by its own handler thread against the shared [`ParamServer`]
//! — the Eq. 7/Eq. 10 update rules run unchanged; only the node ↔ server
//! link is a socket instead of an `Arc` bump.
//!
//! SGWU's Eq. 8 barrier falls out of the protocol: a round part's `Ack` is
//! not written until the last node of the round arrives and the round is
//! installed, so the blocked socket *is* the synchronization wait (accounted
//! in `sync_wait_s` exactly like the in-process runner does).
//!
//! The service produces the same [`ClusterReport`] as the in-process
//! cluster: version log with per-submission loss/accuracy, Eq. 11 comm
//! accounting (logical bytes plus measured wire bytes and handling time),
//! per-node busy proxies (fetch-reply → submit-arrival spans), and the
//! final global weight set.

use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::config::UpdateStrategy;
use crate::tensor::WeightSet;

use super::cluster::{ClusterReport, VersionRecord};
use super::param_server::ParamServer;
use super::transport::SubmitMode;
use super::wire::{read_msg, write_msg, Msg};

/// Configuration of one serving run.
#[derive(Debug, Clone, Copy)]
pub struct ServeOptions {
    /// Number of computing nodes; the accept loop takes exactly this many
    /// connections and the run ends when every node sent `Done`.
    pub nodes: usize,
    /// Update rule this server enforces: SGWU runs reject AGWU submissions
    /// and vice versa (`Plain` submissions ride under `Agwu`).
    pub update: UpdateStrategy,
    /// Log every installed version to stderr.
    pub verbose: bool,
}

struct ServerState {
    ps: ParamServer,
    versions: Vec<VersionRecord>,
    /// SGWU: completed-round counter releasing the Eq. 8 barrier.
    round: usize,
    /// SGWU: per-node (loss, accuracy) of the filling round.
    round_meta: Vec<Option<(f64, f64)>>,
    /// Eq. 8 synchronization wait accumulated across nodes (SGWU only).
    sync_wait_s: f64,
    /// Per-node busy proxy: fetch-reply sent → submission received.
    node_busy: Vec<f64>,
    /// Per-node stall as seen from the server: the Eq. 8 barrier wait the
    /// node's submit spent blocked (0 for AGWU). Worker-side comm stall and
    /// overlap are only observable in the worker's own summary.
    node_stall: Vec<f64>,
    claimed: Vec<bool>,
    /// Set when a handler dies mid-run so barrier waiters don't hang.
    aborted: bool,
}

struct Shared {
    state: Mutex<ServerState>,
    round_cv: Condvar,
    t0: Instant,
    opts: ServeOptions,
}

/// Serve one training run on an already-bound listener (bind to port 0 and
/// read `listener.local_addr()` for ephemeral deployments). Blocks until
/// all `opts.nodes` workers connected, ran and sent `Done`, then returns
/// the run's [`ClusterReport`].
pub fn serve(listener: TcpListener, init: WeightSet, opts: ServeOptions) -> Result<ClusterReport> {
    ensure!(opts.nodes > 0, "param server needs at least one node");
    let shared = Arc::new(Shared {
        state: Mutex::new(ServerState {
            ps: ParamServer::new(init, opts.nodes),
            versions: Vec::new(),
            round: 0,
            round_meta: (0..opts.nodes).map(|_| None).collect(),
            sync_wait_s: 0.0,
            node_busy: vec![0.0; opts.nodes],
            node_stall: vec![0.0; opts.nodes],
            claimed: vec![false; opts.nodes],
            aborted: false,
        }),
        round_cv: Condvar::new(),
        t0: Instant::now(),
        opts,
    });

    let mut handles = Vec::with_capacity(opts.nodes);
    for _ in 0..opts.nodes {
        let (stream, peer) = listener.accept().context("accept worker connection")?;
        if opts.verbose {
            eprintln!("param-server: worker connected from {peer}");
        }
        let sh = Arc::clone(&shared);
        handles.push(std::thread::spawn(move || handle_conn(stream, sh)));
    }
    drop(listener);

    let mut failures: Vec<String> = Vec::new();
    for h in handles {
        match h.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => failures.push(format!("{e:#}")),
            Err(_) => failures.push("connection handler panicked".to_string()),
        }
    }
    let shared = Arc::try_unwrap(shared)
        .map_err(|_| anyhow!("handler threads still hold server state"))?;
    let wall_s = shared.t0.elapsed().as_secs_f64();
    ensure!(failures.is_empty(), "worker connections failed: {}", failures.join("; "));

    let mut st = shared.state.into_inner().unwrap();
    st.versions.sort_by_key(|v| v.version);
    let nodes = opts.nodes;
    Ok(ClusterReport {
        strategy: opts.update,
        versions: st.versions,
        comm: st.ps.comm.clone(),
        sync_wait_s: st.sync_wait_s,
        wall_s,
        node_busy_s: st.node_busy,
        node_stall_s: st.node_stall,
        node_overlap_s: vec![0.0; nodes],
        final_weights: st.ps.into_global(),
    })
}

/// Handler-local measured accounting, folded into the shared state exactly
/// once when the connection ends (valid because one connection = one node).
#[derive(Default)]
struct ConnAcct {
    wire_bytes: u64,
    fetch_wall_s: f64,
    submit_wall_s: f64,
    sync_wait_s: f64,
    busy_s: f64,
    last_fetch_reply: Option<Instant>,
}

/// Mark the run aborted and release any Eq. 8 barrier waiters so a dead
/// peer can't hang the round.
fn abort_run(shared: &Shared) {
    shared.state.lock().unwrap().aborted = true;
    shared.round_cv.notify_all();
}

/// Serve one node's connection: `Hello`, then fetch/submit rounds until
/// `Done` (or disconnect). Measured accounting is handler-local and folded
/// into the shared [`super::CommStats`] once, at the end.
fn handle_conn(stream: TcpStream, shared: Arc<Shared>) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = std::io::BufReader::new(stream.try_clone().context("clone stream")?);
    let mut writer = std::io::BufWriter::new(stream);
    let mut acct = ConnAcct::default();

    // Registration.
    let (hello, hello_bytes) = read_msg(&mut reader)?;
    acct.wire_bytes += hello_bytes as u64;
    let node = match hello {
        Msg::Hello { node } => node as usize,
        other => {
            let _ = write_msg(&mut writer, &Msg::Error { msg: "expected hello".into() });
            abort_run(&shared);
            bail!("expected hello, got {other:?}");
        }
    };
    {
        let mut st = shared.state.lock().unwrap();
        if node >= shared.opts.nodes || st.claimed[node] {
            st.aborted = true;
            shared.round_cv.notify_all();
            drop(st);
            let _ = write_msg(
                &mut writer,
                &Msg::Error { msg: format!("node slot {node} invalid or already claimed") },
            );
            bail!("node slot {node} invalid or already claimed");
        }
        st.claimed[node] = true;
    }

    let result = serve_node(&mut reader, &mut writer, &shared, node, &mut acct);

    // Fold this node's measured accounting into the shared stats exactly
    // once, and make sure barrier waiters can't hang on a dead peer.
    let mut st = shared.state.lock().unwrap();
    st.ps.comm.wire_bytes += acct.wire_bytes;
    st.ps.comm.fetch_wall_s += acct.fetch_wall_s;
    st.ps.comm.submit_wall_s += acct.submit_wall_s;
    st.sync_wait_s += acct.sync_wait_s;
    st.node_busy[node] += acct.busy_s;
    st.node_stall[node] += acct.sync_wait_s;
    if result.is_err() {
        st.aborted = true;
        shared.round_cv.notify_all();
    }
    result.with_context(|| format!("serving node {node}"))
}

/// The per-connection request loop (registration already done).
fn serve_node(
    reader: &mut std::io::BufReader<TcpStream>,
    writer: &mut std::io::BufWriter<TcpStream>,
    shared: &Shared,
    node: usize,
    acct: &mut ConnAcct,
) -> Result<()> {
    loop {
        let (msg, nread) = read_msg(reader)?;
        acct.wire_bytes += nread as u64;
        match msg {
            Msg::Fetch => {
                let t_h = Instant::now();
                let (snapshot, version) = {
                    let mut st = shared.state.lock().unwrap();
                    st.ps.fetch(node)
                };
                let reply = Msg::Global { version: version as u64, weights: (*snapshot).clone() };
                acct.wire_bytes += write_msg(writer, &reply)? as u64;
                acct.fetch_wall_s += t_h.elapsed().as_secs_f64();
                acct.last_fetch_reply = Some(Instant::now());
            }
            Msg::Submit { mode, base, accuracy, loss, weights } => {
                if let Some(t) = acct.last_fetch_reply.take() {
                    acct.busy_s += t.elapsed().as_secs_f64();
                }
                let t_h = Instant::now();
                let mut waited = 0.0f64;
                let version = {
                    let mut st = shared.state.lock().unwrap();
                    let at_s = shared.t0.elapsed().as_secs_f64();
                    match (shared.opts.update, mode) {
                        (UpdateStrategy::Agwu, SubmitMode::Agwu)
                        | (UpdateStrategy::Agwu, SubmitMode::Plain) => {
                            let v = if mode == SubmitMode::Agwu {
                                st.ps.update_agwu(node, &weights, base as usize, accuracy)
                            } else {
                                st.ps.update_async_plain(node, &weights, base as usize)
                            };
                            st.versions.push(VersionRecord {
                                version: v,
                                node,
                                local_loss: loss,
                                local_accuracy: accuracy,
                                at_s,
                                eval: None,
                            });
                            if shared.opts.verbose {
                                eprintln!(
                                    "param-server: v{v} node {node} loss {loss:.4} acc {accuracy:.3}"
                                );
                            }
                            v
                        }
                        (UpdateStrategy::Sgwu, SubmitMode::Sgwu) => {
                            let my_round = st.round;
                            st.round_meta[node] = Some((loss, accuracy));
                            match st.ps.submit_sgwu(node, weights, accuracy) {
                                Some(v) => {
                                    let m = shared.opts.nodes as f64;
                                    let (mut l_sum, mut q_sum) = (0.0f64, 0.0f64);
                                    for meta in st.round_meta.iter_mut() {
                                        let (l, q) = meta.take().expect("full round");
                                        l_sum += l;
                                        q_sum += q;
                                    }
                                    st.versions.push(VersionRecord {
                                        version: v,
                                        node: usize::MAX,
                                        local_loss: l_sum / m,
                                        local_accuracy: q_sum / m,
                                        at_s,
                                        eval: None,
                                    });
                                    if shared.opts.verbose {
                                        eprintln!(
                                            "param-server: v{v} (SGWU round) mean loss {:.4}",
                                            l_sum / m
                                        );
                                    }
                                    st.round += 1;
                                    shared.round_cv.notify_all();
                                    v
                                }
                                None => {
                                    // Eq. 8: wait for the round's last node.
                                    let w0 = Instant::now();
                                    while st.round == my_round && !st.aborted {
                                        st = shared.round_cv.wait(st).unwrap();
                                    }
                                    waited = w0.elapsed().as_secs_f64();
                                    acct.sync_wait_s += waited;
                                    if st.aborted {
                                        bail!("SGWU round aborted: a peer disconnected");
                                    }
                                    st.ps.version()
                                }
                            }
                        }
                        (want, got) => {
                            drop(st);
                            let msg = format!("server runs {want:?} but node submitted {got:?}");
                            let _ = write_msg(writer, &Msg::Error { msg: msg.clone() });
                            bail!("{msg}");
                        }
                    }
                };
                acct.submit_wall_s += t_h.elapsed().as_secs_f64() - waited;
                acct.wire_bytes += write_msg(writer, &Msg::Ack { version: version as u64 })? as u64;
            }
            Msg::Done => return Ok(()),
            other => bail!("unexpected message from node {node}: {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::outer::transport::{SubmitMeta, TcpTransport, Transport};
    use crate::tensor::Tensor;

    fn ws(vals: &[f32]) -> WeightSet {
        WeightSet::new(vec![Tensor::from_vec(&[vals.len()], vals.to_vec())])
    }

    fn spawn_server(
        init: WeightSet,
        opts: ServeOptions,
    ) -> (String, std::thread::JoinHandle<Result<ClusterReport>>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let h = std::thread::spawn(move || serve(listener, init, opts));
        (addr, h)
    }

    #[test]
    fn loopback_agwu_round_trip() {
        let opts =
            ServeOptions { nodes: 1, update: UpdateStrategy::Agwu, verbose: false };
        let (addr, server) = spawn_server(ws(&[1.0]), opts);
        let mut t = TcpTransport::connect(&addr, 0).unwrap();
        let (g, base) = t.fetch_global().unwrap();
        assert_eq!(base, 0);
        assert_eq!(g.tensors()[0].data(), &[1.0]);
        let mut local = (*g).clone();
        local.tensors_mut()[0].data_mut()[0] = 3.0;
        let meta = SubmitMeta {
            mode: SubmitMode::Agwu,
            base,
            accuracy: 0.5,
            loss: 0.9,
            want_snapshot: false,
        };
        let ack = t.submit(local, &meta).unwrap();
        assert_eq!(ack.version, 1);
        // W = 1 + 1·0.5·(3−1) = 2, visible in the next fetch.
        let (g2, v2) = t.fetch_global().unwrap();
        assert_eq!(v2, 1);
        assert_eq!(g2.tensors()[0].data(), &[2.0]);
        t.finish().unwrap();
        let report = server.join().unwrap().unwrap();
        assert_eq!(report.versions.len(), 1);
        assert_eq!(report.comm.fetches, 2);
        assert_eq!(report.comm.submits, 1);
        assert!(report.comm.wire_bytes > 0, "sockets must move real bytes");
        assert_eq!(report.final_weights.tensors()[0].data(), &[2.0]);
        assert!(t.stats().wire_bytes > 0);
        // Connection setup is accounted separately from transfer walls.
        assert!(t.stats().connect_wall_s > 0.0);
        assert!(t.stats().fetch_wall_s > 0.0);
    }

    #[test]
    fn loopback_sgwu_barrier_blocks_until_round_completes() {
        let opts =
            ServeOptions { nodes: 2, update: UpdateStrategy::Sgwu, verbose: false };
        let (addr, server) = spawn_server(ws(&[0.0, 0.0]), opts);
        let addr2 = addr.clone();
        // Node 0 submits first and must block in submit() until node 1 arrives.
        let first = std::thread::spawn(move || {
            let mut t = TcpTransport::connect(&addr2, 0).unwrap();
            let meta = SubmitMeta {
                mode: SubmitMode::Sgwu,
                base: 0,
                accuracy: 0.5,
                loss: 1.0,
                want_snapshot: false,
            };
            let t_submit = Instant::now();
            let ack = t.submit(ws(&[2.0, 0.0]), &meta).unwrap();
            t.finish().unwrap();
            (ack.version, t_submit.elapsed().as_secs_f64())
        });
        std::thread::sleep(std::time::Duration::from_millis(150));
        let mut t1 = TcpTransport::connect(&addr, 1).unwrap();
        let meta = SubmitMeta {
            mode: SubmitMode::Sgwu,
            base: 0,
            accuracy: 0.5,
            loss: 1.0,
            want_snapshot: false,
        };
        let ack1 = t1.submit(ws(&[0.0, 4.0]), &meta).unwrap();
        t1.finish().unwrap();
        let (v0, blocked_s) = first.join().unwrap();
        assert_eq!((v0, ack1.version), (1, 1));
        assert!(blocked_s >= 0.1, "first submitter did not wait: {blocked_s}s");
        let report = server.join().unwrap().unwrap();
        assert_eq!(report.versions.len(), 1);
        assert_eq!(report.versions[0].node, usize::MAX);
        assert!(report.sync_wait_s >= 0.1, "Eq. 8 wait not accounted");
        assert_eq!(report.final_weights.tensors()[0].data(), &[1.0, 2.0]);
    }

    #[test]
    fn wrong_mode_rejected() {
        let opts =
            ServeOptions { nodes: 1, update: UpdateStrategy::Sgwu, verbose: false };
        let (addr, server) = spawn_server(ws(&[0.0]), opts);
        let mut t = TcpTransport::connect(&addr, 0).unwrap();
        let meta = SubmitMeta {
            mode: SubmitMode::Agwu,
            base: 0,
            accuracy: 1.0,
            loss: 1.0,
            want_snapshot: false,
        };
        assert!(t.submit(ws(&[1.0]), &meta).is_err());
        assert!(server.join().unwrap().is_err());
    }

    #[test]
    fn bad_node_slot_rejected() {
        let opts =
            ServeOptions { nodes: 1, update: UpdateStrategy::Agwu, verbose: false };
        let (addr, server) = spawn_server(ws(&[0.0]), opts);
        let mut t = TcpTransport::connect(&addr, 5).unwrap();
        // The registration error surfaces on the first request.
        assert!(t.fetch_global().is_err());
        assert!(server.join().unwrap().is_err());
    }
}
