//! Standalone parameter-server service: the §3.2.1 server node as a real
//! process. An accept loop hands each TCP connection to a handler thread
//! serving the shared [`ParamServer`] — the Eq. 7/Eq. 10 update rules run
//! unchanged; only the node ↔ server link is a socket instead of an `Arc`
//! bump.
//!
//! SGWU's Eq. 8 barrier falls out of the protocol: a round part's `Ack` is
//! not written until the last node of the round arrives and the round is
//! installed, so the blocked socket *is* the synchronization wait (accounted
//! in `sync_wait_s` exactly like the in-process runner does).
//!
//! # Failure model
//!
//! Every connection carries a read/write deadline of [`ServeOptions::lease`]
//! — a peer that goes silent longer than its lease is declared dead (a hung
//! socket can no longer wedge the server). Worker death is a *scheduling
//! event*, not an error, when `--on-failure continue`:
//!
//! * **AGWU** — the run continues with the survivors; the dead node's
//!   remaining IDPA batches are re-allocated across survivors proportional
//!   to their measured epoch throughput ([`super::partition::reallocate`])
//!   and delivered piggybacked on their next fetch replies.
//! * **SGWU** — the Eq. 8 barrier quorum shrinks to the live nodes, so a
//!   round waiting only on the dead peer installs immediately.
//!
//! A worker reconnecting with the same node id is re-admitted (its old
//! session is superseded) and replays the current global snapshot with its
//! first fetch. Protocol violations (bad hello, wrong update mode, decode
//! rejections) are never survivable: they get an `Error` frame and abort
//! the run regardless of policy.
//!
//! With `--checkpoint-dir`, every `--checkpoint-every`-th installed version
//! is persisted through [`super::fault::write_checkpoint`] (atomic
//! rename-on-write), and `--resume` restarts from `latest.ckpt`.
//!
//! # High availability
//!
//! The server itself is replaceable. A primary given `--standby addr`
//! streams every committed update (and periodic full snapshots) to a warm
//! standby over a [`Msg::Replicate`] channel; the standby
//! ([`serve_standby`]) acks each event, tracks the primary's replication
//! lease, and on expiry *promotes* itself: it bumps the cluster epoch and
//! re-opens the worker accept loop ([`serve`]) from the last replicated
//! state. Epochs fence the old world — every `Hello` carries the highest
//! epoch the worker has observed (learned from `Global` replies), a
//! server that sees a higher epoch than its own stands down, and a stale
//! primary's replication hello is answered with [`Msg::Promote`]. Under
//! `--repl-ack standby` a worker's submit is not acked until the standby
//! acked the update (replication-before-ack), so promotion is lossless:
//! the standby's state is bit-identical to the last acked update.

use std::io::Read;
use std::net::{TcpListener, TcpStream};
use std::ops::Range;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::config::{OnFailure, ReplAck, UpdateStrategy};
use crate::tensor::WeightSet;

use super::cluster::{AllocationSchedule, ClusterReport, VersionRecord};
use super::fault::{write_checkpoint, FaultStats};
use super::param_server::ParamServer;
use super::partition::reallocate;
use super::transport::{SubmitMode, DEFAULT_IO_TIMEOUT};
use super::wire::{read_msg, write_msg, Msg, ReplEvent, REPL_NODE};

/// `ReplEvent::Update.node` sentinel for an SGWU round install (maps to
/// `VersionRecord.node == usize::MAX`). Distinct from [`REPL_NODE`], which
/// marks bootstrap snapshots that are not training updates.
const ROUND_NODE: u32 = u32::MAX - 1;

/// Configuration of one serving run.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Number of computing nodes; the run ends when every node slot has
    /// finished (or, under `OnFailure::Continue`, finished or died).
    pub nodes: usize,
    /// Update rule this server enforces: SGWU runs reject AGWU submissions
    /// and vice versa (`Plain` submissions ride under `Agwu`).
    pub update: UpdateStrategy,
    /// Log every installed version to stderr.
    pub verbose: bool,
    /// Policy when a worker's connection dies or its lease expires.
    pub on_failure: OnFailure,
    /// Per-connection read/write deadline; a peer silent for longer is
    /// declared dead. Zero disables the deadline (block forever).
    pub lease: Duration,
    /// Directory receiving periodic `latest.ckpt` weight checkpoints.
    pub checkpoint_dir: Option<PathBuf>,
    /// Checkpoint every this many installed versions (0 = never).
    pub checkpoint_every: usize,
    /// Global version the initial weights correspond to (nonzero when
    /// resuming from a checkpoint).
    pub init_version: usize,
    /// Whether `init` came from a loaded checkpoint (accounted in
    /// [`FaultStats::checkpoints_loaded`]).
    pub resumed: bool,
    /// Per-node IDPA sample schedule (one `Vec<Range>` per node, one range
    /// per iteration). Needed to re-allocate a dead node's remaining
    /// batches; without it, death under AGWU only shrinks the cluster.
    pub schedule: Option<AllocationSchedule>,
    /// Cluster epoch this server serves at: 0 for a fresh primary, the
    /// bumped epoch for a promoted standby. Stamped into every `Global`
    /// reply; a `Hello` carrying a *higher* epoch fences this server.
    pub epoch: u64,
    /// Address of a warm standby to replicate committed updates to.
    pub standby: Option<String>,
    /// Replication consistency: `Standby` holds each worker Ack until the
    /// standby acked the update (lossless promotion), `None` replicates
    /// asynchronously (promotion may lose acked-but-unreplicated tails).
    pub repl_ack: ReplAck,
    /// Under async replication, attach a full weight snapshot to every
    /// this-many-th replicated update (≥ 1; sync replication always
    /// snapshots).
    pub repl_snapshot_every: usize,
    /// Cooperative shutdown flag (SIGTERM/SIGINT): when raised, the server
    /// stops accepting, drains in-flight submits, writes a final
    /// checkpoint, and returns cleanly.
    pub shutdown: Option<Arc<AtomicBool>>,
    /// Promoted standby only: fail the run if no worker registers within
    /// this window — a promoted server nobody fails over to is a lost run.
    pub claim_deadline: Option<Duration>,
    /// This server is a promoted standby (accounts one failover).
    pub promoted: bool,
    /// Slots already `Done` before this server took over.
    pub pre_done: Vec<usize>,
    /// Slots already declared dead before this server took over.
    pub pre_dead: Vec<usize>,
    /// Per-node submit counts replicated from the predecessor, so
    /// throughput-weighted re-allocation keeps working across promotion.
    pub init_submits: Vec<usize>,
    /// Version history replicated from the predecessor, merged into the
    /// final report so loss/version trends span the promotion.
    pub pre_versions: Vec<VersionRecord>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            nodes: 1,
            update: UpdateStrategy::Agwu,
            verbose: false,
            on_failure: OnFailure::Abort,
            lease: DEFAULT_IO_TIMEOUT,
            checkpoint_dir: None,
            checkpoint_every: 0,
            init_version: 0,
            resumed: false,
            schedule: None,
            epoch: 0,
            standby: None,
            repl_ack: ReplAck::None,
            repl_snapshot_every: 8,
            shutdown: None,
            claim_deadline: None,
            promoted: false,
            pre_done: Vec::new(),
            pre_dead: Vec::new(),
            init_submits: Vec::new(),
            pre_versions: Vec::new(),
        }
    }
}

/// Lifecycle of a node slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum NodeStatus {
    /// No connection has claimed this slot yet.
    Unclaimed,
    /// A live connection is serving this slot.
    Active,
    /// The node sent `Done`.
    Done,
    /// The node's connection died / lease expired.
    Dead,
}

/// Lock a poisoned-or-not mutex: a handler that panicked while holding the
/// state must not turn every other handler's next lock into an opaque
/// poison panic — the shared state stays usable and the `aborted` flag
/// (set by the panicking handler's error path or the supervisor) decides
/// whether the run survives.
fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

struct ServerState {
    ps: ParamServer,
    versions: Vec<VersionRecord>,
    /// SGWU: completed-round counter releasing the Eq. 8 barrier.
    round: usize,
    /// SGWU: per-node (loss, accuracy) of the filling round.
    round_meta: Vec<Option<(f64, f64)>>,
    /// Eq. 8 synchronization wait accumulated across nodes (SGWU only).
    sync_wait_s: f64,
    /// Per-node busy proxy: fetch-reply sent → submission received.
    /// Updated per submission so death-time re-allocation sees live values.
    node_busy: Vec<f64>,
    /// Per-node stall as seen from the server: the Eq. 8 barrier wait the
    /// node's submit spent blocked (0 for AGWU). Worker-side comm stall and
    /// overlap are only observable in the worker's own summary.
    node_stall: Vec<f64>,
    /// Submissions per node — the epoch count behind the measured
    /// throughput used for re-allocation.
    node_submits: Vec<usize>,
    status: Vec<NodeStatus>,
    /// Session epoch per slot: bumped when a reconnect supersedes an old
    /// connection, so the stale handler's death report is ignored.
    session: Vec<u64>,
    /// Re-allocated sample ranges awaiting delivery, piggybacked on each
    /// survivor's next fetch reply.
    pending_extras: Vec<Vec<Range<usize>>>,
    /// Fault-recovery accounting for the final report.
    fault: FaultStats,
    /// Highest version already checkpointed (dedups concurrent triggers).
    last_ckpt: u64,
    /// When the most recent node death was declared — starts the reconnect
    /// grace window once every node is dead.
    last_death: Option<Instant>,
    /// Set when the run must fail (protocol violation, all nodes dead, or
    /// any death under `OnFailure::Abort`) so barrier waiters don't hang.
    aborted: bool,
    /// Queue into the replication thread (None: no standby configured, or
    /// the replicator shut down).
    repl: Option<mpsc::Sender<ReplCmd>>,
    /// Submit handlers currently between frame-read and Ack — the work a
    /// graceful shutdown drains before closing connections.
    active_submits: usize,
    /// Raised by a graceful shutdown: handlers treat connection errors as
    /// a quiet end instead of node death, barrier waiters are released.
    draining: bool,
    /// Successful registrations since this server started serving.
    claims: usize,
}

struct Shared {
    state: Mutex<ServerState>,
    round_cv: Condvar,
    t0: Instant,
    opts: ServeOptions,
    /// Clones of every live connection, so a graceful shutdown can unblock
    /// handlers parked in `read_msg` by closing the sockets under them.
    conns: Mutex<Vec<TcpStream>>,
}

// ---------------------------------------------------------------------------
// Replication (primary side)
// ---------------------------------------------------------------------------

/// Commands into the replication thread.
enum ReplCmd {
    /// Ship one event; if `done` is present (replication-before-ack) the
    /// sender blocks until the standby acked — the channel is dropped
    /// (releasing the waiter) even when replication degrades.
    Event { ev: ReplEvent, done: Option<mpsc::SyncSender<()>> },
    /// End of run: tell the standby not to promote, then exit.
    Shutdown,
}

/// The primary's replication worker: owns the TCP link to the standby,
/// ships events in commit order, keeps the standby's lease warm with
/// pings, and fences the primary when the standby says it promoted.
struct ReplWorker {
    addr: String,
    epoch: u64,
    lease: Duration,
    /// (version, weights) to bootstrap a fresh standby with on connect.
    boot: (u64, WeightSet),
    fenced: Arc<AtomicU64>,
    link: Option<(std::io::BufReader<TcpStream>, std::io::BufWriter<TcpStream>)>,
    degraded_logged: bool,
}

impl ReplWorker {
    fn connect(&mut self) -> Result<()> {
        let stream = TcpStream::connect(&self.addr)
            .with_context(|| format!("dial standby {}", self.addr))?;
        stream.set_nodelay(true).ok();
        let lease = Some(self.lease).filter(|d| !d.is_zero());
        stream.set_read_timeout(lease).context("standby read deadline")?;
        stream.set_write_timeout(lease).context("standby write deadline")?;
        let mut reader = std::io::BufReader::new(stream.try_clone().context("clone stream")?);
        let mut writer = std::io::BufWriter::new(stream);
        write_msg(&mut writer, &Msg::Hello { node: REPL_NODE, epoch: self.epoch })?;
        // Bootstrap snapshot: a standby that just started (or lost its
        // state) gets a full base to apply later deltas against. Its ack
        // doubles as the channel handshake — and a promoted ex-standby
        // answers with `Promote` here, fencing us immediately.
        let boot = Msg::Replicate {
            epoch: self.epoch,
            event: ReplEvent::Update {
                version: self.boot.0,
                node: REPL_NODE,
                loss: 0.0,
                accuracy: 0.0,
                at_s: 0.0,
                weights: Some(self.boot.1.clone()),
            },
        };
        write_msg(&mut writer, &boot)?;
        match read_msg(&mut reader)?.0 {
            Msg::ReplAck { .. } => {
                self.link = Some((reader, writer));
                self.degraded_logged = false;
                Ok(())
            }
            Msg::Promote { epoch } => {
                self.fenced.store(epoch.max(1), Ordering::SeqCst);
                bail!("standby already promoted to epoch {epoch}")
            }
            other => bail!("unexpected standby handshake reply: {other:?}"),
        }
    }

    /// Ship `msg` and wait for the standby's ack; one reconnect attempt on
    /// a broken link. Returns false when replication is degraded (standby
    /// unreachable) or the primary got fenced.
    fn ship(&mut self, msg: &Msg) -> bool {
        for _ in 0..2 {
            if self.fenced.load(Ordering::SeqCst) != 0 {
                return false;
            }
            if self.link.is_none() && self.connect().is_err() {
                continue;
            }
            let Some((reader, writer)) = self.link.as_mut() else { continue };
            let reply = write_msg(writer, msg).and_then(|_| read_msg(reader).map(|(m, _)| m));
            match reply {
                Ok(Msg::ReplAck { .. }) | Ok(Msg::Pong) => return true,
                Ok(Msg::Promote { epoch }) => {
                    self.fenced.store(epoch.max(1), Ordering::SeqCst);
                    self.link = None;
                    return false;
                }
                Ok(_) | Err(_) => self.link = None,
            }
        }
        if !self.degraded_logged {
            self.degraded_logged = true;
            eprintln!(
                "param-server: replication to {} degraded (standby unreachable); \
                 continuing without a warm standby",
                self.addr
            );
        }
        false
    }

    fn run(mut self, rx: mpsc::Receiver<ReplCmd>) {
        if self.connect().is_err() && !self.degraded_logged {
            self.degraded_logged = true;
            eprintln!(
                "param-server: standby {} unreachable at startup; replication degraded",
                self.addr
            );
        }
        let keepalive = if self.lease.is_zero() {
            Duration::from_secs(5)
        } else {
            (self.lease / 3).max(Duration::from_millis(20))
        };
        loop {
            match rx.recv_timeout(keepalive) {
                Ok(ReplCmd::Event { ev, done }) => {
                    let msg = Msg::Replicate { epoch: self.epoch, event: ev };
                    self.ship(&msg);
                    // Complete (or abandon) the replication-before-ack
                    // waiter either way: a degraded primary keeps serving.
                    drop(done);
                }
                Ok(ReplCmd::Shutdown) | Err(mpsc::RecvTimeoutError::Disconnected) => {
                    // Clean end of run: the standby must not promote.
                    if let Some((_, writer)) = self.link.as_mut() {
                        let _ = write_msg(writer, &Msg::Done);
                    }
                    return;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    // Keep the standby's replication lease warm.
                    if self.link.is_some() {
                        self.ship(&Msg::Ping);
                    }
                    if self.fenced.load(Ordering::SeqCst) != 0 {
                        return;
                    }
                }
            }
        }
    }
}

/// Enqueue replication of freshly installed `version` (under the state
/// lock, so events leave in commit order). Under `--repl-ack standby`
/// returns the receiver the caller must block on — *outside* the lock —
/// before acking the worker.
fn plan_replication(
    shared: &Shared,
    st: &mut ServerState,
    version: usize,
    node: u32,
    loss: f64,
    accuracy: f64,
    at_s: f64,
) -> Option<mpsc::Receiver<()>> {
    let tx = st.repl.as_ref()?;
    let sync = shared.opts.repl_ack == ReplAck::Standby;
    let every = shared.opts.repl_snapshot_every.max(1);
    let snapshot = sync || version % every == 0;
    let weights = snapshot.then(|| (*st.ps.global_arc()).clone());
    let ev = ReplEvent::Update { version: version as u64, node, loss, accuracy, at_s, weights };
    let (done_tx, done_rx) = if sync {
        let (tx, rx) = mpsc::sync_channel(1);
        (Some(tx), Some(rx))
    } else {
        (None, None)
    };
    if tx.send(ReplCmd::Event { ev, done: done_tx }).is_err() {
        st.repl = None; // replicator gone: degrade to no replication
        return None;
    }
    done_rx
}

/// Fire-and-forget replication of a lifecycle event (node done/dead).
fn replicate_async(st: &mut ServerState, ev: ReplEvent) {
    if let Some(tx) = &st.repl {
        if tx.send(ReplCmd::Event { ev, done: None }).is_err() {
            st.repl = None;
        }
    }
}

/// Serve one training run on an already-bound listener (bind to port 0 and
/// read `listener.local_addr()` for ephemeral deployments). Blocks until
/// every node slot finished — or died, under `OnFailure::Continue` — then
/// returns the run's [`ClusterReport`].
pub fn serve(listener: TcpListener, init: WeightSet, opts: ServeOptions) -> Result<ClusterReport> {
    ensure!(opts.nodes > 0, "param server needs at least one node");
    if let Some(schedule) = &opts.schedule {
        ensure!(
            schedule.len() == opts.nodes,
            "schedule covers {} nodes, server has {}",
            schedule.len(),
            opts.nodes
        );
    }
    let nodes = opts.nodes;
    // A standby replicator needs a bootstrap snapshot captured before the
    // weights move into the ParamServer.
    let boot = opts.standby.as_ref().map(|_| (opts.init_version as u64, init.clone()));
    let mut ps = ParamServer::with_version(init, nodes, opts.init_version);
    let mut status = vec![NodeStatus::Unclaimed; nodes];
    for &n in opts.pre_done.iter().filter(|&&n| n < nodes) {
        status[n] = NodeStatus::Done;
    }
    for &n in opts.pre_dead.iter().filter(|&&n| n < nodes) {
        status[n] = NodeStatus::Dead;
        ps.mark_dead(n);
    }
    let mut node_submits = vec![0usize; nodes];
    for (slot, &c) in node_submits.iter_mut().zip(opts.init_submits.iter()) {
        *slot = c;
    }
    let any_pre_dead = opts.pre_dead.iter().any(|&n| n < nodes);
    let shared = Arc::new(Shared {
        state: Mutex::new(ServerState {
            ps,
            versions: opts.pre_versions.clone(),
            round: 0,
            round_meta: (0..nodes).map(|_| None).collect(),
            sync_wait_s: 0.0,
            node_busy: vec![0.0; nodes],
            node_stall: vec![0.0; nodes],
            node_submits,
            status,
            session: vec![0; nodes],
            pending_extras: vec![Vec::new(); nodes],
            fault: FaultStats {
                checkpoints_loaded: usize::from(opts.resumed),
                failovers: usize::from(opts.promoted),
                ..FaultStats::default()
            },
            last_ckpt: opts.init_version as u64,
            last_death: any_pre_dead.then(Instant::now),
            aborted: false,
            repl: None,
            active_submits: 0,
            draining: false,
            claims: 0,
        }),
        round_cv: Condvar::new(),
        t0: Instant::now(),
        opts,
        conns: Mutex::new(Vec::new()),
    });

    // A promoted standby re-allocates the pre-dead nodes' remaining
    // batches exactly like a live death would have.
    if any_pre_dead && shared.opts.update == UpdateStrategy::Agwu {
        let mut st = lock_recover(&shared.state);
        let dead: Vec<usize> =
            shared.opts.pre_dead.iter().copied().filter(|&n| n < nodes).collect();
        for n in dead {
            reallocate_dead_node(&shared, &mut st, n);
        }
    }

    // Start the replication worker before any worker can submit, so no
    // committed update precedes the channel.
    let fenced = Arc::new(AtomicU64::new(0));
    let replicator = shared.opts.standby.clone().map(|addr| {
        let (tx, rx) = mpsc::channel();
        lock_recover(&shared.state).repl = Some(tx.clone());
        let worker = ReplWorker {
            addr,
            epoch: shared.opts.epoch,
            lease: shared.opts.lease,
            boot: boot.expect("bootstrap snapshot captured when standby is set"),
            fenced: Arc::clone(&fenced),
            link: None,
            degraded_logged: false,
        };
        (tx, std::thread::spawn(move || worker.run(rx)))
    });

    // Poll-accept so the listener stays open for reconnecting workers and
    // the loop can notice completion/abort between connections.
    listener.set_nonblocking(true).context("nonblocking listener")?;
    let mut handles = Vec::with_capacity(nodes);
    let mut graceful = false;
    let mut claim_timeout = false;
    loop {
        if let Some(flag) = shared.opts.shutdown.as_ref() {
            if flag.load(Ordering::SeqCst) {
                // Graceful shutdown: stop accepting and start draining.
                lock_recover(&shared.state).draining = true;
                shared.round_cv.notify_all();
                graceful = true;
                break;
            }
        }
        if fenced.load(Ordering::SeqCst) != 0 {
            // The standby promoted past us: stand down immediately so two
            // servers never serve the same cluster.
            abort_run(&shared);
            break;
        }
        {
            let mut st = lock_recover(&shared.state);
            if st.aborted {
                break;
            }
            if let Some(deadline) = shared.opts.claim_deadline {
                if st.claims == 0 && shared.t0.elapsed() >= deadline {
                    st.aborted = true;
                    claim_timeout = true;
                    drop(st);
                    shared.round_cv.notify_all();
                    break;
                }
            }
            let finished = st
                .status
                .iter()
                .all(|s| matches!(s, NodeStatus::Done | NodeStatus::Dead));
            if finished {
                if st.status.iter().any(|s| *s == NodeStatus::Done) {
                    break;
                }
                // Every node is dead: hold the listener open for a
                // reconnect before declaring the run lost.
                let grace = if shared.opts.lease.is_zero() {
                    Duration::from_secs(2)
                } else {
                    shared.opts.lease * 2
                };
                let expired = st.last_death.map(|t| t.elapsed() >= grace).unwrap_or(true);
                if expired {
                    st.aborted = true;
                    break;
                }
            }
        }
        match listener.accept() {
            Ok((stream, peer)) => {
                if shared.opts.verbose {
                    eprintln!("param-server: worker connected from {peer}");
                }
                let sh = Arc::clone(&shared);
                handles.push(std::thread::spawn(move || handle_conn(stream, sh)));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(e).context("accept worker connection"),
        }
    }
    drop(listener);

    if graceful {
        // Drain: give in-flight submits a bounded window to reach their
        // Ack, then close every connection to unblock parked readers.
        let t_drain = Instant::now();
        while t_drain.elapsed() < Duration::from_secs(1) {
            if lock_recover(&shared.state).active_submits == 0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        for conn in lock_recover(&shared.conns).iter() {
            let _ = conn.shutdown(std::net::Shutdown::Both);
        }
    }

    let mut failures: Vec<String> = Vec::new();
    for h in handles {
        match h.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => failures.push(format!("{e:#}")),
            Err(_) => failures.push("connection handler panicked".to_string()),
        }
    }
    // Stop the replicator (sending the standby a clean `Done`) before
    // unwrapping the shared state.
    if let Some((tx, handle)) = replicator {
        lock_recover(&shared.state).repl = None;
        let _ = tx.send(ReplCmd::Shutdown);
        let _ = handle.join();
    }
    let shared = Arc::try_unwrap(shared)
        .map_err(|_| anyhow!("handler threads still hold server state"))?;
    let wall_s = shared.t0.elapsed().as_secs_f64();
    let fence_epoch = fenced.load(Ordering::SeqCst);
    if fence_epoch != 0 {
        bail!(
            "fenced: standby promoted to cluster epoch {fence_epoch}; \
             this primary stood down"
        );
    }
    if claim_timeout {
        bail!(
            "promoted standby: no worker failed over within {:?}",
            shared.opts.claim_deadline.unwrap_or_default()
        );
    }
    ensure!(failures.is_empty(), "worker connections failed: {}", failures.join("; "));

    let mut st = shared.state.into_inner().unwrap_or_else(|e| e.into_inner());
    ensure!(
        graceful || !st.aborted,
        "run aborted: every worker died before the run completed"
    );
    // Final checkpoint so a resumed deployment can pick up the end state.
    // A graceful shutdown always checkpoints (that is its contract), even
    // when periodic checkpointing is off.
    if let Some(dir) = shared.opts.checkpoint_dir.as_ref() {
        let version = st.ps.version() as u64;
        if (shared.opts.checkpoint_every > 0 || graceful)
            && (version > st.last_ckpt || st.fault.checkpoints_written == 0)
        {
            match write_checkpoint(dir, version, st.ps.global()) {
                Ok(()) => st.fault.checkpoints_written += 1,
                Err(e) => eprintln!("param-server: final checkpoint failed: {e:#}"),
            }
        }
    }
    if graceful {
        eprintln!(
            "param-server: graceful shutdown at v{} (in-flight submits drained)",
            st.ps.version()
        );
    }
    st.versions.sort_by_key(|v| v.version);
    Ok(ClusterReport {
        strategy: shared.opts.update,
        versions: st.versions,
        comm: st.ps.comm.clone(),
        sync_wait_s: st.sync_wait_s,
        wall_s,
        node_busy_s: st.node_busy,
        node_stall_s: st.node_stall,
        node_overlap_s: vec![0.0; nodes],
        fault: st.fault,
        final_weights: st.ps.into_global(),
    })
}

/// Handler-local measured accounting, folded into the shared state exactly
/// once when the connection ends (valid because one connection = one node).
#[derive(Default)]
struct ConnAcct {
    wire_bytes: u64,
    fetch_wall_s: f64,
    submit_wall_s: f64,
    sync_wait_s: f64,
    last_fetch_reply: Option<Instant>,
}

/// RAII decrement of the graceful-shutdown drain counter: `active_submits`
/// must fall even when a submit path bails early.
struct SubmitGuard<'a>(&'a Shared);

impl Drop for SubmitGuard<'_> {
    fn drop(&mut self) {
        let mut st = lock_recover(&self.0.state);
        st.active_submits = st.active_submits.saturating_sub(1);
    }
}

/// Mark the run aborted and release any Eq. 8 barrier waiters so a dead
/// peer can't hang the round.
fn abort_run(shared: &Shared) {
    lock_recover(&shared.state).aborted = true;
    shared.round_cv.notify_all();
}

/// The innermost `std::io::Error` of an error chain, if any — the marker
/// distinguishing "the connection died" from a protocol violation.
fn io_cause(e: &anyhow::Error) -> Option<&std::io::Error> {
    e.chain().find_map(|c| c.downcast_ref::<std::io::Error>())
}

fn is_timeout(io: &std::io::Error) -> bool {
    matches!(io.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// Handle one node's death: shrink the SGWU quorum or re-allocate the
/// node's remaining AGWU batches over the survivors. Idempotent per
/// (node, session): a stale superseded handler reports nothing.
fn declare_dead(shared: &Shared, node: usize, session: u64, lease_expired: bool) {
    let mut st = lock_recover(&shared.state);
    if st.session[node] != session || st.status[node] != NodeStatus::Active {
        return; // superseded by a reconnect, or already resolved
    }
    st.status[node] = NodeStatus::Dead;
    st.last_death = Some(Instant::now());
    if lease_expired {
        st.fault.leases_expired += 1;
    }
    if !st.ps.mark_dead(node) {
        return;
    }
    if shared.opts.verbose {
        let why = if lease_expired { "lease expired" } else { "connection lost" };
        eprintln!("param-server: node {node} dead ({why})");
    }
    replicate_async(&mut st, ReplEvent::NodeDead { node: node as u32 });
    let update = shared.opts.update;
    match update {
        UpdateStrategy::Sgwu => {
            // The quorum shrank: a round waiting only on this node must
            // install now, not hang at the Eq. 8 barrier.
            if let Some(v) = st.ps.sgwu_try_install() {
                let at_s = shared.t0.elapsed().as_secs_f64();
                let mut l_sum = 0.0f64;
                let mut q_sum = 0.0f64;
                let mut parts = 0usize;
                for meta in st.round_meta.iter_mut() {
                    if let Some((l, q)) = meta.take() {
                        l_sum += l;
                        q_sum += q;
                        parts += 1;
                    }
                }
                let m = parts.max(1) as f64;
                st.versions.push(VersionRecord {
                    version: v,
                    node: usize::MAX,
                    local_loss: l_sum / m,
                    local_accuracy: q_sum / m,
                    at_s,
                    eval: None,
                });
                st.round += 1;
            }
        }
        UpdateStrategy::Agwu => reallocate_dead_node(shared, &mut st, node),
    }
    drop(st);
    shared.round_cv.notify_all();
}

/// Move a dead node's remaining schedule (plus its undelivered extras) onto
/// the survivors, weighted by measured epoch throughput.
fn reallocate_dead_node(shared: &Shared, st: &mut ServerState, node: usize) {
    let mut remaining: Vec<Range<usize>> = Vec::new();
    if let Some(schedule) = &shared.opts.schedule {
        let done = st.node_submits[node].min(schedule[node].len());
        remaining.extend(schedule[node][done..].iter().cloned());
    }
    remaining.append(&mut st.pending_extras[node]);
    if remaining.is_empty() {
        return;
    }
    let survivors: Vec<usize> = (0..shared.opts.nodes)
        .filter(|&j| {
            j != node && matches!(st.status[j], NodeStatus::Unclaimed | NodeStatus::Active)
        })
        .collect();
    if survivors.is_empty() {
        let lost: usize = remaining.iter().map(|r| r.len()).sum();
        eprintln!(
            "param-server: node {node} died with {lost} samples left and no \
             survivor to absorb them"
        );
        return;
    }
    let throughput: Vec<f64> = survivors
        .iter()
        .map(|&j| {
            if st.node_busy[j] > 0.0 {
                st.node_submits[j] as f64 / st.node_busy[j]
            } else {
                0.0
            }
        })
        .collect();
    let batches = remaining.len();
    let samples: usize = remaining.iter().map(|r| r.len()).sum();
    let parts = reallocate(&remaining, &throughput);
    for (slot, part) in survivors.iter().zip(parts) {
        st.pending_extras[*slot].extend(part);
    }
    st.fault.reallocated_batches += batches;
    st.fault.reallocated_samples += samples;
    if shared.opts.verbose {
        eprintln!(
            "param-server: re-allocated {batches} batches ({samples} samples) \
             from node {node} to {} survivors",
            survivors.len()
        );
    }
}

/// Plan a periodic checkpoint for freshly installed `version`: dedups under
/// the lock, returns the snapshot to persist once the lock is released.
fn plan_checkpoint(
    shared: &Shared,
    st: &mut ServerState,
    version: usize,
) -> Option<(PathBuf, u64, Arc<WeightSet>)> {
    let dir = shared.opts.checkpoint_dir.as_ref()?;
    let every = shared.opts.checkpoint_every;
    if every == 0 || version % every != 0 || version as u64 <= st.last_ckpt {
        return None;
    }
    st.last_ckpt = version as u64;
    Some((dir.clone(), version as u64, st.ps.global_arc()))
}

/// Persist a planned checkpoint (outside the state lock) and account it.
fn run_checkpoint(shared: &Shared, plan: Option<(PathBuf, u64, Arc<WeightSet>)>) {
    let Some((dir, version, snapshot)) = plan else { return };
    match write_checkpoint(&dir, version, &snapshot) {
        Ok(()) => {
            lock_recover(&shared.state).fault.checkpoints_written += 1;
            if shared.opts.verbose {
                eprintln!("param-server: checkpointed v{version}");
            }
        }
        Err(e) => eprintln!("param-server: checkpoint of v{version} failed: {e:#}"),
    }
}

/// Send a registration/protocol rejection: an `Error` frame, a short drain
/// so the peer can collect the frame, then mark the run aborted.
fn reject_conn(
    reader: &mut std::io::BufReader<TcpStream>,
    writer: &mut std::io::BufWriter<TcpStream>,
    shared: &Shared,
    why: String,
) -> anyhow::Error {
    let _ = write_msg(writer, &Msg::Error { msg: why.clone() });
    drain_for_error_delivery(reader);
    abort_run(shared);
    anyhow!(why)
}

/// Read (and discard) until the peer closes or a short deadline passes.
/// Closing immediately after an `Error` frame can reset the connection and
/// discard the frame from the peer's receive buffer; holding the read side
/// open until the peer hangs up makes the typed error reliably observable.
fn drain_for_error_delivery(reader: &mut std::io::BufReader<TcpStream>) {
    let _ = reader.get_ref().set_read_timeout(Some(Duration::from_secs(1)));
    let mut buf = [0u8; 4096];
    loop {
        match reader.read(&mut buf) {
            Ok(n) if n > 0 => {}
            _ => break,
        }
    }
}

/// Serve one node's connection: `Hello`, then fetch/submit rounds until
/// `Done` (or disconnect). Measured accounting is handler-local and folded
/// into the shared [`super::CommStats`] once, at the end.
fn handle_conn(stream: TcpStream, shared: Arc<Shared>) -> Result<()> {
    stream.set_nodelay(true).ok();
    let lease = Some(shared.opts.lease).filter(|d| !d.is_zero());
    stream.set_read_timeout(lease).context("set connection read deadline")?;
    stream.set_write_timeout(lease).context("set connection write deadline")?;
    if let Ok(clone) = stream.try_clone() {
        lock_recover(&shared.conns).push(clone);
    }
    let mut reader = std::io::BufReader::new(stream.try_clone().context("clone stream")?);
    let mut writer = std::io::BufWriter::new(stream);
    let mut acct = ConnAcct::default();

    // Registration.
    let (hello, hello_bytes) = match read_msg(&mut reader) {
        Ok(v) => v,
        Err(e) if io_cause(&e).is_some() => {
            // The connection died before registering: no slot to clean up
            // under Continue; any failure fails the run under Abort.
            return match shared.opts.on_failure {
                OnFailure::Continue => Ok(()),
                OnFailure::Abort => {
                    abort_run(&shared);
                    Err(e).context("reading hello")
                }
            };
        }
        Err(e) => {
            let why = format!("bad hello: {e:#}");
            return Err(reject_conn(&mut reader, &mut writer, &shared, why));
        }
    };
    acct.wire_bytes += hello_bytes as u64;
    let node = match hello {
        Msg::Hello { node, .. } if node == REPL_NODE => {
            // A (stale) primary's replication channel reached a serving
            // server: answer with our epoch so it fences itself. Not an
            // error — the cluster simply moved on without it.
            let _ = write_msg(&mut writer, &Msg::Promote { epoch: shared.opts.epoch });
            return Ok(());
        }
        Msg::Hello { node, epoch } => {
            if epoch > shared.opts.epoch {
                // The worker has seen a newer cluster epoch than ours: we
                // are the stale server. Fencing beats split-brain.
                let why = format!(
                    "fenced: worker observed cluster epoch {epoch}, this server \
                     serves epoch {}",
                    shared.opts.epoch
                );
                return Err(reject_conn(&mut reader, &mut writer, &shared, why));
            }
            node as usize
        }
        other => {
            let why = format!("expected hello, got {other:?}");
            return Err(reject_conn(&mut reader, &mut writer, &shared, why));
        }
    };
    let session = {
        let mut st = lock_recover(&shared.state);
        let rejection = if node >= shared.opts.nodes {
            Some(format!("node slot {node} out of range"))
        } else {
            match st.status[node] {
                NodeStatus::Unclaimed => None,
                NodeStatus::Dead => {
                    // Re-admission: the node comes back under the same id;
                    // its first fetch replays the current global snapshot.
                    st.ps.revive(node);
                    st.fault.reconnects += 1;
                    if shared.opts.verbose {
                        eprintln!("param-server: node {node} reconnected");
                    }
                    None
                }
                NodeStatus::Active if shared.opts.on_failure == OnFailure::Continue => {
                    // The old connection is still draining its lease;
                    // supersede it so the reconnect needn't wait it out.
                    st.fault.reconnects += 1;
                    if shared.opts.verbose {
                        eprintln!("param-server: node {node} superseded a stale session");
                    }
                    None
                }
                NodeStatus::Active | NodeStatus::Done => {
                    Some(format!("node slot {node} already claimed"))
                }
            }
        };
        match rejection {
            Some(why) => {
                drop(st);
                return Err(reject_conn(&mut reader, &mut writer, &shared, why));
            }
            None => {
                st.status[node] = NodeStatus::Active;
                st.session[node] += 1;
                st.claims += 1;
                st.session[node]
            }
        }
    };

    let result = serve_node(&mut reader, &mut writer, &shared, node, &mut acct);

    // Fold this node's measured accounting into the shared stats exactly
    // once per connection.
    {
        let mut st = lock_recover(&shared.state);
        st.ps.comm.wire_bytes += acct.wire_bytes;
        st.ps.comm.fetch_wall_s += acct.fetch_wall_s;
        st.ps.comm.submit_wall_s += acct.submit_wall_s;
        st.sync_wait_s += acct.sync_wait_s;
        st.node_stall[node] += acct.sync_wait_s;
        if result.is_ok() && st.session[node] == session {
            st.status[node] = NodeStatus::Done;
            replicate_async(&mut st, ReplEvent::NodeDone { node: node as u32 });
        }
    }

    let Err(err) = result else { return Ok(()) };
    if lock_recover(&shared.state).draining {
        // Graceful shutdown closed the socket under this handler: a quiet
        // end, not a node death.
        return Ok(());
    }
    match io_cause(&err) {
        // The connection died (EOF, reset, or lease timeout): a node
        // failure, handled per policy.
        Some(io) => {
            let lease_expired = is_timeout(io);
            match shared.opts.on_failure {
                OnFailure::Continue => {
                    declare_dead(&shared, node, session, lease_expired);
                    Ok(())
                }
                OnFailure::Abort => {
                    abort_run(&shared);
                    Err(err).with_context(|| format!("node {node} connection lost"))
                }
            }
        }
        // Protocol violation: report it to the peer (the socket is still
        // frame-aligned — decode errors happen after the full frame was
        // read) and fail the run regardless of policy.
        None => {
            let _ = write_msg(&mut writer, &Msg::Error { msg: format!("{err:#}") });
            drain_for_error_delivery(&mut reader);
            abort_run(&shared);
            Err(err).with_context(|| format!("serving node {node}"))
        }
    }
}

/// The per-connection request loop (registration already done).
fn serve_node(
    reader: &mut std::io::BufReader<TcpStream>,
    writer: &mut std::io::BufWriter<TcpStream>,
    shared: &Shared,
    node: usize,
    acct: &mut ConnAcct,
) -> Result<()> {
    loop {
        let (msg, nread) = read_msg(reader)?;
        acct.wire_bytes += nread as u64;
        match msg {
            Msg::Fetch => {
                let t_h = Instant::now();
                let (snapshot, version, extras) = {
                    let mut st = lock_recover(&shared.state);
                    let extras: Vec<(u64, u64)> = st.pending_extras[node]
                        .drain(..)
                        .map(|r| (r.start as u64, r.end as u64))
                        .collect();
                    let (snapshot, version) = st.ps.fetch(node);
                    (snapshot, version, extras)
                };
                let reply = Msg::Global {
                    version: version as u64,
                    epoch: shared.opts.epoch,
                    reassigned: extras,
                    weights: (*snapshot).clone(),
                };
                acct.wire_bytes += write_msg(writer, &reply)? as u64;
                acct.fetch_wall_s += t_h.elapsed().as_secs_f64();
                acct.last_fetch_reply = Some(Instant::now());
            }
            Msg::Ping => {
                // Lease renewal: the read deadline restarted when the ping
                // arrived; the reply keeps the worker's side alive too.
                acct.wire_bytes += write_msg(writer, &Msg::Pong)? as u64;
            }
            Msg::Submit { mode, base, accuracy, loss, weights } => {
                let epoch_busy = acct
                    .last_fetch_reply
                    .take()
                    .map(|t| t.elapsed().as_secs_f64())
                    .unwrap_or(0.0);
                let t_h = Instant::now();
                let mut waited = 0.0f64;
                let mut ckpt = None;
                let mut repl_rx = None;
                lock_recover(&shared.state).active_submits += 1;
                let _submit_guard = SubmitGuard(shared);
                let version = {
                    let mut st = lock_recover(&shared.state);
                    st.node_busy[node] += epoch_busy;
                    let at_s = shared.t0.elapsed().as_secs_f64();
                    // A worker retrying a submit whose Ack was lost across a
                    // failover may carry a base newer than a promoted
                    // server's counter (async replication loses acked
                    // tails); clamp instead of underflowing the staleness
                    // math.
                    let base = (base as usize).min(st.ps.version());
                    match (shared.opts.update, mode) {
                        (UpdateStrategy::Agwu, SubmitMode::Agwu)
                        | (UpdateStrategy::Agwu, SubmitMode::Plain) => {
                            let v = if mode == SubmitMode::Agwu {
                                st.ps.update_agwu(node, &weights, base, accuracy)
                            } else {
                                st.ps.update_async_plain(node, &weights, base)
                            };
                            st.node_submits[node] += 1;
                            st.versions.push(VersionRecord {
                                version: v,
                                node,
                                local_loss: loss,
                                local_accuracy: accuracy,
                                at_s,
                                eval: None,
                            });
                            if shared.opts.verbose {
                                eprintln!(
                                    "param-server: v{v} node {node} loss {loss:.4} acc {accuracy:.3}"
                                );
                            }
                            repl_rx =
                                plan_replication(shared, &mut st, v, node as u32, loss, accuracy, at_s);
                            ckpt = plan_checkpoint(shared, &mut st, v);
                            v
                        }
                        (UpdateStrategy::Sgwu, SubmitMode::Sgwu) => {
                            if st.ps.sgwu_has_part(node) {
                                drop(st);
                                bail!(
                                    "node {node} already contributed to the current \
                                     SGWU round (duplicate or replayed submit)"
                                );
                            }
                            let my_round = st.round;
                            st.round_meta[node] = Some((loss, accuracy));
                            st.node_submits[node] += 1;
                            match st.ps.submit_sgwu(node, weights, accuracy) {
                                Some(v) => {
                                    let mut l_sum = 0.0f64;
                                    let mut q_sum = 0.0f64;
                                    let mut parts = 0usize;
                                    for meta in st.round_meta.iter_mut() {
                                        if let Some((l, q)) = meta.take() {
                                            l_sum += l;
                                            q_sum += q;
                                            parts += 1;
                                        }
                                    }
                                    let m = parts.max(1) as f64;
                                    st.versions.push(VersionRecord {
                                        version: v,
                                        node: usize::MAX,
                                        local_loss: l_sum / m,
                                        local_accuracy: q_sum / m,
                                        at_s,
                                        eval: None,
                                    });
                                    if shared.opts.verbose {
                                        eprintln!(
                                            "param-server: v{v} (SGWU round) mean loss {:.4}",
                                            l_sum / m
                                        );
                                    }
                                    if let Some(rx) = plan_replication(
                                        shared,
                                        &mut st,
                                        v,
                                        ROUND_NODE,
                                        l_sum / m,
                                        q_sum / m,
                                        at_s,
                                    ) {
                                        // Replication-before-ack: the Eq. 8
                                        // barrier must not release (no node
                                        // of the round can be acked) until
                                        // the standby holds this round.
                                        drop(st);
                                        let w0 = Instant::now();
                                        let _ = rx.recv();
                                        waited += w0.elapsed().as_secs_f64();
                                        st = lock_recover(&shared.state);
                                    }
                                    st.round += 1;
                                    shared.round_cv.notify_all();
                                    ckpt = plan_checkpoint(shared, &mut st, v);
                                    v
                                }
                                None => {
                                    // Eq. 8: wait for the round's last node.
                                    let w0 = Instant::now();
                                    while st.round == my_round && !st.aborted && !st.draining
                                    {
                                        st = shared
                                            .round_cv
                                            .wait(st)
                                            .unwrap_or_else(|e| e.into_inner());
                                    }
                                    waited = w0.elapsed().as_secs_f64();
                                    acct.sync_wait_s += waited;
                                    if st.aborted {
                                        bail!("SGWU round aborted: the run failed");
                                    }
                                    if st.round == my_round && st.draining {
                                        bail!("SGWU round interrupted: server draining for shutdown");
                                    }
                                    st.ps.version()
                                }
                            }
                        }
                        (want, got) => {
                            drop(st);
                            bail!("server runs {want:?} but node submitted {got:?}");
                        }
                    }
                };
                if let Some(rx) = repl_rx.take() {
                    // Replication-before-ack (AGWU): hold the worker's Ack
                    // until the standby acked this update, so an acked
                    // update can never be lost to a promotion.
                    let w0 = Instant::now();
                    let _ = rx.recv();
                    waited += w0.elapsed().as_secs_f64();
                }
                acct.submit_wall_s += t_h.elapsed().as_secs_f64() - waited;
                acct.wire_bytes += write_msg(writer, &Msg::Ack { version: version as u64 })? as u64;
                run_checkpoint(shared, ckpt);
            }
            Msg::Done => return Ok(()),
            other => bail!("unexpected message from node {node}: {other:?}"),
        }
    }
}

// ---------------------------------------------------------------------------
// Warm standby (replica side)
// ---------------------------------------------------------------------------

/// Configuration of a standby run.
#[derive(Debug, Clone)]
pub struct StandbyOptions {
    /// Replication lease: promote after this much silence from the
    /// primary (its keepalive pings at `lease/3` keep this warm). Zero
    /// disables promotion — the standby only mirrors.
    pub repl_lease: Duration,
    /// Post-promotion window in which at least one worker must fail over,
    /// or the promoted server gives up the run.
    pub claim_deadline: Duration,
    pub verbose: bool,
    /// Template for the promoted server. `epoch`, `init_version`,
    /// `promoted`, `claim_deadline`, and the `pre_*` fields are filled in
    /// from replicated state at promotion time.
    pub serve: ServeOptions,
}

/// How a standby run ended.
#[derive(Debug)]
pub enum StandbyOutcome {
    /// The primary reported a clean end of run (`Done` on the replication
    /// channel): nothing to take over.
    PrimaryFinished,
    /// The primary went silent past its lease; this standby promoted
    /// itself and served the remainder of the run.
    Promoted(ClusterReport),
}

/// Replicated state mirrored by a standby, guarded by one mutex.
struct ReplState {
    weights: WeightSet,
    /// Version of the snapshot in `weights` (≤ `version` under async
    /// replication; equal under replication-before-ack).
    snap_version: u64,
    /// Highest replicated metadata version — the promoted server resumes
    /// the version counter here so versions stay strictly monotone.
    version: u64,
    /// Primary's cluster epoch (promotion serves at `epoch + 1`).
    epoch: u64,
    versions: Vec<VersionRecord>,
    submits: Vec<usize>,
    done: Vec<bool>,
    dead: Vec<bool>,
    finished: bool,
    /// Last replication frame (any kind) — the promotion timer.
    last_activity: Option<Instant>,
    /// Training updates replicated (bootstrap snapshots excluded).
    updates: usize,
}

impl ReplState {
    fn apply(&mut self, epoch: u64, event: ReplEvent) {
        self.last_activity = Some(Instant::now());
        self.epoch = self.epoch.max(epoch);
        match event {
            ReplEvent::Update { version, node, loss, accuracy, at_s, weights } => {
                if version > self.version {
                    self.version = version;
                }
                if let Some(w) = weights {
                    if version >= self.snap_version {
                        self.weights = w;
                        self.snap_version = version;
                    }
                }
                if node != REPL_NODE {
                    self.updates += 1;
                    let slot = node as usize;
                    if slot < self.submits.len() {
                        self.submits[slot] += 1;
                        // An update from a previously-dead node means the
                        // primary revived it.
                        self.dead[slot] = false;
                    }
                    self.versions.push(VersionRecord {
                        version: version as usize,
                        node: if node == ROUND_NODE { usize::MAX } else { node as usize },
                        local_loss: loss,
                        local_accuracy: accuracy,
                        at_s,
                        eval: None,
                    });
                }
            }
            ReplEvent::NodeDone { node } => {
                if let Some(d) = self.done.get_mut(node as usize) {
                    *d = true;
                }
            }
            ReplEvent::NodeDead { node } => {
                if let Some(d) = self.dead.get_mut(node as usize) {
                    *d = true;
                }
            }
        }
    }
}

/// Run as a warm standby on `listener`: mirror the primary's replication
/// stream, and either stand down when the primary finishes the run, or
/// promote to primary — bumped epoch, same listener — when the primary's
/// replication lease expires. `init` must be the same initial weights the
/// primary starts from (the primary's bootstrap snapshot overwrites it on
/// first contact anyway).
pub fn serve_standby(
    listener: TcpListener,
    init: WeightSet,
    opts: StandbyOptions,
) -> Result<StandbyOutcome> {
    ensure!(opts.serve.nodes > 0, "standby needs at least one node slot");
    let nodes = opts.serve.nodes;
    let rs = Arc::new(Mutex::new(ReplState {
        weights: init,
        snap_version: opts.serve.init_version as u64,
        version: opts.serve.init_version as u64,
        epoch: opts.serve.epoch,
        versions: Vec::new(),
        submits: vec![0; nodes],
        done: vec![false; nodes],
        dead: vec![false; nodes],
        finished: false,
        last_activity: None,
        updates: 0,
    }));
    listener.set_nonblocking(true).context("nonblocking standby listener")?;
    loop {
        {
            let st = lock_recover(&rs);
            if st.finished {
                if opts.verbose {
                    eprintln!("param-server: standby standing down (primary finished the run)");
                }
                return Ok(StandbyOutcome::PrimaryFinished);
            }
            if !opts.repl_lease.is_zero() {
                if let Some(t) = st.last_activity {
                    if t.elapsed() >= opts.repl_lease {
                        break; // primary lease expired: promote
                    }
                }
            }
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let state = Arc::clone(&rs);
                let lease = opts.repl_lease;
                let verbose = opts.verbose;
                std::thread::spawn(move || standby_conn(stream, state, lease, verbose));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(e).context("accept on standby listener"),
        }
    }

    // Promotion: bump the epoch, rebuild server options from replicated
    // state, and serve workers on the same listener.
    let (weights, version, old_epoch, versions, submits, done, dead, updates) = {
        let mut st = lock_recover(&rs);
        (
            std::mem::replace(&mut st.weights, WeightSet::new(Vec::new())),
            st.version,
            st.epoch,
            std::mem::take(&mut st.versions),
            std::mem::take(&mut st.submits),
            std::mem::take(&mut st.done),
            std::mem::take(&mut st.dead),
            st.updates,
        )
    };
    let epoch = old_epoch + 1;
    eprintln!(
        "param-server: standby promoting to primary at cluster epoch {epoch} \
         (v{version}, {updates} replicated updates)"
    );
    let mut so = opts.serve.clone();
    so.epoch = epoch;
    so.init_version = version as usize;
    so.promoted = true;
    so.claim_deadline = Some(opts.claim_deadline);
    so.standby = None;
    so.repl_ack = ReplAck::None;
    so.pre_done = done.iter().enumerate().filter(|(_, &d)| d).map(|(i, _)| i).collect();
    so.pre_dead = dead.iter().enumerate().filter(|(_, &d)| d).map(|(i, _)| i).collect();
    so.init_submits = submits;
    so.pre_versions = versions;
    serve(listener, weights, so).map(StandbyOutcome::Promoted)
}

/// One connection into a standby: a replication channel from the primary
/// (mirrored and acked), or an early worker (politely rejected — the
/// worker's retry loop carries it across the promotion window).
fn standby_conn(
    stream: TcpStream,
    rs: Arc<Mutex<ReplState>>,
    lease: Duration,
    verbose: bool,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let lease_opt = Some(lease).filter(|d| !d.is_zero());
    stream.set_read_timeout(lease_opt).context("standby conn read deadline")?;
    stream.set_write_timeout(lease_opt).context("standby conn write deadline")?;
    let mut reader = std::io::BufReader::new(stream.try_clone().context("clone stream")?);
    let mut writer = std::io::BufWriter::new(stream);
    let hello = match read_msg(&mut reader) {
        Ok((msg, _)) => msg,
        Err(_) => return Ok(()), // junk dial: nothing worth failing over
    };
    match hello {
        Msg::Hello { node, epoch } if node == REPL_NODE => {
            if verbose {
                eprintln!("param-server: standby mirroring primary (epoch {epoch})");
            }
            lock_recover(&rs).last_activity = Some(Instant::now());
            loop {
                match read_msg(&mut reader) {
                    Ok((Msg::Replicate { epoch, event }, _)) => {
                        let version = {
                            let mut st = lock_recover(&rs);
                            st.apply(epoch, event);
                            st.version
                        };
                        write_msg(&mut writer, &Msg::ReplAck { epoch, version })?;
                    }
                    Ok((Msg::Ping, _)) => {
                        lock_recover(&rs).last_activity = Some(Instant::now());
                        write_msg(&mut writer, &Msg::Pong)?;
                    }
                    Ok((Msg::Done, _)) => {
                        lock_recover(&rs).finished = true;
                        return Ok(());
                    }
                    Ok(_) | Err(_) => {
                        // EOF, lease timeout, or protocol noise: leave
                        // `last_activity` alone so the promotion timer
                        // keeps counting from the last real frame (the
                        // primary may still redial within its lease).
                        return Ok(());
                    }
                }
            }
        }
        Msg::Hello { node, .. } => {
            // A worker arrived before promotion: tell it why, typed, and
            // let its retry/failover loop try again.
            let _ = write_msg(
                &mut writer,
                &Msg::Error {
                    msg: format!(
                        "standby: not serving workers yet (node {node} arrived before \
                         promotion; primary holds the cluster)"
                    ),
                },
            );
            drain_for_error_delivery(&mut reader);
            Ok(())
        }
        _ => Ok(()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::outer::transport::{ServerError, SubmitMeta, TcpTransport, Transport};
    use crate::tensor::Tensor;

    fn ws(vals: &[f32]) -> WeightSet {
        WeightSet::new(vec![Tensor::from_vec(&[vals.len()], vals.to_vec())])
    }

    fn spawn_server(
        init: WeightSet,
        opts: ServeOptions,
    ) -> (String, std::thread::JoinHandle<Result<ClusterReport>>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let h = std::thread::spawn(move || serve(listener, init, opts));
        (addr, h)
    }

    #[test]
    fn loopback_agwu_round_trip() {
        let opts = ServeOptions {
            nodes: 1,
            update: UpdateStrategy::Agwu,
            ..ServeOptions::default()
        };
        let (addr, server) = spawn_server(ws(&[1.0]), opts);
        let mut t = TcpTransport::connect(&addr, 0).unwrap();
        let (g, base) = t.fetch_global().unwrap();
        assert_eq!(base, 0);
        assert_eq!(g.tensors()[0].data(), &[1.0]);
        let mut local = (*g).clone();
        local.tensors_mut()[0].data_mut()[0] = 3.0;
        let meta = SubmitMeta {
            mode: SubmitMode::Agwu,
            base,
            accuracy: 0.5,
            loss: 0.9,
            want_snapshot: false,
        };
        let ack = t.submit(local, &meta).unwrap();
        assert_eq!(ack.version, 1);
        // W = 1 + 1·0.5·(3−1) = 2, visible in the next fetch.
        let (g2, v2) = t.fetch_global().unwrap();
        assert_eq!(v2, 1);
        assert_eq!(g2.tensors()[0].data(), &[2.0]);
        t.finish().unwrap();
        let report = server.join().unwrap().unwrap();
        assert_eq!(report.versions.len(), 1);
        assert_eq!(report.comm.fetches, 2);
        assert_eq!(report.comm.submits, 1);
        assert!(report.comm.wire_bytes > 0, "sockets must move real bytes");
        assert!(!report.fault.any(), "healthy run reports no fault events");
        assert_eq!(report.final_weights.tensors()[0].data(), &[2.0]);
        assert!(t.stats().wire_bytes > 0);
        // Connection setup is accounted separately from transfer walls.
        assert!(t.stats().connect_wall_s > 0.0);
        assert!(t.stats().fetch_wall_s > 0.0);
    }

    #[test]
    fn loopback_sgwu_barrier_blocks_until_round_completes() {
        let opts = ServeOptions {
            nodes: 2,
            update: UpdateStrategy::Sgwu,
            ..ServeOptions::default()
        };
        let (addr, server) = spawn_server(ws(&[0.0, 0.0]), opts);
        let addr2 = addr.clone();
        // Node 0 submits first and must block in submit() until node 1 arrives.
        let first = std::thread::spawn(move || {
            let mut t = TcpTransport::connect(&addr2, 0).unwrap();
            let meta = SubmitMeta {
                mode: SubmitMode::Sgwu,
                base: 0,
                accuracy: 0.5,
                loss: 1.0,
                want_snapshot: false,
            };
            let t_submit = Instant::now();
            let ack = t.submit(ws(&[2.0, 0.0]), &meta).unwrap();
            t.finish().unwrap();
            (ack.version, t_submit.elapsed().as_secs_f64())
        });
        std::thread::sleep(std::time::Duration::from_millis(150));
        let mut t1 = TcpTransport::connect(&addr, 1).unwrap();
        let meta = SubmitMeta {
            mode: SubmitMode::Sgwu,
            base: 0,
            accuracy: 0.5,
            loss: 1.0,
            want_snapshot: false,
        };
        let ack1 = t1.submit(ws(&[0.0, 4.0]), &meta).unwrap();
        t1.finish().unwrap();
        let (v0, blocked_s) = first.join().unwrap();
        assert_eq!((v0, ack1.version), (1, 1));
        assert!(blocked_s >= 0.1, "first submitter did not wait: {blocked_s}s");
        let report = server.join().unwrap().unwrap();
        assert_eq!(report.versions.len(), 1);
        assert_eq!(report.versions[0].node, usize::MAX);
        assert!(report.sync_wait_s >= 0.1, "Eq. 8 wait not accounted");
        assert_eq!(report.final_weights.tensors()[0].data(), &[1.0, 2.0]);
    }

    #[test]
    fn wrong_mode_rejected() {
        let opts = ServeOptions {
            nodes: 1,
            update: UpdateStrategy::Sgwu,
            ..ServeOptions::default()
        };
        let (addr, server) = spawn_server(ws(&[0.0]), opts);
        let mut t = TcpTransport::connect(&addr, 0).unwrap();
        let meta = SubmitMeta {
            mode: SubmitMode::Agwu,
            base: 0,
            accuracy: 1.0,
            loss: 1.0,
            want_snapshot: false,
        };
        let err = t.submit(ws(&[1.0]), &meta).unwrap_err();
        // The rejection is a *typed* server-side error, not a dead socket.
        assert!(
            err.downcast_ref::<ServerError>().is_some(),
            "want ServerError, got: {err:#}"
        );
        drop(t);
        assert!(server.join().unwrap().is_err());
    }

    #[test]
    fn bad_node_slot_rejected() {
        let opts = ServeOptions {
            nodes: 1,
            update: UpdateStrategy::Agwu,
            ..ServeOptions::default()
        };
        let (addr, server) = spawn_server(ws(&[0.0]), opts);
        let mut t = TcpTransport::connect(&addr, 5).unwrap();
        // The registration error surfaces on the first request.
        let err = t.fetch_global().unwrap_err();
        assert!(
            err.downcast_ref::<ServerError>().is_some(),
            "want ServerError, got: {err:#}"
        );
        drop(t);
        assert!(server.join().unwrap().is_err());
    }

    #[test]
    fn ping_renews_without_touching_state() {
        let opts = ServeOptions { nodes: 1, ..ServeOptions::default() };
        let (addr, server) = spawn_server(ws(&[1.0]), opts);
        let mut t = TcpTransport::connect(&addr, 0).unwrap();
        t.heartbeat().unwrap();
        t.heartbeat().unwrap();
        let (_, v) = t.fetch_global().unwrap();
        assert_eq!(v, 0, "pings must not install versions");
        t.finish().unwrap();
        let report = server.join().unwrap().unwrap();
        assert_eq!(report.comm.fetches, 1);
        assert_eq!(report.versions.len(), 0);
    }

    #[test]
    fn lease_expiry_kills_silent_worker_and_run_continues() {
        let opts = ServeOptions {
            nodes: 2,
            update: UpdateStrategy::Agwu,
            on_failure: OnFailure::Continue,
            lease: Duration::from_millis(200),
            ..ServeOptions::default()
        };
        let (addr, server) = spawn_server(ws(&[0.0]), opts);
        // Node 1 connects and goes silent: its lease must expire.
        let silent = TcpStream::connect(&addr).unwrap();
        let mut w = std::io::BufWriter::new(silent.try_clone().unwrap());
        write_msg(&mut w, &Msg::Hello { node: 1, epoch: 0 }).unwrap();
        // Node 0 does real work and finishes.
        let mut t = TcpTransport::connect(&addr, 0).unwrap();
        let (g, base) = t.fetch_global().unwrap();
        let mut local = (*g).clone();
        local.tensors_mut()[0].data_mut()[0] = 1.0;
        let meta = SubmitMeta {
            mode: SubmitMode::Agwu,
            base,
            accuracy: 1.0,
            loss: 1.0,
            want_snapshot: false,
        };
        t.submit(local, &meta).unwrap();
        t.finish().unwrap();
        drop(w);
        drop(silent);
        let report = server.join().unwrap().unwrap();
        assert_eq!(report.versions.len(), 1, "survivor's work landed");
        // The silent node died by lease expiry or by the socket closing —
        // either way the run survived and the death was accounted.
        assert!(report.fault.leases_expired <= 1);
    }

    #[test]
    fn dead_worker_batches_reallocated_to_survivor() {
        let schedule: AllocationSchedule = vec![vec![0..10, 10..20], vec![20..30, 30..40]];
        let opts = ServeOptions {
            nodes: 2,
            update: UpdateStrategy::Agwu,
            on_failure: OnFailure::Continue,
            schedule: Some(schedule),
            ..ServeOptions::default()
        };
        let (addr, server) = spawn_server(ws(&[0.0]), opts);
        // Node 1 fetches once, then dies without a Done (socket drop = EOF).
        {
            let mut t1 = TcpTransport::connect(&addr, 1).unwrap();
            let _ = t1.fetch_global().unwrap();
        }
        // Node 0 runs its two iterations; the dead node's two batches must
        // arrive piggybacked on a later fetch.
        let mut t = TcpTransport::connect(&addr, 0).unwrap();
        let mut gained: Vec<Range<usize>> = Vec::new();
        for _ in 0..2 {
            let (g, base) = t.fetch_global().unwrap();
            gained.extend(t.take_reassigned());
            let mut local = (*g).clone();
            local.tensors_mut()[0].data_mut()[0] += 1.0;
            let meta = SubmitMeta {
                mode: SubmitMode::Agwu,
                base,
                accuracy: 1.0,
                loss: 1.0,
                want_snapshot: false,
            };
            t.submit(local, &meta).unwrap();
            // Give the server time to notice the EOF of node 1.
            std::thread::sleep(Duration::from_millis(50));
        }
        let (_, _) = t.fetch_global().unwrap();
        gained.extend(t.take_reassigned());
        t.finish().unwrap();
        let report = server.join().unwrap().unwrap();
        assert_eq!(report.fault.reallocated_batches, 2);
        assert_eq!(report.fault.reallocated_samples, 20);
        let gained_samples: usize = gained.iter().map(|r| r.len()).sum();
        assert_eq!(gained_samples, 20, "survivor received the dead node's samples");
    }

    #[test]
    fn reconnect_is_readmitted_and_replays_snapshot() {
        let opts = ServeOptions {
            nodes: 1,
            update: UpdateStrategy::Agwu,
            on_failure: OnFailure::Continue,
            // Grace window for all-dead reconnects is 2× the lease: plenty
            // of room for the 300ms gap below.
            lease: Duration::from_millis(500),
            ..ServeOptions::default()
        };
        let (addr, server) = spawn_server(ws(&[1.0]), opts);
        // First session: fetch + submit, then vanish without Done.
        {
            let mut t = TcpTransport::connect(&addr, 0).unwrap();
            let (g, base) = t.fetch_global().unwrap();
            let mut local = (*g).clone();
            local.tensors_mut()[0].data_mut()[0] = 3.0;
            let meta = SubmitMeta {
                mode: SubmitMode::Agwu,
                base,
                accuracy: 0.5,
                loss: 1.0,
                want_snapshot: false,
            };
            t.submit(local, &meta).unwrap();
        }
        // Second session under the same node id: must be re-admitted and
        // see the v1 snapshot the first session installed.
        std::thread::sleep(Duration::from_millis(300));
        let mut t = TcpTransport::connect(&addr, 0).unwrap();
        let (g, v) = t.fetch_global().unwrap();
        assert_eq!(v, 1);
        assert_eq!(g.tensors()[0].data(), &[2.0]);
        t.finish().unwrap();
        let report = server.join().unwrap().unwrap();
        assert_eq!(report.fault.reconnects, 1);
    }

    fn raw_conn(addr: &str) -> (std::io::BufReader<TcpStream>, std::io::BufWriter<TcpStream>) {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        (
            std::io::BufReader::new(stream.try_clone().unwrap()),
            std::io::BufWriter::new(stream),
        )
    }

    fn standby_opts(nodes: usize, repl_lease_ms: u64) -> StandbyOptions {
        StandbyOptions {
            repl_lease: Duration::from_millis(repl_lease_ms),
            claim_deadline: Duration::from_secs(10),
            verbose: false,
            serve: ServeOptions {
                nodes,
                update: UpdateStrategy::Agwu,
                on_failure: OnFailure::Continue,
                lease: Duration::from_secs(5),
                ..ServeOptions::default()
            },
        }
    }

    #[test]
    fn standby_stands_down_when_primary_finishes() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let h = std::thread::spawn(move || serve_standby(listener, ws(&[0.0]), standby_opts(1, 400)));
        let (mut r, mut w) = raw_conn(&addr);
        write_msg(&mut w, &Msg::Hello { node: REPL_NODE, epoch: 0 }).unwrap();
        let boot = Msg::Replicate {
            epoch: 0,
            event: ReplEvent::Update {
                version: 0,
                node: REPL_NODE,
                loss: 0.0,
                accuracy: 0.0,
                at_s: 0.0,
                weights: Some(ws(&[0.0])),
            },
        };
        write_msg(&mut w, &boot).unwrap();
        assert!(matches!(read_msg(&mut r).unwrap().0, Msg::ReplAck { .. }));
        write_msg(&mut w, &Msg::Done).unwrap();
        let outcome = h.join().unwrap().unwrap();
        assert!(matches!(outcome, StandbyOutcome::PrimaryFinished));
    }

    #[test]
    fn standby_promotes_from_replicated_state_and_serves_bit_identically() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let h = std::thread::spawn(move || serve_standby(listener, ws(&[0.0, 0.0]), standby_opts(1, 300)));

        // Act as the primary: bootstrap, then replicate v3 with a snapshot,
        // then vanish without `Done` (a crash).
        let snap = ws(&[1.25, -0.5]);
        {
            let (mut r, mut w) = raw_conn(&addr);
            write_msg(&mut w, &Msg::Hello { node: REPL_NODE, epoch: 0 }).unwrap();
            write_msg(
                &mut w,
                &Msg::Replicate {
                    epoch: 0,
                    event: ReplEvent::Update {
                        version: 0,
                        node: REPL_NODE,
                        loss: 0.0,
                        accuracy: 0.0,
                        at_s: 0.0,
                        weights: Some(ws(&[0.0, 0.0])),
                    },
                },
            )
            .unwrap();
            assert!(matches!(read_msg(&mut r).unwrap().0, Msg::ReplAck { .. }));
            write_msg(
                &mut w,
                &Msg::Replicate {
                    epoch: 0,
                    event: ReplEvent::Update {
                        version: 3,
                        node: 0,
                        loss: 0.7,
                        accuracy: 0.6,
                        at_s: 1.0,
                        weights: Some(snap.clone()),
                    },
                },
            )
            .unwrap();
            let (ack, _) = read_msg(&mut r).unwrap();
            assert!(matches!(ack, Msg::ReplAck { version: 3, .. }), "{ack:?}");
        }

        // While the standby waits out the lease, an early worker must get a
        // typed rejection, not a hang or an abort.
        {
            let mut t = TcpTransport::connect(&addr, 0).unwrap();
            let err = t.fetch_global().unwrap_err();
            assert!(err.downcast_ref::<ServerError>().is_some(), "{err:#}");
        }

        // After promotion the same address serves workers at epoch 1 from
        // the bit-exact replicated snapshot, version counter continued.
        std::thread::sleep(Duration::from_millis(400));
        let epoch_cell = Arc::new(AtomicU64::new(0));
        let mut t = loop {
            match TcpTransport::connect_with_epoch(
                &addr,
                0,
                Some(Duration::from_secs(5)),
                Some(Arc::clone(&epoch_cell)),
            ) {
                Ok(t) => break t,
                Err(_) => std::thread::sleep(Duration::from_millis(50)),
            }
        };
        let (g, v) = loop {
            match t.fetch_global() {
                Ok(got) => break got,
                Err(_) => {
                    std::thread::sleep(Duration::from_millis(50));
                    t = TcpTransport::connect_with_epoch(
                        &addr,
                        0,
                        Some(Duration::from_secs(5)),
                        Some(Arc::clone(&epoch_cell)),
                    )
                    .unwrap();
                }
            }
        };
        assert_eq!(v, 3, "version counter resumes at the replicated version");
        let a: Vec<u32> = snap.flatten().iter().map(|x| x.to_bits()).collect();
        let b: Vec<u32> = g.flatten().iter().map(|x| x.to_bits()).collect();
        assert_eq!(a, b, "promoted snapshot must be bit-identical");
        assert_eq!(epoch_cell.load(Ordering::SeqCst), 1, "worker learned the bumped epoch");

        let mut local = (*g).clone();
        local.tensors_mut()[0].data_mut()[0] += 1.0;
        let meta = SubmitMeta {
            mode: SubmitMode::Agwu,
            base: v,
            accuracy: 0.8,
            loss: 0.5,
            want_snapshot: false,
        };
        let ack = t.submit(local, &meta).unwrap();
        assert_eq!(ack.version, 4, "strictly monotone across the promotion");
        t.finish().unwrap();

        let outcome = h.join().unwrap().unwrap();
        let StandbyOutcome::Promoted(report) = outcome else {
            panic!("expected promotion, got {outcome:?}");
        };
        assert_eq!(report.fault.failovers, 1, "promotion accounted as a failover");
        let versions: Vec<usize> = report.versions.iter().map(|r| r.version).collect();
        assert_eq!(versions, vec![3, 4], "replicated history merged into the report");
    }

    #[test]
    fn stale_primary_replication_hello_gets_promote_reply() {
        // A server already serving at epoch 2 (a promoted standby) must
        // answer a replication hello with Promote so the stale primary
        // fences itself.
        let opts = ServeOptions {
            nodes: 1,
            update: UpdateStrategy::Agwu,
            epoch: 2,
            ..ServeOptions::default()
        };
        let (addr, server) = spawn_server(ws(&[1.0]), opts);
        {
            let (mut r, mut w) = raw_conn(&addr);
            write_msg(&mut w, &Msg::Hello { node: REPL_NODE, epoch: 0 }).unwrap();
            let (reply, _) = read_msg(&mut r).unwrap();
            assert!(matches!(reply, Msg::Promote { epoch: 2 }), "{reply:?}");
        }
        // The run itself is unaffected: a worker completes normally.
        let mut t = TcpTransport::connect(&addr, 0).unwrap();
        let (_, v) = t.fetch_global().unwrap();
        assert_eq!(v, 0);
        t.finish().unwrap();
        server.join().unwrap().unwrap();
    }

    #[test]
    fn worker_from_newer_epoch_fences_stale_server() {
        let opts = ServeOptions { nodes: 1, ..ServeOptions::default() };
        let (addr, server) = spawn_server(ws(&[1.0]), opts);
        let cell = Arc::new(AtomicU64::new(3)); // worker has seen epoch 3
        let mut t =
            TcpTransport::connect_with_epoch(&addr, 0, Some(Duration::from_secs(5)), Some(cell))
                .unwrap();
        let err = t.fetch_global().unwrap_err();
        let server_err = err.downcast_ref::<ServerError>();
        assert!(server_err.is_some_and(|e| e.0.contains("fenced")), "{err:#}");
        drop(t);
        let err = server.join().unwrap().unwrap_err();
        assert!(format!("{err:#}").contains("fenced"), "{err:#}");
    }

    #[test]
    fn repl_ack_standby_holds_worker_ack_until_standby_acks() {
        // Fake standby that delays its ReplAck: the worker's submit Ack
        // must not arrive before the standby's.
        let standby = TcpListener::bind("127.0.0.1:0").unwrap();
        let standby_addr = standby.local_addr().unwrap().to_string();
        let delay = Duration::from_millis(300);
        let standby_thread = std::thread::spawn(move || -> Result<usize> {
            let (stream, _) = standby.accept()?;
            let mut r = std::io::BufReader::new(stream.try_clone()?);
            let mut w = std::io::BufWriter::new(stream);
            let mut snapshots = 0usize;
            loop {
                match read_msg(&mut r) {
                    Ok((Msg::Hello { node, .. }, _)) => assert_eq!(node, REPL_NODE),
                    Ok((Msg::Replicate { epoch, event }, _)) => {
                        let (version, has_snap, is_boot) = match event {
                            ReplEvent::Update { version, node, weights, .. } => {
                                (version, weights.is_some(), node == REPL_NODE)
                            }
                            _ => (0, false, true),
                        };
                        if !is_boot {
                            assert!(has_snap, "sync replication must carry full snapshots");
                            snapshots += 1;
                            std::thread::sleep(delay);
                        }
                        write_msg(&mut w, &Msg::ReplAck { epoch, version })?;
                    }
                    Ok((Msg::Ping, _)) => write_msg(&mut w, &Msg::Pong).map(|_| ())?,
                    Ok((Msg::Done, _)) | Err(_) => return Ok(snapshots),
                    Ok(other) => bail!("unexpected frame at fake standby: {other:?}"),
                }
            }
        });
        let opts = ServeOptions {
            nodes: 1,
            update: UpdateStrategy::Agwu,
            standby: Some(standby_addr),
            repl_ack: ReplAck::Standby,
            ..ServeOptions::default()
        };
        let (addr, server) = spawn_server(ws(&[1.0]), opts);
        let mut t = TcpTransport::connect(&addr, 0).unwrap();
        let (g, base) = t.fetch_global().unwrap();
        let mut local = (*g).clone();
        local.tensors_mut()[0].data_mut()[0] = 2.0;
        let meta = SubmitMeta {
            mode: SubmitMode::Agwu,
            base,
            accuracy: 1.0,
            loss: 1.0,
            want_snapshot: false,
        };
        let t_submit = Instant::now();
        let ack = t.submit(local, &meta).unwrap();
        let held = t_submit.elapsed();
        assert_eq!(ack.version, 1);
        assert!(
            held >= Duration::from_millis(200),
            "submit ack must wait for the standby ack (held {held:?})"
        );
        t.finish().unwrap();
        server.join().unwrap().unwrap();
        let snapshots = standby_thread.join().unwrap().unwrap();
        assert_eq!(snapshots, 1, "exactly one replicated training update");
    }

    #[test]
    fn graceful_shutdown_drains_and_checkpoints() {
        let dir = std::env::temp_dir().join(format!(
            "bptcnn-graceful-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let flag = Arc::new(AtomicBool::new(false));
        let opts = ServeOptions {
            nodes: 1,
            update: UpdateStrategy::Agwu,
            on_failure: OnFailure::Continue,
            checkpoint_dir: Some(dir.clone()),
            checkpoint_every: 0, // graceful path must checkpoint anyway
            shutdown: Some(Arc::clone(&flag)),
            lease: Duration::from_secs(5),
            ..ServeOptions::default()
        };
        let (addr, server) = spawn_server(ws(&[1.0]), opts);
        let mut t = TcpTransport::connect(&addr, 0).unwrap();
        let (g, base) = t.fetch_global().unwrap();
        let mut local = (*g).clone();
        local.tensors_mut()[0].data_mut()[0] = 3.0;
        let meta = SubmitMeta {
            mode: SubmitMode::Agwu,
            base,
            accuracy: 0.5,
            loss: 1.0,
            want_snapshot: false,
        };
        t.submit(local, &meta).unwrap();
        // Signal: the server must stop accepting, drain, checkpoint, and
        // return Ok even though the worker never sent Done.
        flag.store(true, Ordering::SeqCst);
        let report = server.join().unwrap().unwrap();
        assert_eq!(report.versions.len(), 1);
        assert!(report.fault.checkpoints_written >= 1, "{:?}", report.fault);
        let (version, restored) = crate::outer::fault::read_checkpoint(&dir).unwrap();
        assert_eq!(version, 1);
        assert_eq!(restored.flatten(), vec![2.0]);
        drop(t);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn poisoned_state_lock_recovers() {
        // A panicking lock holder must not turn later lock attempts into
        // poison panics — lock_recover takes the data through the poison.
        let m = Arc::new(Mutex::new(7i32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.lock().is_err(), "lock must actually be poisoned");
        assert_eq!(*lock_recover(&m), 7);
        *lock_recover(&m) += 1;
        assert_eq!(*lock_recover(&m), 8);
    }
}
