//! The BPT-CNN trainer — the top-level outer-layer driver (§3.2/§3.3).
//!
//! Glues together: synthetic dataset → calibration of node speeds →
//! IDPA/UDPA allocation schedule → in-process cluster run (SGWU or AGWU) →
//! held-out evaluation curve and the summary metrics the paper reports
//! (accuracy, AUC, sync wait, communication volume, balance index).

use std::sync::Arc;

use crate::config::{ClusterConfig, PartitionStrategy, TrainConfig, UpdateStrategy};
use crate::data::Dataset;
use crate::nn::Network;
use crate::util::stats;

use super::cluster::{self, AllocationSchedule, ClusterReport};
use super::partition::{udpa_partition, IdpaPartitioner};
use super::worker::{LocalTrainer, NativeTrainer};

/// One point of the held-out evaluation curve.
#[derive(Debug, Clone)]
pub struct CurvePoint {
    pub version: usize,
    pub at_s: f64,
    pub loss: f64,
    pub accuracy: f64,
}

/// Full training report (the Fig. 11 / Fig. 15 measurement bundle).
#[derive(Debug)]
pub struct TrainReport {
    pub curve: Vec<CurvePoint>,
    pub cluster: ClusterReport,
    /// Final per-node sample totals (IDPA/UDPA outcome).
    pub allocations: Vec<usize>,
    pub final_accuracy: f64,
    /// Trapezoidal AUC of the accuracy-vs-version curve, normalized to the
    /// version span (Fig. 11b metric).
    pub accuracy_auc: f64,
    pub comm_mb: f64,
    pub sync_wait_s: f64,
    pub balance_index: f64,
    pub wall_s: f64,
}

/// Node slowdown factors from the cluster profile: the fastest node runs at
/// 1.0×, others proportionally slower (freq × (1 − background load share)).
pub fn slowdown_factors(cluster: &ClusterConfig) -> Vec<f64> {
    let speeds: Vec<f64> = cluster
        .nodes
        .iter()
        .map(|n| n.freq_ghz * n.background_load)
        .collect();
    let max = speeds.iter().copied().fold(f64::MIN, f64::max);
    speeds.iter().map(|s| max / s).collect()
}

/// Build the IDPA or UDPA allocation schedule over dataset indices.
///
/// IDPA runs Algorithm 3.1 against the calibrated speed oracle (per-sample
/// time ∝ slowdown factor); UDPA allocates everything uniformly in one shot.
pub fn build_schedule(
    tc: &TrainConfig,
    cluster: &ClusterConfig,
) -> (AllocationSchedule, Vec<usize>, usize) {
    let m = cluster.size();
    let n = tc.total_samples;
    match tc.partition {
        PartitionStrategy::Udpa => {
            let sizes = udpa_partition(n, m);
            let mut start = 0;
            let row: Vec<std::ops::Range<usize>> = sizes
                .iter()
                .map(|&s| {
                    let r = start..start + s;
                    start += s;
                    r
                })
                .collect();
            (vec![row], sizes, tc.iterations)
        }
        PartitionStrategy::Idpa => {
            let freqs: Vec<f64> = cluster.nodes.iter().map(|nd| nd.freq_ghz).collect();
            let slow = slowdown_factors(cluster);
            let mut part = IdpaPartitioner::new(n, tc.idpa_batches, &freqs);
            part.run_with_oracle(|j| slow[j]);
            // Convert per-batch allocations into index ranges, carving the
            // dataset sequentially.
            let mut start = 0;
            let mut schedule = Vec::with_capacity(part.batches_done());
            for batch in part.allocations() {
                let row: Vec<std::ops::Range<usize>> = batch
                    .iter()
                    .map(|&s| {
                        let r = start..start + s;
                        start += s;
                        r
                    })
                    .collect();
                schedule.push(row);
            }
            let totals = part.totals().to_vec();
            let iterations = part.corrected_iterations(tc.iterations);
            (schedule, totals, iterations)
        }
    }
}

/// Train with the native backend on an in-process cluster. `eval_every`
/// controls how often the held-out hook runs under AGWU (1 = every version).
pub fn train_native(tc: &TrainConfig, cluster_cfg: &ClusterConfig) -> TrainReport {
    let m = cluster_cfg.size();
    let train_ds = Arc::new(Dataset::synthetic(&tc.network, tc.total_samples, 0.3, tc.seed));
    let eval_n = 256.min(tc.total_samples.max(64));
    let eval_ds = Dataset::synthetic_split(&tc.network, eval_n, 0.3, tc.seed, tc.seed ^ 0xEEEE);

    let (schedule, allocations, iterations) = build_schedule(tc, cluster_cfg);
    let slow = slowdown_factors(cluster_cfg);
    let workers: Vec<Box<dyn LocalTrainer>> = (0..m)
        .map(|j| {
            Box::new(
                NativeTrainer::new(&tc.network, Arc::clone(&train_ds), tc.learning_rate)
                    .with_slowdown(slow[j]),
            ) as Box<dyn LocalTrainer>
        })
        .collect();

    let init = Network::init(&tc.network, tc.seed).weights;
    let net_cfg = tc.network.clone();
    let eval_hook = move |ws: &crate::tensor::WeightSet| -> (f64, f64) {
        let net = Network::with_weights(&net_cfg, ws.clone());
        let bsz = net_cfg.batch_size;
        // One workspace (and one weight-pack build) across all eval batches.
        let mut step_ws = crate::nn::StepWorkspace::new();
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        let mut batches = 0usize;
        let mut seen = 0usize;
        while seen < eval_ds.len() {
            let (x, y, _) = eval_ds.batch(seen, bsz);
            let (l, c) = net.eval_batch_ws(&x, &y, bsz, &mut step_ws);
            loss += l as f64;
            correct += c;
            seen += bsz;
            batches += 1;
        }
        (
            loss / batches.max(1) as f64,
            correct as f64 / (batches * bsz).max(1) as f64,
        )
    };

    let report = match tc.update {
        // SGWU's Eq. 8 round barrier leaves nothing to overlap in-process
        // (every node's next fetch waits for the installed round anyway),
        // so the staleness knob applies to the asynchronous strategy only.
        UpdateStrategy::Sgwu => {
            cluster::run_sgwu(init, workers, &schedule, iterations, Some(&eval_hook))
        }
        UpdateStrategy::Agwu => cluster::run_async_pipelined(
            init,
            workers,
            &schedule,
            iterations,
            Some(&eval_hook),
            cluster::AsyncMode::Agwu,
            super::pipeline::Staleness(cluster_cfg.staleness),
        ),
    };

    let curve: Vec<CurvePoint> = report
        .versions
        .iter()
        .filter_map(|v| {
            v.eval.map(|(loss, accuracy)| CurvePoint {
                version: v.version,
                at_s: v.at_s,
                loss,
                accuracy,
            })
        })
        .collect();
    let final_accuracy = curve.last().map(|c| c.accuracy).unwrap_or(0.0);
    let pts: Vec<(f64, f64)> = curve
        .iter()
        .map(|c| (c.version as f64, c.accuracy))
        .collect();
    let span = pts.last().map(|p| p.0).unwrap_or(1.0) - pts.first().map(|p| p.0).unwrap_or(0.0);
    let accuracy_auc = if span > 0.0 { stats::auc(&pts) / span } else { final_accuracy };

    TrainReport {
        comm_mb: report.comm.megabytes(),
        sync_wait_s: report.sync_wait_s,
        balance_index: report.balance_index(),
        wall_s: report.wall_s,
        curve,
        allocations,
        final_accuracy,
        accuracy_auc,
        cluster: report,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetworkConfig;

    fn quick_tc(update: UpdateStrategy, partition: PartitionStrategy) -> TrainConfig {
        TrainConfig {
            network: NetworkConfig::quickstart(),
            update,
            partition,
            total_samples: 256,
            iterations: 6,
            idpa_batches: 2,
            learning_rate: 0.3,
            seed: 42,
        }
    }

    #[test]
    fn schedule_udpa_uniform_single_batch() {
        let tc = quick_tc(UpdateStrategy::Sgwu, PartitionStrategy::Udpa);
        let cluster = ClusterConfig::homogeneous(4);
        let (schedule, sizes, iters) = build_schedule(&tc, &cluster);
        assert_eq!(schedule.len(), 1);
        assert_eq!(sizes, vec![64, 64, 64, 64]);
        assert_eq!(iters, 6);
        // Ranges tile the dataset.
        assert_eq!(schedule[0][0], 0..64);
        assert_eq!(schedule[0][3], 192..256);
    }

    #[test]
    fn schedule_idpa_incremental_and_heterogeneous() {
        let tc = quick_tc(UpdateStrategy::Agwu, PartitionStrategy::Idpa);
        let mut cluster = ClusterConfig::homogeneous(3);
        cluster.nodes[0].freq_ghz = 3.2; // fast node
        cluster.nodes[2].freq_ghz = 1.6; // slow node
        let (schedule, totals, iters) = build_schedule(&tc, &cluster);
        assert_eq!(schedule.len(), 2); // A = 2 batches
        assert!(totals[0] > totals[2], "fast node should get more: {totals:?}");
        assert_eq!(totals.iter().sum::<usize>(), 2 * (256 / 2));
        // Eq. 6: K' = K + A/2 − 1 = 6 + 1 − 1 = 6.
        assert_eq!(iters, 6);
    }

    #[test]
    fn train_native_agwu_idpa_learns() {
        let tc = quick_tc(UpdateStrategy::Agwu, PartitionStrategy::Idpa);
        let cluster = ClusterConfig::heterogeneous(2, 1);
        let report = train_native(&tc, &cluster);
        assert!(!report.curve.is_empty());
        assert!(report.final_accuracy > 0.18, "acc={}", report.final_accuracy);
        assert!(report.comm_mb > 0.0);
        assert_eq!(report.sync_wait_s, 0.0);
        assert!(report.balance_index > 0.0 && report.balance_index <= 1.0);
    }

    #[test]
    fn train_native_sgwu_udpa_learns_and_waits() {
        let tc = quick_tc(UpdateStrategy::Sgwu, PartitionStrategy::Udpa);
        let mut cluster = ClusterConfig::homogeneous(2);
        cluster.nodes[1].background_load = 0.4; // straggler
        let report = train_native(&tc, &cluster);
        assert!(report.final_accuracy > 0.18, "acc={}", report.final_accuracy);
        assert!(report.sync_wait_s > 0.0, "SGWU with straggler must wait");
    }

    /// The pipelined path (staleness ≥ 1) reaches the same learning gate as
    /// the serialized AGWU run it overlaps.
    #[test]
    fn train_native_agwu_pipelined_learns() {
        let tc = quick_tc(UpdateStrategy::Agwu, PartitionStrategy::Udpa);
        let cluster = ClusterConfig::homogeneous(2).with_staleness(1);
        let report = train_native(&tc, &cluster);
        assert!(!report.curve.is_empty());
        assert!(report.final_accuracy > 0.18, "acc={}", report.final_accuracy);
        assert_eq!(report.sync_wait_s, 0.0);
        assert_eq!(report.cluster.node_overlap_s.len(), 2);
    }

    #[test]
    fn curve_versions_monotone() {
        let tc = quick_tc(UpdateStrategy::Agwu, PartitionStrategy::Udpa);
        let cluster = ClusterConfig::homogeneous(2);
        let report = train_native(&tc, &cluster);
        for w in report.curve.windows(2) {
            assert!(w[1].version > w[0].version);
        }
        assert!(report.accuracy_auc > 0.0 && report.accuracy_auc <= 1.0);
    }
}
