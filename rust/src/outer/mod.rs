//! Outer-layer parallel training (paper §3.3): incremental data partitioning
//! and allocation (IDPA, Algorithm 3.1), the parameter server with the
//! synchronous (SGWU, Eq. 7) and asynchronous (AGWU, Algorithm 3.2) global
//! weight-update strategies, the cluster of worker nodes — in-process
//! threads or real processes behind the [`Transport`] trait — and the
//! top-level BPT-CNN trainer.

pub mod cluster;
pub mod fault;
pub mod param_server;
pub mod partition;
pub mod pipeline;
pub mod server;
pub mod trainer;
pub mod transport;
pub mod wire;
pub mod worker;

pub use cluster::{
    run_agwu, run_async, run_async_pipelined, run_sgwu, schedule_columns, AllocationSchedule,
    AsyncMode, ClusterReport, VersionRecord,
};
pub use fault::{
    failover_connect, read_checkpoint, sync_dir, write_checkpoint, ConnectFn, FaultStats,
    FaultyTransport, RetryPolicy, RetryingTransport, ServerList,
};
pub use param_server::{CommStats, ParamServer};
pub use partition::{reallocate, udpa_partition, IdpaPartitioner};
pub use pipeline::{pipeline, AckRecord, CommThread, PipelineAccounting, PipelinedTransport, Staleness};
pub use server::{serve, serve_standby, ServeOptions, StandbyOptions, StandbyOutcome};
pub use trainer::{build_schedule, slowdown_factors, train_native, CurvePoint, TrainReport};
pub use transport::{
    InProcTransport, ServerError, SubmitAck, SubmitMeta, SubmitMode, TcpTransport,
    ThrottledTransport, TransferModel, Transport, TransportStats, DEFAULT_IO_TIMEOUT,
};
pub use worker::{drive_worker, EpochOutcome, LocalTrainer, NativeTrainer, WorkerRunSummary};
