//! Outer-layer parallel training (paper §3.3): incremental data partitioning
//! and allocation (IDPA, Algorithm 3.1), the parameter server with the
//! synchronous (SGWU, Eq. 7) and asynchronous (AGWU, Algorithm 3.2) global
//! weight-update strategies, the in-process cluster of worker threads, and
//! the top-level BPT-CNN trainer.

pub mod cluster;
pub mod comm;
pub mod param_server;
pub mod partition;
pub mod trainer;
pub mod worker;

pub use cluster::{run_agwu, run_sgwu, AllocationSchedule, ClusterReport, VersionRecord};
pub use comm::TransferModel;
pub use param_server::{CommStats, ParamServer};
pub use partition::{udpa_partition, IdpaPartitioner};
pub use trainer::{build_schedule, slowdown_factors, train_native, CurvePoint, TrainReport};
pub use worker::{EpochOutcome, LocalTrainer, NativeTrainer};
