//! BPT-CNN — reproduction of "A Bi-layered Parallel Training Architecture for
//! Large-scale Convolutional Neural Networks" (Chen et al., IEEE TPDS 2018).
//!
//! Layer 3 of the Rust + JAX + Pallas stack: the distributed-training
//! coordinator (outer-layer IDPA/SGWU/AGWU, inner-layer task-DAG
//! scheduling), the PJRT runtime that executes the AOT-compiled XLA
//! artifacts, the discrete-event cluster simulator behind the paper's
//! performance figures, and every substrate those need.
#![allow(clippy::needless_range_loop)]
// Kernel entry points (conv/dense fwd+bwd, the GEMM tile API) take explicit
// dimension + buffer arguments by design — no config structs on hot paths.
#![allow(clippy::too_many_arguments)]
// Every unsafe operation must sit in an explicit `unsafe {}` block with its
// own `// SAFETY:` justification, even inside `unsafe fn` — enforced
// together with scripts/unsafe_lint.py (CI fails on undocumented unsafe).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod config;
pub mod data;
pub mod inner;
pub mod nn;
pub mod outer;
pub mod runtime;
pub mod sim;
pub mod metrics;
pub mod experiments;
pub mod tensor;
pub mod util;
