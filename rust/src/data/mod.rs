//! Synthetic image-classification dataset — the ImageNet substitute
//! (DESIGN.md §2). Each class is a smooth random template; samples are the
//! template plus Gaussian noise, so a small CNN can learn the task quickly
//! while the *volume* of data is freely scalable for the performance sweeps.

use crate::config::NetworkConfig;
use crate::util::rng::Xoshiro256;

/// An in-memory labelled dataset of `(H·W·C)`-float images.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub images: Vec<Vec<f32>>,
    pub labels: Vec<usize>,
    pub hw: usize,
    pub channels: usize,
    pub num_classes: usize,
}

impl Dataset {
    /// Generate `n` samples for the given network config.
    ///
    /// Templates are low-frequency sinusoid mixtures (distinct phase +
    /// frequency per class) with per-sample N(0, noise) pixel noise; this
    /// gives inter-class structure a conv layer can pick up while remaining
    /// unlearnable by chance (10 classes → 10% floor).
    ///
    /// `seed` controls BOTH the class templates and the sample draws. Train
    /// and eval sets must share templates (same task!) but differ in draws —
    /// use [`Dataset::synthetic_split`] for that.
    pub fn synthetic(cfg: &NetworkConfig, n: usize, noise: f32, seed: u64) -> Self {
        Self::synthetic_split(cfg, n, noise, seed, seed)
    }

    /// Like [`Dataset::synthetic`], with the class templates keyed by
    /// `template_seed` and the per-sample noise/shuffle keyed by
    /// `draw_seed`. Held-out evaluation sets use the SAME template seed as
    /// the training set and a different draw seed.
    pub fn synthetic_split(
        cfg: &NetworkConfig,
        n: usize,
        noise: f32,
        template_seed: u64,
        draw_seed: u64,
    ) -> Self {
        let hw = cfg.input_hw;
        let c = cfg.in_channels;
        let classes = cfg.num_classes;
        let mut trng = Xoshiro256::new(template_seed);
        let mut rng = Xoshiro256::new(draw_seed ^ 0xD5A7_5EED_0000_0001);

        // Per-class template parameters.
        let templates: Vec<Vec<f32>> = (0..classes)
            .map(|_| {
                let fx = trng.range_f64(0.5, 2.5);
                let fy = trng.range_f64(0.5, 2.5);
                let px = trng.range_f64(0.0, std::f64::consts::TAU);
                let py = trng.range_f64(0.0, std::f64::consts::TAU);
                let sign = if trng.next_f64() < 0.5 { 1.0 } else { -1.0 };
                let mut t = Vec::with_capacity(hw * hw * c);
                for y in 0..hw {
                    for x in 0..hw {
                        let u = x as f64 / hw as f64 * std::f64::consts::TAU;
                        let v = y as f64 / hw as f64 * std::f64::consts::TAU;
                        let val = sign * ((fx * u + px).sin() + (fy * v + py).cos());
                        for _ in 0..c {
                            t.push(val as f32);
                        }
                    }
                }
                t
            })
            .collect();

        let mut images = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let label = i % classes; // balanced classes
            let mut img = templates[label].clone();
            for px in img.iter_mut() {
                *px += rng.normal(0.0, noise as f64) as f32;
            }
            images.push(img);
            labels.push(label);
        }
        // Shuffle so shards are class-balanced in expectation.
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let images = order.iter().map(|&i| images[i].clone()).collect();
        let labels = order.iter().map(|&i| labels[i]).collect();
        Self { images, labels, hw, channels: c, num_classes: classes }
    }

    pub fn len(&self) -> usize {
        self.images.len()
    }

    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }

    /// View of samples `[start, start+len)` as a shard.
    pub fn shard(&self, start: usize, len: usize) -> Shard<'_> {
        assert!(start + len <= self.len(), "shard out of range");
        Shard { data: self, start, len }
    }

    /// Split into shards with the given sizes (must sum to ≤ len).
    pub fn shards_with_sizes(&self, sizes: &[usize]) -> Vec<Shard<'_>> {
        let mut out = Vec::with_capacity(sizes.len());
        let mut start = 0;
        for &len in sizes {
            out.push(self.shard(start, len));
            start += len;
        }
        out
    }

    /// Pack samples `[start, start+bsz)` (wrapping) into NHWC batch buffers:
    /// `(x, y_onehot, labels)`.
    pub fn batch(&self, start: usize, bsz: usize) -> (Vec<f32>, Vec<f32>, Vec<usize>) {
        let pix = self.hw * self.hw * self.channels;
        let mut x = Vec::with_capacity(bsz * pix);
        let mut y = vec![0.0f32; bsz * self.num_classes];
        let mut labels = Vec::with_capacity(bsz);
        for i in 0..bsz {
            let idx = (start + i) % self.len();
            x.extend_from_slice(&self.images[idx]);
            y[i * self.num_classes + self.labels[idx]] = 1.0;
            labels.push(self.labels[idx]);
        }
        (x, y, labels)
    }
}

/// A contiguous view into a dataset (one computing node's subset).
#[derive(Debug, Clone, Copy)]
pub struct Shard<'a> {
    data: &'a Dataset,
    start: usize,
    len: usize,
}

impl<'a> Shard<'a> {
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Batch relative to the shard (wraps within the shard).
    pub fn batch(&self, offset: usize, bsz: usize) -> (Vec<f32>, Vec<f32>, Vec<usize>) {
        assert!(self.len > 0, "batch from empty shard");
        let pix = self.data.hw * self.data.hw * self.data.channels;
        let classes = self.data.num_classes;
        let mut x = Vec::with_capacity(bsz * pix);
        let mut y = vec![0.0f32; bsz * classes];
        let mut labels = Vec::with_capacity(bsz);
        for i in 0..bsz {
            let idx = self.start + (offset + i) % self.len;
            x.extend_from_slice(&self.data.images[idx]);
            y[i * classes + self.data.labels[idx]] = 1.0;
            labels.push(self.data.labels[idx]);
        }
        (x, y, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> NetworkConfig {
        NetworkConfig::quickstart()
    }

    #[test]
    fn generation_counts_and_balance() {
        let ds = Dataset::synthetic(&cfg(), 100, 0.1, 1);
        assert_eq!(ds.len(), 100);
        assert_eq!(ds.images[0].len(), 8 * 8);
        // Balanced classes (100 samples, 10 classes → 10 each).
        let mut counts = vec![0; 10];
        for &l in &ds.labels {
            counts[l] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10), "{counts:?}");
    }

    #[test]
    fn deterministic_in_seed() {
        let a = Dataset::synthetic(&cfg(), 50, 0.1, 7);
        let b = Dataset::synthetic(&cfg(), 50, 0.1, 7);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
        let c = Dataset::synthetic(&cfg(), 50, 0.1, 8);
        assert_ne!(a.images, c.images);
    }

    #[test]
    fn same_class_closer_than_cross_class() {
        let ds = Dataset::synthetic(&cfg(), 200, 0.2, 3);
        // Mean L2 distance within class 0 vs class 0↔1: signal must exist.
        let of_class = |k: usize| -> Vec<&Vec<f32>> {
            ds.images
                .iter()
                .zip(&ds.labels)
                .filter(|(_, &l)| l == k)
                .map(|(im, _)| im)
                .collect()
        };
        let d = |a: &[f32], b: &[f32]| -> f64 {
            a.iter()
                .zip(b)
                .map(|(x, y)| ((x - y) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        let c0 = of_class(0);
        let c1 = of_class(1);
        let within = d(c0[0], c0[1]);
        let across = d(c0[0], c1[0]);
        assert!(across > within, "across={across} within={within}");
    }

    #[test]
    fn batch_onehot_consistency() {
        let ds = Dataset::synthetic(&cfg(), 40, 0.1, 2);
        let (x, y, labels) = ds.batch(0, 8);
        assert_eq!(x.len(), 8 * 8 * 8);
        assert_eq!(y.len(), 8 * 10);
        for (i, &l) in labels.iter().enumerate() {
            assert_eq!(y[i * 10 + l], 1.0);
            assert_eq!(y[i * 10..(i + 1) * 10].iter().sum::<f32>(), 1.0);
        }
    }

    #[test]
    fn batch_wraps() {
        let ds = Dataset::synthetic(&cfg(), 10, 0.1, 2);
        let (_, _, labels) = ds.batch(8, 4); // indices 8,9,0,1
        assert_eq!(labels[2], ds.labels[0]);
        assert_eq!(labels[3], ds.labels[1]);
    }

    #[test]
    fn shards_partition_dataset() {
        let ds = Dataset::synthetic(&cfg(), 30, 0.1, 2);
        let shards = ds.shards_with_sizes(&[10, 15, 5]);
        assert_eq!(shards.len(), 3);
        assert_eq!(shards[0].len() + shards[1].len() + shards[2].len(), 30);
        // Second shard's first sample is global sample 10.
        let (_, _, labels) = shards[1].batch(0, 1);
        assert_eq!(labels[0], ds.labels[10]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn shard_bounds_checked() {
        let ds = Dataset::synthetic(&cfg(), 10, 0.1, 2);
        ds.shard(8, 5);
    }
}
