//! Host tensors and weight-set algebra.
//!
//! The Rust coordinator treats model parameters the way the paper does: as a
//! **weight set** (Definition 1/2, §3.3.2) — an ordered list of tensors. The
//! parameter-server math (Eq. 7 SGWU averaging, Eq. 10 AGWU increments) runs
//! on [`WeightSet`]; [`Tensor`] also provides the dense ops the native NN
//! backend needs (conv/pool/matmul live in `nn/`).

mod weightset;
pub mod wire;

pub use weightset::WeightSet;

/// A dense, row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn filled(shape: &[usize], value: f32) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![value; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match data length {}",
            data.len()
        );
        Self { shape: shape.to_vec(), data }
    }

    /// Fill with N(mean, std) noise from the given RNG.
    pub fn randn(shape: &[usize], rng: &mut crate::util::rng::Xoshiro256, mean: f32, std: f32) -> Self {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.normal(mean as f64, std as f64) as f32).collect();
        Self { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(self.len(), shape.iter().product::<usize>(), "reshape element mismatch");
        self.shape = shape.to_vec();
        self
    }

    // ---- index helpers (up to 4-D, the layouts the CNN uses) -------------

    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }

    #[inline]
    pub fn at4(&self, a: usize, b: usize, c: usize, d: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 4);
        let (s1, s2, s3) = (self.shape[1], self.shape[2], self.shape[3]);
        self.data[((a * s1 + b) * s2 + c) * s3 + d]
    }

    #[inline]
    pub fn set4(&mut self, a: usize, b: usize, c: usize, d: usize, v: f32) {
        let (s1, s2, s3) = (self.shape[1], self.shape[2], self.shape[3]);
        self.data[((a * s1 + b) * s2 + c) * s3 + d] = v;
    }

    #[inline]
    pub fn add4(&mut self, a: usize, b: usize, c: usize, d: usize, v: f32) {
        let (s1, s2, s3) = (self.shape[1], self.shape[2], self.shape[3]);
        self.data[((a * s1 + b) * s2 + c) * s3 + d] += v;
    }

    // ---- element-wise algebra (the weight-update hot path) ---------------

    /// `self += alpha * other` (axpy) — the core of Eq. 10's
    /// `W + γ·Q·(W_j − W)` update.
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        debug_assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += alpha * b;
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    /// Element-wise `self - other` into a new tensor.
    pub fn sub(&self, other: &Tensor) -> Tensor {
        debug_assert_eq!(self.shape, other.shape);
        let data = self
            .data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| a - b)
            .collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn dot(&self, other: &Tensor) -> f64 {
        debug_assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| *a as f64 * *b as f64)
            .sum()
    }

    pub fn l2_norm(&self) -> f64 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    /// Max |a-b| across elements (test helper).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        debug_assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(other.data.iter())
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn construction_and_shape() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert!(t.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn from_vec_validates_length() {
        Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|i| i as f32).collect());
        let r = t.clone().reshape(&[3, 2]);
        assert_eq!(r.shape(), &[3, 2]);
        assert_eq!(r.data(), t.data());
    }

    #[test]
    fn index_4d_row_major() {
        let mut t = Tensor::zeros(&[2, 3, 4, 5]);
        t.set4(1, 2, 3, 4, 7.0);
        assert_eq!(t.at4(1, 2, 3, 4), 7.0);
        // Row-major: last axis contiguous.
        assert_eq!(t.data()[((1 * 3 + 2) * 4 + 3) * 5 + 4], 7.0);
        t.add4(1, 2, 3, 4, 1.0);
        assert_eq!(t.at4(1, 2, 3, 4), 8.0);
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = Tensor::filled(&[4], 1.0);
        let b = Tensor::filled(&[4], 2.0);
        a.axpy(0.5, &b);
        assert!(a.data().iter().all(|&x| x == 2.0));
        a.scale(0.25);
        assert!(a.data().iter().all(|&x| x == 0.5));
    }

    #[test]
    fn sub_dot_norm() {
        let a = Tensor::from_vec(&[3], vec![3.0, 4.0, 0.0]);
        let b = Tensor::from_vec(&[3], vec![1.0, 1.0, 1.0]);
        let d = a.sub(&b);
        assert_eq!(d.data(), &[2.0, 3.0, -1.0]);
        assert!((a.l2_norm() - 5.0).abs() < 1e-9);
        assert!((a.dot(&b) - 7.0).abs() < 1e-9);
        assert_eq!(a.max_abs_diff(&b), 3.0);
    }

    #[test]
    fn randn_moments() {
        let mut rng = Xoshiro256::new(9);
        let t = Tensor::randn(&[10_000], &mut rng, 1.0, 2.0);
        let mean: f64 = t.data().iter().map(|&x| x as f64).sum::<f64>() / 10_000.0;
        assert!((mean - 1.0).abs() < 0.1, "mean={mean}");
    }
}
