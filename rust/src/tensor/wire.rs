//! Versioned wire codec for [`WeightSet`] (the outer layer's unit of
//! transfer, Eq. 11). The format is deliberately dumb: a fixed header, then
//! per-tensor shape + raw little-endian f32 payload. Every f32 bit pattern —
//! including NaN payloads, infinities and signed zeros — round-trips exactly
//! (`to_le_bytes`/`from_le_bytes` are bit moves, not numeric conversions),
//! so a TCP SGWU run is bit-identical to the in-process cluster.
//!
//! ```text
//! [0..4)   magic  b"BPWS"
//! [4..6)   format version  u16 LE  (currently 1)
//! [6..10)  tensor count    u32 LE
//! per tensor:
//!   ndim   u8  (1..=MAX_NDIM)
//!   dims   ndim × u32 LE
//!   data   Πdims × f32 LE
//! ```
//!
//! Decoding rejects short buffers, bad magic, unknown format versions,
//! impossible shapes and trailing bytes — a corrupt or truncated frame can
//! never produce a silently-wrong weight set.

use anyhow::{bail, ensure, Result};

use super::{Tensor, WeightSet};

/// Header magic: "BPt-cnn Weight Set".
pub const WIRE_MAGIC: [u8; 4] = *b"BPWS";
/// Current format version. Bump on any layout change; decoders reject
/// versions they do not know.
pub const WIRE_VERSION: u16 = 1;
/// Most dims a tensor may carry on the wire (the CNN uses ≤ 4).
pub const MAX_NDIM: usize = 8;

const HEADER_LEN: usize = 4 + 2 + 4;

/// Exact encoded size in bytes (header + shapes + payloads).
pub fn encoded_len(ws: &WeightSet) -> usize {
    let mut n = HEADER_LEN;
    for t in ws.tensors() {
        n += 1 + 4 * t.shape().len() + 4 * t.len();
    }
    n
}

/// Append the encoded form of `ws` to `out` (reusable buffer for repeated
/// sends; `out` is *not* cleared).
pub fn encode_weight_set_into(ws: &WeightSet, out: &mut Vec<u8>) {
    out.reserve(encoded_len(ws));
    out.extend_from_slice(&WIRE_MAGIC);
    out.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    out.extend_from_slice(&(ws.len() as u32).to_le_bytes());
    for t in ws.tensors() {
        let shape = t.shape();
        assert!(
            !shape.is_empty() && shape.len() <= MAX_NDIM,
            "tensor rank {} not encodable (1..={MAX_NDIM})",
            shape.len()
        );
        out.push(shape.len() as u8);
        for &d in shape {
            assert!(d <= u32::MAX as usize, "dim {d} exceeds wire width");
            out.extend_from_slice(&(d as u32).to_le_bytes());
        }
        for &v in t.data() {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
}

/// Encode `ws` into a fresh buffer.
pub fn encode_weight_set(ws: &WeightSet) -> Vec<u8> {
    let mut out = Vec::with_capacity(encoded_len(ws));
    encode_weight_set_into(ws, &mut out);
    out
}

/// Cursor over a byte buffer with bounds-checked little-endian reads.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            self.pos + n <= self.buf.len(),
            "truncated weight-set frame: need {} bytes at offset {}, have {}",
            n,
            self.pos,
            self.buf.len() - self.pos
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
}

/// Decode a weight set previously produced by [`encode_weight_set`].
/// The entire buffer must be consumed — trailing bytes are an error.
pub fn decode_weight_set(bytes: &[u8]) -> Result<WeightSet> {
    let mut r = Reader { buf: bytes, pos: 0 };
    let magic = r.take(4)?;
    ensure!(magic == WIRE_MAGIC, "bad weight-set magic {magic:02x?}");
    let version = r.u16()?;
    ensure!(
        version == WIRE_VERSION,
        "unsupported weight-set wire version {version} (expected {WIRE_VERSION})"
    );
    let count = r.u32()? as usize;
    let mut tensors = Vec::with_capacity(count.min(1024));
    for i in 0..count {
        let ndim = r.u8()? as usize;
        ensure!(
            (1..=MAX_NDIM).contains(&ndim),
            "tensor {i}: rank {ndim} outside 1..={MAX_NDIM}"
        );
        let mut shape = Vec::with_capacity(ndim);
        let mut elems: usize = 1;
        for _ in 0..ndim {
            let d = r.u32()? as usize;
            elems = match elems.checked_mul(d) {
                Some(n) => n,
                None => bail!("tensor {i}: shape {shape:?}×{d} overflows"),
            };
            shape.push(d);
        }
        // Bound the allocation by what the buffer can actually hold before
        // trusting the declared element count.
        let payload = r.take(4 * elems)?;
        let mut data = Vec::with_capacity(elems);
        for c in payload.chunks_exact(4) {
            data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        tensors.push(Tensor::from_vec(&shape, data));
    }
    ensure!(
        r.pos == bytes.len(),
        "trailing {} bytes after weight-set payload",
        bytes.len() - r.pos
    );
    Ok(WeightSet::new(tensors))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> WeightSet {
        WeightSet::new(vec![
            Tensor::from_vec(&[2, 3], vec![1.0, -2.5, 0.0, f32::MAX, f32::MIN_POSITIVE, 7.75]),
            Tensor::from_vec(&[4], vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0]),
        ])
    }

    fn bits(ws: &WeightSet) -> Vec<Vec<u32>> {
        ws.tensors()
            .iter()
            .map(|t| t.data().iter().map(|v| v.to_bits()).collect())
            .collect()
    }

    #[test]
    fn round_trip_is_bit_exact() {
        let ws = sample();
        let enc = encode_weight_set(&ws);
        assert_eq!(enc.len(), encoded_len(&ws));
        let dec = decode_weight_set(&enc).unwrap();
        assert_eq!(dec.len(), ws.len());
        for (a, b) in dec.tensors().iter().zip(ws.tensors()) {
            assert_eq!(a.shape(), b.shape());
        }
        // Bit-level equality (NaN != NaN under PartialEq, so compare bits).
        assert_eq!(bits(&dec), bits(&ws));
    }

    #[test]
    fn empty_set_round_trips() {
        let ws = WeightSet::new(Vec::new());
        let dec = decode_weight_set(&encode_weight_set(&ws)).unwrap();
        assert!(dec.is_empty());
    }

    #[test]
    fn truncation_rejected_at_every_length() {
        let enc = encode_weight_set(&sample());
        for cut in 0..enc.len() {
            assert!(
                decode_weight_set(&enc[..cut]).is_err(),
                "truncation at {cut}/{} accepted",
                enc.len()
            );
        }
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut enc = encode_weight_set(&sample());
        enc.push(0);
        assert!(decode_weight_set(&enc).is_err());
    }

    #[test]
    fn bad_magic_and_version_rejected() {
        let good = encode_weight_set(&sample());
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        assert!(decode_weight_set(&bad).is_err(), "magic");
        let mut bad = good;
        bad[4] = 0xFF; // format version
        bad[5] = 0xFF;
        assert!(decode_weight_set(&bad).is_err(), "version");
    }

    #[test]
    fn absurd_shape_rejected() {
        // Header claiming one tensor of rank 0, then of rank 9.
        for ndim in [0u8, 9] {
            let mut buf = Vec::new();
            buf.extend_from_slice(&WIRE_MAGIC);
            buf.extend_from_slice(&WIRE_VERSION.to_le_bytes());
            buf.extend_from_slice(&1u32.to_le_bytes());
            buf.push(ndim);
            assert!(decode_weight_set(&buf).is_err(), "ndim {ndim}");
        }
    }

    #[test]
    fn declared_payload_longer_than_buffer_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&WIRE_MAGIC);
        buf.extend_from_slice(&WIRE_VERSION.to_le_bytes());
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.push(2);
        buf.extend_from_slice(&1_000_000u32.to_le_bytes());
        buf.extend_from_slice(&1_000_000u32.to_le_bytes());
        // No payload follows the (huge) declared shape.
        assert!(decode_weight_set(&buf).is_err());
    }
}
