//! The paper's *weight set* (Definitions 1 & 2, §3.3.2): the ordered list of
//! all weight tensors of a CNN (sub)network. Local weight sets live on
//! workers; the global weight set lives on the parameter server. The order
//! matches the artifact manifest (`meta.json: params[]`) — it is the wire
//! format between the coordinator and the compiled XLA programs.

use std::sync::atomic::{AtomicU64, Ordering};

use super::Tensor;

/// Monotone source of weight-set generations. Global (process-wide) so two
/// *different* weight sets can never carry the same generation unless one is
/// a clone of the other — which is exactly when value-derived caches (the
/// packed-GEMM weight panels in `nn::WeightPacks`) remain valid.
static NEXT_GENERATION: AtomicU64 = AtomicU64::new(1);

fn fresh_generation() -> u64 {
    NEXT_GENERATION.fetch_add(1, Ordering::Relaxed)
}

#[derive(Debug, Clone)]
pub struct WeightSet {
    tensors: Vec<Tensor>,
    /// Value identity: bumped to a globally fresh id by every mutating
    /// accessor. Caches keyed on it (`generation()`) are invalidated by any
    /// weight mutation; clones keep their source's generation (same values).
    generation: u64,
}

/// Generations are cache keys, not values: equality compares tensors only.
impl PartialEq for WeightSet {
    fn eq(&self, other: &Self) -> bool {
        self.tensors == other.tensors
    }
}

impl Default for WeightSet {
    fn default() -> Self {
        Self::new(Vec::new())
    }
}

impl WeightSet {
    pub fn new(tensors: Vec<Tensor>) -> Self {
        Self { tensors, generation: fresh_generation() }
    }

    pub fn zeros_like(&self) -> Self {
        Self::new(self.tensors.iter().map(|t| Tensor::zeros(t.shape())).collect())
    }

    pub fn tensors(&self) -> &[Tensor] {
        &self.tensors
    }

    pub fn tensors_mut(&mut self) -> &mut [Tensor] {
        self.generation = fresh_generation();
        &mut self.tensors
    }

    /// Value-identity token for caches derived from the current weight
    /// values (e.g. packed GEMM panels): two sets with equal generations
    /// hold equal values; any mutation produces a fresh generation.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    pub fn into_tensors(self) -> Vec<Tensor> {
        self.tensors
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Total number of scalar parameters.
    pub fn param_count(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Size in bytes when transmitted (f32) — the paper's unit communication
    /// cost `c_w` of Eq. 11 is `byte_size()` for one weight-set transfer.
    pub fn byte_size(&self) -> usize {
        self.param_count() * std::mem::size_of::<f32>()
    }

    /// `self += alpha * other`, element-wise over the whole set.
    pub fn axpy(&mut self, alpha: f32, other: &WeightSet) {
        assert_eq!(self.tensors.len(), other.tensors.len(), "weight set arity mismatch");
        self.generation = fresh_generation();
        for (a, b) in self.tensors.iter_mut().zip(other.tensors.iter()) {
            a.axpy(alpha, b);
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        self.generation = fresh_generation();
        for t in self.tensors.iter_mut() {
            t.scale(alpha);
        }
    }

    /// `self - other` as a new set — the AGWU increment `(W_j^(k) − W^(k))`
    /// of Eq. 10.
    pub fn sub(&self, other: &WeightSet) -> WeightSet {
        assert_eq!(self.tensors.len(), other.tensors.len(), "weight set arity mismatch");
        WeightSet::new(
            self.tensors
                .iter()
                .zip(other.tensors.iter())
                .map(|(a, b)| a.sub(b))
                .collect(),
        )
    }

    /// Accuracy-weighted mean of several sets — SGWU's Eq. 7:
    /// `W^(i) = Σ_j W_j · Q_j / Σ_k Q_k`.
    pub fn weighted_mean(sets: &[(&WeightSet, f64)]) -> WeightSet {
        assert!(!sets.is_empty(), "weighted_mean of zero sets");
        let total: f64 = sets.iter().map(|(_, q)| q).sum();
        assert!(total > 0.0, "total weight must be positive");
        let mut acc = sets[0].0.zeros_like();
        for (ws, q) in sets {
            acc.axpy((*q / total) as f32, ws);
        }
        acc
    }

    pub fn l2_norm(&self) -> f64 {
        self.tensors
            .iter()
            .map(|t| {
                let n = t.l2_norm();
                n * n
            })
            .sum::<f64>()
            .sqrt()
    }

    pub fn max_abs_diff(&self, other: &WeightSet) -> f32 {
        self.tensors
            .iter()
            .zip(other.tensors.iter())
            .fold(0.0f32, |m, (a, b)| m.max(a.max_abs_diff(b)))
    }

    /// Flatten to one contiguous vector (metrics/serialization helper).
    pub fn flatten(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.param_count());
        for t in &self.tensors {
            out.extend_from_slice(t.data());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ws(values: &[&[f32]]) -> WeightSet {
        WeightSet::new(
            values
                .iter()
                .map(|v| Tensor::from_vec(&[v.len()], v.to_vec()))
                .collect(),
        )
    }

    #[test]
    fn counting() {
        let w = ws(&[&[1.0, 2.0], &[3.0, 4.0, 5.0]]);
        assert_eq!(w.len(), 2);
        assert_eq!(w.param_count(), 5);
        assert_eq!(w.byte_size(), 20);
    }

    #[test]
    fn axpy_applies_to_all_tensors() {
        let mut a = ws(&[&[1.0], &[2.0, 2.0]]);
        let b = ws(&[&[10.0], &[10.0, 20.0]]);
        a.axpy(0.1, &b);
        assert_eq!(a.tensors()[0].data(), &[2.0]);
        assert_eq!(a.tensors()[1].data(), &[3.0, 4.0]);
    }

    #[test]
    fn sub_is_agwu_increment() {
        let local = ws(&[&[3.0, 5.0]]);
        let base = ws(&[&[1.0, 2.0]]);
        let inc = local.sub(&base);
        assert_eq!(inc.tensors()[0].data(), &[2.0, 3.0]);
    }

    #[test]
    fn weighted_mean_equal_weights_is_mean() {
        let a = ws(&[&[0.0, 4.0]]);
        let b = ws(&[&[2.0, 0.0]]);
        let m = WeightSet::weighted_mean(&[(&a, 1.0), (&b, 1.0)]);
        assert_eq!(m.tensors()[0].data(), &[1.0, 2.0]);
    }

    #[test]
    fn weighted_mean_respects_accuracy_weights() {
        // Eq. 7 with Q = (3, 1): W = (3·a + 1·b) / 4.
        let a = ws(&[&[4.0]]);
        let b = ws(&[&[0.0]]);
        let m = WeightSet::weighted_mean(&[(&a, 3.0), (&b, 1.0)]);
        assert_eq!(m.tensors()[0].data(), &[3.0]);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_mismatch_panics() {
        let mut a = ws(&[&[1.0]]);
        let b = ws(&[&[1.0], &[2.0]]);
        a.axpy(1.0, &b);
    }

    #[test]
    fn flatten_concatenates_in_order() {
        let w = ws(&[&[1.0, 2.0], &[3.0]]);
        assert_eq!(w.flatten(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn l2_norm_across_set() {
        let w = ws(&[&[3.0], &[4.0]]);
        assert!((w.l2_norm() - 5.0).abs() < 1e-9);
    }

    /// Generation semantics backing the weight-pack cache: clones share
    /// their source's generation (equal values → caches stay valid), every
    /// mutating accessor produces a globally fresh one, and independently
    /// created sets never collide.
    #[test]
    fn generation_tracks_value_identity() {
        let mut a = ws(&[&[1.0, 2.0]]);
        let b = a.clone();
        assert_eq!(a.generation(), b.generation(), "clone keeps generation");
        let other = ws(&[&[1.0, 2.0]]);
        assert_ne!(a.generation(), other.generation(), "distinct sets, distinct gens");
        let g0 = a.generation();
        a.axpy(0.5, &b);
        assert_ne!(a.generation(), g0, "axpy invalidates");
        let g1 = a.generation();
        a.scale(2.0);
        assert_ne!(a.generation(), g1, "scale invalidates");
        let g2 = a.generation();
        let _ = a.tensors_mut();
        assert_ne!(a.generation(), g2, "tensors_mut invalidates");
        // Equality ignores generations.
        assert_eq!(ws(&[&[5.0]]), ws(&[&[5.0]]));
    }
}
