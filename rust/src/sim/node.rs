//! Node performance model — the simulator's substitute for the paper's
//! Nehalem-EX testbed (DESIGN.md §2).
//!
//! Per-sample training time = FLOPs / effective-throughput, where effective
//! throughput combines nominal frequency, background load, and the
//! inner-layer multi-thread speedup. The multi-thread model is Amdahl's law
//! with the paper's own measurement as the parallel fraction: convolutional
//! layers take >85% of training time (§4.1.1) and are fully task-parallel
//! (Algorithm 4.1), the FC/loss spine is the serial remainder.

use crate::config::{NetworkConfig, NodeProfile};
use crate::util::rng::Xoshiro256;

/// Fraction of a training step that the inner layer parallelizes (conv
/// forward + conv backward, §4.1.1).
pub const PARALLEL_FRACTION: f64 = 0.88;

/// Effective FLOPs per cycle for a Nehalem-class core running the training
/// loop (includes memory stalls — well below the 4-wide SIMD peak),
/// calibrated so the e2e network lands near the paper's absolute scale
/// (~62.77 s for 100 iterations over 100 k samples on 30 nodes, Fig. 12a;
/// ≈0.13 ms/sample-visit per node). See EXPERIMENTS.md §Fig12.
pub const FLOPS_PER_HZ: f64 = 0.75;

/// Amdahl speedup of `threads` threads on `cores` cores.
pub fn thread_speedup(threads: usize, cores: usize) -> f64 {
    let t = threads.min(cores).max(1) as f64;
    1.0 / ((1.0 - PARALLEL_FRACTION) + PARALLEL_FRACTION / t)
}

/// Deterministic per-node performance model.
#[derive(Debug, Clone)]
pub struct NodeModel {
    /// Mean per-sample time (seconds) at the configured thread count.
    pub per_sample_s: f64,
    /// Lognormal-ish jitter σ applied per iteration (OS noise, "other
    /// employers' applications", §3.3.1).
    pub jitter_sigma: f64,
    rng: Xoshiro256,
}

impl NodeModel {
    pub fn new(
        profile: &NodeProfile,
        network: &NetworkConfig,
        threads: usize,
        seed: u64,
    ) -> Self {
        let flops = network.flops_per_sample();
        let core_rate = profile.freq_ghz * 1e9 * FLOPS_PER_HZ * profile.background_load;
        let speedup = thread_speedup(threads, profile.cores);
        Self {
            per_sample_s: flops / (core_rate * speedup),
            jitter_sigma: 0.05,
            rng: Xoshiro256::new(seed),
        }
    }

    /// Time for one local iteration over `samples` samples, with jitter.
    pub fn iteration_time(&mut self, samples: usize) -> f64 {
        let jitter = (self.rng.normal(0.0, self.jitter_sigma)).exp();
        self.per_sample_s * samples as f64 * jitter
    }

    /// Deterministic (jitter-free) iteration time — used by the IDPA oracle.
    pub fn mean_iteration_time(&self, samples: usize) -> f64 {
        self.per_sample_s * samples as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn profile() -> NodeProfile {
        NodeProfile { freq_ghz: 2.3, cores: 8, background_load: 1.0 }
    }

    #[test]
    fn speedup_monotone_saturates_at_cores() {
        let s1 = thread_speedup(1, 8);
        let s4 = thread_speedup(4, 8);
        let s8 = thread_speedup(8, 8);
        let s16 = thread_speedup(16, 8);
        assert!((s1 - 1.0).abs() < 1e-12);
        assert!(s4 > s1 && s8 > s4);
        assert_eq!(s8, s16, "cannot exceed physical cores");
        // Amdahl ceiling: 1/(1-p) ≈ 8.3.
        assert!(s8 < 1.0 / (1.0 - PARALLEL_FRACTION));
    }

    #[test]
    fn faster_node_smaller_per_sample_time() {
        let net = NetworkConfig::default();
        let slow = NodeModel::new(
            &NodeProfile { freq_ghz: 1.6, ..profile() },
            &net,
            8,
            1,
        );
        let fast = NodeModel::new(
            &NodeProfile { freq_ghz: 3.2, ..profile() },
            &net,
            8,
            1,
        );
        assert!((slow.per_sample_s / fast.per_sample_s - 2.0).abs() < 1e-9);
    }

    #[test]
    fn larger_network_slower() {
        let small = NodeModel::new(&profile(), &NetworkConfig::table2_case(1), 8, 1);
        let large = NodeModel::new(&profile(), &NetworkConfig::table2_case(7), 8, 1);
        assert!(large.per_sample_s > 2.0 * small.per_sample_s);
    }

    #[test]
    fn jitter_centered_on_mean() {
        let mut m = NodeModel::new(&profile(), &NetworkConfig::default(), 8, 7);
        let mean_t = m.mean_iteration_time(1000);
        let n = 2000;
        let avg: f64 = (0..n).map(|_| m.iteration_time(1000)).sum::<f64>() / n as f64;
        assert!((avg / mean_t - 1.0).abs() < 0.02, "avg={avg} mean={mean_t}");
    }

    #[test]
    fn absolute_scale_near_paper() {
        // Paper Fig. 12a: ~62.77 s for 100 iterations over 100 k samples on
        // the 30-node cluster ⇒ ~0.19 ms per sample-visit per node.
        let cluster = ClusterConfig::homogeneous(30);
        let m = NodeModel::new(&cluster.nodes[0], &NetworkConfig::default(), 8, 1);
        assert!(
            m.per_sample_s > 1e-5 && m.per_sample_s < 1e-3,
            "per-sample time {} outside plausible band",
            m.per_sample_s
        );
    }
}
