//! Baseline comparator models: TensorFlow-, DistBelief- and DC-CNN-like
//! policies (§5's comparison algorithms), expressed against the same node
//! performance model as BPT-CNN.
//!
//! These are *policy models*, calibrated to the qualitative shapes the paper
//! reports (who wins, where the crossovers fall), not re-implementations of
//! the actual frameworks:
//!
//! * **tensorflow-like** — synchronous data parallelism over a uniform
//!   split, efficient compute, but dynamic resource scheduling makes the
//!   coordination traffic grow superlinearly with cluster size (paper
//!   Fig. 15a: 2.73 MB @ 5 nodes → 45.23 MB @ 35 nodes).
//! * **distbelief-like** — asynchronous parameter server with *data
//!   migration* for load balancing (heavy traffic, Fig. 15a) and
//!   coordination overhead that erodes scaling past ~25 nodes (Fig. 13).
//! * **dccnn-like** — a dynamically configurable coprocessor design: strong
//!   single-node throughput, but little distributed scaling; execution time
//!   *rises* with cluster size beyond ~20 nodes (Figs. 12b/13).

use crate::config::{PartitionStrategy, UpdateStrategy};
use crate::outer::TransferModel;
use crate::outer::partition::udpa_partition;
use crate::util::stats;

use super::node::NodeModel;
use super::runner::{simulate, SimConfig, SimResult};

/// Comparison algorithms of §5.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    /// The paper's system with a choice of strategies.
    BptCnn(UpdateStrategy, PartitionStrategy),
    TensorflowLike,
    DistBeliefLike,
    DcCnnLike,
}

impl Algorithm {
    pub fn name(&self) -> String {
        match self {
            Algorithm::BptCnn(u, p) => format!("BPT-CNN({}+{})", u.name(), p.name()),
            Algorithm::TensorflowLike => "Tensorflow".into(),
            Algorithm::DistBeliefLike => "DisBelief".into(),
            Algorithm::DcCnnLike => "DC-CNN".into(),
        }
    }

    pub fn paper_set() -> [Algorithm; 4] {
        [
            Algorithm::BptCnn(UpdateStrategy::Agwu, PartitionStrategy::Idpa),
            Algorithm::TensorflowLike,
            Algorithm::DistBeliefLike,
            Algorithm::DcCnnLike,
        ]
    }
}

/// Simulate any comparison algorithm under the given scenario.
pub fn simulate_algorithm(alg: Algorithm, cfg: &SimConfig) -> SimResult {
    match alg {
        Algorithm::BptCnn(update, partition) => {
            simulate(&SimConfig { update, partition, ..cfg.clone() })
        }
        Algorithm::TensorflowLike => simulate_tensorflow_like(cfg),
        Algorithm::DistBeliefLike => simulate_distbelief_like(cfg),
        Algorithm::DcCnnLike => simulate_dccnn_like(cfg),
    }
}

fn node_models(cfg: &SimConfig) -> Vec<NodeModel> {
    cfg.cluster
        .nodes
        .iter()
        .enumerate()
        .map(|(j, p)| NodeModel::new(p, &cfg.network, cfg.threads_per_node, cfg.seed ^ j as u64))
        .collect()
}

fn link(cfg: &SimConfig) -> TransferModel {
    TransferModel::new(cfg.cluster.bandwidth_bytes_per_s, cfg.cluster.link_latency_s)
}

fn mb(bytes: f64) -> f64 {
    bytes / (1024.0 * 1024.0)
}

/// Synchronous uniform data parallelism with dataflow-graph compute
/// (≈5% faster per sample than our reference implementation) and dynamic
/// resource scheduling traffic that grows ∝ m².
fn simulate_tensorflow_like(cfg: &SimConfig) -> SimResult {
    let m = cfg.cluster.size();
    let mut models = node_models(cfg);
    let sizes = udpa_partition(cfg.samples, m);
    let xfer = link(cfg).transfer_time(cfg.network.weight_bytes());
    let mut clock = 0.0;
    let mut compute = vec![0.0f64; m];
    let mut sync_wait = 0.0;
    for _ in 0..cfg.iterations {
        let times: Vec<f64> = (0..m)
            .map(|j| models[j].iteration_time(sizes[j]) * 0.95)
            .collect();
        let t_max = times.iter().copied().fold(0.0f64, f64::max);
        for (j, &t) in times.iter().enumerate() {
            compute[j] += t;
            sync_wait += t_max - t;
        }
        clock += t_max + 2.0 * xfer;
    }
    // Weight sync (Eq. 11 analogue) + per-round dynamic-placement metadata
    // exchanged all-to-all: grows quadratically with m.
    let cw = cfg.network.weight_bytes() as f64;
    let comm_bytes =
        2.0 * cw * m as f64 * cfg.iterations as f64 * (0.45 + 0.022 * m as f64 * m as f64 / 5.0);
    SimResult {
        total_s: clock,
        balance_index: stats::balance_index(&compute),
        compute_s: compute,
        sync_wait_s: sync_wait,
        comm_mb: mb(comm_bytes),
        comm_time_s: 2.0 * xfer * m as f64 * cfg.iterations as f64,
        versions: cfg.iterations,
        mean_staleness: 0.0,
        allocations: sizes,
    }
}

/// Asynchronous PS with data-migration load balancing: no sync wait, but
/// migration traffic and coordination overhead that dominates past ~25
/// nodes (the Fig. 13 turn-up).
fn simulate_distbelief_like(cfg: &SimConfig) -> SimResult {
    let m = cfg.cluster.size();
    let mut models = node_models(cfg);
    let sizes = udpa_partition(cfg.samples, m);
    let xfer = link(cfg).transfer_time(cfg.network.weight_bytes());
    let mut compute = vec![0.0f64; m];
    let mut per_node_clock = vec![0.0f64; m];
    for j in 0..m {
        for _ in 0..cfg.iterations {
            let t = models[j].iteration_time(sizes[j]);
            compute[j] += t;
            // Coordination overhead grows with cluster size (replica
            // management + migration decisions).
            per_node_clock[j] += t * (1.0 + 0.004 * m as f64 * m as f64 / 5.0) + 2.0 * xfer;
        }
    }
    // Weight traffic + sample migration between nodes each rebalancing
    // round (the paper attributes DisBelief's heavy communication to this).
    let cw = cfg.network.weight_bytes() as f64;
    let sample_bytes = (cfg.network.input_hw * cfg.network.input_hw * 4) as f64;
    let migrated = 0.02 * cfg.samples as f64 * (m as f64 / 5.0);
    let comm_bytes =
        2.0 * cw * m as f64 * cfg.iterations as f64 + migrated * sample_bytes * 3.0;
    SimResult {
        total_s: per_node_clock.iter().copied().fold(0.0, f64::max),
        balance_index: stats::balance_index(&compute),
        compute_s: compute,
        sync_wait_s: 0.0,
        comm_mb: mb(comm_bytes),
        comm_time_s: 2.0 * xfer * m as f64 * cfg.iterations as f64,
        versions: m * cfg.iterations,
        mean_staleness: (m as f64 - 1.0) / 2.0,
        allocations: sizes,
    }
}

/// Coprocessor-style design: excellent single-device throughput (2× our
/// per-core model) but near-flat distributed scaling — the effective
/// parallelism saturates quickly and synchronization overhead grows, so
/// execution time *increases* for large clusters (Fig. 12b / Fig. 13).
fn simulate_dccnn_like(cfg: &SimConfig) -> SimResult {
    let m = cfg.cluster.size();
    let models = node_models(cfg);
    let mean_ps: f64 =
        models.iter().map(|mo| mo.per_sample_s).sum::<f64>() / m as f64;
    // Effective speedup saturates at ~6 devices.
    let eff = (m as f64).min(6.0 + (m as f64 - 6.0).max(0.0).sqrt() * 0.5);
    let per_iter = cfg.samples as f64 * (mean_ps / 2.0) / eff;
    // Cross-device sync cost grows quadratically.
    let xfer = link(cfg).transfer_time(cfg.network.weight_bytes());
    let sync = xfer * m as f64 * (1.0 + 0.01 * m as f64 * m as f64);
    let total = (per_iter + sync) * cfg.iterations as f64;
    let compute: Vec<f64> = models
        .iter()
        .map(|mo| per_iter * cfg.iterations as f64 * (mean_ps / mo.per_sample_s) / m as f64)
        .collect();
    let cw = cfg.network.weight_bytes() as f64;
    let comm_bytes = 2.0 * cw * m as f64 * cfg.iterations as f64
        * (0.8 + 0.05 * m as f64);
    SimResult {
        total_s: total,
        balance_index: stats::balance_index(&compute),
        compute_s: compute,
        sync_wait_s: sync * cfg.iterations as f64,
        comm_mb: mb(comm_bytes),
        comm_time_s: sync * cfg.iterations as f64,
        versions: cfg.iterations,
        mean_staleness: 0.0,
        allocations: udpa_partition(cfg.samples, m),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn scenario(m: usize, samples: usize) -> SimConfig {
        SimConfig {
            cluster: ClusterConfig::heterogeneous(m, 9),
            samples,
            iterations: 100,
            ..SimConfig::paper_default()
        }
    }

    #[test]
    fn all_algorithms_produce_results() {
        let cfg = scenario(10, 100_000);
        for alg in Algorithm::paper_set() {
            let r = simulate_algorithm(alg, &cfg);
            assert!(r.total_s > 0.0, "{}", alg.name());
            assert!(r.comm_mb > 0.0, "{}", alg.name());
        }
    }

    #[test]
    fn fig15a_comm_shape_bptcnn_flattest() {
        // BPT-CNN's traffic grows ~linearly in m; TF and DisBelief grow much
        // faster (paper: 11.44 vs 45.23 MB at 35 nodes).
        let bpt_5 = simulate_algorithm(
            Algorithm::BptCnn(UpdateStrategy::Agwu, PartitionStrategy::Idpa),
            &scenario(5, 600_000),
        );
        let bpt_35 = simulate_algorithm(
            Algorithm::BptCnn(UpdateStrategy::Agwu, PartitionStrategy::Idpa),
            &scenario(35, 600_000),
        );
        let tf_5 = simulate_algorithm(Algorithm::TensorflowLike, &scenario(5, 600_000));
        let tf_35 = simulate_algorithm(Algorithm::TensorflowLike, &scenario(35, 600_000));
        let bpt_growth = bpt_35.comm_mb / bpt_5.comm_mb;
        let tf_growth = tf_35.comm_mb / tf_5.comm_mb;
        assert!(
            tf_growth > 1.5 * bpt_growth,
            "tf {tf_growth:.1}× vs bpt {bpt_growth:.1}×"
        );
        assert!(tf_35.comm_mb > 2.0 * bpt_35.comm_mb);
    }

    #[test]
    fn fig12b_dccnn_degrades_with_scale() {
        let small = simulate_algorithm(Algorithm::DcCnnLike, &scenario(10, 100_000));
        let large = simulate_algorithm(Algorithm::DcCnnLike, &scenario(35, 100_000));
        // DC-CNN barely improves (or worsens) with more nodes…
        assert!(large.total_s > 0.6 * small.total_s);
        // …while BPT-CNN keeps improving.
        let b_small = simulate_algorithm(
            Algorithm::BptCnn(UpdateStrategy::Agwu, PartitionStrategy::Idpa),
            &scenario(10, 100_000),
        );
        let b_large = simulate_algorithm(
            Algorithm::BptCnn(UpdateStrategy::Agwu, PartitionStrategy::Idpa),
            &scenario(35, 100_000),
        );
        assert!(b_large.total_s < 0.6 * b_small.total_s);
    }

    #[test]
    fn fig15b_bptcnn_best_balance() {
        let cfg = scenario(20, 600_000);
        let bpt = simulate_algorithm(
            Algorithm::BptCnn(UpdateStrategy::Agwu, PartitionStrategy::Idpa),
            &cfg,
        );
        for alg in [Algorithm::TensorflowLike, Algorithm::DistBeliefLike, Algorithm::DcCnnLike] {
            let other = simulate_algorithm(alg, &cfg);
            assert!(
                bpt.balance_index >= other.balance_index - 1e-9,
                "{}: {} > bpt {}",
                alg.name(),
                other.balance_index,
                bpt.balance_index
            );
        }
        // Paper band: 0.80–0.89 (we assert the stable-high property).
        assert!(bpt.balance_index > 0.8, "bpt balance {}", bpt.balance_index);
    }

    #[test]
    fn names_and_paper_set() {
        assert_eq!(Algorithm::paper_set().len(), 4);
        assert_eq!(Algorithm::TensorflowLike.name(), "Tensorflow");
        assert!(Algorithm::BptCnn(UpdateStrategy::Agwu, PartitionStrategy::Idpa)
            .name()
            .contains("AGWU"));
    }
}
