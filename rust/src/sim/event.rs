//! Generic discrete-event engine.
//!
//! Time is kept in integer nanoseconds so the queue ordering is total (no
//! float `Ord` headaches) and runs are bit-reproducible. The 30-node sweeps
//! behind Figs. 12–15 schedule hundreds of thousands of events; the engine
//! is a plain binary heap with a FIFO tiebreak on equal timestamps.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Simulated clock in nanoseconds.
pub type SimTime = u64;

pub fn secs(t: f64) -> SimTime {
    (t.max(0.0) * 1e9).round() as SimTime
}

pub fn to_secs(t: SimTime) -> f64 {
    t as f64 / 1e9
}

/// The event queue: `pop` yields events in (time, insertion order).
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<Entry<E>>>,
    seq: u64,
    now: SimTime,
}

#[derive(Debug)]
struct Entry<E> {
    at: SimTime,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), seq: 0, now: 0 }
    }

    /// Current simulated time (time of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `event` at absolute time `at`.
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        debug_assert!(at >= self.now, "scheduling into the past");
        self.heap.push(Reverse(Entry { at, seq: self.seq, event }));
        self.seq += 1;
    }

    /// Schedule `event` `delay` after now.
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        self.schedule_at(self.now + delay, event);
    }

    /// Pop the next event, advancing the clock.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let Reverse(entry) = self.heap.pop()?;
        self.now = entry.at;
        Some((entry.at, entry.event))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(secs(3.0), "c");
        q.schedule_at(secs(1.0), "a");
        q.schedule_at(secs(2.0), "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn fifo_tiebreak_at_equal_times() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.schedule_at(secs(1.0), i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn clock_advances_with_pop() {
        let mut q = EventQueue::new();
        q.schedule_at(secs(5.0), ());
        assert_eq!(q.now(), 0);
        q.pop();
        assert_eq!(to_secs(q.now()), 5.0);
        // schedule_in is relative to the advanced clock.
        q.schedule_in(secs(1.0), ());
        let (at, _) = q.pop().unwrap();
        assert_eq!(to_secs(at), 6.0);
    }

    #[test]
    fn secs_roundtrip() {
        for t in [0.0, 1e-9, 0.5, 123.456] {
            assert!((to_secs(secs(t)) - t).abs() < 1e-9);
        }
    }

    #[test]
    fn interleaved_schedule_pop() {
        // A chain of events each scheduling the next: 10 hops of 0.1 s.
        let mut q = EventQueue::new();
        q.schedule_at(secs(0.1), 1u32);
        let mut hops = 0;
        while let Some((_, hop)) = q.pop() {
            hops += 1;
            if hop < 10 {
                q.schedule_in(secs(0.1), hop + 1);
            }
        }
        assert_eq!(hops, 10);
        assert!((to_secs(q.now()) - 1.0).abs() < 1e-6);
    }
}
