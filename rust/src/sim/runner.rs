//! Discrete-event simulation of BPT-CNN's outer layer at paper scale
//! (5–35 nodes, 10⁵–10⁶ samples) — regenerates the timing/communication/
//! balance phenomena of Figs. 12–15 that a single host cannot measure
//! directly.
//!
//! The simulator executes the *same policies* as the in-process cluster
//! (IDPA/UDPA allocation, SGWU barrier rounds with Eq. 8 waiting, AGWU
//! free-running submissions with version staleness) against the calibrated
//! node performance model of [`super::node`].

use crate::config::{
    ClusterConfig, NetworkConfig, PartitionStrategy, UpdateStrategy,
};
use crate::outer::TransferModel;
use crate::outer::partition::{udpa_partition, IdpaPartitioner};
use crate::util::stats;

use super::event::{secs, to_secs, EventQueue};
use super::node::NodeModel;

/// Simulation scenario.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub network: NetworkConfig,
    pub cluster: ClusterConfig,
    pub update: UpdateStrategy,
    pub partition: PartitionStrategy,
    /// N — total training samples.
    pub samples: usize,
    /// K — training iterations.
    pub iterations: usize,
    /// A — IDPA batches.
    pub idpa_batches: usize,
    /// Inner-layer threads per node.
    pub threads_per_node: usize,
    pub seed: u64,
}

impl SimConfig {
    pub fn paper_default() -> Self {
        Self {
            network: NetworkConfig::default(),
            cluster: ClusterConfig::heterogeneous(30, 7),
            update: UpdateStrategy::Agwu,
            partition: PartitionStrategy::Idpa,
            samples: 100_000,
            iterations: 100,
            idpa_batches: 10,
            threads_per_node: 8,
            seed: 7,
        }
    }
}

/// Simulation outcome (the Figs. 12–15 measurement bundle).
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Makespan: wall-clock seconds to finish all iterations.
    pub total_s: f64,
    /// Busy compute seconds per node.
    pub compute_s: Vec<f64>,
    /// Eq. 8 synchronization wait summed over nodes and iterations.
    pub sync_wait_s: f64,
    /// Weight traffic (Eq. 11), MB.
    pub comm_mb: f64,
    /// Time spent in transfers (sum over nodes).
    pub comm_time_s: f64,
    pub balance_index: f64,
    /// Global versions produced.
    pub versions: usize,
    /// AGWU only: mean (i − k) staleness across submissions.
    pub mean_staleness: f64,
    /// Final per-node sample allocation.
    pub allocations: Vec<usize>,
}

/// Per-node sample counts per iteration index (IDPA ramps over the first A
/// iterations; UDPA is constant).
fn allocation_schedule(cfg: &SimConfig, models: &[NodeModel]) -> (Vec<Vec<usize>>, usize) {
    let m = cfg.cluster.size();
    match cfg.partition {
        PartitionStrategy::Udpa => {
            let sizes = udpa_partition(cfg.samples, m);
            (vec![sizes], cfg.iterations)
        }
        PartitionStrategy::Idpa => {
            let freqs: Vec<f64> = cfg.cluster.nodes.iter().map(|n| n.freq_ghz).collect();
            let mut part = IdpaPartitioner::new(cfg.samples, cfg.idpa_batches, &freqs);
            part.run_with_oracle(|j| models[j].per_sample_s);
            let mut cumulative = vec![0usize; m];
            let mut per_iter = Vec::with_capacity(part.batches_done());
            for batch in part.allocations() {
                for (c, &b) in cumulative.iter_mut().zip(batch.iter()) {
                    *c += b;
                }
                per_iter.push(cumulative.clone());
            }
            let iters = part.corrected_iterations(cfg.iterations);
            (per_iter, iters)
        }
    }
}

/// Samples held by node j at iteration `it` under the ramp schedule.
fn samples_at(schedule: &[Vec<usize>], it: usize, j: usize) -> usize {
    let idx = it.min(schedule.len() - 1);
    schedule[idx][j]
}

/// Run the simulation.
pub fn simulate(cfg: &SimConfig) -> SimResult {
    let m = cfg.cluster.size();
    assert!(m > 0);
    let mut models: Vec<NodeModel> = cfg
        .cluster
        .nodes
        .iter()
        .enumerate()
        .map(|(j, p)| NodeModel::new(p, &cfg.network, cfg.threads_per_node, cfg.seed ^ j as u64))
        .collect();
    let (schedule, iterations) = allocation_schedule(cfg, &models);
    let link = TransferModel::new(
        cfg.cluster.bandwidth_bytes_per_s,
        cfg.cluster.link_latency_s,
    );
    let cw = cfg.network.weight_bytes();
    let xfer = link.transfer_time(cw);

    match cfg.update {
        UpdateStrategy::Sgwu => {
            simulate_sgwu(cfg, &mut models, &schedule, iterations, xfer, cw)
        }
        UpdateStrategy::Agwu => {
            simulate_agwu(cfg, &mut models, &schedule, iterations, xfer, cw)
        }
    }
}

fn simulate_sgwu(
    _cfg: &SimConfig,
    models: &mut [NodeModel],
    schedule: &[Vec<usize>],
    iterations: usize,
    xfer: f64,
    cw: usize,
) -> SimResult {
    let m = models.len();
    let mut clock = 0.0f64;
    let mut compute = vec![0.0f64; m];
    let mut comm_time = 0.0f64;
    let mut sync_wait = 0.0f64;
    for it in 0..iterations {
        // Fetch (parallel links), compute, submit; the barrier waits for the
        // slowest node (Eq. 8), then the PS merges (Eq. 7).
        let times: Vec<f64> = (0..m)
            .map(|j| models[j].iteration_time(samples_at(schedule, it, j)))
            .collect();
        let t_max = times.iter().copied().fold(0.0f64, f64::max);
        for (j, &t) in times.iter().enumerate() {
            compute[j] += t;
            sync_wait += t_max - t;
        }
        comm_time += 2.0 * xfer * m as f64;
        clock += xfer + t_max + xfer; // fetch ∥ compute ∥ submit round
    }
    let comm_bytes = 2 * cw * m * iterations;
    SimResult {
        total_s: clock,
        balance_index: stats::balance_index(&compute),
        compute_s: compute,
        sync_wait_s: sync_wait,
        comm_mb: comm_bytes as f64 / (1024.0 * 1024.0),
        comm_time_s: comm_time,
        versions: iterations,
        mean_staleness: 0.0,
        allocations: schedule.last().unwrap().clone(),
    }
}

#[derive(Debug)]
enum Ev {
    /// Node finished compute for its local iteration `it`.
    Done { node: usize, it: usize },
}

fn simulate_agwu(
    _cfg: &SimConfig,
    models: &mut [NodeModel],
    schedule: &[Vec<usize>],
    iterations: usize,
    xfer: f64,
    cw: usize,
) -> SimResult {
    let m = models.len();
    let mut q: EventQueue<Ev> = EventQueue::new();
    let mut compute = vec![0.0f64; m];
    let mut comm_time = 0.0f64;
    let mut version = 0usize; // global version i
    let mut base_version = vec![0usize; m]; // version each node trained from
    let mut staleness_sum = 0.0f64;
    let mut submissions = 0usize;

    // Every node fetches v0 and starts iteration 0.
    for (j, model) in models.iter_mut().enumerate() {
        let t = model.iteration_time(samples_at(schedule, 0, j));
        compute[j] += t;
        comm_time += xfer;
        q.schedule_at(secs(xfer + t), Ev::Done { node: j, it: 0 });
    }
    while let Some((_, Ev::Done { node, it })) = q.pop() {
        // Submit: the PS immediately produces version i+1 (Alg. 3.2).
        version += 1;
        staleness_sum += (version - 1 - base_version[node]) as f64;
        submissions += 1;
        comm_time += xfer;
        if it + 1 < iterations {
            // Fetch the fresh version and start the next local iteration.
            base_version[node] = version;
            let t = models[node].iteration_time(samples_at(schedule, it + 1, node));
            compute[node] += t;
            comm_time += xfer;
            q.schedule_in(secs(xfer + t + xfer), Ev::Done { node, it: it + 1 });
        }
    }
    let comm_bytes = 2 * cw * m * iterations;
    SimResult {
        total_s: to_secs(q.now()),
        balance_index: stats::balance_index(&compute),
        compute_s: compute,
        sync_wait_s: 0.0,
        comm_mb: comm_bytes as f64 / (1024.0 * 1024.0),
        comm_time_s: comm_time,
        versions: version,
        mean_staleness: staleness_sum / submissions.max(1) as f64,
        allocations: schedule.last().unwrap().clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base(update: UpdateStrategy, partition: PartitionStrategy) -> SimConfig {
        SimConfig {
            cluster: ClusterConfig::heterogeneous(10, 3),
            update,
            partition,
            samples: 50_000,
            iterations: 20,
            idpa_batches: 5,
            ..SimConfig::paper_default()
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let cfg = base(UpdateStrategy::Agwu, PartitionStrategy::Idpa);
        let a = simulate(&cfg);
        let b = simulate(&cfg);
        assert_eq!(a.total_s, b.total_s);
        assert_eq!(a.versions, b.versions);
    }

    #[test]
    fn agwu_has_no_sync_wait_sgwu_does() {
        let s = simulate(&base(UpdateStrategy::Sgwu, PartitionStrategy::Udpa));
        let a = simulate(&base(UpdateStrategy::Agwu, PartitionStrategy::Udpa));
        assert!(s.sync_wait_s > 0.0);
        assert_eq!(a.sync_wait_s, 0.0);
    }

    #[test]
    fn agwu_faster_than_sgwu_on_heterogeneous_cluster() {
        // Fig. 14's core claim.
        let s = simulate(&base(UpdateStrategy::Sgwu, PartitionStrategy::Udpa));
        let a = simulate(&base(UpdateStrategy::Agwu, PartitionStrategy::Udpa));
        assert!(
            a.total_s < s.total_s,
            "AGWU {} not faster than SGWU {}",
            a.total_s,
            s.total_s
        );
    }

    #[test]
    fn idpa_balances_better_than_udpa() {
        // Fig. 15b's core claim.
        let u = simulate(&base(UpdateStrategy::Sgwu, PartitionStrategy::Udpa));
        let i = simulate(&base(UpdateStrategy::Sgwu, PartitionStrategy::Idpa));
        assert!(
            i.balance_index > u.balance_index,
            "IDPA balance {} <= UDPA balance {}",
            i.balance_index,
            u.balance_index
        );
        // And it cuts the sync wait (§3.3.1's objective).
        assert!(i.sync_wait_s < u.sync_wait_s);
    }

    #[test]
    fn comm_volume_matches_eq11() {
        let cfg = base(UpdateStrategy::Agwu, PartitionStrategy::Idpa);
        let r = simulate(&cfg);
        // Eq. 11: 2·c_w·m·K' with K' = K + A/2 − 1 = 20+2-1 = 21.
        let expected =
            (2 * cfg.network.weight_bytes() * 10 * 21) as f64 / (1024.0 * 1024.0);
        assert!((r.comm_mb - expected).abs() < 1e-9, "{} vs {expected}", r.comm_mb);
    }

    #[test]
    fn agwu_staleness_positive_and_bounded() {
        let r = simulate(&base(UpdateStrategy::Agwu, PartitionStrategy::Udpa));
        assert!(r.mean_staleness > 0.0, "async must observe staleness");
        assert!(r.mean_staleness < 10.0 * 2.0, "staleness unreasonably large");
    }

    #[test]
    fn time_scales_with_data_and_inverse_with_nodes() {
        let small = simulate(&SimConfig {
            samples: 50_000,
            ..base(UpdateStrategy::Agwu, PartitionStrategy::Idpa)
        });
        let big = simulate(&SimConfig {
            samples: 200_000,
            ..base(UpdateStrategy::Agwu, PartitionStrategy::Idpa)
        });
        assert!(big.total_s > 2.0 * small.total_s);
        let few_nodes = simulate(&SimConfig {
            cluster: ClusterConfig::heterogeneous(5, 3),
            ..base(UpdateStrategy::Agwu, PartitionStrategy::Idpa)
        });
        let many_nodes = simulate(&SimConfig {
            cluster: ClusterConfig::heterogeneous(30, 3),
            ..base(UpdateStrategy::Agwu, PartitionStrategy::Idpa)
        });
        assert!(many_nodes.total_s < few_nodes.total_s);
    }

    #[test]
    fn more_threads_faster() {
        let t1 = simulate(&SimConfig {
            threads_per_node: 1,
            ..base(UpdateStrategy::Agwu, PartitionStrategy::Idpa)
        });
        let t8 = simulate(&SimConfig {
            threads_per_node: 8,
            ..base(UpdateStrategy::Agwu, PartitionStrategy::Idpa)
        });
        assert!(t8.total_s < t1.total_s / 3.0, "t8={} t1={}", t8.total_s, t1.total_s);
    }
}
