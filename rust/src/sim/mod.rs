//! Discrete-event cluster simulator — the 30-node testbed substitute behind
//! the paper's performance evaluation (Figs. 12–15). `event` is the DES
//! engine, `node` the calibrated performance model, `runner` the BPT-CNN
//! policy simulation ({SGWU,AGWU} × {IDPA,UDPA}), and `baselines` the
//! TensorFlow/DistBelief/DC-CNN comparator models.

pub mod baselines;
pub mod event;
pub mod node;
pub mod runner;

pub use baselines::{simulate_algorithm, Algorithm};
pub use event::{secs, to_secs, EventQueue, SimTime};
pub use node::{thread_speedup, NodeModel, PARALLEL_FRACTION};
pub use runner::{simulate, SimConfig, SimResult};
