//! Dense NN primitives.
//!
//! Layouts match the Layer-1/Layer-2 Python side exactly: images NHWC,
//! filters HWIO, FC row-major `(B, I) @ (I, O)`.
//!
//! Convolutions run as **im2col + packed-B micro-kernel GEMM**: each row tile
//! of the output is lowered to a patch matrix and contracted with the HWIO
//! filter, which is packed *once per layer call* into a register-blocked
//! panel layout ([`PackedB`]) reused across all row tiles and all images in
//! the batch. The inner kernel accumulates an `MR×NR` (4×8) register tile
//! with unrolled FMA-friendly loops; with the `simd` cargo feature an
//! AVX2+FMA variant is selected at runtime on x86-64. The seed's direct
//! loops are retained as the `*_naive` reference oracle (and the benches'
//! baseline), and the pre-packing blocked GEMM is retained as the legacy
//! baseline ([`gemm_acc`] / [`conv2d_same_rows_gemm`]). The inner-layer task
//! decomposition (`inner/conv_tasks.rs`, `inner/bp_tasks.rs`) dispatches the
//! same row tiles onto the thread pool, so the parallel and serial paths
//! share one numeric core: forward, backward-input (flipped-filter forward)
//! and backward-filter (patchesᵀ·dy) all run through the two kernels here.

// Kernel code indexes fixed-size register tiles and conv entry points carry
// full problem geometry; range loops and wide signatures are intentional.
#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]

/// Dimensions of a SAME convolution (stride 1, P = (k−1)/2 per Eq. 12).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvDims {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub k: usize,
    pub co: usize,
}

impl ConvDims {
    pub fn pad(&self) -> usize {
        (self.k - 1) / 2
    }

    pub fn x_len(&self) -> usize {
        self.n * self.h * self.w * self.c
    }

    pub fn f_len(&self) -> usize {
        self.k * self.k * self.c * self.co
    }

    pub fn y_len(&self) -> usize {
        self.n * self.h * self.w * self.co
    }

    /// K_C of Eq. 13 for SAME/stride-1: one task per output element
    /// (per image, per output channel collapsed into the task body).
    pub fn kc(&self) -> usize {
        self.h * self.w
    }
}

#[inline]
fn xi(d: &ConvDims, n: usize, y: usize, x: usize, c: usize) -> usize {
    ((n * d.h + y) * d.w + x) * d.c + c
}

#[inline]
fn yi(d: &ConvDims, n: usize, y: usize, x: usize, o: usize) -> usize {
    ((n * d.h + y) * d.w + x) * d.co + o
}

#[inline]
fn fi(d: &ConvDims, ky: usize, kx: usize, c: usize, o: usize) -> usize {
    ((ky * d.k + kx) * d.c + c) * d.co + o
}

/// Compute one output row `(image n, row y)` of a SAME convolution — this is
/// the granularity of the paper's Eq.-13/14 convolution tasks (a row of
/// `a_{i,j}` values; one scalar per task would drown in scheduling overhead,
/// see DESIGN.md §Hardware-Adaptation). Direct-loop implementation, kept as
/// the per-row reference alongside the im2col+GEMM fast path below.
pub fn conv2d_same_row(
    d: &ConvDims,
    x: &[f32],
    f: &[f32],
    bias: &[f32],
    n: usize,
    y: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), d.w * d.co);
    let p = d.pad() as isize;
    for ox in 0..d.w {
        let base = ox * d.co;
        out[base..base + d.co].copy_from_slice(bias);
        for ky in 0..d.k {
            let iy = y as isize + ky as isize - p;
            if iy < 0 || iy >= d.h as isize {
                continue;
            }
            for kx in 0..d.k {
                let ix = ox as isize + kx as isize - p;
                if ix < 0 || ix >= d.w as isize {
                    continue;
                }
                let xoff = xi(d, n, iy as usize, ix as usize, 0);
                let foff = fi(d, ky, kx, 0, 0);
                for c in 0..d.c {
                    let xv = x[xoff + c];
                    let frow = &f[foff + c * d.co..foff + (c + 1) * d.co];
                    let orow = &mut out[base..base + d.co];
                    for o in 0..d.co {
                        orow[o] += xv * frow[o];
                    }
                }
            }
        }
    }
}

// ---- naive reference path (the seed's direct loops, retained as oracle) ---

/// Direct-loop SAME conv forward — the retained reference for the
/// im2col+GEMM fast path (and the seed baseline the benches compare against).
pub fn conv2d_same_fwd_naive(d: &ConvDims, x: &[f32], f: &[f32], bias: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), d.x_len());
    debug_assert_eq!(f.len(), d.f_len());
    debug_assert_eq!(bias.len(), d.co);
    debug_assert_eq!(out.len(), d.y_len());
    let row = d.w * d.co;
    for n in 0..d.n {
        for y in 0..d.h {
            let start = (n * d.h + y) * row;
            conv2d_same_row(d, x, f, bias, n, y, &mut out[start..start + row]);
        }
    }
}

/// Direct-loop backward w.r.t. input (Eq. 18) — retained reference.
pub fn conv2d_same_bwd_input_naive(d: &ConvDims, dy: &[f32], f: &[f32], dx: &mut [f32]) {
    debug_assert_eq!(dy.len(), d.y_len());
    debug_assert_eq!(dx.len(), d.x_len());
    dx.fill(0.0);
    let p = d.pad() as isize;
    for n in 0..d.n {
        for oy in 0..d.h {
            for ox in 0..d.w {
                let dybase = yi(d, n, oy, ox, 0);
                for ky in 0..d.k {
                    let iy = oy as isize + ky as isize - p;
                    if iy < 0 || iy >= d.h as isize {
                        continue;
                    }
                    for kx in 0..d.k {
                        let ix = ox as isize + kx as isize - p;
                        if ix < 0 || ix >= d.w as isize {
                            continue;
                        }
                        let xoff = xi(d, n, iy as usize, ix as usize, 0);
                        let foff = fi(d, ky, kx, 0, 0);
                        for c in 0..d.c {
                            let mut acc = 0.0f32;
                            let frow = &f[foff + c * d.co..foff + (c + 1) * d.co];
                            for o in 0..d.co {
                                acc += dy[dybase + o] * frow[o];
                            }
                            dx[xoff + c] += acc;
                        }
                    }
                }
            }
        }
    }
}

/// Direct-loop backward w.r.t. filter (Eq. 21) and bias (Eq. 22) — retained
/// reference.
pub fn conv2d_same_bwd_filter_naive(
    d: &ConvDims,
    x: &[f32],
    dy: &[f32],
    df: &mut [f32],
    db: &mut [f32],
) {
    debug_assert_eq!(df.len(), d.f_len());
    debug_assert_eq!(db.len(), d.co);
    df.fill(0.0);
    db.fill(0.0);
    let p = d.pad() as isize;
    for n in 0..d.n {
        for oy in 0..d.h {
            for ox in 0..d.w {
                let dybase = yi(d, n, oy, ox, 0);
                for o in 0..d.co {
                    db[o] += dy[dybase + o];
                }
                for ky in 0..d.k {
                    let iy = oy as isize + ky as isize - p;
                    if iy < 0 || iy >= d.h as isize {
                        continue;
                    }
                    for kx in 0..d.k {
                        let ix = ox as isize + kx as isize - p;
                        if ix < 0 || ix >= d.w as isize {
                            continue;
                        }
                        let xoff = xi(d, n, iy as usize, ix as usize, 0);
                        let foff = fi(d, ky, kx, 0, 0);
                        for c in 0..d.c {
                            let xv = x[xoff + c];
                            let frow = &mut df[foff + c * d.co..foff + (c + 1) * d.co];
                            for o in 0..d.co {
                                frow[o] += xv * dy[dybase + o];
                            }
                        }
                    }
                }
            }
        }
    }
}

// ---- im2col + blocked-GEMM fast path ---------------------------------------

/// Output rows per im2col block: bounds the patch-matrix scratch to
/// `TILE · W · k²C` floats while amortizing the GEMM over whole tiles.
pub const IM2COL_TILE_ROWS: usize = 32;

/// Lower output rows `[y0, y0+rows)` of image `n` into the patch matrix
/// `cols` of shape `(rows·W, k²·C)` (row-major, zero-padded borders).
/// Column index `(ky·k + kx)·C + c` matches the HWIO filter layout, so the
/// convolution becomes `cols · f` with `f` viewed as a `(k²·C, C_o)` matrix.
pub fn im2col_rows(d: &ConvDims, x: &[f32], n: usize, y0: usize, rows: usize, cols: &mut [f32]) {
    let kkc = d.k * d.k * d.c;
    debug_assert!(y0 + rows <= d.h);
    debug_assert_eq!(cols.len(), rows * d.w * kkc);
    cols.fill(0.0);
    let p = d.pad() as isize;
    let kc = d.k * d.c;
    for r in 0..rows {
        let y = y0 + r;
        for ky in 0..d.k {
            let iy = y as isize + ky as isize - p;
            if iy < 0 || iy >= d.h as isize {
                continue;
            }
            let xrow = xi(d, n, iy as usize, 0, 0);
            for ox in 0..d.w {
                let dst = (r * d.w + ox) * kkc + ky * kc;
                let ix0 = ox as isize - p;
                if ix0 >= 0 && ix0 as usize + d.k <= d.w {
                    // Whole kx window in-bounds: one contiguous copy of k·C.
                    let src = xrow + ix0 as usize * d.c;
                    cols[dst..dst + kc].copy_from_slice(&x[src..src + kc]);
                } else {
                    for kx in 0..d.k {
                        let ix = ix0 + kx as isize;
                        if ix < 0 || ix >= d.w as isize {
                            continue;
                        }
                        let src = xrow + ix as usize * d.c;
                        let dst = dst + kx * d.c;
                        cols[dst..dst + d.c].copy_from_slice(&x[src..src + d.c]);
                    }
                }
            }
        }
    }
}

// ---- packed-B micro-kernel GEMM (the conv engine's single hot path) -------
//
// All packed kernels are panel-windowed: a caller may contract against any
// sub-range of the NR-column panels ([`gemm_packed_acc_panels_raw`],
// [`gemm_tn_acc_cols_raw`]), which is what lets the inner layer's 2D
// row×column tile grid (`inner/scheduler.rs`) split one GEMM's output
// columns across workers when batch rows alone cannot feed them.

/// Rows of the register accumulator tile.
pub const MR: usize = 4;
/// Columns of the register accumulator tile (one 8-lane f32 vector).
pub const NR: usize = 8;

/// The B operand (`kk × n`, row-major source) packed into cache/register
/// blocked panels: columns are split into ⌈n/NR⌉ panels of `NR` columns, and
/// within a panel element `(l, j)` lives at `panel·NR·kk + l·NR + j`. The
/// micro-kernel then streams one contiguous `NR`-wide row per `l` step —
/// unit-stride loads regardless of `n`. Ragged final panels are zero-padded,
/// so kernels can always load full `NR` lanes.
///
/// For convolutions B is the HWIO filter viewed as a `(k²·C, C_o)` matrix
/// ([`pack_filter`]); it is packed **once per weight mutation** (cached in
/// [`crate::nn::WeightPacks`]) and shared read-only by every row-tile task
/// of every image in the batch.
#[derive(Debug)]
pub struct PackedB {
    data: Vec<f32>,
    kk: usize,
    n: usize,
}

impl PackedB {
    /// An empty pack slot, to be filled by [`PackedB::repack`] /
    /// [`PackedB::repack_transposed`] (the weight-pack cache pre-sizes its
    /// slot vectors with these).
    pub fn empty() -> Self {
        PackedB { data: Vec::new(), kk: 0, n: 0 }
    }

    /// Pack `b` (`kk × n`, row-major).
    pub fn pack(kk: usize, n: usize, b: &[f32]) -> Self {
        let mut p = PackedB { data: Vec::new(), kk: 0, n: 0 };
        p.repack(kk, n, b);
        p
    }

    /// Re-fill in place, reusing the allocation when the new panel layout
    /// fits (arena-style reuse across layer calls).
    pub fn repack(&mut self, kk: usize, n: usize, b: &[f32]) {
        debug_assert_eq!(b.len(), kk * n);
        self.kk = kk;
        self.n = n;
        let panels = (n + NR - 1) / NR;
        let len = panels * NR * kk;
        self.data.clear();
        self.data.resize(len, 0.0);
        for p in 0..panels {
            let j0 = p * NR;
            let w = NR.min(n - j0);
            let panel = &mut self.data[p * NR * kk..(p + 1) * NR * kk];
            for l in 0..kk {
                panel[l * NR..l * NR + w].copy_from_slice(&b[l * n + j0..l * n + j0 + w]);
            }
        }
    }

    /// Pack `bᵀ` given `b` (`rows × cols`, row-major) without materializing
    /// the transpose: the result contracts over `cols` and produces `rows`
    /// output columns. This is how the dense backward's `dx = dy · Wᵀ`
    /// reuses the forward micro-kernel on the same `(k, n)` weight matrix.
    pub fn pack_transposed(rows: usize, cols: usize, b: &[f32]) -> Self {
        let mut p = PackedB { data: Vec::new(), kk: 0, n: 0 };
        p.repack_transposed(rows, cols, b);
        p
    }

    /// Transposed analogue of [`PackedB::repack`] (arena-style reuse).
    pub fn repack_transposed(&mut self, rows: usize, cols: usize, b: &[f32]) {
        debug_assert_eq!(b.len(), rows * cols);
        self.kk = cols;
        self.n = rows;
        let panels = (rows + NR - 1) / NR;
        self.data.clear();
        self.data.resize(panels * NR * cols, 0.0);
        for p in 0..panels {
            let j0 = p * NR;
            let w = NR.min(rows - j0);
            let panel = &mut self.data[p * NR * cols..(p + 1) * NR * cols];
            for l in 0..cols {
                for j in 0..w {
                    panel[l * NR + j] = b[(j0 + j) * cols + l];
                }
            }
        }
    }

    /// Shared (contraction) dimension.
    pub fn kk(&self) -> usize {
        self.kk
    }

    /// Output columns.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of NR-column panels (⌈n/NR⌉) — the column-tile grain of the
    /// 2D row×panel decomposition: a column tile is always a whole number
    /// of panels, so tiled kernels never split a panel.
    pub fn panels(&self) -> usize {
        (self.n + NR - 1) / NR
    }
}

/// Column window `(j0, width)` covered by panels `[p0, p0+np)` of an
/// `n`-column operand — the element range a (row × panel) tile owns.
#[inline]
pub fn panel_window(n: usize, p0: usize, np: usize) -> (usize, usize) {
    let j0 = p0 * NR;
    let hi = ((p0 + np) * NR).min(n);
    debug_assert!(j0 < hi, "empty panel window p0={p0} np={np} n={n}");
    (j0, hi - j0)
}

/// Pack the HWIO filter of `d` viewed as a `(k²·C, C_o)` matrix.
pub fn pack_filter(d: &ConvDims, f: &[f32]) -> PackedB {
    debug_assert_eq!(f.len(), d.f_len());
    PackedB::pack(d.k * d.k * d.c, d.co, f)
}

/// Register-blocked `MR×NR` inner kernel: accumulates `MR` rows of A against
/// one packed panel into a stack tile, then adds the live `w ≤ NR` columns
/// into C. `a` holds at least `MR` consecutive rows (stride `kk`); `c` points
/// at the first row's panel window (row stride `n`). C is a raw pointer so
/// 2D tiles sharing one output allocation never materialize overlapping
/// `&mut` slices — writes stay within the tile's column window.
///
/// # Safety
/// `c[r·n + j]` must be valid for read+write for all `r < MR`, `j < w`, with
/// no concurrent access to those elements.
#[inline(always)]
unsafe fn kernel_4x8(kk: usize, n: usize, a: &[f32], bp: &[f32], c: *mut f32, w: usize) {
    let a0 = &a[..kk];
    let a1 = &a[kk..2 * kk];
    let a2 = &a[2 * kk..3 * kk];
    let a3 = &a[3 * kk..4 * kk];
    let mut acc = [[0.0f32; NR]; MR];
    for l in 0..kk {
        let bl = &bp[l * NR..(l + 1) * NR];
        let av = [a0[l], a1[l], a2[l], a3[l]];
        for r in 0..MR {
            let ar = av[r];
            for j in 0..NR {
                acc[r][j] += ar * bl[j];
            }
        }
    }
    for r in 0..MR {
        // SAFETY: caller guarantees c[r·n + j] valid for r < MR, j < w.
        unsafe {
            let crow = c.add(r * n);
            for j in 0..w {
                *crow.add(j) += acc[r][j];
            }
        }
    }
}

/// Single-row edge kernel for the `m mod MR` remainder.
///
/// # Safety
/// `c[j]` must be valid for read+write for `j < w`, with no concurrent
/// access to those elements.
#[inline(always)]
unsafe fn kernel_1x8(kk: usize, a: &[f32], bp: &[f32], c: *mut f32, w: usize) {
    let mut acc = [0.0f32; NR];
    for l in 0..kk {
        let av = a[l];
        let bl = &bp[l * NR..(l + 1) * NR];
        for j in 0..NR {
            acc[j] += av * bl[j];
        }
    }
    // SAFETY: caller guarantees c[j] valid for j < w.
    unsafe {
        for j in 0..w {
            *c.add(j) += acc[j];
        }
    }
}

/// # Safety
/// See [`gemm_packed_acc_panels_raw`].
unsafe fn gemm_packed_scalar(m: usize, a: &[f32], b: &PackedB, c: *mut f32, p0: usize, np: usize) {
    let (kk, n) = (b.kk, b.n);
    for p in p0..p0 + np {
        let j0 = p * NR;
        let w = NR.min(n - j0);
        let bp = &b.data[p * NR * kk..(p + 1) * NR * kk];
        let mut i = 0;
        while i + MR <= m {
            // SAFETY: rows [i, i+MR) × columns [j0, j0+w) lie inside the
            // output window the caller owns per this fn's contract.
            unsafe { kernel_4x8(kk, n, &a[i * kk..(i + MR) * kk], bp, c.add(i * n + j0), w) };
            i += MR;
        }
        while i < m {
            // SAFETY: as above, for the single remainder row i.
            unsafe { kernel_1x8(kk, &a[i * kk..(i + 1) * kk], bp, c.add(i * n + j0), w) };
            i += 1;
        }
    }
}

/// Explicit AVX2+FMA micro-kernels (x86-64 only), selected at runtime behind
/// the `simd` cargo feature. Same contract and tiling as the scalar kernels;
/// FMA contraction changes rounding within f32 tolerance.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod simd {
    use super::{PackedB, MR, NR};

    pub fn fma_available() -> bool {
        std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma")
    }

    /// # Safety
    /// Requires AVX2 and FMA (check [`fma_available`] first); `c` carries
    /// the [`super::gemm_packed_acc_panels_raw`] output contract.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn gemm_packed_acc_fma(
        m: usize,
        a: &[f32],
        b: &PackedB,
        c: *mut f32,
        p0: usize,
        np: usize,
    ) {
        use std::arch::x86_64::*;
        let (kk, n) = (b.kk, b.n);
        // SAFETY: every packed-B load stays inside panel `p`'s `NR·kk`
        // slice (loads are unaligned), every A load inside the `m·kk`
        // slice, and every C access inside the caller-owned panel window;
        // the AVX2/FMA intrinsics themselves are licensed by this fn's
        // target_feature + the fma_available() runtime check.
        unsafe {
            for p in p0..p0 + np {
                let j0 = p * NR;
                let w = NR.min(n - j0);
                let bp = b.data[p * NR * kk..(p + 1) * NR * kk].as_ptr();
                let mut i = 0;
                while i + MR <= m {
                    let ap = a.as_ptr().add(i * kk);
                    let mut acc0 = _mm256_setzero_ps();
                    let mut acc1 = _mm256_setzero_ps();
                    let mut acc2 = _mm256_setzero_ps();
                    let mut acc3 = _mm256_setzero_ps();
                    for l in 0..kk {
                        let bv = _mm256_loadu_ps(bp.add(l * NR));
                        acc0 = _mm256_fmadd_ps(_mm256_set1_ps(*ap.add(l)), bv, acc0);
                        acc1 = _mm256_fmadd_ps(_mm256_set1_ps(*ap.add(kk + l)), bv, acc1);
                        acc2 = _mm256_fmadd_ps(_mm256_set1_ps(*ap.add(2 * kk + l)), bv, acc2);
                        acc3 = _mm256_fmadd_ps(_mm256_set1_ps(*ap.add(3 * kk + l)), bv, acc3);
                    }
                    let accs = [acc0, acc1, acc2, acc3];
                    let mut buf = [0.0f32; NR];
                    for (r, acc) in accs.into_iter().enumerate() {
                        _mm256_storeu_ps(buf.as_mut_ptr(), acc);
                        let crow = c.add((i + r) * n + j0);
                        for (j, &v) in buf.iter().enumerate().take(w) {
                            *crow.add(j) += v;
                        }
                    }
                    i += MR;
                }
                while i < m {
                    let ap = a.as_ptr().add(i * kk);
                    let mut acc = _mm256_setzero_ps();
                    for l in 0..kk {
                        let bv = _mm256_loadu_ps(bp.add(l * NR));
                        acc = _mm256_fmadd_ps(_mm256_set1_ps(*ap.add(l)), bv, acc);
                    }
                    let mut buf = [0.0f32; NR];
                    _mm256_storeu_ps(buf.as_mut_ptr(), acc);
                    let crow = c.add(i * n + j0);
                    for (j, &v) in buf.iter().enumerate().take(w) {
                        *crow.add(j) += v;
                    }
                    i += 1;
                }
            }
        }
    }
}

/// `C (m×n, row-major) += A (m×kk, row-major) · B` with `B` pre-packed. This
/// is the single hot kernel shared by conv forward, backward-input (flipped
/// filter) and — through [`gemm_tn_acc`] — the structure of backward-filter.
pub fn gemm_packed_acc(m: usize, a: &[f32], b: &PackedB, c: &mut [f32]) {
    debug_assert_eq!(c.len(), m * b.n);
    // SAFETY: `c` is exclusively borrowed and covers the full m×n output.
    unsafe { gemm_packed_acc_panels_raw(m, a, b, c.as_mut_ptr(), 0, b.panels()) }
}

/// Panel-range form of [`gemm_packed_acc`] on an exclusively-borrowed full
/// output: `C[:, j0..j0+w) += A · B[:, j0..j0+w)` for the column window of
/// panels `[p0, p0+np)`. A windowed sweep over all panels is bit-identical
/// to one full call (each panel owns an independent register accumulator).
pub fn gemm_packed_acc_panels(
    m: usize,
    a: &[f32],
    b: &PackedB,
    c: &mut [f32],
    p0: usize,
    np: usize,
) {
    debug_assert_eq!(c.len(), m * b.n);
    // SAFETY: `c` is exclusively borrowed and covers the full m×n output.
    unsafe { gemm_packed_acc_panels_raw(m, a, b, c.as_mut_ptr(), p0, np) }
}

/// The 2D-tile GEMM entry point: like [`gemm_packed_acc_panels`] but the
/// output is a raw pointer to element (0, 0) of the full row-major `m×n`
/// matrix, so concurrent tiles over disjoint (row-range × panel-range)
/// blocks can share one allocation without ever materializing overlapping
/// `&mut` slices. Writes touch only elements `c[i·n + j]` with `i < m` and
/// `j` inside the window of panels `[p0, p0+np)`.
///
/// # Safety
/// `c[i·n + j]` must be valid for read+write for every `i < m` and `j` in
/// the panel window, and no other thread may concurrently access those
/// elements.
pub unsafe fn gemm_packed_acc_panels_raw(
    m: usize,
    a: &[f32],
    b: &PackedB,
    c: *mut f32,
    p0: usize,
    np: usize,
) {
    debug_assert_eq!(a.len(), m * b.kk);
    debug_assert!(p0 + np <= b.panels(), "panel range out of bounds");
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd::fma_available() {
            // SAFETY: feature presence checked at runtime; output contract
            // forwarded from this function's own.
            return unsafe { simd::gemm_packed_acc_fma(m, a, b, c, p0, np) };
        }
    }
    // SAFETY: output contract forwarded from this function's own.
    unsafe { gemm_packed_scalar(m, a, b, c, p0, np) };
}

// ---- legacy blocked GEMM (pre-packing baseline, kept for benches) ---------

/// `C (m×n) += A (m×kk) · B (kk×n)`, all row-major. The pre-`PackedB`
/// blocked GEMM, retained as the benches' "unpacked" baseline (the PR-1
/// engine the packed kernel is measured against) and as a second oracle.
pub fn gemm_acc(m: usize, kk: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * kk);
    debug_assert_eq!(b.len(), kk * n);
    debug_assert_eq!(c.len(), m * n);
    const KC: usize = 256;
    let mut l0 = 0;
    while l0 < kk {
        let lb = KC.min(kk - l0);
        for i in 0..m {
            let arow = &a[i * kk + l0..i * kk + l0 + lb];
            let crow = &mut c[i * n..(i + 1) * n];
            for (dl, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue; // zero-padded border columns
                }
                let brow = &b[(l0 + dl) * n..(l0 + dl + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                    *cv += av * bv;
                }
            }
        }
        l0 += lb;
    }
}

/// `C (kk×n) += Aᵀ · B` where `A` is `(m×kk)` and `B` is `(m×n)` — the
/// Eq. 21 filter-gradient contraction (patchesᵀ · dy). Register-blocked over
/// four rows of C so each pass over `B` feeds four accumulator rows;
/// per-element accumulation order (increasing `i`) matches the row-at-a-time
/// loop, so results are unchanged. Public so the row-tile backward tasks
/// (`inner/bp_tasks.rs`) can accumulate straight into per-worker arenas.
pub fn gemm_tn_acc(m: usize, kk: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(c.len(), kk * n);
    // SAFETY: b/c are plain borrows covering the full window.
    unsafe { gemm_tn_acc_cols_raw(m, kk, n, a, b.as_ptr(), c.as_mut_ptr(), 0, n) }
}

/// Column-windowed Eq.-21 contraction: `C[:, j0..j0+jw) += Aᵀ·B[:, j0..j0+jw)`
/// with `C` (kk×n) and `B` (m×n) row-major. The dW column tiles of the 2D
/// grid use this to fill disjoint stripes of a per-worker arena; per-element
/// accumulation order is identical to [`gemm_tn_acc`], so a windowed sweep
/// over `[0, n)` is bit-identical to one full call.
pub fn gemm_tn_acc_cols(
    m: usize,
    kk: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    j0: usize,
    jw: usize,
) {
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(c.len(), kk * n);
    // SAFETY: b/c are plain borrows covering the window.
    unsafe { gemm_tn_acc_cols_raw(m, kk, n, a, b.as_ptr(), c.as_mut_ptr(), j0, jw) }
}

/// Raw form of [`gemm_tn_acc_cols`] for 2D-tile tasks whose `B` matrix is
/// concurrently written by other tasks in *other* column windows (the dense
/// backward masks `dy` tile by tile): `b` and `c` address element (0, 0) of
/// the full matrices; reads and writes stay inside columns `[j0, j0+jw)`.
///
/// # Safety
/// `b[i·n + j]` must be valid for reads and `c[l·n + j]` for reads+writes
/// for all `i < m`, `l < kk`, `j` in `[j0, j0+jw)`, with no concurrent
/// writer to `b`'s window and no concurrent access to `c`'s window.
pub unsafe fn gemm_tn_acc_cols_raw(
    m: usize,
    kk: usize,
    n: usize,
    a: &[f32],
    b: *const f32,
    c: *mut f32,
    j0: usize,
    jw: usize,
) {
    debug_assert_eq!(a.len(), m * kk);
    debug_assert!(j0 + jw <= n, "column window out of bounds");
    // SAFETY: all B reads are b[i·n + j] with i < m and all C accesses
    // c[l·n + j] with l < kk, j in [j0, j0+jw) — exactly the windows the
    // caller guarantees valid and unaliased per this fn's contract.
    unsafe {
        let mut l0 = 0;
        while l0 + 4 <= kk {
            let c0 = c.add(l0 * n + j0);
            let c1 = c.add((l0 + 1) * n + j0);
            let c2 = c.add((l0 + 2) * n + j0);
            let c3 = c.add((l0 + 3) * n + j0);
            for i in 0..m {
                let av = &a[i * kk + l0..i * kk + l0 + 4];
                if av[0] == 0.0 && av[1] == 0.0 && av[2] == 0.0 && av[3] == 0.0 {
                    continue; // fully zero-padded patch columns
                }
                let brow = b.add(i * n + j0);
                for j in 0..jw {
                    let bv = *brow.add(j);
                    *c0.add(j) += av[0] * bv;
                    *c1.add(j) += av[1] * bv;
                    *c2.add(j) += av[2] * bv;
                    *c3.add(j) += av[3] * bv;
                }
            }
            l0 += 4;
        }
        while l0 < kk {
            let crow = c.add(l0 * n + j0);
            for i in 0..m {
                let av = a[i * kk + l0];
                if av == 0.0 {
                    continue;
                }
                let brow = b.add(i * n + j0);
                for j in 0..jw {
                    *crow.add(j) += av * *brow.add(j);
                }
            }
            l0 += 1;
        }
    }
}

/// Forward row-tile via im2col + packed-B micro-kernel GEMM: computes output
/// rows `[y0, y0+rows)` of image `n` into `out` (length `rows·W·C_o`).
/// `packed` is the filter packed once per layer call ([`pack_filter`]);
/// `cols` is caller-provided patch scratch of length `rows·W·k²·C` — the
/// inner-layer conv tasks take it from their worker's persistent
/// [`crate::util::threadpool::ScratchArena`], so the task body allocates
/// nothing.
pub fn conv2d_same_rows_packed(
    d: &ConvDims,
    x: &[f32],
    packed: &PackedB,
    bias: &[f32],
    n: usize,
    y0: usize,
    rows: usize,
    cols: &mut [f32],
    out: &mut [f32],
) {
    let kkc = d.k * d.k * d.c;
    debug_assert_eq!(packed.kk(), kkc);
    debug_assert_eq!(packed.n(), d.co);
    debug_assert_eq!(out.len(), rows * d.w * d.co);
    debug_assert_eq!(cols.len(), rows * d.w * kkc);
    for px in 0..rows * d.w {
        out[px * d.co..(px + 1) * d.co].copy_from_slice(bias);
    }
    im2col_rows(d, x, n, y0, rows, cols);
    gemm_packed_acc(rows * d.w, cols, packed, out);
}

/// Legacy forward row-tile (unpacked blocked GEMM) — the PR-1 engine, kept
/// as the benches' baseline for the packed kernel.
pub fn conv2d_same_rows_gemm(
    d: &ConvDims,
    x: &[f32],
    f: &[f32],
    bias: &[f32],
    n: usize,
    y0: usize,
    rows: usize,
    cols: &mut [f32],
    out: &mut [f32],
) {
    let kkc = d.k * d.k * d.c;
    debug_assert_eq!(out.len(), rows * d.w * d.co);
    debug_assert_eq!(cols.len(), rows * d.w * kkc);
    for px in 0..rows * d.w {
        out[px * d.co..(px + 1) * d.co].copy_from_slice(bias);
    }
    im2col_rows(d, x, n, y0, rows, cols);
    gemm_acc(rows * d.w, kkc, d.co, cols, f, out);
}

/// Full SAME convolution forward: Eq. (1) with zero padding, stride 1.
/// Packs the filter once, then runs im2col + the packed micro-kernel over
/// row tiles. Matches [`conv2d_same_fwd_naive`] to f32 reduction-order
/// tolerance (the register tile accumulates before adding the bias-seeded
/// output, and the optional FMA kernel fuses the multiply-add rounding).
pub fn conv2d_same_fwd(d: &ConvDims, x: &[f32], f: &[f32], bias: &[f32], out: &mut [f32]) {
    debug_assert_eq!(f.len(), d.f_len());
    let packed = pack_filter(d, f);
    let mut cols = Vec::new();
    conv2d_same_fwd_packed(d, x, &packed, bias, &mut cols, out);
}

/// [`conv2d_same_fwd`] on a pre-packed filter and caller-owned im2col
/// scratch — the allocation-free form the [`crate::nn::StepWorkspace`] train
/// step uses (the filter pack comes from the network's weight-pack cache,
/// `cols` grows once and is reused across batches).
pub fn conv2d_same_fwd_packed(
    d: &ConvDims,
    x: &[f32],
    packed: &PackedB,
    bias: &[f32],
    cols: &mut Vec<f32>,
    out: &mut [f32],
) {
    debug_assert_eq!(x.len(), d.x_len());
    debug_assert_eq!(bias.len(), d.co);
    debug_assert_eq!(out.len(), d.y_len());
    let kkc = d.k * d.k * d.c;
    debug_assert_eq!(packed.kk(), kkc);
    debug_assert_eq!(packed.n(), d.co);
    let row = d.w * d.co;
    let tile = d.h.min(IM2COL_TILE_ROWS);
    cols.resize(tile * d.w * kkc, 0.0);
    for n in 0..d.n {
        let mut y0 = 0;
        while y0 < d.h {
            let rows = tile.min(d.h - y0);
            let start = (n * d.h + y0) * row;
            conv2d_same_rows_packed(
                d,
                x,
                packed,
                bias,
                n,
                y0,
                rows,
                &mut cols[..rows * d.w * kkc],
                &mut out[start..start + rows * row],
            );
            y0 += rows;
        }
    }
}

/// Backward of SAME conv w.r.t. input (Eq. 18): full correlation with the
/// flipped filter. For odd kernels (P = (k−1)/2 symmetric) this is exactly a
/// SAME forward conv of `dy` with the spatially-flipped, channel-transposed
/// filter, so it rides the same im2col+GEMM path; even kernels (asymmetric
/// implicit padding) fall back to the direct loops.
pub fn conv2d_same_bwd_input(d: &ConvDims, dy: &[f32], f: &[f32], dx: &mut [f32]) {
    debug_assert_eq!(dy.len(), d.y_len());
    debug_assert_eq!(dx.len(), d.x_len());
    if d.k % 2 == 0 {
        return conv2d_same_bwd_input_naive(d, dy, f, dx);
    }
    let dd = ConvDims { c: d.co, co: d.c, ..*d };
    let packed = pack_filter(&dd, &flip_transpose_filter(d, f));
    let mut cols = Vec::new();
    conv2d_same_bwd_input_packed(d, dy, &packed, dx, &mut cols);
}

/// Odd-kernel input gradient on a pre-packed flipped/transposed filter
/// (`pack_filter(&swapped, &flip_transpose_filter(d, f))` with
/// `swapped = {c: co, co: c}`) and caller-owned im2col scratch — the
/// allocation-free form the workspace train step uses.
pub fn conv2d_same_bwd_input_packed(
    d: &ConvDims,
    dy: &[f32],
    flip_packed: &PackedB,
    dx: &mut [f32],
    cols: &mut Vec<f32>,
) {
    debug_assert!(d.k % 2 == 1, "even kernels take the naive fallback");
    debug_assert_eq!(dy.len(), d.y_len());
    debug_assert_eq!(dx.len(), d.x_len());
    let dd = ConvDims { c: d.co, co: d.c, ..*d };
    let kkc = dd.k * dd.k * dd.c;
    debug_assert_eq!(flip_packed.kk(), kkc);
    debug_assert_eq!(flip_packed.n(), dd.co);
    let row = dd.w * dd.co;
    let tile = dd.h.min(IM2COL_TILE_ROWS);
    cols.resize(tile * dd.w * kkc, 0.0);
    for n in 0..dd.n {
        let mut y0 = 0;
        while y0 < dd.h {
            let rows = tile.min(dd.h - y0);
            let start = (n * dd.h + y0) * row;
            let out = &mut dx[start..start + rows * row];
            out.fill(0.0);
            im2col_rows(&dd, dy, n, y0, rows, &mut cols[..rows * dd.w * kkc]);
            gemm_packed_acc(rows * dd.w, &cols[..rows * dd.w * kkc], flip_packed, out);
            y0 += rows;
        }
    }
}

/// The spatially-flipped, channel-transposed filter the input-gradient conv
/// uses: `ff[ky, kx, o, c] = f[k−1−ky, k−1−kx, c, o]` (HWIO in, HW"OI" out).
/// Exposed so batch-parallel callers (`inner/bp_tasks.rs`) can build it once
/// and share it across per-image tasks instead of re-flipping per task.
pub fn flip_transpose_filter(d: &ConvDims, f: &[f32]) -> Vec<f32> {
    let mut ff = vec![0.0f32; d.f_len()];
    flip_transpose_filter_into(d, f, &mut ff);
    ff
}

/// [`flip_transpose_filter`] into a caller-owned buffer (allocation-free
/// form for the workspace/pack-cache path).
pub fn flip_transpose_filter_into(d: &ConvDims, f: &[f32], ff: &mut [f32]) {
    debug_assert_eq!(f.len(), d.f_len());
    debug_assert_eq!(ff.len(), d.f_len());
    for ky in 0..d.k {
        for kx in 0..d.k {
            for c in 0..d.c {
                for o in 0..d.co {
                    ff[((ky * d.k + kx) * d.co + o) * d.c + c] =
                        f[fi(d, d.k - 1 - ky, d.k - 1 - kx, c, o)];
                }
            }
        }
    }
}

/// Backward of SAME conv w.r.t. the filter (Eq. 21) and bias (Eq. 22):
/// `df = im2col(x)ᵀ · dy` accumulated tile by tile (blocked GEMM), `db` the
/// column sums of `dy`.
pub fn conv2d_same_bwd_filter(
    d: &ConvDims,
    x: &[f32],
    dy: &[f32],
    df: &mut [f32],
    db: &mut [f32],
) {
    let mut cols = Vec::new();
    conv2d_same_bwd_filter_ws(d, x, dy, df, db, &mut cols);
}

/// [`conv2d_same_bwd_filter`] on caller-owned im2col scratch — the
/// allocation-free form the workspace train step uses.
pub fn conv2d_same_bwd_filter_ws(
    d: &ConvDims,
    x: &[f32],
    dy: &[f32],
    df: &mut [f32],
    db: &mut [f32],
    cols: &mut Vec<f32>,
) {
    debug_assert_eq!(x.len(), d.x_len());
    debug_assert_eq!(dy.len(), d.y_len());
    debug_assert_eq!(df.len(), d.f_len());
    debug_assert_eq!(db.len(), d.co);
    df.fill(0.0);
    db.fill(0.0);
    let kkc = d.k * d.k * d.c;
    let tile = d.h.min(IM2COL_TILE_ROWS);
    cols.resize(tile * d.w * kkc, 0.0);
    for n in 0..d.n {
        let mut y0 = 0;
        while y0 < d.h {
            let rows = tile.min(d.h - y0);
            let patches = rows * d.w;
            im2col_rows(d, x, n, y0, rows, &mut cols[..patches * kkc]);
            let dy0 = (n * d.h + y0) * d.w * d.co;
            let dyb = &dy[dy0..dy0 + patches * d.co];
            gemm_tn_acc(patches, kkc, d.co, &cols[..patches * kkc], dyb, df);
            for px in 0..patches {
                let dyr = &dyb[px * d.co..(px + 1) * d.co];
                for (acc, &v) in db.iter_mut().zip(dyr.iter()) {
                    *acc += v;
                }
            }
            y0 += rows;
        }
    }
}

/// ReLU forward in-place; returns nothing (mask derivable from output).
pub fn relu_fwd(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// ReLU backward: `dx = dy * (out > 0)` where `out` is the *post*-ReLU
/// activation.
pub fn relu_bwd(out: &[f32], dy: &mut [f32]) {
    for (g, &o) in dy.iter_mut().zip(out.iter()) {
        if o <= 0.0 {
            *g = 0.0;
        }
    }
}

/// Non-overlapping mean pool forward. `(n, h, w, c)` → `(n, h/win, w/win, c)`.
pub fn mean_pool_fwd(n: usize, h: usize, w: usize, c: usize, win: usize, x: &[f32], out: &mut [f32]) {
    let ho = h / win;
    let wo = w / win;
    debug_assert_eq!(out.len(), n * ho * wo * c);
    let inv = 1.0 / (win * win) as f32;
    for b in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                let obase = ((b * ho + oy) * wo + ox) * c;
                for ch in 0..c {
                    out[obase + ch] = 0.0;
                }
                for dy_ in 0..win {
                    for dx_ in 0..win {
                        let ibase = ((b * h + oy * win + dy_) * w + ox * win + dx_) * c;
                        for ch in 0..c {
                            out[obase + ch] += x[ibase + ch];
                        }
                    }
                }
                for ch in 0..c {
                    out[obase + ch] *= inv;
                }
            }
        }
    }
}

/// Mean pool backward: uniform spread of the gradient over each window.
pub fn mean_pool_bwd(n: usize, h: usize, w: usize, c: usize, win: usize, dy: &[f32], dx: &mut [f32]) {
    let ho = h / win;
    let wo = w / win;
    debug_assert_eq!(dy.len(), n * ho * wo * c);
    debug_assert_eq!(dx.len(), n * h * w * c);
    dx.fill(0.0);
    let inv = 1.0 / (win * win) as f32;
    for b in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                let obase = ((b * ho + oy) * wo + ox) * c;
                for dy_ in 0..win {
                    for dx_ in 0..win {
                        let ibase = ((b * h + oy * win + dy_) * w + ox * win + dx_) * c;
                        for ch in 0..c {
                            dx[ibase + ch] = dy[obase + ch] * inv;
                        }
                    }
                }
            }
        }
    }
}

/// Dense forward: `out = x @ w + b`; x is `(m, k)`, w `(k, n)`, b `(n,)`.
pub fn dense_fwd(m: usize, k: usize, n: usize, x: &[f32], w: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let orow = &mut out[i * n..(i + 1) * n];
        orow.copy_from_slice(b);
        let xrow = &x[i * k..(i + 1) * k];
        for (kk, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue; // post-ReLU activations are often sparse
            }
            let wrow = &w[kk * n..(kk + 1) * n];
            for j in 0..n {
                orow[j] += xv * wrow[j];
            }
        }
    }
}

/// Dense backward: `dx = dy @ wᵀ`, `dw = xᵀ @ dy`, `db = Σ dy`.
pub fn dense_bwd(
    m: usize,
    k: usize,
    n: usize,
    x: &[f32],
    w: &[f32],
    dy: &[f32],
    dx: &mut [f32],
    dw: &mut [f32],
    db: &mut [f32],
) {
    debug_assert_eq!(dy.len(), m * n);
    dx.fill(0.0);
    dw.fill(0.0);
    db.fill(0.0);
    for i in 0..m {
        let dyrow = &dy[i * n..(i + 1) * n];
        for j in 0..n {
            db[j] += dyrow[j];
        }
        let xrow = &x[i * k..(i + 1) * k];
        let dxrow = &mut dx[i * k..(i + 1) * k];
        for kk in 0..k {
            let wrow = &w[kk * n..(kk + 1) * n];
            let mut acc = 0.0f32;
            for j in 0..n {
                acc += dyrow[j] * wrow[j];
            }
            dxrow[kk] = acc;
            let xv = xrow[kk];
            if xv != 0.0 {
                let dwrow = &mut dw[kk * n..(kk + 1) * n];
                for j in 0..n {
                    dwrow[j] += xv * dyrow[j];
                }
            }
        }
    }
}

/// Dense forward on a pre-packed weight: `out = x · W + b` with `W` (k×n)
/// packed once per step ([`PackedB::pack`]) and shared across all batch rows
/// — FC layers ride the same 4×8 micro-kernel as the conv stack. Matches
/// [`dense_fwd`] to f32 reduction-order tolerance (register-tile
/// accumulation vs the naive row-at-a-time loop).
pub fn dense_fwd_packed(m: usize, x: &[f32], w: &PackedB, b: &[f32], out: &mut [f32]) {
    let (k, n) = (w.kk(), w.n());
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(b.len(), n);
    debug_assert_eq!(out.len(), m * n);
    for row in out.chunks_exact_mut(n) {
        row.copy_from_slice(b);
    }
    gemm_packed_acc(m, x, w, out);
}

/// Dense backward on packed operands: `dx = dy · Wᵀ` rides the packed
/// micro-kernel with `wt` the *transposed* pack of the same `(k, n)` weight
/// ([`PackedB::pack_transposed`], so `wt.kk() == n`, `wt.n() == k`);
/// `dw = xᵀ · dy` rides [`gemm_tn_acc`] exactly like the conv filter
/// gradient; `db = Σ dy`. Matches [`dense_bwd`] to f32 reduction-order
/// tolerance.
pub fn dense_bwd_packed(
    m: usize,
    k: usize,
    n: usize,
    x: &[f32],
    wt: &PackedB,
    dy: &[f32],
    dx: &mut [f32],
    dw: &mut [f32],
    db: &mut [f32],
) {
    debug_assert_eq!(wt.kk(), n, "wt must be the transposed pack");
    debug_assert_eq!(wt.n(), k, "wt must be the transposed pack");
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(dx.len(), m * k);
    debug_assert_eq!(dw.len(), k * n);
    debug_assert_eq!(db.len(), n);
    dx.fill(0.0);
    gemm_packed_acc(m, dy, wt, dx);
    dw.fill(0.0);
    gemm_tn_acc(m, k, n, x, dy, dw);
    db.fill(0.0);
    for dyrow in dy.chunks_exact(n) {
        for (acc, &v) in db.iter_mut().zip(dyrow.iter()) {
            *acc += v;
        }
    }
}

/// Softmax over the last axis of a `(m, n)` matrix, in place.
pub fn softmax_rows(m: usize, n: usize, x: &mut [f32]) {
    for i in 0..m {
        let row = &mut x[i * n..(i + 1) * n];
        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

/// Square-error loss of the output layer (Eq. 16) on softmax probabilities,
/// averaged over the batch; also returns the gradient w.r.t. the logits and
/// the number of correct argmax predictions.
///
/// dE/dz_j = p_j · (g_j − Σ_i g_i·p_i) with g = 2(p − y)/B (softmax Jacobian
/// applied to the square-error gradient).
pub fn mse_softmax_loss(
    m: usize,
    n: usize,
    logits: &[f32],
    y: &[f32],
    dlogits: &mut [f32],
) -> (f32, usize) {
    let mut probs = vec![0.0f32; m * n];
    mse_softmax_loss_into(m, n, logits, y, dlogits, &mut probs)
}

/// [`mse_softmax_loss`] with caller-owned softmax scratch (`probs`, length
/// `m·n`) — the allocation-free form the workspace train step uses. Also
/// the row-range building block of the parallel loss stage
/// (`inner/fc_tasks.rs`): the sums it returns are per-call, so callers
/// aggregating tiles divide by the *full* batch themselves.
pub fn mse_softmax_loss_into(
    m: usize,
    n: usize,
    logits: &[f32],
    y: &[f32],
    dlogits: &mut [f32],
    probs: &mut [f32],
) -> (f32, usize) {
    debug_assert_eq!(logits.len(), m * n);
    debug_assert_eq!(y.len(), m * n);
    debug_assert_eq!(dlogits.len(), m * n);
    debug_assert_eq!(probs.len(), m * n);
    probs.copy_from_slice(logits);
    softmax_rows(m, n, probs);
    let (loss, correct) = mse_softmax_rows(m, n, logits, y, dlogits, probs, 1.0 / m as f32);
    ((loss / m as f64) as f32, correct)
}

/// Loss/gradient core over `m` rows whose softmax `probs` are already
/// computed: returns the *unnormalized* squared-error sum and correct count.
/// `inv_b` is 1/B of the gradient's batch normalization (the full batch
/// size, which for a row tile differs from `m`).
pub(crate) fn mse_softmax_rows(
    m: usize,
    n: usize,
    logits: &[f32],
    y: &[f32],
    dlogits: &mut [f32],
    probs: &[f32],
    inv_b: f32,
) -> (f64, usize) {
    let mut loss = 0.0f64;
    let mut correct = 0usize;
    for i in 0..m {
        let p = &probs[i * n..(i + 1) * n];
        let yy = &y[i * n..(i + 1) * n];
        let zrow = &logits[i * n..(i + 1) * n];
        // loss
        for j in 0..n {
            let d = (yy[j] - p[j]) as f64;
            loss += d * d;
        }
        // correctness (argmax of logits vs one-hot)
        let pred = argmax(zrow);
        let truth = argmax(yy);
        if pred == truth {
            correct += 1;
        }
        // gradient: g_j = 2(p_j − y_j)/B computed in place (no scratch row)
        let gp: f32 = (0..n).map(|j| 2.0 * (p[j] - yy[j]) * inv_b * p[j]).sum();
        let drow = &mut dlogits[i * n..(i + 1) * n];
        for j in 0..n {
            drow[j] = p[j] * (2.0 * (p[j] - yy[j]) * inv_b - gp);
        }
    }
    (loss, correct)
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn rand_vec(rng: &mut Xoshiro256, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect()
    }

    /// Brute-force SAME conv used as the in-Rust oracle.
    fn conv_naive(d: &ConvDims, x: &[f32], f: &[f32], bias: &[f32]) -> Vec<f32> {
        let p = d.pad() as isize;
        let mut out = vec![0.0f32; d.y_len()];
        for n in 0..d.n {
            for oy in 0..d.h {
                for ox in 0..d.w {
                    for o in 0..d.co {
                        let mut acc = bias[o];
                        for ky in 0..d.k {
                            for kx in 0..d.k {
                                let iy = oy as isize + ky as isize - p;
                                let ix = ox as isize + kx as isize - p;
                                if iy < 0 || ix < 0 || iy >= d.h as isize || ix >= d.w as isize {
                                    continue;
                                }
                                for c in 0..d.c {
                                    acc += x[xi(d, n, iy as usize, ix as usize, c)]
                                        * f[fi(d, ky, kx, c, o)];
                                }
                            }
                        }
                        out[yi(d, n, oy, ox, o)] = acc;
                    }
                }
            }
        }
        out
    }

    #[test]
    fn conv_fwd_matches_naive() {
        let mut rng = Xoshiro256::new(1);
        let d = ConvDims { n: 2, h: 6, w: 5, c: 3, k: 3, co: 4 };
        let x = rand_vec(&mut rng, d.x_len());
        let f = rand_vec(&mut rng, d.f_len());
        let b = rand_vec(&mut rng, d.co);
        let mut out = vec![0.0; d.y_len()];
        conv2d_same_fwd(&d, &x, &f, &b, &mut out);
        let naive = conv_naive(&d, &x, &f, &b);
        for (a, b) in out.iter().zip(naive.iter()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn packed_b_layout_and_padding() {
        // 2×3 matrix, NR=8: one panel, columns 3..8 zero-padded.
        let b = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let p = PackedB::pack(2, 3, &b);
        assert_eq!(p.kk(), 2);
        assert_eq!(p.n(), 3);
        assert_eq!(p.data.len(), NR * 2);
        assert_eq!(&p.data[..NR], &[1.0, 2.0, 3.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(&p.data[NR..], &[4.0, 5.0, 6.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        // Multi-panel: n=10 → 2 panels; element (l=1, j=9) in panel 1.
        let b2: Vec<f32> = (0..20).map(|v| v as f32).collect();
        let p2 = PackedB::pack(2, 10, &b2);
        assert_eq!(p2.data.len(), 2 * NR * 2);
        assert_eq!(p2.data[NR * 2 + NR + 1], 19.0); // panel 1, l=1, j=1 ↔ b[1][9]
    }

    #[test]
    fn gemm_packed_matches_unpacked_all_edge_shapes() {
        let mut rng = Xoshiro256::new(17);
        // m around MR multiples, n around NR multiples, small/odd kk.
        for (m, kk, n) in [
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (4, 9, 8),
            (5, 9, 9),
            (8, 18, 16),
            (13, 27, 10),
            (2, 4, 23),
        ] {
            let a = rand_vec(&mut rng, m * kk);
            let b = rand_vec(&mut rng, kk * n);
            let mut c_ref = rand_vec(&mut rng, m * n);
            let mut c_packed = c_ref.clone();
            gemm_acc(m, kk, n, &a, &b, &mut c_ref);
            let packed = PackedB::pack(kk, n, &b);
            gemm_packed_acc(m, &a, &packed, &mut c_packed);
            for (x, y) in c_packed.iter().zip(c_ref.iter()) {
                assert!((x - y).abs() < 1e-4, "m={m} kk={kk} n={n}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn gemm_panel_windows_compose_to_full_gemm() {
        let mut rng = Xoshiro256::new(61);
        // Ragged n (panel remainder), m around MR, panel-by-panel windows.
        for (m, kk, n) in [(1usize, 3usize, 5usize), (5, 7, 9), (4, 6, 16), (9, 4, 23)] {
            let a = rand_vec(&mut rng, m * kk);
            let b = rand_vec(&mut rng, kk * n);
            let packed = PackedB::pack(kk, n, &b);
            let mut full = rand_vec(&mut rng, m * n);
            let mut windowed = full.clone();
            gemm_packed_acc(m, &a, &packed, &mut full);
            // Sweep single-panel windows: must be bit-identical to the full
            // call (each panel owns an independent register accumulator).
            for p in 0..packed.panels() {
                gemm_packed_acc_panels(m, &a, &packed, &mut windowed, p, 1);
            }
            assert_eq!(full, windowed, "m={m} kk={kk} n={n}");
            // Window geometry tiles [0, n) exactly.
            let mut covered = 0;
            for p in 0..packed.panels() {
                let (j0, jw) = panel_window(n, p, 1);
                assert_eq!(j0, covered, "m={m} kk={kk} n={n} p={p}");
                covered += jw;
            }
            assert_eq!(covered, n);
        }
    }

    #[test]
    fn gemm_tn_col_windows_compose_to_full_gemm() {
        let mut rng = Xoshiro256::new(67);
        for (m, kk, n) in [(1usize, 4usize, 5usize), (6, 9, 11), (4, 13, 8)] {
            let a = rand_vec(&mut rng, m * kk);
            let b = rand_vec(&mut rng, m * n);
            let mut full = rand_vec(&mut rng, kk * n);
            let mut windowed = full.clone();
            gemm_tn_acc(m, kk, n, &a, &b, &mut full);
            // Uneven windows sweeping [0, n) — bit-identical per element.
            let mut j0 = 0;
            for jw in [1usize, 3, n] {
                if j0 >= n {
                    break;
                }
                let jw = jw.min(n - j0);
                gemm_tn_acc_cols(m, kk, n, &a, &b, &mut windowed, j0, jw);
                j0 += jw;
            }
            while j0 < n {
                gemm_tn_acc_cols(m, kk, n, &a, &b, &mut windowed, j0, 1);
                j0 += 1;
            }
            assert_eq!(full, windowed, "m={m} kk={kk} n={n}");
        }
    }

    #[test]
    fn packed_b_repack_reuses_allocation() {
        let mut p = PackedB::pack(4, 16, &[1.0; 64]);
        let cap = p.data.capacity();
        p.repack(2, 8, &[2.0; 16]);
        assert_eq!(p.kk(), 2);
        assert_eq!(p.n(), 8);
        assert_eq!(p.data.capacity(), cap, "repack to a smaller panel reallocated");
        assert!(p.data.iter().all(|&v| v == 2.0));
    }

    #[test]
    fn gemm_fwd_matches_naive_across_kernels() {
        let mut rng = Xoshiro256::new(7);
        for (k, h, w) in [
            (1usize, 5usize, 4usize),
            (3, 6, 5),
            (5, 7, 7),
            (3, 33, 3),
            // W < k and tiny spatial dims (heavy border padding).
            (5, 7, 3),
            (5, 3, 2),
            (3, 1, 1),
            // Even kernels (asymmetric implicit padding).
            (2, 5, 5),
            (4, 6, 6),
        ] {
            let d = ConvDims { n: 2, h, w, c: 3, k, co: 4 };
            let x = rand_vec(&mut rng, d.x_len());
            let f = rand_vec(&mut rng, d.f_len());
            let b = rand_vec(&mut rng, d.co);
            let mut fast = vec![0.0; d.y_len()];
            let mut naive = vec![0.0; d.y_len()];
            conv2d_same_fwd(&d, &x, &f, &b, &mut fast);
            conv2d_same_fwd_naive(&d, &x, &f, &b, &mut naive);
            for (a, bb) in fast.iter().zip(naive.iter()) {
                assert!((a - bb).abs() < 1e-4, "k={k}: {a} vs {bb}");
            }
        }
    }

    #[test]
    fn gemm_bwd_matches_naive() {
        let mut rng = Xoshiro256::new(8);
        // Even k: bwd-input falls back to the naive loops, bwd-filter rides
        // the same im2col/gemm_tn path as odd k (identical patch indexing).
        for k in [1usize, 2, 3, 4, 5] {
            let d = ConvDims { n: 2, h: 6, w: 5, c: 2, k, co: 3 };
            let x = rand_vec(&mut rng, d.x_len());
            let f = rand_vec(&mut rng, d.f_len());
            let dy = rand_vec(&mut rng, d.y_len());
            let mut dx_fast = vec![0.0; d.x_len()];
            let mut dx_naive = vec![0.0; d.x_len()];
            conv2d_same_bwd_input(&d, &dy, &f, &mut dx_fast);
            conv2d_same_bwd_input_naive(&d, &dy, &f, &mut dx_naive);
            for (a, b) in dx_fast.iter().zip(dx_naive.iter()) {
                assert!((a - b).abs() < 1e-4, "k={k} dx: {a} vs {b}");
            }
            let mut df_fast = vec![0.0; d.f_len()];
            let mut db_fast = vec![0.0; d.co];
            let mut df_naive = vec![0.0; d.f_len()];
            let mut db_naive = vec![0.0; d.co];
            conv2d_same_bwd_filter(&d, &x, &dy, &mut df_fast, &mut db_fast);
            conv2d_same_bwd_filter_naive(&d, &x, &dy, &mut df_naive, &mut db_naive);
            for (a, b) in df_fast.iter().zip(df_naive.iter()) {
                assert!((a - b).abs() < 1e-4, "k={k} df: {a} vs {b}");
            }
            for (a, b) in db_fast.iter().zip(db_naive.iter()) {
                assert!((a - b).abs() < 1e-4, "k={k} db: {a} vs {b}");
            }
        }
    }

    #[test]
    fn even_kernel_falls_back_consistently() {
        // Even k has asymmetric implicit padding; the fast path must defer
        // to the naive loops and all three ops must stay mutually consistent
        // via the adjoint identity ⟨conv(x), dy⟩ = ⟨x, bwd_input(dy)⟩.
        let mut rng = Xoshiro256::new(9);
        let d = ConvDims { n: 1, h: 5, w: 5, c: 2, k: 2, co: 3 };
        let x = rand_vec(&mut rng, d.x_len());
        let f = rand_vec(&mut rng, d.f_len());
        let dy = rand_vec(&mut rng, d.y_len());
        let zero_bias = vec![0.0f32; d.co];
        let mut y = vec![0.0; d.y_len()];
        conv2d_same_fwd(&d, &x, &f, &zero_bias, &mut y);
        let mut dx = vec![0.0; d.x_len()];
        conv2d_same_bwd_input(&d, &dy, &f, &mut dx);
        let lhs: f64 = y.iter().zip(&dy).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        let rhs: f64 = x.iter().zip(&dx).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        assert!((lhs - rhs).abs() < 1e-3 * (1.0 + lhs.abs()), "{lhs} vs {rhs}");
    }

    #[test]
    fn im2col_lowers_patches_exactly() {
        // 1×3×3×1 image, k=3: the centre patch is the whole image; corner
        // patches are zero-padded.
        let d = ConvDims { n: 1, h: 3, w: 3, c: 1, k: 3, co: 1 };
        let x: Vec<f32> = (1..=9).map(|v| v as f32).collect();
        let mut cols = vec![0.0f32; 3 * 3 * 9];
        im2col_rows(&d, &x, 0, 0, 3, &mut cols);
        // Patch at (y=1, x=1) (row-major patch index 4) == the image.
        assert_eq!(&cols[4 * 9..5 * 9], &x[..]);
        // Patch at (0, 0): top row and left column zero-padded.
        assert_eq!(
            &cols[0..9],
            &[0.0, 0.0, 0.0, 0.0, 1.0, 2.0, 0.0, 4.0, 5.0]
        );
    }

    #[test]
    fn conv_rows_gemm_tile_matches_full() {
        let mut rng = Xoshiro256::new(11);
        let d = ConvDims { n: 2, h: 7, w: 4, c: 2, k: 3, co: 3 };
        let x = rand_vec(&mut rng, d.x_len());
        let f = rand_vec(&mut rng, d.f_len());
        let b = rand_vec(&mut rng, d.co);
        let mut full = vec![0.0; d.y_len()];
        conv2d_same_fwd(&d, &x, &f, &b, &mut full);
        let kkc = d.k * d.k * d.c;
        // Rows [2, 5) of image 1 via the packed tile entry point: per-row
        // kernel math is independent of tile grouping, so the tile is
        // bit-identical to the corresponding slice of the full conv.
        let (n, y0, rows) = (1usize, 2usize, 3usize);
        let packed = pack_filter(&d, &f);
        let mut cols = vec![0.0f32; rows * d.w * kkc];
        let mut tile = vec![0.0f32; rows * d.w * d.co];
        conv2d_same_rows_packed(&d, &x, &packed, &b, n, y0, rows, &mut cols, &mut tile);
        let start = (n * d.h + y0) * d.w * d.co;
        assert_eq!(&tile[..], &full[start..start + rows * d.w * d.co]);

        // The legacy unpacked tile path agrees within tolerance.
        let mut cols2 = vec![0.0f32; rows * d.w * kkc];
        let mut tile2 = vec![0.0f32; rows * d.w * d.co];
        conv2d_same_rows_gemm(&d, &x, &f, &b, n, y0, rows, &mut cols2, &mut tile2);
        for (a, bb) in tile2.iter().zip(tile.iter()) {
            assert!((a - bb).abs() < 1e-4);
        }
    }

    #[test]
    fn conv_fwd_identity_1x1() {
        let d = ConvDims { n: 1, h: 3, w: 3, c: 1, k: 1, co: 1 };
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0];
        let f = vec![1.0];
        let b = vec![0.0];
        let mut out = vec![0.0; 9];
        conv2d_same_fwd(&d, &x, &f, &b, &mut out);
        assert_eq!(out, x);
    }

    /// Finite-difference gradient check of conv backward passes.
    #[test]
    fn conv_bwd_finite_difference() {
        let mut rng = Xoshiro256::new(2);
        let d = ConvDims { n: 1, h: 4, w: 4, c: 2, k: 3, co: 2 };
        let x = rand_vec(&mut rng, d.x_len());
        let f = rand_vec(&mut rng, d.f_len());
        let b = rand_vec(&mut rng, d.co);
        // Loss = sum(out²)/2, so dy = out.
        let mut out = vec![0.0; d.y_len()];
        conv2d_same_fwd(&d, &x, &f, &b, &mut out);
        let dy = out.clone();
        let mut dx = vec![0.0; d.x_len()];
        let mut df = vec![0.0; d.f_len()];
        let mut db = vec![0.0; d.co];
        conv2d_same_bwd_input(&d, &dy, &f, &mut dx);
        conv2d_same_bwd_filter(&d, &x, &dy, &mut df, &mut db);

        let loss = |x: &[f32], f: &[f32], b: &[f32]| -> f64 {
            let mut out = vec![0.0; d.y_len()];
            conv2d_same_fwd(&d, x, f, b, &mut out);
            out.iter().map(|&v| (v as f64) * (v as f64) / 2.0).sum()
        };
        let eps = 1e-2f32;
        for idx in [0usize, 5, d.x_len() - 1] {
            let mut xp = x.clone();
            xp[idx] += eps;
            let mut xm = x.clone();
            xm[idx] -= eps;
            let fd = (loss(&xp, &f, &b) - loss(&xm, &f, &b)) / (2.0 * eps as f64);
            assert!((fd - dx[idx] as f64).abs() < 2e-2, "dx[{idx}]: fd={fd} an={}", dx[idx]);
        }
        for idx in [0usize, d.f_len() / 2, d.f_len() - 1] {
            let mut fp = f.clone();
            fp[idx] += eps;
            let mut fm = f.clone();
            fm[idx] -= eps;
            let fd = (loss(&x, &fp, &b) - loss(&x, &fm, &b)) / (2.0 * eps as f64);
            assert!((fd - df[idx] as f64).abs() < 2e-2, "df[{idx}]: fd={fd} an={}", df[idx]);
        }
        for idx in 0..d.co {
            let mut bp = b.clone();
            bp[idx] += eps;
            let mut bm = b.clone();
            bm[idx] -= eps;
            let fd = (loss(&x, &f, &bp) - loss(&x, &f, &bm)) / (2.0 * eps as f64);
            assert!((fd - db[idx] as f64).abs() < 2e-2, "db[{idx}]: fd={fd} an={}", db[idx]);
        }
    }

    #[test]
    fn relu_roundtrip() {
        let mut x = vec![-1.0, 0.0, 2.0];
        relu_fwd(&mut x);
        assert_eq!(x, vec![0.0, 0.0, 2.0]);
        let mut dy = vec![5.0, 5.0, 5.0];
        relu_bwd(&x, &mut dy);
        assert_eq!(dy, vec![0.0, 0.0, 5.0]);
    }

    #[test]
    fn mean_pool_roundtrip() {
        // 1×2×2×1 constant window pools to its value.
        let x = vec![1.0, 3.0, 5.0, 7.0];
        let mut out = vec![0.0; 1];
        mean_pool_fwd(1, 2, 2, 1, 2, &x, &mut out);
        assert_eq!(out, vec![4.0]);
        let mut dx = vec![0.0; 4];
        mean_pool_bwd(1, 2, 2, 1, 2, &[8.0], &mut dx);
        assert_eq!(dx, vec![2.0, 2.0, 2.0, 2.0]);
    }

    #[test]
    fn dense_matches_manual() {
        // (1,2) @ (2,2): [1,2] @ [[1,2],[3,4]] + [10, 20] = [17, 30]
        let x = vec![1.0, 2.0];
        let w = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![10.0, 20.0];
        let mut out = vec![0.0; 2];
        dense_fwd(1, 2, 2, &x, &w, &b, &mut out);
        assert_eq!(out, vec![17.0, 30.0]);
    }

    #[test]
    fn dense_bwd_finite_difference() {
        let mut rng = Xoshiro256::new(3);
        let (m, k, n) = (3, 4, 5);
        let x = rand_vec(&mut rng, m * k);
        let w = rand_vec(&mut rng, k * n);
        let b = rand_vec(&mut rng, n);
        let mut out = vec![0.0; m * n];
        dense_fwd(m, k, n, &x, &w, &b, &mut out);
        let dy = out.clone(); // loss = sum(out²)/2
        let mut dx = vec![0.0; m * k];
        let mut dw = vec![0.0; k * n];
        let mut db = vec![0.0; n];
        dense_bwd(m, k, n, &x, &w, &dy, &mut dx, &mut dw, &mut db);
        let loss = |x: &[f32], w: &[f32], b: &[f32]| -> f64 {
            let mut out = vec![0.0; m * n];
            dense_fwd(m, k, n, x, w, b, &mut out);
            out.iter().map(|&v| (v as f64).powi(2) / 2.0).sum()
        };
        let eps = 1e-2f32;
        for idx in [0, m * k - 1] {
            let mut xp = x.clone();
            xp[idx] += eps;
            let mut xm = x.clone();
            xm[idx] -= eps;
            let fd = (loss(&xp, &w, &b) - loss(&xm, &w, &b)) / (2.0 * eps as f64);
            assert!((fd - dx[idx] as f64).abs() < 2e-2);
        }
        for idx in [0, k * n - 1] {
            let mut wp = w.clone();
            wp[idx] += eps;
            let mut wm = w.clone();
            wm[idx] -= eps;
            let fd = (loss(&x, &wp, &b) - loss(&x, &wm, &b)) / (2.0 * eps as f64);
            assert!((fd - dw[idx] as f64).abs() < 2e-2);
        }
    }

    #[test]
    fn pack_transposed_matches_packing_the_transpose() {
        let mut rng = Xoshiro256::new(19);
        for (rows, cols) in [(1usize, 1usize), (3, 5), (8, 8), (13, 4), (9, 17)] {
            let b = rand_vec(&mut rng, rows * cols);
            // Materialize bᵀ and pack it the ordinary way.
            let mut bt = vec![0.0f32; rows * cols];
            for r in 0..rows {
                for c in 0..cols {
                    bt[c * rows + r] = b[r * cols + c];
                }
            }
            let direct = PackedB::pack(cols, rows, &bt);
            let transposed = PackedB::pack_transposed(rows, cols, &b);
            assert_eq!(transposed.kk(), cols);
            assert_eq!(transposed.n(), rows);
            assert_eq!(direct.data, transposed.data, "rows={rows} cols={cols}");
        }
    }

    #[test]
    fn dense_fwd_packed_matches_naive() {
        let mut rng = Xoshiro256::new(23);
        // Ragged shapes: n not a multiple of NR, k < MR, single-row batches.
        for (m, k, n) in [(1usize, 2usize, 3usize), (4, 3, 8), (5, 7, 9), (3, 16, 10), (8, 1, 1)] {
            let x = rand_vec(&mut rng, m * k);
            let w = rand_vec(&mut rng, k * n);
            let b = rand_vec(&mut rng, n);
            let mut naive = vec![0.0f32; m * n];
            dense_fwd(m, k, n, &x, &w, &b, &mut naive);
            let packed = PackedB::pack(k, n, &w);
            let mut fast = vec![0.0f32; m * n];
            dense_fwd_packed(m, &x, &packed, &b, &mut fast);
            for (a, bb) in fast.iter().zip(naive.iter()) {
                assert!((a - bb).abs() < 1e-4, "m={m} k={k} n={n}: {a} vs {bb}");
            }
        }
    }

    #[test]
    fn dense_bwd_packed_matches_naive() {
        let mut rng = Xoshiro256::new(29);
        for (m, k, n) in [(1usize, 2usize, 3usize), (4, 3, 8), (5, 7, 9), (3, 16, 10)] {
            let x = rand_vec(&mut rng, m * k);
            let w = rand_vec(&mut rng, k * n);
            let dy = rand_vec(&mut rng, m * n);
            let mut dx_n = vec![0.0f32; m * k];
            let mut dw_n = vec![0.0f32; k * n];
            let mut db_n = vec![0.0f32; n];
            dense_bwd(m, k, n, &x, &w, &dy, &mut dx_n, &mut dw_n, &mut db_n);
            let wt = PackedB::pack_transposed(k, n, &w);
            let mut dx_p = vec![0.0f32; m * k];
            let mut dw_p = vec![0.0f32; k * n];
            let mut db_p = vec![0.0f32; n];
            dense_bwd_packed(m, k, n, &x, &wt, &dy, &mut dx_p, &mut dw_p, &mut db_p);
            for (a, b) in dx_p.iter().zip(dx_n.iter()) {
                assert!((a - b).abs() < 1e-4, "dx m={m} k={k} n={n}: {a} vs {b}");
            }
            for (a, b) in dw_p.iter().zip(dw_n.iter()) {
                assert!((a - b).abs() < 1e-4, "dw m={m} k={k} n={n}: {a} vs {b}");
            }
            for (a, b) in db_p.iter().zip(db_n.iter()) {
                assert!((a - b).abs() < 1e-4, "db m={m} k={k} n={n}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn loss_into_matches_allocating_wrapper() {
        let mut rng = Xoshiro256::new(31);
        let (m, n) = (3, 5);
        let logits = rand_vec(&mut rng, m * n);
        let mut y = vec![0.0f32; m * n];
        y[2] = 1.0;
        y[n] = 1.0;
        y[2 * n + 4] = 1.0;
        let mut dl_a = vec![0.0f32; m * n];
        let mut dl_b = vec![0.0f32; m * n];
        let mut probs = vec![0.0f32; m * n];
        let (la, ca) = mse_softmax_loss(m, n, &logits, &y, &mut dl_a);
        let (lb, cb) = mse_softmax_loss_into(m, n, &logits, &y, &mut dl_b, &mut probs);
        assert_eq!(la, lb);
        assert_eq!(ca, cb);
        assert_eq!(dl_a, dl_b);
    }

    #[test]
    fn bwd_input_packed_matches_wrapper() {
        let mut rng = Xoshiro256::new(37);
        let d = ConvDims { n: 2, h: 5, w: 6, c: 3, k: 3, co: 4 };
        let f = rand_vec(&mut rng, d.f_len());
        let dy = rand_vec(&mut rng, d.y_len());
        let mut dx_a = vec![0.0f32; d.x_len()];
        conv2d_same_bwd_input(&d, &dy, &f, &mut dx_a);
        let dd = ConvDims { c: d.co, co: d.c, ..d };
        let packed = pack_filter(&dd, &flip_transpose_filter(&d, &f));
        let mut dx_b = vec![0.0f32; d.x_len()];
        let mut cols = Vec::new();
        conv2d_same_bwd_input_packed(&d, &dy, &packed, &mut dx_b, &mut cols);
        assert_eq!(dx_a, dx_b);
    }

    #[test]
    fn softmax_rows_normalized() {
        let mut x = vec![1.0, 2.0, 3.0, 1000.0, 1000.0, 1000.0];
        softmax_rows(2, 3, &mut x);
        assert!((x[0..3].iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!((x[3..6].iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(x[2] > x[1] && x[1] > x[0]);
        // Overflow-safe on large values.
        assert!((x[3] - 1.0 / 3.0).abs() < 1e-5);
    }

    #[test]
    fn mse_softmax_loss_gradient_finite_difference() {
        let mut rng = Xoshiro256::new(4);
        let (m, n) = (2, 4);
        let logits = rand_vec(&mut rng, m * n);
        let mut y = vec![0.0f32; m * n];
        y[1] = 1.0;
        y[n + 2] = 1.0;
        let mut dl = vec![0.0; m * n];
        let (loss0, _) = mse_softmax_loss(m, n, &logits, &y, &mut dl);
        assert!(loss0 > 0.0);
        let eps = 1e-3f32;
        for idx in 0..m * n {
            let mut lp = logits.clone();
            lp[idx] += eps;
            let mut lm = logits.clone();
            lm[idx] -= eps;
            let mut scratch = vec![0.0; m * n];
            let (lp_loss, _) = mse_softmax_loss(m, n, &lp, &y, &mut scratch);
            let (lm_loss, _) = mse_softmax_loss(m, n, &lm, &y, &mut scratch);
            let fd = (lp_loss - lm_loss) / (2.0 * eps);
            assert!(
                (fd - dl[idx]).abs() < 1e-3,
                "dlogits[{idx}]: fd={fd} an={}",
                dl[idx]
            );
        }
    }

    #[test]
    fn perfect_prediction_counts_correct() {
        let logits = vec![10.0, -10.0, -10.0, 10.0]; // 2 samples, 2 classes
        let y = vec![1.0, 0.0, 0.0, 1.0];
        let mut dl = vec![0.0; 4];
        let (loss, correct) = mse_softmax_loss(2, 2, &logits, &y, &mut dl);
        assert_eq!(correct, 2);
        assert!(loss < 1e-6);
    }
}
