//! Native (pure-Rust) CNN implementation — the paper's per-node subnetwork.
//!
//! `ops` holds the dense primitives (conv/pool/dense forward+backward, the
//! Eq. 16 loss); `network` assembles them into the full model matching the
//! L2 JAX definition. The inner-layer parallel scheduler (`crate::inner`)
//! decomposes these same computations into DAG tasks per §4.1/§4.2.

pub mod network;
pub mod ops;
pub mod workspace;

pub use network::Network;
pub use ops::ConvDims;
pub use workspace::{StepWorkspace, WeightPacks};
