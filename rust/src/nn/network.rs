//! The native CNN: a pure-Rust implementation of the exact network the L2
//! JAX model defines (same layer stack, same weight-set layout, same loss).
//!
//! Roles:
//! * the artifact-free [`crate::runtime::NativeBackend`] used by most tests
//!   and the simulator calibration;
//! * the task source for the inner-layer parallel scheduler (`inner/`),
//!   which re-executes the conv/backprop loops as DAG tasks (§4.1/4.2).
//!
//! The whole step — conv *and* dense layers — runs on the packed-B 4×8
//! micro-kernel, with every intermediate buffer living in a caller-owned
//! [`StepWorkspace`] and the weight panels cached in [`WeightPacks`]
//! (repacked in place once per weight mutation). A warmed-up
//! [`Network::train_batch_ws`] performs zero heap allocations — pinned by
//! the `alloc_regression` integration test.

use std::cell::RefCell;

use crate::config::NetworkConfig;
use crate::inner::AutoTuner;
use crate::tensor::{Tensor, WeightSet};
use crate::util::rng::Xoshiro256;

use super::ops;
use super::workspace::{StepWorkspace, WeightPacks};
use super::ConvDims;

/// A CNN (sub)network with its local weight set (paper Definition 1).
pub struct Network {
    pub cfg: NetworkConfig,
    pub weights: WeightSet,
    /// Packed-GEMM panels derived from `weights`; rebuilt lazily (in place)
    /// whenever the weight generation changes — once per SGD step, once per
    /// AGWU fetch, never across eval batches on frozen weights.
    pub(crate) packs: RefCell<WeightPacks>,
    /// Per-stage tile autotuner driving `TilePolicy::Auto` steps. Lives
    /// with the pack cache on the node: epoch trainers move it across
    /// their per-epoch networks ([`Network::take_tuner`]) so calibration
    /// and locked plans survive as long as the node does.
    pub(crate) tuner: RefCell<AutoTuner>,
}

impl Clone for Network {
    fn clone(&self) -> Self {
        // The pack cache is value-derived; clones start cold and repack on
        // first use. Tuner state is measurement-derived; clones re-tune.
        Self {
            cfg: self.cfg.clone(),
            weights: self.weights.clone(),
            packs: RefCell::new(WeightPacks::default()),
            tuner: RefCell::new(AutoTuner::default()),
        }
    }
}

impl std::fmt::Debug for Network {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Network")
            .field("cfg", &self.cfg)
            .field("weights", &self.weights)
            .finish_non_exhaustive()
    }
}

impl Network {
    /// He-initialised network; biases zero (parity with the L2 model's
    /// `init_params`, though RNG streams differ).
    pub fn init(cfg: &NetworkConfig, seed: u64) -> Self {
        let mut rng = Xoshiro256::new(seed);
        let tensors = cfg
            .param_shapes()
            .into_iter()
            .map(|(name, shape)| {
                if name.ends_with(".bias") {
                    Tensor::zeros(&shape)
                } else {
                    let fan_in: usize = shape[..shape.len() - 1].iter().product();
                    let std = (2.0 / fan_in as f64).sqrt() as f32;
                    Tensor::randn(&shape, &mut rng, 0.0, std)
                }
            })
            .collect();
        Self::with_weights(cfg, WeightSet::new(tensors))
    }

    /// Wrap an existing weight set (e.g. fetched from the parameter server
    /// or produced by the XLA `init` artifact).
    pub fn with_weights(cfg: &NetworkConfig, weights: WeightSet) -> Self {
        Self::with_weights_and_packs(cfg, weights, WeightPacks::default())
    }

    /// Wrap an existing weight set *and* install a previously-populated
    /// pack cache. This is how epoch trainers share one generation-keyed
    /// cache across the fresh per-epoch `Network`s they spawn
    /// ([`Network::take_packs`] recovers it): `WeightPacks::ensure` is keyed
    /// on [`WeightSet::generation`], so packs built for an identical weight
    /// set (same generation — e.g. an eval on frozen weights, or a fetch
    /// the server did not advance) are reused without repacking, and stale
    /// ones repack **in place** into the cache's existing allocations
    /// instead of reallocating every panel from scratch.
    pub fn with_weights_and_packs(
        cfg: &NetworkConfig,
        weights: WeightSet,
        packs: WeightPacks,
    ) -> Self {
        Self::with_node_state(cfg, weights, packs, AutoTuner::default())
    }

    /// [`Network::with_weights_and_packs`] plus a previously-accumulated
    /// stage autotuner — the full node-state carry: epoch trainers move
    /// both the pack cache and the tuner into each fresh per-epoch network
    /// so packs for unchanged weight generations are never rebuilt *and*
    /// calibrated/locked tile plans are never re-explored.
    pub fn with_node_state(
        cfg: &NetworkConfig,
        weights: WeightSet,
        packs: WeightPacks,
        tuner: AutoTuner,
    ) -> Self {
        assert_eq!(
            weights.len(),
            cfg.param_shapes().len(),
            "weight set arity does not match config"
        );
        Self {
            cfg: cfg.clone(),
            weights,
            packs: RefCell::new(packs),
            tuner: RefCell::new(tuner),
        }
    }

    /// Move the pack cache out of this network (the trainer-side half of
    /// the cross-epoch carry); the network is left with a cold cache.
    pub fn take_packs(&mut self) -> WeightPacks {
        self.packs.replace(WeightPacks::default())
    }

    /// Move the stage autotuner out of this network (the trainer-side half
    /// of the cross-epoch carry); the network is left with a fresh tuner.
    pub fn take_tuner(&mut self) -> AutoTuner {
        self.tuner.replace(AutoTuner::default())
    }

    /// Render the autotuner's per-stage tuning table (calibration, plan,
    /// lock state, best makespan per stage) for debugging / CI logs.
    pub fn tuning_report(&self) -> String {
        self.tuner.borrow().table()
    }

    pub(crate) fn conv_dims(&self, layer: usize, batch: usize) -> ConvDims {
        let c = if layer == 0 { self.cfg.in_channels } else { self.cfg.filters };
        ConvDims {
            n: batch,
            h: self.cfg.input_hw,
            w: self.cfg.input_hw,
            c,
            k: self.cfg.kernel_hw,
            co: self.cfg.filters,
        }
    }

    /// Forward pass into the workspace (activations cached for backward).
    /// Allocation-free once `ws` is warmed for `(cfg, batch)` and the weight
    /// packs are sized.
    pub fn forward_ws(&self, x: &[f32], batch: usize, ws: &mut StepWorkspace) {
        let cfg = &self.cfg;
        let hw = cfg.input_hw;
        assert_eq!(x.len(), batch * hw * hw * cfg.in_channels, "bad input length");
        ws.prepare(cfg, batch, &self.weights);
        self.packs.borrow_mut().ensure(cfg, &self.weights);
        let packs = self.packs.borrow();
        let wts = self.weights.tensors();

        // Conv stack on the packed micro-kernel.
        for l in 0..cfg.conv_layers {
            let d = self.conv_dims(l, batch);
            let (prev, cur) = ws.conv_outs.split_at_mut(l);
            let input: &[f32] = if l == 0 { x } else { &prev[l - 1] };
            let out = &mut cur[0][..];
            ops::conv2d_same_fwd_packed(
                &d,
                input,
                &packs.conv[l],
                wts[2 * l + 1].data(),
                &mut ws.cols,
                out,
            );
            ops::relu_fwd(out);
        }

        // Pool.
        let c = if cfg.conv_layers == 0 { cfg.in_channels } else { cfg.filters };
        let cur: &[f32] = if cfg.conv_layers == 0 {
            x
        } else {
            &ws.conv_outs[cfg.conv_layers - 1]
        };
        ops::mean_pool_fwd(batch, hw, hw, c, cfg.pool_window, cur, &mut ws.pooled);

        // FC stack on the same micro-kernel (cached per-layer packs).
        for l in 0..cfg.fc_layers {
            let (prev, cur) = ws.fc_outs.split_at_mut(l);
            let feat: &[f32] = if l == 0 { &ws.pooled } else { &prev[l - 1] };
            let out = &mut cur[0][..];
            let b = wts[2 * cfg.conv_layers + 2 * l + 1].data();
            ops::dense_fwd_packed(batch, feat, &packs.fc_w[l], b, out);
            ops::relu_fwd(out);
        }
        let last: &[f32] = if cfg.fc_layers == 0 {
            &ws.pooled
        } else {
            &ws.fc_outs[cfg.fc_layers - 1]
        };
        let ob = wts[2 * cfg.conv_layers + 2 * cfg.fc_layers + 1].data();
        ops::dense_fwd_packed(batch, last, &packs.fc_w[cfg.fc_layers], ob, &mut ws.logits);
    }

    /// Backward pass from one-hot labels, reading the activations the last
    /// [`Network::forward_ws`] left in `ws` and writing the gradients into
    /// `ws.grads()`. Returns (loss, correct).
    pub fn backward_ws(&self, x: &[f32], y: &[f32], ws: &mut StepWorkspace) -> (f32, usize) {
        let cfg = &self.cfg;
        let batch = ws.batch;
        let packs = self.packs.borrow();
        let wts = self.weights.tensors();
        let nc = cfg.num_classes;

        // Loss layer (Eq. 16 + softmax Jacobian).
        let (loss, correct) =
            ops::mse_softmax_loss_into(batch, nc, &ws.logits, y, &mut ws.dlogits, &mut ws.probs);

        let hw = cfg.input_hw;
        let win = cfg.pool_window;
        let c = if cfg.conv_layers == 0 { cfg.in_channels } else { cfg.filters };
        let hp = hw / win;
        let pooled_dim = hp * hp * c;
        let out_w_idx = 2 * cfg.conv_layers + 2 * cfg.fc_layers;
        let grads = ws.grads.as_mut().expect("workspace prepared by forward_ws");
        let gts = grads.tensors_mut();

        // Output layer (Eqs. 17–23 for dense layers), packed transpose GEMM.
        let last_feat: &[f32] = if cfg.fc_layers > 0 {
            &ws.fc_outs[cfg.fc_layers - 1]
        } else {
            &ws.pooled
        };
        let last_dim = if cfg.fc_layers > 0 { cfg.fc_neurons } else { pooled_dim };
        {
            let (a, b) = gts.split_at_mut(out_w_idx + 1);
            ops::dense_bwd_packed(
                batch,
                last_dim,
                nc,
                last_feat,
                &packs.fc_wt[cfg.fc_layers],
                &ws.dlogits,
                &mut ws.dfeat[..batch * last_dim],
                a[out_w_idx].data_mut(),
                b[0].data_mut(),
            );
        }

        // Hidden FC layers, last to first (ping-pong delta buffers).
        for l in (0..cfg.fc_layers).rev() {
            ops::relu_bwd(&ws.fc_outs[l], &mut ws.dfeat[..batch * cfg.fc_neurons]);
            let in_feat: &[f32] = if l == 0 { &ws.pooled } else { &ws.fc_outs[l - 1] };
            let in_dim = if l == 0 { pooled_dim } else { cfg.fc_neurons };
            let w_idx = 2 * cfg.conv_layers + 2 * l;
            {
                let (a, b) = gts.split_at_mut(w_idx + 1);
                ops::dense_bwd_packed(
                    batch,
                    in_dim,
                    cfg.fc_neurons,
                    in_feat,
                    &packs.fc_wt[l],
                    &ws.dfeat[..batch * cfg.fc_neurons],
                    &mut ws.dfeat2[..batch * in_dim],
                    a[w_idx].data_mut(),
                    b[0].data_mut(),
                );
            }
            std::mem::swap(&mut ws.dfeat, &mut ws.dfeat2);
        }

        // Pool backward.
        ops::mean_pool_bwd(batch, hw, hw, c, win, &ws.dfeat[..batch * pooled_dim], &mut ws.dconv);

        // Conv stack backward, last to first (Eqs. 18, 21, 22).
        for l in (0..cfg.conv_layers).rev() {
            ops::relu_bwd(&ws.conv_outs[l], &mut ws.dconv);
            let d = self.conv_dims(l, batch);
            let in_act: &[f32] = if l == 0 { x } else { &ws.conv_outs[l - 1] };
            let w_idx = 2 * l;
            {
                let (a, b) = gts.split_at_mut(w_idx + 1);
                ops::conv2d_same_bwd_filter_ws(
                    &d,
                    in_act,
                    &ws.dconv,
                    a[w_idx].data_mut(),
                    b[0].data_mut(),
                    &mut ws.cols,
                );
            }
            if l > 0 {
                if d.k % 2 == 1 {
                    ops::conv2d_same_bwd_input_packed(
                        &d,
                        &ws.dconv,
                        &packs.conv_flip[l],
                        &mut ws.dconv2[..d.x_len()],
                        &mut ws.cols,
                    );
                } else {
                    ops::conv2d_same_bwd_input_naive(
                        &d,
                        &ws.dconv,
                        wts[w_idx].data(),
                        &mut ws.dconv2[..d.x_len()],
                    );
                }
                std::mem::swap(&mut ws.dconv, &mut ws.dconv2);
            }
        }

        (loss, correct)
    }

    /// One SGD step on one batch (Eq. 23) through a caller-owned workspace:
    /// allocation-free once warmed. Returns (loss, correct).
    pub fn train_batch_ws(
        &mut self,
        x: &[f32],
        y: &[f32],
        batch: usize,
        lr: f32,
        ws: &mut StepWorkspace,
    ) -> (f32, usize) {
        self.forward_ws(x, batch, ws);
        let (loss, correct) = self.backward_ws(x, y, ws);
        self.weights.axpy(-lr, ws.grads());
        (loss, correct)
    }

    /// Evaluate one batch without updating weights (workspace form).
    pub fn eval_batch_ws(
        &self,
        x: &[f32],
        y: &[f32],
        batch: usize,
        ws: &mut StepWorkspace,
    ) -> (f32, usize) {
        self.forward_ws(x, batch, ws);
        ops::mse_softmax_loss_into(
            batch,
            self.cfg.num_classes,
            &ws.logits,
            y,
            &mut ws.dlogits,
            &mut ws.probs,
        )
    }

    /// Convenience wrapper over [`Network::train_batch_ws`] with a
    /// throwaway workspace. Hot loops (epoch trainers, benches) should own
    /// a [`StepWorkspace`] instead.
    pub fn train_batch(&mut self, x: &[f32], y: &[f32], batch: usize, lr: f32) -> (f32, usize) {
        let mut ws = StepWorkspace::new();
        self.train_batch_ws(x, y, batch, lr, &mut ws)
    }

    /// Convenience wrapper over [`Network::eval_batch_ws`].
    pub fn eval_batch(&self, x: &[f32], y: &[f32], batch: usize) -> (f32, usize) {
        let mut ws = StepWorkspace::new();
        self.eval_batch_ws(x, y, batch, &mut ws)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::util::rng::Xoshiro256;

    fn tiny_cfg() -> NetworkConfig {
        NetworkConfig {
            name: "tiny".into(),
            input_hw: 6,
            in_channels: 1,
            conv_layers: 1,
            filters: 2,
            kernel_hw: 3,
            fc_layers: 1,
            fc_neurons: 8,
            num_classes: 3,
            batch_size: 4,
            pool_window: 2,
        }
    }

    #[test]
    fn init_matches_manifest() {
        let cfg = NetworkConfig::quickstart();
        let net = Network::init(&cfg, 0);
        assert_eq!(net.weights.len(), cfg.param_shapes().len());
        assert_eq!(net.weights.param_count(), cfg.param_count());
        for (t, (name, shape)) in net.weights.tensors().iter().zip(cfg.param_shapes()) {
            assert_eq!(t.shape(), &shape[..], "{name}");
            if name.ends_with(".bias") {
                assert_eq!(t.max_abs(), 0.0, "{name} should start at zero");
            }
        }
    }

    #[test]
    fn forward_shapes() {
        let cfg = tiny_cfg();
        let net = Network::init(&cfg, 1);
        let x = vec![0.5f32; 4 * 6 * 6];
        let mut ws = StepWorkspace::new();
        net.forward_ws(&x, 4, &mut ws);
        assert_eq!(ws.logits().len(), 4 * 3);
        assert_eq!(ws.conv_outs.len(), 1);
        assert_eq!(ws.conv_outs[0].len(), 4 * 6 * 6 * 2);
        assert_eq!(ws.pooled.len(), 4 * 3 * 3 * 2);
    }

    #[test]
    fn gradients_match_finite_differences() {
        let cfg = tiny_cfg();
        let net = Network::init(&cfg, 2);
        let mut rng = Xoshiro256::new(3);
        let x: Vec<f32> = (0..2 * 36).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let mut y = vec![0.0f32; 2 * 3];
        y[0] = 1.0;
        y[3 + 2] = 1.0;
        let mut ws = StepWorkspace::new();
        net.forward_ws(&x, 2, &mut ws);
        let _ = net.backward_ws(&x, &y, &mut ws);
        let grads = ws.grads().clone();

        let loss_at = |net: &Network| -> f64 {
            let (l, _) = net.eval_batch(&x, &y, 2);
            l as f64
        };
        let eps = 1e-2f32;
        // Probe a few coordinates in each parameter tensor.
        for ti in 0..net.weights.len() {
            let len = net.weights.tensors()[ti].len();
            for &idx in [0usize, len / 2, len - 1].iter() {
                let mut np = net.clone();
                np.weights.tensors_mut()[ti].data_mut()[idx] += eps;
                let mut nm = net.clone();
                nm.weights.tensors_mut()[ti].data_mut()[idx] -= eps;
                let fd = (loss_at(&np) - loss_at(&nm)) / (2.0 * eps as f64);
                let an = grads.tensors()[ti].data()[idx] as f64;
                assert!(
                    (fd - an).abs() < 5e-3,
                    "tensor {ti} idx {idx}: fd={fd:.6} analytic={an:.6}"
                );
            }
        }
    }

    #[test]
    fn overfits_fixed_batch() {
        let cfg = tiny_cfg();
        let mut net = Network::init(&cfg, 4);
        let ds = Dataset::synthetic(
            &NetworkConfig { num_classes: 3, ..tiny_cfg() },
            12,
            0.05,
            5,
        );
        let (x, y, _) = ds.batch(0, 4);
        let mut ws = StepWorkspace::new();
        let (first, _) = net.eval_batch_ws(&x, &y, 4, &mut ws);
        let mut last = first;
        for _ in 0..60 {
            let (l, _) = net.train_batch_ws(&x, &y, 4, 0.5, &mut ws);
            last = l;
        }
        assert!(last < 0.5 * first, "no learning: first={first} last={last}");
    }

    #[test]
    fn learns_synthetic_task_better_than_chance() {
        let cfg = NetworkConfig {
            name: "learn".into(),
            input_hw: 8,
            in_channels: 1,
            conv_layers: 1,
            filters: 4,
            kernel_hw: 3,
            fc_layers: 1,
            fc_neurons: 16,
            num_classes: 4,
            batch_size: 8,
            pool_window: 2,
        };
        let ds = Dataset::synthetic(&cfg, 256, 0.3, 6);
        let mut net = Network::init(&cfg, 7);
        let mut ws = StepWorkspace::new();
        for epoch in 0..6 {
            let _ = epoch;
            for start in (0..256).step_by(8) {
                let (x, y, _) = ds.batch(start, 8);
                net.train_batch_ws(&x, &y, 8, 0.2, &mut ws);
            }
        }
        let mut correct = 0;
        for start in (0..256).step_by(8) {
            let (x, y, _) = ds.batch(start, 8);
            let (_, c) = net.eval_batch_ws(&x, &y, 8, &mut ws);
            correct += c;
        }
        let acc = correct as f64 / 256.0;
        assert!(acc > 0.6, "accuracy {acc} not better than chance (0.25)");
    }

    #[test]
    fn eval_does_not_change_weights() {
        let cfg = tiny_cfg();
        let net = Network::init(&cfg, 8);
        let before = net.weights.clone();
        let x = vec![0.1f32; 2 * 36];
        let y = vec![0.0f32; 6];
        let _ = net.eval_batch(&x, &y, 2);
        assert_eq!(net.weights.max_abs_diff(&before), 0.0);
    }

    #[test]
    fn with_weights_validates_arity() {
        let cfg = tiny_cfg();
        let net = Network::init(&cfg, 9);
        let w = net.weights.clone();
        let net2 = Network::with_weights(&cfg, w);
        assert_eq!(net2.weights.param_count(), cfg.param_count());
    }

    /// The workspace path and the throwaway-workspace wrapper agree — and a
    /// workspace reused across differently-shaped calls re-keys correctly.
    #[test]
    fn workspace_reuse_matches_fresh() {
        let cfg = tiny_cfg();
        let ds = Dataset::synthetic(&cfg, 16, 0.1, 10);
        let (x, y, _) = ds.batch(0, 4);
        let mut a = Network::init(&cfg, 11);
        let mut b = a.clone();
        let mut ws = StepWorkspace::new();
        // Warm the workspace on a different batch size first (re-key path).
        let (x2, y2, _) = ds.batch(4, 2);
        let _ = a.eval_batch_ws(&x2, &y2, 2, &mut ws);
        for _ in 0..5 {
            let (la, ca) = a.train_batch_ws(&x, &y, 4, 0.2, &mut ws);
            let (lb, cb) = b.train_batch(&x, &y, 4, 0.2);
            assert_eq!(la, lb);
            assert_eq!(ca, cb);
        }
        assert_eq!(a.weights.max_abs_diff(&b.weights), 0.0);
    }
}
