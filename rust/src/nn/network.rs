//! The native CNN: a pure-Rust implementation of the exact network the L2
//! JAX model defines (same layer stack, same weight-set layout, same loss).
//!
//! Roles:
//! * the artifact-free [`crate::runtime::NativeBackend`] used by most tests
//!   and the simulator calibration;
//! * the task source for the inner-layer parallel scheduler (`inner/`),
//!   which re-executes the conv/backprop loops as DAG tasks (§4.1/4.2).

use crate::config::NetworkConfig;
use crate::tensor::{Tensor, WeightSet};
use crate::util::rng::Xoshiro256;

use super::ops::{self, ConvDims};

/// Cached per-layer activations from one forward pass (needed by backward).
#[derive(Debug, Clone)]
pub struct Activations {
    /// Input batch (NHWC flattened).
    pub input: Vec<f32>,
    /// Post-ReLU output of each conv layer.
    pub conv_outs: Vec<Vec<f32>>,
    /// Output of the pooling layer (flattened features).
    pub pooled: Vec<f32>,
    /// Post-ReLU output of each hidden FC layer.
    pub fc_outs: Vec<Vec<f32>>,
    /// Final logits.
    pub logits: Vec<f32>,
    pub batch: usize,
}

/// A CNN (sub)network with its local weight set (paper Definition 1).
#[derive(Debug, Clone)]
pub struct Network {
    pub cfg: NetworkConfig,
    pub weights: WeightSet,
}

impl Network {
    /// He-initialised network; biases zero (parity with the L2 model's
    /// `init_params`, though RNG streams differ).
    pub fn init(cfg: &NetworkConfig, seed: u64) -> Self {
        let mut rng = Xoshiro256::new(seed);
        let tensors = cfg
            .param_shapes()
            .into_iter()
            .map(|(name, shape)| {
                if name.ends_with(".bias") {
                    Tensor::zeros(&shape)
                } else {
                    let fan_in: usize = shape[..shape.len() - 1].iter().product();
                    let std = (2.0 / fan_in as f64).sqrt() as f32;
                    Tensor::randn(&shape, &mut rng, 0.0, std)
                }
            })
            .collect();
        Self { cfg: cfg.clone(), weights: WeightSet::new(tensors) }
    }

    /// Wrap an existing weight set (e.g. fetched from the parameter server
    /// or produced by the XLA `init` artifact).
    pub fn with_weights(cfg: &NetworkConfig, weights: WeightSet) -> Self {
        assert_eq!(
            weights.len(),
            cfg.param_shapes().len(),
            "weight set arity does not match config"
        );
        Self { cfg: cfg.clone(), weights }
    }

    fn conv_dims(&self, layer: usize, batch: usize) -> ConvDims {
        let c = if layer == 0 { self.cfg.in_channels } else { self.cfg.filters };
        ConvDims {
            n: batch,
            h: self.cfg.input_hw,
            w: self.cfg.input_hw,
            c,
            k: self.cfg.kernel_hw,
            co: self.cfg.filters,
        }
    }

    /// Forward pass, caching activations for backward.
    pub fn forward(&self, x: &[f32], batch: usize) -> Activations {
        let cfg = &self.cfg;
        let hw = cfg.input_hw;
        assert_eq!(x.len(), batch * hw * hw * cfg.in_channels, "bad input length");
        let ws = self.weights.tensors();
        let mut cur = x.to_vec();
        let mut conv_outs = Vec::with_capacity(cfg.conv_layers);
        let mut pi = 0;
        for layer in 0..cfg.conv_layers {
            let d = self.conv_dims(layer, batch);
            let mut out = vec![0.0f32; d.y_len()];
            ops::conv2d_same_fwd(&d, &cur, ws[pi].data(), ws[pi + 1].data(), &mut out);
            pi += 2;
            ops::relu_fwd(&mut out);
            conv_outs.push(out.clone());
            cur = out;
        }
        // Pool.
        let win = cfg.pool_window;
        let c = if cfg.conv_layers == 0 { cfg.in_channels } else { cfg.filters };
        let hp = hw / win;
        let mut pooled = vec![0.0f32; batch * hp * hp * c];
        ops::mean_pool_fwd(batch, hw, hw, c, win, &cur, &mut pooled);
        // FC stack.
        let mut feat = pooled.clone();
        let mut fan_in = hp * hp * c;
        let mut fc_outs = Vec::with_capacity(cfg.fc_layers);
        for _ in 0..cfg.fc_layers {
            let w = &ws[pi];
            let b = &ws[pi + 1];
            pi += 2;
            let out_dim = w.shape()[1];
            let mut out = vec![0.0f32; batch * out_dim];
            ops::dense_fwd(batch, fan_in, out_dim, &feat, w.data(), b.data(), &mut out);
            ops::relu_fwd(&mut out);
            fc_outs.push(out.clone());
            feat = out;
            fan_in = out_dim;
        }
        let w = &ws[pi];
        let b = &ws[pi + 1];
        let mut logits = vec![0.0f32; batch * cfg.num_classes];
        ops::dense_fwd(batch, fan_in, cfg.num_classes, &feat, w.data(), b.data(), &mut logits);
        Activations {
            input: x.to_vec(),
            conv_outs,
            pooled,
            fc_outs,
            logits,
            batch,
        }
    }

    /// Backward pass from one-hot labels: returns (loss, correct, gradients).
    pub fn backward(&self, acts: &Activations, y: &[f32]) -> (f32, usize, WeightSet) {
        let cfg = &self.cfg;
        let batch = acts.batch;
        let ws = self.weights.tensors();
        let mut grads = self.weights.zeros_like();

        // Loss layer (Eq. 16 + softmax Jacobian).
        let mut dlogits = vec![0.0f32; batch * cfg.num_classes];
        let (loss, correct) =
            ops::mse_softmax_loss(batch, cfg.num_classes, &acts.logits, y, &mut dlogits);

        // FC stack backward (Eqs. 17–23 for dense layers).
        let hw = cfg.input_hw;
        let win = cfg.pool_window;
        let c = if cfg.conv_layers == 0 { cfg.in_channels } else { cfg.filters };
        let hp = hw / win;
        let pooled_dim = hp * hp * c;

        let out_w_idx = 2 * cfg.conv_layers + 2 * cfg.fc_layers;
        let gts = grads.tensors_mut();

        // Output layer.
        let last_feat: &[f32] = if cfg.fc_layers > 0 {
            &acts.fc_outs[cfg.fc_layers - 1]
        } else {
            &acts.pooled
        };
        let last_dim = if cfg.fc_layers > 0 { cfg.fc_neurons } else { pooled_dim };
        let mut dfeat = vec![0.0f32; batch * last_dim];
        {
            let (dw, db_slice) = {
                let (a, b) = gts.split_at_mut(out_w_idx + 1);
                (&mut a[out_w_idx], &mut b[0])
            };
            ops::dense_bwd(
                batch,
                last_dim,
                cfg.num_classes,
                last_feat,
                ws[out_w_idx].data(),
                &dlogits,
                &mut dfeat,
                dw.data_mut(),
                db_slice.data_mut(),
            );
        }

        // Hidden FC layers, last to first.
        for l in (0..cfg.fc_layers).rev() {
            // ReLU backward through this layer's output.
            ops::relu_bwd(&acts.fc_outs[l], &mut dfeat);
            let in_feat: &[f32] = if l == 0 { &acts.pooled } else { &acts.fc_outs[l - 1] };
            let in_dim = if l == 0 { pooled_dim } else { cfg.fc_neurons };
            let w_idx = 2 * cfg.conv_layers + 2 * l;
            let mut dprev = vec![0.0f32; batch * in_dim];
            let (dw, db_slice) = {
                let (a, b) = gts.split_at_mut(w_idx + 1);
                (&mut a[w_idx], &mut b[0])
            };
            ops::dense_bwd(
                batch,
                in_dim,
                cfg.fc_neurons,
                in_feat,
                ws[w_idx].data(),
                &dfeat,
                &mut dprev,
                dw.data_mut(),
                db_slice.data_mut(),
            );
            dfeat = dprev;
        }

        // Pool backward.
        let mut dconv = vec![0.0f32; batch * hw * hw * c];
        ops::mean_pool_bwd(batch, hw, hw, c, win, &dfeat, &mut dconv);

        // Conv stack backward, last to first (Eqs. 18, 21, 22).
        for l in (0..cfg.conv_layers).rev() {
            ops::relu_bwd(&acts.conv_outs[l], &mut dconv);
            let d = self.conv_dims(l, batch);
            let in_act: &[f32] = if l == 0 { &acts.input } else { &acts.conv_outs[l - 1] };
            let w_idx = 2 * l;
            {
                let (dw, db_slice) = {
                    let (a, b) = gts.split_at_mut(w_idx + 1);
                    (&mut a[w_idx], &mut b[0])
                };
                ops::conv2d_same_bwd_filter(
                    &d,
                    in_act,
                    &dconv,
                    dw.data_mut(),
                    db_slice.data_mut(),
                );
            }
            if l > 0 {
                let mut dprev = vec![0.0f32; d.x_len()];
                ops::conv2d_same_bwd_input(&d, &dconv, ws[w_idx].data(), &mut dprev);
                dconv = dprev;
            }
        }

        (loss, correct, grads)
    }

    /// One SGD step on one batch (Eq. 23): returns (loss, correct).
    pub fn train_batch(&mut self, x: &[f32], y: &[f32], batch: usize, lr: f32) -> (f32, usize) {
        let acts = self.forward(x, batch);
        let (loss, correct, grads) = self.backward(&acts, y);
        self.weights.axpy(-lr, &grads);
        (loss, correct)
    }

    /// Evaluate one batch without updating weights.
    pub fn eval_batch(&self, x: &[f32], y: &[f32], batch: usize) -> (f32, usize) {
        let acts = self.forward(x, batch);
        let mut scratch = vec![0.0f32; batch * self.cfg.num_classes];
        ops::mse_softmax_loss(batch, self.cfg.num_classes, &acts.logits, y, &mut scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::util::rng::Xoshiro256;

    fn tiny_cfg() -> NetworkConfig {
        NetworkConfig {
            name: "tiny".into(),
            input_hw: 6,
            in_channels: 1,
            conv_layers: 1,
            filters: 2,
            kernel_hw: 3,
            fc_layers: 1,
            fc_neurons: 8,
            num_classes: 3,
            batch_size: 4,
            pool_window: 2,
        }
    }

    #[test]
    fn init_matches_manifest() {
        let cfg = NetworkConfig::quickstart();
        let net = Network::init(&cfg, 0);
        assert_eq!(net.weights.len(), cfg.param_shapes().len());
        assert_eq!(net.weights.param_count(), cfg.param_count());
        for (t, (name, shape)) in net.weights.tensors().iter().zip(cfg.param_shapes()) {
            assert_eq!(t.shape(), &shape[..], "{name}");
            if name.ends_with(".bias") {
                assert_eq!(t.max_abs(), 0.0, "{name} should start at zero");
            }
        }
    }

    #[test]
    fn forward_shapes() {
        let cfg = tiny_cfg();
        let net = Network::init(&cfg, 1);
        let x = vec![0.5f32; 4 * 6 * 6];
        let acts = net.forward(&x, 4);
        assert_eq!(acts.logits.len(), 4 * 3);
        assert_eq!(acts.conv_outs.len(), 1);
        assert_eq!(acts.conv_outs[0].len(), 4 * 6 * 6 * 2);
        assert_eq!(acts.pooled.len(), 4 * 3 * 3 * 2);
    }

    #[test]
    fn gradients_match_finite_differences() {
        let cfg = tiny_cfg();
        let net = Network::init(&cfg, 2);
        let mut rng = Xoshiro256::new(3);
        let x: Vec<f32> = (0..2 * 36).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let mut y = vec![0.0f32; 2 * 3];
        y[0] = 1.0;
        y[3 + 2] = 1.0;
        let acts = net.forward(&x, 2);
        let (_, _, grads) = net.backward(&acts, &y);

        let loss_at = |net: &Network| -> f64 {
            let (l, _) = net.eval_batch(&x, &y, 2);
            l as f64
        };
        let eps = 1e-2f32;
        // Probe a few coordinates in each parameter tensor.
        for ti in 0..net.weights.len() {
            let len = net.weights.tensors()[ti].len();
            for &idx in [0usize, len / 2, len - 1].iter() {
                let mut np = net.clone();
                np.weights.tensors_mut()[ti].data_mut()[idx] += eps;
                let mut nm = net.clone();
                nm.weights.tensors_mut()[ti].data_mut()[idx] -= eps;
                let fd = (loss_at(&np) - loss_at(&nm)) / (2.0 * eps as f64);
                let an = grads.tensors()[ti].data()[idx] as f64;
                assert!(
                    (fd - an).abs() < 5e-3,
                    "tensor {ti} idx {idx}: fd={fd:.6} analytic={an:.6}"
                );
            }
        }
    }

    #[test]
    fn overfits_fixed_batch() {
        let cfg = tiny_cfg();
        let mut net = Network::init(&cfg, 4);
        let ds = Dataset::synthetic(
            &NetworkConfig { num_classes: 3, ..tiny_cfg() },
            12,
            0.05,
            5,
        );
        let (x, y, _) = ds.batch(0, 4);
        let (first, _) = net.eval_batch(&x, &y, 4);
        let mut last = first;
        for _ in 0..60 {
            let (l, _) = net.train_batch(&x, &y, 4, 0.5);
            last = l;
        }
        assert!(last < 0.5 * first, "no learning: first={first} last={last}");
    }

    #[test]
    fn learns_synthetic_task_better_than_chance() {
        let cfg = NetworkConfig {
            name: "learn".into(),
            input_hw: 8,
            in_channels: 1,
            conv_layers: 1,
            filters: 4,
            kernel_hw: 3,
            fc_layers: 1,
            fc_neurons: 16,
            num_classes: 4,
            batch_size: 8,
            pool_window: 2,
        };
        let ds = Dataset::synthetic(&cfg, 256, 0.3, 6);
        let mut net = Network::init(&cfg, 7);
        for epoch in 0..6 {
            let _ = epoch;
            for start in (0..256).step_by(8) {
                let (x, y, _) = ds.batch(start, 8);
                net.train_batch(&x, &y, 8, 0.2);
            }
        }
        let mut correct = 0;
        for start in (0..256).step_by(8) {
            let (x, y, _) = ds.batch(start, 8);
            let (_, c) = net.eval_batch(&x, &y, 8);
            correct += c;
        }
        let acc = correct as f64 / 256.0;
        assert!(acc > 0.6, "accuracy {acc} not better than chance (0.25)");
    }

    #[test]
    fn eval_does_not_change_weights() {
        let cfg = tiny_cfg();
        let net = Network::init(&cfg, 8);
        let before = net.weights.clone();
        let x = vec![0.1f32; 2 * 36];
        let y = vec![0.0f32; 6];
        let _ = net.eval_batch(&x, &y, 2);
        assert_eq!(net.weights.max_abs_diff(&before), 0.0);
    }

    #[test]
    fn with_weights_validates_arity() {
        let cfg = tiny_cfg();
        let net = Network::init(&cfg, 9);
        let w = net.weights.clone();
        let net2 = Network::with_weights(&cfg, w);
        assert_eq!(net2.weights.param_count(), cfg.param_count());
    }
}
