//! Persistent training-step state: the [`StepWorkspace`] activation /
//! gradient arenas and the [`WeightPacks`] packed-GEMM panel cache.
//!
//! Both exist so that, after one warmup step, the whole native train step —
//! forward, loss, backward, SGD — performs **zero heap allocations** and
//! every FLOP-heavy stage runs on the shared 4×8 micro-kernel:
//!
//! * [`StepWorkspace`] owns every intermediate buffer one step needs
//!   (per-layer activations, logits, softmax/loss scratch, ping-pong delta
//!   buffers, im2col scratch, and the reusable gradient [`WeightSet`]).
//!   It is **caller-owned** — a worker holds one across its whole epoch
//!   loop — and keyed by `(cfg, batch)`: the first call per key sizes the
//!   buffers, later calls reuse them (Vec capacity only ever grows).
//! * [`WeightPacks`] caches the [`PackedB`] panels derived from the weight
//!   values: per conv layer the HWIO filter (and its flipped/transposed
//!   form for the odd-kernel input gradient), per dense layer the `(k, n)`
//!   weight and its transpose (for `dx = dy · Wᵀ`). The cache is keyed on
//!   [`WeightSet::generation`] — any weight mutation (an SGD step, an AGWU
//!   fetch installing new weights) invalidates it, and the next forward
//!   repacks **in place** (one repack per train step, amortized across all
//!   row tiles and batch rows; no repack at all across consecutive
//!   evaluation batches on frozen weights).

use crate::config::NetworkConfig;
use crate::tensor::WeightSet;

use super::ops::{self, ConvDims, PackedB};

/// Caller-owned, reusable buffers for one train/eval step (see module docs).
#[derive(Debug, Default)]
pub struct StepWorkspace {
    key: Option<(NetworkConfig, usize)>,
    pub(crate) batch: usize,
    /// Post-ReLU output of each conv layer.
    pub(crate) conv_outs: Vec<Vec<f32>>,
    /// Output of the pooling layer (flattened features).
    pub(crate) pooled: Vec<f32>,
    /// Post-ReLU output of each hidden FC layer.
    pub(crate) fc_outs: Vec<Vec<f32>>,
    /// Final logits.
    pub(crate) logits: Vec<f32>,
    /// Softmax probabilities (loss scratch).
    pub(crate) probs: Vec<f32>,
    /// Loss gradient w.r.t. the logits.
    pub(crate) dlogits: Vec<f32>,
    /// Ping-pong FC delta buffers (sized for the widest feature vector).
    pub(crate) dfeat: Vec<f32>,
    pub(crate) dfeat2: Vec<f32>,
    /// Ping-pong conv delta buffers.
    pub(crate) dconv: Vec<f32>,
    pub(crate) dconv2: Vec<f32>,
    /// Serial-path im2col scratch (grown by the conv entry points).
    pub(crate) cols: Vec<f32>,
    /// Per-task (loss, correct) partials of the parallel loss stage.
    pub(crate) loss_parts: Vec<(f64, usize)>,
    /// Reusable gradient accumulator, written by every backward pass.
    pub(crate) grads: Option<WeightSet>,
}

impl StepWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Size every buffer for `(cfg, batch)`. Idempotent per key: a repeat
    /// call with the same key returns immediately, so warmed-up steps pay
    /// one key comparison and zero allocations.
    pub fn prepare(&mut self, cfg: &NetworkConfig, batch: usize, weights: &WeightSet) {
        if let Some((c, b)) = &self.key {
            if c == cfg && *b == batch {
                return;
            }
        }
        let hw = cfg.input_hw;
        let c_pool = if cfg.conv_layers == 0 { cfg.in_channels } else { cfg.filters };
        let hp = hw / cfg.pool_window;
        let pooled_dim = hp * hp * c_pool;
        self.batch = batch;
        self.conv_outs.resize_with(cfg.conv_layers, Vec::new);
        for out in self.conv_outs.iter_mut() {
            out.resize(batch * hw * hw * cfg.filters, 0.0);
        }
        self.pooled.resize(batch * pooled_dim, 0.0);
        self.fc_outs.resize_with(cfg.fc_layers, Vec::new);
        for out in self.fc_outs.iter_mut() {
            out.resize(batch * cfg.fc_neurons, 0.0);
        }
        self.logits.resize(batch * cfg.num_classes, 0.0);
        self.probs.resize(batch * cfg.num_classes, 0.0);
        self.dlogits.resize(batch * cfg.num_classes, 0.0);
        let feat_max = pooled_dim.max(cfg.fc_neurons).max(cfg.num_classes);
        self.dfeat.resize(batch * feat_max, 0.0);
        self.dfeat2.resize(batch * feat_max, 0.0);
        self.dconv.resize(batch * hw * hw * c_pool, 0.0);
        self.dconv2.resize(batch * hw * hw * c_pool, 0.0);
        self.loss_parts.clear();
        // The gradient set survives re-keys whose parameter shapes are
        // unchanged (e.g. the same cfg at a different batch size): every
        // backward pass fully overwrites it, so only an arity/shape change
        // forces a rebuild.
        let grads_stale = self.grads.as_ref().map_or(true, |g| {
            g.len() != weights.len()
                || g.tensors()
                    .iter()
                    .zip(weights.tensors())
                    .any(|(a, b)| a.shape() != b.shape())
        });
        if grads_stale {
            self.grads = Some(weights.zeros_like());
        }
        self.key = Some((cfg.clone(), batch));
    }

    /// The gradients computed by the most recent backward pass.
    pub fn grads(&self) -> &WeightSet {
        self.grads.as_ref().expect("workspace not prepared (run a forward/backward first)")
    }

    /// Logits of the most recent forward pass.
    pub fn logits(&self) -> &[f32] {
        &self.logits
    }
}

/// Packed micro-kernel panels derived from one weight generation (see
/// module docs). Lives inside [`crate::nn::Network`] behind a `RefCell`;
/// `ensure` is a no-op while the weight generation is unchanged.
#[derive(Debug, Default)]
pub struct WeightPacks {
    generation: Option<u64>,
    /// Per conv layer: the HWIO filter as a `(k²·C, C_o)` pack.
    pub(crate) conv: Vec<PackedB>,
    /// Per conv layer (odd k only): flipped/transposed filter pack for the
    /// input gradient; even kernels take the naive fallback and skip it.
    pub(crate) conv_flip: Vec<PackedB>,
    /// Per dense layer (hidden FCs then the output layer): `(k, n)` pack.
    pub(crate) fc_w: Vec<PackedB>,
    /// Per dense layer: transposed pack for `dx = dy · Wᵀ`.
    pub(crate) fc_wt: Vec<PackedB>,
    flip_scratch: Vec<f32>,
}

fn grow_slots(v: &mut Vec<PackedB>, len: usize) {
    v.truncate(len);
    while v.len() < len {
        v.push(PackedB::empty());
    }
}

impl WeightPacks {
    /// Repack every panel iff `weights` mutated since the cached
    /// generation. Packs are refilled in place ([`PackedB::repack`]), so a
    /// warmed-up repack allocates nothing.
    pub fn ensure(&mut self, cfg: &NetworkConfig, weights: &WeightSet) {
        let gen = weights.generation();
        if self.generation == Some(gen) {
            return;
        }
        let ts = weights.tensors();
        grow_slots(&mut self.conv, cfg.conv_layers);
        grow_slots(&mut self.conv_flip, cfg.conv_layers);
        let dense_layers = cfg.fc_layers + 1;
        grow_slots(&mut self.fc_w, dense_layers);
        grow_slots(&mut self.fc_wt, dense_layers);
        for l in 0..cfg.conv_layers {
            let c = if l == 0 { cfg.in_channels } else { cfg.filters };
            let d = ConvDims {
                n: 1,
                h: cfg.input_hw,
                w: cfg.input_hw,
                c,
                k: cfg.kernel_hw,
                co: cfg.filters,
            };
            let f = ts[2 * l].data();
            self.conv[l].repack(d.k * d.k * d.c, d.co, f);
            if d.k % 2 == 1 {
                self.flip_scratch.resize(d.f_len(), 0.0);
                ops::flip_transpose_filter_into(&d, f, &mut self.flip_scratch[..d.f_len()]);
                self.conv_flip[l].repack(d.k * d.k * d.co, d.c, &self.flip_scratch[..d.f_len()]);
            }
        }
        let mut pi = 2 * cfg.conv_layers;
        for i in 0..dense_layers {
            let w = &ts[pi];
            pi += 2;
            let (k, n) = (w.shape()[0], w.shape()[1]);
            self.fc_w[i].repack(k, n, w.data());
            self.fc_wt[i].repack_transposed(k, n, w.data());
        }
        self.generation = Some(gen);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Network;

    fn tiny_cfg() -> NetworkConfig {
        NetworkConfig {
            name: "ws".into(),
            input_hw: 6,
            in_channels: 1,
            conv_layers: 1,
            filters: 2,
            kernel_hw: 3,
            fc_layers: 1,
            fc_neurons: 8,
            num_classes: 3,
            batch_size: 4,
            pool_window: 2,
        }
    }

    #[test]
    fn prepare_is_idempotent_and_rekeys() {
        let cfg = tiny_cfg();
        let net = Network::init(&cfg, 1);
        let mut ws = StepWorkspace::new();
        ws.prepare(&cfg, 4, &net.weights);
        assert_eq!(ws.logits.len(), 4 * 3);
        assert_eq!(ws.conv_outs.len(), 1);
        assert_eq!(ws.conv_outs[0].len(), 4 * 6 * 6 * 2);
        let ptr = ws.logits.as_ptr();
        ws.prepare(&cfg, 4, &net.weights);
        assert_eq!(ws.logits.as_ptr(), ptr, "same key must not touch buffers");
        // Re-key to a smaller batch: lengths shrink, allocations are reused,
        // and the gradient set survives (same parameter shapes).
        let grads_ptr = ws.grads().tensors()[0].data().as_ptr();
        ws.prepare(&cfg, 2, &net.weights);
        assert_eq!(ws.logits.len(), 2 * 3);
        assert_eq!(ws.grads().len(), net.weights.len());
        assert_eq!(
            ws.grads().tensors()[0].data().as_ptr(),
            grads_ptr,
            "batch re-key must not rebuild the gradient set"
        );
    }

    #[test]
    fn packs_invalidate_on_weight_mutation_only() {
        let cfg = tiny_cfg();
        let mut net = Network::init(&cfg, 2);
        let mut packs = WeightPacks::default();
        packs.ensure(&cfg, &net.weights);
        let gen = packs.generation;
        assert_eq!(packs.conv.len(), 1);
        assert_eq!(packs.fc_w.len(), 2);
        assert_eq!(packs.fc_wt.len(), 2);
        // Unchanged weights: no re-keying.
        packs.ensure(&cfg, &net.weights);
        assert_eq!(packs.generation, gen);
        // Mutation invalidates.
        let delta = net.weights.zeros_like();
        net.weights.axpy(0.0, &delta);
        packs.ensure(&cfg, &net.weights);
        assert_ne!(packs.generation, gen);
    }

    #[test]
    fn fc_pack_shapes_match_manifest() {
        let cfg = tiny_cfg();
        let net = Network::init(&cfg, 3);
        let mut packs = WeightPacks::default();
        packs.ensure(&cfg, &net.weights);
        // Hidden FC: pooled_dim (3·3·2 = 18) × 8; output: 8 × 3.
        assert_eq!(packs.fc_w[0].kk(), 18);
        assert_eq!(packs.fc_w[0].n(), 8);
        assert_eq!(packs.fc_wt[0].kk(), 8);
        assert_eq!(packs.fc_wt[0].n(), 18);
        assert_eq!(packs.fc_w[1].kk(), 8);
        assert_eq!(packs.fc_w[1].n(), 3);
        // Conv: (3·3·1, 2) pack + flipped (3·3·2, 1).
        assert_eq!(packs.conv[0].kk(), 9);
        assert_eq!(packs.conv[0].n(), 2);
        assert_eq!(packs.conv_flip[0].kk(), 18);
        assert_eq!(packs.conv_flip[0].n(), 1);
    }
}
