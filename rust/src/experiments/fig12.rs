//! Fig. 12 — total execution time of the comparison algorithms
//! (a) vs data size (100 k → 700 k samples, 30-node cluster) and
//! (b) vs cluster scale (5 → 35 nodes, 600 k samples); 100 iterations.
//!
//! Paper anchors: BPT-CNN 62.77 s → 307.35 s over (a) while DC-CNN blows up
//! 91.21 s → 929.74 s; over (b) BPT-CNN and TF keep improving with nodes,
//! DC-CNN does not.

use crate::config::ClusterConfig;
use crate::metrics::Table;
use crate::sim::{simulate_algorithm, Algorithm, SimConfig};

pub fn data_size_sweep(quick: bool) -> Table {
    let sizes: Vec<usize> = if quick {
        vec![100_000, 400_000, 700_000]
    } else {
        vec![100_000, 200_000, 300_000, 400_000, 500_000, 600_000, 700_000]
    };
    let mut table = Table::new(
        "Fig. 12(a): execution time [s] vs data size (30 nodes, 100 iterations)",
        &["samples", "BPT-CNN", "Tensorflow", "DisBelief", "DC-CNN"],
    );
    for &n in &sizes {
        let cfg = SimConfig {
            cluster: ClusterConfig::heterogeneous(30, 7),
            samples: n,
            iterations: 100,
            ..SimConfig::paper_default()
        };
        let mut row = vec![format!("{}k", n / 1000)];
        for alg in Algorithm::paper_set() {
            let r = simulate_algorithm(alg, &cfg);
            row.push(format!("{:.2}", r.total_s));
        }
        table.row(&row);
    }
    table
}

pub fn cluster_scale_sweep(quick: bool) -> Table {
    let nodes: Vec<usize> = if quick { vec![5, 20, 35] } else { vec![5, 10, 15, 20, 25, 30, 35] };
    let mut table = Table::new(
        "Fig. 12(b): execution time [s] vs cluster scale (600k samples, 100 iterations)",
        &["nodes", "BPT-CNN", "Tensorflow", "DisBelief", "DC-CNN"],
    );
    for &m in &nodes {
        let cfg = SimConfig {
            cluster: ClusterConfig::heterogeneous(m, 7),
            samples: 600_000,
            iterations: 100,
            ..SimConfig::paper_default()
        };
        let mut row = vec![format!("{m}")];
        for alg in Algorithm::paper_set() {
            let r = simulate_algorithm(alg, &cfg);
            row.push(format!("{:.2}", r.total_s));
        }
        table.row(&row);
    }
    table
}

pub fn run(quick: bool) -> String {
    let mut out = String::new();
    out.push_str("\n# Fig. 12 — total execution time of the comparison algorithms (simulated)\n");
    out.push_str(&data_size_sweep(quick).render());
    out.push_str(&cluster_scale_sweep(quick).render());
    print!("{out}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweeps_produce_full_tables() {
        assert_eq!(data_size_sweep(true).len(), 3);
        assert_eq!(cluster_scale_sweep(true).len(), 3);
    }
}
