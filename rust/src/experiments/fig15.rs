//! Fig. 15 — data communication volume (a) and workload balance (b) of the
//! comparison algorithms as cluster size grows (600 k samples, 5→35 nodes).
//!
//! Paper anchors: BPT-CNN's traffic 2.35 MB → 11.44 MB (≈linear in m)
//! vs TF 2.73 MB → 45.23 MB; BPT-CNN's balance index stays in 0.80–0.89
//! while the baselines degrade.
//!
//! [`thread_balance_sweep`] complements the simulated node-level figure
//! with **measured thread-level** balance indices: real
//! `parallel_train_step` executions under `TilePolicy::Auto`, per pipeline
//! stage, per pool size — the `ScheduleStats::balance_index` numbers the
//! autotuner also consumes.

use crate::config::ClusterConfig;
use crate::metrics::Table;
use crate::sim::{simulate_algorithm, Algorithm, SimConfig};

fn scenario(m: usize) -> SimConfig {
    SimConfig {
        cluster: ClusterConfig::heterogeneous(m, 7),
        samples: 600_000,
        // The paper's comm anchor (2.35 MB at 5 nodes, ~150 KB weight set)
        // corresponds to one weight sync per *global epoch*; we report the
        // same 2·c_w·m·K bookkeeping with K scaled to epoch granularity.
        iterations: 16,
        ..SimConfig::paper_default()
    }
}

pub fn comm_sweep(quick: bool) -> Table {
    let nodes: Vec<usize> = if quick { vec![5, 20, 35] } else { vec![5, 10, 15, 20, 25, 30, 35] };
    let mut table = Table::new(
        "Fig. 15(a): communication volume [MB] vs cluster scale (600k samples)",
        &["nodes", "BPT-CNN", "Tensorflow", "DisBelief", "DC-CNN"],
    );
    for &m in &nodes {
        let cfg = scenario(m);
        let mut row = vec![format!("{m}")];
        for alg in Algorithm::paper_set() {
            let r = simulate_algorithm(alg, &cfg);
            row.push(format!("{:.2}", r.comm_mb));
        }
        table.row(&row);
    }
    table
}

pub fn balance_sweep(quick: bool) -> Table {
    let nodes: Vec<usize> = if quick { vec![5, 20, 35] } else { vec![5, 10, 15, 20, 25, 30, 35] };
    let mut table = Table::new(
        "Fig. 15(b): workload balance index vs cluster scale (1.0 = perfect)",
        &["nodes", "BPT-CNN", "Tensorflow", "DisBelief", "DC-CNN"],
    );
    for &m in &nodes {
        let cfg = scenario(m);
        let mut row = vec![format!("{m}")];
        for alg in Algorithm::paper_set() {
            let r = simulate_algorithm(alg, &cfg);
            row.push(format!("{:.3}", r.balance_index));
        }
        table.row(&row);
    }
    table
}

/// Fig. 15(b) companion from **real measurements**: run warm
/// `TilePolicy::Auto` train steps on pools of several sizes and report the
/// mean per-stage thread-level balance index (1.0 = every worker equally
/// busy). Rows are pipeline stages in execution order; columns are pool
/// sizes.
pub fn thread_balance_sweep(quick: bool) -> Table {
    use crate::config::NetworkConfig;
    use crate::data::Dataset;
    use crate::inner::{parallel_train_step, TilePolicy};
    use crate::nn::{Network, StepWorkspace};
    use crate::util::threadpool::ThreadPool;

    let cfg = NetworkConfig {
        name: "fig15_threads".into(),
        input_hw: 12,
        in_channels: 1,
        conv_layers: 1,
        filters: 6,
        kernel_hw: 3,
        fc_layers: 2,
        fc_neurons: if quick { 128 } else { 512 },
        num_classes: 8,
        batch_size: 4,
        pool_window: 2,
    };
    let threads: Vec<usize> = if quick { vec![2, 4] } else { vec![2, 4, 8] };
    let steps = if quick { 6 } else { 24 };
    let ds = Dataset::synthetic(&cfg, 16, 0.2, 23);
    let (x, y, _) = ds.batch(0, cfg.batch_size);
    // Ordered per-stage accumulators: (label, per-thread-count (Σ, n)).
    let mut labels: Vec<&'static str> = Vec::new();
    let mut sums: Vec<Vec<(f64, u32)>> = Vec::new();
    for (ti, t) in threads.iter().enumerate() {
        let pool = ThreadPool::new(*t);
        let mut net = Network::init(&cfg, 24);
        let mut ws = StepWorkspace::new();
        let rows = (cfg.input_hw / 2).max(1);
        for step in 0..steps {
            let r = parallel_train_step(
                &pool,
                &mut net,
                &x,
                &y,
                cfg.batch_size,
                0.05,
                TilePolicy::auto(rows),
                &mut ws,
            );
            if step == 0 {
                continue; // skip the cold step (calibration + pack warmup)
            }
            for s in &r.stages {
                let idx = match labels.iter().position(|l| *l == s.label) {
                    Some(i) => i,
                    None => {
                        labels.push(s.label);
                        sums.push(vec![(0.0, 0); threads.len()]);
                        labels.len() - 1
                    }
                };
                let slot = &mut sums[idx][ti];
                slot.0 += s.balance;
                slot.1 += 1;
            }
        }
    }
    let headers: Vec<String> = std::iter::once("stage".to_string())
        .chain(threads.iter().map(|t| format!("{t} threads")))
        .collect();
    let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        "Fig. 15(b) companion: measured thread-level balance index per stage (TilePolicy::Auto)",
        &hrefs,
    );
    for (i, label) in labels.iter().enumerate() {
        let mut row = vec![label.to_string()];
        for (sum, n) in &sums[i] {
            row.push(if *n > 0 { format!("{:.3}", sum / *n as f64) } else { "-".to_string() });
        }
        table.row(&row);
    }
    table
}

pub fn run(quick: bool) -> String {
    let mut out = String::new();
    out.push_str("\n# Fig. 15 — communication & workload balance (simulated)\n");
    out.push_str(&comm_sweep(quick).render());
    out.push_str(&balance_sweep(quick).render());
    out.push_str(&thread_balance_sweep(quick).render());
    print!("{out}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_complete() {
        assert_eq!(comm_sweep(true).len(), 3);
        assert_eq!(balance_sweep(true).len(), 3);
    }

    /// The measured sweep reports one row per pipeline stage, each with a
    /// balance index in (0, 1] for every pool size.
    #[test]
    fn thread_balance_table_covers_stages() {
        let t = thread_balance_sweep(true);
        assert!(t.len() >= 6, "too few stage rows: {}", t.len());
        let rendered = t.render();
        for stage in ["conv_fwd", "dense_fwd", "dense_bwd", "conv_bwd", "loss"] {
            assert!(rendered.contains(stage), "missing {stage}:\n{rendered}");
        }
    }
}
