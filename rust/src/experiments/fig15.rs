//! Fig. 15 — data communication volume (a) and workload balance (b) of the
//! comparison algorithms as cluster size grows (600 k samples, 5→35 nodes).
//!
//! Paper anchors: BPT-CNN's traffic 2.35 MB → 11.44 MB (≈linear in m)
//! vs TF 2.73 MB → 45.23 MB; BPT-CNN's balance index stays in 0.80–0.89
//! while the baselines degrade.

use crate::config::ClusterConfig;
use crate::metrics::Table;
use crate::sim::{simulate_algorithm, Algorithm, SimConfig};

fn scenario(m: usize) -> SimConfig {
    SimConfig {
        cluster: ClusterConfig::heterogeneous(m, 7),
        samples: 600_000,
        // The paper's comm anchor (2.35 MB at 5 nodes, ~150 KB weight set)
        // corresponds to one weight sync per *global epoch*; we report the
        // same 2·c_w·m·K bookkeeping with K scaled to epoch granularity.
        iterations: 16,
        ..SimConfig::paper_default()
    }
}

pub fn comm_sweep(quick: bool) -> Table {
    let nodes: Vec<usize> = if quick { vec![5, 20, 35] } else { vec![5, 10, 15, 20, 25, 30, 35] };
    let mut table = Table::new(
        "Fig. 15(a): communication volume [MB] vs cluster scale (600k samples)",
        &["nodes", "BPT-CNN", "Tensorflow", "DisBelief", "DC-CNN"],
    );
    for &m in &nodes {
        let cfg = scenario(m);
        let mut row = vec![format!("{m}")];
        for alg in Algorithm::paper_set() {
            let r = simulate_algorithm(alg, &cfg);
            row.push(format!("{:.2}", r.comm_mb));
        }
        table.row(&row);
    }
    table
}

pub fn balance_sweep(quick: bool) -> Table {
    let nodes: Vec<usize> = if quick { vec![5, 20, 35] } else { vec![5, 10, 15, 20, 25, 30, 35] };
    let mut table = Table::new(
        "Fig. 15(b): workload balance index vs cluster scale (1.0 = perfect)",
        &["nodes", "BPT-CNN", "Tensorflow", "DisBelief", "DC-CNN"],
    );
    for &m in &nodes {
        let cfg = scenario(m);
        let mut row = vec![format!("{m}")];
        for alg in Algorithm::paper_set() {
            let r = simulate_algorithm(alg, &cfg);
            row.push(format!("{:.3}", r.balance_index));
        }
        table.row(&row);
    }
    table
}

pub fn run(quick: bool) -> String {
    let mut out = String::new();
    out.push_str("\n# Fig. 15 — communication & workload balance (simulated)\n");
    out.push_str(&comm_sweep(quick).render());
    out.push_str(&balance_sweep(quick).render());
    print!("{out}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_complete() {
        assert_eq!(comm_sweep(true).len(), 3);
        assert_eq!(balance_sweep(true).len(), 3);
    }
}
