//! Fig. 14 — BPT-CNN execution time under its own strategy ablations:
//! {AGWU, SGWU} × {IDPA, UDPA} over (a) CNN network scale (Table 2 cases),
//! (b) data size, (c) cluster scale, (d) threads per node.
//!
//! Paper shape: AGWU+IDPA fastest everywhere; the margin grows with
//! cluster size and thread count.

use crate::config::{ClusterConfig, NetworkConfig, PartitionStrategy, UpdateStrategy};
use crate::metrics::Table;
use crate::sim::{simulate, SimConfig};

const COMBOS: [(UpdateStrategy, PartitionStrategy); 4] = [
    (UpdateStrategy::Agwu, PartitionStrategy::Idpa),
    (UpdateStrategy::Agwu, PartitionStrategy::Udpa),
    (UpdateStrategy::Sgwu, PartitionStrategy::Idpa),
    (UpdateStrategy::Sgwu, PartitionStrategy::Udpa),
];

const HEADER: [&str; 5] = ["x", "AGWU+IDPA", "AGWU+UDPA", "SGWU+IDPA", "SGWU+UDPA"];

fn base() -> SimConfig {
    SimConfig {
        cluster: ClusterConfig::heterogeneous(20, 7),
        samples: 300_000,
        iterations: 100,
        ..SimConfig::paper_default()
    }
}

fn sweep<F: Fn(&mut SimConfig, usize)>(
    title: &str,
    xlabel: &str,
    xs: &[usize],
    setter: F,
) -> Table {
    let mut header = HEADER;
    header[0] = xlabel;
    let mut table = Table::new(title, &header);
    for &x in xs {
        let mut row = vec![format!("{x}")];
        for (u, p) in COMBOS {
            let mut cfg = base();
            cfg.update = u;
            cfg.partition = p;
            setter(&mut cfg, x);
            let r = simulate(&cfg);
            row.push(format!("{:.2}", r.total_s));
        }
        table.row(&row);
    }
    table
}

pub fn network_scale_sweep(quick: bool) -> Table {
    let cases: Vec<usize> = if quick { vec![1, 4, 7] } else { (1..=7).collect() };
    sweep(
        "Fig. 14(a): time [s] vs CNN network scale (Table 2 cases)",
        "case",
        &cases,
        |cfg, case| cfg.network = NetworkConfig::table2_case(case),
    )
}

pub fn data_size_sweep(quick: bool) -> Table {
    let sizes: Vec<usize> = if quick {
        vec![100_000, 400_000, 700_000]
    } else {
        vec![100_000, 200_000, 300_000, 400_000, 500_000, 600_000, 700_000]
    };
    sweep(
        "Fig. 14(b): time [s] vs data size",
        "samples",
        &sizes,
        |cfg, n| cfg.samples = n,
    )
}

pub fn cluster_scale_sweep(quick: bool) -> Table {
    let nodes: Vec<usize> = if quick { vec![5, 20, 35] } else { vec![5, 10, 15, 20, 25, 30, 35] };
    sweep(
        "Fig. 14(c): time [s] vs cluster scale",
        "nodes",
        &nodes,
        |cfg, m| cfg.cluster = ClusterConfig::heterogeneous(m, 7),
    )
}

pub fn threads_sweep(quick: bool) -> Table {
    let threads: Vec<usize> = if quick { vec![1, 8, 16] } else { vec![1, 2, 4, 8, 12, 16] };
    sweep(
        "Fig. 14(d): time [s] vs threads per node",
        "threads",
        &threads,
        |cfg, t| cfg.threads_per_node = t,
    )
}

pub fn run(quick: bool) -> String {
    let mut out = String::new();
    out.push_str("\n# Fig. 14 — BPT-CNN strategy ablations {AGWU,SGWU}×{IDPA,UDPA} (simulated)\n");
    out.push_str(&network_scale_sweep(quick).render());
    out.push_str(&data_size_sweep(quick).render());
    out.push_str(&cluster_scale_sweep(quick).render());
    out.push_str(&threads_sweep(quick).render());
    print!("{out}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_sweeps_complete() {
        assert_eq!(network_scale_sweep(true).len(), 3);
        assert_eq!(data_size_sweep(true).len(), 3);
        assert_eq!(cluster_scale_sweep(true).len(), 3);
        assert_eq!(threads_sweep(true).len(), 3);
    }

    #[test]
    fn agwu_idpa_wins_on_heterogeneous_cluster() {
        // The headline ablation claim, checked numerically.
        let mut best = f64::INFINITY;
        let mut best_combo = 0;
        for (i, (u, p)) in COMBOS.iter().enumerate() {
            let mut cfg = base();
            cfg.update = *u;
            cfg.partition = *p;
            let r = simulate(&cfg);
            if r.total_s < best {
                best = r.total_s;
                best_combo = i;
            }
        }
        assert_eq!(best_combo, 0, "AGWU+IDPA should be fastest");
    }
}
