//! Fig. 13 — execution time to reach a fixed accuracy (0.750 in the paper)
//! under different computing resources: (a) cluster nodes, (b) CPU cores.
//!
//! Combines Table 1's iteration requirements (how many epochs each
//! algorithm needs) with the simulator's per-iteration time. Paper shape:
//! BPT-CNN fastest everywhere; DisBelief/DC-CNN *degrade* past ~25 nodes.

use crate::config::ClusterConfig;
use crate::metrics::Table;
use crate::sim::{simulate_algorithm, Algorithm, SimConfig};

/// Iteration requirements for accuracy 0.750 from paper Table 1. Using the
/// paper's own ratios keeps (a)/(b) interpretable even though our synthetic
/// task reaches thresholds faster (see table1.rs for measured equivalents).
pub const ITERS_075: [(&str, usize); 4] = [
    ("BPT-CNN", 42),
    ("Tensorflow", 64),
    ("DisBelief", 85),
    ("DC-CNN", 147),
];

fn algorithms() -> [Algorithm; 4] {
    Algorithm::paper_set()
}

pub fn nodes_sweep(quick: bool) -> Table {
    let nodes: Vec<usize> = if quick { vec![5, 20, 35] } else { vec![5, 10, 15, 20, 25, 30, 35] };
    let mut table = Table::new(
        "Fig. 13(a): time [s] to accuracy 0.750 vs cluster nodes (8 cores/node)",
        &["nodes", "BPT-CNN", "Tensorflow", "DisBelief", "DC-CNN"],
    );
    for &m in &nodes {
        let mut row = vec![format!("{m}")];
        for (alg, (_, iters)) in algorithms().into_iter().zip(ITERS_075) {
            let cfg = SimConfig {
                cluster: ClusterConfig::heterogeneous(m, 7),
                samples: 300_000,
                iterations: iters,
                ..SimConfig::paper_default()
            };
            let r = simulate_algorithm(alg, &cfg);
            row.push(format!("{:.2}", r.total_s));
        }
        table.row(&row);
    }
    table
}

pub fn cores_sweep(quick: bool) -> Table {
    let cores: Vec<usize> = if quick { vec![2, 8, 16] } else { vec![1, 2, 4, 8, 12, 16] };
    let mut table = Table::new(
        "Fig. 13(b): time [s] to accuracy 0.750 vs CPU cores per node (20 nodes)",
        &["cores", "BPT-CNN", "Tensorflow", "DisBelief", "DC-CNN"],
    );
    for &c in &cores {
        let mut row = vec![format!("{c}")];
        for (alg, (_, iters)) in algorithms().into_iter().zip(ITERS_075) {
            let mut cluster = ClusterConfig::heterogeneous(20, 7);
            for n in cluster.nodes.iter_mut() {
                n.cores = c;
            }
            let cfg = SimConfig {
                cluster,
                samples: 300_000,
                iterations: iters,
                threads_per_node: c,
                ..SimConfig::paper_default()
            };
            let r = simulate_algorithm(alg, &cfg);
            row.push(format!("{:.2}", r.total_s));
        }
        table.row(&row);
    }
    table
}

pub fn run(quick: bool) -> String {
    let mut out = String::new();
    out.push_str("\n# Fig. 13 — execution time for fixed accuracy 0.750 (simulated)\n");
    out.push_str("(iteration counts per algorithm from paper Table 1: 42/64/85/147)\n");
    out.push_str(&nodes_sweep(quick).render());
    out.push_str(&cores_sweep(quick).render());
    print!("{out}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_complete() {
        assert_eq!(nodes_sweep(true).len(), 3);
        assert_eq!(cores_sweep(true).len(), 3);
    }
}
