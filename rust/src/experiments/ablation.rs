//! Ablation: AGWU's staleness attenuation γ (Eq. 9) and accuracy weighting
//! Q (Eq. 10) vs plain asynchronous averaging, under a deliberately extreme
//! straggler (one node 6× slower ⇒ very stale submissions).
//!
//! This isolates the paper's *design choice*: without γ, a stale local set
//! `W_j^(k)` with k ≪ i drags the global set back toward an old region;
//! with γ its influence decays. The measured signal is the final accuracy
//! and the worst transient dip of the held-out curve.

use std::sync::Arc;

use crate::config::NetworkConfig;
use crate::data::Dataset;
use crate::metrics::Table;
use crate::nn::Network;
use crate::outer::cluster::{run_async, AsyncMode};
use crate::outer::worker::{LocalTrainer, NativeTrainer};

pub struct AblationResult {
    pub mode: &'static str,
    pub final_accuracy: f64,
    pub min_accuracy_after_warmup: f64,
    pub mean_staleness_effect: f64,
}

fn run_mode(mode: AsyncMode, straggler_slowdown: f64, seed: u64) -> AblationResult {
    let cfg = NetworkConfig::quickstart();
    let m = 4;
    let samples = 512;
    let iterations = 8;
    let train_ds = Arc::new(Dataset::synthetic(&cfg, samples, 0.8, seed));
    let eval_ds = Dataset::synthetic_split(&cfg, 256, 0.8, seed, seed ^ 0xEEEE);
    let per = samples / m;
    let schedule = vec![(0..m).map(|j| j * per..(j + 1) * per).collect::<Vec<_>>()];
    let workers: Vec<Box<dyn LocalTrainer>> = (0..m)
        .map(|j| {
            let slow = if j == m - 1 { straggler_slowdown } else { 1.0 };
            Box::new(
                NativeTrainer::new(&cfg, Arc::clone(&train_ds), 0.3).with_slowdown(slow),
            ) as Box<dyn LocalTrainer>
        })
        .collect();
    let init = Network::init(&cfg, seed).weights;
    let cfg2 = cfg.clone();
    let eval_hook = move |ws: &crate::tensor::WeightSet| -> (f64, f64) {
        let net = Network::with_weights(&cfg2, ws.clone());
        let bsz = cfg2.batch_size;
        let mut step_ws = crate::nn::StepWorkspace::new();
        let (mut correct, mut batches, mut seen) = (0usize, 0usize, 0usize);
        while seen < eval_ds.len() {
            let (x, y, _) = eval_ds.batch(seen, bsz);
            let (_, c) = net.eval_batch_ws(&x, &y, bsz, &mut step_ws);
            correct += c;
            seen += bsz;
            batches += 1;
        }
        (0.0, correct as f64 / (batches * bsz) as f64)
    };
    let report = run_async(init, workers, &schedule, iterations, Some(&eval_hook), mode);
    let accs: Vec<f64> = report.versions.iter().filter_map(|v| v.eval.map(|e| e.1)).collect();
    let warmup = accs.len() / 2;
    let final_accuracy = *accs.last().unwrap_or(&0.0);
    let min_after = accs[warmup..].iter().copied().fold(1.0f64, f64::min);
    AblationResult {
        mode: match mode {
            AsyncMode::Agwu => "AGWU (γ·Q, Eq. 10)",
            AsyncMode::Plain => "plain async (no γ/Q)",
        },
        final_accuracy,
        min_accuracy_after_warmup: min_after,
        mean_staleness_effect: final_accuracy - min_after,
    }
}

pub fn run(quick: bool) -> String {
    let slowdowns: &[f64] = if quick { &[4.0] } else { &[2.0, 4.0, 8.0] };
    let mut out = String::new();
    out.push_str("\n# Ablation — AGWU staleness attenuation γ (Eq. 9) under stragglers\n");
    let mut table = Table::new(
        "final / worst-late accuracy with one straggler node (higher & stabler = better)",
        &["straggler", "mode", "final acc", "min late acc", "late dip"],
    );
    for &slow in slowdowns {
        for mode in [AsyncMode::Agwu, AsyncMode::Plain] {
            let r = run_mode(mode, slow, 42);
            table.row(&[
                format!("{slow}×"),
                r.mode.to_string(),
                format!("{:.3}", r.final_accuracy),
                format!("{:.3}", r.min_accuracy_after_warmup),
                format!("{:.3}", r.mean_staleness_effect),
            ]);
        }
    }
    out.push_str(&table.render());
    out.push_str(
        "\nExpected: with γ·Q the stale straggler's submissions are attenuated, so the\n\
         late curve dips less (smaller 'late dip') at equal-or-better final accuracy.\n",
    );
    print!("{out}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn both_modes_produce_results() {
        let a = run_mode(AsyncMode::Agwu, 3.0, 1);
        let p = run_mode(AsyncMode::Plain, 3.0, 1);
        assert!(a.final_accuracy > 0.1 && p.final_accuracy > 0.1);
        assert!(a.min_accuracy_after_warmup <= a.final_accuracy + 1e-9);
    }
}
