//! Fig. 11 — accuracy & AUC vs training epochs for the comparison
//! algorithms (real training on the in-process cluster, native backend).
//!
//! Paper result: BPT-CNN reaches the highest average accuracy (0.744 vs
//! 0.721 TF / 0.722 DisBelief / 0.639 DC-CNN) and the highest AUC; the
//! expected *shape* here is: AGWU+IDPA ≥ sync-uniform ≈ plain-async >
//! single-node, with BPT-CNN's curve the most stable.

use std::sync::Arc;

use crate::config::{ClusterConfig, NetworkConfig, PartitionStrategy, TrainConfig, UpdateStrategy};
use crate::data::Dataset;
use crate::metrics::{ascii_chart, Table};
use crate::nn::Network;
use crate::outer::cluster::{run_async, run_sgwu, AsyncMode};
use crate::outer::trainer::{build_schedule, slowdown_factors};
use crate::outer::worker::{LocalTrainer, NativeTrainer};
use crate::util::stats;

/// The four comparison strategies realized as real update rules.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// BPT-CNN: AGWU (Eq. 10) + IDPA.
    BptCnn,
    /// tensorflow-like: synchronous uniform data parallelism.
    TensorflowLike,
    /// distbelief-like: plain async (no γ, no accuracy weighting).
    DistBeliefLike,
    /// dccnn-like: single-node training.
    DcCnnLike,
}

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::BptCnn => "BPT-CNN",
            Strategy::TensorflowLike => "Tensorflow",
            Strategy::DistBeliefLike => "DisBelief",
            Strategy::DcCnnLike => "DC-CNN",
        }
    }

    pub fn all() -> [Strategy; 4] {
        [
            Strategy::BptCnn,
            Strategy::TensorflowLike,
            Strategy::DistBeliefLike,
            Strategy::DcCnnLike,
        ]
    }
}

/// Training-noise level for the Fig. 11 / Table 1 accuracy studies.
pub const NOISE: f32 = 1.4;

/// Accuracy curve of one strategy: (epoch-equivalent, accuracy) points plus
/// the wall-clock view (seconds, accuracy).
pub struct StrategyCurve {
    pub strategy: Strategy,
    pub points: Vec<(f64, f64)>,
    pub time_points: Vec<(f64, f64)>,
    pub final_accuracy: f64,
    pub auc: f64,
}

/// First wall-clock second at which the strategy reached `threshold`.
pub fn time_to_accuracy(curve: &StrategyCurve, threshold: f64) -> Option<f64> {
    curve
        .time_points
        .iter()
        .find(|(_, acc)| *acc >= threshold)
        .map(|(t, _)| *t)
}

/// Train one strategy and return its held-out accuracy curve.
pub fn train_strategy(
    strategy: Strategy,
    network: &NetworkConfig,
    samples: usize,
    iterations: usize,
    seed: u64,
) -> StrategyCurve {
    let m = match strategy {
        Strategy::DcCnnLike => 1,
        _ => 4,
    };
    let cluster = match strategy {
        Strategy::DcCnnLike => ClusterConfig::homogeneous(1),
        _ => ClusterConfig::heterogeneous(m, seed ^ 0x5EED),
    };
    let tc = TrainConfig {
        network: network.clone(),
        update: UpdateStrategy::Agwu,
        partition: match strategy {
            Strategy::BptCnn => PartitionStrategy::Idpa,
            _ => PartitionStrategy::Udpa,
        },
        total_samples: samples,
        iterations,
        idpa_batches: (iterations / 2).clamp(1, 4),
        learning_rate: 0.25,
        seed,
    };
    // Heavy pixel noise: the regime where per-node overfitting hurts and
    // the global-averaging robustness the paper credits BPT-CNN with
    // (§5.2 "narrows the impact of local overfitting") actually matters.
    let train_ds = Arc::new(Dataset::synthetic(network, samples, NOISE, seed));
    let eval_ds = Dataset::synthetic_split(network, 256, NOISE, seed, seed ^ 0xEEEE);
    let (schedule, _, iters) = build_schedule(&tc, &cluster);
    let slow = slowdown_factors(&cluster);
    let workers: Vec<Box<dyn LocalTrainer>> = (0..m)
        .map(|j| {
            Box::new(
                NativeTrainer::new(network, Arc::clone(&train_ds), tc.learning_rate)
                    .with_slowdown(slow[j]),
            ) as Box<dyn LocalTrainer>
        })
        .collect();
    let init = Network::init(network, seed).weights;

    let cfg2 = network.clone();
    let eval_hook = move |ws: &crate::tensor::WeightSet| -> (f64, f64) {
        let net = Network::with_weights(&cfg2, ws.clone());
        let bsz = cfg2.batch_size;
        let mut step_ws = crate::nn::StepWorkspace::new();
        let mut correct = 0usize;
        let mut loss = 0.0f64;
        let mut batches = 0usize;
        let mut seen = 0usize;
        while seen < eval_ds.len() {
            let (x, y, _) = eval_ds.batch(seen, bsz);
            let (l, c) = net.eval_batch_ws(&x, &y, bsz, &mut step_ws);
            loss += l as f64;
            correct += c;
            seen += bsz;
            batches += 1;
        }
        (loss / batches as f64, correct as f64 / (batches * bsz) as f64)
    };

    let report = match strategy {
        Strategy::TensorflowLike => run_sgwu(init, workers, &schedule, iters, Some(&eval_hook)),
        Strategy::DistBeliefLike => {
            run_async(init, workers, &schedule, iters, Some(&eval_hook), AsyncMode::Plain)
        }
        Strategy::BptCnn | Strategy::DcCnnLike => {
            run_async(init, workers, &schedule, iters, Some(&eval_hook), AsyncMode::Agwu)
        }
    };

    // Normalize versions to epoch-equivalents (m versions per epoch async).
    let per_epoch = match strategy {
        Strategy::TensorflowLike => 1.0,
        _ => m as f64,
    };
    let points: Vec<(f64, f64)> = report
        .versions
        .iter()
        .filter_map(|v| v.eval.map(|(_, acc)| (v.version as f64 / per_epoch, acc)))
        .collect();
    let time_points: Vec<(f64, f64)> = report
        .versions
        .iter()
        .filter_map(|v| v.eval.map(|(_, acc)| (v.at_s, acc)))
        .collect();
    let final_accuracy = points.last().map(|p| p.1).unwrap_or(0.0);
    let span = points.last().map(|p| p.0).unwrap_or(1.0)
        - points.first().map(|p| p.0).unwrap_or(0.0);
    let auc = if span > 0.0 { stats::auc(&points) / span } else { final_accuracy };
    StrategyCurve { strategy, points, time_points, final_accuracy, auc }
}

pub fn run(quick: bool) -> String {
    let network = NetworkConfig::quickstart();
    let (samples, iterations) = if quick { (384, 6) } else { (1024, 24) };
    let mut out = String::new();
    out.push_str("\n# Fig. 11 — accuracy & AUC of the comparison algorithms\n");
    out.push_str(&format!(
        "(real training, native backend, {samples} samples, {iterations} iterations)\n"
    ));
    let curves: Vec<StrategyCurve> = Strategy::all()
        .into_iter()
        .map(|s| train_strategy(s, &network, samples, iterations, 42))
        .collect();

    let mut table = Table::new(
        "Fig. 11 summary (paper: BPT-CNN 0.744 acc, AUC +5.9–10.1% over baselines)",
        &["algorithm", "final acc", "mean acc", "AUC", "t→0.5acc[s]"],
    );
    for c in &curves {
        let mean_acc = stats::mean(&c.points.iter().map(|p| p.1).collect::<Vec<_>>());
        table.row(&[
            c.strategy.name().to_string(),
            format!("{:.3}", c.final_accuracy),
            format!("{mean_acc:.3}"),
            format!("{:.3}", c.auc),
            time_to_accuracy(c, 0.5)
                .map(|t| format!("{t:.2}"))
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    out.push_str(&table.render());
    out.push_str(
        "
Deviation note: per-EPOCH ordering differs from the paper — the synthetic
         task is small enough that plain single-node SGD converges in a few epochs,
         and Eq. 10's Q-weighting (local accuracy ≈ chance at start) damps AGWU's
         early updates. The paper's equal-resource claim is carried by the wall-
         clock view below (heterogeneous stragglers + single-node serialization
         penalize the baselines), and by Figs. 12–13. See EXPERIMENTS.md §Fig11.
",
    );

    let series: Vec<(&str, Vec<(f64, f64)>)> = curves
        .iter()
        .map(|c| (c.strategy.name(), c.points.clone()))
        .collect();
    out.push_str(&ascii_chart(
        "\nFig. 11(a): held-out accuracy vs epoch",
        &series,
        64,
        16,
    ));
    let time_series: Vec<(&str, Vec<(f64, f64)>)> = curves
        .iter()
        .map(|c| (c.strategy.name(), c.time_points.clone()))
        .collect();
    out.push_str(&ascii_chart(
        "\nFig. 11(a'): held-out accuracy vs wall-clock seconds (equal resources)",
        &time_series,
        64,
        16,
    ));
    print!("{out}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategies_learn_and_bptcnn_competitive() {
        let network = NetworkConfig::quickstart();
        let bpt = train_strategy(Strategy::BptCnn, &network, 384, 6, 1);
        let dc = train_strategy(Strategy::DcCnnLike, &network, 384, 6, 1);
        assert!(bpt.final_accuracy > 0.15, "bpt acc {}", bpt.final_accuracy);
        assert!(!bpt.points.is_empty() && !dc.points.is_empty());
        assert!(bpt.auc > 0.0 && bpt.auc <= 1.0);
    }
}
