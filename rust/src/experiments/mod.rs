//! Paper-experiment regenerators: one module per table/figure of §5.
//! Each `run(quick)` prints the same rows/series the paper reports and
//! returns the rendered text (also logged to `results/` as JSON lines).
//!
//! `quick = true` shrinks workloads for CI-speed smoke runs; `quick = false`
//! runs the paper-scale sweeps (simulator figures stay fast either way; the
//! real-training figures scale with the flag).

pub mod ablation;
pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod fig14;
pub mod fig15;
pub mod table1;

/// Dispatch by experiment id ("fig11" … "fig15", "table1", "all").
pub fn run(id: &str, quick: bool) -> anyhow::Result<String> {
    let out = match id {
        "fig11" => fig11::run(quick),
        "fig12" => fig12::run(quick),
        "fig13" => fig13::run(quick),
        "fig14" => fig14::run(quick),
        "fig15" => fig15::run(quick),
        "table1" => table1::run(quick),
        "ablation" => ablation::run(quick),
        "all" => {
            let mut all = String::new();
            for id in ["fig11", "table1", "fig12", "fig13", "fig14", "fig15", "ablation"] {
                all.push_str(&run(id, quick)?);
            }
            all
        }
        other => anyhow::bail!("unknown experiment '{other}' (fig11..fig15, table1, ablation, all)"),
    };
    Ok(out)
}

#[cfg(test)]
mod tests {
    #[test]
    fn unknown_experiment_rejected() {
        assert!(super::run("fig99", true).is_err());
    }
}
