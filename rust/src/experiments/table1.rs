//! Table 1 — training iterations required by each comparison algorithm to
//! reach fixed accuracy thresholds.
//!
//! Paper row (ImageNet): acc 0.75 → BPT-CNN 42, TF 64, DisBelief 85,
//! DC-CNN 147; acc 0.80 → 97 / 187 / 211 / –. Expected shape on the
//! synthetic task: BPT-CNN needs the fewest epochs at the higher
//! thresholds; DC-CNN (single node) the most (or never reaches them).

use crate::config::NetworkConfig;
use crate::metrics::Table;

use super::fig11::{train_strategy, Strategy, StrategyCurve};

/// First epoch at which the curve reaches `threshold` accuracy.
pub fn iterations_to_accuracy(curve: &StrategyCurve, threshold: f64) -> Option<f64> {
    curve
        .points
        .iter()
        .find(|(_, acc)| *acc >= threshold)
        .map(|(epoch, _)| *epoch)
}

pub fn run(quick: bool) -> String {
    let network = NetworkConfig::quickstart();
    let (samples, iterations) = if quick { (384, 8) } else { (1024, 32) };
    // Thresholds scaled to the synthetic task's accuracy range.
    let thresholds = [0.35, 0.50, 0.65, 0.80];

    let curves: Vec<StrategyCurve> = Strategy::all()
        .into_iter()
        .map(|s| train_strategy(s, &network, samples, iterations, 42))
        .collect();

    let mut out = String::new();
    out.push_str("\n# Table 1 — iterations (epochs) required for fixed accuracy\n");
    out.push_str("(paper @0.75: BPT-CNN 42 < TF 64 < DisBelief 85 < DC-CNN 147)\n");
    let mut table = Table::new(
        "Epochs to reach accuracy threshold ('-' = not reached)",
        &["accuracy", "BPT-CNN", "Tensorflow", "DisBelief", "DC-CNN"],
    );
    for &th in &thresholds {
        let mut row = vec![format!("{th:.2}")];
        for c in &curves {
            row.push(
                iterations_to_accuracy(c, th)
                    .map(|e| format!("{e:.1}"))
                    .unwrap_or_else(|| "-".to_string()),
            );
        }
        table.row(&row);
    }
    out.push_str(&table.render());
    print!("{out}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_lookup() {
        let curve = StrategyCurve {
            strategy: Strategy::BptCnn,
            points: vec![(1.0, 0.2), (2.0, 0.5), (3.0, 0.7)],
            time_points: vec![(0.1, 0.2), (0.2, 0.5), (0.3, 0.7)],
            final_accuracy: 0.7,
            auc: 0.5,
        };
        assert_eq!(iterations_to_accuracy(&curve, 0.4), Some(2.0));
        assert_eq!(iterations_to_accuracy(&curve, 0.1), Some(1.0));
        assert_eq!(iterations_to_accuracy(&curve, 0.9), None);
    }
}
