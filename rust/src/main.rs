//! `bptcnn` — the BPT-CNN launcher (Layer-3 leader entrypoint).
//!
//! Subcommands:
//!   train         run distributed training on the in-process cluster
//!   param-server  standalone parameter-server process (outer layer over TCP)
//!   worker        computing-node process connecting to a param-server
//!   simulate      run one discrete-event cluster simulation
//!   experiment    regenerate a paper table/figure (fig11..fig15, table1, all)
//!   inspect       print artifact manifest / config information

use bptcnn::config::{
    ClusterConfig, NetworkConfig, PartitionStrategy, TrainConfig, UpdateStrategy,
};
use bptcnn::metrics::Table;
use bptcnn::nn::Network;
use bptcnn::sim::{simulate, SimConfig};
use bptcnn::util::cli::{Args, CliError};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match argv.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&argv[1..]),
        Some("param-server") => cmd_param_server(&argv[1..]),
        Some("worker") => cmd_worker(&argv[1..]),
        Some("simulate") => cmd_simulate(&argv[1..]),
        Some("experiment") => cmd_experiment(&argv[1..]),
        Some("inspect") => cmd_inspect(&argv[1..]),
        Some("--help") | Some("-h") | None => {
            print_usage();
            0
        }
        Some(other) => {
            eprintln!("unknown command '{other}'\n");
            print_usage();
            2
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    println!(
        "bptcnn — Bi-layered Parallel Training for large-scale CNNs (TPDS'18 reproduction)\n\n\
         USAGE: bptcnn <command> [flags]\n\n\
         COMMANDS:\n  \
           train         distributed training on the in-process cluster\n  \
           param-server  standalone parameter-server process (outer layer over TCP)\n  \
           worker        computing-node process connecting to a param-server\n  \
           simulate      discrete-event cluster simulation at paper scale\n  \
           experiment    regenerate paper results: fig11..fig15, table1, all\n  \
           inspect       show artifact manifests and configs\n\n\
         Run `bptcnn <command> --help` for flags."
    );
}

fn handle<T>(r: Result<T, CliError>, usage: &str) -> Result<T, i32> {
    match r {
        Ok(v) => Ok(v),
        Err(CliError::HelpRequested) => {
            println!("{usage}");
            Err(0)
        }
        Err(e) => {
            eprintln!("error: {e}\n{usage}");
            Err(2)
        }
    }
}

fn cmd_train(argv: &[String]) -> i32 {
    let spec = Args::new("bptcnn train", "distributed training on the in-process cluster")
        .opt("network", "quickstart", "network config: quickstart|e2e|case1..case7")
        .opt("update", "agwu", "global weight update strategy: agwu|sgwu")
        .opt("partition", "idpa", "data partitioning: idpa|udpa")
        .opt("nodes", "4", "computing nodes (worker threads)")
        .opt("samples", "2048", "training samples (synthetic dataset)")
        .opt("iterations", "10", "training iterations K")
        .opt("batches", "4", "IDPA batches A")
        .opt("lr", "0.1", "learning rate η (Eq. 23)")
        .opt("seed", "42", "RNG seed")
        .opt("backend", "native", "compute backend: native|xla")
        .opt(
            "staleness",
            "0",
            "pipelined outer layer: max versions a training snapshot may lag (0 = serialized)",
        );
    let usage = spec.usage();
    let p = match handle(spec.parse(argv), &usage) {
        Ok(p) => p,
        Err(c) => return c,
    };
    let run = || -> anyhow::Result<()> {
        let network = parse_network(p.str("network"))?;
        let tc = TrainConfig {
            network,
            update: UpdateStrategy::parse(p.str("update"))?,
            partition: PartitionStrategy::parse(p.str("partition"))?,
            total_samples: p.usize("samples")?,
            iterations: p.usize("iterations")?,
            idpa_batches: p.usize("batches")?,
            learning_rate: p.f64("lr")? as f32,
            seed: p.u64("seed")?,
        };
        let cluster = ClusterConfig::heterogeneous(p.usize("nodes")?, tc.seed ^ 0x5EED)
            .with_staleness(p.usize("staleness")?);
        println!(
            "training {} ({} params) on {} nodes: {} + {}, N={}, K={}{}",
            tc.network.name,
            tc.network.param_count(),
            cluster.size(),
            tc.update.name(),
            tc.partition.name(),
            tc.total_samples,
            tc.iterations,
            if cluster.staleness > 0 {
                format!(", pipelined (staleness {})", cluster.staleness)
            } else {
                String::new()
            }
        );
        let report = match p.str("backend") {
            "native" => bptcnn::outer::train_native(&tc, &cluster),
            "xla" => train_xla(&tc, &cluster)?,
            other => anyhow::bail!("unknown backend '{other}'"),
        };
        let mut t = Table::new("training curve (held-out)", &["version", "t[s]", "loss", "accuracy"]);
        for c in &report.curve {
            t.row(&[
                format!("{}", c.version),
                format!("{:.2}", c.at_s),
                format!("{:.4}", c.loss),
                format!("{:.3}", c.accuracy),
            ]);
        }
        t.print();
        println!(
            "\nfinal accuracy {:.3} | AUC {:.3} | comm {:.2} MB | sync wait {:.2} s | balance {:.3} | wall {:.1} s",
            report.final_accuracy,
            report.accuracy_auc,
            report.comm_mb,
            report.sync_wait_s,
            report.balance_index,
            report.wall_s
        );
        println!("allocations: {:?}", report.allocations);
        println!(
            "comm on critical path (stall) {:.2} s | hidden behind compute (overlap) {:.2} s",
            report.cluster.node_stall_s.iter().sum::<f64>(),
            report.cluster.node_overlap_s.iter().sum::<f64>()
        );
        Ok(())
    };
    exit_on(run())
}

/// XLA-backed training: the artifacts drive every worker through the shared
/// device service (Python is not involved).
fn train_xla(
    tc: &TrainConfig,
    cluster: &ClusterConfig,
) -> anyhow::Result<bptcnn::outer::TrainReport> {
    use bptcnn::outer::worker::LocalTrainer;
    use bptcnn::runtime::{find_model_dir, XlaService, XlaTrainer};
    use std::sync::Arc;

    let dir = find_model_dir(&tc.network.name).ok_or_else(|| {
        anyhow::anyhow!(
            "artifacts for '{}' not found — run `make artifacts` first",
            tc.network.name
        )
    })?;
    let service = XlaService::start(&dir)?;
    // Use the manifest's network config (authoritative for batch shape).
    let network = service.handle().manifest.config.clone();
    let tc = TrainConfig { network: network.clone(), ..tc.clone() };
    let train_ds = Arc::new(bptcnn::data::Dataset::synthetic(
        &network,
        tc.total_samples,
        0.3,
        tc.seed,
    ));
    let eval_ds = bptcnn::data::Dataset::synthetic_split(&network, 256, 0.3, tc.seed, tc.seed ^ 0xEEEE);
    let (schedule, allocations, iterations) = bptcnn::outer::build_schedule(&tc, cluster);
    let slow = bptcnn::outer::slowdown_factors(cluster);
    let workers: Vec<Box<dyn LocalTrainer>> = (0..cluster.size())
        .map(|j| {
            Box::new(
                XlaTrainer::new(service.handle(), Arc::clone(&train_ds), tc.learning_rate)
                    .with_slowdown(slow[j]),
            ) as Box<dyn LocalTrainer>
        })
        .collect();
    let init = service.handle().init_weights(tc.seed as i32)?;
    let eval_handle = service.handle();
    let net2 = network.clone();
    let eval_hook = move |ws: &bptcnn::tensor::WeightSet| -> (f64, f64) {
        let bsz = net2.batch_size;
        let mut loss = 0.0f64;
        let mut correct = 0.0f64;
        let mut batches = 0usize;
        let mut seen = 0usize;
        while seen < eval_ds.len() {
            let (xv, yv, _) = eval_ds.batch(seen, bsz);
            let x = bptcnn::tensor::Tensor::from_vec(
                &[bsz, net2.input_hw, net2.input_hw, net2.in_channels],
                xv,
            );
            let y = bptcnn::tensor::Tensor::from_vec(&[bsz, net2.num_classes], yv);
            let (l, c) = eval_handle.eval_step(ws.clone(), x, y).expect("xla eval");
            loss += l as f64;
            correct += c as f64;
            seen += bsz;
            batches += 1;
        }
        (loss / batches as f64, correct / (batches * bsz) as f64)
    };
    let report = match tc.update {
        UpdateStrategy::Sgwu => {
            bptcnn::outer::run_sgwu(init, workers, &schedule, iterations, Some(&eval_hook))
        }
        UpdateStrategy::Agwu => {
            bptcnn::outer::run_agwu(init, workers, &schedule, iterations, Some(&eval_hook))
        }
    };
    // Package like train_native does.
    let curve: Vec<bptcnn::outer::CurvePoint> = report
        .versions
        .iter()
        .filter_map(|v| {
            v.eval.map(|(loss, accuracy)| bptcnn::outer::CurvePoint {
                version: v.version,
                at_s: v.at_s,
                loss,
                accuracy,
            })
        })
        .collect();
    let final_accuracy = curve.last().map(|c| c.accuracy).unwrap_or(0.0);
    let pts: Vec<(f64, f64)> = curve.iter().map(|c| (c.version as f64, c.accuracy)).collect();
    let span = pts.last().map(|p| p.0).unwrap_or(1.0) - pts.first().map(|p| p.0).unwrap_or(0.0);
    let accuracy_auc = if span > 0.0 {
        bptcnn::util::stats::auc(&pts) / span
    } else {
        final_accuracy
    };
    Ok(bptcnn::outer::TrainReport {
        comm_mb: report.comm.megabytes(),
        sync_wait_s: report.sync_wait_s,
        balance_index: report.balance_index(),
        wall_s: report.wall_s,
        curve,
        allocations,
        final_accuracy,
        accuracy_auc,
        cluster: report,
    })
}

/// Standalone parameter-server process: binds a socket, accepts `--nodes`
/// worker connections (re-admitting reconnects), serves the SGWU/AGWU
/// update rules over the wire protocol, and prints the run's ClusterReport
/// summary at the end. With `--on-failure continue` a dead worker's
/// remaining IDPA batches are re-allocated to the survivors (AGWU) or the
/// round quorum shrinks (SGWU) instead of aborting the run.
fn cmd_param_server(argv: &[String]) -> i32 {
    let spec = Args::new(
        "bptcnn param-server",
        "standalone parameter-server process (outer layer over TCP)",
    )
    .opt(
        "listen",
        "127.0.0.1:7878",
        "bind address; port 0 picks an ephemeral port (the bound address is printed)",
    )
    .opt("network", "quickstart", "network config: quickstart|e2e|case1..case7")
    .opt("update", "sgwu", "global weight update strategy: agwu|sgwu")
    .opt("nodes", "2", "number of worker processes to accept")
    .opt("seed", "42", "RNG seed for the initial weights (share with the workers)")
    .opt("partition", "idpa", "data partitioning: idpa|udpa (must match the workers)")
    .opt("samples", "512", "training samples (must match the workers)")
    .opt("iterations", "4", "training iterations K (must match the workers)")
    .opt("batches", "2", "IDPA batches A (must match the workers)")
    .opt("on-failure", "abort", "worker-death policy: continue|abort")
    .opt("lease-ms", "30000", "per-connection read/write deadline in ms (0 = none)")
    .opt("checkpoint-dir", "", "directory for periodic latest.ckpt weight checkpoints")
    .opt("checkpoint-every", "25", "checkpoint every this many installed versions")
    .opt("role", "primary", "primary serves workers; standby mirrors a primary and promotes itself")
    .opt("standby", "", "primary: replicate committed updates to a warm standby at this address")
    .opt(
        "repl-ack",
        "none",
        "replication consistency: none (async) | standby (hold worker acks until replicated)",
    )
    .opt(
        "repl-snapshot-every",
        "8",
        "async replication: attach a full weight snapshot every this many updates",
    )
    .opt(
        "repl-lease-ms",
        "0",
        "standby: promote after this much primary silence in ms (0 = use --lease-ms)",
    )
    .opt(
        "claim-deadline-ms",
        "10000",
        "promoted standby: give up unless a worker fails over within this window",
    )
    .flag("resume", "restore weights/version from <checkpoint-dir>/latest.ckpt")
    .flag("verbose", "log every installed version")
    .flag(
        "expect-learning",
        "exit nonzero unless the local loss improved first → last (CI smoke)",
    );
    let usage = spec.usage();
    let p = match handle(spec.parse(argv), &usage) {
        Ok(p) => p,
        Err(c) => return c,
    };
    let run = || -> anyhow::Result<()> {
        let network = parse_network(p.str("network"))?;
        let update = UpdateStrategy::parse(p.str("update"))?;
        let nodes = p.usize("nodes")?;
        let listener = std::net::TcpListener::bind(p.str("listen"))?;
        let addr = listener.local_addr()?;
        let mut init = Network::init(&network, p.u64("seed")?).weights;
        let mut init_version = 0usize;
        let mut resumed = false;
        let checkpoint_dir = p.str("checkpoint-dir");
        if p.bool("resume") {
            anyhow::ensure!(!checkpoint_dir.is_empty(), "--resume needs --checkpoint-dir");
            match bptcnn::outer::read_checkpoint(std::path::Path::new(checkpoint_dir)) {
                Ok((version, weights)) => {
                    println!("resuming from checkpoint v{version}");
                    init = weights;
                    init_version = version as usize;
                    resumed = true;
                }
                Err(e) => println!("no usable checkpoint ({e:#}); starting fresh"),
            }
        }
        // Rebuild the per-node IDPA schedule the workers derive from the
        // same flags, so a dead node's remaining batches can be re-allocated.
        let tc = TrainConfig {
            network: network.clone(),
            update,
            partition: PartitionStrategy::parse(p.str("partition"))?,
            total_samples: p.usize("samples")?,
            iterations: p.usize("iterations")?,
            idpa_batches: p.usize("batches")?,
            learning_rate: 0.2, // schedule shape does not depend on η
            seed: p.u64("seed")?,
        };
        let cluster = ClusterConfig::homogeneous(nodes);
        let (schedule, _totals, _iterations) = bptcnn::outer::build_schedule(&tc, &cluster);
        let columns = bptcnn::outer::schedule_columns(&schedule, nodes);
        let role = p.str("role");
        println!(
            "param-server ({role}) listening on {addr} ({nodes} nodes, {}, {} params)",
            update.name(),
            network.param_count()
        );
        // SIGTERM/SIGINT flips this flag; the serve loop drains in-flight
        // submits, writes a final checkpoint, and returns cleanly.
        let shutdown = bptcnn::util::signal::install_shutdown_handler();
        let standby_addr = p.str("standby");
        let opts = bptcnn::outer::ServeOptions {
            nodes,
            update,
            verbose: p.bool("verbose"),
            on_failure: bptcnn::config::OnFailure::parse(p.str("on-failure"))?,
            lease: std::time::Duration::from_millis(p.u64("lease-ms")?),
            checkpoint_dir: (!checkpoint_dir.is_empty())
                .then(|| std::path::PathBuf::from(checkpoint_dir)),
            checkpoint_every: p.usize("checkpoint-every")?,
            init_version,
            resumed,
            schedule: Some(columns),
            standby: (!standby_addr.is_empty()).then(|| standby_addr.to_string()),
            repl_ack: bptcnn::config::ReplAck::parse(p.str("repl-ack"))?,
            repl_snapshot_every: p.usize("repl-snapshot-every")?.max(1),
            shutdown: Some(shutdown),
            ..Default::default()
        };
        let report = match role {
            "primary" => bptcnn::outer::serve(listener, init, opts)?,
            "standby" => {
                let repl_lease_ms = match p.u64("repl-lease-ms")? {
                    0 => p.u64("lease-ms")?,
                    ms => ms,
                };
                let sopts = bptcnn::outer::StandbyOptions {
                    repl_lease: std::time::Duration::from_millis(repl_lease_ms),
                    claim_deadline: std::time::Duration::from_millis(
                        p.u64("claim-deadline-ms")?,
                    ),
                    verbose: p.bool("verbose"),
                    serve: opts,
                };
                match bptcnn::outer::serve_standby(listener, init, sopts)? {
                    bptcnn::outer::StandbyOutcome::PrimaryFinished => {
                        println!("standby: primary finished the run; standing down");
                        return Ok(());
                    }
                    bptcnn::outer::StandbyOutcome::Promoted(report) => report,
                }
            }
            other => anyhow::bail!("unknown role '{other}' (primary|standby)"),
        };
        let mb = 1024.0 * 1024.0;
        println!(
            "run complete: {} versions | comm {:.2} MB logical, {:.2} MB wire | \
             comm wall {:.2} s | sync wait {:.2} s | wall {:.1} s | balance {:.3}",
            report.versions.len(),
            report.comm.megabytes(),
            report.comm.wire_bytes as f64 / mb,
            report.comm.comm_wall_s(),
            report.sync_wait_s,
            report.wall_s,
            report.balance_index()
        );
        if report.fault.any() {
            println!(
                "fault recovery: {} reconnects | {} failovers | {} leases expired | \
                 {} batches ({} samples) re-allocated | {} checkpoints written, {} loaded",
                report.fault.reconnects,
                report.fault.failovers,
                report.fault.leases_expired,
                report.fault.reallocated_batches,
                report.fault.reallocated_samples,
                report.fault.checkpoints_written,
                report.fault.checkpoints_loaded
            );
        }
        match (report.versions.first(), report.versions.last()) {
            (Some(first), Some(last)) => {
                println!(
                    "local loss first {:.4} -> last {:.4}",
                    first.local_loss, last.local_loss
                );
                if p.bool("expect-learning") {
                    anyhow::ensure!(
                        last.local_loss < first.local_loss,
                        "no learning: first loss {:.4}, last {:.4}",
                        first.local_loss,
                        last.local_loss
                    );
                }
            }
            _ => anyhow::ensure!(!p.bool("expect-learning"), "no versions recorded"),
        }
        Ok(())
    };
    exit_on(run())
}

/// Computing-node worker process: regenerates the deterministic dataset and
/// IDPA schedule from the shared flags, connects to the param-server, and
/// drives the fetch → train → submit loop over TCP.
fn cmd_worker(argv: &[String]) -> i32 {
    let spec = Args::new(
        "bptcnn worker",
        "computing-node worker process (connects to a param-server)",
    )
    .opt("connect", "127.0.0.1:7878", "param-server address")
    .opt(
        "servers",
        "",
        "ordered failover list 'primary:port,standby:port' (overrides --connect)",
    )
    .opt("node", "0", "this node's slot index (0..nodes)")
    .opt("nodes", "2", "total computing nodes m (must match the server)")
    .opt("network", "quickstart", "network config: quickstart|e2e|case1..case7")
    .opt("update", "sgwu", "agwu|sgwu (must match the server)")
    .opt("partition", "idpa", "data partitioning: idpa|udpa")
    .opt("samples", "512", "training samples (synthetic dataset; share across workers)")
    .opt("iterations", "4", "training iterations K")
    .opt("batches", "2", "IDPA batches A")
    .opt("lr", "0.2", "learning rate η")
    .opt("seed", "42", "RNG seed (must match the server and peers)")
    .opt("bandwidth-mbs", "0", "throttle: modeled link bandwidth in MB/s (0 = off)")
    .opt("latency-ms", "0", "throttle: modeled link latency in ms")
    .opt(
        "staleness",
        "0",
        "pipeline comm on a background thread; snapshots may lag ≤ s versions (0 = serialized)",
    )
    .opt("retries", "4", "attempts per transport operation (reconnecting between tries)")
    .opt("retry-backoff-ms", "50", "backoff before the first retry; doubles per retry")
    .opt("io-timeout-ms", "30000", "socket read/write deadline in ms (0 = none)")
    .opt("checkpoint-dir", "", "server checkpoint directory (for --resume)")
    .flag("resume", "log the server checkpoint version before connecting")
    .flag("verbose", "log every iteration");
    let usage = spec.usage();
    let p = match handle(spec.parse(argv), &usage) {
        Ok(p) => p,
        Err(c) => return c,
    };
    let run = || -> anyhow::Result<()> {
        let network = parse_network(p.str("network"))?;
        let update = UpdateStrategy::parse(p.str("update"))?;
        let nodes = p.usize("nodes")?;
        let node = p.usize("node")?;
        anyhow::ensure!(node < nodes, "node index {node} out of range for {nodes} nodes");
        let tc = TrainConfig {
            network: network.clone(),
            update,
            partition: PartitionStrategy::parse(p.str("partition"))?,
            total_samples: p.usize("samples")?,
            iterations: p.usize("iterations")?,
            idpa_batches: p.usize("batches")?,
            learning_rate: p.f64("lr")? as f32,
            seed: p.u64("seed")?,
        };
        // Every worker derives the identical dataset and schedule from the
        // shared flags; the homogeneous cluster profile keeps the IDPA
        // schedule independent of local speed calibration across processes.
        let cluster = ClusterConfig::homogeneous(nodes);
        let (schedule, _totals, iterations) = bptcnn::outer::build_schedule(&tc, &cluster);
        let column = bptcnn::outer::schedule_columns(&schedule, nodes).swap_remove(node);
        let ds = std::sync::Arc::new(bptcnn::data::Dataset::synthetic(
            &network,
            tc.total_samples,
            0.3,
            tc.seed,
        ));
        let mut trainer = bptcnn::outer::NativeTrainer::new(&network, ds, tc.learning_rate);
        let mode = match update {
            UpdateStrategy::Sgwu => bptcnn::outer::SubmitMode::Sgwu,
            UpdateStrategy::Agwu => bptcnn::outer::SubmitMode::Agwu,
        };
        // The ordered server list drives worker-side failover: dial the
        // preferred address first, advance to the next on connect failure.
        let addrs: Vec<String> = match p.str("servers") {
            "" => vec![p.str("connect").to_string()],
            list => list
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect(),
        };
        anyhow::ensure!(!addrs.is_empty(), "--servers needs at least one address");
        println!(
            "worker {node}/{nodes} connecting to {} ({}, K={iterations})",
            addrs.join(","),
            update.name()
        );
        if p.bool("resume") {
            // The server owns the training state; a resuming worker only
            // reports which version it expects to rejoin at.
            let dir = p.str("checkpoint-dir");
            anyhow::ensure!(!dir.is_empty(), "--resume needs --checkpoint-dir");
            match bptcnn::outer::read_checkpoint(std::path::Path::new(dir)) {
                Ok((version, _)) => println!("worker {node}: server checkpoint at v{version}"),
                Err(e) => println!("worker {node}: no usable checkpoint ({e:#})"),
            }
        }
        let bw_mbs = p.f64("bandwidth-mbs")?;
        let latency_s = p.f64("latency-ms")? / 1e3;
        let staleness = bptcnn::outer::Staleness(p.usize("staleness")?);
        let verbose = p.bool("verbose");
        let policy = bptcnn::outer::RetryPolicy {
            max_attempts: p.usize("retries")?.max(1),
            base_backoff: std::time::Duration::from_millis(p.u64("retry-backoff-ms")?),
            max_backoff: std::time::Duration::from_secs(2),
        };
        let io_timeout = Some(std::time::Duration::from_millis(p.u64("io-timeout-ms")?));
        // Every (re)connection goes through the same factory: a dead link is
        // re-dialed with the same node id and the server replays the current
        // global snapshot on the first fetch. The shared epoch cell carries
        // the highest observed cluster epoch into each Hello, so a reconnect
        // after a standby promotion registers with (and fences) the right
        // server generation.
        let throttle = (bw_mbs > 0.0)
            .then(|| bptcnn::outer::TransferModel::new(bw_mbs * 1e6, latency_s));
        let servers = bptcnn::outer::ServerList::new(addrs);
        let connect = bptcnn::outer::failover_connect(
            std::sync::Arc::clone(&servers),
            move |addr, epoch_cell| {
                let tcp = bptcnn::outer::TcpTransport::connect_with_epoch(
                    addr,
                    node,
                    io_timeout,
                    Some(epoch_cell),
                )?;
                Ok(match throttle {
                    Some(model) => Box::new(bptcnn::outer::ThrottledTransport::new(tcp, model))
                        as Box<dyn bptcnn::outer::Transport>,
                    None => Box::new(tcp) as Box<dyn bptcnn::outer::Transport>,
                })
            },
        );
        let mut t = bptcnn::outer::RetryingTransport::new(connect, policy).with_servers(servers);
        let summary = bptcnn::outer::drive_worker(
            &mut t, &mut trainer, &column, iterations, mode, staleness, verbose,
        )?;
        let mb = 1024.0 * 1024.0;
        println!(
            "worker {node} done: v{} | loss {:.4} | acc {:.3} | busy {:.2} s | \
             wire {:.2} MB | fetch {:.2} s | submit {:.2} s | connect {:.2} s | \
             stall {:.2} s | overlap {:.2} s | max staleness {} ({} refetches)",
            summary.final_version,
            summary.last_loss,
            summary.last_accuracy,
            summary.busy_s,
            summary.stats.wire_bytes as f64 / mb,
            summary.stats.fetch_wall_s,
            summary.stats.submit_wall_s,
            summary.stats.connect_wall_s,
            summary.stats.stall_wall_s,
            summary.stats.overlap_wall_s,
            summary.max_staleness,
            summary.staleness_refetches
        );
        if summary.stats.fault.any() {
            println!(
                "worker {node} fault recovery: {} retries | {} reconnects | {} failovers",
                summary.stats.fault.retries,
                summary.stats.fault.reconnects,
                summary.stats.fault.failovers
            );
        }
        Ok(())
    };
    exit_on(run())
}

fn cmd_simulate(argv: &[String]) -> i32 {
    let spec = Args::new("bptcnn simulate", "discrete-event cluster simulation")
        .opt("network", "e2e", "network config: quickstart|e2e|case1..case7")
        .opt("update", "agwu", "agwu|sgwu")
        .opt("partition", "idpa", "idpa|udpa")
        .opt("nodes", "30", "cluster size")
        .opt("samples", "100000", "training samples N")
        .opt("iterations", "100", "iterations K")
        .opt("batches", "10", "IDPA batches A")
        .opt("threads", "8", "inner-layer threads per node")
        .opt("seed", "7", "RNG seed");
    let usage = spec.usage();
    let p = match handle(spec.parse(argv), &usage) {
        Ok(p) => p,
        Err(c) => return c,
    };
    let run = || -> anyhow::Result<()> {
        let cfg = SimConfig {
            network: parse_network(p.str("network"))?,
            cluster: ClusterConfig::heterogeneous(p.usize("nodes")?, p.u64("seed")?),
            update: UpdateStrategy::parse(p.str("update"))?,
            partition: PartitionStrategy::parse(p.str("partition"))?,
            samples: p.usize("samples")?,
            iterations: p.usize("iterations")?,
            idpa_batches: p.usize("batches")?,
            threads_per_node: p.usize("threads")?,
            seed: p.u64("seed")?,
        };
        let r = simulate(&cfg);
        println!(
            "{} + {} | {} nodes | N={} K={}",
            cfg.update.name(),
            cfg.partition.name(),
            cfg.cluster.size(),
            cfg.samples,
            cfg.iterations
        );
        println!("  makespan        {:.2} s", r.total_s);
        println!("  sync wait (Eq8) {:.2} s", r.sync_wait_s);
        println!("  comm (Eq11)     {:.2} MB over {:.2} s", r.comm_mb, r.comm_time_s);
        println!("  balance index   {:.3}", r.balance_index);
        println!("  versions        {} (mean staleness {:.2})", r.versions, r.mean_staleness);
        Ok(())
    };
    exit_on(run())
}

fn cmd_experiment(argv: &[String]) -> i32 {
    let spec = Args::new("bptcnn experiment", "regenerate a paper table/figure")
        .opt("id", "all", "fig11|fig12|fig13|fig14|fig15|table1|all")
        .flag("quick", "shrink workloads for a fast smoke run")
        .opt("out", "", "also write the rendered text to this file");
    let usage = spec.usage();
    let p = match handle(spec.parse(argv), &usage) {
        Ok(p) => p,
        Err(c) => return c,
    };
    // Allow positional id: `bptcnn experiment fig12`.
    let id = p
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| p.str("id").to_string());
    let run = || -> anyhow::Result<()> {
        let text = bptcnn::experiments::run(&id, p.bool("quick"))?;
        let out = p.str("out");
        if !out.is_empty() {
            std::fs::write(out, &text)?;
            println!("\n(wrote {out})");
        }
        Ok(())
    };
    exit_on(run())
}

fn cmd_inspect(argv: &[String]) -> i32 {
    let spec = Args::new("bptcnn inspect", "show artifact manifests and configs")
        .opt("network", "e2e", "network name");
    let usage = spec.usage();
    let p = match handle(spec.parse(argv), &usage) {
        Ok(p) => p,
        Err(c) => return c,
    };
    let run = || -> anyhow::Result<()> {
        let name = p.str("network");
        let cfg = parse_network(name)?;
        let mut t = Table::new(
            &format!("network '{}'", cfg.name),
            &["param", "shape", "elements"],
        );
        for (pname, shape) in cfg.param_shapes() {
            let n: usize = shape.iter().product();
            t.row(&[pname, format!("{shape:?}"), format!("{n}")]);
        }
        t.print();
        println!(
            "\ntotal {} params | {} KB weight set | ~{:.1} MFLOPs/sample",
            cfg.param_count(),
            cfg.weight_bytes() / 1024,
            cfg.flops_per_sample() / 1e6
        );
        match bptcnn::runtime::find_model_dir(name) {
            Some(dir) => {
                let m = bptcnn::runtime::ArtifactManifest::load(&dir)?;
                println!("artifacts: {} (validated ✓)", m.dir.display());
            }
            None => println!("artifacts: not built (run `make artifacts`)"),
        }
        Ok(())
    };
    exit_on(run())
}

fn parse_network(name: &str) -> anyhow::Result<NetworkConfig> {
    match name {
        "quickstart" => Ok(NetworkConfig::quickstart()),
        "e2e" => Ok(NetworkConfig::default()),
        other => {
            if let Some(case) = other.strip_prefix("case") {
                let case: usize = case.parse()?;
                anyhow::ensure!((1..=7).contains(&case), "case must be 1..=7");
                Ok(NetworkConfig::table2_case(case))
            } else {
                anyhow::bail!("unknown network '{other}' (quickstart|e2e|case1..case7)")
            }
        }
    }
}

fn exit_on(r: anyhow::Result<()>) -> i32 {
    match r {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}
