//! Online makespan-feedback autotuning for the 2D tile planner.
//!
//! PR 4's [`super::scheduler::plan_tile_grid`] drives every stage from
//! static heuristics: a `2×workers` tile target, MR row fattening, and a
//! per-tile FLOP floor that was hand-eyeballed on one machine. Dryden et
//! al. (arXiv:1903.06681) and Jia et al. (arXiv:1802.04924) both show the
//! best decomposition per layer is configuration-dependent and worth
//! *searching* for. This module closes the loop from measurement to
//! planning in two pieces:
//!
//! * **Startup calibration** ([`Calibration`]): times the packed 4×8
//!   micro-kernel on the calling thread and the per-task dispatch overhead
//!   on the live [`ThreadPool`], then derives the per-tile FLOP floor from
//!   the measured dispatch-cost/compute-rate ratio — a tile must compute
//!   for [`DISPATCH_AMORTIZATION`]× its dispatch cost. The derived floor
//!   replaces the old hard-coded 32 kFLOP constant: the planner reads it
//!   through [`tile_floor_flops`], which falls back to a one-shot serial
//!   estimate (kernel timing × a conservative dispatch guess) before any
//!   pool has been calibrated.
//! * **Online controller** ([`AutoTuner`]): keyed on stage identity
//!   `(kind, M, K, N, workers)` ([`StageKey`]), it records the
//!   [`ScheduleStats`] makespan and `balance_index()` of each executed
//!   grid, explores neighboring grids (±1 row/column split, floor×{½,2}
//!   replans) with a seeded epsilon-greedy/hill-climb policy during early
//!   steps, then locks in the best plan. The cold-start prior is exactly
//!   the static planner's grid, so the first step is never worse than the
//!   PR-4 heuristic; near-ties resolve toward the earliest candidate (the
//!   prior), so measurement noise cannot push a stage off a known-good
//!   plan.
//!
//! Determinism: given a fixed seed and a fixed stream of observed
//! makespans, the sequence of planned grids is reproducible (pinned by a
//! property test) — all randomness flows through one [`Xoshiro256`] stream
//! owned by the tuner.
//!
//! Steady state is allocation-free: once a stage is locked, `plan` is a
//! hash lookup returning a `Copy` grid and `observe` updates scalars in
//! pre-sized candidate slots (pinned by `tests/alloc_regression.rs`). The
//! tuner lives with [`crate::nn::WeightPacks`] on the node
//! ([`crate::nn::Network`] carries one per instance;
//! `crate::outer::NativeTrainer` moves it across per-epoch networks), so
//! tuning state survives as long as the node does.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

use crate::nn::ops::{self, PackedB};
use crate::util::rng::Xoshiro256;
use crate::util::threadpool::ThreadPool;

use super::scheduler::{
    ceil_div, panel_count, plan_cols_for_rows_with_floor, plan_tile_grid_with_floor, ScheduleStats,
    TileGrid,
};

// ---- calibrated per-tile FLOP floor ---------------------------------------

/// Clamp bounds for the calibrated floor: even an implausibly fast dispatch
/// measurement keeps tiles ≥ 4 kFLOP (below that the DAG bookkeeping itself
/// dominates), and even a pathologically slow one keeps the planner willing
/// to split ≥ 512 kFLOP stages (the Table-2 FC shapes must stay splittable).
pub const FLOOR_MIN_FLOPS: usize = 4 * 1024;
pub const FLOOR_MAX_FLOPS: usize = 512 * 1024;

/// A tile must compute for this multiple of its dispatch cost, so dispatch
/// overhead stays a small fraction of the schedule.
const DISPATCH_AMORTIZATION: f64 = 12.0;

/// Dispatch-cost guess used before any pool has been probed (condvar wakeup
/// plus queue push/pop lands in single-digit microseconds).
const FALLBACK_DISPATCH_S: f64 = 4e-6;

/// The process-wide floor the planner's default path reads. 0 ⇒ not yet
/// derived; the first [`tile_floor_flops`] call fills it from a serial
/// estimate, and pool calibration ([`Calibration::install`]) overwrites it.
static TILE_FLOOR_FLOPS: AtomicUsize = AtomicUsize::new(0);

/// The per-tile FLOP floor the planner uses on its default path. Derived,
/// never hard-coded: before any calibration this times the micro-kernel
/// once (serial, cached) and assumes [`FALLBACK_DISPATCH_S`]; after
/// [`Calibration::install`] it is the measured dispatch/compute ratio.
pub fn tile_floor_flops() -> usize {
    let cur = TILE_FLOOR_FLOPS.load(Ordering::Relaxed);
    if cur != 0 {
        return cur;
    }
    static SERIAL_ESTIMATE: OnceLock<usize> = OnceLock::new();
    let est = *SERIAL_ESTIMATE
        .get_or_init(|| derive_floor(measure_kernel_flops_per_s(), FALLBACK_DISPATCH_S));
    // Racy first fill is benign: every racer computed a valid clamped floor.
    let _ = TILE_FLOOR_FLOPS.compare_exchange(0, est, Ordering::Relaxed, Ordering::Relaxed);
    TILE_FLOOR_FLOPS.load(Ordering::Relaxed)
}

/// Publish a calibrated floor (clamped to the sane range) for every
/// subsequent default-path plan.
pub fn set_tile_floor_flops(floor: usize) {
    TILE_FLOOR_FLOPS.store(floor.clamp(FLOOR_MIN_FLOPS, FLOOR_MAX_FLOPS), Ordering::Relaxed);
}

fn derive_floor(flops_per_s: f64, dispatch_s: f64) -> usize {
    ((flops_per_s * dispatch_s * DISPATCH_AMORTIZATION) as usize)
        .clamp(FLOOR_MIN_FLOPS, FLOOR_MAX_FLOPS)
}

/// Time the packed 4×8 micro-kernel on an L1-resident GEMM and return its
/// measured compute rate in FLOP/s (best of several batched reps, so an OS
/// preemption cannot drag the estimate down).
pub fn measure_kernel_flops_per_s() -> f64 {
    let (m, kk, n) = (48usize, 96usize, 64usize);
    let a: Vec<f32> = (0..m * kk).map(|i| (i % 13) as f32 * 0.05 - 0.3).collect();
    let bsrc: Vec<f32> = (0..kk * n).map(|i| (i % 7) as f32 * 0.07 - 0.2).collect();
    let b = PackedB::pack(kk, n, &bsrc);
    let mut c = vec![0.0f32; m * n];
    let flops_per_call = (2 * m * kk * n) as f64;
    ops::gemm_packed_acc(m, &a, &b, &mut c); // warm caches and the pack
    let mut best = f64::INFINITY;
    for _ in 0..5 {
        let t0 = Instant::now();
        for _ in 0..8 {
            ops::gemm_packed_acc(m, &a, &b, &mut c);
        }
        best = best.min(t0.elapsed().as_secs_f64() / 8.0);
    }
    std::hint::black_box(&c);
    (flops_per_call / best.max(1e-9)).max(1.0)
}

/// Result of the one-shot startup calibration on a live pool.
#[derive(Debug, Clone, Copy)]
pub struct Calibration {
    /// Measured micro-kernel compute rate (FLOP/s, single thread).
    pub flops_per_s: f64,
    /// Measured per-task dispatch + wakeup overhead on the pool (seconds).
    pub dispatch_s: f64,
    /// Floor derived from the two: `flops_per_s · dispatch_s ·`
    /// [`DISPATCH_AMORTIZATION`], clamped to
    /// [`FLOOR_MIN_FLOPS`]`..=`[`FLOOR_MAX_FLOPS`].
    pub floor_flops: usize,
}

impl Calibration {
    /// Measure kernel rate and dispatch overhead on `pool`.
    pub fn measure(pool: &ThreadPool) -> Self {
        let flops_per_s = measure_kernel_flops_per_s();
        let dispatch_s = pool.dispatch_overhead_s();
        Calibration { flops_per_s, dispatch_s, floor_flops: derive_floor(flops_per_s, dispatch_s) }
    }

    /// Publish this calibration's floor as the planner's default-path floor.
    pub fn install(&self) {
        set_tile_floor_flops(self.floor_flops);
    }
}

// ---- stage identity --------------------------------------------------------

/// Which GEMM-shaped train-step stage a tuning entry describes. Conv
/// backward splits by whether the stage also computes the input gradient:
/// the dx half roughly doubles the work, so a df-only layer and a df+dx
/// layer with identical `(m, k, n)` must not pool their makespan samples
/// (a min over incommensurate measurements would lock arbitrary grids).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum StageKind {
    ConvFwd,
    /// Conv backward, filter/bias gradients only (the first conv layer).
    ConvBwd,
    /// Conv backward that also produces dx (hidden conv layers).
    ConvBwdDx,
    DenseFwd,
    DenseBwd,
}

impl StageKind {
    pub fn label(&self) -> &'static str {
        match self {
            StageKind::ConvFwd => "conv_fwd",
            StageKind::ConvBwd => "conv_bwd",
            StageKind::ConvBwdDx => "conv_bwd_dx",
            StageKind::DenseFwd => "dense_fwd",
            StageKind::DenseBwd => "dense_bwd",
        }
    }
}

/// Identity of one tunable stage: `(kind, M, K, N, workers)`. `m` is the
/// planned row space (batch rows for dense, batch×H image rows for conv),
/// `k` the contraction length, `n` the output width whose packed panels
/// form the column grain. Same-shaped layers share an entry (and therefore
/// share measurements).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StageKey {
    pub kind: StageKind,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub workers: usize,
}

impl StageKey {
    pub fn new(kind: StageKind, m: usize, k: usize, n: usize, workers: usize) -> Self {
        StageKey { kind, m, k, n, workers }
    }
}

// ---- per-stage controller --------------------------------------------------

/// Measurements wanted per candidate before the hill-climb compares them
/// (best-of-k damps one-sided scheduler noise).
const SAMPLES_PER_CANDIDATE: u32 = 2;
/// Hill-climb rounds: after the initial ring is sampled, neighbors of the
/// current best are expanded at most this many times before locking.
const MAX_HILL_ROUNDS: u32 = 2;
/// Hard cap on tracked candidates per stage (bounds both exploration time
/// and the pre-sized bookkeeping).
const MAX_CANDIDATES: usize = 12;
/// Epsilon-greedy: probability of visiting a random (rather than the next)
/// unsampled candidate during exploration.
const EXPLORE_EPS: f64 = 0.2;
/// Near-tie tolerance when locking: candidates within ~3% of the fastest
/// makespan count as ties and the earliest (the static prior first) wins.
const IMPROVE_TOL: f64 = 0.97;

#[derive(Debug, Clone, Copy)]
struct Candidate {
    grid: TileGrid,
    samples: u32,
    best_s: f64,
}

/// Tuning state of one stage: the candidate ring, the measurement cursor,
/// and the lock flag. Produced and owned by [`AutoTuner`].
#[derive(Debug)]
pub struct StageTuner {
    key: StageKey,
    rows_hint: usize,
    floor: usize,
    candidates: Vec<Candidate>,
    current: usize,
    locked: bool,
    rounds: u32,
    observations: u64,
    last_makespan_s: f64,
    last_balance: f64,
}

impl StageTuner {
    fn new(key: StageKey, rows_hint: usize, floor: usize) -> Self {
        let prior = plan_tile_grid_with_floor(key.m, key.k, key.n, key.workers, rows_hint, floor);
        let mut t = StageTuner {
            key,
            rows_hint,
            floor,
            candidates: vec![Candidate { grid: prior, samples: 0, best_s: f64::INFINITY }],
            current: 0,
            locked: false,
            rounds: 0,
            observations: 0,
            last_makespan_s: 0.0,
            last_balance: 0.0,
        };
        t.add_neighbors(prior);
        t
    }

    /// The grid the stage should execute next (the cold-start value is the
    /// static planner's prior).
    pub fn grid(&self) -> TileGrid {
        self.candidates[self.current].grid
    }

    pub fn locked(&self) -> bool {
        self.locked
    }

    pub fn observations(&self) -> u64 {
        self.observations
    }

    pub fn candidate_count(&self) -> usize {
        self.candidates.len()
    }

    pub fn last_makespan_s(&self) -> f64 {
        self.last_makespan_s
    }

    pub fn last_balance(&self) -> f64 {
        self.last_balance
    }

    /// The best-measured plan so far and its best makespan.
    pub fn best_plan(&self) -> (TileGrid, f64) {
        let i = self.best_index();
        (self.candidates[i].grid, self.candidates[i].best_s)
    }

    fn push_candidate(&mut self, grid: TileGrid) -> bool {
        if self.candidates.len() >= MAX_CANDIDATES
            || grid.rows_per_tile == 0
            || grid.panels_per_tile == 0
            || self.candidates.iter().any(|c| c.grid == grid)
        {
            return false;
        }
        self.candidates.push(Candidate { grid, samples: 0, best_s: f64::INFINITY });
        true
    }

    /// Expand the exploration ring around `g`: ±1 row split, ±1 column
    /// split, and full replans at floor×{½, 2}. Returns how many new
    /// candidates were added (duplicates are dropped).
    fn add_neighbors(&mut self, g: TileGrid) -> usize {
        let StageKey { m, k, n, workers, .. } = self.key;
        let m = m.max(1);
        let panels = panel_count(n);
        let mut added = 0;
        for rt in [g.row_tiles.saturating_sub(1).max(1), (g.row_tiles + 1).min(m)] {
            if rt == g.row_tiles {
                continue;
            }
            let rpt = ceil_div(m, rt);
            let gg =
                plan_cols_for_rows_with_floor(rpt, ceil_div(m, rpt), k, n, workers, self.floor);
            added += usize::from(self.push_candidate(gg));
        }
        for pt in [g.panel_tiles.saturating_sub(1).max(1), (g.panel_tiles + 1).min(panels)] {
            if pt == g.panel_tiles {
                continue;
            }
            let ppt = ceil_div(panels, pt);
            let gg = TileGrid {
                rows_per_tile: g.rows_per_tile,
                row_tiles: g.row_tiles,
                panels_per_tile: ppt,
                panel_tiles: ceil_div(panels, ppt),
            };
            added += usize::from(self.push_candidate(gg));
        }
        for f in [self.floor / 2, self.floor.saturating_mul(2)] {
            let f = f.max(1);
            let gg = plan_tile_grid_with_floor(m, k, n, workers, self.rows_hint, f);
            added += usize::from(self.push_candidate(gg));
        }
        added
    }

    /// Record one execution of the current grid and advance the policy.
    /// Locked stages only update the running scalars (allocation-free).
    fn observe(&mut self, makespan_s: f64, balance: f64, rng: &mut Xoshiro256) {
        self.observations += 1;
        self.last_makespan_s = makespan_s;
        self.last_balance = balance;
        let c = &mut self.candidates[self.current];
        c.samples += 1;
        if makespan_s < c.best_s {
            c.best_s = makespan_s;
        }
        if !self.locked {
            self.advance(rng);
        }
    }

    fn advance(&mut self, rng: &mut Xoshiro256) {
        let unsampled: Vec<usize> = self
            .candidates
            .iter()
            .enumerate()
            .filter(|(_, c)| c.samples < SAMPLES_PER_CANDIDATE)
            .map(|(i, _)| i)
            .collect();
        if !unsampled.is_empty() {
            self.current = if rng.next_f64() < EXPLORE_EPS {
                unsampled[rng.next_below(unsampled.len() as u64) as usize]
            } else {
                unsampled[0]
            };
            return;
        }
        let best = self.best_index();
        if self.rounds < MAX_HILL_ROUNDS {
            self.rounds += 1;
            let g = self.candidates[best].grid;
            if self.add_neighbors(g) > 0 {
                // Sample the freshly added ring next.
                self.current = self
                    .candidates
                    .iter()
                    .position(|c| c.samples < SAMPLES_PER_CANDIDATE)
                    .unwrap_or(best);
                return;
            }
        }
        self.locked = true;
        self.current = best;
    }

    fn best_index(&self) -> usize {
        let min = self.candidates.iter().map(|c| c.best_s).fold(f64::INFINITY, f64::min);
        // Near-ties resolve to the earliest candidate — the static prior is
        // index 0, so noise cannot evict a known-good plan without a real
        // (> ~3%) win.
        self.candidates.iter().position(|c| c.best_s <= min / IMPROVE_TOL).unwrap_or(0)
    }
}

// ---- the node-level tuner --------------------------------------------------

/// Per-stage plan cache + controller (see module docs). One per node;
/// cheap to construct, grows one [`StageTuner`] per distinct [`StageKey`].
#[derive(Debug)]
pub struct AutoTuner {
    stages: HashMap<StageKey, StageTuner>,
    rng: Xoshiro256,
    calibration: Option<Calibration>,
}

impl Default for AutoTuner {
    fn default() -> Self {
        Self::new(0xb17a_7e55)
    }
}

impl AutoTuner {
    pub fn new(seed: u64) -> Self {
        AutoTuner { stages: HashMap::new(), rng: Xoshiro256::new(seed), calibration: None }
    }

    /// One-shot startup calibration on the live pool: measures the kernel
    /// rate + dispatch overhead, installs the derived FLOP floor as the
    /// planner default, and remembers the result. Idempotent.
    pub fn ensure_calibrated(&mut self, pool: &ThreadPool) -> Calibration {
        if let Some(c) = self.calibration {
            return c;
        }
        let c = Calibration::measure(pool);
        c.install();
        self.calibration = Some(c);
        c
    }

    pub fn calibration(&self) -> Option<Calibration> {
        self.calibration
    }

    /// The grid to execute for `key` this step. First sight of a key seeds
    /// its controller with the static planner's grid as the prior, so a
    /// cold tuner is exactly the PR-4 heuristic.
    pub fn plan(&mut self, key: StageKey, rows_hint: usize) -> TileGrid {
        let floor = tile_floor_flops();
        self.stages.entry(key).or_insert_with(|| StageTuner::new(key, rows_hint, floor)).grid()
    }

    /// Feed one executed stage's measured stats back into its controller.
    pub fn observe(&mut self, key: StageKey, stats: &ScheduleStats) {
        self.observe_raw(key, stats.makespan_s, stats.balance_index());
    }

    /// Measurement-injection form of [`AutoTuner::observe`]; determinism
    /// tests use it to feed synthetic makespan streams.
    pub fn observe_raw(&mut self, key: StageKey, makespan_s: f64, balance: f64) {
        if let Some(st) = self.stages.get_mut(&key) {
            st.observe(makespan_s, balance, &mut self.rng);
        }
    }

    pub fn stage(&self, key: &StageKey) -> Option<&StageTuner> {
        self.stages.get(key)
    }

    pub fn len(&self) -> usize {
        self.stages.len()
    }

    pub fn is_empty(&self) -> bool {
        self.stages.is_empty()
    }

    pub fn all_locked(&self) -> bool {
        !self.stages.is_empty() && self.stages.values().all(|s| s.locked)
    }

    /// Render the per-stage tuning table (debugging / CI logs): stage
    /// identity, current plan, lock state, best makespan and last measured
    /// thread-level balance index.
    pub fn table(&self) -> String {
        let mut keys: Vec<&StageKey> = self.stages.keys().collect();
        keys.sort();
        let mut out = String::new();
        let floor = TILE_FLOOR_FLOPS.load(Ordering::Relaxed);
        out.push_str(&format!(
            "per-stage tuning table (floor = {} FLOPs{}):\n",
            floor,
            match self.calibration {
                Some(c) => format!(
                    ", calibrated: {:.2} GFLOP/s kernel, {:.2} µs dispatch",
                    c.flops_per_s / 1e9,
                    c.dispatch_s * 1e6
                ),
                None => String::from(", uncalibrated"),
            }
        ));
        out.push_str(
            "stage       m      k      n      w  | plan rows×panels (rpt,ppt) | state    | best ms  | balance | obs\n",
        );
        for key in keys {
            let st = &self.stages[key];
            let (g, best) = st.best_plan();
            out.push_str(&format!(
                "{:<10} {:<6} {:<6} {:<6} {:<2} | {:>3}×{:<3} ({:>4},{:<4})       | {:<8} | {:>8.4} | {:>7.3} | {}\n",
                key.kind.label(),
                key.m,
                key.k,
                key.n,
                key.workers,
                g.row_tiles,
                g.panel_tiles,
                g.rows_per_tile,
                g.panels_per_tile,
                if st.locked { "locked" } else { "explore" },
                if best.is_finite() { best * 1e3 } else { f64::NAN },
                st.last_balance,
                st.observations,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inner::scheduler::plan_tile_grid;
    use std::sync::Mutex;

    /// Serializes tests that mutate (or assert exact values of) the
    /// process-wide floor — every other test only relies on the floor
    /// staying inside the clamp band, which mutation preserves.
    static FLOOR_LOCK: Mutex<()> = Mutex::new(());

    fn floor_lock() -> std::sync::MutexGuard<'static, ()> {
        FLOOR_LOCK.lock().unwrap_or_else(|p| p.into_inner())
    }

    #[test]
    fn floor_derivation_clamps_both_ways() {
        assert_eq!(derive_floor(1e12, 1.0), FLOOR_MAX_FLOPS);
        assert_eq!(derive_floor(1.0, 1e-12), FLOOR_MIN_FLOPS);
        // A plausible mid-range machine: 10 GFLOP/s kernel, 2 µs dispatch
        // → 240 kFLOP, inside the clamp band.
        let mid = derive_floor(10e9, 2e-6);
        assert!((FLOOR_MIN_FLOPS..=FLOOR_MAX_FLOPS).contains(&mid), "{mid}");
    }

    #[test]
    fn global_floor_is_derived_and_settable() {
        let _g = floor_lock();
        let f = tile_floor_flops();
        assert!((FLOOR_MIN_FLOPS..=FLOOR_MAX_FLOPS).contains(&f), "{f}");
        // set_* clamps; restore the derived value afterwards (the global is
        // process-wide and other tests plan through it).
        set_tile_floor_flops(1);
        assert_eq!(tile_floor_flops(), FLOOR_MIN_FLOPS);
        set_tile_floor_flops(usize::MAX);
        assert_eq!(tile_floor_flops(), FLOOR_MAX_FLOPS);
        set_tile_floor_flops(f);
    }

    #[test]
    fn kernel_measurement_is_positive() {
        let r = measure_kernel_flops_per_s();
        assert!(r > 1e6, "implausible kernel rate {r}");
    }

    #[test]
    fn pool_calibration_installs_floor() {
        let _g = floor_lock();
        let pool = ThreadPool::new(2);
        let c = Calibration::measure(&pool);
        assert!(c.dispatch_s > 0.0);
        assert!(c.flops_per_s > 0.0);
        assert!((FLOOR_MIN_FLOPS..=FLOOR_MAX_FLOPS).contains(&c.floor_flops));
        let before = tile_floor_flops();
        c.install();
        assert_eq!(tile_floor_flops(), c.floor_flops);
        set_tile_floor_flops(before);
    }

    #[test]
    fn cold_start_plan_is_the_static_prior() {
        let _g = floor_lock();
        let mut t = AutoTuner::new(1);
        let key = StageKey::new(StageKind::DenseFwd, 4, 2000, 2000, 8);
        let g = t.plan(key, 1);
        assert_eq!(g, plan_tile_grid(4, 2000, 2000, 8, 1));
        // Unobserved stages keep returning the prior.
        assert_eq!(t.plan(key, 1), g);
    }

    /// Feed a deterministic synthetic makespan that favors one specific
    /// neighbor; the tuner must lock onto it (and stay there).
    #[test]
    fn tuner_locks_onto_fed_optimum() {
        // Cost model: strictly increasing in the distance from 24 tiles, so
        // the 24-tile candidate (if ever proposed) or the closest supply
        // wins; deterministic, so the lock must minimize it.
        fn cost(g: &TileGrid) -> f64 {
            1e-3 * ((g.tiles() as f64 - 24.0).abs() + 1.0)
        }
        let mut t = AutoTuner::new(3);
        let key = StageKey::new(StageKind::DenseFwd, 4, 2000, 2000, 8);
        let mut seen = Vec::new();
        for _ in 0..200 {
            let g = t.plan(key, 1);
            seen.push(g);
            t.observe_raw(key, cost(&g), 1.0);
            if t.stage(&key).unwrap().locked() {
                break;
            }
        }
        let st = t.stage(&key).unwrap();
        assert!(st.locked(), "never locked after {} observations", st.observations());
        let locked = t.plan(key, 1);
        let best_seen = seen.iter().map(cost).fold(f64::INFINITY, f64::min);
        assert!(
            cost(&locked) <= best_seen / IMPROVE_TOL,
            "locked onto {locked:?} (cost {}), best explored cost {}",
            cost(&locked),
            best_seen
        );
        // Locked: plan is stable and further observes don't move it.
        for _ in 0..10 {
            t.observe_raw(key, cost(&locked), 1.0);
            assert_eq!(t.plan(key, 1), locked);
        }
    }

    /// The explored candidate set includes real neighbors of the prior, not
    /// just the prior itself.
    #[test]
    fn exploration_ring_contains_neighbors() {
        let mut t = AutoTuner::new(5);
        let key = StageKey::new(StageKind::DenseFwd, 4, 2000, 2000, 8);
        let prior = t.plan(key, 1);
        let mut distinct = std::collections::HashSet::new();
        for _ in 0..60 {
            let g = t.plan(key, 1);
            distinct.insert((g.rows_per_tile, g.row_tiles, g.panels_per_tile, g.panel_tiles));
            t.observe_raw(key, 1.0, 1.0);
        }
        assert!(distinct.len() > 1, "only explored the prior {prior:?}");
        let st = t.stage(&key).unwrap();
        assert!(st.candidate_count() > 1);
        assert!(st.candidate_count() <= MAX_CANDIDATES);
    }

    /// Stages too small to ever split still work: the candidate ring may
    /// collapse to a single grid, which locks immediately.
    #[test]
    fn degenerate_stage_locks_on_single_candidate() {
        let mut t = AutoTuner::new(7);
        // n = 1 → a single panel; m = 1 → a single row tile.
        let key = StageKey::new(StageKind::DenseBwd, 1, 4, 1, 4);
        for _ in 0..40 {
            let g = t.plan(key, 1);
            assert!(g.rows_per_tile >= 1 && g.panels_per_tile >= 1);
            t.observe_raw(key, 1e-5, 1.0);
            if t.stage(&key).unwrap().locked() {
                break;
            }
        }
        assert!(t.stage(&key).unwrap().locked());
    }

    #[test]
    fn table_renders_every_stage() {
        let mut t = AutoTuner::new(9);
        let k1 = StageKey::new(StageKind::ConvFwd, 64, 72, 8, 4);
        let k2 = StageKey::new(StageKind::DenseFwd, 8, 128, 64, 4);
        t.plan(k1, 8);
        t.plan(k2, 1);
        t.observe_raw(k1, 1e-4, 0.9);
        let table = t.table();
        assert!(table.contains("conv_fwd"), "{table}");
        assert!(table.contains("dense_fwd"), "{table}");
        assert_eq!(t.len(), 2);
        assert!(!t.all_locked());
    }
}
