//! Task DAG for the inner-layer parallelism (§4.2(1)).
//!
//! Computation steps of a CNN subnetwork's training pass are decomposed into
//! subtasks "depending upon their logical and data dependence" (Fig. 9); the
//! resulting graph is a DAG whose levels drive priority marking.

use std::collections::VecDeque;

/// Task identifier within one [`TaskDag`].
pub type TaskId = usize;

/// A node in the task DAG. The payload is opaque to the graph; the scheduler
/// receives it when the task is dispatched.
#[derive(Debug)]
pub struct TaskNode<P> {
    pub id: TaskId,
    pub label: String,
    pub payload: P,
    /// Tasks that must complete before this one starts (data dependence).
    pub deps: Vec<TaskId>,
    /// Estimated cost (arbitrary units) for load-balanced assignment.
    pub cost: f64,
}

/// A directed acyclic graph of tasks.
#[derive(Debug, Default)]
pub struct TaskDag<P> {
    nodes: Vec<TaskNode<P>>,
}

impl<P> TaskDag<P> {
    pub fn new() -> Self {
        Self { nodes: Vec::new() }
    }

    /// Add a task with the given dependencies; returns its id.
    /// Dependencies must already exist (ids are created in topological
    /// insertion order, which makes cycles unrepresentable by construction).
    pub fn add(&mut self, label: impl Into<String>, cost: f64, deps: &[TaskId], payload: P) -> TaskId {
        let id = self.nodes.len();
        for &d in deps {
            assert!(d < id, "dependency {d} does not exist yet (inserting {id})");
        }
        self.nodes.push(TaskNode {
            id,
            label: label.into(),
            payload,
            deps: deps.to_vec(),
            cost,
        });
        id
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node(&self, id: TaskId) -> &TaskNode<P> {
        &self.nodes[id]
    }

    pub fn nodes(&self) -> &[TaskNode<P>] {
        &self.nodes
    }

    pub fn into_nodes(self) -> Vec<TaskNode<P>> {
        self.nodes
    }

    /// Downstream adjacency: for each task, the tasks that depend on it.
    pub fn dependents(&self) -> Vec<Vec<TaskId>> {
        let mut out = vec![Vec::new(); self.nodes.len()];
        for node in &self.nodes {
            for &d in &node.deps {
                out[d].push(node.id);
            }
        }
        out
    }

    /// DAG level of each task: level 0 = entry tasks, level of a task =
    /// 1 + max(level of deps). Drives §4.2's priority marking ("upstream
    /// tasks' priorities are higher than that of downstream tasks").
    pub fn levels(&self) -> Vec<usize> {
        let mut levels = vec![0usize; self.nodes.len()];
        for node in &self.nodes {
            let lvl = node
                .deps
                .iter()
                .map(|&d| levels[d] + 1)
                .max()
                .unwrap_or(0);
            levels[node.id] = lvl;
        }
        levels
    }

    /// Length of the critical path through the DAG in cost units — the lower
    /// bound on parallel makespan (§4.2's "waiting time of critical paths").
    pub fn critical_path_cost(&self) -> f64 {
        let mut finish = vec![0.0f64; self.nodes.len()];
        for node in &self.nodes {
            let start = node
                .deps
                .iter()
                .map(|&d| finish[d])
                .fold(0.0f64, f64::max);
            finish[node.id] = start + node.cost;
        }
        finish.iter().copied().fold(0.0, f64::max)
    }

    pub fn total_cost(&self) -> f64 {
        self.nodes.iter().map(|n| n.cost).sum()
    }

    /// Kahn topological order (sanity / test helper; insertion order is
    /// already topological by construction).
    pub fn topological_order(&self) -> Vec<TaskId> {
        let mut indeg: Vec<usize> = self.nodes.iter().map(|n| n.deps.len()).collect();
        let dependents = self.dependents();
        let mut queue: VecDeque<TaskId> = indeg
            .iter()
            .enumerate()
            .filter(|(_, &d)| d == 0)
            .map(|(i, _)| i)
            .collect();
        let mut order = Vec::with_capacity(self.nodes.len());
        while let Some(id) = queue.pop_front() {
            order.push(id);
            for &dep in &dependents[id] {
                indeg[dep] -= 1;
                if indeg[dep] == 0 {
                    queue.push_back(dep);
                }
            }
        }
        assert_eq!(order.len(), self.nodes.len(), "cycle detected");
        order
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> TaskDag<u32> {
        // a → b, a → c, {b,c} → d
        let mut dag = TaskDag::new();
        let a = dag.add("a", 1.0, &[], 0);
        let b = dag.add("b", 2.0, &[a], 1);
        let c = dag.add("c", 3.0, &[a], 2);
        let _d = dag.add("d", 1.0, &[b, c], 3);
        dag
    }

    #[test]
    fn levels_of_diamond() {
        assert_eq!(diamond().levels(), vec![0, 1, 1, 2]);
    }

    #[test]
    fn dependents_inverse_of_deps() {
        let dag = diamond();
        let deps = dag.dependents();
        assert_eq!(deps[0], vec![1, 2]);
        assert_eq!(deps[1], vec![3]);
        assert_eq!(deps[2], vec![3]);
        assert!(deps[3].is_empty());
    }

    #[test]
    fn critical_path_of_diamond() {
        // a(1) → c(3) → d(1) = 5.
        assert!((diamond().critical_path_cost() - 5.0).abs() < 1e-12);
        assert!((diamond().total_cost() - 7.0).abs() < 1e-12);
    }

    #[test]
    fn topological_order_respects_deps() {
        let dag = diamond();
        let order = dag.topological_order();
        let pos: Vec<usize> = (0..4).map(|i| order.iter().position(|&x| x == i).unwrap()).collect();
        assert!(pos[0] < pos[1] && pos[0] < pos[2]);
        assert!(pos[1] < pos[3] && pos[2] < pos[3]);
    }

    #[test]
    #[should_panic(expected = "does not exist")]
    fn forward_references_rejected() {
        let mut dag: TaskDag<()> = TaskDag::new();
        dag.add("bad", 1.0, &[5], ());
    }

    #[test]
    fn empty_dag() {
        let dag: TaskDag<()> = TaskDag::new();
        assert!(dag.is_empty());
        assert_eq!(dag.critical_path_cost(), 0.0);
        assert!(dag.topological_order().is_empty());
    }
}
