//! Priority task scheduling — Algorithm 4.2.
//!
//! Tasks are taken in priority order (upstream first, §4.2(1)); a task whose
//! dependencies are incomplete makes the dispatcher *wait* (Alg 4.2 line 7);
//! ready tasks are assigned to the thread with minimal accumulated workload
//! (line 8). Execution happens on [`ThreadPool`] workers via their pinned
//! per-thread queues, so "assignment to thread k" is real, not advisory.
//!
//! Dispatch is **zero-copy**: the runner (and the task payloads) may borrow
//! the caller's tensors directly — `execute_dag` blocks until every
//! dispatched task has completed (even on unwind, via a completion guard), so
//! no borrow can escape the call. The runner also receives the index of the
//! worker a task was assigned to, which is how conv tasks reach that worker's
//! persistent [`crate::util::threadpool::ScratchArena`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Instant;

use crate::util::stats;
use crate::util::threadpool::ThreadPool;

use super::dag::TaskDag;
use super::priority::priority_order;

/// Outcome of one DAG execution.
#[derive(Debug, Clone)]
pub struct ScheduleStats {
    /// Wall-clock seconds from first dispatch to last completion.
    pub makespan_s: f64,
    /// Busy seconds per worker thread (measured, not estimated).
    pub thread_busy_s: Vec<f64>,
    /// Estimated cost assigned per thread (the quantity Alg 4.2 balances).
    pub thread_assigned_cost: Vec<f64>,
    pub tasks: usize,
}

impl ScheduleStats {
    /// Balance index over measured busy time (Fig. 15b metric, applied to
    /// threads instead of nodes).
    pub fn balance_index(&self) -> f64 {
        stats::balance_index(&self.thread_busy_s)
    }

    /// Balance index over assigned cost.
    pub fn assigned_balance_index(&self) -> f64 {
        stats::balance_index(&self.thread_assigned_cost)
    }
}

struct DoneState {
    /// Per-task completion flags (dependency waits key off these). A
    /// panicked task is also marked done so dependents and the barrier can
    /// make progress; the panic is re-raised on the dispatching thread.
    flags: Vec<bool>,
    /// Number of completed tasks (the completion barrier keys off this).
    completed: usize,
    /// First panic payload caught in a task, re-thrown after the barrier.
    panic: Option<Box<dyn std::any::Any + Send>>,
}

struct DispatchState {
    done: Mutex<DoneState>,
    cv: Condvar,
}

/// Poison-tolerant lock: task panics are caught inside the job (they never
/// unwind through this mutex), but tolerate poisoning anyway so the
/// completion guard can always observe the counters instead of
/// double-panicking.
fn lock(m: &Mutex<DoneState>) -> MutexGuard<'_, DoneState> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

fn wait<'a>(cv: &Condvar, g: MutexGuard<'a, DoneState>) -> MutexGuard<'a, DoneState> {
    cv.wait(g).unwrap_or_else(|p| p.into_inner())
}

/// Blocks (on drop) until every job dispatched so far has completed. This is
/// what makes borrowed task payloads sound: even if the dispatch loop
/// unwinds, no borrow of the `execute_dag` frame can outlive the frame.
struct CompletionGuard {
    state: Arc<DispatchState>,
    dispatched: usize,
}

impl Drop for CompletionGuard {
    fn drop(&mut self) {
        let mut g = lock(&self.state.done);
        while g.completed < self.dispatched {
            g = wait(&self.state.cv, g);
        }
    }
}

/// Execute a task DAG per Algorithm 4.2. `runner` is invoked as
/// `runner(worker, payload)` on the assigned worker thread; `worker` indexes
/// the pool's workers (and their scratch arenas). Payloads and the runner may
/// borrow caller data — `execute_dag` returns only after all tasks finished.
pub fn execute_dag<'env, P, F>(pool: &ThreadPool, dag: TaskDag<P>, runner: F) -> ScheduleStats
where
    P: Send + Sync + 'env,
    F: Fn(usize, &P) + Send + Sync + 'env,
{
    let n = dag.len();
    let order = priority_order(&dag);
    let nodes = dag.into_nodes();
    let state = Arc::new(DispatchState {
        done: Mutex::new(DoneState { flags: vec![false; n], completed: 0, panic: None }),
        cv: Condvar::new(),
    });
    let busy_ns: Arc<Vec<AtomicU64>> =
        Arc::new((0..pool.size()).map(|_| AtomicU64::new(0)).collect());
    let mut assigned = vec![0.0f64; pool.size()];
    // Declared after `nodes`/`assigned` so it drops (and thus waits) first.
    let mut completion = CompletionGuard { state: Arc::clone(&state), dispatched: 0 };

    let t0 = Instant::now();
    for &tid in &order {
        // Line 5–7: wait until every dependency of the top task is complete.
        {
            let mut guard = lock(&state.done);
            while !nodes[tid].deps.iter().all(|&d| guard.flags[d]) {
                guard = wait(&state.cv, guard);
            }
        }
        // Line 8: thread with minimal (assigned) workload.
        let k = assigned
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        assigned[k] += nodes[tid].cost;
        // Line 9: assignment. The job borrows `nodes` and `runner` from this
        // frame — no Arc clones of payload data.
        let node = &nodes[tid];
        let runner_ref = &runner;
        let state2 = Arc::clone(&state);
        let busy2 = Arc::clone(&busy_ns);
        let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
            let start = Instant::now();
            // Catch task panics so the worker thread, the pool's inflight
            // accounting and this DAG's completion barrier all stay intact;
            // the payload is re-thrown on the dispatching thread below.
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                runner_ref(k, &node.payload);
            }));
            busy2[k].fetch_add(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
            let mut guard = lock(&state2.done);
            guard.flags[tid] = true;
            guard.completed += 1;
            if let Err(payload) = result {
                guard.panic.get_or_insert(payload);
            }
            state2.cv.notify_all();
        });
        // SAFETY: the completion guard (and the barrier below) guarantee the
        // job finishes before this frame — hence before `nodes`, `runner`
        // and anything the payloads borrow — is invalidated.
        unsafe { pool.execute_on_borrowed(k, job) };
        completion.dispatched += 1;
    }
    // Wait for all tasks to complete; re-raise the first task panic here on
    // the dispatching thread (after the barrier, so borrows stay sound).
    {
        let mut guard = lock(&state.done);
        while guard.completed != n {
            guard = wait(&state.cv, guard);
        }
        if let Some(payload) = guard.panic.take() {
            drop(guard);
            std::panic::resume_unwind(payload);
        }
    }
    let makespan = t0.elapsed().as_secs_f64();
    ScheduleStats {
        makespan_s: makespan,
        thread_busy_s: busy_ns
            .iter()
            .map(|a| a.load(Ordering::Relaxed) as f64 / 1e9)
            .collect(),
        thread_assigned_cost: assigned,
        tasks: n,
    }
}

/// Sequential baseline: run tasks in topological (insertion) order on the
/// calling thread. Used by the ablation benches to measure scheduling
/// overhead and speedup.
pub fn execute_sequential<P, F>(dag: TaskDag<P>, runner: F) -> f64
where
    F: Fn(&P),
{
    let t0 = Instant::now();
    for node in dag.nodes() {
        runner(&node.payload);
    }
    t0.elapsed().as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    /// Build a random layered DAG and check the scheduler never violates
    /// dependency order.
    #[test]
    fn execution_respects_dependencies() {
        let pool = ThreadPool::new(4);
        let mut dag: TaskDag<usize> = TaskDag::new();
        // 3 layers of 8 tasks, each depending on 2 tasks of the previous.
        let mut prev: Vec<usize> = Vec::new();
        let mut all = Vec::new();
        for layer in 0..3 {
            let mut cur = Vec::new();
            for i in 0..8 {
                let deps: Vec<usize> = if layer == 0 {
                    vec![]
                } else {
                    vec![prev[i % prev.len()], prev[(i + 3) % prev.len()]]
                };
                let id = dag.add(format!("t{layer}_{i}"), 1.0, &deps, all.len());
                cur.push(id);
                all.push(id);
            }
            prev = cur;
        }
        // Record completion order.
        let n = dag.len();
        let seq = Arc::new(AtomicUsize::new(0));
        let finish_pos: Arc<Vec<AtomicUsize>> =
            Arc::new((0..n).map(|_| AtomicUsize::new(usize::MAX)).collect());
        let deps_snapshot: Vec<Vec<usize>> =
            dag.nodes().iter().map(|nd| nd.deps.clone()).collect();
        {
            let seq = Arc::clone(&seq);
            let fp = Arc::clone(&finish_pos);
            execute_dag(&pool, dag, move |_, &tid| {
                let p = seq.fetch_add(1, Ordering::SeqCst);
                fp[tid].store(p, Ordering::SeqCst);
            });
        }
        for (tid, deps) in deps_snapshot.iter().enumerate() {
            let my = finish_pos[tid].load(Ordering::SeqCst);
            for &d in deps {
                let dp = finish_pos[d].load(Ordering::SeqCst);
                assert!(dp < my, "task {tid} (pos {my}) finished before dep {d} (pos {dp})");
            }
        }
    }

    #[test]
    fn all_tasks_run_exactly_once() {
        let pool = ThreadPool::new(3);
        let mut dag: TaskDag<usize> = TaskDag::new();
        for i in 0..50 {
            let deps = if i >= 10 { vec![i - 10] } else { vec![] };
            dag.add("t", 1.0, &deps, i);
        }
        let counts: Arc<Vec<AtomicUsize>> =
            Arc::new((0..50).map(|_| AtomicUsize::new(0)).collect());
        let c2 = Arc::clone(&counts);
        let stats = execute_dag(&pool, dag, move |_, &i| {
            c2[i].fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(stats.tasks, 50);
        for c in counts.iter() {
            assert_eq!(c.load(Ordering::SeqCst), 1);
        }
    }

    /// The runner's worker index matches the worker the task actually ran on
    /// (pinned queues) — the invariant the per-worker arenas rely on.
    #[test]
    fn worker_index_matches_executing_thread() {
        let pool = ThreadPool::new(3);
        // Map each worker index to the thread id observed running it.
        let seen: Arc<Mutex<std::collections::HashMap<usize, Vec<std::thread::ThreadId>>>> =
            Arc::new(Mutex::new(std::collections::HashMap::new()));
        let mut dag: TaskDag<usize> = TaskDag::new();
        for i in 0..48 {
            dag.add("t", 1.0, &[], i);
        }
        let s2 = Arc::clone(&seen);
        execute_dag(&pool, dag, move |worker, _| {
            s2.lock()
                .unwrap()
                .entry(worker)
                .or_default()
                .push(std::thread::current().id());
        });
        let seen = seen.lock().unwrap();
        for ids in seen.values() {
            assert!(ids.iter().all(|&id| id == ids[0]), "one worker index, several threads");
        }
        // Distinct worker indices ran on distinct threads.
        let firsts: Vec<_> = seen.values().map(|v| v[0]).collect();
        let mut dedup = firsts.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), firsts.len());
    }

    /// Tasks may borrow caller-local data (zero-copy dispatch).
    #[test]
    fn tasks_borrow_caller_data() {
        let pool = ThreadPool::new(2);
        let data: Vec<u64> = (0..100).collect();
        let total = std::sync::atomic::AtomicU64::new(0);
        let mut dag: TaskDag<usize> = TaskDag::new();
        for i in 0..data.len() {
            dag.add("t", 1.0, &[], i);
        }
        let d: &[u64] = &data;
        let t = &total;
        execute_dag(&pool, dag, move |_, &i| {
            t.fetch_add(d[i], Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), 99 * 100 / 2);
    }

    /// A panicking task must not deadlock the barrier or wedge the pool: the
    /// panic is re-raised on the dispatching thread and the pool stays
    /// usable for the next DAG.
    #[test]
    fn panicking_task_propagates_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let mut dag: TaskDag<usize> = TaskDag::new();
        for i in 0..8 {
            dag.add("t", 1.0, &[], i);
        }
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            execute_dag(&pool, dag, |_, &i| {
                if i == 3 {
                    panic!("task 3 exploded");
                }
            })
        }));
        assert!(res.is_err(), "task panic was swallowed");
        // Pool and scheduler still fully functional afterwards.
        let mut dag2: TaskDag<usize> = TaskDag::new();
        for i in 0..4 {
            dag2.add("t", 1.0, &[], i);
        }
        let stats = execute_dag(&pool, dag2, |_, _| {});
        assert_eq!(stats.tasks, 4);
        pool.wait_idle();
    }

    #[test]
    fn assigned_cost_is_balanced_for_uniform_independent_tasks() {
        let pool = ThreadPool::new(4);
        let mut dag: TaskDag<()> = TaskDag::new();
        for _ in 0..64 {
            dag.add("t", 1.0, &[], ());
        }
        let stats = execute_dag(&pool, dag, |_, _| {});
        // 64 equal tasks over 4 threads → exactly 16 cost units each.
        assert!(stats.assigned_balance_index() > 0.99, "{:?}", stats.thread_assigned_cost);
    }

    #[test]
    fn heavier_tasks_spread_by_cost() {
        let pool = ThreadPool::new(2);
        let mut dag: TaskDag<()> = TaskDag::new();
        // One big task (cost 3) + three small (cost 1) → 3 | 1+1+1 split.
        dag.add("big", 3.0, &[], ());
        for _ in 0..3 {
            dag.add("small", 1.0, &[], ());
        }
        let stats = execute_dag(&pool, dag, |_, _| {});
        let mut costs = stats.thread_assigned_cost.clone();
        costs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(costs, vec![3.0, 3.0]);
    }

    #[test]
    fn sequential_runs_everything() {
        let mut dag: TaskDag<usize> = TaskDag::new();
        for i in 0..10 {
            dag.add("t", 1.0, &[], i);
        }
        let count = std::cell::Cell::new(0usize);
        execute_sequential(dag, |_| count.set(count.get() + 1));
        assert_eq!(count.get(), 10);
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = ThreadPool::new(1);
        let mut dag: TaskDag<usize> = TaskDag::new();
        let a = dag.add("a", 1.0, &[], 0);
        dag.add("b", 1.0, &[a], 1);
        let stats = execute_dag(&pool, dag, |_, _| {});
        assert_eq!(stats.tasks, 2);
        assert_eq!(stats.thread_assigned_cost.len(), 1);
    }
}
