//! Priority task scheduling — Algorithm 4.2.
//!
//! Tasks are taken in priority order (upstream first, §4.2(1)); a task whose
//! dependencies are incomplete makes the dispatcher *wait* (Alg 4.2 line 7);
//! ready tasks are assigned to the thread with minimal accumulated workload
//! (line 8). Execution happens on [`ThreadPool`] workers via their pinned
//! per-thread queues, so "assignment to thread k" is real, not advisory.
//!
//! Dispatch is **zero-copy**: the runner (and the task payloads) may borrow
//! the caller's tensors directly — `execute_dag` blocks until every
//! dispatched task has completed (even on unwind, via a completion guard), so
//! no borrow can escape the call. The runner also receives the index of the
//! worker a task was assigned to, which is how conv tasks reach that worker's
//! persistent [`crate::util::threadpool::ScratchArena`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Instant;

use crate::nn::ops::{MR, NR};
use crate::util::stats;
use crate::util::threadpool::ThreadPool;

use super::dag::TaskDag;
use super::priority::priority_order;

// ---- 2D row×column tile planning ------------------------------------------

/// Tiles per worker the planner aims for: enough slack for Algorithm 4.2's
/// least-loaded assignment to balance uneven tiles, small enough that
/// dispatch overhead stays amortized.
pub const TILE_TARGET_PER_WORKER: usize = 2;

/// `⌈n/NR⌉` — the packed-B panel count of an `n`-column stage (the column
/// grain of the 2D grid; a column tile is always a whole number of panels).
pub fn panel_count(n: usize) -> usize {
    (n.max(1) + NR - 1) / NR
}

pub(super) fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// A 2D row×column tile grid over one GEMM-shaped stage: rows are batch
/// rows (dense) or image rows (conv), columns are packed-B `NR`-column
/// panels. `panel_tiles == 1` is exactly the pre-2D row-only decomposition.
///
/// Produced by [`plan_tile_grid`]; the row/panel counts are what the dag
/// builders iterate (per-image builders may produce more row tiles than
/// `row_tiles` when rows cannot span images — the fields are the grid's
/// *shape*, not a task-count promise).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileGrid {
    /// Rows per row tile (the final tile may be ragged).
    pub rows_per_tile: usize,
    /// Row-tile count over the planned row space.
    pub row_tiles: usize,
    /// NR-column panels per column tile (the final tile may be ragged).
    pub panels_per_tile: usize,
    /// Column-tile count; 1 ⇒ no column split.
    pub panel_tiles: usize,
}

impl TileGrid {
    /// Row-only grid at the given granularity — the pre-2D decomposition
    /// (and the bench baseline the 2D grid is measured against).
    pub fn rows_only(m: usize, rows_per_task: usize, n: usize) -> Self {
        let m = m.max(1);
        let rows_per_tile = rows_per_task.clamp(1, m);
        TileGrid {
            rows_per_tile,
            row_tiles: ceil_div(m, rows_per_tile),
            panels_per_tile: panel_count(n),
            panel_tiles: 1,
        }
    }

    /// Total tiles this grid yields over its planned row space.
    pub fn tiles(&self) -> usize {
        self.row_tiles * self.panel_tiles
    }

    /// Reject degenerate grids early. The fields are public so tests and
    /// benches can hand-build grids; a zero granularity would make the dag
    /// builders' `y += rows` / `p += np` loops spin forever, so the tile
    /// executors assert here first (the planner never produces zeros).
    pub fn check(&self) {
        assert!(self.rows_per_tile >= 1, "degenerate grid: rows_per_tile = 0");
        assert!(self.panels_per_tile >= 1, "degenerate grid: panels_per_tile = 0");
    }
}

/// Plan the 2D tile grid for one GEMM-shaped stage: `m` output rows,
/// contraction length `kk`, `n` output columns, `workers` pool threads,
/// `rows_hint` the caller's 1D row granularity.
///
/// Heuristic: row tiles stay the decomposition of choice (contiguous A and
/// C, no duplicated im2col); columns split **only** when rows alone cannot
/// produce [`TILE_TARGET_PER_WORKER`]`× workers` tiles — the Table-2
/// cases-5–7 regime (small batch, 2000-neuron FC layers), where a single
/// batch row's GEMM must span workers to keep them busy. When columns do
/// split, row tiles are first fattened to `MR` so each tile still feeds
/// full 4×8 register tiles instead of 1-row edge kernels, and the split is
/// capped so no tile drops under the per-tile FLOP floor — **calibrated**
/// per machine from the measured micro-kernel rate and dispatch overhead
/// ([`crate::inner::autotune::tile_floor_flops`]), not a hard-coded
/// constant.
pub fn plan_tile_grid(m: usize, kk: usize, n: usize, workers: usize, rows_hint: usize) -> TileGrid {
    plan_tile_grid_with_floor(m, kk, n, workers, rows_hint, super::autotune::tile_floor_flops())
}

/// [`plan_tile_grid`] with an explicit per-tile FLOP floor — the form the
/// autotuner uses to generate floor×{½,2} neighbor plans.
pub fn plan_tile_grid_with_floor(
    m: usize,
    kk: usize,
    n: usize,
    workers: usize,
    rows_hint: usize,
    floor_flops: usize,
) -> TileGrid {
    let m = m.max(1);
    let target = TILE_TARGET_PER_WORKER * workers.max(1);
    let rows_per_tile = rows_hint.clamp(1, m);
    let row_tiles = ceil_div(m, rows_per_tile);
    if row_tiles >= target || panel_count(n) <= 1 || workers <= 1 {
        return TileGrid::rows_only(m, rows_per_tile, n);
    }
    // Fatten row tiles to MR before splitting columns: a 2D tile should
    // feed whole register tiles, not 1-row edge kernels.
    let rows_per_tile = rows_per_tile.max(MR.min(m));
    let row_tiles = ceil_div(m, rows_per_tile);
    plan_cols_for_rows_with_floor(rows_per_tile, row_tiles, kk, n, workers, floor_flops)
}

/// The column-split half of the planner with the row split already fixed —
/// used directly where a second grid must share row tiles with an existing
/// one (the dense backward's dx space mirrors the dy grid's rows, conv
/// backward's dx space mirrors the df grid's rows).
pub fn plan_cols_for_rows(
    rows_per_tile: usize,
    row_tiles: usize,
    kk: usize,
    n: usize,
    workers: usize,
) -> TileGrid {
    plan_cols_for_rows_with_floor(
        rows_per_tile,
        row_tiles,
        kk,
        n,
        workers,
        super::autotune::tile_floor_flops(),
    )
}

/// [`plan_cols_for_rows`] with an explicit per-tile FLOP floor.
pub fn plan_cols_for_rows_with_floor(
    rows_per_tile: usize,
    row_tiles: usize,
    kk: usize,
    n: usize,
    workers: usize,
    floor_flops: usize,
) -> TileGrid {
    let target = TILE_TARGET_PER_WORKER * workers.max(1);
    let panels = panel_count(n);
    // Tiles wanted from the column dimension, capped by the panel supply
    // and by the work floor (2·rows·kk·n FLOPs split `want` ways).
    let mut want = ceil_div(target, row_tiles.max(1));
    let row_tile_flops = 2usize
        .saturating_mul(rows_per_tile)
        .saturating_mul(kk)
        .saturating_mul(n);
    want = want.min((row_tile_flops / floor_flops.max(1)).max(1)).min(panels).max(1);
    let panels_per_tile = ceil_div(panels, want);
    TileGrid {
        rows_per_tile,
        row_tiles,
        panels_per_tile,
        panel_tiles: ceil_div(panels, panels_per_tile),
    }
}

/// How a task-parallel train step decomposes its stages into tiles.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TilePolicy {
    /// 1D row tiles only, at the given conv granularity — the pre-2D
    /// engine, retained as the bench baseline.
    RowsOnly { rows_per_task: usize },
    /// 2D row×panel grids from [`plan_tile_grid`]; `rows_per_task` seeds
    /// the conv row split exactly like the old 1D knob.
    Grid2d { rows_per_task: usize },
    /// Per-stage grids chosen online by the node's
    /// [`crate::inner::AutoTuner`] from measured makespans. Where no tuner
    /// state is available (the [`TilePolicy::plan`] fallback below, or a
    /// freshly-seen stage), this degrades to the static [`plan_tile_grid`]
    /// — an untuned Auto step is exactly a `Grid2d` step.
    Auto { rows_per_task: usize },
}

impl TilePolicy {
    pub fn rows_only(rows_per_task: usize) -> Self {
        TilePolicy::RowsOnly { rows_per_task }
    }

    pub fn grid2d(rows_per_task: usize) -> Self {
        TilePolicy::Grid2d { rows_per_task }
    }

    pub fn auto(rows_per_task: usize) -> Self {
        TilePolicy::Auto { rows_per_task }
    }

    /// Whether this policy routes planning through the stage autotuner.
    pub fn is_auto(&self) -> bool {
        matches!(self, TilePolicy::Auto { .. })
    }

    /// The conv row granularity this policy was seeded with.
    pub fn rows_per_task(&self) -> usize {
        match *self {
            TilePolicy::RowsOnly { rows_per_task }
            | TilePolicy::Grid2d { rows_per_task }
            | TilePolicy::Auto { rows_per_task } => rows_per_task,
        }
    }

    /// Plan one stage's grid under this policy (the static path — `Auto`
    /// steps route through the tuner instead and only land here as the
    /// no-tuner degradation).
    pub fn plan(
        &self,
        m: usize,
        kk: usize,
        n: usize,
        workers: usize,
        rows_hint: usize,
    ) -> TileGrid {
        match *self {
            TilePolicy::RowsOnly { .. } => TileGrid::rows_only(m, rows_hint, n),
            TilePolicy::Grid2d { .. } | TilePolicy::Auto { .. } => {
                plan_tile_grid(m, kk, n, workers, rows_hint)
            }
        }
    }

    /// Companion grid sharing `base`'s row split, column-split over a
    /// different output width (the backward dx spaces). Companions are
    /// always derived statically from the base grid — under `Auto` the base
    /// is the tuned grid, so the companion follows the tuner's row split.
    pub fn plan_cols(&self, base: &TileGrid, kk: usize, n: usize, workers: usize) -> TileGrid {
        match *self {
            TilePolicy::RowsOnly { .. } => TileGrid {
                panels_per_tile: panel_count(n),
                panel_tiles: 1,
                ..*base
            },
            TilePolicy::Grid2d { .. } | TilePolicy::Auto { .. } => {
                plan_cols_for_rows(base.rows_per_tile, base.row_tiles, kk, n, workers)
            }
        }
    }
}

/// Outcome of one DAG execution.
#[derive(Debug, Clone)]
pub struct ScheduleStats {
    /// Wall-clock seconds from first dispatch to last completion.
    pub makespan_s: f64,
    /// Busy seconds per worker thread (measured, not estimated).
    pub thread_busy_s: Vec<f64>,
    /// Estimated cost assigned per thread (the quantity Alg 4.2 balances).
    pub thread_assigned_cost: Vec<f64>,
    pub tasks: usize,
}

impl ScheduleStats {
    /// Stats of an empty schedule over `workers` threads (the identity for
    /// [`ScheduleStats::merge`]).
    pub fn zero(workers: usize) -> Self {
        ScheduleStats {
            makespan_s: 0.0,
            thread_busy_s: vec![0.0; workers],
            thread_assigned_cost: vec![0.0; workers],
            tasks: 0,
        }
    }

    /// Balance index over measured busy time (Fig. 15b metric, applied to
    /// threads instead of nodes).
    pub fn balance_index(&self) -> f64 {
        stats::balance_index(&self.thread_busy_s)
    }

    /// Balance index over assigned cost.
    pub fn assigned_balance_index(&self) -> f64 {
        stats::balance_index(&self.thread_assigned_cost)
    }

    /// Accumulate another **sequentially executed** sub-stage's stats into
    /// this one: makespans and task counts add (the stages ran one after
    /// another), and the per-thread vectors add element-wise **padded to
    /// the larger worker count** — merging stats from pools of different
    /// sizes is well-defined (a worker absent from one stage contributed
    /// zero time there), instead of silently truncating to the shorter
    /// vector as the old ad-hoc merge did.
    pub fn merge(&mut self, s: &ScheduleStats) {
        self.makespan_s += s.makespan_s;
        self.tasks += s.tasks;
        if self.thread_busy_s.len() < s.thread_busy_s.len() {
            self.thread_busy_s.resize(s.thread_busy_s.len(), 0.0);
        }
        for (x, y) in self.thread_busy_s.iter_mut().zip(s.thread_busy_s.iter()) {
            *x += y;
        }
        if self.thread_assigned_cost.len() < s.thread_assigned_cost.len() {
            self.thread_assigned_cost.resize(s.thread_assigned_cost.len(), 0.0);
        }
        for (x, y) in self.thread_assigned_cost.iter_mut().zip(s.thread_assigned_cost.iter()) {
            *x += y;
        }
    }
}

struct DoneState {
    /// Per-task completion flags (dependency waits key off these). A
    /// panicked task is also marked done so dependents and the barrier can
    /// make progress; the panic is re-raised on the dispatching thread.
    flags: Vec<bool>,
    /// Number of completed tasks (the completion barrier keys off this).
    completed: usize,
    /// First panic payload caught in a task, re-thrown after the barrier.
    panic: Option<Box<dyn std::any::Any + Send>>,
}

struct DispatchState {
    done: Mutex<DoneState>,
    cv: Condvar,
}

/// Poison-tolerant lock: task panics are caught inside the job (they never
/// unwind through this mutex), but tolerate poisoning anyway so the
/// completion guard can always observe the counters instead of
/// double-panicking.
fn lock(m: &Mutex<DoneState>) -> MutexGuard<'_, DoneState> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

fn wait<'a>(cv: &Condvar, g: MutexGuard<'a, DoneState>) -> MutexGuard<'a, DoneState> {
    cv.wait(g).unwrap_or_else(|p| p.into_inner())
}

/// Blocks (on drop) until every job dispatched so far has completed. This is
/// what makes borrowed task payloads sound: even if the dispatch loop
/// unwinds, no borrow of the `execute_dag` frame can outlive the frame.
struct CompletionGuard {
    state: Arc<DispatchState>,
    dispatched: usize,
}

impl Drop for CompletionGuard {
    fn drop(&mut self) {
        let mut g = lock(&self.state.done);
        while g.completed < self.dispatched {
            g = wait(&self.state.cv, g);
        }
    }
}

/// Execute a task DAG per Algorithm 4.2. `runner` is invoked as
/// `runner(worker, payload)` on the assigned worker thread; `worker` indexes
/// the pool's workers (and their scratch arenas). Payloads and the runner may
/// borrow caller data — `execute_dag` returns only after all tasks finished.
pub fn execute_dag<'env, P, F>(pool: &ThreadPool, dag: TaskDag<P>, runner: F) -> ScheduleStats
where
    P: Send + Sync + 'env,
    F: Fn(usize, &P) + Send + Sync + 'env,
{
    let n = dag.len();
    let order = priority_order(&dag);
    let nodes = dag.into_nodes();
    let state = Arc::new(DispatchState {
        done: Mutex::new(DoneState { flags: vec![false; n], completed: 0, panic: None }),
        cv: Condvar::new(),
    });
    let busy_ns: Arc<Vec<AtomicU64>> =
        Arc::new((0..pool.size()).map(|_| AtomicU64::new(0)).collect());
    let mut assigned = vec![0.0f64; pool.size()];
    // Declared after `nodes`/`assigned` so it drops (and thus waits) first.
    let mut completion = CompletionGuard { state: Arc::clone(&state), dispatched: 0 };

    let t0 = Instant::now();
    for &tid in &order {
        // Line 5–7: wait until every dependency of the top task is complete.
        {
            let mut guard = lock(&state.done);
            while !nodes[tid].deps.iter().all(|&d| guard.flags[d]) {
                guard = wait(&state.cv, guard);
            }
        }
        // Line 8: thread with minimal (assigned) workload.
        let k = assigned
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        assigned[k] += nodes[tid].cost;
        // Line 9: assignment. The job borrows `nodes` and `runner` from this
        // frame — no Arc clones of payload data.
        let node = &nodes[tid];
        let runner_ref = &runner;
        let state2 = Arc::clone(&state);
        let busy2 = Arc::clone(&busy_ns);
        let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
            let start = Instant::now();
            // Catch task panics so the worker thread, the pool's inflight
            // accounting and this DAG's completion barrier all stay intact;
            // the payload is re-thrown on the dispatching thread below. The
            // `scoped_task` wrapper tags the worker thread with the task id
            // for the `chk`-feature claim cross-check (no-op otherwise) and
            // restores the previous tag even when the runner panics.
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                super::check::scoped_task(node.id, || runner_ref(k, &node.payload));
            }));
            busy2[k].fetch_add(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
            let mut guard = lock(&state2.done);
            guard.flags[tid] = true;
            guard.completed += 1;
            if let Err(payload) = result {
                guard.panic.get_or_insert(payload);
            }
            state2.cv.notify_all();
        });
        // SAFETY: the completion guard (and the barrier below) guarantee the
        // job finishes before this frame — hence before `nodes`, `runner`
        // and anything the payloads borrow — is invalidated.
        unsafe { pool.execute_on_borrowed(k, job) };
        completion.dispatched += 1;
    }
    // Wait for all tasks to complete; re-raise the first task panic here on
    // the dispatching thread (after the barrier, so borrows stay sound).
    {
        let mut guard = lock(&state.done);
        while guard.completed != n {
            guard = wait(&state.cv, guard);
        }
        if let Some(payload) = guard.panic.take() {
            drop(guard);
            std::panic::resume_unwind(payload);
        }
    }
    let makespan = t0.elapsed().as_secs_f64();
    ScheduleStats {
        makespan_s: makespan,
        thread_busy_s: busy_ns
            .iter()
            .map(|a| a.load(Ordering::Relaxed) as f64 / 1e9)
            .collect(),
        thread_assigned_cost: assigned,
        tasks: n,
    }
}

/// Sequential baseline: run tasks in topological (insertion) order on the
/// calling thread. Used by the ablation benches to measure scheduling
/// overhead and speedup.
pub fn execute_sequential<P, F>(dag: TaskDag<P>, runner: F) -> f64
where
    F: Fn(&P),
{
    let t0 = Instant::now();
    for node in dag.nodes() {
        runner(&node.payload);
    }
    t0.elapsed().as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    /// Build a random layered DAG and check the scheduler never violates
    /// dependency order.
    #[test]
    fn execution_respects_dependencies() {
        let pool = ThreadPool::new(4);
        let mut dag: TaskDag<usize> = TaskDag::new();
        // 3 layers of 8 tasks, each depending on 2 tasks of the previous.
        let mut prev: Vec<usize> = Vec::new();
        let mut all = Vec::new();
        for layer in 0..3 {
            let mut cur = Vec::new();
            for i in 0..8 {
                let deps: Vec<usize> = if layer == 0 {
                    vec![]
                } else {
                    vec![prev[i % prev.len()], prev[(i + 3) % prev.len()]]
                };
                let id = dag.add(format!("t{layer}_{i}"), 1.0, &deps, all.len());
                cur.push(id);
                all.push(id);
            }
            prev = cur;
        }
        // Record completion order.
        let n = dag.len();
        let seq = Arc::new(AtomicUsize::new(0));
        let finish_pos: Arc<Vec<AtomicUsize>> =
            Arc::new((0..n).map(|_| AtomicUsize::new(usize::MAX)).collect());
        let deps_snapshot: Vec<Vec<usize>> =
            dag.nodes().iter().map(|nd| nd.deps.clone()).collect();
        {
            let seq = Arc::clone(&seq);
            let fp = Arc::clone(&finish_pos);
            execute_dag(&pool, dag, move |_, &tid| {
                let p = seq.fetch_add(1, Ordering::SeqCst);
                fp[tid].store(p, Ordering::SeqCst);
            });
        }
        for (tid, deps) in deps_snapshot.iter().enumerate() {
            let my = finish_pos[tid].load(Ordering::SeqCst);
            for &d in deps {
                let dp = finish_pos[d].load(Ordering::SeqCst);
                assert!(dp < my, "task {tid} (pos {my}) finished before dep {d} (pos {dp})");
            }
        }
    }

    #[test]
    fn all_tasks_run_exactly_once() {
        let pool = ThreadPool::new(3);
        let mut dag: TaskDag<usize> = TaskDag::new();
        for i in 0..50 {
            let deps = if i >= 10 { vec![i - 10] } else { vec![] };
            dag.add("t", 1.0, &deps, i);
        }
        let counts: Arc<Vec<AtomicUsize>> =
            Arc::new((0..50).map(|_| AtomicUsize::new(0)).collect());
        let c2 = Arc::clone(&counts);
        let stats = execute_dag(&pool, dag, move |_, &i| {
            c2[i].fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(stats.tasks, 50);
        for c in counts.iter() {
            assert_eq!(c.load(Ordering::SeqCst), 1);
        }
    }

    /// The runner's worker index matches the worker the task actually ran on
    /// (pinned queues) — the invariant the per-worker arenas rely on.
    #[test]
    fn worker_index_matches_executing_thread() {
        let pool = ThreadPool::new(3);
        // Map each worker index to the thread id observed running it.
        let seen: Arc<Mutex<std::collections::HashMap<usize, Vec<std::thread::ThreadId>>>> =
            Arc::new(Mutex::new(std::collections::HashMap::new()));
        let mut dag: TaskDag<usize> = TaskDag::new();
        for i in 0..48 {
            dag.add("t", 1.0, &[], i);
        }
        let s2 = Arc::clone(&seen);
        execute_dag(&pool, dag, move |worker, _| {
            s2.lock()
                .unwrap()
                .entry(worker)
                .or_default()
                .push(std::thread::current().id());
        });
        let seen = seen.lock().unwrap();
        for ids in seen.values() {
            assert!(ids.iter().all(|&id| id == ids[0]), "one worker index, several threads");
        }
        // Distinct worker indices ran on distinct threads.
        let firsts: Vec<_> = seen.values().map(|v| v[0]).collect();
        let mut dedup = firsts.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), firsts.len());
    }

    /// Tasks may borrow caller-local data (zero-copy dispatch).
    #[test]
    fn tasks_borrow_caller_data() {
        let pool = ThreadPool::new(2);
        let data: Vec<u64> = (0..100).collect();
        let total = std::sync::atomic::AtomicU64::new(0);
        let mut dag: TaskDag<usize> = TaskDag::new();
        for i in 0..data.len() {
            dag.add("t", 1.0, &[], i);
        }
        let d: &[u64] = &data;
        let t = &total;
        execute_dag(&pool, dag, move |_, &i| {
            t.fetch_add(d[i], Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), 99 * 100 / 2);
    }

    /// A panicking task must not deadlock the barrier or wedge the pool: the
    /// panic is re-raised on the dispatching thread and the pool stays
    /// usable for the next DAG.
    #[test]
    fn panicking_task_propagates_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let mut dag: TaskDag<usize> = TaskDag::new();
        for i in 0..8 {
            dag.add("t", 1.0, &[], i);
        }
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            execute_dag(&pool, dag, |_, &i| {
                if i == 3 {
                    panic!("task 3 exploded");
                }
            })
        }));
        assert!(res.is_err(), "task panic was swallowed");
        // Pool and scheduler still fully functional afterwards.
        let mut dag2: TaskDag<usize> = TaskDag::new();
        for i in 0..4 {
            dag2.add("t", 1.0, &[], i);
        }
        let stats = execute_dag(&pool, dag2, |_, _| {});
        assert_eq!(stats.tasks, 4);
        pool.wait_idle();
    }

    #[test]
    fn assigned_cost_is_balanced_for_uniform_independent_tasks() {
        let pool = ThreadPool::new(4);
        let mut dag: TaskDag<()> = TaskDag::new();
        for _ in 0..64 {
            dag.add("t", 1.0, &[], ());
        }
        let stats = execute_dag(&pool, dag, |_, _| {});
        // 64 equal tasks over 4 threads → exactly 16 cost units each.
        assert!(stats.assigned_balance_index() > 0.99, "{:?}", stats.thread_assigned_cost);
    }

    #[test]
    fn heavier_tasks_spread_by_cost() {
        let pool = ThreadPool::new(2);
        let mut dag: TaskDag<()> = TaskDag::new();
        // One big task (cost 3) + three small (cost 1) → 3 | 1+1+1 split.
        dag.add("big", 3.0, &[], ());
        for _ in 0..3 {
            dag.add("small", 1.0, &[], ());
        }
        let stats = execute_dag(&pool, dag, |_, _| {});
        let mut costs = stats.thread_assigned_cost.clone();
        costs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(costs, vec![3.0, 3.0]);
    }

    #[test]
    fn sequential_runs_everything() {
        let mut dag: TaskDag<usize> = TaskDag::new();
        for i in 0..10 {
            dag.add("t", 1.0, &[], i);
        }
        let count = std::cell::Cell::new(0usize);
        execute_sequential(dag, |_| count.set(count.get() + 1));
        assert_eq!(count.get(), 10);
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = ThreadPool::new(1);
        let mut dag: TaskDag<usize> = TaskDag::new();
        let a = dag.add("a", 1.0, &[], 0);
        dag.add("b", 1.0, &[a], 1);
        let stats = execute_dag(&pool, dag, |_, _| {});
        assert_eq!(stats.tasks, 2);
        assert_eq!(stats.thread_assigned_cost.len(), 1);
    }

    /// The ISSUE-4 acceptance shape: batch 4, 2000-neuron FC, 8 workers —
    /// the planner must column-split so the stage yields ≥ 8 (indeed ≥ 2×8)
    /// near-equal tiles instead of 4 serializing batch-row tiles.
    #[test]
    fn planner_splits_columns_for_small_batch_wide_fc() {
        let g = plan_tile_grid(4, 2000, 2000, 8, 1);
        assert!(g.panel_tiles > 1, "{g:?}");
        assert!(g.tiles() >= 8, "{g:?}");
        // Row tiles fattened to MR: whole register tiles, not 1-row edges.
        assert_eq!(g.rows_per_tile, 4, "{g:?}");
        // The supply hits the Alg.-4.2 balancing target exactly (2×workers
        // tiles: 1 row tile × 16 column tiles of ≤16 panels over 250), and
        // only the final tile may be ragged — every other tile is full
        // width, so least-loaded assignment sees uniform costs plus at most
        // one smaller tile.
        assert_eq!(g.tiles(), 16, "{g:?}");
        let panels = panel_count(2000);
        let last = panels - (g.panel_tiles - 1) * g.panels_per_tile;
        assert!((1..=g.panels_per_tile).contains(&last), "{g:?}");
        assert_eq!((g.panel_tiles - 1) * g.panels_per_tile + last, panels, "{g:?}");
    }

    /// Plenty of batch rows → the planner reproduces the 1D decomposition
    /// exactly (the no-regression guarantee for large-batch steps).
    #[test]
    fn planner_keeps_rows_only_when_rows_suffice() {
        let g = plan_tile_grid(32, 256, 256, 4, 4);
        assert_eq!(g, TileGrid::rows_only(32, 4, 256));
        assert_eq!(g.panel_tiles, 1);
        assert_eq!(g.rows_per_tile, 4);
        assert_eq!(g.row_tiles, 8);
    }

    /// Tiny stages (output-layer logits, small test nets) stay coarse: the
    /// FLOP floor forbids splitting work that would not amortize dispatch.
    #[test]
    fn planner_work_floor_prevents_tiny_tiles() {
        // batch 4, k 16, n 10: whole stage ≈ 1.3 kFLOP ⇒ no column split.
        let g = plan_tile_grid(4, 16, 10, 8, 1);
        assert_eq!(g.panel_tiles, 1, "{g:?}");
        // Single-column stages can never split.
        let g1 = plan_tile_grid(4, 2000, 1, 8, 1);
        assert_eq!(g1.panel_tiles, 1);
    }

    /// `plan_cols_for_rows` degenerates to one column tile when the row
    /// split already meets the target (shared-row companion grids must not
    /// over-split).
    #[test]
    fn plan_cols_respects_existing_row_supply() {
        let base = plan_tile_grid(64, 512, 512, 4, 8);
        assert_eq!(base.panel_tiles, 1);
        let dx = plan_cols_for_rows(base.rows_per_tile, base.row_tiles, 512, 512, 4);
        assert_eq!(dx.panel_tiles, 1, "{dx:?}");
    }

    /// Column tiles of any grid partition the panel space exactly.
    #[test]
    fn grid_panel_tiles_partition_panel_space() {
        for n in [1usize, 7, 8, 9, 63, 250, 2000] {
            for workers in [1usize, 2, 8] {
                let g = plan_tile_grid(4, 64, n, workers, 1);
                let panels = panel_count(n);
                let mut covered = 0;
                for t in 0..g.panel_tiles {
                    let p0 = t * g.panels_per_tile;
                    let np = g.panels_per_tile.min(panels - p0);
                    assert!(np >= 1, "n={n} workers={workers} {g:?}");
                    covered += np;
                }
                assert_eq!(covered, panels, "n={n} workers={workers} {g:?}");
            }
        }
    }

    #[test]
    fn tile_policy_plans_match_mode() {
        let rows = TilePolicy::rows_only(2);
        assert_eq!(rows.plan(4, 2000, 2000, 8, 1), TileGrid::rows_only(4, 1, 2000));
        let grid = TilePolicy::grid2d(2);
        assert_eq!(grid.rows_per_task(), 2);
        let g = grid.plan(4, 2000, 2000, 8, 1);
        assert!(g.panel_tiles > 1);
        // plan_cols under RowsOnly keeps a single column tile.
        let dx = rows.plan_cols(&g, 2000, 2000, 8);
        assert_eq!(dx.panel_tiles, 1);
        assert_eq!(dx.rows_per_tile, g.rows_per_tile);
        // Auto degrades to the static planner when no tuner drives it.
        let auto = TilePolicy::auto(2);
        assert!(auto.is_auto());
        assert_eq!(auto.rows_per_task(), 2);
        assert_eq!(auto.plan(4, 2000, 2000, 8, 1), g);
        assert_eq!(auto.plan_cols(&g, 2000, 2000, 8), grid.plan_cols(&g, 2000, 2000, 8));
    }

    /// The merge of sequentially-executed sub-stage stats is well-defined
    /// for *any* pair of worker counts: per-thread vectors pad to the max
    /// instead of silently truncating to the min.
    #[test]
    fn merge_pads_to_max_worker_count() {
        let mut a = ScheduleStats {
            makespan_s: 1.0,
            thread_busy_s: vec![1.0, 2.0],
            thread_assigned_cost: vec![3.0, 4.0],
            tasks: 2,
        };
        let b = ScheduleStats {
            makespan_s: 0.5,
            thread_busy_s: vec![0.5, 0.5, 0.5, 0.5],
            thread_assigned_cost: vec![1.0, 1.0, 1.0, 1.0],
            tasks: 4,
        };
        a.merge(&b);
        assert_eq!(a.makespan_s, 1.5);
        assert_eq!(a.tasks, 6);
        assert_eq!(a.thread_busy_s, vec![1.5, 2.5, 0.5, 0.5]);
        assert_eq!(a.thread_assigned_cost, vec![4.0, 5.0, 1.0, 1.0]);
        // Longer-into-shorter (the old silent-truncation case): the extra
        // workers of the accumulator keep their totals.
        let mut c = ScheduleStats::zero(4);
        c.thread_busy_s[3] = 9.0;
        c.merge(&ScheduleStats {
            makespan_s: 1.0,
            thread_busy_s: vec![1.0],
            thread_assigned_cost: vec![2.0],
            tasks: 1,
        });
        assert_eq!(c.thread_busy_s, vec![1.0, 0.0, 0.0, 9.0]);
        assert_eq!(c.thread_assigned_cost, vec![2.0, 0.0, 0.0, 0.0]);
        assert_eq!(c.tasks, 1);
    }

    /// The floor is an explicit parameter with the default path reading the
    /// calibrated global — no hard-coded constant left in the planner.
    #[test]
    fn planner_floor_is_explicit_and_calibrated() {
        // A tiny floor lets the acceptance shape reach the full 2×workers
        // supply; a huge floor forbids column-splitting entirely.
        let fine = plan_tile_grid_with_floor(4, 2000, 2000, 8, 1, 1);
        assert!(fine.panel_tiles > 1, "{fine:?}");
        let coarse = plan_tile_grid_with_floor(4, 2000, 2000, 8, 1, usize::MAX / 4);
        assert_eq!(coarse.panel_tiles, 1, "{coarse:?}");
        // The default path's calibrated floor stays inside the clamp band,
        // where every pinned planner expectation holds.
        let f = crate::inner::autotune::tile_floor_flops();
        assert!(
            (crate::inner::autotune::FLOOR_MIN_FLOPS..=crate::inner::autotune::FLOOR_MAX_FLOPS)
                .contains(&f),
            "calibrated floor {f} outside clamp band"
        );
    }
}
