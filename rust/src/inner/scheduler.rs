//! Priority task scheduling — Algorithm 4.2.
//!
//! Tasks are taken in priority order (upstream first, §4.2(1)); a task whose
//! dependencies are incomplete makes the dispatcher *wait* (Alg 4.2 line 7);
//! ready tasks are assigned to the thread with minimal accumulated workload
//! (line 8). Execution happens on [`ThreadPool`] workers via their pinned
//! per-thread queues, so "assignment to thread k" is real, not advisory.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::util::stats;
use crate::util::threadpool::ThreadPool;

use super::dag::TaskDag;
use super::priority::priority_order;

/// Outcome of one DAG execution.
#[derive(Debug, Clone)]
pub struct ScheduleStats {
    /// Wall-clock seconds from first dispatch to last completion.
    pub makespan_s: f64,
    /// Busy seconds per worker thread (measured, not estimated).
    pub thread_busy_s: Vec<f64>,
    /// Estimated cost assigned per thread (the quantity Alg 4.2 balances).
    pub thread_assigned_cost: Vec<f64>,
    pub tasks: usize,
}

impl ScheduleStats {
    /// Balance index over measured busy time (Fig. 15b metric, applied to
    /// threads instead of nodes).
    pub fn balance_index(&self) -> f64 {
        stats::balance_index(&self.thread_busy_s)
    }

    /// Balance index over assigned cost.
    pub fn assigned_balance_index(&self) -> f64 {
        stats::balance_index(&self.thread_assigned_cost)
    }
}

struct DispatchState {
    done: Mutex<(Vec<bool>, usize)>, // (per-task done flags, remaining)
    cv: Condvar,
}

/// Execute a task DAG per Algorithm 4.2. `runner` is invoked with each
/// task's payload on the assigned worker thread.
pub fn execute_dag<P, F>(pool: &ThreadPool, dag: TaskDag<P>, runner: F) -> ScheduleStats
where
    P: Send + Sync + 'static,
    F: Fn(&P) + Send + Sync + 'static,
{
    let n = dag.len();
    let order = priority_order(&dag);
    let nodes = Arc::new(dag.into_nodes());
    let runner = Arc::new(runner);
    let state = Arc::new(DispatchState {
        done: Mutex::new((vec![false; n], n)),
        cv: Condvar::new(),
    });
    let busy_ns: Arc<Vec<AtomicU64>> =
        Arc::new((0..pool.size()).map(|_| AtomicU64::new(0)).collect());
    let mut assigned = vec![0.0f64; pool.size()];

    let t0 = Instant::now();
    for &tid in &order {
        // Line 5–7: wait until every dependency of the top task is complete.
        {
            let mut guard = state.done.lock().unwrap();
            while !nodes[tid].deps.iter().all(|&d| guard.0[d]) {
                guard = state.cv.wait(guard).unwrap();
            }
        }
        // Line 8: thread with minimal (assigned) workload.
        let k = assigned
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        assigned[k] += nodes[tid].cost;
        // Line 9: assignment.
        let nodes2 = Arc::clone(&nodes);
        let runner2 = Arc::clone(&runner);
        let state2 = Arc::clone(&state);
        let busy2 = Arc::clone(&busy_ns);
        pool.execute_on(k, move || {
            let start = Instant::now();
            runner2(&nodes2[tid].payload);
            busy2[k].fetch_add(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
            let mut guard = state2.done.lock().unwrap();
            guard.0[tid] = true;
            guard.1 -= 1;
            state2.cv.notify_all();
        });
    }
    // Wait for all tasks to complete.
    {
        let mut guard = state.done.lock().unwrap();
        while guard.1 != 0 {
            guard = state.cv.wait(guard).unwrap();
        }
    }
    let makespan = t0.elapsed().as_secs_f64();
    ScheduleStats {
        makespan_s: makespan,
        thread_busy_s: busy_ns
            .iter()
            .map(|a| a.load(Ordering::Relaxed) as f64 / 1e9)
            .collect(),
        thread_assigned_cost: assigned,
        tasks: n,
    }
}

/// Sequential baseline: run tasks in topological (insertion) order on the
/// calling thread. Used by the ablation benches to measure scheduling
/// overhead and speedup.
pub fn execute_sequential<P, F>(dag: TaskDag<P>, runner: F) -> f64
where
    F: Fn(&P),
{
    let t0 = Instant::now();
    for node in dag.nodes() {
        runner(&node.payload);
    }
    t0.elapsed().as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    /// Build a random layered DAG and check the scheduler never violates
    /// dependency order.
    #[test]
    fn execution_respects_dependencies() {
        let pool = ThreadPool::new(4);
        let mut dag: TaskDag<usize> = TaskDag::new();
        // 3 layers of 8 tasks, each depending on 2 tasks of the previous.
        let mut prev: Vec<usize> = Vec::new();
        let mut all = Vec::new();
        for layer in 0..3 {
            let mut cur = Vec::new();
            for i in 0..8 {
                let deps: Vec<usize> = if layer == 0 {
                    vec![]
                } else {
                    vec![prev[i % prev.len()], prev[(i + 3) % prev.len()]]
                };
                let id = dag.add(format!("t{layer}_{i}"), 1.0, &deps, all.len());
                cur.push(id);
                all.push(id);
            }
            prev = cur;
        }
        // Record completion order.
        let n = dag.len();
        let seq = Arc::new(AtomicUsize::new(0));
        let finish_pos: Arc<Vec<AtomicUsize>> =
            Arc::new((0..n).map(|_| AtomicUsize::new(usize::MAX)).collect());
        let deps_snapshot: Vec<Vec<usize>> =
            dag.nodes().iter().map(|nd| nd.deps.clone()).collect();
        {
            let seq = Arc::clone(&seq);
            let fp = Arc::clone(&finish_pos);
            execute_dag(&pool, dag, move |&tid| {
                let p = seq.fetch_add(1, Ordering::SeqCst);
                fp[tid].store(p, Ordering::SeqCst);
            });
        }
        for (tid, deps) in deps_snapshot.iter().enumerate() {
            let my = finish_pos[tid].load(Ordering::SeqCst);
            for &d in deps {
                let dp = finish_pos[d].load(Ordering::SeqCst);
                assert!(dp < my, "task {tid} (pos {my}) finished before dep {d} (pos {dp})");
            }
        }
    }

    #[test]
    fn all_tasks_run_exactly_once() {
        let pool = ThreadPool::new(3);
        let mut dag: TaskDag<usize> = TaskDag::new();
        for i in 0..50 {
            let deps = if i >= 10 { vec![i - 10] } else { vec![] };
            dag.add("t", 1.0, &deps, i);
        }
        let counts: Arc<Vec<AtomicUsize>> =
            Arc::new((0..50).map(|_| AtomicUsize::new(0)).collect());
        let c2 = Arc::clone(&counts);
        let stats = execute_dag(&pool, dag, move |&i| {
            c2[i].fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(stats.tasks, 50);
        for c in counts.iter() {
            assert_eq!(c.load(Ordering::SeqCst), 1);
        }
    }

    #[test]
    fn assigned_cost_is_balanced_for_uniform_independent_tasks() {
        let pool = ThreadPool::new(4);
        let mut dag: TaskDag<()> = TaskDag::new();
        for _ in 0..64 {
            dag.add("t", 1.0, &[], ());
        }
        let stats = execute_dag(&pool, dag, |_| {});
        // 64 equal tasks over 4 threads → exactly 16 cost units each.
        assert!(stats.assigned_balance_index() > 0.99, "{:?}", stats.thread_assigned_cost);
    }

    #[test]
    fn heavier_tasks_spread_by_cost() {
        let pool = ThreadPool::new(2);
        let mut dag: TaskDag<()> = TaskDag::new();
        // One big task (cost 3) + three small (cost 1) → 3 | 1+1+1 split.
        dag.add("big", 3.0, &[], ());
        for _ in 0..3 {
            dag.add("small", 1.0, &[], ());
        }
        let stats = execute_dag(&pool, dag, |_| {});
        let mut costs = stats.thread_assigned_cost.clone();
        costs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(costs, vec![3.0, 3.0]);
    }

    #[test]
    fn sequential_runs_everything() {
        let mut dag: TaskDag<usize> = TaskDag::new();
        for i in 0..10 {
            dag.add("t", 1.0, &[], i);
        }
        let count = std::cell::Cell::new(0usize);
        execute_sequential(dag, |_| count.set(count.get() + 1));
        assert_eq!(count.get(), 10);
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = ThreadPool::new(1);
        let mut dag: TaskDag<usize> = TaskDag::new();
        let a = dag.add("a", 1.0, &[], 0);
        dag.add("b", 1.0, &[a], 1);
        let stats = execute_dag(&pool, dag, |_| {});
        assert_eq!(stats.tasks, 2);
        assert_eq!(stats.thread_assigned_cost.len(), 1);
    }
}
