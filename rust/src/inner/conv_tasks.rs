//! Convolution-layer task decomposition — Algorithm 4.1 (§4.1.1).
//!
//! The paper extracts every convolution area of the input matrix (Eq. 14)
//! and convolves them in parallel with the shared filter (Fig. 6). Its
//! maximum parallelism degree is `K_C = H_a × W_a` (Eq. 13) — one task per
//! output element. At CPU-thread granularity one scalar per task drowns in
//! scheduling overhead, so the decomposition here groups whole output *rows*
//! into one task (`rows_per_task` tunes the granularity; `1` row ≈ `W_a`
//! paper-tasks fused — the ablation bench sweeps this knob).
//!
//! Each task executes its row tile through the im2col + packed-GEMM fast
//! path ([`crate::nn::ops::conv2d_same_rows_packed`]): the filter is packed
//! once per layer call ([`crate::nn::ops::pack_filter`]) and shared
//! read-only by every task, patch scratch comes from the executing worker's
//! persistent [`ScratchArena`], and the input/filter/bias tensors are
//! **borrowed** by the tasks (the scheduler's completion barrier makes that
//! sound) — the task body performs no heap allocation and dispatch copies no
//! tensor.
//!
//! Tasks write disjoint row slices of the shared output buffer through
//! [`DisjointBuf`], the lock-free analogue of the paper's observation that
//! "different tasks can access different convolution areas simultaneously…
//! without data dependence".

use crate::nn::ops::{self, ConvDims};
use crate::util::threadpool::{ScratchArena, ThreadPool};

use super::check;
use super::dag::TaskDag;
use super::scheduler::{execute_dag, panel_count, plan_tile_grid, ScheduleStats, TileGrid};

/// A buffer whose tasks write provably disjoint regions concurrently.
///
/// Safety contract: every (offset, len) window handed out via `slice_mut`
/// must be disjoint across concurrently running tasks. The conv
/// decomposition guarantees this structurally: task (n, y) owns exactly
/// rows `[y, y+rows)` of image `n` — and every stage plan's region map is
/// proved disjoint by [`check::verify`] (statically in `tests/plan_sweep.rs`
/// and at stage start under the `chk` feature, where accessors additionally
/// cross-check each touched window against the task's declared claims).
pub struct DisjointBuf {
    ptr: *mut f32,
    len: usize,
    /// Logical buffer id + stage claim guard, set by [`DisjointBuf::checked`]
    /// — accessors cross-check every window against the executing task's
    /// verified claims.
    #[cfg(feature = "chk")]
    claims: Option<(check::Buf, check::StageGuard)>,
}

// SAFETY: `DisjointBuf` is a bounds-tagged raw pointer into a buffer the
// dispatching stage exclusively borrows for the lifetime of its task DAG
// (the scheduler's completion barrier enforces the lifetime). Tasks on
// other threads may move the handle (`Send`) and access it concurrently
// (`Sync`) because every access goes through windows that are pairwise
// disjoint across unordered tasks — the invariant `check::verify` proves
// for each stage plan and `chk` builds re-check per actual access.
unsafe impl Send for DisjointBuf {}
// SAFETY: see the `Send` justification above — shared `&DisjointBuf` use
// is sound only through disjoint (or dependency-ordered) windows, which is
// exactly the checked stage-plan invariant.
unsafe impl Sync for DisjointBuf {}

impl DisjointBuf {
    pub fn new(buf: &mut [f32]) -> Self {
        Self {
            ptr: buf.as_mut_ptr(),
            len: buf.len(),
            #[cfg(feature = "chk")]
            claims: None,
        }
    }

    /// Register this buffer with a stage's claim guard under the logical id
    /// `buf`: in `chk` builds every subsequent `slice_mut`/`slice_ref`
    /// window is checked against the executing task's declared claims. A
    /// no-op token pass-through in default builds.
    #[must_use]
    pub fn checked(self, buf: check::Buf, guard: &check::StageGuard) -> Self {
        #[cfg(feature = "chk")]
        {
            let mut this = self;
            this.claims = Some((buf, guard.clone()));
            this
        }
        #[cfg(not(feature = "chk"))]
        {
            let _ = (buf, guard);
            self
        }
    }

    #[cfg(feature = "chk")]
    fn check_claim(&self, access: check::Access, lo: usize, hi: usize) {
        if let Some((buf, guard)) = &self.claims {
            guard.check_access(*buf, access, lo, hi);
        }
    }

    #[cfg(not(feature = "chk"))]
    #[inline(always)]
    fn check_claim(&self, _access: check::Access, _lo: usize, _hi: usize) {}

    /// # Safety
    /// Callers must ensure `[offset, offset+len)` windows of concurrent
    /// calls do not overlap.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, offset: usize, len: usize) -> &mut [f32] {
        let end = offset.checked_add(len).expect("disjoint window overflows usize");
        assert!(end <= self.len, "disjoint window out of bounds");
        self.check_claim(check::Access::Write, offset, end);
        // SAFETY: bounds asserted above; the caller contract (checked
        // against the stage plan in `chk` builds) keeps concurrent windows
        // disjoint, so no other live reference aliases these elements.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(offset), len) }
    }

    /// Raw pointer at `offset` — the output handle for the panel-windowed
    /// GEMM entry points ([`ops::gemm_packed_acc_panels_raw`]), whose 2D
    /// tiles write strided column windows that no `&mut` slice could cover
    /// without aliasing a neighbour tile's elements. Creating the pointer is
    /// safe; dereferences inherit the disjoint-window contract. The `chk`
    /// cross-check does not see these dereferences — each GEMM window
    /// through `ptr_at` is claimed alongside (and element-equal to) the
    /// task's checked `slice_mut` seeding sweep or an explicit Read claim.
    pub fn ptr_at(&self, offset: usize) -> *mut f32 {
        assert!(offset <= self.len, "offset out of bounds");
        // SAFETY: offset is within (or one past the end of) the buffer.
        unsafe { self.ptr.add(offset) }
    }

    /// Shared view of `[offset, offset+len)` — for tiles that *read* a
    /// window other tasks finished writing (e.g. dx tiles reading masked
    /// `dy` rows after their dependency barrier).
    ///
    /// # Safety
    /// No concurrent task may write any element of the window while the
    /// returned borrow lives.
    pub unsafe fn slice_ref(&self, offset: usize, len: usize) -> &[f32] {
        let end = offset.checked_add(len).expect("disjoint window overflows usize");
        assert!(end <= self.len, "disjoint window out of bounds");
        self.check_claim(check::Access::Read, offset, end);
        // SAFETY: bounds asserted above; the caller contract (checked
        // against the stage plan in `chk` builds) rules out concurrent
        // writers to this window.
        unsafe { std::slice::from_raw_parts(self.ptr.add(offset), len) }
    }
}

/// Payload of one convolution task: image index + row range.
#[derive(Debug, Clone, Copy)]
pub struct ConvTask {
    pub n: usize,
    pub y0: usize,
    pub rows: usize,
}

/// Payload of one **2D** convolution tile: image index + row range +
/// output-channel panel range. With `np` covering all panels this is
/// exactly a [`ConvTask`]; with a real panel split, several tiles share the
/// same rows (each re-lowers the patch matrix — the price of keeping all
/// workers busy when `batch × H` row tiles alone cannot) and write disjoint
/// column windows of the output.
#[derive(Debug, Clone, Copy)]
pub struct ConvTile {
    pub n: usize,
    pub y0: usize,
    pub rows: usize,
    /// First NR-column output panel of this tile.
    pub p0: usize,
    /// Panels covered.
    pub np: usize,
}

/// Build the Algorithm 4.1 task list for one SAME conv layer: `K_C` output
/// areas grouped `rows_per_task` rows at a time (per image). All tasks are
/// independent (level-0 DAG), mirroring Fig. 6.
pub fn conv_task_dag(d: &ConvDims, rows_per_task: usize) -> TaskDag<ConvTask> {
    assert!(rows_per_task >= 1);
    let mut dag = TaskDag::new();
    // Cost model: rows × W output elements × k²·C·O MACs each.
    let cost_per_row = (d.w * d.k * d.k * d.c * d.co) as f64;
    for n in 0..d.n {
        let mut y = 0;
        while y < d.h {
            let rows = rows_per_task.min(d.h - y);
            dag.add(
                format!("conv[n{n},y{y}+{rows}]"),
                cost_per_row * rows as f64,
                &[],
                ConvTask { n, y0: y, rows },
            );
            y += rows;
        }
    }
    dag
}

/// Build the 2D tile list for one SAME conv layer: row tiles per image ×
/// output-channel panel tiles (all independent, level-0, mirroring Fig. 6).
pub fn conv_tile_dag(d: &ConvDims, grid: &TileGrid) -> TaskDag<ConvTile> {
    let mut dag = TaskDag::new();
    let panels = panel_count(d.co);
    // Cost model: rows × W output patches × jw columns × k²·C MACs each.
    let cost_per_el = (d.w * d.k * d.k * d.c) as f64;
    for n in 0..d.n {
        let mut y = 0;
        while y < d.h {
            let rows = grid.rows_per_tile.min(d.h - y);
            let mut p = 0;
            while p < panels {
                let np = grid.panels_per_tile.min(panels - p);
                let (_, jw) = ops::panel_window(d.co, p, np);
                dag.add(
                    format!("conv[n{n},y{y}+{rows},p{p}]"),
                    cost_per_el * (rows * jw) as f64,
                    &[],
                    ConvTile { n, y0: y, rows, p0: p, np },
                );
                p += np;
            }
            y += rows;
        }
    }
    dag
}

/// Execute a SAME conv layer with the task-parallel decomposition on the
/// pool; numerically identical to `ops::conv2d_same_fwd`. The tile grid
/// comes from the planner: row tiles at `rows_per_task` granularity, plus
/// output-channel panel tiles when row tiles alone cannot feed the workers
/// (small batch × small H).
///
/// Dispatch is zero-copy (`x`/`f`/`bias` are borrowed by the tasks, the
/// filter is packed once and shared) and the task body is allocation-free
/// (im2col scratch comes from the executing worker's [`ScratchArena`]).
pub fn conv2d_parallel(
    pool: &ThreadPool,
    d: &ConvDims,
    x: &[f32],
    f: &[f32],
    bias: &[f32],
    out: &mut [f32],
    rows_per_task: usize,
) -> ScheduleStats {
    let packed = ops::pack_filter(d, f);
    let grid = plan_tile_grid(d.n * d.h, d.k * d.k * d.c, d.co, pool.size(), rows_per_task);
    conv2d_parallel_packed(pool, d, x, &packed, bias, out, grid)
}

/// [`conv2d_parallel`] on a caller-provided filter pack and tile grid — the
/// form the workspace train step uses, so the per-layer pack comes from the
/// network's [`crate::nn::WeightPacks`] cache instead of being rebuilt
/// every call, and the grid from the step's [`crate::inner::TilePolicy`]
/// plan. Wraps [`conv2d_parallel_packed_ws`] with a throwaway lowering
/// buffer (only touched when the grid column-splits); hot loops pass a
/// persistent one instead.
pub fn conv2d_parallel_packed(
    pool: &ThreadPool,
    d: &ConvDims,
    x: &[f32],
    packed: &ops::PackedB,
    bias: &[f32],
    out: &mut [f32],
    grid: TileGrid,
) -> ScheduleStats {
    let mut lower = Vec::new();
    conv2d_parallel_packed_ws(pool, d, x, packed, bias, out, grid, &mut lower)
}

/// One task of the column-split conv DAG: a [`ConvLowerStage::Lower`] task
/// lowers one (image × row-range) patch matrix **once** into the shared
/// scratch; the [`ConvLowerStage::Tile`] tasks of that row range depend on
/// it and contract disjoint panel windows of the shared patches. Before
/// this, every panel tile of a row range re-ran the same im2col — work the
/// autotuner would mis-attribute to grid shape.
#[derive(Debug, Clone, Copy)]
pub enum ConvLowerStage {
    Lower { off: usize, len: usize, n: usize, y0: usize, rows: usize },
    Tile { t: ConvTile, off: usize },
}

/// Build the column-split conv forward DAG: one `Lower` task per
/// (image, row-range) writing segment `[off, off+len)` of the shared
/// lowering scratch, plus that row range's panel `Tile` tasks depending on
/// it. Returns the DAG and the total lowering-scratch length. Extracted
/// from [`conv2d_parallel_packed_ws`] so the plan-sweep tests can verify
/// every planner-emitted schedule without executing it.
pub fn conv_lower_dag(d: &ConvDims, grid: &TileGrid) -> (TaskDag<ConvLowerStage>, usize) {
    let kkc = d.k * d.k * d.c;
    let panels = panel_count(d.co);
    let cost_per_el = (d.w * d.k * d.k * d.c) as f64;
    let mut dag: TaskDag<ConvLowerStage> = TaskDag::new();
    let mut total = 0usize;
    for n in 0..d.n {
        let mut y = 0;
        while y < d.h {
            let rows = grid.rows_per_tile.min(d.h - y);
            let len = rows * d.w * kkc;
            let off = total;
            total += len;
            let lid = dag.add(
                format!("conv_lower[n{n},y{y}+{rows}]"),
                len as f64,
                &[],
                ConvLowerStage::Lower { off, len, n, y0: y, rows },
            );
            let deps = [lid];
            let mut p = 0;
            while p < panels {
                let np = grid.panels_per_tile.min(panels - p);
                let (_, jw) = ops::panel_window(d.co, p, np);
                dag.add(
                    format!("conv[n{n},y{y}+{rows},p{p}]"),
                    cost_per_el * (rows * jw) as f64,
                    &deps,
                    ConvLowerStage::Tile { t: ConvTile { n, y0: y, rows, p0: p, np }, off },
                );
                p += np;
            }
            y += rows;
        }
    }
    (dag, total)
}

/// Access claims of the row-only conv forward DAG: each tile writes the
/// strided (patch-row × column-window) block of the output it owns. The
/// input/filter/bias are stage-wide read-only and carry no claims.
pub fn conv_fwd_claims(d: &ConvDims, dag: &TaskDag<ConvTile>) -> Vec<check::Claim> {
    let mut claims = Vec::with_capacity(dag.len());
    for node in dag.nodes() {
        let t = &node.payload;
        let (j0, jw) = ops::panel_window(d.co, t.p0, t.np);
        let base = (t.n * d.h + t.y0) * d.w * d.co;
        claims.push(check::Claim::write(
            node.id,
            check::Buf::Out,
            check::Span::strided(base + j0, t.rows * d.w, d.co, jw),
        ));
    }
    claims
}

/// Access claims of the column-split conv forward DAG ([`conv_lower_dag`]):
/// `Lower` tasks write disjoint segments of the shared lowering scratch;
/// `Tile` tasks read their row range's segment (ordered behind the Lower
/// dependency) and write their strided output block.
pub fn conv_lower_claims(d: &ConvDims, dag: &TaskDag<ConvLowerStage>) -> Vec<check::Claim> {
    let kkc = d.k * d.k * d.c;
    let mut claims = Vec::with_capacity(2 * dag.len());
    for node in dag.nodes() {
        match node.payload {
            ConvLowerStage::Lower { off, len, .. } => {
                claims.push(check::Claim::write(
                    node.id,
                    check::Buf::Lower,
                    check::Span::interval(off, len),
                ));
            }
            ConvLowerStage::Tile { t, off } => {
                let (j0, jw) = ops::panel_window(d.co, t.p0, t.np);
                let patches = t.rows * d.w;
                let base = (t.n * d.h + t.y0) * d.w * d.co;
                claims.push(check::Claim::read(
                    node.id,
                    check::Buf::Lower,
                    check::Span::interval(off, patches * kkc),
                ));
                claims.push(check::Claim::write(
                    node.id,
                    check::Buf::Out,
                    check::Span::strided(base + j0, patches, d.co, jw),
                ));
            }
        }
    }
    claims
}

/// [`conv2d_parallel_packed`] with a caller-owned lowering buffer. Row-only
/// grids keep the pre-2D path: each tile lowers its own rows into the
/// executing worker's arena (no shared buffer, nothing grows). Column-split
/// grids lower each (image, row-range) patch matrix exactly once into
/// `lower` (level-0 tasks writing disjoint segments) and the row range's
/// panel tiles read it behind the scheduler's dependency wait — the im2col
/// cost no longer multiplies with the column-tile count.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_parallel_packed_ws(
    pool: &ThreadPool,
    d: &ConvDims,
    x: &[f32],
    packed: &ops::PackedB,
    bias: &[f32],
    out: &mut [f32],
    grid: TileGrid,
    lower: &mut Vec<f32>,
) -> ScheduleStats {
    assert_eq!(out.len(), d.y_len());
    assert_eq!(x.len(), d.x_len());
    assert_eq!(packed.n(), d.co);
    grid.check();
    let dd = *d;
    let kkc = dd.k * dd.k * dd.c;
    if grid.panel_tiles <= 1 {
        let dag = conv_tile_dag(d, &grid);
        let guard = check::stage_guard(&dag, || conv_fwd_claims(d, &dag));
        let shared = DisjointBuf::new(out).checked(check::Buf::Out, &guard);
        let arenas = pool.arenas();
        return execute_dag(pool, dag, move |worker: usize, t: &ConvTile| {
            let (j0, jw) = ops::panel_window(dd.co, t.p0, t.np);
            let patches = t.rows * dd.w;
            let base = (t.n * dd.h + t.y0) * dd.w * dd.co;
            // Bias-seed the tile's column window, one patch row at a time.
            // SAFETY: tile (n, y0, rows, p0, np) exclusively owns these
            // (row × column-window) elements; windows never overlap across
            // concurrent tiles.
            for px in 0..patches {
                let row = unsafe { shared.slice_mut(base + px * dd.co + j0, jw) };
                row.copy_from_slice(&bias[j0..j0 + jw]);
            }
            // Worker-persistent im2col scratch (uncontended: only worker
            // `worker` runs tasks pinned to it, one at a time).
            let mut arena = arenas[worker].lock().unwrap();
            let cols = ScratchArena::grow(&mut arena.cols, patches * kkc);
            ops::im2col_rows(&dd, x, t.n, t.y0, t.rows, cols);
            // SAFETY: the panel-windowed GEMM writes only the column window
            // this tile owns.
            unsafe {
                ops::gemm_packed_acc_panels_raw(
                    patches,
                    cols,
                    packed,
                    shared.ptr_at(base),
                    t.p0,
                    t.np,
                );
            }
        });
    }
    // Column-split grid: lower once per (image, row-range), contract per
    // panel window.
    let (dag, total) = conv_lower_dag(d, &grid);
    let guard = check::stage_guard(&dag, || conv_lower_claims(d, &dag));
    let lslice = ScratchArena::grow(lower, total);
    let lbuf = DisjointBuf::new(lslice).checked(check::Buf::Lower, &guard);
    let shared = DisjointBuf::new(out).checked(check::Buf::Out, &guard);
    execute_dag(pool, dag, move |_worker: usize, task: &ConvLowerStage| match *task {
        ConvLowerStage::Lower { off, len, n, y0, rows } => {
            // SAFETY: each Lower task exclusively owns its scratch segment.
            let cols = unsafe { lbuf.slice_mut(off, len) };
            ops::im2col_rows(&dd, x, n, y0, rows, cols);
        }
        ConvLowerStage::Tile { t, off } => {
            let (j0, jw) = ops::panel_window(dd.co, t.p0, t.np);
            let patches = t.rows * dd.w;
            let base = (t.n * dd.h + t.y0) * dd.w * dd.co;
            // SAFETY: tile (n, y0, rows, p0, np) exclusively owns its
            // (row × column-window) output elements.
            for px in 0..patches {
                let row = unsafe { shared.slice_mut(base + px * dd.co + j0, jw) };
                row.copy_from_slice(&bias[j0..j0 + jw]);
            }
            // SAFETY: the DAG dependency guarantees this segment was fully
            // lowered and is no longer written — shared reads are sound.
            let cols = unsafe { lbuf.slice_ref(off, patches * kkc) };
            // SAFETY: the panel-windowed GEMM writes only the column window
            // this tile owns.
            unsafe {
                ops::gemm_packed_acc_panels_raw(
                    patches,
                    cols,
                    packed,
                    shared.ptr_at(base),
                    t.p0,
                    t.np,
                );
            }
        }
    })
}

/// K_C of Eq. 13 (stride 1, SAME padding ⇒ output H×W), per image.
pub fn kc(d: &ConvDims) -> usize {
    d.kc()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn rand_vec(rng: &mut Xoshiro256, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect()
    }

    #[test]
    fn task_count_matches_decomposition() {
        let d = ConvDims { n: 2, h: 8, w: 8, c: 1, k: 3, co: 4 };
        assert_eq!(conv_task_dag(&d, 1).len(), 2 * 8);
        assert_eq!(conv_task_dag(&d, 4).len(), 2 * 2);
        assert_eq!(conv_task_dag(&d, 3).len(), 2 * 3); // 3+3+2 rows
        assert_eq!(kc(&d), 64);
    }

    #[test]
    fn parallel_matches_serial_all_granularities() {
        let mut rng = Xoshiro256::new(10);
        let d = ConvDims { n: 3, h: 7, w: 6, c: 2, k: 3, co: 4 };
        let x = rand_vec(&mut rng, d.x_len());
        let f = rand_vec(&mut rng, d.f_len());
        let b = rand_vec(&mut rng, d.co);
        let mut serial = vec![0.0; d.y_len()];
        ops::conv2d_same_fwd(&d, &x, &f, &b, &mut serial);
        let pool = ThreadPool::new(4);
        for rows in [1, 2, 3, 7] {
            let mut par = vec![0.0; d.y_len()];
            let stats = conv2d_parallel(&pool, &d, &x, &f, &b, &mut par, rows);
            assert_eq!(stats.tasks, conv_task_dag(&d, rows).len());
            for (a, bb) in par.iter().zip(serial.iter()) {
                assert!((a - bb).abs() < 1e-5, "rows={rows}");
            }
        }
    }

    #[test]
    fn tasks_are_independent_level_zero() {
        let d = ConvDims { n: 1, h: 4, w: 4, c: 1, k: 3, co: 1 };
        let dag = conv_task_dag(&d, 1);
        assert!(dag.levels().iter().all(|&l| l == 0));
        // Critical path == one task's cost (full parallelism, Eq. 15).
        let max_cost = dag.nodes().iter().map(|n| n.cost).fold(0.0, f64::max);
        assert_eq!(dag.critical_path_cost(), max_cost);
    }

    /// Scratch contents left behind by a previous (larger) layer call must
    /// not leak into later results: run a big conv to fill every worker's
    /// arena with data, then a smaller conv on the same pool, and check the
    /// small conv against the serial reference.
    #[test]
    fn arena_reuse_does_not_leak_between_layer_calls() {
        let mut rng = Xoshiro256::new(21);
        let pool = ThreadPool::new(4);
        let big = ConvDims { n: 4, h: 12, w: 10, c: 5, k: 5, co: 7 };
        let bx = rand_vec(&mut rng, big.x_len());
        let bf = rand_vec(&mut rng, big.f_len());
        let bb = rand_vec(&mut rng, big.co);
        let mut bout = vec![0.0; big.y_len()];
        conv2d_parallel(&pool, &big, &bx, &bf, &bb, &mut bout, 1);

        let small = ConvDims { n: 2, h: 5, w: 4, c: 2, k: 3, co: 3 };
        let sx = rand_vec(&mut rng, small.x_len());
        let sf = rand_vec(&mut rng, small.f_len());
        let sb = rand_vec(&mut rng, small.co);
        let mut serial = vec![0.0; small.y_len()];
        ops::conv2d_same_fwd(&small, &sx, &sf, &sb, &mut serial);
        let mut par = vec![0.0; small.y_len()];
        conv2d_parallel(&pool, &small, &sx, &sf, &sb, &mut par, 2);
        for (a, b) in par.iter().zip(serial.iter()) {
            assert!((a - b).abs() < 1e-5, "stale arena contents leaked: {a} vs {b}");
        }
    }

    /// Forced column tiles (co spanning several NR panels) match the serial
    /// reference at every panel granularity — including the ragged final
    /// panel and 1×1-ish spatial dims where rows alone cannot parallelize.
    #[test]
    fn column_tiles_match_serial_at_all_panel_granularities() {
        let mut rng = Xoshiro256::new(31);
        for d in [
            ConvDims { n: 2, h: 3, w: 4, c: 3, k: 3, co: 20 }, // 3 panels, ragged
            ConvDims { n: 2, h: 1, w: 1, c: 2, k: 1, co: 17 }, // 1×1 spatial
        ] {
            let x = rand_vec(&mut rng, d.x_len());
            let f = rand_vec(&mut rng, d.f_len());
            let b = rand_vec(&mut rng, d.co);
            let mut serial = vec![0.0; d.y_len()];
            ops::conv2d_same_fwd(&d, &x, &f, &b, &mut serial);
            let packed = ops::pack_filter(&d, &f);
            let pool = ThreadPool::new(4);
            let panels = panel_count(d.co);
            for ppt in 1..=panels {
                let grid = TileGrid {
                    rows_per_tile: 2.min(d.h),
                    row_tiles: (d.n * d.h + 1) / 2.min(d.h),
                    panels_per_tile: ppt,
                    panel_tiles: (panels + ppt - 1) / ppt,
                };
                let mut par = vec![0.0; d.y_len()];
                let stats = conv2d_parallel_packed(&pool, &d, &x, &packed, &b, &mut par, grid);
                assert!(stats.tasks >= grid.panel_tiles, "ppt={ppt}");
                for (a, bb) in par.iter().zip(serial.iter()) {
                    assert!((a - bb).abs() < 1e-5, "ppt={ppt} ({d:?}): {a} vs {bb}");
                }
            }
        }
    }

    /// Column-split grids take the shared-lowering DAG (one im2col per
    /// (image, row-range), panel tiles contracting the shared buffer): the
    /// output must be **bit-identical** to the row-only path (panel windows
    /// have independent accumulators), the DAG must contain the extra Lower
    /// tasks, and the caller's lowering buffer must be reused across calls.
    #[test]
    fn shared_lowering_matches_rowonly_bitwise() {
        let mut rng = Xoshiro256::new(33);
        let d = ConvDims { n: 2, h: 4, w: 5, c: 3, k: 3, co: 20 }; // 3 panels
        let x = rand_vec(&mut rng, d.x_len());
        let f = rand_vec(&mut rng, d.f_len());
        let b = rand_vec(&mut rng, d.co);
        let packed = ops::pack_filter(&d, &f);
        let pool = ThreadPool::new(4);
        let rows_only = TileGrid::rows_only(d.n * d.h, 2, d.co);
        let mut base = vec![0.0; d.y_len()];
        let s0 = conv2d_parallel_packed(&pool, &d, &x, &packed, &b, &mut base, rows_only);
        let panels = panel_count(d.co);
        let split = TileGrid {
            rows_per_tile: 2,
            row_tiles: (d.n * d.h + 1) / 2,
            panels_per_tile: 1,
            panel_tiles: panels,
        };
        let mut lower = Vec::new();
        let mut out = vec![0.0; d.y_len()];
        let s1 = conv2d_parallel_packed_ws(&pool, &d, &x, &packed, &b, &mut out, split, &mut lower);
        assert_eq!(out, base, "shared-lowering path is not bit-identical");
        // One Lower task per (image, row-range) on top of the panel tiles.
        let row_ranges = d.n * ((d.h + 1) / 2);
        assert_eq!(s1.tasks, s0.tasks + row_ranges * panels, "{s1:?} vs {s0:?}");
        // The lowering buffer was sized for all segments and is reused.
        let kkc = d.k * d.k * d.c;
        assert!(lower.len() >= row_ranges * 2 * d.w * kkc - d.w * kkc);
        let cap = lower.capacity();
        let mut out2 = vec![0.0; d.y_len()];
        conv2d_parallel_packed_ws(&pool, &d, &x, &packed, &b, &mut out2, split, &mut lower);
        assert_eq!(out2, base);
        assert_eq!(lower.capacity(), cap, "second call reallocated the lowering buffer");
    }

    #[test]
    fn disjoint_buf_bounds_checked() {
        let mut buf = vec![0.0f32; 8];
        let db = DisjointBuf::new(&mut buf);
        // SAFETY: the window is deliberately out of bounds — the accessor
        // must panic before any slice is created.
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
            db.slice_mut(6, 4);
        }));
        assert!(res.is_err());
        // SAFETY: offset+len overflows usize — must panic, not wrap into a
        // bogus in-bounds window.
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
            db.slice_ref(usize::MAX, 2);
        }));
        assert!(res.is_err(), "overflowing window wrapped instead of panicking");
    }

    /// Aliasing-model target (run under Miri in the sanitizers workflow):
    /// two live disjoint `&mut` windows plus a later shared view must be
    /// sound and see the written values.
    #[test]
    fn disjoint_buf_windows_do_not_alias() {
        let mut buf = vec![0.0f32; 16];
        let db = DisjointBuf::new(&mut buf);
        // SAFETY: [0,8) and [8,16) are disjoint windows.
        let (a, b) = unsafe { (db.slice_mut(0, 8), db.slice_mut(8, 8)) };
        a.fill(1.0);
        b.fill(2.0);
        // SAFETY: the mutable windows above are no longer used.
        let r = unsafe { db.slice_ref(0, 16) };
        assert_eq!(&r[..8], &[1.0; 8]);
        assert_eq!(&r[8..], &[2.0; 8]);
        assert_eq!(db.ptr_at(16), db.ptr_at(0).wrapping_add(16));
    }
}
