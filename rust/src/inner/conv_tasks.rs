//! Convolution-layer task decomposition — Algorithm 4.1 (§4.1.1).
//!
//! The paper extracts every convolution area of the input matrix (Eq. 14)
//! and convolves them in parallel with the shared filter (Fig. 6). Its
//! maximum parallelism degree is `K_C = H_a × W_a` (Eq. 13) — one task per
//! output element. At CPU-thread granularity one scalar per task drowns in
//! scheduling overhead, so the decomposition here groups whole output *rows*
//! into one task (`rows_per_task` tunes the granularity; `1` row ≈ `W_a`
//! paper-tasks fused — the ablation bench sweeps this knob).
//!
//! Each task executes its row tile through the im2col + packed-GEMM fast
//! path ([`crate::nn::ops::conv2d_same_rows_packed`]): the filter is packed
//! once per layer call ([`crate::nn::ops::pack_filter`]) and shared
//! read-only by every task, patch scratch comes from the executing worker's
//! persistent [`ScratchArena`], and the input/filter/bias tensors are
//! **borrowed** by the tasks (the scheduler's completion barrier makes that
//! sound) — the task body performs no heap allocation and dispatch copies no
//! tensor.
//!
//! Tasks write disjoint row slices of the shared output buffer through
//! [`DisjointBuf`], the lock-free analogue of the paper's observation that
//! "different tasks can access different convolution areas simultaneously…
//! without data dependence".

use crate::nn::ops::{self, ConvDims};
use crate::util::threadpool::{ScratchArena, ThreadPool};

use super::dag::TaskDag;
use super::scheduler::{execute_dag, ScheduleStats};

/// A buffer whose tasks write provably disjoint regions concurrently.
///
/// Safety contract: every (offset, len) window handed out via `slice_mut`
/// must be disjoint across concurrently running tasks. The conv
/// decomposition guarantees this structurally: task (n, y) owns exactly
/// rows `[y, y+rows)` of image `n`.
pub struct DisjointBuf {
    ptr: *mut f32,
    len: usize,
}

unsafe impl Send for DisjointBuf {}
unsafe impl Sync for DisjointBuf {}

impl DisjointBuf {
    pub fn new(buf: &mut [f32]) -> Self {
        Self { ptr: buf.as_mut_ptr(), len: buf.len() }
    }

    /// # Safety
    /// Callers must ensure `[offset, offset+len)` windows of concurrent
    /// calls do not overlap.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, offset: usize, len: usize) -> &mut [f32] {
        assert!(offset + len <= self.len, "disjoint window out of bounds");
        std::slice::from_raw_parts_mut(self.ptr.add(offset), len)
    }
}

/// Payload of one convolution task: image index + row range.
#[derive(Debug, Clone, Copy)]
pub struct ConvTask {
    pub n: usize,
    pub y0: usize,
    pub rows: usize,
}

/// Build the Algorithm 4.1 task list for one SAME conv layer: `K_C` output
/// areas grouped `rows_per_task` rows at a time (per image). All tasks are
/// independent (level-0 DAG), mirroring Fig. 6.
pub fn conv_task_dag(d: &ConvDims, rows_per_task: usize) -> TaskDag<ConvTask> {
    assert!(rows_per_task >= 1);
    let mut dag = TaskDag::new();
    // Cost model: rows × W output elements × k²·C·O MACs each.
    let cost_per_row = (d.w * d.k * d.k * d.c * d.co) as f64;
    for n in 0..d.n {
        let mut y = 0;
        while y < d.h {
            let rows = rows_per_task.min(d.h - y);
            dag.add(
                format!("conv[n{n},y{y}+{rows}]"),
                cost_per_row * rows as f64,
                &[],
                ConvTask { n, y0: y, rows },
            );
            y += rows;
        }
    }
    dag
}

/// Execute a SAME conv layer with the task-parallel decomposition on the
/// pool; numerically identical to `ops::conv2d_same_fwd`.
///
/// Dispatch is zero-copy (`x`/`f`/`bias` are borrowed by the tasks, the
/// filter is packed once and shared) and the task body is allocation-free
/// (im2col scratch comes from the executing worker's [`ScratchArena`]).
pub fn conv2d_parallel(
    pool: &ThreadPool,
    d: &ConvDims,
    x: &[f32],
    f: &[f32],
    bias: &[f32],
    out: &mut [f32],
    rows_per_task: usize,
) -> ScheduleStats {
    let packed = ops::pack_filter(d, f);
    conv2d_parallel_packed(pool, d, x, &packed, bias, out, rows_per_task)
}

/// [`conv2d_parallel`] on a caller-provided filter pack — the form the
/// workspace train step uses, so the per-layer pack comes from the
/// network's [`crate::nn::WeightPacks`] cache instead of being rebuilt
/// every call.
pub fn conv2d_parallel_packed(
    pool: &ThreadPool,
    d: &ConvDims,
    x: &[f32],
    packed: &ops::PackedB,
    bias: &[f32],
    out: &mut [f32],
    rows_per_task: usize,
) -> ScheduleStats {
    assert_eq!(out.len(), d.y_len());
    assert_eq!(x.len(), d.x_len());
    let dag = conv_task_dag(d, rows_per_task);
    let shared = DisjointBuf::new(out);
    let row_len = d.w * d.co;
    let dd = *d;
    let kkc = dd.k * dd.k * dd.c;
    let arenas = pool.arenas();
    execute_dag(pool, dag, move |worker: usize, task: &ConvTask| {
        let offset = (task.n * dd.h + task.y0) * row_len;
        let len = task.rows * row_len;
        // SAFETY: task (n, y0, rows) exclusively owns output rows
        // [y0, y0+rows) of image n; ranges never overlap across tasks.
        let tile = unsafe { shared.slice_mut(offset, len) };
        // Worker-persistent im2col scratch (uncontended: only worker
        // `worker` runs tasks pinned to it, one at a time).
        let mut arena = arenas[worker].lock().unwrap();
        let cols = ScratchArena::grow(&mut arena.cols, task.rows * dd.w * kkc);
        ops::conv2d_same_rows_packed(
            &dd, x, packed, bias, task.n, task.y0, task.rows, cols, tile,
        );
    })
}

/// K_C of Eq. 13 (stride 1, SAME padding ⇒ output H×W), per image.
pub fn kc(d: &ConvDims) -> usize {
    d.kc()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn rand_vec(rng: &mut Xoshiro256, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect()
    }

    #[test]
    fn task_count_matches_decomposition() {
        let d = ConvDims { n: 2, h: 8, w: 8, c: 1, k: 3, co: 4 };
        assert_eq!(conv_task_dag(&d, 1).len(), 2 * 8);
        assert_eq!(conv_task_dag(&d, 4).len(), 2 * 2);
        assert_eq!(conv_task_dag(&d, 3).len(), 2 * 3); // 3+3+2 rows
        assert_eq!(kc(&d), 64);
    }

    #[test]
    fn parallel_matches_serial_all_granularities() {
        let mut rng = Xoshiro256::new(10);
        let d = ConvDims { n: 3, h: 7, w: 6, c: 2, k: 3, co: 4 };
        let x = rand_vec(&mut rng, d.x_len());
        let f = rand_vec(&mut rng, d.f_len());
        let b = rand_vec(&mut rng, d.co);
        let mut serial = vec![0.0; d.y_len()];
        ops::conv2d_same_fwd(&d, &x, &f, &b, &mut serial);
        let pool = ThreadPool::new(4);
        for rows in [1, 2, 3, 7] {
            let mut par = vec![0.0; d.y_len()];
            let stats = conv2d_parallel(&pool, &d, &x, &f, &b, &mut par, rows);
            assert_eq!(stats.tasks, conv_task_dag(&d, rows).len());
            for (a, bb) in par.iter().zip(serial.iter()) {
                assert!((a - bb).abs() < 1e-5, "rows={rows}");
            }
        }
    }

    #[test]
    fn tasks_are_independent_level_zero() {
        let d = ConvDims { n: 1, h: 4, w: 4, c: 1, k: 3, co: 1 };
        let dag = conv_task_dag(&d, 1);
        assert!(dag.levels().iter().all(|&l| l == 0));
        // Critical path == one task's cost (full parallelism, Eq. 15).
        let max_cost = dag.nodes().iter().map(|n| n.cost).fold(0.0, f64::max);
        assert_eq!(dag.critical_path_cost(), max_cost);
    }

    /// Scratch contents left behind by a previous (larger) layer call must
    /// not leak into later results: run a big conv to fill every worker's
    /// arena with data, then a smaller conv on the same pool, and check the
    /// small conv against the serial reference.
    #[test]
    fn arena_reuse_does_not_leak_between_layer_calls() {
        let mut rng = Xoshiro256::new(21);
        let pool = ThreadPool::new(4);
        let big = ConvDims { n: 4, h: 12, w: 10, c: 5, k: 5, co: 7 };
        let bx = rand_vec(&mut rng, big.x_len());
        let bf = rand_vec(&mut rng, big.f_len());
        let bb = rand_vec(&mut rng, big.co);
        let mut bout = vec![0.0; big.y_len()];
        conv2d_parallel(&pool, &big, &bx, &bf, &bb, &mut bout, 1);

        let small = ConvDims { n: 2, h: 5, w: 4, c: 2, k: 3, co: 3 };
        let sx = rand_vec(&mut rng, small.x_len());
        let sf = rand_vec(&mut rng, small.f_len());
        let sb = rand_vec(&mut rng, small.co);
        let mut serial = vec![0.0; small.y_len()];
        ops::conv2d_same_fwd(&small, &sx, &sf, &sb, &mut serial);
        let mut par = vec![0.0; small.y_len()];
        conv2d_parallel(&pool, &small, &sx, &sf, &sb, &mut par, 2);
        for (a, b) in par.iter().zip(serial.iter()) {
            assert!((a - b).abs() < 1e-5, "stale arena contents leaked: {a} vs {b}");
        }
    }

    #[test]
    fn disjoint_buf_bounds_checked() {
        let mut buf = vec![0.0f32; 8];
        let db = DisjointBuf::new(&mut buf);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
            db.slice_mut(6, 4);
        }));
        assert!(res.is_err());
    }
}
