//! Dense-layer / pool / ReLU / loss task decomposition — the §4.1.2 stages
//! that are *not* convolutions, so the **full** local weight-training step
//! rides the thread pool, not just the conv stack (Dryden et al.,
//! arXiv:1903.06681, make the case that fine-grained parallelism across all
//! layer types is what unlocks strong scaling; Jia et al., arXiv:1802.04924,
//! specifically for FC layers).
//!
//! Decomposition mirrors `conv_tasks`/`bp_tasks`:
//! * **FC forward/backward** — batch-row tiles contracted on the shared
//!   packed-B 4×8 micro-kernel (`gemm_packed_acc` over a weight pack cached
//!   in the network's [`crate::nn::WeightPacks`]); backward tiles accumulate
//!   their dW/db partials into the *executing worker's* persistent
//!   [`ScratchArena`] and a sequential post-barrier reduce combines them —
//!   no mutex in any task body, no per-task allocation.
//! * **ReLU** — fused into the producing/consuming tile where possible
//!   (forward tiles apply it before writing; backward tiles mask their `dy`
//!   rows in place), with standalone chunk tasks for the conv activations.
//! * **Pool** — one task per image, disjoint output slices.
//! * **Loss** — row tiles write disjoint `dlogits`/`probs` rows and report
//!   per-task (Σerr², correct) partials into caller-provided slots.

use crate::nn::ops::{self, PackedB};
use crate::util::threadpool::{ScratchArena, ThreadPool};

use super::conv_tasks::DisjointBuf;
use super::dag::TaskDag;
use super::scheduler::{execute_dag, ScheduleStats};

/// One batch-row tile: rows `[i0, i0+rows)` of a `(m, ·)` matrix.
#[derive(Debug, Clone, Copy)]
pub struct RowTask {
    pub i0: usize,
    pub rows: usize,
}

fn row_tile_dag(
    m: usize,
    rows_per_task: usize,
    cost_per_row: f64,
    label: &str,
) -> TaskDag<RowTask> {
    assert!(rows_per_task >= 1);
    let mut dag = TaskDag::new();
    let mut i = 0;
    while i < m {
        let rows = rows_per_task.min(m - i);
        dag.add(
            format!("{label}[i{i}+{rows}]"),
            cost_per_row * rows as f64,
            &[],
            RowTask { i0: i, rows },
        );
        i += rows;
    }
    dag
}

/// Typed analogue of [`DisjointBuf`] for the loss stage's per-task result
/// slots. Safety contract: concurrent tasks write distinct indices.
struct DisjointSlots<T> {
    ptr: *mut T,
    len: usize,
}

unsafe impl<T: Send> Send for DisjointSlots<T> {}
unsafe impl<T: Send> Sync for DisjointSlots<T> {}

impl<T> DisjointSlots<T> {
    fn new(s: &mut [T]) -> Self {
        Self { ptr: s.as_mut_ptr(), len: s.len() }
    }

    /// # Safety
    /// Concurrent calls must use distinct `i`.
    unsafe fn set(&self, i: usize, v: T) {
        assert!(i < self.len, "slot out of bounds");
        *self.ptr.add(i) = v;
    }
}

/// Dense forward `out = x · W + b` (optionally fused ReLU) as batch-row
/// tiles on the pool. `w` is the layer's cached weight pack, shared
/// read-only by every tile; tiles write disjoint row slices, task bodies
/// allocate nothing. Numerically ≡ [`ops::dense_fwd_packed`].
#[allow(clippy::too_many_arguments)]
pub fn dense_fwd_parallel(
    pool: &ThreadPool,
    m: usize,
    x: &[f32],
    w: &PackedB,
    bias: &[f32],
    out: &mut [f32],
    relu: bool,
    rows_per_task: usize,
) -> ScheduleStats {
    let (k, n) = (w.kk(), w.n());
    assert_eq!(x.len(), m * k);
    assert_eq!(bias.len(), n);
    assert_eq!(out.len(), m * n);
    let dag = row_tile_dag(m, rows_per_task, (2 * k * n) as f64, "dense_fwd");
    let shared = DisjointBuf::new(out);
    execute_dag(pool, dag, move |_worker, task: &RowTask| {
        // SAFETY: tile (i0, rows) exclusively owns out rows [i0, i0+rows).
        let tile = unsafe { shared.slice_mut(task.i0 * n, task.rows * n) };
        let xt = &x[task.i0 * k..(task.i0 + task.rows) * k];
        ops::dense_fwd_packed(task.rows, xt, w, bias, tile);
        if relu {
            ops::relu_fwd(tile);
        }
    })
}

/// Dense backward as batch-row tiles: each tile (optionally) applies the
/// ReLU mask to its `dy` rows in place, computes its `dx` rows on the
/// packed transpose (`dx = dy · Wᵀ`), and accumulates its dW/db partial
/// into the executing worker's [`ScratchArena`]; the partials are reduced
/// sequentially after the barrier, exactly like `bp_tasks`. Numerically ≡
/// `relu_bwd` (when `relu_out` is given) followed by
/// [`ops::dense_bwd_packed`], to f32 reduction-order tolerance in dW/db.
#[allow(clippy::too_many_arguments)]
pub fn dense_bwd_parallel(
    pool: &ThreadPool,
    m: usize,
    k: usize,
    n: usize,
    x: &[f32],
    wt: &PackedB,
    dy: &mut [f32],
    relu_out: Option<&[f32]>,
    dx: &mut [f32],
    dw: &mut [f32],
    db: &mut [f32],
    rows_per_task: usize,
) -> ScheduleStats {
    assert_eq!(wt.kk(), n, "wt must be the transposed pack");
    assert_eq!(wt.n(), k, "wt must be the transposed pack");
    assert_eq!(x.len(), m * k);
    assert_eq!(dy.len(), m * n);
    assert_eq!(dx.len(), m * k);
    assert_eq!(dw.len(), k * n);
    assert_eq!(db.len(), n);
    if let Some(r) = relu_out {
        assert_eq!(r.len(), m * n);
    }
    // Size + zero each worker's gradient accumulators for this layer call.
    for arena in pool.arenas() {
        let mut g = arena.lock().unwrap();
        ScratchArena::grow_zeroed(&mut g.grad_f, k * n);
        ScratchArena::grow_zeroed(&mut g.grad_b, n);
    }
    let dag = row_tile_dag(m, rows_per_task, (4 * k * n) as f64, "dense_bwd");
    let dy_buf = DisjointBuf::new(dy);
    let dx_buf = DisjointBuf::new(dx);
    let arenas = pool.arenas();
    let stats = execute_dag(pool, dag, move |worker, task: &RowTask| {
        // SAFETY: tile (i0, rows) exclusively owns its dy and dx rows.
        let dyt = unsafe { dy_buf.slice_mut(task.i0 * n, task.rows * n) };
        let dxt = unsafe { dx_buf.slice_mut(task.i0 * k, task.rows * k) };
        if let Some(out) = relu_out {
            ops::relu_bwd(&out[task.i0 * n..(task.i0 + task.rows) * n], dyt);
        }
        let xt = &x[task.i0 * k..(task.i0 + task.rows) * k];
        let mut arena = arenas[worker].lock().unwrap();
        let arena = &mut *arena;
        dxt.fill(0.0);
        ops::gemm_packed_acc(task.rows, dyt, wt, dxt);
        ops::gemm_tn_acc(task.rows, k, n, xt, dyt, &mut arena.grad_f[..k * n]);
        let gb = &mut arena.grad_b[..n];
        for row in dyt.chunks_exact(n) {
            for (acc, &v) in gb.iter_mut().zip(row.iter()) {
                *acc += v;
            }
        }
    });
    // Sequential reduce of the per-worker partials (the Fig.-9 reduce node).
    dw.fill(0.0);
    db.fill(0.0);
    for arena in pool.arenas() {
        let g = arena.lock().unwrap();
        for (acc, &v) in dw.iter_mut().zip(g.grad_f.iter()) {
            *acc += v;
        }
        for (acc, &v) in db.iter_mut().zip(g.grad_b.iter()) {
            *acc += v;
        }
    }
    stats
}

/// Mean-pool forward, one task per image (disjoint output slices).
#[allow(clippy::too_many_arguments)]
pub fn mean_pool_fwd_parallel(
    pool: &ThreadPool,
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    win: usize,
    x: &[f32],
    out: &mut [f32],
) -> ScheduleStats {
    let (ho, wo) = (h / win, w / win);
    assert_eq!(x.len(), n * h * w * c);
    assert_eq!(out.len(), n * ho * wo * c);
    let mut dag: TaskDag<usize> = TaskDag::new();
    for i in 0..n {
        dag.add(format!("pool_fwd[{i}]"), (h * w * c) as f64, &[], i);
    }
    let img_in = h * w * c;
    let img_out = ho * wo * c;
    let shared = DisjointBuf::new(out);
    execute_dag(pool, dag, move |_, &i| {
        // SAFETY: image task i exclusively owns its output slice.
        let tile = unsafe { shared.slice_mut(i * img_out, img_out) };
        ops::mean_pool_fwd(1, h, w, c, win, &x[i * img_in..(i + 1) * img_in], tile);
    })
}

/// Mean-pool backward, one task per image (disjoint `dx` slices).
#[allow(clippy::too_many_arguments)]
pub fn mean_pool_bwd_parallel(
    pool: &ThreadPool,
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    win: usize,
    dy: &[f32],
    dx: &mut [f32],
) -> ScheduleStats {
    let (ho, wo) = (h / win, w / win);
    assert_eq!(dy.len(), n * ho * wo * c);
    assert_eq!(dx.len(), n * h * w * c);
    let mut dag: TaskDag<usize> = TaskDag::new();
    for i in 0..n {
        dag.add(format!("pool_bwd[{i}]"), (h * w * c) as f64, &[], i);
    }
    let img_in = h * w * c;
    let img_out = ho * wo * c;
    let shared = DisjointBuf::new(dx);
    execute_dag(pool, dag, move |_, &i| {
        // SAFETY: image task i exclusively owns its dx slice.
        let tile = unsafe { shared.slice_mut(i * img_in, img_in) };
        ops::mean_pool_bwd(1, h, w, c, win, &dy[i * img_out..(i + 1) * img_out], tile);
    })
}

/// Standalone ReLU stages for the conv activations (elementwise, chunked
/// across the pool; FC ReLUs are fused into their dense tiles instead).
pub fn relu_fwd_parallel(pool: &ThreadPool, buf: &mut [f32], chunks: usize) -> ScheduleStats {
    let n = buf.len();
    let per = (n / chunks.max(1)).max(1);
    let mut dag: TaskDag<(usize, usize)> = TaskDag::new();
    let mut i = 0;
    while i < n {
        let len = per.min(n - i);
        dag.add("relu_fwd", len as f64, &[], (i, len));
        i += len;
    }
    let shared = DisjointBuf::new(buf);
    execute_dag(pool, dag, move |_, &(off, len)| {
        // SAFETY: chunks tile the buffer disjointly.
        ops::relu_fwd(unsafe { shared.slice_mut(off, len) });
    })
}

/// Chunked `dx = dy · (out > 0)` mask (conv ReLU backward).
pub fn relu_bwd_parallel(
    pool: &ThreadPool,
    out: &[f32],
    dy: &mut [f32],
    chunks: usize,
) -> ScheduleStats {
    assert_eq!(out.len(), dy.len());
    let n = dy.len();
    let per = (n / chunks.max(1)).max(1);
    let mut dag: TaskDag<(usize, usize)> = TaskDag::new();
    let mut i = 0;
    while i < n {
        let len = per.min(n - i);
        dag.add("relu_bwd", len as f64, &[], (i, len));
        i += len;
    }
    let shared = DisjointBuf::new(dy);
    execute_dag(pool, dag, move |_, &(off, len)| {
        // SAFETY: chunks tile the buffer disjointly.
        ops::relu_bwd(&out[off..off + len], unsafe { shared.slice_mut(off, len) });
    })
}

/// Parallel Eq.-16 loss: row tiles write disjoint `dlogits`/`probs` rows
/// and per-task (Σerr², correct) partials into `parts`; the partials are
/// summed sequentially after the barrier. Numerically ≡
/// [`ops::mse_softmax_loss_into`] up to the f64 loss-sum grouping
/// (`dlogits` is bit-identical).
#[allow(clippy::too_many_arguments)]
pub fn loss_parallel(
    pool: &ThreadPool,
    m: usize,
    n: usize,
    logits: &[f32],
    y: &[f32],
    dlogits: &mut [f32],
    probs: &mut [f32],
    parts: &mut Vec<(f64, usize)>,
    rows_per_task: usize,
) -> (f32, usize, ScheduleStats) {
    assert!(rows_per_task >= 1);
    assert_eq!(logits.len(), m * n);
    assert_eq!(y.len(), m * n);
    assert_eq!(dlogits.len(), m * n);
    assert_eq!(probs.len(), m * n);
    let mut dag: TaskDag<(usize, RowTask)> = TaskDag::new();
    let mut i = 0;
    let mut slots = 0;
    while i < m {
        let rows = rows_per_task.min(m - i);
        dag.add(
            format!("loss[i{i}+{rows}]"),
            (rows * n) as f64,
            &[],
            (slots, RowTask { i0: i, rows }),
        );
        i += rows;
        slots += 1;
    }
    parts.clear();
    parts.resize(slots, (0.0, 0));
    let dl_buf = DisjointBuf::new(dlogits);
    let p_buf = DisjointBuf::new(probs);
    let part_slots = DisjointSlots::new(parts);
    let inv_b = 1.0 / m as f32;
    let stats = execute_dag(pool, dag, move |_, &(slot, task)| {
        let r0 = task.i0 * n;
        let rl = task.rows * n;
        // SAFETY: tiles own disjoint dlogits/probs rows and distinct slots.
        let dlt = unsafe { dl_buf.slice_mut(r0, rl) };
        let pt = unsafe { p_buf.slice_mut(r0, rl) };
        let lt = &logits[r0..r0 + rl];
        pt.copy_from_slice(lt);
        ops::softmax_rows(task.rows, n, pt);
        let part = ops::mse_softmax_rows(task.rows, n, lt, &y[r0..r0 + rl], dlt, pt, inv_b);
        unsafe { part_slots.set(slot, part) };
    });
    let mut loss = 0.0f64;
    let mut correct = 0usize;
    for &(l, c) in parts.iter() {
        loss += l;
        correct += c;
    }
    ((loss / m as f64) as f32, correct, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn rand_vec(rng: &mut Xoshiro256, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect()
    }

    #[test]
    fn dense_fwd_parallel_matches_serial_all_granularities() {
        let mut rng = Xoshiro256::new(41);
        let (m, k, n) = (7usize, 10usize, 9usize); // ragged on purpose
        let x = rand_vec(&mut rng, m * k);
        let w = rand_vec(&mut rng, k * n);
        let b = rand_vec(&mut rng, n);
        let packed = PackedB::pack(k, n, &w);
        let mut serial = vec![0.0f32; m * n];
        ops::dense_fwd_packed(m, &x, &packed, &b, &mut serial);
        let pool = ThreadPool::new(4);
        for rows in [1usize, 2, 3, 7] {
            let mut par = vec![0.0f32; m * n];
            let stats = dense_fwd_parallel(&pool, m, &x, &packed, &b, &mut par, false, rows);
            assert_eq!(stats.tasks, (m + rows - 1) / rows);
            assert_eq!(par, serial, "rows={rows}");
        }
        // Fused ReLU == serial ReLU after the fact.
        ops::relu_fwd(&mut serial);
        let mut par = vec![0.0f32; m * n];
        dense_fwd_parallel(&pool, m, &x, &packed, &b, &mut par, true, 2);
        assert_eq!(par, serial);
    }

    #[test]
    fn dense_bwd_parallel_matches_serial() {
        let mut rng = Xoshiro256::new(43);
        let (m, k, n) = (6usize, 11usize, 5usize);
        let x = rand_vec(&mut rng, m * k);
        let w = rand_vec(&mut rng, k * n);
        let dy0 = rand_vec(&mut rng, m * n);
        let wt = PackedB::pack_transposed(k, n, &w);
        let mut dx_s = vec![0.0f32; m * k];
        let mut dw_s = vec![0.0f32; k * n];
        let mut db_s = vec![0.0f32; n];
        ops::dense_bwd_packed(m, k, n, &x, &wt, &dy0, &mut dx_s, &mut dw_s, &mut db_s);
        let pool = ThreadPool::new(3);
        for rows in [1usize, 2, 6] {
            let mut dy = dy0.clone();
            let mut dx_p = vec![0.0f32; m * k];
            let mut dw_p = vec![0.0f32; k * n];
            let mut db_p = vec![0.0f32; n];
            dense_bwd_parallel(
                &pool, m, k, n, &x, &wt, &mut dy, None, &mut dx_p, &mut dw_p, &mut db_p, rows,
            );
            assert_eq!(dx_p, dx_s, "rows={rows}");
            for (a, b) in dw_p.iter().zip(dw_s.iter()) {
                assert!((a - b).abs() < 1e-4, "dw rows={rows}: {a} vs {b}");
            }
            for (a, b) in db_p.iter().zip(db_s.iter()) {
                assert!((a - b).abs() < 1e-4, "db rows={rows}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn dense_bwd_parallel_fused_relu_matches_explicit_mask() {
        let mut rng = Xoshiro256::new(47);
        let (m, k, n) = (5usize, 4usize, 6usize);
        let x = rand_vec(&mut rng, m * k);
        let w = rand_vec(&mut rng, k * n);
        let out = {
            // A plausible post-ReLU activation: clamp random values at 0.
            let mut o = rand_vec(&mut rng, m * n);
            ops::relu_fwd(&mut o);
            o
        };
        let dy0 = rand_vec(&mut rng, m * n);
        let wt = PackedB::pack_transposed(k, n, &w);
        // Serial reference: explicit mask, then packed backward.
        let mut dy_s = dy0.clone();
        ops::relu_bwd(&out, &mut dy_s);
        let mut dx_s = vec![0.0f32; m * k];
        let mut dw_s = vec![0.0f32; k * n];
        let mut db_s = vec![0.0f32; n];
        ops::dense_bwd_packed(m, k, n, &x, &wt, &dy_s, &mut dx_s, &mut dw_s, &mut db_s);
        let pool = ThreadPool::new(2);
        let mut dy_p = dy0.clone();
        let mut dx_p = vec![0.0f32; m * k];
        let mut dw_p = vec![0.0f32; k * n];
        let mut db_p = vec![0.0f32; n];
        dense_bwd_parallel(
            &pool, m, k, n, &x, &wt, &mut dy_p, Some(&out), &mut dx_p, &mut dw_p, &mut db_p, 2,
        );
        assert_eq!(dy_p, dy_s, "fused mask must equal explicit mask");
        assert_eq!(dx_p, dx_s);
        for (a, b) in dw_p.iter().zip(dw_s.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
        for (a, b) in db_p.iter().zip(db_s.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn pool_and_relu_parallel_match_serial() {
        let mut rng = Xoshiro256::new(53);
        let (n, h, w, c, win) = (3usize, 6usize, 4usize, 2usize, 2usize);
        let x = rand_vec(&mut rng, n * h * w * c);
        let pool = ThreadPool::new(4);
        let (ho, wo) = (h / win, w / win);
        let mut fwd_s = vec![0.0f32; n * ho * wo * c];
        ops::mean_pool_fwd(n, h, w, c, win, &x, &mut fwd_s);
        let mut fwd_p = vec![0.0f32; n * ho * wo * c];
        mean_pool_fwd_parallel(&pool, n, h, w, c, win, &x, &mut fwd_p);
        assert_eq!(fwd_p, fwd_s);
        let dy = rand_vec(&mut rng, n * ho * wo * c);
        let mut bwd_s = vec![0.0f32; n * h * w * c];
        ops::mean_pool_bwd(n, h, w, c, win, &dy, &mut bwd_s);
        let mut bwd_p = vec![0.0f32; n * h * w * c];
        mean_pool_bwd_parallel(&pool, n, h, w, c, win, &dy, &mut bwd_p);
        assert_eq!(bwd_p, bwd_s);
        // ReLU chunk tasks.
        let mut a = rand_vec(&mut rng, 101);
        let mut b = a.clone();
        ops::relu_fwd(&mut a);
        relu_fwd_parallel(&pool, &mut b, 4);
        assert_eq!(a, b);
        let out = a;
        let mut da = rand_vec(&mut rng, 101);
        let mut db = da.clone();
        ops::relu_bwd(&out, &mut da);
        relu_bwd_parallel(&pool, &out, &mut db, 3);
        assert_eq!(da, db);
    }

    #[test]
    fn loss_parallel_matches_serial() {
        let mut rng = Xoshiro256::new(59);
        let (m, n) = (7usize, 5usize);
        let logits = rand_vec(&mut rng, m * n);
        let mut y = vec![0.0f32; m * n];
        for i in 0..m {
            y[i * n + i % n] = 1.0;
        }
        let mut dl_s = vec![0.0f32; m * n];
        let mut probs_s = vec![0.0f32; m * n];
        let (loss_s, correct_s) =
            ops::mse_softmax_loss_into(m, n, &logits, &y, &mut dl_s, &mut probs_s);
        let pool = ThreadPool::new(4);
        for rows in [1usize, 3, 7] {
            let mut dl_p = vec![0.0f32; m * n];
            let mut probs_p = vec![0.0f32; m * n];
            let mut parts = Vec::new();
            let (loss_p, correct_p, stats) = loss_parallel(
                &pool, m, n, &logits, &y, &mut dl_p, &mut probs_p, &mut parts, rows,
            );
            assert_eq!(stats.tasks, (m + rows - 1) / rows, "rows={rows}");
            assert_eq!(correct_p, correct_s, "rows={rows}");
            assert!((loss_p - loss_s).abs() < 1e-6, "rows={rows}: {loss_p} vs {loss_s}");
            assert_eq!(dl_p, dl_s, "dlogits must be bit-identical");
            assert_eq!(probs_p, probs_s);
        }
    }
}
