//! Dense-layer / pool / ReLU / loss task decomposition — the §4.1.2 stages
//! that are *not* convolutions, so the **full** local weight-training step
//! rides the thread pool, not just the conv stack (Dryden et al.,
//! arXiv:1903.06681, make the case that fine-grained parallelism across all
//! layer types is what unlocks strong scaling; Jia et al., arXiv:1802.04924,
//! specifically for FC layers).
//!
//! Decomposition mirrors `conv_tasks`/`bp_tasks`:
//! * **FC forward/backward** — 2D batch-row × packed-panel tiles
//!   ([`Tile2`], grids from [`crate::inner::plan_tile_grid`]) contracted on
//!   the shared panel-windowed 4×8 micro-kernel over a weight pack cached
//!   in the network's [`crate::nn::WeightPacks`]. Columns split exactly
//!   when batch rows alone cannot feed the pool (small batch × wide FC);
//!   backward tiles accumulate their dW/db partials into **disjoint column
//!   stripes** of the *executing worker's* persistent [`ScratchArena`] and
//!   a post-barrier stripe-sequential reduce combines them
//!   ([`reduce_arena_grads`]) — no mutex in any task body, no per-task
//!   allocation.
//! * **ReLU** — fused into the producing/consuming tile where possible
//!   (forward tiles apply it before writing; backward tiles mask their `dy`
//!   rows in place), with standalone chunk tasks for the conv activations.
//! * **Pool** — one task per image, disjoint output slices.
//! * **Loss** — row tiles write disjoint `dlogits`/`probs` rows and report
//!   per-task (Σerr², correct) partials into caller-provided slots.

use std::sync::Arc;

use crate::nn::ops::{self, PackedB};
use crate::util::threadpool::{ScratchArena, ThreadPool};

use super::check;
use super::conv_tasks::DisjointBuf;
use super::dag::TaskDag;
use super::scheduler::{execute_dag, panel_count, ScheduleStats, TileGrid};

/// One batch-row tile: rows `[i0, i0+rows)` of a `(m, ·)` matrix.
#[derive(Debug, Clone, Copy)]
pub struct RowTask {
    pub i0: usize,
    pub rows: usize,
}

/// One 2D tile: rows `[i0, i0+rows)` × packed panels `[p0, p0+np)` of a
/// `(m, n)` matrix — the dense analogue of
/// [`super::conv_tasks::ConvTile`].
#[derive(Debug, Clone, Copy)]
pub struct Tile2 {
    pub i0: usize,
    pub rows: usize,
    pub p0: usize,
    pub np: usize,
}

/// Level-0 row-tile list over `m` batch rows, `rows_per_task` at a time.
/// Public so the plan-sweep tests can verify fused-backward schedules
/// without executing them.
pub fn row_tile_dag(
    m: usize,
    rows_per_task: usize,
    cost_per_row: f64,
    label: &str,
) -> TaskDag<RowTask> {
    assert!(rows_per_task >= 1);
    let mut dag = TaskDag::new();
    let mut i = 0;
    while i < m {
        let rows = rows_per_task.min(m - i);
        dag.add(
            format!("{label}[i{i}+{rows}]"),
            cost_per_row * rows as f64,
            &[],
            RowTask { i0: i, rows },
        );
        i += rows;
    }
    dag
}

/// Level-0 2D tile list over a `(m, n)` output: row tiles × panel tiles of
/// `grid`; `cost_per_el` prices one output element for Alg.-4.2 balancing.
/// Public so the plan-sweep tests can verify forward schedules statically.
pub fn tile2_dag(
    m: usize,
    n: usize,
    grid: &TileGrid,
    cost_per_el: f64,
    label: &str,
) -> TaskDag<Tile2> {
    let mut dag = TaskDag::new();
    let panels = panel_count(n);
    let mut i = 0;
    while i < m {
        let rows = grid.rows_per_tile.min(m - i);
        let mut p = 0;
        while p < panels {
            let np = grid.panels_per_tile.min(panels - p);
            let (_, jw) = ops::panel_window(n, p, np);
            dag.add(
                format!("{label}[i{i}+{rows},p{p}]"),
                cost_per_el * (rows * jw) as f64,
                &[],
                Tile2 { i0: i, rows, p0: p, np },
            );
            p += np;
        }
        i += rows;
    }
    dag
}

/// Typed analogue of [`DisjointBuf`] for the loss stage's per-task result
/// slots. Safety contract: concurrent tasks write distinct indices.
struct DisjointSlots<T> {
    ptr: *mut T,
    len: usize,
}

// SAFETY: a bounds-tagged raw pointer into a slot array the dispatching
// stage exclusively borrows until its completion barrier. Handles may move
// across threads (`Send`; `T: Send` because slot values do) and be shared
// (`Sync`) because each task writes exactly one distinct index — claimed as
// `check::Buf::Slots` and proved disjoint by the stage verifier.
unsafe impl<T: Send> Send for DisjointSlots<T> {}
// SAFETY: see the `Send` justification above — shared use is sound only
// through distinct-index writes, which the loss DAG guarantees.
unsafe impl<T: Send> Sync for DisjointSlots<T> {}

impl<T> DisjointSlots<T> {
    fn new(s: &mut [T]) -> Self {
        Self { ptr: s.as_mut_ptr(), len: s.len() }
    }

    /// # Safety
    /// Concurrent calls must use distinct `i`.
    unsafe fn set(&self, i: usize, v: T) {
        assert!(i < self.len, "slot out of bounds");
        // SAFETY: bounds asserted above; the caller contract keeps
        // concurrent writes on distinct slots.
        unsafe { *self.ptr.add(i) = v };
    }
}

/// Dense forward `out = x · W + b` (optionally fused ReLU) as 2D row×panel
/// tiles on the pool. `w` is the layer's cached weight pack, shared
/// read-only by every tile; tiles write disjoint (row-range ×
/// column-window) element sets, task bodies allocate nothing. Numerically ≡
/// [`ops::dense_fwd_packed`] bit for bit (each panel owns an independent
/// register accumulator, so the column split does not regroup sums).
#[allow(clippy::too_many_arguments)]
pub fn dense_fwd_parallel(
    pool: &ThreadPool,
    m: usize,
    x: &[f32],
    w: &PackedB,
    bias: &[f32],
    out: &mut [f32],
    relu: bool,
    grid: TileGrid,
) -> ScheduleStats {
    let (k, n) = (w.kk(), w.n());
    assert_eq!(x.len(), m * k);
    assert_eq!(bias.len(), n);
    assert_eq!(out.len(), m * n);
    grid.check();
    let dag = tile2_dag(m, n, &grid, (2 * k) as f64, "dense_fwd");
    let guard = check::stage_guard(&dag, || dense_fwd_claims(n, &dag));
    let shared = DisjointBuf::new(out).checked(check::Buf::Out, &guard);
    execute_dag(pool, dag, move |_worker, t: &Tile2| {
        let (j0, jw) = ops::panel_window(n, t.p0, t.np);
        // Bias-seed the tile's column window row by row. SAFETY: tile
        // (i0, rows, p0, np) exclusively owns these elements; concurrent
        // tiles cover other rows or other column windows.
        for r in t.i0..t.i0 + t.rows {
            let row = unsafe { shared.slice_mut(r * n + j0, jw) };
            row.copy_from_slice(&bias[j0..j0 + jw]);
        }
        let xt = &x[t.i0 * k..(t.i0 + t.rows) * k];
        // SAFETY: the panel-windowed GEMM writes only this tile's window.
        unsafe {
            ops::gemm_packed_acc_panels_raw(t.rows, xt, w, shared.ptr_at(t.i0 * n), t.p0, t.np);
        }
        if relu {
            for r in t.i0..t.i0 + t.rows {
                // SAFETY: same exclusive window as above.
                ops::relu_fwd(unsafe { shared.slice_mut(r * n + j0, jw) });
            }
        }
    })
}

/// Access claims of the dense-forward DAG: each tile writes its
/// (row-range × column-window) block of the `(m, n)` output; `x`/weights/
/// bias are stage-wide read-only and carry no claims.
pub fn dense_fwd_claims(n: usize, dag: &TaskDag<Tile2>) -> Vec<check::Claim> {
    let mut claims = Vec::with_capacity(dag.len());
    for node in dag.nodes() {
        let t = &node.payload;
        let (j0, jw) = ops::panel_window(n, t.p0, t.np);
        claims.push(check::Claim::write(
            node.id,
            check::Buf::Out,
            check::Span::strided(t.i0 * n + j0, t.rows, n, jw),
        ));
    }
    claims
}

/// One task of the two-phase 2D dense backward.
pub enum DenseBwdTile {
    /// Mask its `dy` column window (ReLU) + accumulate the dW/db stripe for
    /// that window into the executing worker's arena.
    Grad(Tile2),
    /// `dx` tile over a transposed-pack (k-column) panel window; depends on
    /// every [`DenseBwdTile::Grad`] task of its row range (they mask `dy`
    /// in place, and `dx = dy · Wᵀ` contracts over *all* of `n`).
    Dx(Tile2),
}

/// Build the two-phase 2D dense-backward DAG: per row range, `Grad` tiles
/// over `dy` column windows (level 0), then `Dx` tiles over transposed-pack
/// panel windows depending on all of that row range's `Grad` tiles.
/// Extracted from [`dense_bwd_parallel`] so the plan-sweep tests can verify
/// every planner-emitted schedule statically.
pub fn dense_bwd_dag(
    m: usize,
    k: usize,
    n: usize,
    dy_grid: &TileGrid,
    dx_grid: &TileGrid,
) -> TaskDag<DenseBwdTile> {
    let panels_n = panel_count(n);
    let panels_k = panel_count(k);
    let mut dag: TaskDag<DenseBwdTile> = TaskDag::new();
    let mut grad_ids = Vec::with_capacity(dy_grid.panel_tiles);
    let mut i = 0;
    while i < m {
        let rows = dy_grid.rows_per_tile.min(m - i);
        grad_ids.clear();
        let mut p = 0;
        while p < panels_n {
            let np = dy_grid.panels_per_tile.min(panels_n - p);
            let (_, jw) = ops::panel_window(n, p, np);
            grad_ids.push(dag.add(
                format!("dense_bwd_grad[i{i},p{p}]"),
                (2 * k * rows * jw) as f64,
                &[],
                DenseBwdTile::Grad(Tile2 { i0: i, rows, p0: p, np }),
            ));
            p += np;
        }
        let mut q = 0;
        while q < panels_k {
            let nq = dx_grid.panels_per_tile.min(panels_k - q);
            let (_, qw) = ops::panel_window(k, q, nq);
            dag.add(
                format!("dense_bwd_dx[i{i},p{q}]"),
                (2 * n * rows * qw) as f64,
                &grad_ids,
                DenseBwdTile::Dx(Tile2 { i0: i, rows, p0: q, np: nq }),
            );
            q += nq;
        }
        i += rows;
    }
    dag
}

/// Access claims of the two-phase dense-backward DAG ([`dense_bwd_dag`]):
/// `Grad` tiles mask their `dy` column window in place and accumulate dW/db
/// column stripes of the executing worker's arena (per-worker, exempt from
/// pairwise disjointness); `Dx` tiles read their full masked `dy` row range
/// (ordered behind the `Grad` dependencies) and write their `dx` window
/// (`Buf::Out`).
pub fn dense_bwd_claims(k: usize, n: usize, dag: &TaskDag<DenseBwdTile>) -> Vec<check::Claim> {
    let mut claims = Vec::with_capacity(3 * dag.len());
    for node in dag.nodes() {
        match node.payload {
            DenseBwdTile::Grad(t) => {
                let (j0, jw) = ops::panel_window(n, t.p0, t.np);
                claims.push(check::Claim::write(
                    node.id,
                    check::Buf::Dy,
                    check::Span::strided(t.i0 * n + j0, t.rows, n, jw),
                ));
                claims.push(check::Claim::write(
                    node.id,
                    check::Buf::ArenaGradF,
                    check::Span::strided(j0, k, n, jw),
                ));
                claims.push(check::Claim::write(
                    node.id,
                    check::Buf::ArenaGradB,
                    check::Span::interval(j0, jw),
                ));
            }
            DenseBwdTile::Dx(t) => {
                let (j0, jw) = ops::panel_window(k, t.p0, t.np);
                claims.push(check::Claim::read(
                    node.id,
                    check::Buf::Dy,
                    check::Span::interval(t.i0 * n, t.rows * n),
                ));
                claims.push(check::Claim::write(
                    node.id,
                    check::Buf::Out,
                    check::Span::strided(t.i0 * k + j0, t.rows, k, jw),
                ));
            }
        }
    }
    claims
}

/// Access claims of the fused row-tile dense backward: each task owns its
/// full `dy` and `dx` row ranges and accumulates the *whole* dW/db into its
/// worker's arena.
pub fn dense_bwd_fused_claims(k: usize, n: usize, dag: &TaskDag<RowTask>) -> Vec<check::Claim> {
    let mut claims = Vec::with_capacity(4 * dag.len());
    for node in dag.nodes() {
        let t = &node.payload;
        claims.push(check::Claim::write(
            node.id,
            check::Buf::Dy,
            check::Span::interval(t.i0 * n, t.rows * n),
        ));
        claims.push(check::Claim::write(
            node.id,
            check::Buf::Out,
            check::Span::interval(t.i0 * k, t.rows * k),
        ));
        claims.push(check::Claim::write(
            node.id,
            check::Buf::ArenaGradF,
            check::Span::interval(0, k * n),
        ));
        claims.push(check::Claim::write(
            node.id,
            check::Buf::ArenaGradB,
            check::Span::interval(0, n),
        ));
    }
    claims
}

/// Dense backward as 2D tiles: each tile (optionally) applies the ReLU mask
/// to its `dy` window in place, accumulates its dW/db **column stripe**
/// into a disjoint stripe of the executing worker's [`ScratchArena`], and —
/// once all of a row range's windows are masked — `dx` tiles compute
/// `dx = dy · Wᵀ` over panel windows of the transposed pack. With both
/// grids at a single column tile this collapses to the fused row-tile path
/// (one task per row range, no second phase — the pre-2D engine, kept so
/// large-batch steps pay no extra dispatch). The per-worker partials are
/// reduced after the barrier, stripe-sequentially and contention-free
/// ([`reduce_arena_grads`]). Numerically ≡ `relu_bwd` (when `relu_out` is
/// given) followed by [`ops::dense_bwd_packed`], to f32 reduction-order
/// tolerance in dW/db (`dx` and the mask are bit-identical).
#[allow(clippy::too_many_arguments)]
pub fn dense_bwd_parallel(
    pool: &ThreadPool,
    m: usize,
    k: usize,
    n: usize,
    x: &[f32],
    wt: &PackedB,
    dy: &mut [f32],
    relu_out: Option<&[f32]>,
    dx: &mut [f32],
    dw: &mut [f32],
    db: &mut [f32],
    dy_grid: TileGrid,
    dx_grid: TileGrid,
) -> ScheduleStats {
    assert_eq!(wt.kk(), n, "wt must be the transposed pack");
    assert_eq!(wt.n(), k, "wt must be the transposed pack");
    assert_eq!(x.len(), m * k);
    assert_eq!(dy.len(), m * n);
    assert_eq!(dx.len(), m * k);
    assert_eq!(dw.len(), k * n);
    assert_eq!(db.len(), n);
    assert_eq!(
        dy_grid.rows_per_tile, dx_grid.rows_per_tile,
        "backward grids must share the row split"
    );
    dy_grid.check();
    dx_grid.check();
    if let Some(r) = relu_out {
        assert_eq!(r.len(), m * n);
    }
    // Size + zero each worker's gradient accumulators for this layer call.
    zero_arena_grads(pool, k * n, n);
    let arenas = pool.arenas();

    let stats = if dy_grid.panel_tiles == 1 && dx_grid.panel_tiles == 1 {
        // Fused row-tile fast path: one task masks, computes dx and
        // accumulates dW/db for its rows.
        let dag = row_tile_dag(m, dy_grid.rows_per_tile, (4 * k * n) as f64, "dense_bwd");
        let guard = check::stage_guard(&dag, || dense_bwd_fused_claims(k, n, &dag));
        let dy_buf = DisjointBuf::new(dy).checked(check::Buf::Dy, &guard);
        let dx_buf = DisjointBuf::new(dx).checked(check::Buf::Out, &guard);
        execute_dag(pool, dag, move |worker, task: &RowTask| {
            // SAFETY: tile (i0, rows) exclusively owns its dy and dx rows.
            let dyt = unsafe { dy_buf.slice_mut(task.i0 * n, task.rows * n) };
            let dxt = unsafe { dx_buf.slice_mut(task.i0 * k, task.rows * k) };
            if let Some(out) = relu_out {
                ops::relu_bwd(&out[task.i0 * n..(task.i0 + task.rows) * n], dyt);
            }
            let xt = &x[task.i0 * k..(task.i0 + task.rows) * k];
            let mut arena = arenas[worker].lock().unwrap();
            let arena = &mut *arena;
            dxt.fill(0.0);
            ops::gemm_packed_acc(task.rows, dyt, wt, dxt);
            let gf = ScratchArena::grad_all(&mut arena.grad_f, k * n);
            ops::gemm_tn_acc(task.rows, k, n, xt, dyt, gf);
            let gb = ScratchArena::grad_all(&mut arena.grad_b, n);
            for row in dyt.chunks_exact(n) {
                for (acc, &v) in gb.iter_mut().zip(row.iter()) {
                    *acc += v;
                }
            }
        })
    } else {
        // Two-phase 2D DAG: per row range, Grad tiles (level 0) over dy
        // column windows, then Dx tiles (level 1) over wt panel windows.
        let dag = dense_bwd_dag(m, k, n, &dy_grid, &dx_grid);
        let guard = check::stage_guard(&dag, || dense_bwd_claims(k, n, &dag));
        let dy_buf = DisjointBuf::new(dy).checked(check::Buf::Dy, &guard);
        let dx_buf = DisjointBuf::new(dx).checked(check::Buf::Out, &guard);
        execute_dag(pool, dag, move |worker, task: &DenseBwdTile| match *task {
            DenseBwdTile::Grad(t) => {
                let (j0, jw) = ops::panel_window(n, t.p0, t.np);
                let mut arena = arenas[worker].lock().unwrap();
                let arena = &mut *arena;
                let gb = ScratchArena::grad_stripe(&mut arena.grad_b, n, j0, jw);
                for r in t.i0..t.i0 + t.rows {
                    // SAFETY: this tile exclusively owns the (row ×
                    // column-window) dy elements it masks and reads.
                    let w = unsafe { dy_buf.slice_mut(r * n + j0, jw) };
                    if let Some(out) = relu_out {
                        ops::relu_bwd(&out[r * n + j0..r * n + j0 + jw], w);
                    }
                    for (acc, &v) in gb.iter_mut().zip(w.iter()) {
                        *acc += v;
                    }
                }
                let xt = &x[t.i0 * k..(t.i0 + t.rows) * k];
                // SAFETY: dy reads and grad_f writes stay inside the column
                // window; grad_f is the worker's own arena.
                unsafe {
                    ops::gemm_tn_acc_cols_raw(
                        t.rows,
                        k,
                        n,
                        xt,
                        dy_buf.ptr_at(t.i0 * n) as *const f32,
                        ScratchArena::grad_window_ptr(&mut arena.grad_f, k, n, j0, jw),
                        j0,
                        jw,
                    );
                }
            }
            DenseBwdTile::Dx(t) => {
                let (j0, jw) = ops::panel_window(k, t.p0, t.np);
                for r in t.i0..t.i0 + t.rows {
                    // SAFETY: this tile exclusively owns its dx window.
                    unsafe { dx_buf.slice_mut(r * k + j0, jw) }.fill(0.0);
                }
                // SAFETY: the DAG dependencies guarantee rows [i0, i0+rows)
                // of dy are fully masked and no longer written; reading them
                // shared is sound. dx writes stay inside this tile's window.
                let dyt = unsafe { dy_buf.slice_ref(t.i0 * n, t.rows * n) };
                unsafe {
                    ops::gemm_packed_acc_panels_raw(
                        t.rows,
                        dyt,
                        wt,
                        dx_buf.ptr_at(t.i0 * k),
                        t.p0,
                        t.np,
                    );
                }
            }
        })
    };
    // Post-barrier reduce of the per-worker partials (the Fig.-9 reduce
    // node): stripe-sequential, contention-free.
    reduce_arena_grads(pool, dw, db);
    stats
}

/// Size + zero every worker's `grad_f`/`grad_b` accumulators before a
/// backward layer call dispatches. Small accumulators zero sequentially on
/// the calling thread; wide-FC ones (where a sequential memset of
/// `workers × |dW|` floats would rival the GEMM itself) are zeroed by one
/// job pinned to each worker — parallel across the pool and first-touch
/// local to the worker that will accumulate into them.
pub(crate) fn zero_arena_grads(pool: &ThreadPool, f_len: usize, b_len: usize) {
    /// Matches the reduce threshold: below this the dispatch overhead wins.
    const PAR_ZERO_MIN: usize = 64 * 1024;
    if f_len < PAR_ZERO_MIN || pool.size() < 2 {
        for arena in pool.arenas() {
            let mut g = arena.lock().unwrap();
            let g = &mut *g;
            ScratchArena::grow_zeroed(&mut g.grad_f, f_len);
            ScratchArena::grow_zeroed(&mut g.grad_b, b_len);
        }
        return;
    }
    for w in 0..pool.size() {
        let arena = Arc::clone(pool.arena(w));
        pool.execute_on(w, move || {
            let mut g = arena.lock().unwrap();
            let g = &mut *g;
            ScratchArena::grow_zeroed(&mut g.grad_f, f_len);
            ScratchArena::grow_zeroed(&mut g.grad_b, b_len);
        });
    }
    // The layer call owns the pool (no concurrent layer calls), so idle ⇔
    // all zeroing jobs finished.
    pool.wait_idle();
}

/// Reduce the per-worker `grad_f`/`grad_b` arena partials into `dw`/`db`
/// after a backward layer call's barrier. `db` (and small `dw`s) reduce
/// sequentially on the calling thread; a large `dw` (wide-FC layers, where
/// the sequential sweep would rival the GEMM itself) is reduced by parallel
/// chunk tasks — each chunk of `dw` is summed across all arenas by exactly
/// one task, so the reduce is sequential *per stripe* and workers never
/// contend (the calling thread holds the arena locks; tasks read the
/// partials through shared borrows and write disjoint `dw` chunks).
pub(crate) fn reduce_arena_grads(pool: &ThreadPool, dw: &mut [f32], db: &mut [f32]) {
    /// Below this many elements the sequential sweep wins (parallel reduce
    /// pays one dispatch per chunk).
    const PAR_REDUCE_MIN: usize = 64 * 1024;
    let guards: Vec<_> = pool.arenas().iter().map(|a| a.lock().unwrap()).collect();
    db.fill(0.0);
    for g in &guards {
        for (acc, &v) in db.iter_mut().zip(g.grad_b.iter()) {
            *acc += v;
        }
    }
    dw.fill(0.0);
    if dw.len() < PAR_REDUCE_MIN || pool.size() < 2 {
        for g in &guards {
            for (acc, &v) in dw.iter_mut().zip(g.grad_f.iter()) {
                *acc += v;
            }
        }
        return;
    }
    let len = dw.len();
    let parts: Vec<&[f32]> = guards.iter().map(|g| &g.grad_f[..len]).collect();
    let per = (len + 2 * pool.size() - 1) / (2 * pool.size());
    let mut dag: TaskDag<(usize, usize)> = TaskDag::new();
    let mut off = 0;
    while off < len {
        let l = per.min(len - off);
        dag.add("grad_reduce", l as f64, &[], (off, l));
        off += l;
    }
    let guard = check::stage_guard(&dag, || chunk_claims(&dag));
    let out = DisjointBuf::new(dw).checked(check::Buf::Out, &guard);
    let parts_ref: &[&[f32]] = &parts;
    execute_dag(pool, dag, move |_, &(off, l)| {
        // SAFETY: chunks tile dw disjointly.
        let o = unsafe { out.slice_mut(off, l) };
        for p in parts_ref {
            for (acc, &v) in o.iter_mut().zip(p[off..off + l].iter()) {
                *acc += v;
            }
        }
    });
}

/// Mean-pool forward, one task per image (disjoint output slices).
#[allow(clippy::too_many_arguments)]
pub fn mean_pool_fwd_parallel(
    pool: &ThreadPool,
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    win: usize,
    x: &[f32],
    out: &mut [f32],
) -> ScheduleStats {
    let (ho, wo) = (h / win, w / win);
    assert_eq!(x.len(), n * h * w * c);
    assert_eq!(out.len(), n * ho * wo * c);
    let mut dag: TaskDag<usize> = TaskDag::new();
    for i in 0..n {
        dag.add(format!("pool_fwd[{i}]"), (h * w * c) as f64, &[], i);
    }
    let img_in = h * w * c;
    let img_out = ho * wo * c;
    let guard = check::stage_guard(&dag, || {
        dag.nodes()
            .iter()
            .map(|nd| {
                let span = check::Span::interval(nd.payload * img_out, img_out);
                check::Claim::write(nd.id, check::Buf::Out, span)
            })
            .collect()
    });
    let shared = DisjointBuf::new(out).checked(check::Buf::Out, &guard);
    execute_dag(pool, dag, move |_, &i| {
        // SAFETY: image task i exclusively owns its output slice.
        let tile = unsafe { shared.slice_mut(i * img_out, img_out) };
        ops::mean_pool_fwd(1, h, w, c, win, &x[i * img_in..(i + 1) * img_in], tile);
    })
}

/// Mean-pool backward, one task per image (disjoint `dx` slices).
#[allow(clippy::too_many_arguments)]
pub fn mean_pool_bwd_parallel(
    pool: &ThreadPool,
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    win: usize,
    dy: &[f32],
    dx: &mut [f32],
) -> ScheduleStats {
    let (ho, wo) = (h / win, w / win);
    assert_eq!(dy.len(), n * ho * wo * c);
    assert_eq!(dx.len(), n * h * w * c);
    let mut dag: TaskDag<usize> = TaskDag::new();
    for i in 0..n {
        dag.add(format!("pool_bwd[{i}]"), (h * w * c) as f64, &[], i);
    }
    let img_in = h * w * c;
    let img_out = ho * wo * c;
    let guard = check::stage_guard(&dag, || {
        dag.nodes()
            .iter()
            .map(|nd| {
                let span = check::Span::interval(nd.payload * img_in, img_in);
                check::Claim::write(nd.id, check::Buf::Out, span)
            })
            .collect()
    });
    let shared = DisjointBuf::new(dx).checked(check::Buf::Out, &guard);
    execute_dag(pool, dag, move |_, &i| {
        // SAFETY: image task i exclusively owns its dx slice.
        let tile = unsafe { shared.slice_mut(i * img_in, img_in) };
        ops::mean_pool_bwd(1, h, w, c, win, &dy[i * img_out..(i + 1) * img_out], tile);
    })
}

/// Claims of a `(offset, len)`-chunk DAG: each task writes its own chunk.
fn chunk_claims(dag: &TaskDag<(usize, usize)>) -> Vec<check::Claim> {
    dag.nodes()
        .iter()
        .map(|nd| {
            let (off, len) = nd.payload;
            check::Claim::write(nd.id, check::Buf::Out, check::Span::interval(off, len))
        })
        .collect()
}

/// Standalone ReLU stages for the conv activations (elementwise, chunked
/// across the pool; FC ReLUs are fused into their dense tiles instead).
pub fn relu_fwd_parallel(pool: &ThreadPool, buf: &mut [f32], chunks: usize) -> ScheduleStats {
    let n = buf.len();
    let per = (n / chunks.max(1)).max(1);
    let mut dag: TaskDag<(usize, usize)> = TaskDag::new();
    let mut i = 0;
    while i < n {
        let len = per.min(n - i);
        dag.add("relu_fwd", len as f64, &[], (i, len));
        i += len;
    }
    let guard = check::stage_guard(&dag, || chunk_claims(&dag));
    let shared = DisjointBuf::new(buf).checked(check::Buf::Out, &guard);
    execute_dag(pool, dag, move |_, &(off, len)| {
        // SAFETY: chunks tile the buffer disjointly.
        ops::relu_fwd(unsafe { shared.slice_mut(off, len) });
    })
}

/// Chunked `dx = dy · (out > 0)` mask (conv ReLU backward).
pub fn relu_bwd_parallel(
    pool: &ThreadPool,
    out: &[f32],
    dy: &mut [f32],
    chunks: usize,
) -> ScheduleStats {
    assert_eq!(out.len(), dy.len());
    let n = dy.len();
    let per = (n / chunks.max(1)).max(1);
    let mut dag: TaskDag<(usize, usize)> = TaskDag::new();
    let mut i = 0;
    while i < n {
        let len = per.min(n - i);
        dag.add("relu_bwd", len as f64, &[], (i, len));
        i += len;
    }
    let guard = check::stage_guard(&dag, || chunk_claims(&dag));
    let shared = DisjointBuf::new(dy).checked(check::Buf::Out, &guard);
    execute_dag(pool, dag, move |_, &(off, len)| {
        // SAFETY: chunks tile the buffer disjointly.
        ops::relu_bwd(&out[off..off + len], unsafe { shared.slice_mut(off, len) });
    })
}

/// Parallel Eq.-16 loss: row tiles write disjoint `dlogits`/`probs` rows
/// and per-task (Σerr², correct) partials into `parts`; the partials are
/// summed sequentially after the barrier. Numerically ≡
/// [`ops::mse_softmax_loss_into`] up to the f64 loss-sum grouping
/// (`dlogits` is bit-identical).
#[allow(clippy::too_many_arguments)]
pub fn loss_parallel(
    pool: &ThreadPool,
    m: usize,
    n: usize,
    logits: &[f32],
    y: &[f32],
    dlogits: &mut [f32],
    probs: &mut [f32],
    parts: &mut Vec<(f64, usize)>,
    rows_per_task: usize,
) -> (f32, usize, ScheduleStats) {
    assert!(rows_per_task >= 1);
    assert_eq!(logits.len(), m * n);
    assert_eq!(y.len(), m * n);
    assert_eq!(dlogits.len(), m * n);
    assert_eq!(probs.len(), m * n);
    let mut dag: TaskDag<(usize, RowTask)> = TaskDag::new();
    let mut i = 0;
    let mut slots = 0;
    while i < m {
        let rows = rows_per_task.min(m - i);
        dag.add(
            format!("loss[i{i}+{rows}]"),
            (rows * n) as f64,
            &[],
            (slots, RowTask { i0: i, rows }),
        );
        i += rows;
        slots += 1;
    }
    parts.clear();
    parts.resize(slots, (0.0, 0));
    let guard = check::stage_guard(&dag, || {
        let mut cs = Vec::new();
        for nd in dag.nodes() {
            let (slot, task) = nd.payload;
            let rows = check::Span::interval(task.i0 * n, task.rows * n);
            cs.push(check::Claim::write(nd.id, check::Buf::Out, rows));
            cs.push(check::Claim::write(nd.id, check::Buf::Out2, rows));
            cs.push(check::Claim::write(nd.id, check::Buf::Slots, check::Span::interval(slot, 1)));
        }
        cs
    });
    let dl_buf = DisjointBuf::new(dlogits).checked(check::Buf::Out, &guard);
    let p_buf = DisjointBuf::new(probs).checked(check::Buf::Out2, &guard);
    let part_slots = DisjointSlots::new(parts);
    let inv_b = 1.0 / m as f32;
    let stats = execute_dag(pool, dag, move |_, &(slot, task)| {
        let r0 = task.i0 * n;
        let rl = task.rows * n;
        // SAFETY: tiles own disjoint dlogits/probs rows and distinct slots.
        let dlt = unsafe { dl_buf.slice_mut(r0, rl) };
        let pt = unsafe { p_buf.slice_mut(r0, rl) };
        let lt = &logits[r0..r0 + rl];
        pt.copy_from_slice(lt);
        ops::softmax_rows(task.rows, n, pt);
        let part = ops::mse_softmax_rows(task.rows, n, lt, &y[r0..r0 + rl], dlt, pt, inv_b);
        unsafe { part_slots.set(slot, part) };
    });
    let mut loss = 0.0f64;
    let mut correct = 0usize;
    for &(l, c) in parts.iter() {
        loss += l;
        correct += c;
    }
    ((loss / m as f64) as f32, correct, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn rand_vec(rng: &mut Xoshiro256, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect()
    }

    /// Every combination of row granularity × panel granularity (including
    /// single-panel ragged `n`) is bit-identical to the serial packed path.
    #[test]
    fn dense_fwd_parallel_matches_serial_all_granularities() {
        let mut rng = Xoshiro256::new(41);
        let (m, k, n) = (7usize, 10usize, 19usize); // ragged rows and panels
        let x = rand_vec(&mut rng, m * k);
        let w = rand_vec(&mut rng, k * n);
        let b = rand_vec(&mut rng, n);
        let packed = PackedB::pack(k, n, &w);
        let mut serial = vec![0.0f32; m * n];
        ops::dense_fwd_packed(m, &x, &packed, &b, &mut serial);
        let pool = ThreadPool::new(4);
        let panels = panel_count(n);
        for rows in [1usize, 2, 3, 7] {
            for ppt in 1..=panels {
                let grid = TileGrid {
                    rows_per_tile: rows,
                    row_tiles: (m + rows - 1) / rows,
                    panels_per_tile: ppt,
                    panel_tiles: (panels + ppt - 1) / ppt,
                };
                let mut par = vec![0.0f32; m * n];
                let stats = dense_fwd_parallel(&pool, m, &x, &packed, &b, &mut par, false, grid);
                assert_eq!(stats.tasks, grid.tiles(), "rows={rows} ppt={ppt}");
                assert_eq!(par, serial, "rows={rows} ppt={ppt}");
            }
        }
        // Fused ReLU == serial ReLU after the fact, across column tiles.
        ops::relu_fwd(&mut serial);
        let mut par = vec![0.0f32; m * n];
        let grid =
            TileGrid { rows_per_tile: 2, row_tiles: 4, panels_per_tile: 1, panel_tiles: panels };
        dense_fwd_parallel(&pool, m, &x, &packed, &b, &mut par, true, grid);
        assert_eq!(par, serial);
    }

    /// Row-only grids on both spaces (the fused fast path) match the serial
    /// packed reference.
    #[test]
    fn dense_bwd_parallel_matches_serial() {
        let mut rng = Xoshiro256::new(43);
        let (m, k, n) = (6usize, 11usize, 5usize);
        let x = rand_vec(&mut rng, m * k);
        let w = rand_vec(&mut rng, k * n);
        let dy0 = rand_vec(&mut rng, m * n);
        let wt = PackedB::pack_transposed(k, n, &w);
        let mut dx_s = vec![0.0f32; m * k];
        let mut dw_s = vec![0.0f32; k * n];
        let mut db_s = vec![0.0f32; n];
        ops::dense_bwd_packed(m, k, n, &x, &wt, &dy0, &mut dx_s, &mut dw_s, &mut db_s);
        let pool = ThreadPool::new(3);
        for rows in [1usize, 2, 6] {
            let mut dy = dy0.clone();
            let mut dx_p = vec![0.0f32; m * k];
            let mut dw_p = vec![0.0f32; k * n];
            let mut db_p = vec![0.0f32; n];
            dense_bwd_parallel(
                &pool,
                m,
                k,
                n,
                &x,
                &wt,
                &mut dy,
                None,
                &mut dx_p,
                &mut dw_p,
                &mut db_p,
                TileGrid::rows_only(m, rows, n),
                TileGrid::rows_only(m, rows, k),
            );
            assert_eq!(dx_p, dx_s, "rows={rows}");
            for (a, b) in dw_p.iter().zip(dw_s.iter()) {
                assert!((a - b).abs() < 1e-4, "dw rows={rows}: {a} vs {b}");
            }
            for (a, b) in db_p.iter().zip(db_s.iter()) {
                assert!((a - b).abs() < 1e-4, "db rows={rows}: {a} vs {b}");
            }
        }
    }

    /// Column-split grids (the two-phase DAG: masked dW/db stripes, then dx
    /// panel tiles) match the serial reference at every panel granularity —
    /// ragged `n` and `k`, batch smaller than the pool, fused ReLU mask.
    #[test]
    fn dense_bwd_parallel_2d_matches_serial() {
        let mut rng = Xoshiro256::new(44);
        let (m, k, n) = (3usize, 21usize, 19usize); // 3 k-panels, 3 n-panels
        let x = rand_vec(&mut rng, m * k);
        let w = rand_vec(&mut rng, k * n);
        let dy0 = rand_vec(&mut rng, m * n);
        let relu_out = {
            let mut o = rand_vec(&mut rng, m * n);
            ops::relu_fwd(&mut o);
            o
        };
        let wt = PackedB::pack_transposed(k, n, &w);
        let mut dy_s = dy0.clone();
        ops::relu_bwd(&relu_out, &mut dy_s);
        let mut dx_s = vec![0.0f32; m * k];
        let mut dw_s = vec![0.0f32; k * n];
        let mut db_s = vec![0.0f32; n];
        ops::dense_bwd_packed(m, k, n, &x, &wt, &dy_s, &mut dx_s, &mut dw_s, &mut db_s);
        let pool = ThreadPool::new(4);
        let panels_n = panel_count(n);
        let panels_k = panel_count(k);
        for ppt_n in 1..=panels_n {
            for ppt_k in [1usize, panels_k] {
                let dy_grid = TileGrid {
                    rows_per_tile: 2,
                    row_tiles: 2,
                    panels_per_tile: ppt_n,
                    panel_tiles: (panels_n + ppt_n - 1) / ppt_n,
                };
                let dx_grid = TileGrid {
                    rows_per_tile: 2,
                    row_tiles: 2,
                    panels_per_tile: ppt_k,
                    panel_tiles: (panels_k + ppt_k - 1) / ppt_k,
                };
                let mut dy = dy0.clone();
                let mut dx_p = vec![0.0f32; m * k];
                let mut dw_p = vec![0.0f32; k * n];
                let mut db_p = vec![0.0f32; n];
                dense_bwd_parallel(
                    &pool,
                    m,
                    k,
                    n,
                    &x,
                    &wt,
                    &mut dy,
                    Some(&relu_out),
                    &mut dx_p,
                    &mut dw_p,
                    &mut db_p,
                    dy_grid,
                    dx_grid,
                );
                assert_eq!(dy, dy_s, "mask ppt_n={ppt_n} ppt_k={ppt_k}");
                assert_eq!(dx_p, dx_s, "dx ppt_n={ppt_n} ppt_k={ppt_k}");
                for (a, b) in dw_p.iter().zip(dw_s.iter()) {
                    assert!((a - b).abs() < 1e-4, "dw ppt_n={ppt_n} ppt_k={ppt_k}: {a} vs {b}");
                }
                for (a, b) in db_p.iter().zip(db_s.iter()) {
                    assert!((a - b).abs() < 1e-4, "db ppt_n={ppt_n} ppt_k={ppt_k}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn dense_bwd_parallel_fused_relu_matches_explicit_mask() {
        let mut rng = Xoshiro256::new(47);
        let (m, k, n) = (5usize, 4usize, 6usize);
        let x = rand_vec(&mut rng, m * k);
        let w = rand_vec(&mut rng, k * n);
        let out = {
            // A plausible post-ReLU activation: clamp random values at 0.
            let mut o = rand_vec(&mut rng, m * n);
            ops::relu_fwd(&mut o);
            o
        };
        let dy0 = rand_vec(&mut rng, m * n);
        let wt = PackedB::pack_transposed(k, n, &w);
        // Serial reference: explicit mask, then packed backward.
        let mut dy_s = dy0.clone();
        ops::relu_bwd(&out, &mut dy_s);
        let mut dx_s = vec![0.0f32; m * k];
        let mut dw_s = vec![0.0f32; k * n];
        let mut db_s = vec![0.0f32; n];
        ops::dense_bwd_packed(m, k, n, &x, &wt, &dy_s, &mut dx_s, &mut dw_s, &mut db_s);
        let pool = ThreadPool::new(2);
        let mut dy_p = dy0.clone();
        let mut dx_p = vec![0.0f32; m * k];
        let mut dw_p = vec![0.0f32; k * n];
        let mut db_p = vec![0.0f32; n];
        dense_bwd_parallel(
            &pool,
            m,
            k,
            n,
            &x,
            &wt,
            &mut dy_p,
            Some(&out),
            &mut dx_p,
            &mut dw_p,
            &mut db_p,
            TileGrid::rows_only(m, 2, n),
            TileGrid::rows_only(m, 2, k),
        );
        assert_eq!(dy_p, dy_s, "fused mask must equal explicit mask");
        assert_eq!(dx_p, dx_s);
        for (a, b) in dw_p.iter().zip(dw_s.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
        for (a, b) in db_p.iter().zip(db_s.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn pool_and_relu_parallel_match_serial() {
        let mut rng = Xoshiro256::new(53);
        let (n, h, w, c, win) = (3usize, 6usize, 4usize, 2usize, 2usize);
        let x = rand_vec(&mut rng, n * h * w * c);
        let pool = ThreadPool::new(4);
        let (ho, wo) = (h / win, w / win);
        let mut fwd_s = vec![0.0f32; n * ho * wo * c];
        ops::mean_pool_fwd(n, h, w, c, win, &x, &mut fwd_s);
        let mut fwd_p = vec![0.0f32; n * ho * wo * c];
        mean_pool_fwd_parallel(&pool, n, h, w, c, win, &x, &mut fwd_p);
        assert_eq!(fwd_p, fwd_s);
        let dy = rand_vec(&mut rng, n * ho * wo * c);
        let mut bwd_s = vec![0.0f32; n * h * w * c];
        ops::mean_pool_bwd(n, h, w, c, win, &dy, &mut bwd_s);
        let mut bwd_p = vec![0.0f32; n * h * w * c];
        mean_pool_bwd_parallel(&pool, n, h, w, c, win, &dy, &mut bwd_p);
        assert_eq!(bwd_p, bwd_s);
        // ReLU chunk tasks.
        let mut a = rand_vec(&mut rng, 101);
        let mut b = a.clone();
        ops::relu_fwd(&mut a);
        relu_fwd_parallel(&pool, &mut b, 4);
        assert_eq!(a, b);
        let out = a;
        let mut da = rand_vec(&mut rng, 101);
        let mut db = da.clone();
        ops::relu_bwd(&out, &mut da);
        relu_bwd_parallel(&pool, &out, &mut db, 3);
        assert_eq!(da, db);
    }

    #[test]
    fn loss_parallel_matches_serial() {
        let mut rng = Xoshiro256::new(59);
        let (m, n) = (7usize, 5usize);
        let logits = rand_vec(&mut rng, m * n);
        let mut y = vec![0.0f32; m * n];
        for i in 0..m {
            y[i * n + i % n] = 1.0;
        }
        let mut dl_s = vec![0.0f32; m * n];
        let mut probs_s = vec![0.0f32; m * n];
        let (loss_s, correct_s) =
            ops::mse_softmax_loss_into(m, n, &logits, &y, &mut dl_s, &mut probs_s);
        let pool = ThreadPool::new(4);
        for rows in [1usize, 3, 7] {
            let mut dl_p = vec![0.0f32; m * n];
            let mut probs_p = vec![0.0f32; m * n];
            let mut parts = Vec::new();
            let (loss_p, correct_p, stats) = loss_parallel(
                &pool, m, n, &logits, &y, &mut dl_p, &mut probs_p, &mut parts, rows,
            );
            assert_eq!(stats.tasks, (m + rows - 1) / rows, "rows={rows}");
            assert_eq!(correct_p, correct_s, "rows={rows}");
            assert!((loss_p - loss_s).abs() < 1e-6, "rows={rows}: {loss_p} vs {loss_s}");
            assert_eq!(dl_p, dl_s, "dlogits must be bit-identical");
            assert_eq!(probs_p, probs_s);
        }
    }
}
