//! Task priority marking (§4.2(1)).
//!
//! "We set a maximum value for the entrance task of the task DAG graph.
//! Then, the priorities of tasks in each level are set according to the
//! tasks' level. Specifically, upstream tasks' priorities are higher than
//! that of downstream tasks, while tasks at the same level have the same
//! priority."

use super::dag::TaskDag;

/// Priority of each task: entry tasks get `max_priority`, each level down
/// decrements. Higher value = schedule earlier.
pub fn mark_priorities<P>(dag: &TaskDag<P>) -> Vec<u32> {
    let levels = dag.levels();
    let max_level = levels.iter().copied().max().unwrap_or(0) as u32;
    levels.iter().map(|&l| max_level - l as u32).collect()
}

/// Order of dispatch: by priority descending (stable on task id so
/// same-level tasks keep decomposition order — deterministic schedules).
pub fn priority_order<P>(dag: &TaskDag<P>) -> Vec<usize> {
    let pri = mark_priorities(dag);
    let mut order: Vec<usize> = (0..dag.len()).collect();
    order.sort_by(|&a, &b| pri[b].cmp(&pri[a]).then(a.cmp(&b)));
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inner::dag::TaskDag;

    #[test]
    fn entry_tasks_have_max_priority() {
        let mut dag = TaskDag::new();
        let a = dag.add("a", 1.0, &[], ());
        let b = dag.add("b", 1.0, &[a], ());
        let c = dag.add("c", 1.0, &[a], ());
        let _d = dag.add("d", 1.0, &[b, c], ());
        let pri = mark_priorities(&dag);
        assert_eq!(pri, vec![2, 1, 1, 0]);
    }

    #[test]
    fn same_level_same_priority() {
        let mut dag = TaskDag::new();
        let a = dag.add("a", 1.0, &[], ());
        for _ in 0..5 {
            dag.add("x", 1.0, &[a], ());
        }
        let pri = mark_priorities(&dag);
        assert!(pri[1..].iter().all(|&p| p == pri[1]));
        assert!(pri[0] > pri[1]);
    }

    #[test]
    fn priority_order_is_topological() {
        let mut dag = TaskDag::new();
        let a = dag.add("a", 1.0, &[], ());
        let b = dag.add("b", 1.0, &[a], ());
        let c = dag.add("c", 1.0, &[b], ());
        let d = dag.add("d", 1.0, &[], ());
        let order = priority_order(&dag);
        let pos: Vec<usize> = (0..4).map(|i| order.iter().position(|&x| x == i).unwrap()).collect();
        assert!(pos[a] < pos[b] && pos[b] < pos[c]);
        // d is an entry task → same priority as a, ordered by id.
        assert!(pos[d] < pos[b]);
    }

    #[test]
    fn empty_dag_ok() {
        let dag: TaskDag<()> = TaskDag::new();
        assert!(mark_priorities(&dag).is_empty());
        assert!(priority_order(&dag).is_empty());
    }
}
