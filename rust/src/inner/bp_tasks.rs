//! Backward-pass (local weight training) task decomposition — §4.1.2.
//!
//! The paper parallelizes the loss-function calculation per neuron of the
//! upstream layer (Fig. 8) and the weight-gradient computation per filter
//! weight (Eq. 21). Here a full train step of the native network runs as
//! task DAGs mirroring Fig. 9:
//!
//! * forward conv layers — Algorithm 4.1 row tasks ([`conv_tasks`]);
//! * pool / FC / ReLU / loss — batch-row, per-image and chunk tasks from
//!   [`super::fc_tasks`], so the spine stages ride the pool too (they are
//!   <15% of the time per §4.1.1 on conv-heavy nets, but dominate the
//!   paper's FC-heavy Table-2 configurations);
//! * backward conv — the same **2D tile** decomposition as forward (row
//!   tiles × channel-panel windows when the grids split): each task lowers
//!   its tile's patches once, accumulates its partial filter / bias
//!   gradient stripe (Eq. 21 restricted to the tile's column window) into
//!   the *executing worker's* persistent arena, and dx tiles write their
//!   disjoint (row × input-channel-window) elements of `dx` (Eq. 18, as a
//!   panel-windowed flipped-filter packed-GEMM forward for odd k).
//!   Per-worker partials are reduced stripe-sequentially after the barrier
//!   — there is **no mutex in the task body** and no per-task allocation.
//!   This is the thread-safe realization of Fig. 8's per-neuron parallelism
//!   with the synchronization overhead driven to zero.

use std::cell::RefMut;

use crate::config::NetworkConfig;
use crate::nn::ops::{self, ConvDims, PackedB};
use crate::nn::{Network, StepWorkspace};
use crate::util::threadpool::{ScratchArena, ThreadPool};

use super::autotune::{AutoTuner, StageKey, StageKind};
use super::check;
use super::conv_tasks::{conv2d_parallel_packed_ws, ConvTask, ConvTile, DisjointBuf};
use super::dag::TaskDag;
use super::fc_tasks;
use super::scheduler::{
    execute_dag, panel_count, plan_cols_for_rows, plan_tile_grid, ScheduleStats, TileGrid,
    TilePolicy,
};

/// One stage's contribution to a step, in execution order: the stage
/// family, its measured makespan and thread-level [`balance
/// index`](ScheduleStats::balance_index), and how many tasks it dispatched.
/// This is how the task modules report their stats *out* of
/// [`parallel_train_step`] (instead of the pre-ISSUE-5 behavior of merging
/// them away): the autotuner consumes the GEMM-shaped stages' entries and
/// `experiments::fig15` renders the measured balance figure from them.
#[derive(Debug, Clone, Copy)]
pub struct StageSample {
    pub label: &'static str,
    pub makespan_s: f64,
    pub balance: f64,
    pub tasks: usize,
}

/// Result of one task-parallel train step.
pub struct ParallelStepResult {
    pub loss: f32,
    pub correct: usize,
    /// All stages merged ([`ScheduleStats::merge`]).
    pub stats: ScheduleStats,
    /// Per-stage samples in execution order.
    pub stages: Vec<StageSample>,
}

/// One backward task of a conv layer:
/// * [`BwdTask::Tile`] — fused row tile (df/db, plus dx when the kernel is
///   odd), the pre-2D path taken whenever neither grid column-splits;
/// * [`BwdTask::Lower`] — shared im2col: lowers one (image, row-range)
///   patch matrix (of `x`, or of `dy` for the dx space) once into the
///   caller's lowering buffer, so the row range's column tiles stop
///   re-running the same im2col per panel window;
/// * [`BwdTask::Df`] / [`BwdTask::Dx`] — 2D tiles over output-channel /
///   input-channel panel windows when the grids do split (small batch ×
///   small spatial extent); `off` points at the row range's shared lowered
///   patches, or is [`OWN_SCRATCH`] when the tile is its range's only
///   column tile and lowers into the worker arena as before;
/// * [`BwdTask::DxImage`] — whole-image input-gradient fallback for even
///   kernels (asymmetric implicit padding doesn't ride the flipped-forward
///   conv).
#[derive(Debug, Clone, Copy)]
pub enum BwdTask {
    Tile(ConvTask),
    Lower { off: usize, len: usize, n: usize, y0: usize, rows: usize, dy_space: bool },
    Df { t: ConvTile, off: usize },
    Dx { t: ConvTile, off: usize },
    DxImage(usize),
}

/// Sentinel `off`: the tile lowers its own patches into the executing
/// worker's arena (no shared segment exists for its row range).
pub const OWN_SCRATCH: usize = usize::MAX;

/// Backward of one conv layer with 2D tile tasks (the row granularity
/// mirrors the forward decomposition via `rows_per_task`; output/input
/// channel panels split when `batch × H` row tiles cannot feed the pool):
/// filter/bias gradients are accumulated into disjoint stripes of
/// per-worker arenas and reduced once at the end, the input gradient is
/// written into disjoint (row × channel-window) element sets. Numerically ≡
/// `ops::conv2d_same_bwd_*` to f32 reduction-order tolerance (per-tile
/// partial sums commute with the full-batch sums of Eq. 21).
///
/// Zero-copy / zero-alloc: `x`/`f`/`dy` are borrowed by the tasks, im2col
/// scratch and gradient partials live in the workers' [`ScratchArena`]s.
#[allow(clippy::too_many_arguments)]
pub fn conv_bwd_parallel(
    pool: &ThreadPool,
    d: &ConvDims,
    x: &[f32],
    f: &[f32],
    dy: &[f32],
    df: &mut [f32],
    db: &mut [f32],
    dx: Option<&mut [f32]>,
    rows_per_task: usize,
) -> ScheduleStats {
    let flip = if dx.is_some() && d.k % 2 == 1 {
        let swapped = ConvDims { c: d.co, co: d.c, ..*d };
        Some(ops::pack_filter(&swapped, &ops::flip_transpose_filter(d, f)))
    } else {
        None
    };
    let df_grid = plan_tile_grid(d.n * d.h, d.k * d.k * d.c, d.co, pool.size(), rows_per_task);
    let dx_grid = plan_cols_for_rows(
        df_grid.rows_per_tile,
        df_grid.row_tiles,
        d.k * d.k * d.co,
        d.c,
        pool.size(),
    );
    conv_bwd_parallel_packed(pool, d, x, f, dy, df, db, dx, flip.as_ref(), df_grid, dx_grid)
}

/// [`conv_bwd_parallel`] on a caller-provided flipped-filter pack (from the
/// network's [`crate::nn::WeightPacks`] cache) and tile grids; `flip_packed`
/// is required exactly when `dx` is wanted and the kernel is odd. `df_grid`
/// tiles (rows × output-channel panels) drive the Eq.-21/22 gradients;
/// `dx_grid` tiles (same rows × input-channel panels) drive the odd-kernel
/// Eq.-18 input gradient. When neither grid column-splits, the two collapse
/// into fused row-tile tasks — the pre-2D path, so large-batch layers pay
/// no extra dispatch. Wraps [`conv_bwd_parallel_packed_ws`] with a
/// throwaway lowering buffer (only touched when a grid column-splits).
#[allow(clippy::too_many_arguments)]
pub fn conv_bwd_parallel_packed(
    pool: &ThreadPool,
    d: &ConvDims,
    x: &[f32],
    f: &[f32],
    dy: &[f32],
    df: &mut [f32],
    db: &mut [f32],
    dx: Option<&mut [f32]>,
    flip_packed: Option<&PackedB>,
    df_grid: TileGrid,
    dx_grid: TileGrid,
) -> ScheduleStats {
    let mut lower = Vec::new();
    conv_bwd_parallel_packed_ws(
        pool, d, x, f, dy, df, db, dx, flip_packed, df_grid, dx_grid, &mut lower,
    )
}

/// Build the backward stage plan for one conv layer: the [`BwdTask`] DAG
/// (fused row tiles, or Lower → Df/Dx column tiles when a grid splits, plus
/// per-image dx fallbacks for even kernels) and the total lowering-buffer
/// length its `Lower` tasks claim. Pure planning — shared with the offline
/// plan-sweep verifier, which replays every emitted plan through
/// [`check::verify`] via [`conv_bwd_claims`].
pub fn conv_bwd_dag(
    d: &ConvDims,
    want_dx: bool,
    df_grid: &TileGrid,
    dx_grid: &TileGrid,
) -> (TaskDag<BwdTask>, usize) {
    let dd = *d;
    let odd_k = dd.k % 2 == 1;
    let kkc = dd.k * dd.k * dd.c;
    let kkco = dd.k * dd.k * dd.co;
    // Fused row tiles whenever neither space column-splits (and, for odd-k
    // dx, the row splits agree); otherwise independent Df/Dx tile kinds.
    let fused = df_grid.panel_tiles == 1
        && (!want_dx
            || !odd_k
            || (dx_grid.panel_tiles == 1 && dx_grid.rows_per_tile == df_grid.rows_per_tile));

    // Task list: dy is read-only here, so df and dx tiles never need
    // ordering between them — the only dependencies are each column-split
    // row range's tiles on its shared Lower task.
    let mut dag: TaskDag<BwdTask> = TaskDag::new();
    let cost_per_el = (dd.w * dd.k * dd.k * dd.c) as f64;
    let panels_co = panel_count(dd.co);
    let panels_c = panel_count(dd.c);
    let mut lower_total = 0usize;
    for n in 0..dd.n {
        if fused {
            let mut y = 0;
            while y < dd.h {
                let rows = df_grid.rows_per_tile.min(dd.h - y);
                // A tile does the filter-gradient contraction and (odd k)
                // the input-gradient conv: ~2× the forward cost per row.
                dag.add(
                    format!("conv_bwd[n{n},y{y}+{rows}]"),
                    2.0 * cost_per_el * (rows * dd.co) as f64,
                    &[],
                    BwdTask::Tile(ConvTask { n, y0: y, rows }),
                );
                y += rows;
            }
        } else {
            let mut y = 0;
            while y < dd.h {
                let rows = df_grid.rows_per_tile.min(dd.h - y);
                // Column-split row ranges lower their x patches once.
                let (off, dep) = if df_grid.panel_tiles > 1 {
                    let len = rows * dd.w * kkc;
                    let off = lower_total;
                    lower_total += len;
                    let lid = dag.add(
                        format!("conv_bwd_lower_x[n{n},y{y}]"),
                        len as f64,
                        &[],
                        BwdTask::Lower { off, len, n, y0: y, rows, dy_space: false },
                    );
                    (off, Some(lid))
                } else {
                    (OWN_SCRATCH, None)
                };
                let deps: &[usize] = match &dep {
                    Some(id) => std::slice::from_ref(id),
                    None => &[],
                };
                let mut p = 0;
                while p < panels_co {
                    let np = df_grid.panels_per_tile.min(panels_co - p);
                    let (_, jw) = ops::panel_window(dd.co, p, np);
                    dag.add(
                        format!("conv_bwd_df[n{n},y{y},p{p}]"),
                        cost_per_el * (rows * jw) as f64,
                        deps,
                        BwdTask::Df { t: ConvTile { n, y0: y, rows, p0: p, np }, off },
                    );
                    p += np;
                }
                y += rows;
            }
            if want_dx && odd_k {
                let cost_dx_el = (dd.w * dd.k * dd.k * dd.co) as f64;
                let mut y = 0;
                while y < dd.h {
                    let rows = dx_grid.rows_per_tile.min(dd.h - y);
                    let (off, dep) = if dx_grid.panel_tiles > 1 {
                        let len = rows * dd.w * kkco;
                        let off = lower_total;
                        lower_total += len;
                        let lid = dag.add(
                            format!("conv_bwd_lower_dy[n{n},y{y}]"),
                            len as f64,
                            &[],
                            BwdTask::Lower { off, len, n, y0: y, rows, dy_space: true },
                        );
                        (off, Some(lid))
                    } else {
                        (OWN_SCRATCH, None)
                    };
                    let deps: &[usize] = match &dep {
                        Some(id) => std::slice::from_ref(id),
                        None => &[],
                    };
                    let mut p = 0;
                    while p < panels_c {
                        let np = dx_grid.panels_per_tile.min(panels_c - p);
                        let (_, jw) = ops::panel_window(dd.c, p, np);
                        dag.add(
                            format!("conv_bwd_dx[n{n},y{y},p{p}]"),
                            cost_dx_el * (rows * jw) as f64,
                            deps,
                            BwdTask::Dx { t: ConvTile { n, y0: y, rows, p0: p, np }, off },
                        );
                        p += np;
                    }
                    y += rows;
                }
            }
        }
        if want_dx && !odd_k {
            dag.add(
                format!("conv_bwd_dx[n{n}]"),
                cost_per_el * (dd.h * dd.co) as f64,
                &[],
                BwdTask::DxImage(n),
            );
        }
    }
    (dag, lower_total)
}

/// Lower a [`conv_bwd_dag`] plan to access claims over the stage's shared
/// buffers: `dx` rows / channel windows ([`check::Buf::Out`]), the shared
/// lowering buffer ([`check::Buf::Lower`]) and the per-worker gradient
/// accumulators ([`check::Buf::ArenaGradF`]/[`ArenaGradB`](check::Buf),
/// worker-serialized, so exempt from pairwise disjointness but still
/// cross-checked at runtime under `--features chk`).
pub fn conv_bwd_claims(
    d: &ConvDims,
    want_dx: bool,
    dag: &TaskDag<BwdTask>,
) -> Vec<check::Claim> {
    use check::{Buf, Claim, Span};
    let odd_k = d.k % 2 == 1;
    let kkc = d.k * d.k * d.c;
    let kkco = d.k * d.k * d.co;
    let x_img = d.h * d.w * d.c;
    let mut cs = Vec::new();
    for nd in dag.nodes() {
        let id = nd.id;
        match nd.payload {
            BwdTask::Tile(t) => {
                cs.push(Claim::write(id, Buf::ArenaGradF, Span::interval(0, d.f_len())));
                cs.push(Claim::write(id, Buf::ArenaGradB, Span::interval(0, d.co)));
                if want_dx && odd_k {
                    let base = (t.n * d.h + t.y0) * d.w * d.c;
                    let len = t.rows * d.w * d.c;
                    cs.push(Claim::write(id, Buf::Out, Span::interval(base, len)));
                }
            }
            BwdTask::Lower { off, len, .. } => {
                cs.push(Claim::write(id, Buf::Lower, Span::interval(off, len)));
            }
            BwdTask::Df { t, off } => {
                let (j0, jw) = ops::panel_window(d.co, t.p0, t.np);
                let patches = t.rows * d.w;
                cs.push(Claim::write(id, Buf::ArenaGradF, Span::strided(j0, kkc, d.co, jw)));
                cs.push(Claim::write(id, Buf::ArenaGradB, Span::interval(j0, jw)));
                if off != OWN_SCRATCH {
                    cs.push(Claim::read(id, Buf::Lower, Span::interval(off, patches * kkc)));
                }
            }
            BwdTask::Dx { t, off } => {
                let (j0, jw) = ops::panel_window(d.c, t.p0, t.np);
                let patches = t.rows * d.w;
                let base = (t.n * d.h + t.y0) * d.w * d.c;
                cs.push(Claim::write(id, Buf::Out, Span::strided(base + j0, patches, d.c, jw)));
                if off != OWN_SCRATCH {
                    cs.push(Claim::read(id, Buf::Lower, Span::interval(off, patches * kkco)));
                }
            }
            BwdTask::DxImage(n) => {
                cs.push(Claim::write(id, Buf::Out, Span::interval(n * x_img, x_img)));
            }
        }
    }
    cs
}

/// [`conv_bwd_parallel_packed`] with a caller-owned lowering buffer: when a
/// grid column-splits, each (image, row-range) patch matrix — `x` patches
/// for the df tiles, `dy` patches for the odd-kernel dx tiles — is lowered
/// **once** by a level-0 [`BwdTask::Lower`] task into a disjoint segment of
/// `lower`, and the range's column tiles read it behind the scheduler's
/// dependency wait instead of each re-running im2col.
#[allow(clippy::too_many_arguments)]
pub fn conv_bwd_parallel_packed_ws(
    pool: &ThreadPool,
    d: &ConvDims,
    x: &[f32],
    f: &[f32],
    dy: &[f32],
    df: &mut [f32],
    db: &mut [f32],
    dx: Option<&mut [f32]>,
    flip_packed: Option<&PackedB>,
    df_grid: TileGrid,
    dx_grid: TileGrid,
    lower: &mut Vec<f32>,
) -> ScheduleStats {
    assert_eq!(x.len(), d.x_len());
    assert_eq!(dy.len(), d.y_len());
    assert_eq!(df.len(), d.f_len());
    assert_eq!(db.len(), d.co);
    df_grid.check();
    dx_grid.check();
    let want_dx = dx.is_some();
    let odd_k = d.k % 2 == 1;

    let dd = *d;
    let kkc = dd.k * dd.k * dd.c;
    let kkco = dd.k * dd.k * dd.co;
    // Input gradient = SAME forward conv of dy with the spatially-flipped,
    // channel-transposed filter (odd k): packed once per weight mutation in
    // the caller's pack cache, shared read-only by all tiles.
    let swapped = ConvDims { c: dd.co, co: dd.c, ..dd };
    let per_image = ConvDims { n: 1, ..dd };
    let flip_packed: Option<&PackedB> = if want_dx && odd_k {
        let pf = flip_packed.expect("flip_packed required for odd-kernel dx");
        debug_assert_eq!(pf.kk(), kkco);
        debug_assert_eq!(pf.n(), dd.c);
        Some(pf)
    } else {
        None
    };
    let (dag, lower_total) = conv_bwd_dag(d, want_dx, &df_grid, &dx_grid);
    let guard = check::stage_guard(&dag, || conv_bwd_claims(d, want_dx, &dag));

    // Only the packed flip-forward path reads the zero bias; skip the
    // allocation entirely on df/db-only and even-kernel calls.
    let zero_bias = if flip_packed.is_some() { vec![0.0f32; dd.c] } else { Vec::new() };
    let dx_buf = dx.map(|s| DisjointBuf::new(s).checked(check::Buf::Out, &guard));
    let x_img = dd.h * dd.w * dd.c;
    let y_img = dd.h * dd.w * dd.co;

    // Size + zero each worker's gradient accumulators for this layer call.
    fc_tasks::zero_arena_grads(pool, dd.f_len(), dd.co);

    let lslice = ScratchArena::grow(lower, lower_total);
    let lbuf = DisjointBuf::new(lslice).checked(check::Buf::Lower, &guard);
    let arenas = pool.arenas();
    let stats = execute_dag(pool, dag, move |worker: usize, task: &BwdTask| {
        match *task {
            BwdTask::Tile(t) => {
                let patches = t.rows * dd.w;
                let mut arena = arenas[worker].lock().unwrap();
                let arena = &mut *arena;
                // Eq. 21 tile: df_worker += im2col(x tile)ᵀ · dy tile.
                let cols = ScratchArena::grow(&mut arena.cols, patches * kkc);
                ops::im2col_rows(&dd, x, t.n, t.y0, t.rows, cols);
                let dy0 = (t.n * dd.h + t.y0) * dd.w * dd.co;
                let dyt = &dy[dy0..dy0 + patches * dd.co];
                let gf = ScratchArena::grad_all(&mut arena.grad_f, dd.f_len());
                ops::gemm_tn_acc(patches, kkc, dd.co, cols, dyt, gf);
                // Eq. 22 tile: db_worker += column sums of the dy tile.
                let gb = ScratchArena::grad_all(&mut arena.grad_b, dd.co);
                for px in 0..patches {
                    let row = &dyt[px * dd.co..(px + 1) * dd.co];
                    for (acc, &v) in gb.iter_mut().zip(row.iter()) {
                        *acc += v;
                    }
                }
                // Eq. 18 tile (odd k): dx rows [y0, y0+rows) of image n via
                // the packed flipped-filter forward.
                if let Some(pf) = flip_packed {
                    let cols2 = ScratchArena::grow(&mut arena.cols2, patches * kkco);
                    // SAFETY: tile (n, y0, rows) exclusively owns dx rows
                    // [y0, y0+rows) of image n; tiles never overlap.
                    let dxt = unsafe {
                        dx_buf
                            .as_ref()
                            .unwrap()
                            .slice_mut((t.n * dd.h + t.y0) * dd.w * dd.c, patches * dd.c)
                    };
                    ops::conv2d_same_rows_packed(
                        &swapped, dy, pf, &zero_bias, t.n, t.y0, t.rows, cols2, dxt,
                    );
                }
            }
            BwdTask::Lower { off, len, n, y0, rows, dy_space } => {
                // SAFETY: each Lower task exclusively owns its segment of
                // the lowering buffer.
                let cols = unsafe { lbuf.slice_mut(off, len) };
                if dy_space {
                    ops::im2col_rows(&swapped, dy, n, y0, rows, cols);
                } else {
                    ops::im2col_rows(&dd, x, n, y0, rows, cols);
                }
            }
            BwdTask::Df { t, off } => {
                // Eq. 21/22 column stripe: this tile's dW/db contributions
                // land in the [j0, j0+jw) output-channel stripe of the
                // executing worker's arena — disjoint from every other
                // stripe, shared (accumulated) only with this worker's own
                // tiles of the same stripe.
                let (j0, jw) = ops::panel_window(dd.co, t.p0, t.np);
                let patches = t.rows * dd.w;
                let mut arena = arenas[worker].lock().unwrap();
                let arena = &mut *arena;
                let cols: &[f32] = if off == OWN_SCRATCH {
                    // Sole column tile of its row range: lower into the
                    // worker arena as before.
                    let c = ScratchArena::grow(&mut arena.cols, patches * kkc);
                    ops::im2col_rows(&dd, x, t.n, t.y0, t.rows, c);
                    c
                } else {
                    // SAFETY: the DAG dependency guarantees the segment was
                    // fully lowered and is no longer written.
                    unsafe { lbuf.slice_ref(off, patches * kkc) }
                };
                let dy0 = (t.n * dd.h + t.y0) * dd.w * dd.co;
                let dyt = &dy[dy0..dy0 + patches * dd.co];
                let gf = ScratchArena::grad_all(&mut arena.grad_f, dd.f_len());
                ops::gemm_tn_acc_cols(patches, kkc, dd.co, cols, dyt, gf, j0, jw);
                let gb = ScratchArena::grad_stripe(&mut arena.grad_b, dd.co, j0, jw);
                for px in 0..patches {
                    let row = &dyt[px * dd.co + j0..px * dd.co + j0 + jw];
                    for (acc, &v) in gb.iter_mut().zip(row.iter()) {
                        *acc += v;
                    }
                }
            }
            BwdTask::Dx { t, off } => {
                // Eq. 18 tile windowed over input-channel panels: the
                // flipped-filter forward writes only columns [j0, j0+jw) of
                // this tile's dx rows.
                let pf = flip_packed.expect("Dx tiles only exist with a flip pack");
                let (j0, jw) = ops::panel_window(dd.c, t.p0, t.np);
                let patches = t.rows * dd.w;
                let base = (t.n * dd.h + t.y0) * dd.w * dd.c;
                let dxb = dx_buf.as_ref().unwrap();
                for px in 0..patches {
                    // SAFETY: this tile exclusively owns its (row ×
                    // channel-window) dx elements.
                    unsafe { dxb.slice_mut(base + px * dd.c + j0, jw) }.fill(0.0);
                }
                if off == OWN_SCRATCH {
                    let mut arena = arenas[worker].lock().unwrap();
                    let cols2 = ScratchArena::grow(&mut arena.cols2, patches * kkco);
                    ops::im2col_rows(&swapped, dy, t.n, t.y0, t.rows, cols2);
                    // SAFETY: panel-windowed writes stay inside this tile's
                    // column window.
                    unsafe {
                        ops::gemm_packed_acc_panels_raw(
                            patches,
                            cols2,
                            pf,
                            dxb.ptr_at(base),
                            t.p0,
                            t.np,
                        );
                    }
                } else {
                    // SAFETY: shared read behind the dependency barrier;
                    // panel-windowed writes stay inside this tile's window.
                    let cols2 = unsafe { lbuf.slice_ref(off, patches * kkco) };
                    unsafe {
                        ops::gemm_packed_acc_panels_raw(
                            patches,
                            cols2,
                            pf,
                            dxb.ptr_at(base),
                            t.p0,
                            t.np,
                        );
                    }
                }
            }
            BwdTask::DxImage(n) => {
                let dys = &dy[n * y_img..(n + 1) * y_img];
                // SAFETY: image task n exclusively owns dx[n·x_img, (n+1)·x_img).
                let dxs = unsafe { dx_buf.as_ref().unwrap().slice_mut(n * x_img, x_img) };
                ops::conv2d_same_bwd_input_naive(&per_image, dys, f, dxs);
            }
        }
    });

    // Post-barrier reduce of the per-worker partials (the paper's Fig.-9
    // "reduce" node) — stripe-sequential and contention-free, parallelized
    // over chunks when df is large.
    fc_tasks::reduce_arena_grads(pool, df, db);
    stats
}

/// One full training step (forward + backward + SGD, Eq. 23) executed with
/// the inner-layer task decomposition on the thread pool: 2D row×panel
/// tiles for the conv **and** FC stacks (planned per stage by the
/// [`TilePolicy`] from `(batch, M, K, N, workers)` — columns split exactly
/// when batch rows alone cannot feed the workers, the Table-2 cases-5–7
/// regime), per-image pool tasks, chunked ReLU tasks and row-tile loss
/// tasks — the whole pipeline is inner-parallel, not just conv.
/// Intermediate buffers live in the caller-owned [`StepWorkspace`] (no
/// per-layer `vec!` or activation clones; steady-state heap traffic is the
/// scheduler's task boxes only) and weight panels come from the network's
/// pack cache. Numerically ≡ `Network::train_batch` to f32 reduction-order
/// tolerance.
///
/// Under [`TilePolicy::Auto`] the GEMM-shaped stages route their grids
/// through the network's node-owned [`AutoTuner`]: the pool is calibrated
/// once at first use (micro-kernel rate + dispatch overhead → the planner's
/// FLOP floor), each stage's measured [`ScheduleStats`] feeds back into its
/// [`StageKey`] entry, and after the exploration window every stage runs
/// its locked best grid. Backward companion grids (`dx` spaces) follow the
/// tuned base grid's row split. Static policies bypass the tuner entirely.
#[allow(clippy::too_many_arguments)]
pub fn parallel_train_step(
    pool: &ThreadPool,
    net: &mut Network,
    x: &[f32],
    y: &[f32],
    batch: usize,
    lr: f32,
    policy: TilePolicy,
    ws: &mut StepWorkspace,
) -> ParallelStepResult {
    let cfg = &net.cfg;
    let hw = cfg.input_hw;
    let workers = pool.size();
    let conv_rows = policy.rows_per_task();
    ws.prepare(cfg, batch, &net.weights);
    net.packs.borrow_mut().ensure(cfg, &net.weights);
    let mut agg: Option<ScheduleStats> = None;
    let mut stages: Vec<StageSample> = Vec::new();
    // FC/loss row granularity: ~2 batch-row tiles per worker.
    let fc_rows = (batch / (2 * workers)).max(1);

    let (loss, correct) = {
        let mut tuner: Option<RefMut<'_, AutoTuner>> = if policy.is_auto() {
            let mut t = net.tuner.borrow_mut();
            t.ensure_calibrated(pool);
            Some(t)
        } else {
            None
        };
        let packs = net.packs.borrow();
        let wts = net.weights.tensors();

        // Plan one GEMM-shaped stage: through the tuner when one drives
        // this step, statically otherwise. Yields `(grid, key)`.
        macro_rules! plan_stage {
            ($kind:expr, $m:expr, $k:expr, $n:expr, $hint:expr) => {{
                let (m, k, n, hint) = ($m, $k, $n, $hint);
                match tuner.as_mut() {
                    Some(t) => {
                        let key = StageKey::new($kind, m, k, n, workers);
                        (t.plan(key, hint), Some(key))
                    }
                    None => (policy.plan(m, k, n, workers, hint), None),
                }
            }};
        }
        // Record one executed stage: feed the measured stats back into the
        // tuner (tuned stages only), append the per-stage sample, merge
        // into the step aggregate.
        macro_rules! record {
            ($label:expr, $key:expr, $s:expr) => {{
                let s: ScheduleStats = $s;
                let key: Option<StageKey> = $key;
                if let (Some(t), Some(k)) = (tuner.as_mut(), key) {
                    t.observe(k, &s);
                }
                stages.push(StageSample {
                    label: $label,
                    makespan_s: s.makespan_s,
                    balance: s.balance_index(),
                    tasks: s.tasks,
                });
                if let Some(a) = agg.as_mut() {
                    a.merge(&s);
                } else {
                    agg = Some(s);
                }
            }};
        }

        // ---- Forward: conv stack (Algorithm 4.1 tasks per layer) ---------
        for l in 0..cfg.conv_layers {
            let c = if l == 0 { cfg.in_channels } else { cfg.filters };
            let d = ConvDims { n: batch, h: hw, w: hw, c, k: cfg.kernel_hw, co: cfg.filters };
            let (grid, key) =
                plan_stage!(StageKind::ConvFwd, batch * hw, d.k * d.k * d.c, d.co, conv_rows);
            let (prev, cur) = ws.conv_outs.split_at_mut(l);
            let input: &[f32] = if l == 0 { x } else { &prev[l - 1] };
            let out = &mut cur[0][..];
            let s = conv2d_parallel_packed_ws(
                pool,
                &d,
                input,
                &packs.conv[l],
                wts[2 * l + 1].data(),
                out,
                grid,
                &mut ws.cols,
            );
            record!("conv_fwd", key, s);
            let s = fc_tasks::relu_fwd_parallel(pool, out, pool.size());
            record!("relu_fwd", None, s);
        }

        // ---- Forward: pool (per-image tasks) + FC row tiles --------------
        let c = if cfg.conv_layers == 0 { cfg.in_channels } else { cfg.filters };
        let win = cfg.pool_window;
        let hp = hw / win;
        let cur: &[f32] = if cfg.conv_layers == 0 {
            x
        } else {
            &ws.conv_outs[cfg.conv_layers - 1]
        };
        let s = fc_tasks::mean_pool_fwd_parallel(pool, batch, hw, hw, c, win, cur, &mut ws.pooled);
        record!("pool_fwd", None, s);
        for l in 0..cfg.fc_layers {
            let (prev, cur) = ws.fc_outs.split_at_mut(l);
            let feat: &[f32] = if l == 0 { &ws.pooled } else { &prev[l - 1] };
            let b = wts[2 * cfg.conv_layers + 2 * l + 1].data();
            let w = &packs.fc_w[l];
            let (grid, key) = plan_stage!(StageKind::DenseFwd, batch, w.kk(), w.n(), fc_rows);
            let s = fc_tasks::dense_fwd_parallel(
                pool,
                batch,
                feat,
                w,
                b,
                &mut cur[0][..],
                true,
                grid,
            );
            record!("dense_fwd", key, s);
        }
        let last: &[f32] = if cfg.fc_layers == 0 {
            &ws.pooled
        } else {
            &ws.fc_outs[cfg.fc_layers - 1]
        };
        let ob = wts[2 * cfg.conv_layers + 2 * cfg.fc_layers + 1].data();
        let out_w = &packs.fc_w[cfg.fc_layers];
        let (out_grid, out_key) =
            plan_stage!(StageKind::DenseFwd, batch, out_w.kk(), out_w.n(), fc_rows);
        let s = fc_tasks::dense_fwd_parallel(
            pool,
            batch,
            last,
            out_w,
            ob,
            &mut ws.logits,
            false,
            out_grid,
        );
        record!("dense_fwd", out_key, s);

        // ---- Loss (Eq. 16), row tiles ------------------------------------
        let (loss, correct, s) = fc_tasks::loss_parallel(
            pool,
            batch,
            cfg.num_classes,
            &ws.logits,
            y,
            &mut ws.dlogits,
            &mut ws.probs,
            &mut ws.loss_parts,
            fc_rows,
        );
        record!("loss", None, s);

        // ---- Backward: FC row tiles (ReLU masks fused into the tiles) ----
        let pooled_dim = hp * hp * c;
        let out_w_idx = 2 * cfg.conv_layers + 2 * cfg.fc_layers;
        let grads = ws.grads.as_mut().expect("workspace prepared");
        let gts = grads.tensors_mut();
        let last_feat: &[f32] = if cfg.fc_layers > 0 {
            &ws.fc_outs[cfg.fc_layers - 1]
        } else {
            &ws.pooled
        };
        let last_dim = if cfg.fc_layers > 0 { cfg.fc_neurons } else { pooled_dim };
        {
            let (a, b) = gts.split_at_mut(out_w_idx + 1);
            let (dy_grid, key) =
                plan_stage!(StageKind::DenseBwd, batch, last_dim, cfg.num_classes, fc_rows);
            let dx_grid = policy.plan_cols(&dy_grid, cfg.num_classes, last_dim, workers);
            let s = fc_tasks::dense_bwd_parallel(
                pool,
                batch,
                last_dim,
                cfg.num_classes,
                last_feat,
                &packs.fc_wt[cfg.fc_layers],
                &mut ws.dlogits,
                None,
                &mut ws.dfeat[..batch * last_dim],
                a[out_w_idx].data_mut(),
                b[0].data_mut(),
                dy_grid,
                dx_grid,
            );
            record!("dense_bwd", key, s);
        }
        for l in (0..cfg.fc_layers).rev() {
            let in_feat: &[f32] = if l == 0 { &ws.pooled } else { &ws.fc_outs[l - 1] };
            let in_dim = if l == 0 { pooled_dim } else { cfg.fc_neurons };
            let w_idx = 2 * cfg.conv_layers + 2 * l;
            {
                let (a, b) = gts.split_at_mut(w_idx + 1);
                let (dy_grid, key) =
                    plan_stage!(StageKind::DenseBwd, batch, in_dim, cfg.fc_neurons, fc_rows);
                let dx_grid = policy.plan_cols(&dy_grid, cfg.fc_neurons, in_dim, workers);
                let s = fc_tasks::dense_bwd_parallel(
                    pool,
                    batch,
                    in_dim,
                    cfg.fc_neurons,
                    in_feat,
                    &packs.fc_wt[l],
                    &mut ws.dfeat[..batch * cfg.fc_neurons],
                    Some(&ws.fc_outs[l]),
                    &mut ws.dfeat2[..batch * in_dim],
                    a[w_idx].data_mut(),
                    b[0].data_mut(),
                    dy_grid,
                    dx_grid,
                );
                record!("dense_bwd", key, s);
            }
            std::mem::swap(&mut ws.dfeat, &mut ws.dfeat2);
        }

        // ---- Backward: pool (per-image) + conv row tiles (Fig. 8) --------
        let s = fc_tasks::mean_pool_bwd_parallel(
            pool,
            batch,
            hw,
            hw,
            c,
            win,
            &ws.dfeat[..batch * pooled_dim],
            &mut ws.dconv,
        );
        record!("pool_bwd", None, s);
        for l in (0..cfg.conv_layers).rev() {
            let s = fc_tasks::relu_bwd_parallel(pool, &ws.conv_outs[l], &mut ws.dconv, pool.size());
            record!("relu_bwd", None, s);
            let cin = if l == 0 { cfg.in_channels } else { cfg.filters };
            let d = ConvDims { n: batch, h: hw, w: hw, c: cin, k: cfg.kernel_hw, co: cfg.filters };
            let w_idx = 2 * l;
            let in_act: &[f32] = if l == 0 { x } else { &ws.conv_outs[l - 1] };
            let want_dx = l > 0;
            {
                let (a, b) = gts.split_at_mut(w_idx + 1);
                let dx = if want_dx { Some(&mut ws.dconv2[..d.x_len()]) } else { None };
                let flip = if want_dx && d.k % 2 == 1 { Some(&packs.conv_flip[l]) } else { None };
                // dx roughly doubles the stage's work: key it separately so
                // df-only and df+dx layers never pool makespan samples.
                let kind = if want_dx { StageKind::ConvBwdDx } else { StageKind::ConvBwd };
                let (df_grid, key) =
                    plan_stage!(kind, batch * hw, d.k * d.k * d.c, d.co, conv_rows);
                let dx_grid = policy.plan_cols(&df_grid, d.k * d.k * d.co, d.c, workers);
                let s = conv_bwd_parallel_packed_ws(
                    pool,
                    &d,
                    in_act,
                    wts[w_idx].data(),
                    &ws.dconv,
                    a[w_idx].data_mut(),
                    b[0].data_mut(),
                    dx,
                    flip,
                    df_grid,
                    dx_grid,
                    &mut ws.cols,
                );
                record!("conv_bwd", key, s);
            }
            if want_dx {
                std::mem::swap(&mut ws.dconv, &mut ws.dconv2);
            }
        }
        (loss, correct)
    };

    // ---- SGD (Eq. 23) -------------------------------------------------------
    net.weights.axpy(-lr, ws.grads());
    let stats = agg.unwrap_or_else(|| ScheduleStats::zero(pool.size()));
    ParallelStepResult { loss, correct, stats, stages }
}

/// Build the Fig.-9 style task DAG for a whole train step at (image × layer)
/// granularity — used for DAG-structure analysis and critical-path benches.
pub fn train_step_dag(cfg: &NetworkConfig, batch: usize) -> TaskDag<String> {
    let mut dag = TaskDag::new();
    let hw = cfg.input_hw;
    let k = cfg.kernel_hw;
    let mut prev: Vec<usize> = Vec::new();
    for l in 0..cfg.conv_layers {
        let c = if l == 0 { cfg.in_channels } else { cfg.filters };
        let cost = (hw * hw * k * k * c * cfg.filters) as f64;
        let mut cur = Vec::new();
        for n in 0..batch {
            let deps: Vec<usize> = if l == 0 { vec![] } else { vec![prev[n]] };
            cur.push(dag.add(format!("fwd_conv{l}[n{n}]"), cost, &deps, format!("fwd_conv{l}")));
        }
        prev = cur;
    }
    let pool_cost = (hw * hw * cfg.filters) as f64;
    let mut pool_ids = Vec::new();
    for n in 0..batch {
        let deps = if prev.is_empty() { vec![] } else { vec![prev[n]] };
        pool_ids.push(dag.add(format!("fwd_pool[n{n}]"), pool_cost, &deps, "fwd_pool".into()));
    }
    let hp = hw / cfg.pool_window;
    let fan0 = hp * hp * cfg.filters;
    let mut last = dag.add(
        "fwd_fc0".to_string(),
        (batch * fan0 * cfg.fc_neurons) as f64,
        &pool_ids,
        "fwd_fc".into(),
    );
    for l in 1..cfg.fc_layers {
        last = dag.add(
            format!("fwd_fc{l}"),
            (batch * cfg.fc_neurons * cfg.fc_neurons) as f64,
            &[last],
            "fwd_fc".into(),
        );
    }
    let loss = dag.add("loss", (batch * cfg.num_classes) as f64, &[last], "loss".into());
    let mut bwd_last = dag.add("bwd_fc", (batch * cfg.fc_neurons) as f64, &[loss], "bwd_fc".into());
    bwd_last = dag.add("bwd_pool", pool_cost, &[bwd_last], "bwd_pool".into());
    for l in (0..cfg.conv_layers).rev() {
        let c = if l == 0 { cfg.in_channels } else { cfg.filters };
        let cost = (hw * hw * k * k * c * cfg.filters) as f64;
        let mut cur = Vec::new();
        for n in 0..batch {
            cur.push(dag.add(format!("bwd_conv{l}[n{n}]"), cost, &[bwd_last], format!("bwd_conv{l}")));
        }
        bwd_last = dag.add(
            format!("reduce_conv{l}"),
            (k * k * c * cfg.filters) as f64,
            &cur,
            "reduce".into(),
        );
    }
    dag
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::util::rng::Xoshiro256;

    fn cfg() -> NetworkConfig {
        NetworkConfig {
            name: "bp".into(),
            input_hw: 8,
            in_channels: 1,
            conv_layers: 2,
            filters: 4,
            kernel_hw: 3,
            fc_layers: 1,
            fc_neurons: 16,
            num_classes: 4,
            batch_size: 4,
            pool_window: 2,
        }
    }

    #[test]
    fn conv_bwd_parallel_matches_serial() {
        let mut rng = Xoshiro256::new(20);
        let d = ConvDims { n: 4, h: 6, w: 6, c: 2, k: 3, co: 3 };
        let x: Vec<f32> = (0..d.x_len()).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let f: Vec<f32> = (0..d.f_len()).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let dy: Vec<f32> = (0..d.y_len()).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let mut df_s = vec![0.0; d.f_len()];
        let mut db_s = vec![0.0; d.co];
        let mut dx_s = vec![0.0; d.x_len()];
        ops::conv2d_same_bwd_filter(&d, &x, &dy, &mut df_s, &mut db_s);
        ops::conv2d_same_bwd_input(&d, &dy, &f, &mut dx_s);
        let pool = ThreadPool::new(4);
        for rows in [1usize, 2, 4, 6] {
            let mut df_p = vec![0.0; d.f_len()];
            let mut db_p = vec![0.0; d.co];
            let mut dx_p = vec![0.0; d.x_len()];
            conv_bwd_parallel(&pool, &d, &x, &f, &dy, &mut df_p, &mut db_p, Some(&mut dx_p), rows);
            for (a, b) in df_s.iter().zip(df_p.iter()) {
                assert!((a - b).abs() < 1e-4, "rows={rows}");
            }
            for (a, b) in db_s.iter().zip(db_p.iter()) {
                assert!((a - b).abs() < 1e-4, "rows={rows}");
            }
            for (a, b) in dx_s.iter().zip(dx_p.iter()) {
                assert!((a - b).abs() < 1e-4, "rows={rows}");
            }
        }
    }

    /// Even kernels take the per-image naive fallback for dx while df/db
    /// still run the row-tile path — all three must match the references.
    #[test]
    fn conv_bwd_parallel_even_kernel_fallback() {
        let mut rng = Xoshiro256::new(22);
        let d = ConvDims { n: 3, h: 5, w: 5, c: 2, k: 2, co: 3 };
        let x: Vec<f32> = (0..d.x_len()).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let f: Vec<f32> = (0..d.f_len()).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let dy: Vec<f32> = (0..d.y_len()).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let mut df_s = vec![0.0; d.f_len()];
        let mut db_s = vec![0.0; d.co];
        let mut dx_s = vec![0.0; d.x_len()];
        ops::conv2d_same_bwd_filter_naive(&d, &x, &dy, &mut df_s, &mut db_s);
        ops::conv2d_same_bwd_input_naive(&d, &dy, &f, &mut dx_s);
        let pool = ThreadPool::new(2);
        let mut df_p = vec![0.0; d.f_len()];
        let mut db_p = vec![0.0; d.co];
        let mut dx_p = vec![0.0; d.x_len()];
        conv_bwd_parallel(&pool, &d, &x, &f, &dy, &mut df_p, &mut db_p, Some(&mut dx_p), 2);
        for (a, b) in df_s.iter().zip(df_p.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
        for (a, b) in db_s.iter().zip(db_p.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
        for (a, b) in dx_s.iter().zip(dx_p.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    /// No dx requested: df/db alone must still reduce correctly.
    #[test]
    fn conv_bwd_parallel_without_dx() {
        let mut rng = Xoshiro256::new(23);
        let d = ConvDims { n: 2, h: 4, w: 7, c: 3, k: 3, co: 2 };
        let x: Vec<f32> = (0..d.x_len()).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let f: Vec<f32> = (0..d.f_len()).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let dy: Vec<f32> = (0..d.y_len()).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let mut df_s = vec![0.0; d.f_len()];
        let mut db_s = vec![0.0; d.co];
        ops::conv2d_same_bwd_filter(&d, &x, &dy, &mut df_s, &mut db_s);
        let pool = ThreadPool::new(3);
        let mut df_p = vec![0.0; d.f_len()];
        let mut db_p = vec![0.0; d.co];
        conv_bwd_parallel(&pool, &d, &x, &f, &dy, &mut df_p, &mut db_p, None, 1);
        for (a, b) in df_s.iter().zip(df_p.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
        for (a, b) in db_s.iter().zip(db_p.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn parallel_step_matches_serial_step() {
        let cfg = cfg();
        let ds = Dataset::synthetic(&cfg, 16, 0.1, 11);
        let (x, y, _) = ds.batch(0, 4);
        let mut serial = Network::init(&cfg, 12);
        let mut par = serial.clone();
        let pool = ThreadPool::new(4);
        let mut ws = StepWorkspace::new();
        let (sl, sc) = serial.train_batch(&x, &y, 4, 0.1);
        let r =
            parallel_train_step(&pool, &mut par, &x, &y, 4, 0.1, TilePolicy::grid2d(2), &mut ws);
        assert!((sl - r.loss).abs() < 1e-5, "loss {sl} vs {}", r.loss);
        assert_eq!(sc, r.correct);
        assert!(
            serial.weights.max_abs_diff(&par.weights) < 1e-5,
            "weights diverged: {}",
            serial.weights.max_abs_diff(&par.weights)
        );
    }

    /// The ISSUE-4 regime: batch smaller than the pool with FC layers wide
    /// enough to cross the planner's work floor, so the dense stages really
    /// do column-split — the whole 2D step must match the serial step, and
    /// the row-only policy must agree too.
    #[test]
    fn parallel_step_2d_small_batch_wide_fc_matches_serial() {
        let cfg = NetworkConfig {
            name: "widefc".into(),
            input_hw: 8,
            in_channels: 1,
            conv_layers: 1,
            filters: 4,
            kernel_hw: 3,
            fc_layers: 2,
            fc_neurons: 256,
            num_classes: 4,
            batch_size: 2,
            pool_window: 2,
        };
        // The planner must actually split FC columns at this shape.
        let g = plan_tile_grid(2, 256, 256, 4, 1);
        assert!(g.panel_tiles > 1, "test shape does not exercise 2D: {g:?}");
        let ds = Dataset::synthetic(&cfg, 8, 0.1, 19);
        let (x, y, _) = ds.batch(0, 2);
        let mut serial = Network::init(&cfg, 20);
        let mut par2d = serial.clone();
        let mut par1d = serial.clone();
        let pool = ThreadPool::new(4);
        let (sl, sc) = serial.train_batch(&x, &y, 2, 0.1);
        let mut ws = StepWorkspace::new();
        let r2 =
            parallel_train_step(&pool, &mut par2d, &x, &y, 2, 0.1, TilePolicy::grid2d(2), &mut ws);
        assert!((sl - r2.loss).abs() < 1e-5, "2d loss {sl} vs {}", r2.loss);
        assert_eq!(sc, r2.correct);
        assert!(
            serial.weights.max_abs_diff(&par2d.weights) < 1e-4,
            "2d weights diverged: {}",
            serial.weights.max_abs_diff(&par2d.weights)
        );
        let mut ws1 = StepWorkspace::new();
        let r1 = parallel_train_step(
            &pool,
            &mut par1d,
            &x,
            &y,
            2,
            0.1,
            TilePolicy::rows_only(2),
            &mut ws1,
        );
        assert!((sl - r1.loss).abs() < 1e-5, "rows-only loss {sl} vs {}", r1.loss);
        assert!(
            serial.weights.max_abs_diff(&par1d.weights) < 1e-4,
            "rows-only weights diverged: {}",
            serial.weights.max_abs_diff(&par1d.weights)
        );
    }

    /// `TilePolicy::Auto`: the tuner-driven step stays numerically ≡ the
    /// serial step across its whole exploration window (every candidate
    /// grid is an equivalent decomposition), accumulates per-stage tuner
    /// state on the network, and reports per-stage samples.
    #[test]
    fn parallel_step_auto_matches_serial_through_exploration() {
        let cfg = NetworkConfig {
            name: "auto_fc".into(),
            input_hw: 8,
            in_channels: 1,
            conv_layers: 1,
            filters: 4,
            kernel_hw: 3,
            fc_layers: 2,
            fc_neurons: 256,
            num_classes: 4,
            batch_size: 2,
            pool_window: 2,
        };
        let ds = Dataset::synthetic(&cfg, 8, 0.1, 29);
        let (x, y, _) = ds.batch(0, 2);
        let pool = ThreadPool::new(4);
        let mut serial = Network::init(&cfg, 30);
        let mut auto_net = serial.clone();
        let mut ws = StepWorkspace::new();
        let mut sws = StepWorkspace::new();
        for step in 0..12 {
            let (sl, sc) = serial.train_batch_ws(&x, &y, 2, 0.05, &mut sws);
            let r = parallel_train_step(
                &pool,
                &mut auto_net,
                &x,
                &y,
                2,
                0.05,
                TilePolicy::auto(2),
                &mut ws,
            );
            assert!(
                (sl - r.loss).abs() < 1e-3,
                "step {step}: serial loss {sl} vs auto {}",
                r.loss
            );
            assert_eq!(sc, r.correct, "step {step}");
            assert!(!r.stages.is_empty(), "step reported no stage samples");
            assert!(r.stages.iter().any(|s| s.label == "dense_fwd"));
            assert!(r.stages.iter().all(|s| s.makespan_s >= 0.0 && s.balance >= 0.0));
            // Weights track the serial trajectory within f32 reduction
            // tolerance, step by step (divergence would compound).
            assert!(
                serial.weights.max_abs_diff(&auto_net.weights) < 1e-3,
                "step {step}: weights diverged by {}",
                serial.weights.max_abs_diff(&auto_net.weights)
            );
        }
        let tuner = auto_net.take_tuner();
        assert!(tuner.calibration().is_some(), "pool was never calibrated");
        assert!(tuner.len() >= 3, "too few tuned stages: {}", tuner.len());
        let table = tuner.table();
        assert!(table.contains("dense_bwd"), "{table}");
    }

    #[test]
    fn parallel_training_converges() {
        let cfg = cfg();
        let ds = Dataset::synthetic(&cfg, 32, 0.1, 13);
        let (x, y, _) = ds.batch(0, 4);
        let mut net = Network::init(&cfg, 14);
        let pool = ThreadPool::new(2);
        let mut ws = StepWorkspace::new();
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..40 {
            let r = parallel_train_step(
                &pool,
                &mut net,
                &x,
                &y,
                4,
                0.3,
                TilePolicy::grid2d(2),
                &mut ws,
            );
            first.get_or_insert(r.loss);
            last = r.loss;
        }
        assert!(last < 0.5 * first.unwrap());
    }

    /// The workspace survives across differently-shaped parallel steps on
    /// the same pool (re-keying) without corrupting results.
    #[test]
    fn parallel_step_workspace_rekeys_across_configs() {
        let big = cfg();
        let small = NetworkConfig { fc_neurons: 8, filters: 2, ..cfg() };
        let pool = ThreadPool::new(3);
        let mut ws = StepWorkspace::new();
        let ds_big = Dataset::synthetic(&big, 8, 0.1, 15);
        let (xb, yb, _) = ds_big.batch(0, 4);
        let mut nb = Network::init(&big, 16);
        parallel_train_step(&pool, &mut nb, &xb, &yb, 4, 0.1, TilePolicy::grid2d(2), &mut ws);
        // Now a smaller network through the *same* workspace.
        let ds_small = Dataset::synthetic(&small, 8, 0.1, 17);
        let (xs, ys, _) = ds_small.batch(0, 4);
        let mut np = Network::init(&small, 18);
        let mut ns = np.clone();
        let (sl, _) = ns.train_batch(&xs, &ys, 4, 0.1);
        let r =
            parallel_train_step(&pool, &mut np, &xs, &ys, 4, 0.1, TilePolicy::grid2d(2), &mut ws);
        assert!((sl - r.loss).abs() < 1e-5, "stale workspace leaked: {sl} vs {}", r.loss);
        assert!(ns.weights.max_abs_diff(&np.weights) < 1e-5);
    }

    #[test]
    fn train_step_dag_structure() {
        let cfg = cfg();
        let dag = train_step_dag(&cfg, 4);
        let fwd_conv = dag.nodes().iter().filter(|n| n.label.starts_with("fwd_conv")).count();
        let bwd_conv = dag.nodes().iter().filter(|n| n.label.starts_with("bwd_conv")).count();
        assert_eq!(fwd_conv, 8);
        assert_eq!(bwd_conv, 8);
        let order = dag.topological_order();
        assert_eq!(order.len(), dag.len());
        let levels = dag.levels();
        let loss_id = dag.nodes().iter().position(|n| n.label == "loss").unwrap();
        assert!(levels[loss_id] >= 3);
    }

    #[test]
    fn dag_critical_path_shorter_than_total() {
        let dag = train_step_dag(&cfg(), 8);
        assert!(
            dag.critical_path_cost() < dag.total_cost() / 2.0,
            "expected ≥2× theoretical parallelism"
        );
    }
}
