//! Inner-layer parallel training (paper §4): task decomposition of the
//! convolutional layer (Algorithm 4.1) and the local weight training
//! (backward pass), task-DAG construction with priority marking (§4.2(1)),
//! and the priority scheduler with least-loaded thread assignment
//! (Algorithm 4.2).
//!
//! Tiles are **2D row×column**: batch/image rows crossed with packed-B
//! `NR`-column panel windows ([`TileGrid`], planned per stage by
//! [`plan_tile_grid`]). Columns split exactly when rows alone cannot
//! produce enough tiles to feed the pool — the paper's Table-2 cases 5–7
//! (2000-neuron FC layers at small batch), where a single batch row's GEMM
//! must span workers to keep strong scaling alive (cf. Dryden et al.,
//! arXiv:1903.06681; Jia et al., arXiv:1802.04924).

pub mod bp_tasks;
pub mod conv_tasks;
pub mod dag;
pub mod fc_tasks;
pub mod priority;
pub mod scheduler;

pub use bp_tasks::{parallel_train_step, train_step_dag, ParallelStepResult};
pub use conv_tasks::{
    conv2d_parallel, conv2d_parallel_packed, conv_task_dag, conv_tile_dag, ConvTask, ConvTile,
};
pub use dag::{TaskDag, TaskId, TaskNode};
pub use fc_tasks::{dense_bwd_parallel, dense_fwd_parallel, loss_parallel, RowTask, Tile2};
pub use priority::{mark_priorities, priority_order};
pub use scheduler::{
    execute_dag, execute_sequential, panel_count, plan_cols_for_rows, plan_tile_grid,
    ScheduleStats, TileGrid, TilePolicy,
};
