//! Inner-layer parallel training (paper §4): task decomposition of the
//! convolutional layer (Algorithm 4.1) and the local weight training
//! (backward pass), task-DAG construction with priority marking (§4.2(1)),
//! and the priority scheduler with least-loaded thread assignment
//! (Algorithm 4.2).
//!
//! Tiles are **2D row×column**: batch/image rows crossed with packed-B
//! `NR`-column panel windows ([`TileGrid`], planned per stage by
//! [`plan_tile_grid`]). Columns split exactly when rows alone cannot
//! produce enough tiles to feed the pool — the paper's Table-2 cases 5–7
//! (2000-neuron FC layers at small batch), where a single batch row's GEMM
//! must span workers to keep strong scaling alive (cf. Dryden et al.,
//! arXiv:1903.06681; Jia et al., arXiv:1802.04924).
//!
//! The planner's per-tile FLOP floor is **calibrated per machine** (micro-
//! kernel rate × measured dispatch overhead, `autotune`), and under
//! [`TilePolicy::Auto`] every GEMM-shaped stage's grid is adapted **online**
//! from its measured [`ScheduleStats`] makespan by the node's
//! [`AutoTuner`] — static heuristics are only the cold-start prior.

pub mod autotune;
pub mod bp_tasks;
pub mod check;
pub mod conv_tasks;
pub mod dag;
pub mod fc_tasks;
pub mod priority;
pub mod scheduler;

pub use autotune::{
    set_tile_floor_flops, tile_floor_flops, AutoTuner, Calibration, StageKey, StageKind,
    StageTuner,
};
pub use bp_tasks::{
    conv_bwd_claims, conv_bwd_dag, parallel_train_step, train_step_dag, BwdTask,
    ParallelStepResult, StageSample,
};
pub use conv_tasks::{
    conv2d_parallel, conv2d_parallel_packed, conv2d_parallel_packed_ws, conv_fwd_claims,
    conv_lower_claims, conv_lower_dag, conv_task_dag, conv_tile_dag, ConvLowerStage, ConvTask,
    ConvTile, DisjointBuf,
};
pub use dag::{TaskDag, TaskId, TaskNode};
pub use fc_tasks::{
    dense_bwd_claims, dense_bwd_dag, dense_bwd_fused_claims, dense_bwd_parallel,
    dense_fwd_claims, dense_fwd_parallel, loss_parallel, row_tile_dag, tile2_dag, DenseBwdTile,
    RowTask, Tile2,
};
pub use priority::{mark_priorities, priority_order};
pub use scheduler::{
    execute_dag, execute_sequential, panel_count, plan_cols_for_rows, plan_cols_for_rows_with_floor,
    plan_tile_grid, plan_tile_grid_with_floor, ScheduleStats, TileGrid, TilePolicy,
};
