//! Inner-layer parallel training (paper §4): task decomposition of the
//! convolutional layer (Algorithm 4.1) and the local weight training
//! (backward pass), task-DAG construction with priority marking (§4.2(1)),
//! and the priority scheduler with least-loaded thread assignment
//! (Algorithm 4.2).

pub mod bp_tasks;
pub mod conv_tasks;
pub mod dag;
pub mod fc_tasks;
pub mod priority;
pub mod scheduler;

pub use bp_tasks::{parallel_train_step, train_step_dag, ParallelStepResult};
pub use conv_tasks::{conv2d_parallel, conv2d_parallel_packed, conv_task_dag, ConvTask};
pub use dag::{TaskDag, TaskId, TaskNode};
pub use fc_tasks::{dense_bwd_parallel, dense_fwd_parallel, loss_parallel, RowTask};
pub use priority::{mark_priorities, priority_order};
pub use scheduler::{execute_dag, execute_sequential, ScheduleStats};
