//! Schedule-soundness checker for the inner-layer tile plans.
//!
//! The paper's §4 task parallelism is safe because every task writes a
//! provably disjoint region of the shared output ("different tasks can
//! access different convolution areas simultaneously … without data
//! dependence"). The parity proptests catch wrong *values*, but a latent
//! data race can produce right answers; this module makes the disjointness
//! argument itself a checked artifact:
//!
//! * **Plan time (always compiled, zero runtime cost on hot paths):** every
//!   stage DAG lowers to a set of [`Claim`]s — `(buffer, access, span)` per
//!   task — and [`verify`] asserts that any two overlapping claims are
//!   either both reads or ordered by declared DAG dependencies. The
//!   `tests/plan_sweep.rs` suite runs this over the full planner output
//!   space, so the planner cannot emit a racy schedule unnoticed.
//! * **Runtime (behind the `chk` cargo feature):** [`stage_guard`] verifies
//!   the plan and indexes its claims; [`DisjointBuf`] accessors registered
//!   with the guard cross-check every *actual* touched interval against the
//!   executing task's declared claims and panic on undeclared access. The
//!   scheduler tags the executing task via [`scoped_task`].
//!
//! Spans are in **f32 elements** of the owning buffer (multiply by 4 for
//! bytes). Buffers that are only ever read during a stage (inputs, packed
//! filters) carry no claims — a race needs at least one writer.
//!
//! [`DisjointBuf`]: super::conv_tasks::DisjointBuf

use std::fmt;

use super::dag::{TaskDag, TaskId};

/// Kind of access a task performs on a buffer window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Access {
    Read,
    Write,
}

/// Logical identity of a stage-shared buffer. One stage call never shares
/// two distinct buffers under the same id, so `(Buf, span)` identifies a
/// memory region unambiguously within a stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Buf {
    /// The stage's primary output (conv/dense `out`, backward `dx`, reduce
    /// target, …).
    Out,
    /// Secondary output when a stage has two (e.g. softmax probabilities
    /// next to the loss gradient).
    Out2,
    /// The upstream-gradient buffer masked in place by dense backward.
    Dy,
    /// The shared im2col lowering scratch of column-split conv stages.
    Lower,
    /// Per-task scalar result slots (loss partials).
    Slots,
    /// Per-worker arena filter-gradient partials (`ScratchArena::grad_f`).
    ArenaGradF,
    /// Per-worker arena bias-gradient partials (`ScratchArena::grad_b`).
    ArenaGradB,
}

impl Buf {
    /// Per-worker buffers are serialized by the executing worker (only
    /// worker `i` runs tasks pinned to `i`, one at a time) and are *meant*
    /// to be accumulated into by many tasks — overlap across tasks is the
    /// design, so they are exempt from pairwise disjointness. Their claims
    /// still feed the runtime undeclared-access check.
    pub fn per_worker(self) -> bool {
        matches!(self, Buf::ArenaGradF | Buf::ArenaGradB)
    }
}

/// A (possibly strided) set of elements: `rows` windows of `width` elements
/// spaced `stride` apart, starting at `start`. `rows == 1` is a plain
/// interval; the strided form describes a 2D tile's column window inside a
/// row-major matrix (row stride = the matrix's full width).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    start: usize,
    rows: usize,
    stride: usize,
    width: usize,
}

impl Span {
    /// Contiguous `[start, start+len)`.
    pub fn interval(start: usize, len: usize) -> Self {
        assert!(len >= 1, "empty span");
        Span { start, rows: 1, stride: len, width: len }
    }

    /// `rows` windows of `width` elements, `stride` apart. Windows must not
    /// self-overlap (`width <= stride`); full-width windows collapse to one
    /// contiguous interval.
    pub fn strided(start: usize, rows: usize, stride: usize, width: usize) -> Self {
        assert!(rows >= 1 && width >= 1, "empty span");
        if rows == 1 {
            return Self::interval(start, width);
        }
        assert!(width <= stride, "span rows overlap each other");
        if width == stride {
            return Self::interval(start, rows * stride);
        }
        Span { start, rows, stride, width }
    }

    /// First element.
    pub fn lo(&self) -> usize {
        self.start
    }

    /// One past the last element (bounding interval, gaps included).
    pub fn hi(&self) -> usize {
        self.start + (self.rows - 1) * self.stride + self.width
    }

    fn contiguous(&self) -> bool {
        self.rows == 1
    }

    /// Is the contiguous interval `[lo, hi)` fully contained in this span?
    /// Runtime accesses are always within a single claim row (a tile touches
    /// its column window one matrix row at a time), so single-row
    /// containment is sufficient.
    pub fn covers_interval(&self, lo: usize, hi: usize) -> bool {
        if hi <= lo {
            return true;
        }
        if lo < self.start || hi > self.hi() {
            return false;
        }
        if self.contiguous() {
            return true;
        }
        let r = (lo - self.start) / self.stride;
        let s = self.start + r * self.stride;
        lo >= s && hi <= s + self.width
    }

    /// Does this span share at least one element with the interval
    /// `[lo, hi)`?
    fn hits_interval(&self, lo: usize, hi: usize) -> bool {
        if hi <= lo || lo >= self.hi() || hi <= self.start {
            return false;
        }
        if self.contiguous() {
            return true;
        }
        // An interval at least one period long cannot fit in a gap
        // (gaps are `stride - width < stride` elements).
        if hi - lo >= self.stride {
            return true;
        }
        // Shorter interval: it can only touch the row it starts in or the
        // next one.
        let r0 = lo.saturating_sub(self.start) / self.stride;
        for r in [r0, r0 + 1] {
            if r >= self.rows {
                continue;
            }
            let s = self.start + r * self.stride;
            if s < hi && lo < s + self.width {
                return true;
            }
        }
        false
    }

    /// Exact element-set intersection test.
    pub fn intersects(&self, other: &Span) -> bool {
        if self.lo() >= other.hi() || other.lo() >= self.hi() {
            return false;
        }
        if self.contiguous() {
            return other.hits_interval(self.lo(), self.hi());
        }
        if other.contiguous() {
            return self.hits_interval(other.lo(), other.hi());
        }
        // Both strided: walk the rows of the span with fewer of them.
        let (few, many) = if self.rows <= other.rows { (self, other) } else { (other, self) };
        for r in 0..few.rows {
            let s = few.start + r * few.stride;
            if many.hits_interval(s, s + few.width) {
                return true;
            }
        }
        false
    }
}

/// One task's declared access to one buffer region.
#[derive(Debug, Clone, Copy)]
pub struct Claim {
    pub task: TaskId,
    pub buf: Buf,
    pub access: Access,
    pub span: Span,
}

impl Claim {
    pub fn read(task: TaskId, buf: Buf, span: Span) -> Self {
        Claim { task, buf, access: Access::Read, span }
    }

    pub fn write(task: TaskId, buf: Buf, span: Span) -> Self {
        Claim { task, buf, access: Access::Write, span }
    }
}

/// A pair of claims [`verify`] proved can race: they overlap, at least one
/// writes, and no dependency chain orders the two tasks.
#[derive(Debug)]
pub struct Violation {
    pub buf: Buf,
    pub kind: &'static str,
    pub task_a: TaskId,
    pub label_a: String,
    pub span_a: Span,
    pub task_b: TaskId,
    pub label_b: String,
    pub span_b: Span,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} race on {:?}: task {} ({}) {:?} vs task {} ({}) {:?} with no ordering dependency",
            self.kind,
            self.buf,
            self.task_a,
            self.label_a,
            self.span_a,
            self.task_b,
            self.label_b,
            self.span_b,
        )
    }
}

/// Prove the claim set race-free under the DAG's dependency order: any two
/// claims on the same (non-per-worker) buffer whose spans intersect must be
/// both reads, belong to the same task, or belong to tasks ordered by a
/// dependency path. This subsumes the per-level check — two tasks on the
/// same DAG level are never ordered, and *unordered* tasks on different
/// levels are checked too.
pub fn verify<P>(dag: &TaskDag<P>, claims: &[Claim]) -> Result<(), Box<Violation>> {
    let n = dag.len();
    let words = (n + 63) / 64;
    // reach[id] ⊇ all transitive dependencies of `id`, as a bitset. Built in
    // one pass: ids are inserted in topological order (deps < id), so every
    // dependency's row is final when its dependent's row is assembled.
    let mut reach = vec![0u64; n * words];
    for node in dag.nodes() {
        if node.deps.is_empty() {
            continue;
        }
        let (done, rest) = reach.split_at_mut(node.id * words);
        let dst = &mut rest[..words];
        for &d in &node.deps {
            let src = &done[d * words..(d + 1) * words];
            for (dw, sw) in dst.iter_mut().zip(src) {
                *dw |= *sw;
            }
            dst[d / 64] |= 1u64 << (d % 64);
        }
    }
    let ordered = |a: TaskId, b: TaskId| {
        (reach[a * words + b / 64] >> (b % 64)) & 1 == 1
            || (reach[b * words + a / 64] >> (a % 64)) & 1 == 1
    };

    // Group claim indices by buffer, then sweep each group sorted by span
    // start: a claim only needs checking against later-starting claims that
    // begin before its bounding interval ends.
    let mut by_buf: Vec<(Buf, Vec<usize>)> = Vec::new();
    for (i, c) in claims.iter().enumerate() {
        assert!(c.task < n, "claim references task {} outside the dag", c.task);
        if c.buf.per_worker() {
            continue;
        }
        match by_buf.iter_mut().find(|(b, _)| *b == c.buf) {
            Some((_, v)) => v.push(i),
            None => by_buf.push((c.buf, vec![i])),
        }
    }
    for (buf, mut idx) in by_buf {
        idx.sort_by_key(|&i| claims[i].span.lo());
        for (pos, &i) in idx.iter().enumerate() {
            let ci = &claims[i];
            let hi_i = ci.span.hi();
            for &j in &idx[pos + 1..] {
                let cj = &claims[j];
                if cj.span.lo() >= hi_i {
                    break;
                }
                if ci.task == cj.task
                    || (ci.access == Access::Read && cj.access == Access::Read)
                    || !ci.span.intersects(&cj.span)
                    || ordered(ci.task, cj.task)
                {
                    continue;
                }
                let kind = if ci.access == Access::Write && cj.access == Access::Write {
                    "write-write"
                } else {
                    "read-write"
                };
                return Err(Box::new(Violation {
                    buf,
                    kind,
                    task_a: ci.task,
                    label_a: dag.node(ci.task).label.clone(),
                    span_a: ci.span,
                    task_b: cj.task,
                    label_b: dag.node(cj.task).label.clone(),
                    span_b: cj.span,
                }));
            }
        }
    }
    Ok(())
}

/// Largest element index + 1 any claim on `buf` can touch — lets sweep
/// tests assert a plan stays inside the buffer it will be given.
pub fn max_extent(claims: &[Claim], buf: Buf) -> usize {
    claims.iter().filter(|c| c.buf == buf).map(|c| c.span.hi()).max().unwrap_or(0)
}

#[cfg(feature = "chk")]
mod runtime {
    use super::{Access, Buf, Claim, Span, TaskDag, TaskId};
    use std::cell::Cell;
    use std::collections::HashMap;

    thread_local! {
        static CURRENT_TASK: Cell<Option<TaskId>> = const { Cell::new(None) };
    }

    /// Run `f` with the executing task id visible to claim checks on this
    /// thread. The previous id is restored even if `f` panics, so a
    /// panicking task cannot poison attribution for later dispatches.
    pub fn scoped_task<R>(task: TaskId, f: impl FnOnce() -> R) -> R {
        struct Restore(Option<TaskId>);
        impl Drop for Restore {
            fn drop(&mut self) {
                CURRENT_TASK.with(|c| c.set(self.0));
            }
        }
        let prev = CURRENT_TASK.with(|c| c.replace(Some(task)));
        let _restore = Restore(prev);
        f()
    }

    /// Task id of the innermost [`scoped_task`] on this thread, if any.
    pub fn current_task() -> Option<TaskId> {
        CURRENT_TASK.with(|c| c.get())
    }

    #[derive(Default)]
    struct TaskClaims {
        writes: Vec<Span>,
        reads: Vec<Span>,
    }

    /// A verified stage plan's claims, indexed per `(task, buffer)` for the
    /// runtime cross-check. Immutable after construction and freshly built
    /// per stage call, so a mid-stage panic leaves nothing to un-poison.
    pub struct ClaimSet {
        by_task: HashMap<(TaskId, Buf), TaskClaims>,
        labels: Vec<String>,
    }

    impl ClaimSet {
        pub fn index<P>(dag: &TaskDag<P>, claims: &[Claim]) -> Self {
            let mut by_task: HashMap<(TaskId, Buf), TaskClaims> = HashMap::new();
            for c in claims {
                let e = by_task.entry((c.task, c.buf)).or_default();
                match c.access {
                    Access::Write => e.writes.push(c.span),
                    Access::Read => e.reads.push(c.span),
                }
            }
            let labels = dag.nodes().iter().map(|n| n.label.clone()).collect();
            ClaimSet { by_task, labels }
        }

        /// Panic unless the currently executing task declared the access.
        /// A write claim also licenses reads (tasks read back what they
        /// wrote); accesses outside any task scope (the dispatching thread
        /// preparing buffers) are not checked.
        pub fn check_access(&self, buf: Buf, access: Access, lo: usize, hi: usize) {
            if hi <= lo {
                return;
            }
            let Some(task) = current_task() else { return };
            let covered = |spans: &[Span]| spans.iter().any(|s| s.covers_interval(lo, hi));
            let ok = match (self.by_task.get(&(task, buf)), access) {
                (Some(tc), Access::Write) => covered(&tc.writes),
                (Some(tc), Access::Read) => covered(&tc.reads) || covered(&tc.writes),
                (None, _) => false,
            };
            if !ok {
                let label = self.labels.get(task).map(|s| s.as_str()).unwrap_or("?");
                panic!(
                    "chk: task {task} ({label}) touched undeclared {access:?} window \
                     [{lo}, {hi}) of {buf:?}"
                );
            }
        }
    }
}

#[cfg(feature = "chk")]
pub use runtime::{current_task, scoped_task, ClaimSet};

/// With `chk` off, [`scoped_task`] is an inlined identity — the scheduler
/// seam costs nothing in default builds.
#[cfg(not(feature = "chk"))]
#[inline(always)]
pub fn scoped_task<R>(_task: TaskId, f: impl FnOnce() -> R) -> R {
    f()
}

/// Handle a stage attaches to its [`DisjointBuf`]s. With `chk` on it is the
/// indexed, verified claim set; with `chk` off it is a zero-sized token and
/// the whole claim machinery compiles away.
///
/// [`DisjointBuf`]: super::conv_tasks::DisjointBuf
#[cfg(feature = "chk")]
pub type StageGuard = std::sync::Arc<ClaimSet>;

#[cfg(not(feature = "chk"))]
#[derive(Clone)]
pub struct StageGuard(());

/// Verify a stage plan and produce its runtime guard. With `chk` on, the
/// claims closure runs, [`verify`] panics on any violation, and the indexed
/// claims are returned for accessor cross-checks; with `chk` off the
/// closure is never called and nothing is allocated.
pub fn stage_guard<P>(dag: &TaskDag<P>, claims: impl FnOnce() -> Vec<Claim>) -> StageGuard {
    #[cfg(feature = "chk")]
    {
        let claims = claims();
        if let Err(v) = verify(dag, &claims) {
            panic!("chk: unsound stage plan: {v}");
        }
        std::sync::Arc::new(ClaimSet::index(dag, &claims))
    }
    #[cfg(not(feature = "chk"))]
    {
        let _ = (dag, claims);
        StageGuard(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_interval_basics() {
        let s = Span::interval(4, 6); // [4, 10)
        assert_eq!(s.lo(), 4);
        assert_eq!(s.hi(), 10);
        assert!(s.covers_interval(4, 10));
        assert!(s.covers_interval(5, 7));
        assert!(!s.covers_interval(3, 5));
        assert!(!s.covers_interval(8, 11));
        assert!(s.intersects(&Span::interval(9, 1)));
        assert!(!s.intersects(&Span::interval(10, 3)));
        assert!(!s.intersects(&Span::interval(0, 4)));
    }

    #[test]
    fn span_strided_geometry() {
        // Rows {0,1}, columns [2,5) of a 3×8 row-major matrix.
        let a = Span::strided(2, 2, 8, 3); // {2,3,4, 10,11,12}
        assert_eq!(a.lo(), 2);
        assert_eq!(a.hi(), 13);
        // Row-window containment.
        assert!(a.covers_interval(2, 5));
        assert!(a.covers_interval(10, 13));
        assert!(a.covers_interval(11, 12));
        assert!(!a.covers_interval(4, 6)); // crosses a row boundary
        assert!(!a.covers_interval(5, 6)); // gap element
        // Disjoint column windows of the same rows never intersect.
        let b = Span::strided(5, 2, 8, 3); // {5,6,7, 13,14,15}
        assert!(!a.intersects(&b));
        assert!(!b.intersects(&a));
        // Same columns, overlapping rows do.
        let c = Span::strided(10, 2, 8, 3); // {10..13, 18..21}
        assert!(a.intersects(&c));
        // Interval through a gap only: {5,6} misses a.
        assert!(!a.intersects(&Span::interval(5, 2)));
        // Interval of a full period always hits.
        assert!(a.intersects(&Span::interval(5, 8)));
        // Full-width strided collapses to contiguous.
        let full = Span::strided(0, 3, 8, 8);
        assert_eq!(full, Span::interval(0, 24));
    }

    #[test]
    fn verify_rejects_unordered_overlapping_writes() {
        let mut dag: TaskDag<()> = TaskDag::new();
        let a = dag.add("a", 1.0, &[], ());
        let b = dag.add("b", 1.0, &[], ());
        let claims = vec![
            Claim::write(a, Buf::Out, Span::interval(0, 8)),
            Claim::write(b, Buf::Out, Span::interval(4, 8)),
        ];
        let err = verify(&dag, &claims).unwrap_err();
        assert_eq!(err.kind, "write-write");
        assert_eq!(err.buf, Buf::Out);
    }

    #[test]
    fn verify_accepts_dependency_ordered_overlap() {
        let mut dag: TaskDag<()> = TaskDag::new();
        let a = dag.add("lower", 1.0, &[], ());
        let b = dag.add("tile", 1.0, &[a], ());
        let claims = vec![
            Claim::write(a, Buf::Lower, Span::interval(0, 16)),
            Claim::read(b, Buf::Lower, Span::interval(0, 16)),
        ];
        verify(&dag, &claims).unwrap();
        // Same spans without the edge: read-write race.
        let mut flat: TaskDag<()> = TaskDag::new();
        let a2 = flat.add("lower", 1.0, &[], ());
        let b2 = flat.add("tile", 1.0, &[], ());
        let claims2 = vec![
            Claim::write(a2, Buf::Lower, Span::interval(0, 16)),
            Claim::read(b2, Buf::Lower, Span::interval(0, 16)),
        ];
        assert_eq!(verify(&flat, &claims2).unwrap_err().kind, "read-write");
    }

    #[test]
    fn verify_ordering_is_transitive() {
        // a → b → c; a and c overlap, with no direct edge.
        let mut dag: TaskDag<()> = TaskDag::new();
        let a = dag.add("a", 1.0, &[], ());
        let b = dag.add("b", 1.0, &[a], ());
        let c = dag.add("c", 1.0, &[b], ());
        let claims = vec![
            Claim::write(a, Buf::Out, Span::interval(0, 8)),
            Claim::write(c, Buf::Out, Span::interval(0, 8)),
        ];
        verify(&dag, &claims).unwrap();
    }

    #[test]
    fn verify_ignores_read_read_and_per_worker_overlap() {
        let mut dag: TaskDag<()> = TaskDag::new();
        let a = dag.add("a", 1.0, &[], ());
        let b = dag.add("b", 1.0, &[], ());
        let claims = vec![
            Claim::read(a, Buf::Dy, Span::interval(0, 8)),
            Claim::read(b, Buf::Dy, Span::interval(0, 8)),
            // Arena partials intentionally overlap across tasks.
            Claim::write(a, Buf::ArenaGradF, Span::interval(0, 64)),
            Claim::write(b, Buf::ArenaGradF, Span::interval(0, 64)),
        ];
        verify(&dag, &claims).unwrap();
    }

    #[test]
    fn verify_accepts_disjoint_2d_tiling() {
        // Four tiles of a 4×16 matrix: 2 row tiles × 2 column windows.
        let mut dag: TaskDag<()> = TaskDag::new();
        let mut claims = Vec::new();
        for ti in 0..2 {
            for tj in 0..2 {
                let id = dag.add(format!("t{ti}{tj}"), 1.0, &[], ());
                claims.push(Claim::write(
                    id,
                    Buf::Out,
                    Span::strided(ti * 2 * 16 + tj * 8, 2, 16, 8),
                ));
            }
        }
        verify(&dag, &claims).unwrap();
        assert_eq!(max_extent(&claims, Buf::Out), 4 * 16);
        assert_eq!(max_extent(&claims, Buf::Lower), 0);
    }

    #[test]
    fn ragged_final_panel_tiles_stay_disjoint() {
        // n = 19 columns split as [0,8), [8,16), [16,19) across 3 tasks,
        // 2 rows each — the Table-2 ragged-panel shape in miniature.
        let mut dag: TaskDag<()> = TaskDag::new();
        let mut claims = Vec::new();
        for (j0, jw) in [(0usize, 8usize), (8, 8), (16, 3)] {
            let id = dag.add(format!("p{j0}"), 1.0, &[], ());
            claims.push(Claim::write(id, Buf::Out, Span::strided(j0, 2, 19, jw)));
        }
        verify(&dag, &claims).unwrap();
        assert_eq!(max_extent(&claims, Buf::Out), 2 * 19);
    }
}
