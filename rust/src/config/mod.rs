//! Typed configuration for networks, clusters, training and simulation.
//!
//! `NetworkConfig` mirrors `python/compile/model.py::CNNConfig` — the Rust
//! side derives the same parameter manifest so the native backend, the
//! simulator's cost model and the XLA artifacts all agree on the weight-set
//! layout. Configs round-trip through the hand-rolled JSON module.

use crate::util::json::Json;

/// CNN network-scale configuration (paper Table 2 vocabulary).
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkConfig {
    pub name: String,
    pub input_hw: usize,
    pub in_channels: usize,
    pub conv_layers: usize,
    pub filters: usize,
    pub kernel_hw: usize,
    pub fc_layers: usize,
    pub fc_neurons: usize,
    pub num_classes: usize,
    pub batch_size: usize,
    pub pool_window: usize,
}

impl Default for NetworkConfig {
    fn default() -> Self {
        // Mirrors python CONFIGS["e2e"].
        Self {
            name: "e2e".into(),
            input_hw: 16,
            in_channels: 1,
            conv_layers: 2,
            filters: 8,
            kernel_hw: 3,
            fc_layers: 2,
            fc_neurons: 64,
            num_classes: 10,
            batch_size: 32,
            pool_window: 2,
        }
    }
}

impl NetworkConfig {
    /// Mirrors python CONFIGS["quickstart"].
    pub fn quickstart() -> Self {
        Self {
            name: "quickstart".into(),
            input_hw: 8,
            conv_layers: 1,
            filters: 4,
            fc_layers: 1,
            fc_neurons: 32,
            batch_size: 8,
            ..Self::default()
        }
    }

    /// Paper Table 2 network-scale cases 1–7 (Fig. 14a sweep).
    pub fn table2_case(case: usize) -> Self {
        assert!((1..=7).contains(&case), "Table 2 has cases 1–7");
        let layers_conv = [2, 4, 6, 8, 8, 10, 10];
        let filters_conv = [4, 4, 8, 8, 10, 10, 12];
        let layers_fc = [3, 3, 5, 5, 7, 7, 7];
        let neurons_fc = [500, 1000, 1500, 1500, 2000, 2000, 2000];
        let i = case - 1;
        Self {
            name: format!("case{case}"),
            input_hw: 16,
            conv_layers: layers_conv[i],
            filters: filters_conv[i],
            fc_layers: layers_fc[i],
            fc_neurons: neurons_fc[i],
            ..Self::default()
        }
    }

    /// Ordered parameter manifest — must match
    /// `python/compile/model.py::CNNConfig.param_shapes` exactly.
    pub fn param_shapes(&self) -> Vec<(String, Vec<usize>)> {
        let mut shapes = Vec::new();
        let mut c = self.in_channels;
        let k = self.kernel_hw;
        for i in 0..self.conv_layers {
            shapes.push((format!("conv{i}.filter"), vec![k, k, c, self.filters]));
            shapes.push((format!("conv{i}.bias"), vec![self.filters]));
            c = self.filters;
        }
        let hw = self.input_hw / self.pool_window;
        let mut fan_in = hw * hw * c;
        for i in 0..self.fc_layers {
            shapes.push((format!("fc{i}.weight"), vec![fan_in, self.fc_neurons]));
            shapes.push((format!("fc{i}.bias"), vec![self.fc_neurons]));
            fan_in = self.fc_neurons;
        }
        shapes.push(("out.weight".into(), vec![fan_in, self.num_classes]));
        shapes.push(("out.bias".into(), vec![self.num_classes]));
        shapes
    }

    pub fn param_count(&self) -> usize {
        self.param_shapes()
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum()
    }

    /// Weight-set size in bytes (f32) — `c_w` of Eq. 11.
    pub fn weight_bytes(&self) -> usize {
        self.param_count() * 4
    }

    /// Per-sample forward+backward FLOP estimate, the simulator's cost-model
    /// input. Convolutions dominate (the paper measures >85% of time in conv
    /// layers, §4.1.1); backward ≈ 2× forward.
    pub fn flops_per_sample(&self) -> f64 {
        let mut flops = 0.0;
        let hw = self.input_hw as f64;
        let k = self.kernel_hw as f64;
        let mut c = self.in_channels as f64;
        for _ in 0..self.conv_layers {
            // SAME conv: H·W output positions × k² × C_in × C_out MACs.
            flops += hw * hw * k * k * c * self.filters as f64 * 2.0;
            c = self.filters as f64;
        }
        let hwp = (self.input_hw / self.pool_window) as f64;
        let mut fan_in = hwp * hwp * c;
        for _ in 0..self.fc_layers {
            flops += fan_in * self.fc_neurons as f64 * 2.0;
            fan_in = self.fc_neurons as f64;
        }
        flops += fan_in * self.num_classes as f64 * 2.0;
        flops * 3.0 // fwd + ~2× bwd
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::from(self.name.clone())),
            ("input_hw", Json::from(self.input_hw)),
            ("in_channels", Json::from(self.in_channels)),
            ("conv_layers", Json::from(self.conv_layers)),
            ("filters", Json::from(self.filters)),
            ("kernel_hw", Json::from(self.kernel_hw)),
            ("fc_layers", Json::from(self.fc_layers)),
            ("fc_neurons", Json::from(self.fc_neurons)),
            ("num_classes", Json::from(self.num_classes)),
            ("batch_size", Json::from(self.batch_size)),
            ("pool_window", Json::from(self.pool_window)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let d = Self::default();
        let get = |key: &str, dv: usize| j.get(key).as_usize().unwrap_or(dv);
        Ok(Self {
            name: j.get("name").as_str().unwrap_or("unnamed").to_string(),
            input_hw: get("input_hw", d.input_hw),
            in_channels: get("in_channels", d.in_channels),
            conv_layers: get("conv_layers", d.conv_layers),
            filters: get("filters", d.filters),
            kernel_hw: get("kernel_hw", d.kernel_hw),
            fc_layers: get("fc_layers", d.fc_layers),
            fc_neurons: get("fc_neurons", d.fc_neurons),
            num_classes: get("num_classes", d.num_classes),
            batch_size: get("batch_size", d.batch_size),
            pool_window: get("pool_window", d.pool_window),
        })
    }
}

/// Global weight-update strategy (§3.3.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UpdateStrategy {
    /// Synchronous: Eq. 7 accuracy-weighted averaging at epoch barriers.
    Sgwu,
    /// Asynchronous: Eqs. 9–10 with staleness attenuation γ.
    Agwu,
}

impl UpdateStrategy {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "sgwu" | "sync" => Ok(Self::Sgwu),
            "agwu" | "async" => Ok(Self::Agwu),
            other => anyhow::bail!("unknown update strategy '{other}' (want sgwu|agwu)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Sgwu => "SGWU",
            Self::Agwu => "AGWU",
        }
    }
}

/// What the param server does when a worker's lease expires or its
/// connection dies mid-run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OnFailure {
    /// Degrade gracefully: survivors absorb the dead node's remaining IDPA
    /// batches (AGWU) or the Eq. 8 barrier quorum shrinks (SGWU).
    Continue,
    /// Fail fast: any node loss aborts the whole run.
    Abort,
}

impl OnFailure {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "continue" => Ok(Self::Continue),
            "abort" => Ok(Self::Abort),
            other => anyhow::bail!("unknown failure policy '{other}' (want continue|abort)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Continue => "continue",
            Self::Abort => "abort",
        }
    }
}

/// When the primary parameter server acknowledges a worker's submit,
/// relative to streaming the update to the warm standby (`--repl-ack`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum ReplAck {
    /// Ack the worker immediately; replication is asynchronous. A primary
    /// crash can lose updates acked after the last replicated snapshot.
    #[default]
    None,
    /// Replication-before-ack: the worker's Ack waits until the standby
    /// acknowledged the update (with its full snapshot), so every update a
    /// worker ever saw acked survives a failover bit-identically.
    Standby,
}

impl ReplAck {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "none" => Ok(Self::None),
            "standby" => Ok(Self::Standby),
            other => anyhow::bail!("unknown repl-ack mode '{other}' (want none|standby)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::None => "none",
            Self::Standby => "standby",
        }
    }
}

/// Data partitioning strategy (§3.3.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionStrategy {
    /// Incremental heterogeneity-aware partitioning (Algorithm 3.1).
    Idpa,
    /// Uniform baseline from §5.3.3.
    Udpa,
}

impl PartitionStrategy {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "idpa" => Ok(Self::Idpa),
            "udpa" | "uniform" => Ok(Self::Udpa),
            other => anyhow::bail!("unknown partition strategy '{other}' (want idpa|udpa)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Idpa => "IDPA",
            Self::Udpa => "UDPA",
        }
    }
}

/// One computing node's capability profile (§3.3.1: heterogeneous cluster).
#[derive(Debug, Clone, PartialEq)]
pub struct NodeProfile {
    /// Nominal CPU frequency in GHz — μ_j of Eq. 2.
    pub freq_ghz: f64,
    /// Cores available for inner-layer threads.
    pub cores: usize,
    /// Multiplicative load factor on actual speed (models "other employers'
    /// applications", §3.3.1); 1.0 = unloaded.
    pub background_load: f64,
}

impl NodeProfile {
    pub fn uniform(freq_ghz: f64, cores: usize) -> Self {
        Self { freq_ghz, cores, background_load: 1.0 }
    }
}

/// Cluster description for both the in-process trainer and the simulator.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    pub nodes: Vec<NodeProfile>,
    /// Link bandwidth node↔parameter-server, bytes/s (Fig. 15a model).
    pub bandwidth_bytes_per_s: f64,
    /// Per-message latency, seconds.
    pub link_latency_s: f64,
    /// Bounded-staleness knob for the pipelined outer layer. 0 = serialized
    /// fetch → train → submit per node (the classic SGWU/AGWU loops,
    /// bit-identical to the pre-pipeline behavior); s ≥ 1 = each node trains
    /// on a prefetched snapshot at most `s` versions behind its newest
    /// server-acked update, overlapping comm with compute (AGWU only).
    pub staleness: usize,
}

impl ClusterConfig {
    /// A heterogeneous cluster like the paper's testbed: frequencies spread
    /// around 2.3 GHz (Nehalem-EX era), 8 cores each, varied load.
    pub fn heterogeneous(m: usize, seed: u64) -> Self {
        let mut rng = crate::util::rng::Xoshiro256::new(seed);
        let nodes = (0..m)
            .map(|_| NodeProfile {
                freq_ghz: rng.range_f64(1.6, 3.2),
                cores: 8, // Nehalem-EX: 8 cores/chip (paper §5.1)
                background_load: rng.range_f64(0.6, 1.0),
            })
            .collect();
        Self {
            nodes,
            bandwidth_bytes_per_s: 1.0e9 / 8.0, // 1 Gb/s
            link_latency_s: 200e-6,
            staleness: 0,
        }
    }

    /// Homogeneous cluster (for UDPA-favourable control runs).
    pub fn homogeneous(m: usize) -> Self {
        Self {
            nodes: (0..m).map(|_| NodeProfile::uniform(2.3, 8)).collect(),
            bandwidth_bytes_per_s: 1.0e9 / 8.0,
            link_latency_s: 200e-6,
            staleness: 0,
        }
    }

    /// Builder: set the pipelined outer layer's staleness bound.
    pub fn with_staleness(mut self, s: usize) -> Self {
        self.staleness = s;
        self
    }

    pub fn size(&self) -> usize {
        self.nodes.len()
    }

    /// μ_j / Σ μ_j' shares of Eq. 2.
    pub fn frequency_shares(&self) -> Vec<f64> {
        let total: f64 = self.nodes.iter().map(|n| n.freq_ghz).sum();
        self.nodes.iter().map(|n| n.freq_ghz / total).collect()
    }
}

/// End-to-end training configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub network: NetworkConfig,
    pub update: UpdateStrategy,
    pub partition: PartitionStrategy,
    /// N: total training samples.
    pub total_samples: usize,
    /// K: training iterations (epochs of local iteration training).
    pub iterations: usize,
    /// A: number of IDPA batches (A < K).
    pub idpa_batches: usize,
    pub learning_rate: f32,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            network: NetworkConfig::default(),
            update: UpdateStrategy::Agwu,
            partition: PartitionStrategy::Idpa,
            total_samples: 2048,
            iterations: 20,
            idpa_batches: 4,
            learning_rate: 0.05,
            seed: 42,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_manifest_matches_python_e2e() {
        // python: CONFIGS["e2e"].param_count() == 38306 (verified by pytest
        // + the artifact manifest).
        assert_eq!(NetworkConfig::default().param_count(), 38306);
    }

    #[test]
    fn param_manifest_matches_python_quickstart() {
        // python: CONFIGS["quickstart"].param_count() == 2450.
        assert_eq!(NetworkConfig::quickstart().param_count(), 2450);
    }

    #[test]
    fn param_shape_order() {
        let shapes = NetworkConfig::quickstart().param_shapes();
        assert_eq!(shapes[0].0, "conv0.filter");
        assert_eq!(shapes[0].1, vec![3, 3, 1, 4]);
        assert_eq!(shapes.last().unwrap().0, "out.bias");
    }

    #[test]
    fn table2_rows_match_paper() {
        let c1 = NetworkConfig::table2_case(1);
        assert_eq!((c1.conv_layers, c1.filters, c1.fc_layers, c1.fc_neurons), (2, 4, 3, 500));
        let c7 = NetworkConfig::table2_case(7);
        assert_eq!((c7.conv_layers, c7.filters, c7.fc_layers, c7.fc_neurons), (10, 12, 7, 2000));
    }

    #[test]
    fn table2_cases_monotone_in_size() {
        let mut prev = 0;
        for case in 1..=7 {
            let count = NetworkConfig::table2_case(case).param_count();
            assert!(count >= prev, "case {case} shrank: {count} < {prev}");
            prev = count;
        }
    }

    #[test]
    #[should_panic(expected = "Table 2")]
    fn table2_case_bounds() {
        NetworkConfig::table2_case(8);
    }

    #[test]
    fn flops_grow_with_network() {
        let small = NetworkConfig::table2_case(1).flops_per_sample();
        let large = NetworkConfig::table2_case(7).flops_per_sample();
        assert!(large > small * 2.0);
    }

    #[test]
    fn network_json_roundtrip() {
        let cfg = NetworkConfig::table2_case(3);
        let j = cfg.to_json();
        let back = NetworkConfig::from_json(&j).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn strategies_parse() {
        assert_eq!(UpdateStrategy::parse("agwu").unwrap(), UpdateStrategy::Agwu);
        assert_eq!(UpdateStrategy::parse("SGWU").unwrap(), UpdateStrategy::Sgwu);
        assert!(UpdateStrategy::parse("x").is_err());
        assert_eq!(PartitionStrategy::parse("idpa").unwrap(), PartitionStrategy::Idpa);
        assert_eq!(PartitionStrategy::parse("uniform").unwrap(), PartitionStrategy::Udpa);
        assert_eq!(OnFailure::parse("continue").unwrap(), OnFailure::Continue);
        assert_eq!(OnFailure::parse("Abort").unwrap(), OnFailure::Abort);
        assert!(OnFailure::parse("retry").is_err());
        assert_eq!(ReplAck::parse("none").unwrap(), ReplAck::None);
        assert_eq!(ReplAck::parse("Standby").unwrap(), ReplAck::Standby);
        assert!(ReplAck::parse("quorum").is_err());
        assert_eq!(ReplAck::default(), ReplAck::None);
    }

    #[test]
    fn heterogeneous_cluster_varies() {
        let c = ClusterConfig::heterogeneous(10, 1);
        assert_eq!(c.size(), 10);
        let freqs: Vec<f64> = c.nodes.iter().map(|n| n.freq_ghz).collect();
        let spread = crate::util::stats::max(&freqs) - crate::util::stats::min(&freqs);
        assert!(spread > 0.1, "expected heterogeneity, spread={spread}");
        let shares = c.frequency_shares();
        assert!((shares.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn heterogeneous_cluster_deterministic_in_seed() {
        let a = ClusterConfig::heterogeneous(5, 7);
        let b = ClusterConfig::heterogeneous(5, 7);
        assert_eq!(a, b);
    }
}
