//! Runtime layer: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them via the PJRT C API (`xla`
//! crate). The device service thread owns the non-`Send` PJRT objects;
//! workers use cloneable handles. `XlaTrainer` plugs the artifacts into the
//! outer-layer cluster as a drop-in [`crate::outer::LocalTrainer`].

pub mod artifacts;
pub mod program;
pub mod service;
pub mod xla_trainer;

pub use artifacts::{artifacts_root, find_model_dir, ArtifactManifest};
pub use program::{Program, ProgramInput, XlaContext};
pub use service::{XlaHandle, XlaService};
pub use xla_trainer::XlaTrainer;
