//! The XLA device service: a dedicated thread owns the (non-`Send`) PJRT
//! client and compiled programs; worker threads talk to it through a
//! cloneable [`XlaHandle`]. This mirrors a real deployment where every
//! computing node has one accelerator runtime serving its training threads.

use std::path::Path;
use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;

use anyhow::{anyhow, Context, Result};

use crate::tensor::{Tensor, WeightSet};

use super::artifacts::ArtifactManifest;
use super::program::{Program, ProgramInput, XlaContext};

enum Request {
    Init {
        seed: i32,
        resp: Sender<Result<WeightSet>>,
    },
    TrainStep {
        weights: WeightSet,
        x: Tensor,
        y: Tensor,
        lr: f32,
        resp: Sender<Result<(WeightSet, f32, f32)>>,
    },
    EvalStep {
        weights: WeightSet,
        x: Tensor,
        y: Tensor,
        resp: Sender<Result<(f32, f32)>>,
    },
    Shutdown,
}

/// Handle to the service; cheap to clone and `Send`.
#[derive(Clone)]
pub struct XlaHandle {
    tx: Sender<Request>,
    pub manifest: ArtifactManifest,
}

// Sender<Request> is Send but not Sync; wrap usage accordingly: each worker
// clones its own handle.
impl XlaHandle {
    /// Run the `init` program → initial weight set.
    pub fn init_weights(&self, seed: i32) -> Result<WeightSet> {
        let (tx, rx) = channel();
        self.tx
            .send(Request::Init { seed, resp: tx })
            .map_err(|_| anyhow!("xla service stopped"))?;
        rx.recv().map_err(|_| anyhow!("xla service dropped reply"))?
    }

    /// Run one SGD step: returns (new weights, loss, correct-count).
    pub fn train_step(
        &self,
        weights: WeightSet,
        x: Tensor,
        y: Tensor,
        lr: f32,
    ) -> Result<(WeightSet, f32, f32)> {
        let (tx, rx) = channel();
        self.tx
            .send(Request::TrainStep { weights, x, y, lr, resp: tx })
            .map_err(|_| anyhow!("xla service stopped"))?;
        rx.recv().map_err(|_| anyhow!("xla service dropped reply"))?
    }

    /// Evaluate one batch: (loss, correct-count).
    pub fn eval_step(&self, weights: WeightSet, x: Tensor, y: Tensor) -> Result<(f32, f32)> {
        let (tx, rx) = channel();
        self.tx
            .send(Request::EvalStep { weights, x, y, resp: tx })
            .map_err(|_| anyhow!("xla service stopped"))?;
        rx.recv().map_err(|_| anyhow!("xla service dropped reply"))?
    }
}

/// The service thread plus its handle.
pub struct XlaService {
    handle: XlaHandle,
    join: Option<JoinHandle<()>>,
    shutdown_tx: Sender<Request>,
}

impl XlaService {
    /// Load the model artifacts in `dir` and start the device thread.
    pub fn start(dir: &Path) -> Result<Self> {
        let manifest = ArtifactManifest::load(dir)?;
        let (tx, rx) = channel::<Request>();
        let m2 = manifest.clone();
        // Compile on the service thread (the context is not Send); report
        // readiness (or failure) through a one-shot channel.
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let join = std::thread::spawn(move || {
            let setup = (|| -> Result<(Program, Program, Program)> {
                let ctx = XlaContext::cpu()?;
                let init = ctx.load_program(&m2.hlo_path("init"))?;
                let train = ctx.load_program(&m2.hlo_path("train_step"))?;
                let eval = ctx.load_program(&m2.hlo_path("eval_step"))?;
                Ok((init, train, eval))
            })();
            let (init, train, eval) = match setup {
                Ok(p) => {
                    let _ = ready_tx.send(Ok(()));
                    p
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            let nparams = m2.params.len();
            while let Ok(req) = rx.recv() {
                match req {
                    Request::Shutdown => break,
                    Request::Init { seed, resp } => {
                        let r = init
                            .run(&[ProgramInput::ScalarI32(seed)])
                            .map(WeightSet::new);
                        let _ = resp.send(r);
                    }
                    Request::TrainStep { weights, x, y, lr, resp } => {
                        let r = (|| {
                            let mut inputs: Vec<ProgramInput> =
                                weights.tensors().iter().map(ProgramInput::Tensor).collect();
                            inputs.push(ProgramInput::Tensor(&x));
                            inputs.push(ProgramInput::Tensor(&y));
                            inputs.push(ProgramInput::ScalarF32(lr));
                            let mut out = train.run(&inputs)?;
                            if out.len() != nparams + 2 {
                                anyhow::bail!(
                                    "train_step returned {} outputs, want {}",
                                    out.len(),
                                    nparams + 2
                                );
                            }
                            let correct = out.pop().unwrap().data()[0];
                            let loss = out.pop().unwrap().data()[0];
                            Ok((WeightSet::new(out), loss, correct))
                        })();
                        let _ = resp.send(r);
                    }
                    Request::EvalStep { weights, x, y, resp } => {
                        let r = (|| {
                            let mut inputs: Vec<ProgramInput> =
                                weights.tensors().iter().map(ProgramInput::Tensor).collect();
                            inputs.push(ProgramInput::Tensor(&x));
                            inputs.push(ProgramInput::Tensor(&y));
                            let out = eval.run(&inputs)?;
                            anyhow::ensure!(out.len() == 2, "eval_step must return 2 outputs");
                            Ok((out[0].data()[0], out[1].data()[0]))
                        })();
                        let _ = resp.send(r);
                    }
                }
            }
        });
        ready_rx
            .recv()
            .context("xla service thread died during setup")??;
        Ok(Self {
            handle: XlaHandle { tx: tx.clone(), manifest },
            join: Some(join),
            shutdown_tx: tx,
        })
    }

    pub fn handle(&self) -> XlaHandle {
        self.handle.clone()
    }
}

impl Drop for XlaService {
    fn drop(&mut self) {
        let _ = self.shutdown_tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

// Gated on the real PJRT backend: with the default stub, `XlaContext::cpu`
// always errors, so these would fail (not skip) on machines that do have
// artifacts built.
#[cfg(all(test, feature = "xla-pjrt"))]
mod tests {
    use super::*;
    use crate::runtime::artifacts::find_model_dir;

    #[test]
    fn service_roundtrip_on_quickstart() {
        let Some(dir) = find_model_dir("quickstart") else {
            eprintln!("skipping: quickstart artifacts not built");
            return;
        };
        let service = XlaService::start(&dir).unwrap();
        let h = service.handle();
        let cfg = h.manifest.config.clone();
        let w0 = h.init_weights(7).unwrap();
        assert_eq!(w0.param_count(), cfg.param_count());

        let x = Tensor::filled(
            &[cfg.batch_size, cfg.input_hw, cfg.input_hw, cfg.in_channels],
            0.1,
        );
        let mut y = Tensor::zeros(&[cfg.batch_size, cfg.num_classes]);
        for i in 0..cfg.batch_size {
            y.data_mut()[i * cfg.num_classes + i % cfg.num_classes] = 1.0;
        }
        let (l0, _c0) = h.eval_step(w0.clone(), x.clone(), y.clone()).unwrap();
        // Several SGD steps must reduce the loss on the fixed batch.
        let mut w = w0;
        let mut last = l0;
        for _ in 0..10 {
            let (nw, l, _) = h.train_step(w, x.clone(), y.clone(), 0.5).unwrap();
            w = nw;
            last = l;
        }
        assert!(last < l0, "XLA training did not reduce loss: {l0} → {last}");
    }

    #[test]
    fn handles_usable_from_other_threads() {
        let Some(dir) = find_model_dir("quickstart") else {
            eprintln!("skipping: quickstart artifacts not built");
            return;
        };
        let service = XlaService::start(&dir).unwrap();
        let handles: Vec<_> = (0..3)
            .map(|seed| {
                let h = service.handle();
                std::thread::spawn(move || h.init_weights(seed).unwrap().param_count())
            })
            .collect();
        for th in handles {
            assert_eq!(th.join().unwrap(), service.handle().manifest.param_count);
        }
    }
}
