//! PJRT program loading and execution (the AOT bridge).
//!
//! The real implementation (feature `xla-pjrt`) loads HLO **text** (the
//! 0.5.1-safe interchange format), compiles it on the PJRT CPU client via the
//! `xla` bindings, and executes it with [`Tensor`] inputs/outputs. All
//! programs were lowered with `return_tuple=True`, so every result is a tuple
//! literal that gets unpacked into a `Vec<Tensor>`.
//!
//! The default build has no PJRT bindings available (the `xla` crate is not
//! in the offline registry), so it ships the stub below: identical API, but
//! [`XlaContext::cpu`] reports the backend as unavailable. Everything
//! downstream (service, trainer, examples, benches) is artifact-gated and
//! skips or errors gracefully. Enabling `xla-pjrt` additionally requires a
//! manual `xla = { path = "..." }` dependency (see Cargo.toml's feature
//! comment) — the feature flag alone cannot pull in an unpublished crate.
//!
//! These types wrap raw PJRT pointers and are **not** `Send`; cross-thread
//! access goes through [`super::service::XlaService`].

use std::path::Path;

use anyhow::Result;

use crate::tensor::Tensor;

/// An input value: an f32 tensor, an f32 scalar, or an i32 scalar (seed).
pub enum ProgramInput<'a> {
    Tensor(&'a Tensor),
    ScalarF32(f32),
    ScalarI32(i32),
}

#[cfg(feature = "xla-pjrt")]
mod imp {
    use super::*;
    use anyhow::Context;

    /// Owner of the PJRT client (one per process/device).
    pub struct XlaContext {
        client: xla::PjRtClient,
    }

    impl XlaContext {
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Self { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile one HLO text file.
        pub fn load_program(&self, path: &Path) -> Result<Program> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            Ok(Program { exe })
        }
    }

    /// One compiled XLA executable.
    pub struct Program {
        exe: xla::PjRtLoadedExecutable,
    }

    impl Program {
        /// Execute with tensor inputs; returns the unpacked output tuple.
        pub fn run(&self, inputs: &[ProgramInput<'_>]) -> Result<Vec<Tensor>> {
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|inp| inp.to_literal())
                .collect::<Result<_>>()?;
            let result = self.exe.execute::<xla::Literal>(&literals)?;
            let out = result[0][0].to_literal_sync()?;
            let parts = out.to_tuple()?;
            parts
                .into_iter()
                .map(|lit| {
                    let shape = lit.array_shape().context("non-array output")?;
                    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                    let data = lit.to_vec::<f32>().context("output not f32")?;
                    Ok(Tensor::from_vec(&dims, data))
                })
                .collect()
        }
    }

    impl ProgramInput<'_> {
        fn to_literal(&self) -> Result<xla::Literal> {
            match self {
                ProgramInput::Tensor(t) => {
                    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
                    let lit = xla::Literal::vec1(t.data());
                    Ok(lit.reshape(&dims)?)
                }
                ProgramInput::ScalarF32(v) => Ok(xla::Literal::scalar(*v)),
                ProgramInput::ScalarI32(v) => Ok(xla::Literal::scalar(*v)),
            }
        }
    }
}

#[cfg(not(feature = "xla-pjrt"))]
mod imp {
    use super::*;

    const UNAVAILABLE: &str = "XLA/PJRT backend unavailable: this build was compiled without the \
         `xla-pjrt` feature (the PJRT bindings are not in the offline registry). \
         Use the native backend instead (`--backend native`).";

    /// Stub owner of the PJRT client. [`XlaContext::cpu`] always errors.
    pub struct XlaContext {
        _private: (),
    }

    impl XlaContext {
        pub fn cpu() -> Result<Self> {
            anyhow::bail!(UNAVAILABLE)
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        /// Unreachable in practice (no context can be constructed).
        pub fn load_program(&self, _path: &Path) -> Result<Program> {
            anyhow::bail!(UNAVAILABLE)
        }
    }

    /// Stub executable; cannot be constructed outside this module.
    pub struct Program {
        _private: (),
    }

    impl Program {
        pub fn run(&self, _inputs: &[ProgramInput<'_>]) -> Result<Vec<Tensor>> {
            anyhow::bail!(UNAVAILABLE)
        }
    }
}

pub use imp::{Program, XlaContext};

#[cfg(all(test, feature = "xla-pjrt"))]
mod tests {
    use super::*;
    use crate::runtime::artifacts::find_model_dir;

    /// End-to-end PJRT smoke test against the real quickstart artifacts
    /// (skips when `make artifacts` has not run).
    #[test]
    fn quickstart_init_and_eval_execute() {
        let Some(dir) = find_model_dir("quickstart") else {
            eprintln!("skipping: quickstart artifacts not built");
            return;
        };
        let manifest = crate::runtime::artifacts::ArtifactManifest::load(&dir).unwrap();
        let ctx = XlaContext::cpu().unwrap();
        let init = ctx.load_program(&manifest.hlo_path("init")).unwrap();
        let weights = init.run(&[ProgramInput::ScalarI32(0)]).unwrap();
        assert_eq!(weights.len(), manifest.params.len());
        for (t, (name, shape)) in weights.iter().zip(&manifest.params) {
            assert_eq!(t.shape(), &shape[..], "{name}");
        }
        // Determinism in the seed.
        let weights2 = init.run(&[ProgramInput::ScalarI32(0)]).unwrap();
        for (a, b) in weights.iter().zip(&weights2) {
            assert_eq!(a.data(), b.data());
        }

        let eval = ctx.load_program(&manifest.hlo_path("eval_step")).unwrap();
        let cfg = &manifest.config;
        let x = Tensor::zeros(&[cfg.batch_size, cfg.input_hw, cfg.input_hw, cfg.in_channels]);
        let mut y = Tensor::zeros(&[cfg.batch_size, cfg.num_classes]);
        for i in 0..cfg.batch_size {
            y.data_mut()[i * cfg.num_classes] = 1.0;
        }
        let mut inputs: Vec<ProgramInput> = weights.iter().map(ProgramInput::Tensor).collect();
        inputs.push(ProgramInput::Tensor(&x));
        inputs.push(ProgramInput::Tensor(&y));
        let out = eval.run(&inputs).unwrap();
        assert_eq!(out.len(), 2); // (loss, correct)
        let loss = out[0].data()[0];
        assert!(loss.is_finite() && loss >= 0.0);
    }
}
