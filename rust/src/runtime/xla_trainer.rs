//! [`LocalTrainer`] backed by the AOT-compiled XLA artifacts: the production
//! compute path. Each worker drives the shared device service through its
//! own [`XlaHandle`]; Python never runs.

use std::ops::Range;
use std::sync::Arc;
use std::time::Instant;

use crate::data::Dataset;
use crate::outer::worker::{EpochOutcome, LocalTrainer};
use crate::tensor::{Tensor, WeightSet};

use super::service::XlaHandle;

/// XLA-backed node-local trainer.
pub struct XlaTrainer {
    handle: XlaHandle,
    data: Arc<Dataset>,
    indices: Vec<usize>,
    lr: f32,
    pub slowdown: f64,
}

impl XlaTrainer {
    pub fn new(handle: XlaHandle, data: Arc<Dataset>, lr: f32) -> Self {
        Self { handle, data, indices: Vec::new(), lr, slowdown: 1.0 }
    }

    pub fn with_slowdown(mut self, factor: f64) -> Self {
        assert!(factor >= 1.0);
        self.slowdown = factor;
        self
    }

    fn gather(&self, offset: usize, bsz: usize) -> (Tensor, Tensor) {
        let cfg = &self.handle.manifest.config;
        let pix = self.data.hw * self.data.hw * self.data.channels;
        let classes = self.data.num_classes;
        let mut x = Vec::with_capacity(bsz * pix);
        let mut y = vec![0.0f32; bsz * classes];
        for i in 0..bsz {
            let idx = self.indices[(offset + i) % self.indices.len()];
            x.extend_from_slice(&self.data.images[idx]);
            y[i * classes + self.data.labels[idx]] = 1.0;
        }
        (
            Tensor::from_vec(&[bsz, cfg.input_hw, cfg.input_hw, cfg.in_channels], x),
            Tensor::from_vec(&[bsz, classes], y),
        )
    }
}

impl LocalTrainer for XlaTrainer {
    fn train_epoch(&mut self, start: Arc<WeightSet>) -> EpochOutcome {
        assert!(!self.indices.is_empty(), "worker has no samples (allocate first)");
        let t0 = Instant::now();
        let bsz = self.handle.manifest.config.batch_size;
        // Copy-on-write on the shared server snapshot.
        let mut weights = Arc::try_unwrap(start).unwrap_or_else(|shared| (*shared).clone());
        let mut seen = 0usize;
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut batches = 0usize;
        while seen < self.indices.len() {
            let take = bsz.min(self.indices.len() - seen);
            let (x, y) = self.gather(seen, bsz);
            let (w, loss, corr) = self
                .handle
                .train_step(weights, x, y, self.lr)
                .expect("xla train_step failed");
            weights = w;
            loss_sum += loss as f64;
            correct += (corr as f64).min(take as f64);
            seen += take;
            batches += 1;
        }
        let compute = t0.elapsed().as_secs_f64();
        if self.slowdown > 1.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(
                compute * (self.slowdown - 1.0),
            ));
        }
        EpochOutcome {
            weights,
            loss: loss_sum / batches.max(1) as f64,
            accuracy: correct / self.indices.len() as f64,
            samples: self.indices.len(),
            compute_s: t0.elapsed().as_secs_f64(),
        }
    }

    fn add_samples(&mut self, range: Range<usize>) {
        self.indices.extend(range);
    }

    fn sample_count(&self) -> usize {
        self.indices.len()
    }
}

// Gated like service.rs's tests: the default stub build cannot execute
// artifacts, so these must not compile into a default `cargo test`.
#[cfg(all(test, feature = "xla-pjrt"))]
mod tests {
    use super::*;
    use crate::config::NetworkConfig;
    use crate::nn::Network;
    use crate::runtime::artifacts::find_model_dir;
    use crate::runtime::service::XlaService;

    #[test]
    fn xla_trainer_epoch_learns() {
        let Some(dir) = find_model_dir("quickstart") else {
            eprintln!("skipping: quickstart artifacts not built");
            return;
        };
        let service = XlaService::start(&dir).unwrap();
        let cfg = service.handle().manifest.config.clone();
        let ds = Arc::new(Dataset::synthetic(&cfg, 64, 0.2, 51));
        let mut w = XlaTrainer::new(service.handle(), ds, 0.3);
        w.add_samples(0..32);
        let mut weights = service.handle().init_weights(1).unwrap();
        let mut losses = Vec::new();
        for _ in 0..5 {
            let out = w.train_epoch(Arc::new(weights));
            weights = out.weights.clone();
            losses.push(out.loss);
        }
        assert!(
            losses.last().unwrap() < &losses[0],
            "XLA epochs did not learn: {losses:?}"
        );
    }

    /// Cross-backend parity: the XLA artifacts and the native Rust network
    /// implement the same model — same weights + same batch ⇒ same loss.
    #[test]
    fn xla_eval_matches_native_eval() {
        let Some(dir) = find_model_dir("quickstart") else {
            eprintln!("skipping: quickstart artifacts not built");
            return;
        };
        let service = XlaService::start(&dir).unwrap();
        let h = service.handle();
        let cfg: NetworkConfig = h.manifest.config.clone();
        let ds = Dataset::synthetic(&cfg, 32, 0.2, 52);
        let weights = h.init_weights(3).unwrap();

        let (xv, yv, _) = ds.batch(0, cfg.batch_size);
        let x = Tensor::from_vec(
            &[cfg.batch_size, cfg.input_hw, cfg.input_hw, cfg.in_channels],
            xv.clone(),
        );
        let y = Tensor::from_vec(&[cfg.batch_size, cfg.num_classes], yv.clone());
        let (xla_loss, xla_correct) = h.eval_step(weights.clone(), x, y).unwrap();

        let net = Network::with_weights(&cfg, weights);
        let (native_loss, native_correct) = net.eval_batch(&xv, &yv, cfg.batch_size);

        assert!(
            (xla_loss - native_loss).abs() < 1e-3,
            "loss mismatch: xla={xla_loss} native={native_loss}"
        );
        assert_eq!(xla_correct as usize, native_correct, "correct-count mismatch");
    }
}
