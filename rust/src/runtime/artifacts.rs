//! Artifact manifests: the `meta.json` each AOT-compiled model directory
//! carries (written by `python/compile/aot.py`). The manifest is the wire
//! contract between the coordinator and the HLO programs: parameter order,
//! shapes, batch geometry.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::NetworkConfig;
use crate::util::json::Json;

/// Parsed `meta.json`.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub config: NetworkConfig,
    /// Ordered (name, shape) parameter manifest.
    pub params: Vec<(String, Vec<usize>)>,
    pub param_count: usize,
    pub dir: PathBuf,
}

impl ArtifactManifest {
    /// Load `<dir>/meta.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {}", meta_path.display()))?;
        let json = Json::parse(&text).with_context(|| format!("parsing {}", meta_path.display()))?;
        let config = NetworkConfig::from_json(json.get("config"))?;
        let params_json = json
            .get("params")
            .as_arr()
            .context("meta.json: missing params[]")?;
        let mut params = Vec::with_capacity(params_json.len());
        for p in params_json {
            let name = p.get("name").as_str().context("param missing name")?.to_string();
            let shape: Vec<usize> = p
                .get("shape")
                .as_arr()
                .context("param missing shape")?
                .iter()
                .map(|d| d.as_usize().context("non-integer dim"))
                .collect::<Result<_>>()?;
            params.push((name, shape));
        }
        let param_count = json
            .get("param_count")
            .as_usize()
            .context("meta.json: missing param_count")?;
        let manifest = Self { config, params, param_count, dir: dir.to_path_buf() };
        manifest.validate()?;
        Ok(manifest)
    }

    /// Cross-check the manifest against the Rust-side config derivation —
    /// catches drift between `model.py::param_shapes` and
    /// `NetworkConfig::param_shapes`.
    pub fn validate(&self) -> Result<()> {
        let expect = self.config.param_shapes();
        if expect.len() != self.params.len() {
            bail!(
                "manifest lists {} params, config derives {}",
                self.params.len(),
                expect.len()
            );
        }
        for ((en, es), (mn, ms)) in expect.iter().zip(self.params.iter()) {
            if en != mn || es != ms {
                bail!("param mismatch: manifest {mn}{ms:?} vs config {en}{es:?}");
            }
        }
        let total: usize = self
            .params
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum();
        if total != self.param_count {
            bail!("param_count {} != shapes total {}", self.param_count, total);
        }
        Ok(())
    }

    pub fn hlo_path(&self, entry: &str) -> PathBuf {
        self.dir.join(format!("{entry}.hlo.txt"))
    }
}

/// Root artifacts directory: `$BPTCNN_ARTIFACTS` or `./artifacts`.
pub fn artifacts_root() -> PathBuf {
    std::env::var("BPTCNN_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Directory for a named model config, if its artifacts exist.
pub fn find_model_dir(name: &str) -> Option<PathBuf> {
    let dir = artifacts_root().join(name);
    if dir.join("meta.json").exists() && dir.join("train_step.hlo.txt").exists() {
        Some(dir)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, text: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("meta.json"), text).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("bptcnn_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn loads_valid_manifest() {
        let dir = tmpdir("valid");
        // quickstart config: conv0 3x3x1x4 + bias + fc0 64x32 + bias + out 32x10 + bias.
        write_manifest(
            &dir,
            r#"{
              "config": {"name":"quickstart","input_hw":8,"in_channels":1,
                "conv_layers":1,"filters":4,"kernel_hw":3,"fc_layers":1,
                "fc_neurons":32,"num_classes":10,"batch_size":8,"pool_window":2},
              "params": [
                {"name":"conv0.filter","shape":[3,3,1,4]},
                {"name":"conv0.bias","shape":[4]},
                {"name":"fc0.weight","shape":[64,32]},
                {"name":"fc0.bias","shape":[32]},
                {"name":"out.weight","shape":[32,10]},
                {"name":"out.bias","shape":[10]}
              ],
              "param_count": 2450
            }"#,
        );
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.config.name, "quickstart");
        assert_eq!(m.param_count, 2450);
        assert_eq!(m.params.len(), 6);
        assert!(m.hlo_path("train_step").ends_with("train_step.hlo.txt"));
    }

    #[test]
    fn rejects_mismatched_manifest() {
        let dir = tmpdir("bad");
        write_manifest(
            &dir,
            r#"{
              "config": {"name":"quickstart","input_hw":8,"in_channels":1,
                "conv_layers":1,"filters":4,"kernel_hw":3,"fc_layers":1,
                "fc_neurons":32,"num_classes":10,"batch_size":8,"pool_window":2},
              "params": [{"name":"conv0.filter","shape":[3,3,1,8]}],
              "param_count": 72
            }"#,
        );
        assert!(ArtifactManifest::load(&dir).is_err());
    }

    #[test]
    fn missing_dir_errors() {
        assert!(ArtifactManifest::load(Path::new("/nonexistent/xyz")).is_err());
    }

    #[test]
    fn real_artifacts_validate_when_present() {
        for name in ["quickstart", "e2e"] {
            if let Some(dir) = find_model_dir(name) {
                let m = ArtifactManifest::load(&dir).unwrap();
                assert_eq!(m.config.name, name);
            }
        }
    }
}
