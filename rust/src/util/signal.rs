//! Minimal POSIX signal handling for graceful shutdown, without any
//! external crate: a raw `signal(2)` FFI binding installs a handler that
//! does nothing but raise a process-global flag (one atomic store — the
//! only async-signal-safe thing a handler should do). A small watcher
//! thread mirrors the flag into the `Arc<AtomicBool>` that serving loops
//! poll between accepts, so the actual shutdown work (stop accepting,
//! drain in-flight submits, final checkpoint) runs in ordinary code.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Duration;

/// POSIX signal numbers (identical on Linux and the BSDs).
pub const SIGINT: i32 = 2;
pub const SIGTERM: i32 = 15;

extern "C" {
    /// `signal(2)`: install `handler` for `signum`, returning the previous
    /// disposition (`SIG_ERR` = `usize::MAX` on failure).
    fn signal(signum: i32, handler: usize) -> usize;
    /// `kill(2)`: send signal `sig` to process `pid`.
    fn kill(pid: i32, sig: i32) -> i32;
}

/// Send `sig` to process `pid` via `kill(2)`. Process-level tests use this
/// to deliver SIGTERM to a spawned server — std's `Child::kill` can only
/// send SIGKILL, which is exactly the wrong signal for a graceful-shutdown
/// test.
pub fn send_signal(pid: u32, sig: i32) -> std::io::Result<()> {
    // SAFETY: kill(2) takes plain integers and has no memory-safety
    // preconditions; failure is reported through the -1 return and errno.
    let rc = unsafe { kill(pid as i32, sig) };
    if rc == 0 {
        Ok(())
    } else {
        Err(std::io::Error::last_os_error())
    }
}

/// Process-global "a termination signal arrived" flag — the only thing
/// the handler touches.
static REQUESTED: AtomicBool = AtomicBool::new(false);

/// The flag handed to serving loops; initialized once with the handlers.
static SHARED: OnceLock<Arc<AtomicBool>> = OnceLock::new();

/// The installed handler. Only async-signal-safe operations are allowed
/// here: a single atomic store qualifies, and nothing else happens.
extern "C" fn on_signal(_signum: i32) {
    REQUESTED.store(true, Ordering::SeqCst);
}

/// Install SIGTERM/SIGINT handlers (idempotent) and return the shared
/// shutdown flag they raise. The first signal flips the flag so serving
/// loops can drain gracefully; the handler stays installed, so the
/// process never falls back to the default die-instantly disposition.
pub fn install_shutdown_handler() -> Arc<AtomicBool> {
    Arc::clone(SHARED.get_or_init(|| {
        // SAFETY: `on_signal` is an `extern "C" fn(i32)` matching the
        // sighandler_t ABI, performs only an atomic store (async-signal-
        // safe), and lives for the whole program, so handing its address
        // to signal(2) is sound. An install failure (SIG_ERR) just leaves
        // the default disposition in place.
        unsafe {
            signal(SIGTERM, on_signal as extern "C" fn(i32) as usize);
            signal(SIGINT, on_signal as extern "C" fn(i32) as usize);
        }
        let flag = Arc::new(AtomicBool::new(false));
        // Mirror the handler's static into the Arc the serving loop polls.
        // The handler itself must not touch the Arc (not signal-safe to
        // race its initialization), so a detached watcher bridges the two.
        let mirror = Arc::clone(&flag);
        std::thread::Builder::new()
            .name("signal-watcher".into())
            .spawn(move || loop {
                if REQUESTED.load(Ordering::SeqCst) {
                    mirror.store(true, Ordering::SeqCst);
                    return;
                }
                std::thread::sleep(Duration::from_millis(20));
            })
            .expect("spawn signal watcher");
        flag
    }))
}

/// True once a SIGTERM/SIGINT arrived.
pub fn shutdown_requested() -> bool {
    REQUESTED.load(Ordering::SeqCst)
}

#[cfg(test)]
mod tests {
    use super::*;

    extern "C" {
        fn raise(signum: i32) -> i32;
    }

    #[test]
    fn handler_raises_the_flag_on_sigterm() {
        let flag = install_shutdown_handler();
        // SAFETY: raise(3) delivers SIGTERM to this process; the handler
        // installed above replaces the default death disposition with an
        // atomic store, so the test process survives and observes it.
        let rc = unsafe { raise(SIGTERM) };
        assert_eq!(rc, 0);
        assert!(shutdown_requested());
        // The watcher mirrors the handler's static into the shared flag.
        let t0 = std::time::Instant::now();
        while !flag.load(Ordering::SeqCst) {
            assert!(t0.elapsed() < Duration::from_secs(2), "watcher never mirrored");
            std::thread::sleep(Duration::from_millis(10));
        }
    }
}
